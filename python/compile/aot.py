"""AOT lowering: JAX/Pallas → HLO **text** artifacts for the rust runtime.

HLO text (not `.serialize()`) is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Artifacts (written to --out, default ../artifacts):
  mmdit_step.hlo.txt     — one dense denoising step; params are runtime
                           inputs in sorted-name order (mmdit_step.params.json).
  attention_masked.hlo.txt — single-head Pallas FlashOmni attention
                           (q, k, v, s_c, s_s int32 packed symbols).
  gemm_q.hlo.txt         — Pallas sparse query projection.
  gemm_o.hlo.txt         — Pallas dispatch-step sparse output projection.
  golden.fot             — example inputs + expected outputs for every
                           artifact (rust integration tests assert both the
                           PJRT path and the native kernels reproduce them).

Usage:  cd python && python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import fot
from .kernels.flashomni_attention import flashomni_attention_head
from .kernels.ref import gemm_o_bias_ref, masked_attention_ref
from .kernels.sparse_gemm import gemm_o_dispatch, gemm_q
from .kernels.symbols import encode_symbols
from .model import Config, forward, init_params


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_mmdit_step(cfg: Config, params: dict, out_dir: str, golden: dict) -> None:
    names = sorted(params.keys())

    def step(flat_params, text_ids, patches, t):
        p = dict(zip(names, flat_params))
        return (forward(p, cfg, text_ids, patches, t),)

    flat = [params[n] for n in names]
    specs = tuple(jax.ShapeDtypeStruct(a.shape, a.dtype) for a in flat)
    ids_spec = jax.ShapeDtypeStruct((cfg.text_tokens,), jnp.int32)
    patch_spec = jax.ShapeDtypeStruct((cfg.vision_tokens, cfg.patch_dim), jnp.float32)
    t_spec = jax.ShapeDtypeStruct((), jnp.float32)
    # keep_unused: every parameter must survive lowering so the rust side
    # can bind the full sorted-name list positionally.
    lowered = jax.jit(step, keep_unused=True).lower(specs, ids_spec, patch_spec, t_spec)
    text = to_hlo_text(lowered)
    with open(os.path.join(out_dir, "mmdit_step.hlo.txt"), "w") as f:
        f.write(text)
    with open(os.path.join(out_dir, "mmdit_step.params.json"), "w") as f:
        json.dump({"order": names, "config": cfg.to_meta()}, f, indent=1)

    # Golden vector.
    rng = np.random.default_rng(42)
    ids = rng.integers(0, cfg.vocab, size=cfg.text_tokens).astype(np.int32)
    patches = rng.normal(size=(cfg.vision_tokens, cfg.patch_dim)).astype(np.float32)
    t = np.float32(0.5)
    (vel,) = jax.jit(step)(flat, ids, patches, t)
    golden["mmdit.ids"] = ids
    golden["mmdit.patches"] = patches
    golden["mmdit.t"] = np.array([0.5], dtype=np.float32)
    golden["mmdit.velocity"] = np.asarray(vel)


def lower_attention(cfg: Config, out_dir: str, golden: dict) -> None:
    n, d = cfg.seq_len, cfg.head_dim
    bq = bk = 8
    qg, kg = n // bq, n // bk
    sc_bytes = (qg + 7) // 8
    ss_bytes = (kg + 7) // 8

    def attn(q, k, v, s_c, s_s):
        return (flashomni_attention_head(q, k, v, s_c, s_s, block_q=bq, block_k=bk),)

    f32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)
    i32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)
    lowered = jax.jit(attn).lower(f32(n, d), f32(n, d), f32(n, d), i32(sc_bytes), i32(qg, ss_bytes))
    with open(os.path.join(out_dir, "attention_masked.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))

    rng = np.random.default_rng(7)
    q = rng.normal(size=(n, d)).astype(np.float32)
    k = rng.normal(size=(n, d)).astype(np.float32)
    v = rng.normal(size=(n, d)).astype(np.float32)
    m_c = rng.random(qg) < 0.7
    m_s = rng.random((qg, kg)) < 0.6
    s_c, s_s = encode_symbols(m_c, m_s)
    (o,) = jax.jit(attn)(q, k, v, s_c.astype(np.int32), s_s.astype(np.int32))
    golden["attn.q"] = q
    golden["attn.k"] = k
    golden["attn.v"] = v
    golden["attn.s_c"] = s_c  # u8 packed (rust re-encodes to i32 for PJRT)
    golden["attn.s_s"] = s_s
    golden["attn.block"] = np.array([bq, bk], dtype=np.int32)
    golden["attn.out"] = np.asarray(o)
    # Cross-check vs the pure-jnp oracle.
    ref = masked_attention_ref(q, k, v, m_c, m_s, bq, bk)
    assert float(jnp.max(jnp.abs(o - ref))) < 1e-4


def lower_gemms(cfg: Config, out_dir: str, golden: dict) -> None:
    n, heads = cfg.seq_len, cfg.heads
    d, dh = cfg.dim, cfg.head_dim
    bq = 8
    qg = n // bq
    sc_bytes = (qg + 7) // 8

    def gq(x, w, s_c):
        return (gemm_q(x, w, s_c, heads=heads, block_q=bq),)

    def go(o, w, bias, s_c):
        return (gemm_o_dispatch(o, w, bias, s_c, heads=heads, block_q=bq),)

    f32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)
    i32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)
    lowered = jax.jit(gq).lower(f32(n, d), f32(d, d), i32(heads, sc_bytes))
    with open(os.path.join(out_dir, "gemm_q.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))
    lowered = jax.jit(go).lower(f32(n, d), f32(d, d), f32(n, d), i32(heads, sc_bytes))
    with open(os.path.join(out_dir, "gemm_o.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))

    rng = np.random.default_rng(11)
    x = rng.normal(size=(n, d)).astype(np.float32)
    w = rng.normal(size=(d, d)).astype(np.float32)
    m_c_heads = rng.random((heads, qg)) < 0.5
    s_c = np.stack(
        [encode_symbols(m_c_heads[h], np.ones((qg, 1), bool))[0] for h in range(heads)]
    )
    (y,) = jax.jit(gq)(x, w, s_c.astype(np.int32))
    golden["gq.x"] = x
    golden["gq.w"] = w
    golden["gq.s_c"] = s_c
    golden["gq.out"] = np.asarray(y)

    o = rng.normal(size=(n, heads * dh)).astype(np.float32)
    wo = rng.normal(size=(heads * dh, d)).astype(np.float32)
    bias = np.asarray(gemm_o_bias_ref(o, wo, m_c_heads, bq))
    (out,) = jax.jit(go)(o, wo, bias, s_c.astype(np.int32))
    golden["go.o"] = o
    golden["go.w"] = wo
    golden["go.bias"] = bias
    golden["go.out"] = np.asarray(out)
    # Eq. 3 exactness: bias + computed tiles == dense projection.
    assert float(np.max(np.abs(out - o @ wo))) < 1e-3


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", type=str, default="../artifacts")
    ap.add_argument("--weights", type=str, default=None,
                    help="weights.fot to embed in the golden step (default: <out>/weights.fot if present)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    cfg = Config()
    wpath = args.weights or os.path.join(args.out, "weights.fot")
    if os.path.exists(wpath):
        tensors, meta = fot.load(wpath)
        cfg = Config(**meta["config"])
        params = {k: jnp.asarray(v) for k, v in tensors.items()}
        src = wpath
    else:
        params = init_params(cfg, seed=0)
        src = "init(seed=0)"
    golden: dict[str, np.ndarray] = {}
    lower_mmdit_step(cfg, params, args.out, golden)
    lower_attention(cfg, args.out, golden)
    lower_gemms(cfg, args.out, golden)
    fot.save(os.path.join(args.out, "golden.fot"), golden,
             meta={"weights": src, "config": cfg.to_meta()})
    print(f"artifacts written to {args.out} (weights source: {src})")


if __name__ == "__main__":
    main()
