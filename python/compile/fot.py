"""`.fot` tensor container — python twin of `rust/src/util/fot.rs`.

Layout: magic ``FOT1`` | u64-le header length | JSON header | raw payload.
Header: ``{"tensors": {name: {dtype, shape, offset, nbytes}}, "meta": {...}}``.
Dtypes: f32, u8, i32 (little-endian).
"""

from __future__ import annotations

import json
import struct

import numpy as np

MAGIC = b"FOT1"
_DTYPES = {"f32": np.float32, "u8": np.uint8, "i32": np.int32}
_NAMES = {np.dtype(np.float32): "f32", np.dtype(np.uint8): "u8", np.dtype(np.int32): "i32"}


def save(path: str, tensors: dict[str, np.ndarray], meta: dict | None = None) -> None:
    """Write named tensors + metadata to a .fot file."""
    header: dict = {"tensors": {}, "meta": meta or {}}
    blobs = []
    offset = 0
    for name in sorted(tensors):
        arr = np.ascontiguousarray(tensors[name])
        dname = _NAMES.get(arr.dtype)
        if dname is None:
            arr = arr.astype(np.float32)
            dname = "f32"
        raw = arr.tobytes()
        header["tensors"][name] = {
            "dtype": dname,
            "shape": list(arr.shape),
            "offset": offset,
            "nbytes": len(raw),
        }
        blobs.append(raw)
        offset += len(raw)
    hjson = json.dumps(header, separators=(",", ":")).encode()
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<Q", len(hjson)))
        f.write(hjson)
        for b in blobs:
            f.write(b)


def load(path: str) -> tuple[dict[str, np.ndarray], dict]:
    """Read a .fot file → (tensors, meta)."""
    with open(path, "rb") as f:
        data = f.read()
    if data[:4] != MAGIC:
        raise ValueError(f"{path}: not a FOT1 file")
    (hlen,) = struct.unpack("<Q", data[4:12])
    header = json.loads(data[12 : 12 + hlen])
    body = data[12 + hlen :]
    out = {}
    for name, spec in header["tensors"].items():
        dt = _DTYPES[spec["dtype"]]
        arr = np.frombuffer(
            body, dtype=dt, count=spec["nbytes"] // np.dtype(dt).itemsize, offset=spec["offset"]
        )
        out[name] = arr.reshape(spec["shape"]).copy()
    return out, header.get("meta", {})
