"""Export trained JAX parameters to `artifacts/weights.fot` for the rust
engine (names already match the rust loader)."""

from __future__ import annotations

import numpy as np

from . import fot
from .model import Config


def export_weights(params: dict, cfg: Config, path: str) -> None:
    tensors = {name: np.asarray(arr, dtype=np.float32) for name, arr in params.items()}
    fot.save(path, tensors, meta={"config": cfg.to_meta(), "format": "minimmdit-v1"})


def load_weights(path: str) -> tuple[dict, Config]:
    tensors, meta = fot.load(path)
    cfg = Config(**meta["config"])
    return tensors, cfg
