"""Pure-jnp oracles for the Pallas kernels (the CORE correctness signal).

Masked semantics identical to `rust/src/kernels/attention.rs::
masked_reference`: skipped (Q,K) block pairs contribute −inf before softmax;
cached Q blocks output zeros (GEMM-O bias path — the cached rows are never
materialized).
"""

from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np


def masked_attention_ref(q, k, v, m_c, m_s, block_q, block_k):
    """q,k,v: [N, d]; m_c: [q_groups] bool; m_s: [q_groups, kv_groups] bool
    (pool folded into the block sizes). Returns [N, d]."""
    n, d = q.shape
    n_kv = k.shape[0]
    scale = 1.0 / math.sqrt(d)
    row_groups = np.arange(n) // block_q
    col_groups = np.arange(n_kv) // block_k
    keep = np.asarray(m_s)[row_groups][:, col_groups]  # [N, N_kv] bool
    s = (q @ k.T) * scale
    s = jnp.where(jnp.asarray(keep), s, -jnp.inf)
    # Rows with no kept block → all -inf → softmax NaN; guard with where.
    mx = jnp.max(s, axis=-1, keepdims=True)
    mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
    e = jnp.exp(s - mx)
    e = jnp.where(jnp.asarray(keep), e, 0.0)
    denom = jnp.sum(e, axis=-1, keepdims=True)
    p = jnp.where(denom > 0, e / jnp.maximum(denom, 1e-30), 0.0)
    o = p @ v
    computed_rows = jnp.asarray(np.asarray(m_c)[row_groups], dtype=q.dtype)[:, None]
    return o * computed_rows


def gemm_q_ref(x, w, m_c_heads, block_q):
    """x: [N, din]; w: [din, H*dh]; m_c_heads: [H, q_groups] bool.
    Skipped (block, head) tiles are zero."""
    n = x.shape[0]
    heads = m_c_heads.shape[0]
    d_out = w.shape[1]
    dh = d_out // heads
    y = x @ w
    row_groups = np.arange(n) // block_q
    mask = np.zeros((n, d_out), dtype=np.float32)
    for h in range(heads):
        mask[:, h * dh : (h + 1) * dh] = np.asarray(m_c_heads)[h][row_groups][:, None]
    return y * jnp.asarray(mask)


def gemm_o_dispatch_ref(o_cat, w, m_c_heads, block_q, bias):
    """Out = bias + Σ_{computed tiles} O^h W^h."""
    n = o_cat.shape[0]
    heads = m_c_heads.shape[0]
    d_cat = o_cat.shape[1]
    dh = d_cat // heads
    row_groups = np.arange(n) // block_q
    out = jnp.asarray(bias)
    for h in range(heads):
        sel = jnp.asarray(np.asarray(m_c_heads)[h][row_groups], dtype=o_cat.dtype)[:, None]
        oh = o_cat[:, h * dh : (h + 1) * dh] * sel
        out = out + oh @ w[h * dh : (h + 1) * dh, :]
    return out


def gemm_o_bias_ref(o_cat, w, m_c_heads, block_q):
    """B_c = Σ_{cached tiles} O^h W^h (stage 1 of the Update step)."""
    n = o_cat.shape[0]
    heads = m_c_heads.shape[0]
    dh = o_cat.shape[1] // heads
    row_groups = np.arange(n) // block_q
    bias = jnp.zeros((n, w.shape[1]), dtype=o_cat.dtype)
    for h in range(heads):
        sel = jnp.asarray(~np.asarray(m_c_heads)[h][row_groups], dtype=o_cat.dtype)[:, None]
        oh = o_cat[:, h * dh : (h + 1) * dh] * sel
        bias = bias + oh @ w[h * dh : (h + 1) * dh, :]
    return bias


def taylor_forecast_ref(stack, k):
    """TaylorSeer: Σ_d k^d/d! · stack[d]."""
    out = jnp.zeros_like(stack[0])
    coeff = 1.0
    for d, s in enumerate(stack):
        if d > 0:
            coeff *= k / d
        out = out + coeff * s
    return out
