"""Sparse-symbol packing helpers — python twin of `rust/src/symbols`.

Bits are packed MSB-first within each byte (paper Fig. 5: mask [1,1,1,0,0]
→ 0b1110_0000 = 224). `True` = compute, `False` = cache/skip.
"""

from __future__ import annotations

import numpy as np


def pack_bits(bits: np.ndarray) -> np.ndarray:
    """Bool array → uint8 array, MSB-first."""
    bits = np.asarray(bits, dtype=bool)
    n = len(bits)
    out = np.zeros((n + 7) // 8, dtype=np.uint8)
    for i, b in enumerate(bits):
        if b:
            out[i // 8] |= 1 << (7 - i % 8)
    return out


def unpack_bits(packed: np.ndarray, n: int) -> np.ndarray:
    """uint8 array → bool array of length n, MSB-first."""
    packed = np.asarray(packed, dtype=np.uint8)
    bits = np.unpackbits(packed)  # MSB-first by default
    return bits[:n].astype(bool)


def encode_symbols(m_c: np.ndarray, m_s: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Logical masks → packed symbols.

    m_c: [q_groups] bool; m_s: [q_groups, kv_groups] bool.
    Returns (s_c [ceil(qg/8)] u8, s_s [qg, ceil(kg/8)] u8) — S_s packed
    row-wise so each CTA's row decode touches contiguous bytes.
    """
    m_c = np.asarray(m_c, dtype=bool)
    m_s = np.asarray(m_s, dtype=bool)
    qg, kg = m_s.shape
    assert m_c.shape == (qg,)
    s_c = pack_bits(m_c)
    s_s = np.stack([pack_bits(m_s[i]) for i in range(qg)])
    return s_c, s_s


def decode_f(s_c: np.ndarray, i: int, pool: int = 1) -> bool:
    """Spatial decode F(S_c, i) for raw block index i."""
    g = i // pool
    return bool((s_c[g // 8] >> (7 - g % 8)) & 1)


def decode_j(s_s: np.ndarray, i: int, j: int, pool: int = 1) -> bool:
    """Reduction decode J(S_s, i, j) for raw block indices (row-packed)."""
    gi, gj = i // pool, j // pool
    return bool((s_s[gi, gj // 8] >> (7 - gj % 8)) & 1)
