"""Layer-1: the FlashOmni **general sparse attention kernel** in Pallas.

One `pallas_call` grid step = one CTA of the paper's Algorithm 1: it decodes
the spatial symbol `F(S_c, i)` once, decodes the reduction-axis symbol row
`J(S_s, i, ·)` bytewise (the "up to 8n consecutive blocks per decode"
register trick becomes a vectorized unpack of the row before the K loop),
and computes the masked online attention for its Q tile.

TPU-adaptation notes (DESIGN.md §Hardware-Adaptation):
* CUDA CTA grid → `pallas_call` grid over Q blocks; `BlockSpec` expresses
  the HBM→VMEM tile schedule the paper wrote with threadblocks.
* The symbol vectors are tiny (`ceil(T/8)` bytes/row) and live wholly in
  VMEM; decode is vector integer ops on the VPU, not CUDA-core scalar work.
* `interpret=True` is REQUIRED on this CPU image: real TPU lowering emits a
  Mosaic custom-call the CPU PJRT plugin cannot execute. Under interpret
  mode the grid is dense, so skipping is expressed as masking — identical
  numerics, no wall-clock savings (the rust twin provides those). On a real
  TPU the same kernel would move `S_c`/`S_s` to scalar-prefetch operands
  (`pltpu.PrefetchScalarGridSpec`) and skip K tiles for real.

Symbols are passed as int32 (one byte value per element) because the rust
PJRT bridge has no u8 literal support; the bitwise decode is unchanged.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, sc_ref, ss_ref, o_ref, *, block_k, q_groups,
                 kv_groups, pool):
    i = pl.program_id(0)  # Q-block index == CTA id
    g = i // pool
    # --- spatial-axis decode F(S_c, i), once per CTA (Alg. 1 line 5) ---
    f_bit = (sc_ref[g // 8] >> (7 - g % 8)) & 1

    q = q_ref[...]  # [block_q, d]
    k = k_ref[...]  # [N_kv, d]
    v = v_ref[...]
    d = q.shape[-1]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * (1.0 / math.sqrt(d))

    # --- reduction-axis decode J(S_s, i, ·): bytewise row unpack ---
    row = ss_ref[g, :]  # [ceil(kv_groups/8)] int32 byte values
    shifts = 7 - jnp.arange(8, dtype=row.dtype)
    bits = ((row[:, None] >> shifts[None, :]) & 1).reshape(-1)[:kv_groups]
    keep = jnp.repeat(bits, block_k * pool)[: s.shape[1]]  # per-token mask

    s = jnp.where(keep[None, :] == 1, s, -jnp.inf)
    mx = jnp.max(s, axis=-1, keepdims=True)
    mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
    e = jnp.where(keep[None, :] == 1, jnp.exp(s - mx), 0.0)
    denom = jnp.sum(e, axis=-1, keepdims=True)
    p = jnp.where(denom > 0, e / jnp.maximum(denom, 1e-30), 0.0)
    o = jnp.dot(p, v, preferred_element_type=jnp.float32)
    # Cached CTAs (F = 0) write zeros: the GEMM-O bias path reconstructs
    # their projected contribution, so the element-wise reuse write is
    # skipped entirely (§3.5 Obs. 3).
    o_ref[...] = o * f_bit.astype(o.dtype)


def flashomni_attention_head(q, k, v, s_c, s_s, *, block_q, block_k, pool=1,
                             interpret=True):
    """Single-head FlashOmni attention.

    q, k, v: [N, d] f32; s_c: [ceil(q_groups/8)] int32 packed bytes;
    s_s: [q_groups, ceil(kv_groups/8)] int32. Returns [N, d].
    """
    n, d = q.shape
    n_kv = k.shape[0]
    assert n % block_q == 0, "N must divide block_q for the Pallas grid"
    t_q = n // block_q
    t_kv = -(-n_kv // block_k)
    q_groups = -(-t_q // pool)
    kv_groups = -(-t_kv // pool)
    kernel = functools.partial(
        _attn_kernel, block_k=block_k, q_groups=q_groups, kv_groups=kv_groups, pool=pool
    )
    return pl.pallas_call(
        kernel,
        grid=(t_q,),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i: (i, 0)),
            pl.BlockSpec((n_kv, d), lambda i: (0, 0)),
            pl.BlockSpec((n_kv, d), lambda i: (0, 0)),
            pl.BlockSpec(s_c.shape, lambda i: (0,)),
            pl.BlockSpec(s_s.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), q.dtype),
        interpret=interpret,
    )(q, k, v, s_c, s_s)


def flashomni_attention(q, k, v, s_c, s_s, *, heads, block_q, block_k, pool=1,
                        interpret=True):
    """Multi-head wrapper: q/k/v [N, heads*dh]; s_c [H, bytes];
    s_s [H, q_groups, bytes]. Returns [N, heads*dh]."""
    n, dcat = q.shape
    dh = dcat // heads
    outs = []
    for h in range(heads):
        sl = slice(h * dh, (h + 1) * dh)
        outs.append(
            flashomni_attention_head(
                q[:, sl], k[:, sl], v[:, sl], s_c[h], s_s[h],
                block_q=block_q, block_k=block_k, pool=pool, interpret=interpret,
            )
        )
    return jnp.concatenate(outs, axis=1)
