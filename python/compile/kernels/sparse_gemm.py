"""Layer-1: FlashOmni **sparse GEMM-Q / GEMM-O** in Pallas (§3.5).

Same CTA ↔ grid-step mapping as the attention kernel. GEMM-Q tiles are
`(row block × head)`: a tile whose caching symbol is 0 exits without work
(masked to zero under interpret mode). GEMM-O initializes from the cached
bias `B_c` and projects only the computed head tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gemm_q_kernel(x_ref, w_ref, sc_ref, y_ref, *, heads, dh, pool):
    i = pl.program_id(0)
    g = i // pool
    x = x_ref[...]  # [bq, din]
    w = w_ref[...]  # [din, H*dh]
    y = jnp.dot(x, w, preferred_element_type=jnp.float32)
    bits = (sc_ref[...][:, g // 8] >> (7 - g % 8)) & 1  # [H]
    mask = jnp.repeat(bits, dh).astype(y.dtype)  # [H*dh]
    y_ref[...] = y * mask[None, :]


def gemm_q(x, w, s_c, *, heads, block_q, pool=1, interpret=True):
    """x: [N, din]; w: [din, H*dh]; s_c: [H, ceil(q_groups/8)] int32.
    Returns [N, H*dh] with cached (block, head) tiles zeroed."""
    n, din = x.shape
    d_out = w.shape[1]
    dh = d_out // heads
    assert n % block_q == 0
    t_q = n // block_q
    kernel = functools.partial(_gemm_q_kernel, heads=heads, dh=dh, pool=pool)
    return pl.pallas_call(
        kernel,
        grid=(t_q,),
        in_specs=[
            pl.BlockSpec((block_q, din), lambda i: (i, 0)),
            pl.BlockSpec((din, d_out), lambda i: (0, 0)),
            pl.BlockSpec(s_c.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, d_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d_out), x.dtype),
        interpret=interpret,
    )(x, w, s_c)


def _gemm_o_kernel(o_ref, w_ref, bias_ref, sc_ref, out_ref, *, heads, dh, pool):
    i = pl.program_id(0)
    g = i // pool
    o = o_ref[...]  # [bq, H*dh] (cached tiles hold garbage/zeros)
    w = w_ref[...]  # [H*dh, dout]
    bits = (sc_ref[...][:, g // 8] >> (7 - g % 8)) & 1  # [H]
    mask = jnp.repeat(bits, dh).astype(o.dtype)
    out_ref[...] = bias_ref[...] + jnp.dot(
        o * mask[None, :], w, preferred_element_type=jnp.float32
    )


def gemm_o_dispatch(o_cat, w, bias, s_c, *, heads, block_q, pool=1, interpret=True):
    """Dispatch-step GEMM-O: `out = OP_reuse(B_c) + Σ_{computed} O^h W^h`.

    o_cat: [N, H*dh]; w: [H*dh, dout]; bias: [N, dout];
    s_c: [H, ceil(q_groups/8)] int32."""
    n, d_cat = o_cat.shape
    d_out = w.shape[1]
    dh = d_cat // heads
    assert n % block_q == 0
    t_q = n // block_q
    kernel = functools.partial(_gemm_o_kernel, heads=heads, dh=dh, pool=pool)
    return pl.pallas_call(
        kernel,
        grid=(t_q,),
        in_specs=[
            pl.BlockSpec((block_q, d_cat), lambda i: (i, 0)),
            pl.BlockSpec((d_cat, d_out), lambda i: (0, 0)),
            pl.BlockSpec((block_q, d_out), lambda i: (i, 0)),
            pl.BlockSpec(s_c.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, d_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d_out), o_cat.dtype),
        interpret=interpret,
    )(o_cat, w, bias, s_c)
