"""The paper's §A.4 programming interface, mirrored 1:1.

The appendix sketches how FlashOmni plugs into a diffusers-style
AttnProcessor:

    q = flashomni.to_q(cache_dic.sparse_symbols, x)          # GEMM-Q
    attn_out = self.attn_proc(q, k, v, cache_dic.sparse_symbols)
    cache_dic.sparse_symbols = self.update_sparse_symbols(q, k)
    out = flashomni.to_out(attn_out, cache_dic.sparse_symbols, cached_bias)

This module provides exactly that surface over the L1 Pallas kernels, so a
user can wrap any JAX DiT's attention processor the way the paper wraps
PyTorch ones. (The rust engine exposes the same flow natively via
`engine::DiTEngine`.)
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from .kernels.flashomni_attention import flashomni_attention
from .kernels.sparse_gemm import gemm_o_dispatch, gemm_q
from .kernels.symbols import encode_symbols


@dataclass
class SparseSymbols:
    """Per-head packed symbols (`S_c` `[H, bytes]`, `S_s` `[H, qg, bytes]`)."""

    s_c: jnp.ndarray
    s_s: jnp.ndarray
    block_q: int
    block_k: int

    @classmethod
    def dense(cls, heads: int, seq: int, block_q: int, block_k: int) -> "SparseSymbols":
        qg, kg = seq // block_q, seq // block_k
        sc, ss = encode_symbols(np.ones(qg, bool), np.ones((qg, kg), bool))
        return cls(
            s_c=jnp.asarray(np.stack([sc] * heads), jnp.int32),
            s_s=jnp.asarray(np.stack([ss] * heads), jnp.int32),
            block_q=block_q,
            block_k=block_k,
        )

    @classmethod
    def from_masks(cls, m_c: np.ndarray, m_s: np.ndarray, block_q: int, block_k: int
                   ) -> "SparseSymbols":
        """m_c: [H, qg] bool; m_s: [H, qg, kg] bool."""
        packed = [encode_symbols(m_c[h], m_s[h]) for h in range(m_c.shape[0])]
        return cls(
            s_c=jnp.asarray(np.stack([p[0] for p in packed]), jnp.int32),
            s_s=jnp.asarray(np.stack([p[1] for p in packed]), jnp.int32),
            block_q=block_q,
            block_k=block_k,
        )


@dataclass
class CacheDic:
    """The paper's `cache_dic`: symbols + cached GEMM-O bias."""

    sparse_symbols: SparseSymbols
    cached_bias: jnp.ndarray | None = None
    step_type: str = "update"
    extra: dict = field(default_factory=dict)


def to_q(sparse_symbols: SparseSymbols, x, w, *, heads):
    """FlashOmni GEMM-Q: query projection skipping cached (block, head)
    tiles (`flashomni.to_q` in the paper's listing)."""
    return gemm_q(x, w, sparse_symbols.s_c, heads=heads,
                  block_q=sparse_symbols.block_q)


def attention(q, k, v, sparse_symbols: SparseSymbols, *, heads):
    """The general sparse attention kernel (`self.attn_proc(...)`)."""
    return flashomni_attention(
        q, k, v, sparse_symbols.s_c, sparse_symbols.s_s,
        heads=heads, block_q=sparse_symbols.block_q,
        block_k=sparse_symbols.block_k,
    )


def to_out(attn_out, sparse_symbols: SparseSymbols, cached_bias, w, *, heads):
    """FlashOmni GEMM-O dispatch: bias init + computed tiles only."""
    return gemm_o_dispatch(attn_out, w, cached_bias, sparse_symbols.s_c,
                           heads=heads, block_q=sparse_symbols.block_q)


def update_sparse_symbols(q, k, *, heads, block_q, block_k, text_tokens,
                          tau_q, tau_kv) -> SparseSymbols:
    """Refresh symbols from fresh Q/K at an *Update* step: compressed
    attention map → C/G metrics → Eq. 1 selection (numpy reference of the
    rust `masks` module, adequate at build/calibration time)."""
    import math

    n, dcat = q.shape
    dh = dcat // heads
    qg, kg = n // block_q, n // block_k
    nt = text_tokens // block_q
    m_c = np.ones((heads, qg), bool)
    m_s = np.ones((heads, qg, kg), bool)
    for h in range(heads):
        qs = np.asarray(q[:, h * dh:(h + 1) * dh])
        ks = np.asarray(k[:, h * dh:(h + 1) * dh])
        qp = qs.reshape(qg, block_q, dh).mean(1)
        kp = ks.reshape(kg, block_k, dh).mean(1)
        s = qp @ kp.T / math.sqrt(dh)
        p = np.exp(s - s.max(-1, keepdims=True))
        p /= p.sum(-1, keepdims=True)
        # C: vision→text contribution; G: text→vision guidance.
        c = p[:nt, nt:].sum(0)
        beta = p[nt:, :nt].T
        beta = np.exp(beta - beta.max(-1, keepdims=True))
        beta /= beta.sum(-1, keepdims=True)
        g = beta.sum(0)
        order = np.argsort(c / max(c.sum(), 1e-12) + g / max(g.sum(), 1e-12))
        cum_c = cum_g = 0.0
        for i in order:
            if cum_c + c[i] <= tau_q * c.sum() and cum_g + g[i] <= tau_q * g.sum():
                cum_c += c[i]
                cum_g += g[i]
                m_c[h, nt + i] = False
            else:
                break
        # BSS: skip smallest-mass blocks per row within tau_kv.
        for i in range(qg):
            row_order = np.argsort(p[i])
            cum = 0.0
            for j in row_order:
                if j == min(i, kg - 1):
                    continue
                if cum + p[i, j] <= tau_kv:
                    cum += p[i, j]
                    m_s[h, i, j] = False
                else:
                    break
    return SparseSymbols.from_masks(m_c, m_s, block_q, block_k)
