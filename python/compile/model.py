"""Layer-2: MiniMMDiT in JAX — must match `rust/src/model` bit-for-bit-ish.

A double-stream MMDiT (SD3/FLUX style): separate text/vision stream weights,
joint self-attention over the concatenated sequence, adaLN-zero modulation,
per-head RMSNorm on Q/K, 1-D RoPE, rectified-flow velocity output.

Parameters live in a flat dict keyed by the same names the rust loader uses
(`blocks.{i}.{txt|img}.wq` …), so `export.py` writes them straight to
`artifacts/weights.fot`.

The attention stage is pluggable (`attn_fn`): training uses the plain jnp
reference; the AOT path (`aot.py`) injects the Pallas FlashOmni kernel so it
lowers into the exported HLO.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

ROPE_THETA = 10_000.0
LN_EPS = 1e-6
RMS_EPS = 1e-6


@dataclass(frozen=True)
class Config:
    dim: int = 128
    heads: int = 4
    layers: int = 4
    text_tokens: int = 16
    patch_h: int = 12
    patch_w: int = 12
    patch_size: int = 2
    channels: int = 3
    mlp_ratio: int = 4
    vocab: int = 256

    @property
    def head_dim(self) -> int:
        return self.dim // self.heads

    @property
    def vision_tokens(self) -> int:
        return self.patch_h * self.patch_w

    @property
    def seq_len(self) -> int:
        return self.text_tokens + self.vision_tokens

    @property
    def patch_dim(self) -> int:
        return self.patch_size * self.patch_size * self.channels

    @property
    def image_h(self) -> int:
        return self.patch_h * self.patch_size

    @property
    def image_w(self) -> int:
        return self.patch_w * self.patch_size

    def to_meta(self) -> dict:
        return {
            "dim": self.dim,
            "heads": self.heads,
            "layers": self.layers,
            "text_tokens": self.text_tokens,
            "patch_h": self.patch_h,
            "patch_w": self.patch_w,
            "patch_size": self.patch_size,
            "channels": self.channels,
            "mlp_ratio": self.mlp_ratio,
            "vocab": self.vocab,
        }


# ---------------------------------------------------------------- params --


def init_params(cfg: Config, seed: int = 0) -> dict[str, jnp.ndarray]:
    """Random init, names matching the rust weight loader."""
    rng = np.random.default_rng(seed)
    d, hd, m = cfg.dim, cfg.head_dim, cfg.mlp_ratio * cfg.dim
    s = 1.0 / math.sqrt(d)

    def t(*shape, scale=s):
        return jnp.asarray(rng.normal(0, scale, size=shape), dtype=jnp.float32)

    def zeros(*shape):
        return jnp.zeros(shape, dtype=jnp.float32)

    p: dict[str, jnp.ndarray] = {
        "text_embed": t(cfg.vocab, d, scale=0.02),
        "patch_embed.w": t(cfg.patch_dim, d),
        "patch_embed.b": zeros(d),
        "time_mlp.w1": t(d, d),
        "time_mlp.b1": zeros(d),
        "time_mlp.w2": t(d, d),
        "time_mlp.b2": zeros(d),
        "final.ada.w": t(d, 2 * d, scale=s * 0.1),
        "final.ada.b": zeros(2 * d),
        "final.w": t(d, cfg.patch_dim),
        "final.b": zeros(cfg.patch_dim),
    }
    for i in range(cfg.layers):
        for st in ("txt", "img"):
            pre = f"blocks.{i}.{st}"
            p[f"{pre}.ada.w"] = t(d, 6 * d, scale=s * 0.1)
            p[f"{pre}.ada.b"] = zeros(6 * d)
            p[f"{pre}.wq"] = t(d, d)
            p[f"{pre}.bq"] = zeros(d)
            p[f"{pre}.wk"] = t(d, d)
            p[f"{pre}.bk"] = zeros(d)
            p[f"{pre}.wv"] = t(d, d)
            p[f"{pre}.bv"] = zeros(d)
            p[f"{pre}.q_rms"] = jnp.ones(hd, dtype=jnp.float32)
            p[f"{pre}.k_rms"] = jnp.ones(hd, dtype=jnp.float32)
            p[f"{pre}.wo"] = t(d, d)
            p[f"{pre}.bo"] = zeros(d)
            p[f"{pre}.mlp.w1"] = t(d, m)
            p[f"{pre}.mlp.b1"] = zeros(m)
            p[f"{pre}.mlp.w2"] = t(m, d, scale=1.0 / math.sqrt(m))
            p[f"{pre}.mlp.b2"] = zeros(d)
    return p


# ------------------------------------------------------------------ ops --


def layernorm(x):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + LN_EPS)


def headwise_rmsnorm(x, heads, scale):
    """x: [N, dim] → per-head RMS norm with learned [head_dim] scale."""
    n, d = x.shape
    hd = d // heads
    xh = x.reshape(n, heads, hd)
    inv = 1.0 / jnp.sqrt(jnp.mean(xh * xh, axis=-1, keepdims=True) + RMS_EPS)
    return (xh * inv * scale).reshape(n, d)


def rope_angles(positions, head_dim):
    i = jnp.arange(head_dim // 2, dtype=jnp.float32)
    freq = ROPE_THETA ** (-2.0 * i / head_dim)
    return positions[:, None].astype(jnp.float32) * freq[None, :]  # [N, hd/2]


def headwise_rope(x, heads, positions):
    """Pair convention (x[2i], x[2i+1]); matches rust `rope`."""
    n, d = x.shape
    hd = d // heads
    ang = rope_angles(positions, hd)  # [N, hd/2]
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    xh = x.reshape(n, heads, hd // 2, 2)
    a, b = xh[..., 0], xh[..., 1]
    ra = a * cos[:, None, :] - b * sin[:, None, :]
    rb = a * sin[:, None, :] + b * cos[:, None, :]
    return jnp.stack([ra, rb], axis=-1).reshape(n, d)


def timestep_features(cfg: Config, t):
    half = cfg.dim // 2
    i = jnp.arange(half, dtype=jnp.float32)
    freq = jnp.exp(-math.log(10_000.0) * i / half)
    ts = t * 1000.0
    return jnp.concatenate([jnp.cos(ts * freq), jnp.sin(ts * freq)])


def attention_reference(q, k, v, heads):
    """Dense joint attention. q/k/v: [N, dim] → [N, dim]."""
    n, d = q.shape
    hd = d // heads
    qh = q.reshape(n, heads, hd).transpose(1, 0, 2)
    kh = k.reshape(n, heads, hd).transpose(1, 0, 2)
    vh = v.reshape(n, heads, hd).transpose(1, 0, 2)
    s = jnp.einsum("hqd,hkd->hqk", qh, kh) / math.sqrt(hd)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("hqk,hkd->hqd", p, vh)
    return o.transpose(1, 0, 2).reshape(n, d)


def adaln6(p, pre, c):
    a = jax.nn.silu(c) @ p[f"{pre}.ada.w"] + p[f"{pre}.ada.b"]
    return jnp.split(a, 6)


def mlp(p, pre, x):
    h = x @ p[f"{pre}.mlp.w1"] + p[f"{pre}.mlp.b1"]
    h = jax.nn.gelu(h, approximate=True)
    return h @ p[f"{pre}.mlp.w2"] + p[f"{pre}.mlp.b2"]


# -------------------------------------------------------------- forward --


def forward(params, cfg: Config, text_ids, patches, t, attn_fn=None):
    """One denoising step. text_ids: [T] int32, patches: [V, patch_dim],
    t: scalar in [0,1]. Returns per-patch velocity [V, patch_dim].

    `attn_fn(layer, q, k, v, heads) -> o_cat` lets the AOT path substitute
    the Pallas FlashOmni kernel.
    """
    if attn_fn is None:
        attn_fn = lambda layer, q, k, v, heads: attention_reference(q, k, v, heads)
    p = params
    txt = p["text_embed"][text_ids]  # [T, dim]
    img = patches @ p["patch_embed.w"] + p["patch_embed.b"]

    emb = timestep_features(cfg, t)
    h = jax.nn.silu(emb @ p["time_mlp.w1"] + p["time_mlp.b1"])
    c = h @ p["time_mlp.w2"] + p["time_mlp.b2"]

    positions = jnp.arange(cfg.seq_len)
    for i in range(cfg.layers):
        pt, pi = f"blocks.{i}.txt", f"blocks.{i}.img"
        sh1t, sc1t, g1t, sh2t, sc2t, g2t = adaln6(p, pt, c)
        sh1i, sc1i, g1i, sh2i, sc2i, g2i = adaln6(p, pi, c)
        tm = layernorm(txt) * (1 + sc1t) + sh1t
        im = layernorm(img) * (1 + sc1i) + sh1i

        q = jnp.concatenate(
            [
                headwise_rmsnorm(tm @ p[f"{pt}.wq"] + p[f"{pt}.bq"], cfg.heads, p[f"{pt}.q_rms"]),
                headwise_rmsnorm(im @ p[f"{pi}.wq"] + p[f"{pi}.bq"], cfg.heads, p[f"{pi}.q_rms"]),
            ]
        )
        k = jnp.concatenate(
            [
                headwise_rmsnorm(tm @ p[f"{pt}.wk"] + p[f"{pt}.bk"], cfg.heads, p[f"{pt}.k_rms"]),
                headwise_rmsnorm(im @ p[f"{pi}.wk"] + p[f"{pi}.bk"], cfg.heads, p[f"{pi}.k_rms"]),
            ]
        )
        v = jnp.concatenate(
            [tm @ p[f"{pt}.wv"] + p[f"{pt}.bv"], im @ p[f"{pi}.wv"] + p[f"{pi}.bv"]]
        )
        q = headwise_rope(q, cfg.heads, positions)
        k = headwise_rope(k, cfg.heads, positions)

        o = attn_fn(i, q, k, v, cfg.heads)
        ot, oi = o[: cfg.text_tokens], o[cfg.text_tokens :]
        txt = txt + g1t * (ot @ p[f"{pt}.wo"] + p[f"{pt}.bo"])
        img = img + g1i * (oi @ p[f"{pi}.wo"] + p[f"{pi}.bo"])

        txt = txt + g2t * mlp(p, pt, layernorm(txt) * (1 + sc2t) + sh2t)
        img = img + g2i * mlp(p, pi, layernorm(img) * (1 + sc2i) + sh2i)

    a = jax.nn.silu(c) @ p["final.ada.w"] + p["final.ada.b"]
    shift, scale = jnp.split(a, 2)
    h = layernorm(img) * (1 + scale) + shift
    return h @ p["final.w"] + p["final.b"]


def patchify(cfg: Config, img):
    """[H, W, C] → [tokens, patch_dim] matching rust `diffusion::patchify`."""
    p = cfg.patch_size
    x = img.reshape(cfg.patch_h, p, cfg.patch_w, p, cfg.channels)
    return x.transpose(0, 2, 1, 3, 4).reshape(cfg.vision_tokens, cfg.patch_dim)


def unpatchify(cfg: Config, patches):
    p = cfg.patch_size
    x = patches.reshape(cfg.patch_h, cfg.patch_w, p, p, cfg.channels)
    return x.transpose(0, 2, 1, 3, 4).reshape(cfg.image_h, cfg.image_w, cfg.channels)
