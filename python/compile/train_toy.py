"""Toy rectified-flow training of MiniMMDiT on the procedural shapes corpus.

Build-time only (never on the serve path). Manual Adam (optax unavailable in
this offline image). Run:

    cd python && python -m compile.train_toy --steps 600 --out ../artifacts

Writes `weights.fot` + `train_log.json` (loss curve, recorded in
EXPERIMENTS.md).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import dataset
from .export import export_weights
from .model import Config, forward, init_params, patchify


def make_loss(cfg: Config):
    def single(params, ids, img, t, eps):
        x0 = patchify(cfg, img)
        xt = (1.0 - t) * x0 + t * eps
        v_hat = forward(params, cfg, ids, xt, t)
        v_star = eps - x0
        return jnp.mean((v_hat - v_star) ** 2)

    def loss(params, ids_b, imgs_b, ts_b, eps_b):
        return jnp.mean(jax.vmap(single, in_axes=(None, 0, 0, 0, 0))(params, ids_b, imgs_b, ts_b, eps_b))

    return loss


def adam_update(params, grads, m, v, step, lr=2e-3, b1=0.9, b2=0.999, eps=1e-8):
    m = jax.tree.map(lambda mi, g: b1 * mi + (1 - b1) * g, m, grads)
    v = jax.tree.map(lambda vi, g: b2 * vi + (1 - b2) * g * g, v, grads)
    mh = jax.tree.map(lambda mi: mi / (1 - b1**step), m)
    vh = jax.tree.map(lambda vi: vi / (1 - b2**step), v)
    params = jax.tree.map(lambda p, mi, vi: p - lr * mi / (jnp.sqrt(vi) + eps), params, mh, vh)
    return params, m, v


def train(cfg: Config, steps: int, batch: int, seed: int, lr: float, log_every: int = 25):
    params = init_params(cfg, seed)
    loss_fn = make_loss(cfg)
    grad_fn = jax.jit(jax.value_and_grad(loss_fn))

    @jax.jit
    def opt_step(params, m, v, step, ids_b, imgs_b, ts_b, eps_b):
        l, g = jax.value_and_grad(loss_fn)(params, ids_b, imgs_b, ts_b, eps_b)
        params, m, v = adam_update(params, g, m, v, step, lr=lr)
        return params, m, v, l

    _ = grad_fn  # jitted inside opt_step
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)
    rng = np.random.default_rng(seed + 1)
    log = []
    t0 = time.time()
    for step in range(1, steps + 1):
        imgs, caps = dataset.batch(rng, batch, cfg.text_tokens, cfg.image_h, cfg.image_w)
        ts = rng.uniform(0.001, 0.999, size=batch).astype(np.float32)
        eps = rng.normal(size=(batch, cfg.vision_tokens, cfg.patch_dim)).astype(np.float32)
        params, m, v, l = opt_step(
            params,
            m,
            v,
            jnp.float32(step),
            jnp.asarray(caps),
            jnp.asarray(imgs),
            jnp.asarray(ts),
            jnp.asarray(eps),
        )
        if step % log_every == 0 or step == 1:
            log.append({"step": step, "loss": float(l), "elapsed_s": time.time() - t0})
            print(f"step {step:5d}  loss {float(l):.4f}  ({time.time()-t0:.0f}s)", flush=True)
    return params, log


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=600)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--out", type=str, default="../artifacts")
    args = ap.parse_args()

    cfg = Config()
    os.makedirs(args.out, exist_ok=True)
    params, log = train(cfg, args.steps, args.batch, args.seed, args.lr)
    export_weights(params, cfg, os.path.join(args.out, "weights.fot"))
    with open(os.path.join(args.out, "train_log.json"), "w") as f:
        json.dump({"config": cfg.to_meta(), "steps": args.steps, "batch": args.batch, "log": log}, f, indent=1)
    print(f"saved weights + log to {args.out}")


if __name__ == "__main__":
    main()
