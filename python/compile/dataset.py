"""Procedural shapes corpus — the training/eval data substrate.

The paper evaluates on COCO prompts with FLUX/Hunyuan; we cannot ship those
models or data, so (per DESIGN.md) the toy MiniMMDiT is trained on a fully
procedural text→image task that still exercises real multimodal attention
structure: captions are token tuples describing a scene (shape, color,
position, size, background) and images render that description.

Images are `[H, W, 3]` float32 in [-1, 1]. Captions are `text_tokens` ids in
`[0, vocab)`; the first 6 positions carry the semantic fields, the rest are
deterministic filler ("padding words") derived from the scene id.
"""

from __future__ import annotations

import numpy as np

SHAPES = ["circle", "square", "triangle", "ring"]
COLORS = np.array(
    [
        [0.9, 0.2, 0.2],
        [0.2, 0.8, 0.3],
        [0.25, 0.35, 0.95],
        [0.95, 0.85, 0.2],
        [0.85, 0.3, 0.85],
        [0.2, 0.85, 0.9],
    ],
    dtype=np.float32,
)
BACKGROUNDS = np.array(
    [[-0.85, -0.85, -0.85], [-0.4, -0.5, -0.6], [-0.6, -0.4, -0.5], [-0.5, -0.6, -0.35]],
    dtype=np.float32,
)
N_POS = 3  # positions per axis
N_SIZE = 3

# Token-id blocks (all < 256 so the mini vocab fits).
_BASE_SHAPE = 10
_BASE_COLOR = 20
_BASE_X = 30
_BASE_Y = 40
_BASE_SIZE = 50
_BASE_BG = 60
_BASE_FILLER = 100


def num_scenes() -> int:
    return len(SHAPES) * len(COLORS) * N_POS * N_POS * N_SIZE * len(BACKGROUNDS)


def scene_params(scene_id: int) -> dict:
    """Decode a scene id into its semantic fields."""
    s = scene_id % num_scenes()
    shape = s % len(SHAPES)
    s //= len(SHAPES)
    color = s % len(COLORS)
    s //= len(COLORS)
    px = s % N_POS
    s //= N_POS
    py = s % N_POS
    s //= N_POS
    size = s % N_SIZE
    s //= N_SIZE
    bg = s % len(BACKGROUNDS)
    return {"shape": shape, "color": color, "px": px, "py": py, "size": size, "bg": bg}


def caption_ids(scene_id: int, text_tokens: int = 16) -> np.ndarray:
    """Token ids for a scene (deterministic)."""
    p = scene_params(scene_id)
    ids = [
        _BASE_SHAPE + p["shape"],
        _BASE_COLOR + p["color"],
        _BASE_X + p["px"],
        _BASE_Y + p["py"],
        _BASE_SIZE + p["size"],
        _BASE_BG + p["bg"],
    ]
    # Filler tokens: pseudo-words derived from the scene id (stable hash).
    h = scene_id
    while len(ids) < text_tokens:
        h = (h * 1103515245 + 12345) & 0x7FFFFFFF
        ids.append(_BASE_FILLER + h % 100)
    return np.array(ids[:text_tokens], dtype=np.int32)


def render(scene_id: int, h: int = 24, w: int = 24) -> np.ndarray:
    """Render the scene to an `[h, w, 3]` image in [-1, 1]."""
    p = scene_params(scene_id)
    img = np.broadcast_to(BACKGROUNDS[p["bg"]], (h, w, 3)).copy()
    cx = (p["px"] + 1) * w / (N_POS + 1)
    cy = (p["py"] + 1) * h / (N_POS + 1)
    r = (0.14 + 0.08 * p["size"]) * min(h, w)
    color = COLORS[p["color"]]
    yy, xx = np.mgrid[0:h, 0:w].astype(np.float32)
    dx, dy = xx + 0.5 - cx, yy + 0.5 - cy
    name = SHAPES[p["shape"]]
    if name == "circle":
        mask = dx * dx + dy * dy <= r * r
    elif name == "square":
        mask = (np.abs(dx) <= r * 0.9) & (np.abs(dy) <= r * 0.9)
    elif name == "triangle":
        mask = (dy >= -r) & (dy <= r) & (np.abs(dx) <= (dy + r) * 0.6)
    else:  # ring
        rr = dx * dx + dy * dy
        mask = (rr <= r * r) & (rr >= (0.55 * r) ** 2)
    img[mask] = color * 2.0 - 1.0 + img[mask] * 0.0  # colors mapped to [-1,1]
    return img.astype(np.float32)


def batch(rng: np.random.Generator, batch_size: int, text_tokens: int = 16,
          h: int = 24, w: int = 24) -> tuple[np.ndarray, np.ndarray]:
    """Random (images, captions) batch."""
    ids = rng.integers(0, num_scenes(), size=batch_size)
    imgs = np.stack([render(int(i), h, w) for i in ids])
    caps = np.stack([caption_ids(int(i), text_tokens) for i in ids])
    return imgs, caps
