"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes, block sizes, pools, and mask densities.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.flashomni_attention import flashomni_attention, flashomni_attention_head
from compile.kernels.ref import (
    gemm_o_bias_ref,
    gemm_o_dispatch_ref,
    gemm_q_ref,
    masked_attention_ref,
    taylor_forecast_ref,
)
from compile.kernels.sparse_gemm import gemm_o_dispatch, gemm_q
from compile.kernels.symbols import decode_f, decode_j, encode_symbols, pack_bits, unpack_bits

SETTINGS = dict(max_examples=20, deadline=None)


# ------------------------------------------------------------- symbols --


@given(bits=st.lists(st.booleans(), min_size=1, max_size=64))
@settings(**SETTINGS)
def test_pack_unpack_roundtrip(bits):
    packed = pack_bits(np.array(bits))
    assert unpack_bits(packed, len(bits)).tolist() == bits


def test_figure5_example():
    # Paper Fig. 5: caching mask [1,1,1,0,0] → uint8 224.
    assert pack_bits(np.array([1, 1, 1, 0, 0], bool))[0] == 224


@given(
    qg=st.integers(1, 20),
    kg=st.integers(1, 20),
    pool=st.integers(1, 3),
    seed=st.integers(0, 100),
)
@settings(**SETTINGS)
def test_decode_matches_masks(qg, kg, pool, seed):
    rng = np.random.default_rng(seed)
    m_c = rng.random(qg) < 0.6
    m_s = rng.random((qg, kg)) < 0.5
    s_c, s_s = encode_symbols(m_c, m_s)
    for gi in range(qg):
        for raw_i in (gi * pool, gi * pool + pool - 1):
            assert decode_f(s_c, raw_i, pool) == m_c[gi]
        for gj in range(kg):
            assert decode_j(s_s, gi * pool, gj * pool, pool) == m_s[gi, gj]


# ----------------------------------------------------------- attention --


@given(
    n_blocks=st.integers(2, 8),
    d=st.sampled_from([4, 8, 16, 32]),
    bq=st.sampled_from([4, 8, 16]),
    density=st.floats(0.2, 1.0),
    seed=st.integers(0, 1000),
)
@settings(**SETTINGS)
def test_attention_vs_ref(n_blocks, d, bq, density, seed):
    n = n_blocks * bq
    bk = bq
    qg, kg = n // bq, n // bk
    rng = np.random.default_rng(seed)
    q = rng.normal(size=(n, d)).astype(np.float32)
    k = rng.normal(size=(n, d)).astype(np.float32)
    v = rng.normal(size=(n, d)).astype(np.float32)
    m_c = rng.random(qg) < density
    m_s = rng.random((qg, kg)) < density
    s_c, s_s = encode_symbols(m_c, m_s)
    out = flashomni_attention_head(
        q, k, v, jnp.asarray(s_c, jnp.int32), jnp.asarray(s_s, jnp.int32),
        block_q=bq, block_k=bk,
    )
    ref = masked_attention_ref(q, k, v, m_c, m_s, bq, bk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-4)


def test_attention_dense_symbols_equal_softmax():
    rng = np.random.default_rng(3)
    n, d, b = 32, 8, 8
    q = rng.normal(size=(n, d)).astype(np.float32)
    k = rng.normal(size=(n, d)).astype(np.float32)
    v = rng.normal(size=(n, d)).astype(np.float32)
    qg = n // b
    s_c, s_s = encode_symbols(np.ones(qg, bool), np.ones((qg, qg), bool))
    out = flashomni_attention_head(
        q, k, v, jnp.asarray(s_c, jnp.int32), jnp.asarray(s_s, jnp.int32),
        block_q=b, block_k=b,
    )
    import math
    p = np.asarray(jnp.exp((q @ k.T) / math.sqrt(d)))
    p = p / p.sum(-1, keepdims=True)
    np.testing.assert_allclose(np.asarray(out), p @ v, atol=1e-4, rtol=1e-3)


def test_attention_multihead_wrapper():
    rng = np.random.default_rng(4)
    n, heads, dh, b = 32, 2, 8, 8
    qg = n // b
    q = rng.normal(size=(n, heads * dh)).astype(np.float32)
    k = rng.normal(size=(n, heads * dh)).astype(np.float32)
    v = rng.normal(size=(n, heads * dh)).astype(np.float32)
    m_c = rng.random((heads, qg)) < 0.7
    m_s = rng.random((heads, qg, qg)) < 0.6
    s_c = np.stack([encode_symbols(m_c[h], m_s[h])[0] for h in range(heads)])
    s_s = np.stack([encode_symbols(m_c[h], m_s[h])[1] for h in range(heads)])
    out = flashomni_attention(
        q, k, v, jnp.asarray(s_c, jnp.int32), jnp.asarray(s_s, jnp.int32),
        heads=heads, block_q=b, block_k=b,
    )
    for h in range(heads):
        sl = slice(h * dh, (h + 1) * dh)
        ref = masked_attention_ref(q[:, sl], k[:, sl], v[:, sl], m_c[h], m_s[h], b, b)
        np.testing.assert_allclose(np.asarray(out[:, sl]), np.asarray(ref), atol=2e-5, rtol=2e-4)


def test_fully_cached_head_outputs_zero():
    rng = np.random.default_rng(5)
    n, d, b = 16, 4, 8
    qg = n // b
    q = rng.normal(size=(n, d)).astype(np.float32)
    s_c, s_s = encode_symbols(np.zeros(qg, bool), np.ones((qg, qg), bool))
    out = flashomni_attention_head(
        q, q, q, jnp.asarray(s_c, jnp.int32), jnp.asarray(s_s, jnp.int32),
        block_q=b, block_k=b,
    )
    assert float(jnp.max(jnp.abs(out))) == 0.0


# --------------------------------------------------------------- gemms --


@given(
    n_blocks=st.integers(2, 6),
    heads=st.sampled_from([1, 2, 4]),
    dh=st.sampled_from([4, 8]),
    din=st.sampled_from([8, 16]),
    seed=st.integers(0, 500),
)
@settings(**SETTINGS)
def test_gemm_q_vs_ref(n_blocks, heads, dh, din, seed):
    bq = 8
    n = n_blocks * bq
    qg = n // bq
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, din)).astype(np.float32)
    w = rng.normal(size=(din, heads * dh)).astype(np.float32)
    m_c = rng.random((heads, qg)) < 0.5
    s_c = np.stack([pack_bits(m_c[h]) for h in range(heads)])
    y = gemm_q(x, w, jnp.asarray(s_c, jnp.int32), heads=heads, block_q=bq)
    ref = gemm_q_ref(x, w, m_c, bq)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-5, rtol=1e-4)


@given(
    n_blocks=st.integers(2, 6),
    heads=st.sampled_from([1, 2, 4]),
    dh=st.sampled_from([4, 8]),
    dout=st.sampled_from([8, 24]),
    seed=st.integers(0, 500),
)
@settings(**SETTINGS)
def test_gemm_o_dispatch_vs_ref_and_eq3(n_blocks, heads, dh, dout, seed):
    bq = 8
    n = n_blocks * bq
    qg = n // bq
    rng = np.random.default_rng(seed)
    o = rng.normal(size=(n, heads * dh)).astype(np.float32)
    w = rng.normal(size=(heads * dh, dout)).astype(np.float32)
    m_c = rng.random((heads, qg)) < 0.5
    s_c = np.stack([pack_bits(m_c[h]) for h in range(heads)])
    bias = np.asarray(gemm_o_bias_ref(o, w, m_c, bq))
    out = gemm_o_dispatch(o, w, bias, jnp.asarray(s_c, jnp.int32), heads=heads, block_q=bq)
    ref = gemm_o_dispatch_ref(o, w, m_c, bq, bias)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-3)
    # Eq. 3: cached bias + computed tiles == the dense projection.
    np.testing.assert_allclose(np.asarray(out), o @ w, atol=1e-3, rtol=1e-3)


# ----------------------------------------------------------- taylorseer --


@given(k=st.floats(0.0, 5.0), seed=st.integers(0, 100))
@settings(**SETTINGS)
def test_taylor_order1_linear_exact(k, seed):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(4, 3)).astype(np.float32)
    b = rng.normal(size=(4, 3)).astype(np.float32)
    # y(t) = a + b·t; updates at t=0 and t=N → stack = [y(N), b].
    n = 5.0
    y0, y1 = a, a + b * n
    stack = [y1, (y1 - y0) / n]
    got = taylor_forecast_ref(stack, k)
    want = a + b * (n + k)
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-4, rtol=1e-4)
