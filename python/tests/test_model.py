"""L2 model tests: shapes, determinism, patchify round-trip, Pallas-in-model
equivalence, and training-step sanity."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.flashomni_attention import flashomni_attention
from compile.kernels.symbols import encode_symbols
from compile.model import (
    Config,
    attention_reference,
    forward,
    headwise_rmsnorm,
    headwise_rope,
    init_params,
    layernorm,
    patchify,
    timestep_features,
    unpatchify,
)


def tiny():
    return Config(dim=32, heads=2, layers=2, text_tokens=8, patch_h=4, patch_w=4,
                  patch_size=2, channels=3, mlp_ratio=2, vocab=16)


def test_forward_shape_and_determinism():
    cfg = tiny()
    p = init_params(cfg, 0)
    rng = np.random.default_rng(1)
    ids = jnp.asarray(rng.integers(0, cfg.vocab, cfg.text_tokens), jnp.int32)
    x = jnp.asarray(rng.normal(size=(cfg.vision_tokens, cfg.patch_dim)), jnp.float32)
    v1 = forward(p, cfg, ids, x, jnp.float32(0.5))
    v2 = forward(p, cfg, ids, x, jnp.float32(0.5))
    assert v1.shape == (cfg.vision_tokens, cfg.patch_dim)
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    assert np.isfinite(np.asarray(v1)).all()


def test_text_conditioning_matters():
    cfg = tiny()
    p = init_params(cfg, 0)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(cfg.vision_tokens, cfg.patch_dim)), jnp.float32)
    a = forward(p, cfg, jnp.full(cfg.text_tokens, 1, jnp.int32), x, jnp.float32(0.5))
    b = forward(p, cfg, jnp.full(cfg.text_tokens, 9, jnp.int32), x, jnp.float32(0.5))
    assert float(jnp.max(jnp.abs(a - b))) > 1e-6


@given(seed=st.integers(0, 50))
@settings(max_examples=10, deadline=None)
def test_patchify_roundtrip(seed):
    cfg = tiny()
    rng = np.random.default_rng(seed)
    img = jnp.asarray(rng.normal(size=(cfg.image_h, cfg.image_w, 3)), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(unpatchify(cfg, patchify(cfg, img))), np.asarray(img)
    )


def test_layernorm_stats():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(2.0, 3.0, size=(5, 64)), jnp.float32)
    y = layernorm(x)
    np.testing.assert_allclose(np.asarray(jnp.mean(y, -1)), 0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(jnp.var(y, -1)), 1, atol=1e-3)


def test_rope_relative_dot_products():
    rng = np.random.default_rng(4)
    q = jnp.asarray(rng.normal(size=(1, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 16)), jnp.float32)

    def dot_at(pq, pk):
        qr = headwise_rope(q, 1, jnp.array([pq]))
        kr = headwise_rope(k, 1, jnp.array([pk]))
        return float(jnp.sum(qr * kr))

    assert abs(dot_at(3, 1) - dot_at(10, 8)) < 1e-3


def test_headwise_rmsnorm_unit_rms():
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(6, 16)), jnp.float32)
    y = headwise_rmsnorm(x, 2, jnp.ones(8))
    yh = np.asarray(y).reshape(6, 2, 8)
    rms = np.sqrt((yh**2).mean(-1))
    np.testing.assert_allclose(rms, 1.0, atol=1e-3)


def test_timestep_features_shape_and_range():
    cfg = tiny()
    f = np.asarray(timestep_features(cfg, jnp.float32(0.3)))
    assert f.shape == (cfg.dim,)
    assert (np.abs(f) <= 1.0 + 1e-6).all()


def test_model_with_pallas_attention_matches_reference():
    """The AOT path swaps in the Pallas kernel with dense symbols — the
    full forward must be unchanged."""
    cfg = tiny()
    p = init_params(cfg, 0)
    rng = np.random.default_rng(6)
    ids = jnp.asarray(rng.integers(0, cfg.vocab, cfg.text_tokens), jnp.int32)
    x = jnp.asarray(rng.normal(size=(cfg.vision_tokens, cfg.patch_dim)), jnp.float32)

    n, b = cfg.seq_len, 8
    qg = n // b
    s_c, s_s = encode_symbols(np.ones(qg, bool), np.ones((qg, qg), bool))
    s_c_h = jnp.asarray(np.stack([s_c] * cfg.heads), jnp.int32)
    s_s_h = jnp.asarray(np.stack([s_s] * cfg.heads), jnp.int32)

    def attn_pallas(layer, q, k, v, heads):
        return flashomni_attention(q, k, v, s_c_h, s_s_h, heads=heads, block_q=b, block_k=b)

    want = forward(p, cfg, ids, x, jnp.float32(0.5))
    got = forward(p, cfg, ids, x, jnp.float32(0.5), attn_fn=attn_pallas)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-3)


def test_attention_reference_is_softmax():
    rng = np.random.default_rng(7)
    n, heads, dh = 12, 2, 4
    q = jnp.asarray(rng.normal(size=(n, heads * dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(n, heads * dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(n, heads * dh)), jnp.float32)
    o = attention_reference(q, k, v, heads)
    # Row 0, head 0 by hand.
    import math
    qh = np.asarray(q).reshape(n, heads, dh)[:, 0]
    kh = np.asarray(k).reshape(n, heads, dh)[:, 0]
    vh = np.asarray(v).reshape(n, heads, dh)[:, 0]
    s = qh @ kh.T / math.sqrt(dh)
    pm = np.exp(s - s.max(-1, keepdims=True))
    pm /= pm.sum(-1, keepdims=True)
    np.testing.assert_allclose(np.asarray(o)[:, :dh], pm @ vh, atol=1e-5, rtol=1e-4)


def test_one_training_step_reduces_loss_direction():
    """Gradient step on a fixed batch decreases the loss (sanity)."""
    from compile.train_toy import make_loss

    cfg = tiny()
    p = init_params(cfg, 0)
    loss_fn = make_loss(cfg)
    rng = np.random.default_rng(8)
    ids = jnp.asarray(rng.integers(0, cfg.vocab, (2, cfg.text_tokens)), jnp.int32)
    imgs = jnp.asarray(rng.normal(size=(2, cfg.image_h, cfg.image_w, 3)), jnp.float32)
    ts = jnp.asarray([0.3, 0.7], jnp.float32)
    eps = jnp.asarray(rng.normal(size=(2, cfg.vision_tokens, cfg.patch_dim)), jnp.float32)
    l0, g = jax.value_and_grad(loss_fn)(p, ids, imgs, ts, eps)
    p2 = jax.tree.map(lambda a, b: a - 1e-3 * b, p, g)
    l1 = loss_fn(p2, ids, imgs, ts, eps)
    assert float(l1) < float(l0)


def test_dataset_renderer_determinism_and_range():
    from compile import dataset

    img1 = dataset.render(123)
    img2 = dataset.render(123)
    np.testing.assert_array_equal(img1, img2)
    assert img1.min() >= -1.0 - 1e-6 and img1.max() <= 1.0 + 1e-6
    assert (dataset.caption_ids(123) == dataset.caption_ids(123)).all()
    assert dataset.caption_ids(123).max() < 256


def test_fot_roundtrip():
    from compile import fot
    import tempfile, os

    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "t.fot")
        fot.save(path, {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
                        "b": np.array([224, 235], np.uint8)}, meta={"x": 1})
        t, meta = fot.load(path)
        np.testing.assert_array_equal(t["a"], np.arange(6, dtype=np.float32).reshape(2, 3))
        assert t["b"].dtype == np.uint8
        assert meta["x"] == 1
