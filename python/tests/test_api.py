"""Tests for the §A.4 programming-interface mirror (flashomni_api)."""

import numpy as np
import jax.numpy as jnp

from compile import flashomni_api as fo
from compile.kernels.ref import masked_attention_ref, gemm_o_bias_ref


def test_dense_symbols_roundtrip_full_attention_flow():
    rng = np.random.default_rng(0)
    n, heads, dh, b = 32, 2, 8, 8
    d = heads * dh
    x = rng.normal(size=(n, d)).astype(np.float32)
    wq = rng.normal(size=(d, d)).astype(np.float32)
    wo = rng.normal(size=(d, d)).astype(np.float32)
    syms = fo.SparseSymbols.dense(heads, n, b, b)

    q = fo.to_q(syms, x, wq, heads=heads)
    np.testing.assert_allclose(np.asarray(q), x @ wq, atol=1e-4, rtol=1e-4)

    out = fo.attention(q, q, q, syms, heads=heads)
    assert out.shape == (n, d)

    bias = jnp.zeros((n, d), jnp.float32)
    final = fo.to_out(out, syms, bias, wo, heads=heads)
    np.testing.assert_allclose(np.asarray(final), np.asarray(out) @ wo, atol=1e-3, rtol=1e-3)


def test_update_sparse_symbols_caches_within_budget():
    rng = np.random.default_rng(1)
    n, heads, dh, b, text = 64, 2, 8, 8, 8
    q = rng.normal(size=(n, heads * dh)).astype(np.float32)
    k = rng.normal(size=(n, heads * dh)).astype(np.float32)
    syms = fo.update_sparse_symbols(
        q, k, heads=heads, block_q=b, block_k=b, text_tokens=text,
        tau_q=0.5, tau_kv=0.2,
    )
    # Text groups never cached; some vision group cached at τ=0.5.
    from compile.kernels.symbols import decode_f
    sc = np.asarray(syms.s_c, np.uint8)
    nt = text // b
    for h in range(heads):
        for g in range(nt):
            assert decode_f(sc[h], g)
    cached = sum(
        not decode_f(sc[h], g) for h in range(heads) for g in range(n // b)
    )
    assert cached > 0


def test_sparse_flow_matches_masked_reference():
    rng = np.random.default_rng(2)
    n, heads, dh, b = 32, 2, 8, 8
    d = heads * dh
    qg = n // b
    m_c = rng.random((heads, qg)) < 0.6
    m_s = rng.random((heads, qg, qg)) < 0.7
    syms = fo.SparseSymbols.from_masks(m_c, m_s, b, b)
    q = rng.normal(size=(n, d)).astype(np.float32)
    k = rng.normal(size=(n, d)).astype(np.float32)
    v = rng.normal(size=(n, d)).astype(np.float32)
    out = fo.attention(q, k, v, syms, heads=heads)
    for h in range(heads):
        sl = slice(h * dh, (h + 1) * dh)
        ref = masked_attention_ref(q[:, sl], k[:, sl], v[:, sl], m_c[h], m_s[h], b, b)
        np.testing.assert_allclose(np.asarray(out[:, sl]), np.asarray(ref),
                                   atol=2e-5, rtol=2e-4)
    # Eq. 3: to_out with the cached bias reconstructs the dense projection.
    wo = rng.normal(size=(d, d)).astype(np.float32)
    o_full = rng.normal(size=(n, d)).astype(np.float32)
    bias = gemm_o_bias_ref(o_full, wo, m_c, b)
    final = fo.to_out(jnp.asarray(o_full), syms, bias, wo, heads=heads)
    np.testing.assert_allclose(np.asarray(final), o_full @ wo, atol=1e-3, rtol=1e-3)
