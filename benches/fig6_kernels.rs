//! Figure 6 — normalized kernel performance at different sparsity levels:
//! sparse GEMM-Q, sparse GEMM-O (N = 6 amortized), and the FlashOmni
//! attention kernel under FC-only / BSS-only / FC+BSS random symbols.
//!
//! All sparse kernels run from a [`SparsePlan`]/[`HeadPlan`] compiled once
//! outside the timed region (the engine compiles once per Update window
//! and reuses the plan across Dispatch steps, so per-call compile cost is
//! amortized away); the one-off compile cost is measured separately and
//! reported in the JSON output.
//!
//! Shapes are 17K-scaled (seq 2048, head dim 64, block 64) per DESIGN.md.
//! Expected shape (paper): attention and GEMM-Q track the theoretical
//! linear law ~1:1; GEMM-O lands at 85–95% of the Eq. 5 bound.
//!
//! Besides the human-readable table + CSV, the bench emits a
//! machine-readable `BENCH_fig6.json` (per-kernel ns + sparsity) so later
//! PRs have a perf trajectory to compare against. PR 2 additions: the
//! multi-head attention dispatch comparison (serial loop vs per-step
//! `thread::scope` vs persistent `ExecPool` — the pool must be no slower
//! than the scope path) and the `u32` plan-index footprint report.
//!
//! PR 6 additions: per-kernel `scalar` / `simd` / `tuned` microkernel rows
//! on the dense baselines (the SIMD layer's headline numbers), a
//! sparsity:speedup `ratio` field on every kernel row, and the microkernel
//! ISA / autotuner state in the JSON header.
//!
//! PR 8 additions: an `obs_overhead` row measuring the disabled
//! observability span guard (asserted < 2% of the dense attention kernel
//! per enter/drop), uniform `plan_cache_*` counter fields on every row,
//! and `FO_METRICS`/`FO_TRACE` exports on exit.
//!
//! Env: FO_SEQ (default 2048), FO_BUDGET seconds/case (default 0.4),
//! FO_CHUNK (tile-loop chunk override; recorded in the JSON header),
//! FO_SIMD / FO_TUNE / FO_TUNE_CACHE (microkernel + autotuner knobs),
//! FO_METRICS / FO_TRACE (observability exports; `docs/observability.md`).
//! Knobs + the `BENCH_fig6.json` schema: `docs/benchmarks.md`.

use flashomni::bench::{
    json_row, json_row_ratio, print_table, write_bench_json_tagged, write_csv, Bencher,
    Measurement,
};
use flashomni::exec::ExecPool;
use flashomni::kernels::attention::{attention_dense, attention_dense_isa, flashomni_attention};
use flashomni::kernels::flops;
use flashomni::kernels::gemm_o::{
    gemm_o_dispatch, gemm_o_dispatch_isa, gemm_o_update, WeightPanels,
};
use flashomni::kernels::gemm_q::{gemm_q, gemm_q_isa, gemm_q_pool};
use flashomni::kernels::microkernel::{self, Isa};
use flashomni::kernels::tune::{self, Family};
use flashomni::model::blocks::{extract_head, insert_head};
use flashomni::plan::{DecodeMode, HeadPlan, SparsePlan};
use flashomni::symbols::random_symbols;
use flashomni::tensor::Tensor;
use flashomni::testutil::randn;
use flashomni::util::rng::Pcg32;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let seq = env_usize("FO_SEQ", 2048);
    let block = 64;
    let d = 64;
    let heads = 8;
    let bencher = Bencher { warmup: 1, min_iters: 3, budget_s: env_f64("FO_BUDGET", 0.4) };
    let mut rng = Pcg32::seeded(0x516);
    let t = seq / block;
    let mut json_rows: Vec<String> = Vec::new();

    println!("# Figure 6 — kernel speedup vs sparsity (seq {seq}, block {block}, d {d})");

    // ---------------- attention: FC / BSS / FC+BSS ----------------
    let q = randn(&mut rng, &[seq, d]);
    let k = randn(&mut rng, &[seq, d]);
    let v = randn(&mut rng, &[seq, d]);
    let dense = bencher.run("attention dense", || {
        std::hint::black_box(attention_dense(&q, &k, &v, block, block));
    });
    json_rows.push(json_row("attention", "dense", 0.0, &dense, 1.0));
    let mut rows: Vec<(Measurement, Option<f64>)> = vec![(dense.clone(), Some(1.0))];
    // Microkernel comparison on the dense baseline: scalar vs SIMD vs the
    // autotuner's pick for this geometry (`tune_now` measures without
    // touching the process-wide table, so the sparse rows below still run
    // under whatever FO_SIMD/FO_TUNE the caller set).
    let att_scalar = bencher.run("attention dense scalar", || {
        std::hint::black_box(attention_dense_isa(Isa::Scalar, &q, &k, &v, block, block));
    });
    let att_simd = bencher.run("attention dense simd", || {
        std::hint::black_box(attention_dense_isa(Isa::Simd, &q, &k, &v, block, block));
    });
    let att_cfg = tune::tune_now(Family::Attention, [block, d, block], 1);
    let att_tuned = bencher.run("attention dense tuned", || {
        std::hint::black_box(attention_dense_isa(att_cfg.isa, &q, &k, &v, block, block));
    });
    println!(
        "attention microkernels: scalar {:.3}ms  simd[{}] {:.2}x  tuned[{}] {:.2}x",
        att_scalar.median_s * 1e3,
        microkernel::isa_name(Isa::Simd),
        att_simd.speedup_vs(&att_scalar),
        microkernel::isa_name(att_cfg.isa),
        att_tuned.speedup_vs(&att_scalar)
    );
    json_rows.push(json_row("attention", "dense_scalar", 0.0, &att_scalar, 1.0));
    json_rows.push(json_row(
        "attention",
        "dense_simd",
        0.0,
        &att_simd,
        att_simd.speedup_vs(&att_scalar),
    ));
    json_rows.push(json_row(
        "attention",
        "dense_tuned",
        0.0,
        &att_tuned,
        att_tuned.speedup_vs(&att_scalar),
    ));
    rows.push((att_scalar.clone(), None));
    rows.push((att_simd, None));
    rows.push((att_tuned, None));
    for (label, fc_on, bss_on) in
        [("FC", true, false), ("BSS", false, true), ("FC+BSS", true, true)]
    {
        for sparsity in [0.1f64, 0.2, 0.4, 0.6, 0.8] {
            // Split the target sparsity across the enabled mechanisms.
            let (fc, bss) = match (fc_on, bss_on) {
                (true, false) => (sparsity, 0.0),
                (false, true) => (0.0, sparsity),
                _ => {
                    // combined: 1-(1-fc)(1-bss) = s with fc = bss
                    let p = 1.0 - (1.0 - sparsity).sqrt();
                    (p, p)
                }
            };
            let sym = random_symbols(&mut rng, t, t, 1, fc, bss);
            let actual = sym.pair_sparsity();
            // Symbols → plan, decoded once outside the timed region.
            let plan = HeadPlan::from_symbols(&sym, t, t, DecodeMode::RowCached);
            let m = bencher.run(&format!("attention {label} s={actual:.2}"), || {
                std::hint::black_box(flashomni_attention(&q, &k, &v, &plan, block, block, None));
            });
            let speedup = m.speedup_vs(&dense);
            let theory = flops::attention_theoretical_speedup(actual);
            println!(
                "attention {label:<7} sparsity {actual:.3}  speedup {speedup:.2}x  theory {theory:.2}x  ratio {:.1}%",
                100.0 * speedup / theory
            );
            json_rows.push(json_row_ratio("attention", label, actual, &m, speedup));
            rows.push((m, Some(speedup)));
        }
    }
    // One-off symbol→plan compile cost (amortized over a Dispatch window).
    let sym = random_symbols(&mut rng, t, t, 1, 0.5, 0.3);
    for decode in [DecodeMode::RowCached, DecodeMode::PerAccess] {
        let m = bencher.run(&format!("plan compile {decode:?}"), || {
            std::hint::black_box(HeadPlan::from_symbols(&sym, t, t, decode));
        });
        println!("plan compile {decode:?}: {:.1}us per head", m.median_s * 1e6);
        json_rows.push(json_row("plan_compile", &format!("{decode:?}"), sym.pair_sparsity(), &m, 0.0));
        rows.push((m, None));
    }
    // u32 index packing (FlashInfer idiom): report the footprint shrink
    // against the pre-PR-2 usize lists.
    let probe = HeadPlan::from_symbols(&sym, t, t, DecodeMode::RowCached);
    let plan_index_bytes = probe.index_bytes();
    let plan_index_bytes_usize = probe.index_len() * std::mem::size_of::<usize>();
    println!(
        "plan index lists: {} B (u32) vs {} B (usize) — {:.1}% smaller",
        plan_index_bytes,
        plan_index_bytes_usize,
        100.0 * (1.0 - plan_index_bytes as f64 / plan_index_bytes_usize.max(1) as f64)
    );

    // ---------------- multi-head dispatch: serial vs scope vs pool --------
    // The engine's per-step head fan-out. `thread::scope` pays a spawn per
    // call (the PR 1 scheme); the persistent pool must be no slower.
    {
        let heads_d = heads * d;
        let qm = randn(&mut rng, &[seq, heads_d]);
        let km = randn(&mut rng, &[seq, heads_d]);
        let vm = randn(&mut rng, &[seq, heads_d]);
        let head_plans: Vec<HeadPlan> = (0..heads)
            .map(|_| {
                let s = random_symbols(&mut rng, t, t, 1, 0.5, 0.3);
                HeadPlan::from_symbols(&s, t, t, DecodeMode::RowCached)
            })
            .collect();
        let gather = |per_head: Vec<Tensor>| {
            let mut o = Tensor::zeros(&[seq, heads_d]);
            for (h, oh) in per_head.iter().enumerate() {
                insert_head(&mut o, oh, heads, h);
            }
            o
        };
        let run_head = |h: usize| {
            let qh = extract_head(&qm, heads, h);
            let kh = extract_head(&km, heads, h);
            let vh = extract_head(&vm, heads, h);
            flashomni_attention(&qh, &kh, &vh, &head_plans[h], block, block, None).0
        };
        let serial = bencher.run("attention 8-head serial", || {
            std::hint::black_box(gather((0..heads).map(run_head).collect()));
        });
        let scoped = bencher.run("attention 8-head thread::scope", || {
            let per_head: Vec<Tensor> = std::thread::scope(|scope| {
                let handles: Vec<_> =
                    (0..heads).map(|h| scope.spawn(move || run_head(h))).collect();
                handles.into_iter().map(|jh| jh.join().unwrap()).collect()
            });
            std::hint::black_box(gather(per_head));
        });
        let pool = ExecPool::global();
        let pooled = bencher.run("attention 8-head ExecPool", || {
            std::hint::black_box(gather(pool.parallel_map_indexed(heads, run_head)));
        });
        println!(
            "multi-head dispatch: serial {:.3}ms  scope {:.3}ms  pool {:.3}ms (pool vs scope {:+.1}%)",
            serial.median_s * 1e3,
            scoped.median_s * 1e3,
            pooled.median_s * 1e3,
            100.0 * (pooled.median_s / scoped.median_s - 1.0)
        );
        json_rows.push(json_row("attention_multihead", "serial", 0.0, &serial, 1.0));
        json_rows.push(json_row(
            "attention_multihead",
            "thread_scope",
            0.0,
            &scoped,
            scoped.speedup_vs(&serial),
        ));
        json_rows.push(json_row(
            "attention_multihead",
            "pool",
            0.0,
            &pooled,
            pooled.speedup_vs(&serial),
        ));
        rows.push((serial, Some(1.0)));
        rows.push((scoped, None));
        rows.push((pooled, None));
    }

    // ---------------- GEMM-Q (spatial skipping) ----------------
    let d_in = heads * d;
    let x = randn(&mut rng, &[seq, d_in]);
    let w = randn(&mut rng, &[d_in, d_in]);
    // Fair baseline: gemm_q itself with an all-dense plan.
    let dense_plan_q = SparsePlan::dense(heads, t, t, block, block);
    let gq_dense = bencher.run("gemm_q dense", || {
        std::hint::black_box(gemm_q(&x, &w, &dense_plan_q, None));
    });
    json_rows.push(json_row("gemm_q", "dense", 0.0, &gq_dense, 1.0));
    rows.push((gq_dense.clone(), Some(1.0)));
    // Microkernel comparison on the dense GEMM-Q baseline (same all-dense
    // plan, explicit ISA). The tuned row runs the autotuner's pick for the
    // per-tile geometry `[block, d_in, d_h]`.
    let gq_scalar = bencher.run("gemm_q dense scalar", || {
        std::hint::black_box(gemm_q_isa(Isa::Scalar, &x, &w, &dense_plan_q, None));
    });
    let gq_simd = bencher.run("gemm_q dense simd", || {
        std::hint::black_box(gemm_q_isa(Isa::Simd, &x, &w, &dense_plan_q, None));
    });
    let gq_cfg = tune::tune_now(Family::GemmQ, [block, d_in, d], 1);
    let gq_tuned = bencher.run("gemm_q dense tuned", || {
        std::hint::black_box(gemm_q_isa(gq_cfg.isa, &x, &w, &dense_plan_q, None));
    });
    println!(
        "gemm_q microkernels: scalar {:.3}ms  simd[{}] {:.2}x  tuned[{}] {:.2}x",
        gq_scalar.median_s * 1e3,
        microkernel::isa_name(Isa::Simd),
        gq_simd.speedup_vs(&gq_scalar),
        microkernel::isa_name(gq_cfg.isa),
        gq_tuned.speedup_vs(&gq_scalar)
    );
    json_rows.push(json_row("gemm_q", "dense_scalar", 0.0, &gq_scalar, 1.0));
    json_rows.push(json_row(
        "gemm_q",
        "dense_simd",
        0.0,
        &gq_simd,
        gq_simd.speedup_vs(&gq_scalar),
    ));
    json_rows.push(json_row(
        "gemm_q",
        "dense_tuned",
        0.0,
        &gq_tuned,
        gq_tuned.speedup_vs(&gq_scalar),
    ));
    rows.push((gq_scalar, None));
    rows.push((gq_simd, None));
    rows.push((gq_tuned, None));
    for sparsity in [0.1, 0.2, 0.4, 0.6, 0.8, 0.9] {
        let syms = flashomni::symbols::LayerSymbols {
            heads: (0..heads)
                .map(|_| random_symbols(&mut rng, t, t, 1, sparsity, 0.0))
                .collect(),
        };
        let plan = SparsePlan::compile(&syms, t, t, block, block, DecodeMode::RowCached);
        let m = bencher.run(&format!("gemm_q s={sparsity}"), || {
            std::hint::black_box(gemm_q(&x, &w, &plan, None));
        });
        let pool = ExecPool::global();
        let mp = bencher.run(&format!("gemm_q pool s={sparsity}"), || {
            std::hint::black_box(gemm_q_pool(&x, &w, &plan, None, &pool));
        });
        let speedup = m.speedup_vs(&gq_dense);
        let theory = 1.0 / (1.0 - sparsity);
        println!(
            "gemm_q            sparsity {sparsity:.2}  speedup {speedup:.2}x  theory {theory:.2}x  ratio {:.1}%  pool {:.2}x",
            100.0 * speedup / theory,
            mp.speedup_vs(&gq_dense)
        );
        json_rows.push(json_row_ratio("gemm_q", "random", sparsity, &m, speedup));
        json_rows.push(json_row_ratio(
            "gemm_q_pool",
            "random",
            sparsity,
            &mp,
            mp.speedup_vs(&gq_dense),
        ));
        rows.push((m, Some(speedup)));
        rows.push((mp, None));
    }

    // ---------------- GEMM-O (amortized over N = 6) ----------------
    let interval = 6;
    let o = randn(&mut rng, &[seq, d_in]);
    let wo = randn(&mut rng, &[d_in, d_in]);
    let panels = WeightPanels::new(&wo, heads);
    // Fair baseline: the SAME tiled kernel, a dense plan, zero bias.
    let dense_plan_o = SparsePlan::dense(heads, t, t, block, block);
    let zero_bias = flashomni::tensor::Tensor::zeros(&[seq, d_in]);
    let go_dense = bencher.run("gemm_o dense", || {
        std::hint::black_box(gemm_o_dispatch(&o, &panels, &dense_plan_o, &zero_bias));
    });
    json_rows.push(json_row("gemm_o", "dense", 0.0, &go_dense, 1.0));
    rows.push((go_dense.clone(), Some(1.0)));
    // Microkernel comparison on the dense GEMM-O baseline.
    let go_scalar = bencher.run("gemm_o dense scalar", || {
        std::hint::black_box(gemm_o_dispatch_isa(
            Isa::Scalar,
            &o,
            &panels,
            &dense_plan_o,
            &zero_bias,
        ));
    });
    let go_simd = bencher.run("gemm_o dense simd", || {
        std::hint::black_box(gemm_o_dispatch_isa(
            Isa::Simd,
            &o,
            &panels,
            &dense_plan_o,
            &zero_bias,
        ));
    });
    let go_cfg = tune::tune_now(Family::GemmO, [block, d, d_in], 1);
    let go_tuned = bencher.run("gemm_o dense tuned", || {
        std::hint::black_box(gemm_o_dispatch_isa(
            go_cfg.isa,
            &o,
            &panels,
            &dense_plan_o,
            &zero_bias,
        ));
    });
    println!(
        "gemm_o microkernels: scalar {:.3}ms  simd[{}] {:.2}x  tuned[{}] {:.2}x",
        go_scalar.median_s * 1e3,
        microkernel::isa_name(Isa::Simd),
        go_simd.speedup_vs(&go_scalar),
        microkernel::isa_name(go_cfg.isa),
        go_tuned.speedup_vs(&go_scalar)
    );
    json_rows.push(json_row("gemm_o", "dense_scalar", 0.0, &go_scalar, 1.0));
    json_rows.push(json_row(
        "gemm_o",
        "dense_simd",
        0.0,
        &go_simd,
        go_simd.speedup_vs(&go_scalar),
    ));
    json_rows.push(json_row(
        "gemm_o",
        "dense_tuned",
        0.0,
        &go_tuned,
        go_tuned.speedup_vs(&go_scalar),
    ));
    rows.push((go_scalar, None));
    rows.push((go_simd, None));
    rows.push((go_tuned, None));
    for sparsity in [0.5, 0.7, 0.8, 0.9] {
        let syms = flashomni::symbols::LayerSymbols {
            heads: (0..heads)
                .map(|_| random_symbols(&mut rng, t, t, 1, sparsity, 0.0))
                .collect(),
        };
        let plan = SparsePlan::compile(&syms, t, t, block, block, DecodeMode::RowCached);
        let (_, bias, _) = gemm_o_update(&o, &panels, &plan);
        let update = bencher.run(&format!("gemm_o update s={sparsity}"), || {
            std::hint::black_box(gemm_o_update(&o, &panels, &plan));
        });
        let dispatch = bencher.run(&format!("gemm_o dispatch s={sparsity}"), || {
            std::hint::black_box(gemm_o_dispatch(&o, &panels, &plan, &bias));
        });
        // Amortized: 1 update + (N−1) dispatches vs N dense projections.
        let fo_time = update.median_s + (interval - 1) as f64 * dispatch.median_s;
        let dense_time = interval as f64 * go_dense.median_s;
        let speedup = dense_time / fo_time;
        let theory = flops::gemm_o_theoretical_speedup(interval, sparsity);
        println!(
            "gemm_o (N={interval})      sparsity {sparsity:.2}  speedup {speedup:.2}x  theory {theory:.2}x  ratio {:.1}%",
            100.0 * speedup / theory
        );
        json_rows.push(json_row("gemm_o_update", "random", sparsity, &update, 0.0));
        json_rows.push(json_row_ratio("gemm_o_dispatch", "random", sparsity, &dispatch, speedup));
        rows.push((update, None));
        rows.push((dispatch, Some(speedup)));
    }

    // ---------------- observability span overhead ----------------
    // With FO_METRICS/FO_TRACE unset, a Span::enter/drop pair is a single
    // gate load and must be vanishingly cheap next to any kernel: the
    // acceptance bound is per-guard cost < 2% of the dense attention
    // median (in practice it is orders of magnitude below that).
    {
        let spans_per_iter = 1024usize;
        let ov = bencher.run("obs span enter/drop x1024", || {
            for _ in 0..spans_per_iter {
                let sp = flashomni::obs::Span::enter(
                    "bench.overhead",
                    &flashomni::obs::metrics::ENGINE_STEP,
                );
                std::hint::black_box(&sp);
            }
        });
        let per_guard_ns = ov.median_s * 1e9 / spans_per_iter as f64;
        let share = per_guard_ns / (dense.median_s * 1e9);
        println!(
            "obs span overhead: {per_guard_ns:.1}ns per enter/drop ({:.5}% of dense attention)",
            share * 100.0
        );
        json_rows.push(json_row("obs_overhead", "span_enter_drop", 0.0, &ov, 0.0));
        if !flashomni::obs::metrics_enabled() && !flashomni::obs::trace_enabled() {
            assert!(
                share < 0.02,
                "disabled span guard costs {per_guard_ns:.1}ns — {:.2}% of the dense \
                 attention kernel (bound: 2%)",
                share * 100.0
            );
        }
        rows.push((ov, None));
    }

    print_table("fig6 raw measurements", &rows);
    let _ = write_csv("reports/fig6_kernels.csv", &rows);
    let tune_cache = tune::cache_path().unwrap_or_default();
    match write_bench_json_tagged(
        "BENCH_fig6.json",
        "fig6_kernels",
        &[
            ("seq", seq as f64),
            ("block", block as f64),
            ("head_dim", d as f64),
            ("heads", heads as f64),
            ("gemm_o_interval", interval as f64),
            ("exec_pool_threads", ExecPool::global().size() as f64),
            // 0 = built-in `tiles/(4·threads)` heuristic; nonzero = the
            // FO_CHUNK override this run was measured under (autotuner data).
            ("fo_chunk", flashomni::exec::tile_chunk_override().unwrap_or(0) as f64),
            ("fo_tune", tune::enabled() as u8 as f64),
            ("simd_available", microkernel::simd_available() as u8 as f64),
            ("tune_table_len", tune::table_len() as f64),
            ("plan_index_bytes_u32", plan_index_bytes as f64),
            ("plan_index_bytes_usize_equiv", plan_index_bytes_usize as f64),
        ],
        &[("isa", microkernel::isa_name(microkernel::active())), ("fo_tune_cache", &tune_cache)],
        &json_rows,
    ) {
        Ok(()) => println!("\nwrote BENCH_fig6.json ({} rows)", json_rows.len()),
        Err(e) => eprintln!("could not write BENCH_fig6.json: {e}"),
    }
    for p in flashomni::obs::export_if_enabled() {
        println!("wrote {p}");
    }
}
