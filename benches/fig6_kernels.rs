//! Figure 6 — normalized kernel performance at different sparsity levels:
//! sparse GEMM-Q, sparse GEMM-O (N = 6 amortized), and the FlashOmni
//! attention kernel under FC-only / BSS-only / FC+BSS random symbols.
//!
//! All sparse kernels run from a [`SparsePlan`]/[`HeadPlan`] compiled once
//! outside the timed region (the engine compiles once per Update window
//! and reuses the plan across Dispatch steps, so per-call compile cost is
//! amortized away); the one-off compile cost is measured separately and
//! reported in the JSON output.
//!
//! Shapes are 17K-scaled (seq 2048, head dim 64, block 64) per DESIGN.md.
//! Expected shape (paper): attention and GEMM-Q track the theoretical
//! linear law ~1:1; GEMM-O lands at 85–95% of the Eq. 5 bound.
//!
//! Besides the human-readable table + CSV, the bench emits a
//! machine-readable `BENCH_fig6.json` (per-kernel ns + sparsity) so later
//! PRs have a perf trajectory to compare against.
//!
//! Env: FO_SEQ (default 2048), FO_BUDGET seconds/case (default 0.4).

use flashomni::bench::{print_table, write_csv, Bencher, Measurement};
use flashomni::kernels::attention::{attention_dense, flashomni_attention};
use flashomni::kernels::flops;
use flashomni::kernels::gemm_o::{gemm_o_dispatch, gemm_o_update, WeightPanels};
use flashomni::kernels::gemm_q::gemm_q;
use flashomni::plan::{DecodeMode, HeadPlan, SparsePlan};
use flashomni::symbols::random_symbols;
use flashomni::testutil::randn;
use flashomni::util::rng::Pcg32;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// One machine-readable result row for BENCH_fig6.json.
fn json_row(kernel: &str, case: &str, sparsity: f64, m: &Measurement, speedup: f64) -> String {
    format!(
        "{{\"kernel\":\"{kernel}\",\"case\":\"{case}\",\"sparsity\":{sparsity:.6},\
         \"median_ns\":{:.0},\"min_ns\":{:.0},\"iters\":{},\"speedup\":{speedup:.4}}}",
        m.median_s * 1e9,
        m.min_s * 1e9,
        m.iters
    )
}

fn main() {
    let seq = env_usize("FO_SEQ", 2048);
    let block = 64;
    let d = 64;
    let heads = 8;
    let bencher = Bencher { warmup: 1, min_iters: 3, budget_s: env_f64("FO_BUDGET", 0.4) };
    let mut rng = Pcg32::seeded(0x516);
    let t = seq / block;
    let mut json_rows: Vec<String> = Vec::new();

    println!("# Figure 6 — kernel speedup vs sparsity (seq {seq}, block {block}, d {d})");

    // ---------------- attention: FC / BSS / FC+BSS ----------------
    let q = randn(&mut rng, &[seq, d]);
    let k = randn(&mut rng, &[seq, d]);
    let v = randn(&mut rng, &[seq, d]);
    let dense = bencher.run("attention dense", || {
        std::hint::black_box(attention_dense(&q, &k, &v, block, block));
    });
    json_rows.push(json_row("attention", "dense", 0.0, &dense, 1.0));
    let mut rows: Vec<(Measurement, Option<f64>)> = vec![(dense.clone(), Some(1.0))];
    for (label, fc_on, bss_on) in
        [("FC", true, false), ("BSS", false, true), ("FC+BSS", true, true)]
    {
        for sparsity in [0.1f64, 0.2, 0.4, 0.6, 0.8] {
            // Split the target sparsity across the enabled mechanisms.
            let (fc, bss) = match (fc_on, bss_on) {
                (true, false) => (sparsity, 0.0),
                (false, true) => (0.0, sparsity),
                _ => {
                    // combined: 1-(1-fc)(1-bss) = s with fc = bss
                    let p = 1.0 - (1.0 - sparsity).sqrt();
                    (p, p)
                }
            };
            let sym = random_symbols(&mut rng, t, t, 1, fc, bss);
            let actual = sym.pair_sparsity();
            // Symbols → plan, decoded once outside the timed region.
            let plan = HeadPlan::from_symbols(&sym, t, t, DecodeMode::RowCached);
            let m = bencher.run(&format!("attention {label} s={actual:.2}"), || {
                std::hint::black_box(flashomni_attention(&q, &k, &v, &plan, block, block, None));
            });
            let speedup = m.speedup_vs(&dense);
            let theory = flops::attention_theoretical_speedup(actual);
            println!(
                "attention {label:<7} sparsity {actual:.3}  speedup {speedup:.2}x  theory {theory:.2}x  ratio {:.1}%",
                100.0 * speedup / theory
            );
            json_rows.push(json_row("attention", label, actual, &m, speedup));
            rows.push((m, Some(speedup)));
        }
    }
    // One-off symbol→plan compile cost (amortized over a Dispatch window).
    let sym = random_symbols(&mut rng, t, t, 1, 0.5, 0.3);
    for decode in [DecodeMode::RowCached, DecodeMode::PerAccess] {
        let m = bencher.run(&format!("plan compile {decode:?}"), || {
            std::hint::black_box(HeadPlan::from_symbols(&sym, t, t, decode));
        });
        println!("plan compile {decode:?}: {:.1}us per head", m.median_s * 1e6);
        json_rows.push(json_row("plan_compile", &format!("{decode:?}"), sym.pair_sparsity(), &m, 0.0));
        rows.push((m, None));
    }

    // ---------------- GEMM-Q (spatial skipping) ----------------
    let d_in = heads * d;
    let x = randn(&mut rng, &[seq, d_in]);
    let w = randn(&mut rng, &[d_in, d_in]);
    // Fair baseline: gemm_q itself with an all-dense plan.
    let dense_plan_q = SparsePlan::dense(heads, t, t, block, block);
    let gq_dense = bencher.run("gemm_q dense", || {
        std::hint::black_box(gemm_q(&x, &w, &dense_plan_q, None));
    });
    json_rows.push(json_row("gemm_q", "dense", 0.0, &gq_dense, 1.0));
    rows.push((gq_dense.clone(), Some(1.0)));
    for sparsity in [0.1, 0.2, 0.4, 0.6, 0.8, 0.9] {
        let syms = flashomni::symbols::LayerSymbols {
            heads: (0..heads)
                .map(|_| random_symbols(&mut rng, t, t, 1, sparsity, 0.0))
                .collect(),
        };
        let plan = SparsePlan::compile(&syms, t, t, block, block, DecodeMode::RowCached);
        let m = bencher.run(&format!("gemm_q s={sparsity}"), || {
            std::hint::black_box(gemm_q(&x, &w, &plan, None));
        });
        let speedup = m.speedup_vs(&gq_dense);
        let theory = 1.0 / (1.0 - sparsity);
        println!(
            "gemm_q            sparsity {sparsity:.2}  speedup {speedup:.2}x  theory {theory:.2}x  ratio {:.1}%",
            100.0 * speedup / theory
        );
        json_rows.push(json_row("gemm_q", "random", sparsity, &m, speedup));
        rows.push((m, Some(speedup)));
    }

    // ---------------- GEMM-O (amortized over N = 6) ----------------
    let interval = 6;
    let o = randn(&mut rng, &[seq, d_in]);
    let wo = randn(&mut rng, &[d_in, d_in]);
    let panels = WeightPanels::new(&wo, heads);
    // Fair baseline: the SAME tiled kernel, a dense plan, zero bias.
    let dense_plan_o = SparsePlan::dense(heads, t, t, block, block);
    let zero_bias = flashomni::tensor::Tensor::zeros(&[seq, d_in]);
    let go_dense = bencher.run("gemm_o dense", || {
        std::hint::black_box(gemm_o_dispatch(&o, &panels, &dense_plan_o, &zero_bias));
    });
    json_rows.push(json_row("gemm_o", "dense", 0.0, &go_dense, 1.0));
    rows.push((go_dense.clone(), Some(1.0)));
    for sparsity in [0.5, 0.7, 0.8, 0.9] {
        let syms = flashomni::symbols::LayerSymbols {
            heads: (0..heads)
                .map(|_| random_symbols(&mut rng, t, t, 1, sparsity, 0.0))
                .collect(),
        };
        let plan = SparsePlan::compile(&syms, t, t, block, block, DecodeMode::RowCached);
        let (_, bias, _) = gemm_o_update(&o, &panels, &plan);
        let update = bencher.run(&format!("gemm_o update s={sparsity}"), || {
            std::hint::black_box(gemm_o_update(&o, &panels, &plan));
        });
        let dispatch = bencher.run(&format!("gemm_o dispatch s={sparsity}"), || {
            std::hint::black_box(gemm_o_dispatch(&o, &panels, &plan, &bias));
        });
        // Amortized: 1 update + (N−1) dispatches vs N dense projections.
        let fo_time = update.median_s + (interval - 1) as f64 * dispatch.median_s;
        let dense_time = interval as f64 * go_dense.median_s;
        let speedup = dense_time / fo_time;
        let theory = flops::gemm_o_theoretical_speedup(interval, sparsity);
        println!(
            "gemm_o (N={interval})      sparsity {sparsity:.2}  speedup {speedup:.2}x  theory {theory:.2}x  ratio {:.1}%",
            100.0 * speedup / theory
        );
        json_rows.push(json_row("gemm_o_update", "random", sparsity, &update, 0.0));
        json_rows.push(json_row("gemm_o_dispatch", "random", sparsity, &dispatch, speedup));
        rows.push((update, None));
        rows.push((dispatch, Some(speedup)));
    }

    print_table("fig6 raw measurements", &rows);
    let _ = write_csv("reports/fig6_kernels.csv", &rows);
    let json = format!(
        "{{\"bench\":\"fig6_kernels\",\"seq\":{seq},\"block\":{block},\"head_dim\":{d},\
         \"heads\":{heads},\"gemm_o_interval\":{interval},\"rows\":[\n{}\n]}}\n",
        json_rows.join(",\n")
    );
    match std::fs::write("BENCH_fig6.json", &json) {
        Ok(()) => println!("\nwrote BENCH_fig6.json ({} rows)", json_rows.len()),
        Err(e) => eprintln!("could not write BENCH_fig6.json: {e}"),
    }
}
