//! Figure 6 — normalized kernel performance at different sparsity levels:
//! sparse GEMM-Q, sparse GEMM-O (N = 6 amortized), and the FlashOmni
//! attention kernel under FC-only / BSS-only / FC+BSS random symbols.
//!
//! Shapes are 17K-scaled (seq 2048, head dim 64, block 64) per DESIGN.md.
//! Expected shape (paper): attention and GEMM-Q track the theoretical
//! linear law ~1:1; GEMM-O lands at 85–95% of the Eq. 5 bound.
//!
//! Env: FO_SEQ (default 2048), FO_BUDGET seconds/case (default 0.4).

use flashomni::bench::{print_table, write_csv, Bencher, Measurement};
use flashomni::kernels::attention::{attention_dense, flashomni_attention, DecodeMode};
use flashomni::kernels::flops;
use flashomni::kernels::gemm_o::{gemm_o_dispatch, gemm_o_update, WeightPanels};
use flashomni::kernels::gemm_q::gemm_q;
use flashomni::symbols::{random_symbols, LayerSymbols};
use flashomni::testutil::randn;
use flashomni::util::rng::Pcg32;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let seq = env_usize("FO_SEQ", 2048);
    let block = 64;
    let d = 64;
    let heads = 8;
    let bencher = Bencher { warmup: 1, min_iters: 3, budget_s: env_f64("FO_BUDGET", 0.4) };
    let mut rng = Pcg32::seeded(0x516);
    let t = seq / block;

    println!("# Figure 6 — kernel speedup vs sparsity (seq {seq}, block {block}, d {d})");

    // ---------------- attention: FC / BSS / FC+BSS ----------------
    let q = randn(&mut rng, &[seq, d]);
    let k = randn(&mut rng, &[seq, d]);
    let v = randn(&mut rng, &[seq, d]);
    let dense = bencher.run("attention dense", || {
        std::hint::black_box(attention_dense(&q, &k, &v, block, block));
    });
    let mut rows: Vec<(Measurement, Option<f64>)> = vec![(dense.clone(), Some(1.0))];
    for (label, fc_on, bss_on) in
        [("FC", true, false), ("BSS", false, true), ("FC+BSS", true, true)]
    {
        for sparsity in [0.1f64, 0.2, 0.4, 0.6, 0.8] {
            // Split the target sparsity across the enabled mechanisms.
            let (fc, bss) = match (fc_on, bss_on) {
                (true, false) => (sparsity, 0.0),
                (false, true) => (0.0, sparsity),
                _ => {
                    // combined: 1-(1-fc)(1-bss) = s with fc = bss
                    let p = 1.0 - (1.0 - sparsity).sqrt();
                    (p, p)
                }
            };
            let sym = random_symbols(&mut rng, t, t, 1, fc, bss);
            let actual = sym.pair_sparsity();
            let m = bencher.run(&format!("attention {label} s={actual:.2}"), || {
                std::hint::black_box(flashomni_attention(
                    &q,
                    &k,
                    &v,
                    &sym,
                    block,
                    block,
                    None,
                    DecodeMode::RowCached,
                ));
            });
            let speedup = m.speedup_vs(&dense);
            let theory = flops::attention_theoretical_speedup(actual);
            println!(
                "attention {label:<7} sparsity {actual:.3}  speedup {speedup:.2}x  theory {theory:.2}x  ratio {:.1}%",
                100.0 * speedup / theory
            );
            rows.push((m, Some(speedup)));
        }
    }

    // ---------------- GEMM-Q (spatial skipping) ----------------
    let d_in = heads * d;
    let x = randn(&mut rng, &[seq, d_in]);
    let w = randn(&mut rng, &[d_in, d_in]);
    // Fair baseline: gemm_q itself with all-dense symbols.
    let dense_syms_q = LayerSymbols::dense(heads, t, t, 1);
    let gq_dense = bencher.run("gemm_q dense", || {
        std::hint::black_box(gemm_q(&x, &w, &dense_syms_q, block, None));
    });
    rows.push((gq_dense.clone(), Some(1.0)));
    for sparsity in [0.1, 0.2, 0.4, 0.6, 0.8, 0.9] {
        let syms = LayerSymbols {
            heads: (0..heads)
                .map(|_| random_symbols(&mut rng, t, t, 1, sparsity, 0.0))
                .collect(),
        };
        let m = bencher.run(&format!("gemm_q s={sparsity}"), || {
            std::hint::black_box(gemm_q(&x, &w, &syms, block, None));
        });
        let speedup = m.speedup_vs(&gq_dense);
        let theory = 1.0 / (1.0 - sparsity);
        println!(
            "gemm_q            sparsity {sparsity:.2}  speedup {speedup:.2}x  theory {theory:.2}x  ratio {:.1}%",
            100.0 * speedup / theory
        );
        rows.push((m, Some(speedup)));
    }

    // ---------------- GEMM-O (amortized over N = 6) ----------------
    let interval = 6;
    let o = randn(&mut rng, &[seq, d_in]);
    let wo = randn(&mut rng, &[d_in, d_in]);
    let panels = WeightPanels::new(&wo, heads);
    // Fair baseline: the SAME tiled kernel, dense symbols, zero bias.
    let dense_syms_o = LayerSymbols::dense(heads, t, t, 1);
    let zero_bias = flashomni::tensor::Tensor::zeros(&[seq, d_in]);
    let go_dense = bencher.run("gemm_o dense", || {
        std::hint::black_box(gemm_o_dispatch(&o, &panels, &dense_syms_o, block, &zero_bias));
    });
    rows.push((go_dense.clone(), Some(1.0)));
    for sparsity in [0.5, 0.7, 0.8, 0.9] {
        let syms = LayerSymbols {
            heads: (0..heads)
                .map(|_| random_symbols(&mut rng, t, t, 1, sparsity, 0.0))
                .collect(),
        };
        let (_, bias, _) = gemm_o_update(&o, &panels, &syms, block);
        let update = bencher.run(&format!("gemm_o update s={sparsity}"), || {
            std::hint::black_box(gemm_o_update(&o, &panels, &syms, block));
        });
        let dispatch = bencher.run(&format!("gemm_o dispatch s={sparsity}"), || {
            std::hint::black_box(gemm_o_dispatch(&o, &panels, &syms, block, &bias));
        });
        // Amortized: 1 update + (N−1) dispatches vs N dense projections.
        let fo_time = update.median_s + (interval - 1) as f64 * dispatch.median_s;
        let dense_time = interval as f64 * go_dense.median_s;
        let speedup = dense_time / fo_time;
        let theory = flops::gemm_o_theoretical_speedup(interval, sparsity);
        println!(
            "gemm_o (N={interval})      sparsity {sparsity:.2}  speedup {speedup:.2}x  theory {theory:.2}x  ratio {:.1}%",
            100.0 * speedup / theory
        );
        rows.push((update, None));
        rows.push((dispatch, Some(speedup)));
    }

    print_table("fig6 raw measurements", &rows);
    let _ = write_csv("reports/fig6_kernels.csv", &rows);
}
