//! Tables 1–2 TOPS columns / Figure 1 — end-to-end wall-clock acceleration
//! on an attention-dominated "video-scale" synthetic model (random weights,
//! long sequence) where the FLOP mix matches HunyuanVideo's regime
//! (attention ≫ projections), plus the trained mini model for reference.
//!
//! Env: FO_SEQ_VIDEO (default 1936), FO_STEPS (default 10) — see
//! `docs/benchmarks.md` for the full knob index.

use flashomni::config::{ModelConfig, SparsityConfig};
use flashomni::engine::{DiTEngine, Policy};
use flashomni::model::{weights::Weights, MiniMMDiT};
use flashomni::workload::caption_ids;

fn env<T: std::str::FromStr>(key: &str, default: T) -> T {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn video_scale_model(seq_vision: usize) -> MiniMMDiT {
    // Attention-dominated configuration: small width, long sequence.
    let side = (seq_vision as f64).sqrt() as usize;
    let cfg = ModelConfig {
        dim: 64,
        heads: 4,
        layers: 2,
        text_tokens: 64,
        patch_h: side,
        patch_w: side,
        patch_size: 2,
        channels: 3,
        mlp_ratio: 4,
        vocab: 256,
    };
    MiniMMDiT::new(cfg.clone(), Weights::random(&cfg, 42))
}

fn main() {
    let seq_vision: usize = env("FO_SEQ_VIDEO", 1936); // 44² → seq 2000
    let steps: usize = env("FO_STEPS", 10);
    let model = video_scale_model(seq_vision);
    let n = model.cfg.seq_len() as f64;
    let d = model.cfg.dim as f64;
    let attn_frac = 4.0 * n * n * d
        / (4.0 * n * n * d + (8.0 + 16.0) * n * d * d);
    println!(
        "# e2e Table-1/Fig-1 bench — video-scale model: seq {} | attention fraction of FLOPs {:.0}%",
        model.cfg.seq_len(),
        attn_frac * 100.0
    );
    println!(
        "microkernel isa: {} (simd available: {}, autotune: {})",
        flashomni::kernels::microkernel::isa_name(flashomni::kernels::microkernel::active()),
        flashomni::kernels::microkernel::simd_available(),
        flashomni::kernels::tune::enabled()
    );
    let ids = caption_ids(1, model.cfg.text_tokens);

    let mut dense = DiTEngine::new(model.clone(), Policy::full(), 64, 64);
    let r0 = dense.generate(&ids, 3, steps);
    println!(
        "{:<36} wall {:>7.2}s  sparsity {:>5.1}%  speedup {:>5.2}x",
        "Full-Attention",
        r0.stats.wall_s,
        0.0,
        1.0
    );

    let cases: Vec<(Policy, &str)> = vec![
        (Policy::sparge(0.065, 0.07, 2), "SpargeAttn (l1=6.5%,l2=7%)"),
        (Policy::dfa2(0.2, 2), "DiTFastAttnV2 (θ=0.2)"),
        (
            Policy::flashomni(SparsityConfig {
                warmup: 2,
                ramp_steps: 2,
                block_q: 64,
                block_k: 64,
                ..SparsityConfig::paper(0.4, 0.1, 4, 1, 0.0)
            }),
            "FlashOmni (40%, 10%, 4, 1, 0%)",
        ),
        (
            Policy::flashomni(SparsityConfig {
                warmup: 2,
                ramp_steps: 2,
                block_q: 64,
                block_k: 64,
                ..SparsityConfig::paper(0.5, 0.15, 5, 1, 0.3)
            }),
            "FlashOmni (50%, 15%, 5, 1, 30%)",
        ),
        (Policy::taylorseer(5, 1, 2), "TaylorSeer (N=5, D=1)"),
    ];
    let mut csv = String::from("method,wall_s,sparsity,speedup\nFull-Attention,");
    csv.push_str(&format!("{},0,1\n", r0.stats.wall_s));
    for (policy, label) in cases {
        let mut engine = DiTEngine::new(model.clone(), policy, 64, 64);
        let r = engine.generate(&ids, 3, steps);
        let speedup = r0.stats.wall_s / r.stats.wall_s;
        println!(
            "{label:<36} wall {:>7.2}s  sparsity {:>5.1}%  speedup {:>5.2}x",
            r.stats.wall_s,
            r.stats.attn_sparsity() * 100.0,
            speedup
        );
        csv.push_str(&format!(
            "{label},{},{},{speedup}\n",
            r.stats.wall_s,
            r.stats.attn_sparsity()
        ));
    }
    std::fs::create_dir_all("reports").ok();
    let _ = std::fs::write("reports/e2e_table1.csv", csv);
    println!("(paper reference: ~1.5x end-to-end at 46% sparsity on Hunyuan 33K)");
    for p in flashomni::obs::export_if_enabled() {
        println!("wrote {p}");
    }
}
