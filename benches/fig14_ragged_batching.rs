//! Figure 14 (repo extension) — **ragged batching** throughput: one
//! mixed-resolution request stream served three ways, bitwise-equivalence
//! asserted against solo runs before any timing row is emitted.
//!
//! * **solo** — each request through a fresh single-request `DiTEngine`
//!   at its own resolution, sequentially (the per-request baseline).
//! * **uniform** — exact-geometry bucketing: requests partitioned by
//!   resolution, one `BatchedEngine` per bucket, buckets run to
//!   completion one after another (what the pre-ragged engine had to do).
//! * **ragged** — one `BatchedEngine` behind a token-budget
//!   `BatchScheduler`: the whole mixed stream rides one engine, every
//!   Dispatch layer walking one concatenated token buffer with cu-seqlen
//!   offsets (`FO_TOKEN_BUDGET` caps total in-flight tokens; 0 =
//!   unbounded).
//!
//! Emits `BENCH_fig14.json`: one row per scenario with wall time,
//! request throughput, speedup vs solo, token occupancy, the per-request
//! queue-wait / execution latency split, and the uniform `plan_cache_*`
//! counters every bench row carries. Row schema (custom, documented
//! here): `{case, requests, steps, wall_s, req_per_s, speedup_vs_solo,
//! mean_tokens_in_flight, peak_tokens, token_budget, p50_queue_s,
//! p95_queue_s, p99_queue_s, p50_exec_s, p95_exec_s, p99_exec_s,
//! plan_cache_hits, plan_cache_misses, plan_cache_shared,
//! plan_cache_delta}` (the solo row carries zeros for the scheduler-only
//! columns).
//!
//! Env: FO_REQUESTS (default 6), FO_STEPS (default 8), FO_LAYERS
//! (default 2), FO_BATCH (max slots, default 8), FO_TOKEN_BUDGET
//! (default 0 = unbounded), FO_METRICS / FO_TRACE (observability
//! exports; `docs/observability.md`). Knobs + schema:
//! `docs/benchmarks.md`.

use flashomni::batch::{BatchScheduler, BatchedEngine};
use flashomni::bench::{write_bench_json_tagged, PlanCacheCounters};
use flashomni::config::{ModelConfig, SparsityConfig};
use flashomni::engine::{DiTEngine, Policy};
use flashomni::exec::ExecPool;
use flashomni::model::{weights::Weights, MiniMMDiT};
use flashomni::tensor::Tensor;
use flashomni::workload::{caption_ids, Request};
use std::time::Instant;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn build_model(layers: usize) -> MiniMMDiT {
    let cfg = ModelConfig {
        dim: 64,
        heads: 4,
        layers,
        text_tokens: 8,
        patch_h: 4,
        patch_w: 4,
        patch_size: 2,
        channels: 3,
        mlp_ratio: 2,
        vocab: 256,
    };
    MiniMMDiT::new(cfg.clone(), Weights::random(&cfg, 0xf14))
}

fn policy() -> Policy {
    Policy::flashomni(SparsityConfig {
        tau_q: 0.5,
        tau_kv: 0.2,
        interval: 3,
        order: 1,
        s_q: 0.0,
        block_q: 8,
        block_k: 8,
        pool: 1,
        warmup: 2,
        ramp_steps: 1,
    })
}

/// Mixed-resolution stream: requests cycle through three vision grids
/// (seq 24 / 44 / 72 at text_tokens = 8) with distinct prompts + seeds.
fn requests(n: usize, steps: usize) -> Vec<Request> {
    const GRIDS: [Option<(usize, usize)>; 3] = [None, Some((6, 6)), Some((8, 8))];
    (0..n as u64)
        .map(|i| Request {
            id: i,
            scene: 3 * i as usize + 1,
            prompt_ids: caption_ids(3 * i as usize + 1, 8),
            seed: 1000 + i,
            steps,
            arrival_s: 0.0,
            patch_hw: GRIDS[i as usize % GRIDS.len()],
        })
        .collect()
}

/// Solo reference at the request's own resolution.
fn solo_run(model: &MiniMMDiT, req: &Request) -> Tensor {
    let mut cfg = model.cfg.clone();
    if let Some((ph, pw)) = req.patch_hw {
        cfg.patch_h = ph;
        cfg.patch_w = pw;
    }
    let mut engine = DiTEngine::new(MiniMMDiT::new(cfg, model.w.clone()), policy(), 8, 8);
    engine.generate(&req.prompt_ids, req.seed, req.steps).image
}

#[derive(Default)]
struct Scenario {
    wall_s: f64,
    tok_sum: usize,
    tok_peak: usize,
    ticks: usize,
    /// Per-request latency breakdowns (queue-wait / execution seconds).
    queue_s: Vec<f64>,
    exec_s: Vec<f64>,
    counters: PlanCacheCounters,
}

/// Nearest-rank percentile over an unsorted sample (0.0 when empty —
/// the solo scenario has no scheduler data). Routes through the shared
/// NaN-safe helper instead of a local truncating-rank copy.
fn pct(xs: &[f64], p: f64) -> f64 {
    let mut s = xs.to_vec();
    s.sort_by(f64::total_cmp);
    flashomni::report::percentile_sorted(&s, p)
}

/// Drive one engine to completion, sampling token occupancy per tick,
/// collecting per-request latency splits + plan-cache counters, and
/// checking every retiring image against the solo baseline.
fn drive(
    sched: &mut BatchScheduler,
    solo: &[(u64, Tensor)],
    sc: &mut Scenario,
) -> usize {
    let mut served = 0;
    while !sched.is_idle() {
        let done = sched.step();
        let tok = sched.engine().tokens_in_flight();
        sc.tok_sum += tok;
        sc.tok_peak = sc.tok_peak.max(tok);
        sc.ticks += 1;
        for r in done {
            let (_, img) = solo.iter().find(|(id, _)| *id == r.id).unwrap();
            assert_eq!(
                &r.image, img,
                "request {} diverged from its solo run — refusing to time a wrong result",
                r.id
            );
            sc.queue_s.push(r.queue_s);
            sc.exec_s.push(r.exec_s);
            sc.counters.hits += r.stats.plan_cache_hits;
            sc.counters.misses += r.stats.plan_cache_misses;
            sc.counters.shared += r.stats.plan_cache_shared;
            sc.counters.delta += r.stats.plan_cache_delta;
            served += 1;
        }
    }
    served
}

fn main() {
    let n_req = env_usize("FO_REQUESTS", 6);
    let steps = env_usize("FO_STEPS", 8);
    let layers = env_usize("FO_LAYERS", 2);
    let max_batch = env_usize("FO_BATCH", 8);
    let budget = env_usize("FO_TOKEN_BUDGET", 0);
    let model = build_model(layers);
    let reqs = requests(n_req, steps);

    println!(
        "# Figure 14 — ragged batching: {n_req} mixed-resolution requests × {steps} steps, \
         {layers} layers, token budget {budget} (0 = unbounded)"
    );

    // ---- solo baseline (also the bitwise reference). ----
    let t0 = Instant::now();
    let solo: Vec<(u64, Tensor)> =
        reqs.iter().map(|r| (r.id, solo_run(&model, r))).collect();
    let wall_solo = t0.elapsed().as_secs_f64();
    println!("  solo     wall={wall_solo:>7.3}s");

    let mut rows: Vec<String> = Vec::new();
    let mut push_row = |case: &str, wall: f64, sc: &Scenario| {
        let rps = n_req as f64 / wall.max(1e-9);
        let mean_tok =
            if sc.ticks == 0 { 0.0 } else { sc.tok_sum as f64 / sc.ticks as f64 };
        println!(
            "  {case:<8} wall={wall:>7.3}s thpt={rps:>6.3}/s speedup={:>5.2}x \
             mean_tokens={mean_tok:>6.1} peak={}",
            wall_solo / wall.max(1e-9),
            sc.tok_peak
        );
        rows.push(format!(
            "{{\"case\":\"{case}\",\"requests\":{n_req},\"steps\":{steps},\
             \"wall_s\":{wall:.6},\"req_per_s\":{rps:.4},\
             \"speedup_vs_solo\":{:.4},\"mean_tokens_in_flight\":{mean_tok:.2},\
             \"peak_tokens\":{},\"token_budget\":{budget},\
             \"p50_queue_s\":{:.6},\"p95_queue_s\":{:.6},\"p99_queue_s\":{:.6},\
             \"p50_exec_s\":{:.6},\"p95_exec_s\":{:.6},\"p99_exec_s\":{:.6},\
             \"plan_cache_hits\":{},\"plan_cache_misses\":{},\
             \"plan_cache_shared\":{},\"plan_cache_delta\":{}}}",
            wall_solo / wall.max(1e-9),
            sc.tok_peak,
            pct(&sc.queue_s, 0.5),
            pct(&sc.queue_s, 0.95),
            pct(&sc.queue_s, 0.99),
            pct(&sc.exec_s, 0.5),
            pct(&sc.exec_s, 0.95),
            pct(&sc.exec_s, 0.99),
            sc.counters.hits,
            sc.counters.misses,
            sc.counters.shared,
            sc.counters.delta,
        ));
        if !sc.queue_s.is_empty() {
            println!(
                "           queue p50={:.4}s p99={:.4}s | exec p50={:.4}s p99={:.4}s",
                pct(&sc.queue_s, 0.5),
                pct(&sc.queue_s, 0.99),
                pct(&sc.exec_s, 0.5),
                pct(&sc.exec_s, 0.99)
            );
        }
    };
    push_row("solo", wall_solo, &Scenario { wall_s: wall_solo, ..Scenario::default() });

    // ---- uniform: exact-geometry buckets, run one after another. ----
    {
        let mut buckets: Vec<(Option<(usize, usize)>, Vec<Request>)> = Vec::new();
        for r in &reqs {
            match buckets.iter_mut().find(|(hw, _)| *hw == r.patch_hw) {
                Some((_, b)) => b.push(r.clone()),
                None => buckets.push((r.patch_hw, vec![r.clone()])),
            }
        }
        let mut sc = Scenario::default();
        let t0 = Instant::now();
        let mut served = 0;
        for (_, bucket) in &buckets {
            let engine =
                BatchedEngine::new(model.clone(), policy(), 8, 8, max_batch.min(bucket.len()));
            let mut sched = BatchScheduler::with_token_budget(engine, budget);
            for r in bucket {
                sched.submit(r.clone());
            }
            served += drive(&mut sched, &solo, &mut sc);
        }
        assert_eq!(served, n_req);
        sc.wall_s = t0.elapsed().as_secs_f64();
        push_row("uniform", sc.wall_s, &sc);
    }

    // ---- ragged: the whole mixed stream through one engine. ----
    {
        let engine = BatchedEngine::new(model.clone(), policy(), 8, 8, max_batch);
        let mut sched = BatchScheduler::with_token_budget(engine, budget);
        for r in &reqs {
            sched.submit(r.clone());
        }
        let mut sc = Scenario::default();
        let t0 = Instant::now();
        let served = drive(&mut sched, &solo, &mut sc);
        assert_eq!(served, n_req);
        sc.wall_s = t0.elapsed().as_secs_f64();
        push_row("ragged", sc.wall_s, &sc);
    }

    let tune_cache = flashomni::kernels::tune::cache_path().unwrap_or_default();
    match write_bench_json_tagged(
        "BENCH_fig14.json",
        "fig14_ragged_batching",
        &[
            ("requests", n_req as f64),
            ("steps", steps as f64),
            ("layers", layers as f64),
            ("dim", model.cfg.dim as f64),
            ("heads", model.cfg.heads as f64),
            ("max_batch", max_batch as f64),
            ("token_budget", budget as f64),
            ("exec_pool_threads", ExecPool::global().size() as f64),
            ("fo_tune", flashomni::kernels::tune::enabled() as u8 as f64),
            (
                "simd_available",
                flashomni::kernels::microkernel::simd_available() as u8 as f64,
            ),
        ],
        &[
            (
                "isa",
                flashomni::kernels::microkernel::isa_name(
                    flashomni::kernels::microkernel::active(),
                ),
            ),
            ("fo_tune_cache", &tune_cache),
        ],
        &rows,
    ) {
        Ok(()) => println!("\nwrote BENCH_fig14.json ({} rows)", rows.len()),
        Err(e) => eprintln!("could not write BENCH_fig14.json: {e}"),
    }
    for p in flashomni::obs::export_if_enabled() {
        println!("wrote {p}");
    }
}
