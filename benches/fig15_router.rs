//! Figure 15 (repo extension) — **router serving under offered load**:
//! sustained throughput, tail latency, shed rate and deadline-miss rate
//! as Poisson arrivals sweep multiples of measured capacity.
//!
//! Setup: a [`Router`] (admission-controlled front-end: in-flight permit
//! cap `FO_MAX_IN_FLIGHT`, bounded queue `FO_QUEUE_CAP`, claim-time
//! deadlines, streaming previews every `FO_PREVIEW_INTERVAL` steps) over
//! `FO_WORKERS` continuous-batching workers. Capacity is calibrated from
//! a solo run (`capacity ≈ workers / mean solo seconds`), then each
//! `FO_LOADS` multiple replays a Poisson trace at `mult × capacity`
//! request/s, honoring arrival times.
//!
//! Two gates run before timing:
//! * **preview prefix gate** — every preview streamed by the router is
//!   bitwise-identical to a solo `DiTEngine` run truncated at the same
//!   step (previews are prefixes of the final decode);
//! * **burst shed gate** — a back-to-back burst of
//!   `max_in_flight + queue_cap + 4` submits must shed (> 0) instead of
//!   queueing without bound.
//!
//! Emits `BENCH_fig15.json`: one row per case. Row schema (custom,
//! documented here and in `docs/benchmarks.md`):
//! `{case, offered_x, rate_rps, requests, completed, shed, shed_rate,
//! deadline_miss, deadline_miss_rate, previews, wall_s, req_per_s,
//! p50_s, p95_s, p99_s, p50_queue_s, p95_queue_s, p99_queue_s,
//! p50_exec_s, p95_exec_s, p99_exec_s, plan_cache_hits,
//! plan_cache_misses, plan_cache_shared, plan_cache_delta}`.
//!
//! Env: FO_WORKERS (default 2), FO_BATCH (max batch per worker, default
//! 4), FO_REQUESTS (requests per load point, default 24), FO_STEPS
//! (default 8), FO_LAYERS (default 2), FO_MAX_IN_FLIGHT / FO_QUEUE_CAP /
//! FO_PREVIEW_INTERVAL (router knobs; defaults from `RouterConfig`),
//! FO_DEADLINE_MS (0 = derive 8× solo latency), FO_LOADS (comma list of
//! offered-load multiples, default "0.5,1,2,4").
//! Knobs + the `BENCH_fig15.json` schema: `docs/benchmarks.md`.
//!
//! [`Router`]: flashomni::router::Router

use flashomni::bench::{write_bench_json_tagged, PlanCacheCounters};
use flashomni::config::{ModelConfig, SparsityConfig};
use flashomni::coordinator::{Response, ServeReport};
use flashomni::diffusion::{initial_noise, plan_steps, time_grid};
use flashomni::engine::{DiTEngine, Policy};
use flashomni::exec::ExecPool;
use flashomni::model::{weights::Weights, MiniMMDiT};
use flashomni::router::{Rejected, Router, RouterConfig, SubmitOptions};
use flashomni::workload::{caption_ids, poisson_trace, Request};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn build_model(layers: usize) -> MiniMMDiT {
    let cfg = ModelConfig {
        dim: 64,
        heads: 4,
        layers,
        text_tokens: 8,
        patch_h: 8,
        patch_w: 8,
        patch_size: 2,
        channels: 3,
        mlp_ratio: 2,
        vocab: 256,
    };
    MiniMMDiT::new(cfg.clone(), Weights::random(&cfg, 0xf15))
}

fn policy() -> Policy {
    Policy::flashomni(SparsityConfig {
        tau_q: 0.5,
        tau_kv: 0.2,
        interval: 3,
        order: 1,
        s_q: 0.0,
        block_q: 8,
        block_k: 8,
        pool: 1,
        warmup: 2,
        ramp_steps: 1,
    })
}

fn engine_factory(
    model: &MiniMMDiT,
    pol: &Policy,
) -> impl Fn(usize) -> DiTEngine + Send + Sync + 'static {
    let m = model.clone();
    let p = pol.clone();
    move |_wid| DiTEngine::new(MiniMMDiT::new(m.cfg.clone(), m.w.clone()), p.clone(), 8, 8)
}

/// Outcome of one router run over a trace.
struct Outcome {
    completed: Vec<Response>,
    shed: usize,
    deadline_miss: usize,
    panicked: usize,
    previews: usize,
    wall_s: f64,
}

/// Replay `trace` through a fresh router, honoring `arrival_s` offsets.
/// One collector thread per accepted handle drains previews + terminal.
fn run_load(
    model: &MiniMMDiT,
    pol: &Policy,
    cfg: RouterConfig,
    trace: &[Request],
    deadline: Option<Duration>,
) -> Outcome {
    let router = Router::start(engine_factory(model, pol), cfg);
    type Slot = (Result<Response, Rejected>, usize);
    let results: Arc<Mutex<Vec<Slot>>> = Arc::new(Mutex::new(Vec::new()));
    let mut joins = Vec::new();
    let mut shed = 0usize;
    let t0 = Instant::now();
    for req in trace {
        let target = req.arrival_s;
        let now = t0.elapsed().as_secs_f64();
        if target > now {
            std::thread::sleep(Duration::from_secs_f64(target - now));
        }
        let mut opts = SubmitOptions::interactive();
        if let Some(d) = deadline {
            opts = opts.with_deadline(d);
        }
        match router.submit(req.clone(), opts) {
            Ok(h) => {
                let results = Arc::clone(&results);
                joins.push(std::thread::spawn(move || {
                    let (r, previews) = h.wait();
                    results.lock().unwrap().push((r, previews.len()));
                }));
            }
            Err(Rejected::Overloaded { .. }) => shed += 1,
            Err(e) => panic!("unexpected submit-time rejection: {e}"),
        }
    }
    for j in joins {
        j.join().expect("collector thread");
    }
    let wall_s = t0.elapsed().as_secs_f64();
    router.shutdown();
    let mut out = Outcome {
        completed: Vec::new(),
        shed,
        deadline_miss: 0,
        panicked: 0,
        previews: 0,
        wall_s,
    };
    let collected = std::mem::take(&mut *results.lock().unwrap());
    for (r, previews) in collected {
        out.previews += previews;
        match r {
            Ok(resp) => out.completed.push(resp),
            Err(Rejected::DeadlineExceeded { .. }) => out.deadline_miss += 1,
            Err(Rejected::WorkerPanicked { .. }) => out.panicked += 1,
            Err(e) => panic!("unexpected terminal rejection: {e}"),
        }
    }
    out
}

/// Preview prefix gate: the router's streamed previews must be bitwise
/// prefixes of the final decode (solo reference truncated at each step).
fn preview_prefix_gate(model: &MiniMMDiT, pol: &Policy) {
    let steps = 7;
    let (warmup, interval) = pol.schedule();
    let mut cfg = RouterConfig::new(1, 1);
    cfg.preview_interval = 2;
    let router = Router::start(engine_factory(model, pol), cfg);
    let req = Request {
        id: 0,
        scene: 3,
        prompt_ids: caption_ids(3, model.cfg.text_tokens),
        seed: 77,
        steps,
        arrival_s: 0.0,
        patch_hw: None,
    };
    let handle = router.submit(req.clone(), SubmitOptions::interactive()).expect("admitted");
    let (result, previews) = handle.wait();
    let resp = result.expect("gate request must complete");
    router.shutdown();
    assert!(!previews.is_empty(), "preview interval 2 over {steps} steps must stream previews");
    let grid = time_grid(steps);
    let plan = plan_steps(steps, warmup.min(steps), interval);
    for p in &previews {
        let mut solo = DiTEngine::new(
            MiniMMDiT::new(model.cfg.clone(), model.w.clone()),
            pol.clone(),
            8,
            8,
        );
        let x = initial_noise(&model.cfg, req.seed);
        let prefix =
            solo.generate_with_grid(&req.prompt_ids, x, &grid[..=p.step], &plan[..p.step]);
        assert_eq!(
            p.image, prefix.image,
            "preview at step {} is not a bitwise prefix of the final decode",
            p.step
        );
    }
    let mut solo = DiTEngine::new(
        MiniMMDiT::new(model.cfg.clone(), model.w.clone()),
        pol.clone(),
        8,
        8,
    );
    let full = solo.generate(&req.prompt_ids, req.seed, steps);
    assert_eq!(resp.image, full.image, "router result must equal the solo run");
    println!("preview prefix gate: OK ({} previews, all bitwise)", previews.len());
}

fn main() {
    let workers = env_usize("FO_WORKERS", 2);
    let max_batch = env_usize("FO_BATCH", 4);
    let n_req = env_usize("FO_REQUESTS", 24);
    let steps = env_usize("FO_STEPS", 8);
    let layers = env_usize("FO_LAYERS", 2);
    let model = build_model(layers);
    let pol = policy();
    let router_cfg = RouterConfig::from_env(workers, max_batch);

    println!(
        "# Figure 15 — router serving: workers={workers} max_batch={max_batch} \
         in_flight_cap={} queue_cap={} preview_every={} ({n_req} req × {steps} steps, {layers} layers)",
        router_cfg.max_in_flight, router_cfg.queue_cap, router_cfg.preview_interval
    );

    // Correctness gate before any timing.
    preview_prefix_gate(&model, &pol);

    // Capacity calibration: mean solo seconds per request → capacity.
    let solo_s = {
        let mut e = DiTEngine::new(
            MiniMMDiT::new(model.cfg.clone(), model.w.clone()),
            pol.clone(),
            8,
            8,
        );
        let t0 = Instant::now();
        let cal = 2;
        for i in 0..cal {
            let _ = e.generate(&caption_ids(1 + i, model.cfg.text_tokens), 10 + i as u64, steps);
        }
        t0.elapsed().as_secs_f64() / cal as f64
    };
    let capacity_rps = workers as f64 / solo_s.max(1e-9);
    let deadline_ms = {
        let v = env_usize("FO_DEADLINE_MS", 0);
        if v == 0 { ((solo_s * 8.0) * 1000.0).max(1.0) as usize } else { v }
    };
    println!(
        "calibration: solo {solo_s:.4}s/req → capacity ≈ {capacity_rps:.3} req/s; \
         deadline {deadline_ms} ms"
    );

    let loads: Vec<f64> = std::env::var("FO_LOADS")
        .unwrap_or_else(|_| "0.5,1,2,4".to_string())
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .collect();

    let mut json_rows: Vec<String> = Vec::new();
    let mut push_row = |case: &str, offered_x: f64, rate: f64, requests: usize, o: &Outcome| {
        let total = requests as f64;
        let report = if o.completed.is_empty() {
            None
        } else {
            Some(ServeReport::from_responses(&o.completed, o.wall_s))
        };
        let pick = |f: fn(&ServeReport) -> f64| report.as_ref().map(f).unwrap_or(0.0);
        let counters = PlanCacheCounters {
            hits: o.completed.iter().map(|r| r.stats.plan_cache_hits).sum(),
            misses: o.completed.iter().map(|r| r.stats.plan_cache_misses).sum(),
            shared: o.completed.iter().map(|r| r.stats.plan_cache_shared).sum(),
            delta: o.completed.iter().map(|r| r.stats.plan_cache_delta).sum(),
        };
        println!(
            "fig15 {case:<10} offered={offered_x:>4.1}x rate={rate:>7.3}/s served={:<3} \
             shed={:<3} miss={:<3} previews={:<4} p50={:.3}s p99={:.3}s",
            o.completed.len(),
            o.shed,
            o.deadline_miss,
            o.previews,
            pick(|r| r.p50_latency_s),
            pick(|r| r.p99_latency_s),
        );
        json_rows.push(format!(
            "{{\"case\":\"{case}\",\"offered_x\":{offered_x:.3},\"rate_rps\":{rate:.4},\
             \"requests\":{requests},\"completed\":{},\"shed\":{},\"shed_rate\":{:.4},\
             \"deadline_miss\":{},\"deadline_miss_rate\":{:.4},\"previews\":{},\
             \"wall_s\":{:.6},\"req_per_s\":{:.4},\
             \"p50_s\":{:.6},\"p95_s\":{:.6},\"p99_s\":{:.6},\
             \"p50_queue_s\":{:.6},\"p95_queue_s\":{:.6},\"p99_queue_s\":{:.6},\
             \"p50_exec_s\":{:.6},\"p95_exec_s\":{:.6},\"p99_exec_s\":{:.6},\
             \"plan_cache_hits\":{},\"plan_cache_misses\":{},\
             \"plan_cache_shared\":{},\"plan_cache_delta\":{}}}",
            o.completed.len(),
            o.shed,
            o.shed as f64 / total.max(1.0),
            o.deadline_miss,
            o.deadline_miss as f64 / total.max(1.0),
            o.previews,
            o.wall_s,
            o.completed.len() as f64 / o.wall_s.max(1e-9),
            pick(|r| r.p50_latency_s),
            pick(|r| r.p95_latency_s),
            pick(|r| r.p99_latency_s),
            pick(|r| r.p50_queue_s),
            pick(|r| r.p95_queue_s),
            pick(|r| r.p99_queue_s),
            pick(|r| r.p50_exec_s),
            pick(|r| r.p95_exec_s),
            pick(|r| r.p99_exec_s),
            counters.hits,
            counters.misses,
            counters.shared,
            counters.delta,
        ));
    };

    // Burst shed gate: max_in_flight + queue_cap + 4 back-to-back submits
    // cannot all be admitted — load shedding must engage (deterministic:
    // permits only free when a request finishes, which takes real work).
    {
        let burst_n = router_cfg.max_in_flight + router_cfg.queue_cap + 4;
        let trace: Vec<Request> = (0..burst_n as u64)
            .map(|i| Request {
                id: i,
                scene: 1 + i as usize,
                prompt_ids: caption_ids(1 + i as usize, model.cfg.text_tokens),
                seed: i,
                steps,
                arrival_s: 0.0,
                patch_hw: None,
            })
            .collect();
        let o = run_load(&model, &pol, router_cfg, &trace, None);
        assert!(o.shed > 0, "a burst past in-flight + queue capacity must shed");
        assert_eq!(o.completed.len() + o.shed + o.deadline_miss + o.panicked, burst_n);
        assert_eq!(o.panicked, 0);
        push_row("burst", 0.0, 0.0, burst_n, &o);
    }

    // Offered-load sweep: Poisson arrivals at multiples of capacity.
    for (li, &mult) in loads.iter().enumerate() {
        let rate = (capacity_rps * mult).max(1e-3);
        let trace = poisson_trace(0xf15 + li as u64, n_req, rate, steps, model.cfg.text_tokens);
        let o = run_load(
            &model,
            &pol,
            router_cfg,
            &trace,
            Some(Duration::from_millis(deadline_ms as u64)),
        );
        assert_eq!(o.completed.len() + o.shed + o.deadline_miss + o.panicked, n_req);
        assert_eq!(o.panicked, 0, "no worker may panic during the sweep");
        if router_cfg.preview_interval > 0
            && router_cfg.preview_interval < steps
            && !o.completed.is_empty()
        {
            assert!(o.previews > 0, "previews enabled but none streamed");
        }
        push_row(&format!("load_{mult}x"), mult, rate, n_req, &o);
    }

    let tune_cache = flashomni::kernels::tune::cache_path().unwrap_or_default();
    match write_bench_json_tagged(
        "BENCH_fig15.json",
        "fig15_router",
        &[
            ("requests", n_req as f64),
            ("steps", steps as f64),
            ("layers", layers as f64),
            ("workers", workers as f64),
            ("max_batch", max_batch as f64),
            ("max_in_flight", router_cfg.max_in_flight as f64),
            ("queue_cap", router_cfg.queue_cap as f64),
            ("preview_interval", router_cfg.preview_interval as f64),
            ("deadline_ms", deadline_ms as f64),
            ("capacity_rps", capacity_rps),
            ("solo_s", solo_s),
            ("dim", model.cfg.dim as f64),
            ("heads", model.cfg.heads as f64),
            ("seq", model.cfg.seq_len() as f64),
            ("exec_pool_threads", ExecPool::global().size() as f64),
        ],
        &[
            (
                "isa",
                flashomni::kernels::microkernel::isa_name(
                    flashomni::kernels::microkernel::active(),
                ),
            ),
            ("fo_tune_cache", &tune_cache),
        ],
        &json_rows,
    ) {
        Ok(()) => println!("\nwrote BENCH_fig15.json ({} rows)", json_rows.len()),
        Err(e) => eprintln!("could not write BENCH_fig15.json: {e}"),
    }

    for p in flashomni::obs::export_if_enabled() {
        println!("wrote {p}");
    }
}
