//! Figure 8 — GEMM-O amortized speedup across cache intervals N ∈ {4, 6, 8}
//! at 17K-scaled token length, vs the Eq. 5 theoretical bound.
//!
//! Paper reference points: measured speedup reaches 93.1% / 87.7% / 84.7%
//! of theory at N = 4 / 6 / 8 (the decode overhead grows with N).
//! Env: FO_SEQ (default 2048), FO_BUDGET (default 0.4).

use flashomni::bench::{write_csv, Bencher, Measurement};
use flashomni::kernels::flops;
use flashomni::kernels::gemm_o::{gemm_o_dispatch, gemm_o_update, WeightPanels};
use flashomni::plan::{DecodeMode, SparsePlan};
use flashomni::symbols::{random_symbols, LayerSymbols};
use flashomni::testutil::randn;
use flashomni::util::rng::Pcg32;

fn env<T: std::str::FromStr>(key: &str, default: T) -> T {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let seq: usize = env("FO_SEQ", 2048);
    let block = 64;
    let heads = 8;
    let d_h = 64;
    let d = heads * d_h;
    let bencher = Bencher { warmup: 1, min_iters: 3, budget_s: env("FO_BUDGET", 0.4) };
    let mut rng = Pcg32::seeded(0x816);
    let t = seq / block;

    println!("# Figure 8 — GEMM-O speedup vs interval N (seq {seq})");
    let o = randn(&mut rng, &[seq, d]);
    let w = randn(&mut rng, &[d, d]);
    let panels = WeightPanels::new(&w, heads);
    // Fair baseline: the SAME tiled kernel with an all-dense plan and a
    // zero bias (isolates the skip benefit from tiling/layout effects).
    let dense_plan = SparsePlan::dense(heads, t, t, block, block);
    let zero_bias = flashomni::tensor::Tensor::zeros(&[seq, d]);
    let dense = bencher.run("gemm_o dense", || {
        std::hint::black_box(gemm_o_dispatch(&o, &panels, &dense_plan, &zero_bias));
    });
    let mut rows: Vec<(Measurement, Option<f64>)> = vec![(dense.clone(), Some(1.0))];

    for interval in [4usize, 6, 8] {
        for sparsity in [0.5f64, 0.7, 0.9] {
            let syms = LayerSymbols {
                heads: (0..heads)
                    .map(|_| random_symbols(&mut rng, t, t, 1, sparsity, 0.0))
                    .collect(),
            };
            let plan = SparsePlan::compile(&syms, t, t, block, block, DecodeMode::RowCached);
            let (_, bias, _) = gemm_o_update(&o, &panels, &plan);
            let update = bencher.run(&format!("update N={interval} s={sparsity}"), || {
                std::hint::black_box(gemm_o_update(&o, &panels, &plan));
            });
            let dispatch =
                bencher.run(&format!("dispatch N={interval} s={sparsity}"), || {
                    std::hint::black_box(gemm_o_dispatch(&o, &panels, &plan, &bias));
                });
            let fo = update.median_s + (interval - 1) as f64 * dispatch.median_s;
            let speedup = interval as f64 * dense.median_s / fo;
            let theory = flops::gemm_o_theoretical_speedup(interval, sparsity);
            println!(
                "N={interval} sparsity {sparsity:.1}  speedup {speedup:.3}x  theory {theory:.3}x  %of-theory {:.1}%",
                100.0 * speedup / theory
            );
            rows.push((update, None));
            rows.push((dispatch, Some(speedup)));
        }
    }
    let _ = write_csv("reports/fig8_gemm_o.csv", &rows);
}
