//! Figure 8 — GEMM-O amortized speedup across cache intervals N ∈ {4, 6, 8}
//! at 17K-scaled token length, vs the Eq. 5 theoretical bound.
//!
//! Paper reference points: measured speedup reaches 93.1% / 87.7% / 84.7%
//! of theory at N = 4 / 6 / 8 (the decode overhead grows with N).
//!
//! PR 2: also times the pool-backed dispatch kernel (row-block parallel on
//! the persistent `ExecPool`) against the serial one and emits a
//! machine-readable `BENCH_fig8.json` perf trajectory like fig6.
//! PR 6: scalar/simd/tuned microkernel rows on the dense baseline, a
//! `ratio` field on the amortized dispatch rows, and the microkernel ISA /
//! autotuner state in the JSON header.
//! PR 8: an `obs_overhead` row asserting the disabled observability span
//! guard costs < 2% of the dense kernel per enter/drop, uniform
//! `plan_cache_*` counter fields on every row, and `FO_METRICS`/`FO_TRACE`
//! exports on exit.
//! Env: FO_SEQ (default 2048), FO_BUDGET (default 0.4), FO_CHUNK
//! (tile-loop chunk override; recorded in the JSON header), FO_SIMD /
//! FO_TUNE / FO_TUNE_CACHE (microkernel + autotuner knobs), FO_METRICS /
//! FO_TRACE (observability exports; `docs/observability.md`).
//! Knobs + the `BENCH_fig8.json` schema: `docs/benchmarks.md`.

use flashomni::bench::{
    json_row, json_row_ratio, write_bench_json_tagged, write_csv, Bencher, Measurement,
};
use flashomni::exec::ExecPool;
use flashomni::kernels::flops;
use flashomni::kernels::gemm_o::{
    gemm_o_dispatch, gemm_o_dispatch_isa, gemm_o_dispatch_pool, gemm_o_update,
    gemm_o_update_pool, WeightPanels,
};
use flashomni::kernels::microkernel::{self, Isa};
use flashomni::kernels::tune::{self, Family};
use flashomni::plan::{DecodeMode, SparsePlan};
use flashomni::symbols::{random_symbols, LayerSymbols};
use flashomni::testutil::randn;
use flashomni::util::rng::Pcg32;

fn env<T: std::str::FromStr>(key: &str, default: T) -> T {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let seq: usize = env("FO_SEQ", 2048);
    let block = 64;
    let heads = 8;
    let d_h = 64;
    let d = heads * d_h;
    let bencher = Bencher { warmup: 1, min_iters: 3, budget_s: env("FO_BUDGET", 0.4) };
    let mut rng = Pcg32::seeded(0x816);
    let t = seq / block;
    let pool = ExecPool::global();
    let mut json_rows: Vec<String> = Vec::new();

    println!("# Figure 8 — GEMM-O speedup vs interval N (seq {seq})");
    let o = randn(&mut rng, &[seq, d]);
    let w = randn(&mut rng, &[d, d]);
    let panels = WeightPanels::new(&w, heads);
    // Fair baseline: the SAME tiled kernel with an all-dense plan and a
    // zero bias (isolates the skip benefit from tiling/layout effects).
    let dense_plan = SparsePlan::dense(heads, t, t, block, block);
    let zero_bias = flashomni::tensor::Tensor::zeros(&[seq, d]);
    let dense = bencher.run("gemm_o dense", || {
        std::hint::black_box(gemm_o_dispatch(&o, &panels, &dense_plan, &zero_bias));
    });
    json_rows.push(json_row("gemm_o", "dense", 0.0, &dense, 1.0));
    let mut rows: Vec<(Measurement, Option<f64>)> = vec![(dense.clone(), Some(1.0))];

    // Microkernel comparison on the dense baseline: scalar vs SIMD vs the
    // autotuner's pick for the per-tile geometry `[block, d_h, d_out]`
    // (`tune_now` measures without touching the process-wide table).
    let go_scalar = bencher.run("gemm_o dense scalar", || {
        std::hint::black_box(gemm_o_dispatch_isa(Isa::Scalar, &o, &panels, &dense_plan, &zero_bias));
    });
    let go_simd = bencher.run("gemm_o dense simd", || {
        std::hint::black_box(gemm_o_dispatch_isa(Isa::Simd, &o, &panels, &dense_plan, &zero_bias));
    });
    let go_cfg = tune::tune_now(Family::GemmO, [block, d_h, d], 1);
    let go_tuned = bencher.run("gemm_o dense tuned", || {
        std::hint::black_box(gemm_o_dispatch_isa(go_cfg.isa, &o, &panels, &dense_plan, &zero_bias));
    });
    println!(
        "gemm_o microkernels: scalar {:.3}ms  simd[{}] {:.2}x  tuned[{}] {:.2}x",
        go_scalar.median_s * 1e3,
        microkernel::isa_name(Isa::Simd),
        go_simd.speedup_vs(&go_scalar),
        microkernel::isa_name(go_cfg.isa),
        go_tuned.speedup_vs(&go_scalar)
    );
    json_rows.push(json_row("gemm_o", "dense_scalar", 0.0, &go_scalar, 1.0));
    json_rows.push(json_row(
        "gemm_o",
        "dense_simd",
        0.0,
        &go_simd,
        go_simd.speedup_vs(&go_scalar),
    ));
    json_rows.push(json_row(
        "gemm_o",
        "dense_tuned",
        0.0,
        &go_tuned,
        go_tuned.speedup_vs(&go_scalar),
    ));
    rows.push((go_scalar, None));
    rows.push((go_simd, None));
    rows.push((go_tuned, None));

    for interval in [4usize, 6, 8] {
        for sparsity in [0.5f64, 0.7, 0.9] {
            let syms = LayerSymbols {
                heads: (0..heads)
                    .map(|_| random_symbols(&mut rng, t, t, 1, sparsity, 0.0))
                    .collect(),
            };
            let plan = SparsePlan::compile(&syms, t, t, block, block, DecodeMode::RowCached);
            let (_, bias, _) = gemm_o_update(&o, &panels, &plan);
            let update = bencher.run(&format!("update N={interval} s={sparsity}"), || {
                std::hint::black_box(gemm_o_update(&o, &panels, &plan));
            });
            let dispatch =
                bencher.run(&format!("dispatch N={interval} s={sparsity}"), || {
                    std::hint::black_box(gemm_o_dispatch(&o, &panels, &plan, &bias));
                });
            let update_pool =
                bencher.run(&format!("update pool N={interval} s={sparsity}"), || {
                    std::hint::black_box(gemm_o_update_pool(&o, &panels, &plan, &pool));
                });
            let dispatch_pool =
                bencher.run(&format!("dispatch pool N={interval} s={sparsity}"), || {
                    std::hint::black_box(gemm_o_dispatch_pool(&o, &panels, &plan, &bias, &pool));
                });
            let fo = update.median_s + (interval - 1) as f64 * dispatch.median_s;
            let fo_pool =
                update_pool.median_s + (interval - 1) as f64 * dispatch_pool.median_s;
            let speedup = interval as f64 * dense.median_s / fo;
            let speedup_pool = interval as f64 * dense.median_s / fo_pool;
            let theory = flops::gemm_o_theoretical_speedup(interval, sparsity);
            println!(
                "N={interval} sparsity {sparsity:.1}  speedup {speedup:.3}x (pool {speedup_pool:.3}x)  theory {theory:.3}x  %of-theory {:.1}%",
                100.0 * speedup / theory
            );
            json_rows.push(json_row("gemm_o_update", &format!("N{interval}"), sparsity, &update, 0.0));
            json_rows.push(json_row_ratio(
                "gemm_o_dispatch",
                &format!("N{interval}"),
                sparsity,
                &dispatch,
                speedup,
            ));
            json_rows.push(json_row(
                "gemm_o_update_pool",
                &format!("N{interval}"),
                sparsity,
                &update_pool,
                0.0,
            ));
            json_rows.push(json_row_ratio(
                "gemm_o_dispatch_pool",
                &format!("N{interval}"),
                sparsity,
                &dispatch_pool,
                speedup_pool,
            ));
            rows.push((update, None));
            rows.push((dispatch, Some(speedup)));
            rows.push((update_pool, None));
            rows.push((dispatch_pool, Some(speedup_pool)));
        }
    }
    // Observability span-guard overhead vs the dense GEMM-O kernel (same
    // bound fig6 asserts against dense attention).
    {
        let spans_per_iter = 1024usize;
        let ov = bencher.run("obs span enter/drop x1024", || {
            for _ in 0..spans_per_iter {
                let sp = flashomni::obs::Span::enter(
                    "bench.overhead",
                    &flashomni::obs::metrics::ENGINE_STEP,
                );
                std::hint::black_box(&sp);
            }
        });
        let per_guard_ns = ov.median_s * 1e9 / spans_per_iter as f64;
        let share = per_guard_ns / (dense.median_s * 1e9);
        println!(
            "obs span overhead: {per_guard_ns:.1}ns per enter/drop ({:.5}% of dense gemm_o)",
            share * 100.0
        );
        json_rows.push(json_row("obs_overhead", "span_enter_drop", 0.0, &ov, 0.0));
        if !flashomni::obs::metrics_enabled() && !flashomni::obs::trace_enabled() {
            assert!(
                share < 0.02,
                "disabled span guard costs {per_guard_ns:.1}ns — {:.2}% of the dense \
                 gemm_o kernel (bound: 2%)",
                share * 100.0
            );
        }
        rows.push((ov, None));
    }
    let _ = write_csv("reports/fig8_gemm_o.csv", &rows);
    let tune_cache = tune::cache_path().unwrap_or_default();
    match write_bench_json_tagged(
        "BENCH_fig8.json",
        "fig8_gemm_o",
        &[
            ("seq", seq as f64),
            ("block", block as f64),
            ("heads", heads as f64),
            ("head_dim", d_h as f64),
            ("exec_pool_threads", pool.size() as f64),
            // 0 = built-in `tiles/(4·threads)` heuristic; nonzero = the
            // FO_CHUNK override this run was measured under (autotuner data).
            ("fo_chunk", flashomni::exec::tile_chunk_override().unwrap_or(0) as f64),
            ("fo_tune", tune::enabled() as u8 as f64),
            ("simd_available", microkernel::simd_available() as u8 as f64),
            ("tune_table_len", tune::table_len() as f64),
        ],
        &[("isa", microkernel::isa_name(microkernel::active())), ("fo_tune_cache", &tune_cache)],
        &json_rows,
    ) {
        Ok(()) => println!("\nwrote BENCH_fig8.json ({} rows)", json_rows.len()),
        Err(e) => eprintln!("could not write BENCH_fig8.json: {e}"),
    }
    for p in flashomni::obs::export_if_enabled() {
        println!("wrote {p}");
    }
}
