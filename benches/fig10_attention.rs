//! Figure 10 (appendix A.2) — attention kernel speedup detail at 17K- and
//! 33K-scaled token lengths: three BSS threshold groups @1/@2/@3
//! (0.1/0.3/0.5) with the FC threshold swept within each group
//! (0.1, 0.2, 0.4, 0.6, 0.8), all from random symbols.
//!
//! Also reproduces the FC-vs-BSS decode-overhead claim (§4.3) by timing
//! the naive per-access decode against the register-cached row decode.
//!
//! PR 2: also times the single-head kernel dispatched serially vs on the
//! persistent `ExecPool` (8 heads) and emits a machine-readable
//! `BENCH_fig10.json` perf trajectory like fig6.
//! Env: FO_SEQS (default "2048,4096"), FO_BUDGET (default 0.3).
//! Knobs + the `BENCH_fig10.json` schema: `docs/benchmarks.md`.

use flashomni::bench::{json_row, write_bench_json_tagged, write_csv, Bencher, Measurement};
use flashomni::exec::ExecPool;
use flashomni::kernels::attention::{
    attention_dense, flashomni_attention, flashomni_attention_symbols,
};
use flashomni::kernels::flops;
use flashomni::plan::{DecodeMode, HeadPlan};
use flashomni::symbols::random_symbols;
use flashomni::testutil::randn;
use flashomni::util::rng::Pcg32;

fn main() {
    let seqs: Vec<usize> = std::env::var("FO_SEQS")
        .unwrap_or_else(|_| "2048,4096".into())
        .split(',')
        .filter_map(|s| s.parse().ok())
        .collect();
    let budget: f64 =
        std::env::var("FO_BUDGET").ok().and_then(|v| v.parse().ok()).unwrap_or(0.3);
    let bencher = Bencher { warmup: 1, min_iters: 3, budget_s: budget };
    let block = 64;
    let d = 64;
    let mut rows: Vec<(Measurement, Option<f64>)> = Vec::new();
    let mut json_rows: Vec<String> = Vec::new();
    let pool = ExecPool::global();

    for &seq in &seqs {
        let mut rng = Pcg32::seeded(0xa10 + seq as u64);
        let t = seq / block;
        println!("\n# Figure 10 — attention speedups, seq {seq} ({}K-scale)", seq * 17 / 2048);
        let q = randn(&mut rng, &[seq, d]);
        let k = randn(&mut rng, &[seq, d]);
        let v = randn(&mut rng, &[seq, d]);
        let dense = bencher.run(&format!("dense seq={seq}"), || {
            std::hint::black_box(attention_dense(&q, &k, &v, block, block));
        });
        json_rows.push(json_row("attention", &format!("dense_seq{seq}"), 0.0, &dense, 1.0));
        rows.push((dense.clone(), Some(1.0)));
        for (gname, bss) in [("@1", 0.1f64), ("@2", 0.3), ("@3", 0.5)] {
            for fc in [0.1f64, 0.2, 0.4, 0.6, 0.8] {
                let sym = random_symbols(&mut rng, t, t, 1, fc, bss);
                let s = sym.pair_sparsity();
                let plan = HeadPlan::from_symbols(&sym, t, t, DecodeMode::RowCached);
                let m = bencher.run(&format!("seq={seq} {gname} fc={fc}"), || {
                    std::hint::black_box(flashomni_attention(
                        &q, &k, &v, &plan, block, block, None,
                    ));
                });
                let speedup = m.speedup_vs(&dense);
                let theory = flops::attention_theoretical_speedup(s);
                println!(
                    "{gname} fc={fc:.1}  sparsity {s:.3}  speedup {speedup:.2}x  theory {theory:.2}x  ratio {:.1}%",
                    100.0 * speedup / theory
                );
                json_rows.push(json_row(
                    "attention",
                    &format!("{gname}_fc{fc}_seq{seq}"),
                    s,
                    &m,
                    speedup,
                ));
                rows.push((m, Some(speedup)));
            }
        }
        // Decode-overhead ablation (paper: FC beats BSS at equal sparsity
        // because BSS decodes repeatedly along the reduction axis). The
        // symbol-decoding kernel shows both decode schemes; the plan-based
        // kernel is the zero-decode upper bound.
        let sym = random_symbols(&mut rng, t, t, 1, 0.0, 0.6);
        let plan = HeadPlan::from_symbols(&sym, t, t, DecodeMode::RowCached);
        let cached = bencher.run(&format!("seq={seq} row-cached decode"), || {
            std::hint::black_box(flashomni_attention_symbols(
                &q, &k, &v, &sym, block, block, None, DecodeMode::RowCached,
            ));
        });
        let naive = bencher.run(&format!("seq={seq} per-access decode"), || {
            std::hint::black_box(flashomni_attention_symbols(
                &q, &k, &v, &sym, block, block, None, DecodeMode::PerAccess,
            ));
        });
        let planned = bencher.run(&format!("seq={seq} precompiled plan"), || {
            std::hint::black_box(flashomni_attention(&q, &k, &v, &plan, block, block, None));
        });
        println!(
            "decode ablation: plan {:.3}ms vs row-cached {:.3}ms vs per-access {:.3}ms ({:+.1}% naive overhead)",
            planned.median_s * 1e3,
            cached.median_s * 1e3,
            naive.median_s * 1e3,
            100.0 * (naive.median_s / cached.median_s - 1.0)
        );
        json_rows.push(json_row("decode", &format!("row_cached_seq{seq}"), 0.6, &cached, 0.0));
        json_rows.push(json_row("decode", &format!("per_access_seq{seq}"), 0.6, &naive, 0.0));
        json_rows.push(json_row("decode", &format!("plan_seq{seq}"), 0.6, &planned, 0.0));

        // Serial-vs-pool head dispatch at this sequence length: 8
        // independent heads through the same sparse kernel and plan.
        let heads = 8;
        let serial = bencher.run(&format!("seq={seq} 8-head serial"), || {
            for _ in 0..heads {
                std::hint::black_box(flashomni_attention(&q, &k, &v, &plan, block, block, None));
            }
        });
        let pooled = bencher.run(&format!("seq={seq} 8-head pool"), || {
            std::hint::black_box(pool.parallel_map_indexed(heads, |_| {
                flashomni_attention(&q, &k, &v, &plan, block, block, None).0
            }));
        });
        println!(
            "8-head dispatch: serial {:.3}ms vs pool {:.3}ms ({:.2}x)",
            serial.median_s * 1e3,
            pooled.median_s * 1e3,
            serial.median_s / pooled.median_s
        );
        json_rows.push(json_row("attention_multihead", &format!("serial_seq{seq}"), 0.6, &serial, 1.0));
        json_rows.push(json_row(
            "attention_multihead",
            &format!("pool_seq{seq}"),
            0.6,
            &pooled,
            pooled.speedup_vs(&serial),
        ));
        rows.push((cached, None));
        rows.push((naive, None));
        rows.push((planned, None));
        rows.push((serial, None));
        rows.push((pooled, None));
        // FC vs BSS at matched sparsity (paper: 4.97× vs 4.6× at 80%).
        let fc_sym = random_symbols(&mut rng, t, t, 1, 0.8, 0.0);
        let bss_sym = random_symbols(&mut rng, t, t, 1, 0.0, 0.8);
        let fc_plan = HeadPlan::from_symbols(&fc_sym, t, t, DecodeMode::RowCached);
        let bss_plan = HeadPlan::from_symbols(&bss_sym, t, t, DecodeMode::RowCached);
        let m_fc = bencher.run(&format!("seq={seq} FC80"), || {
            std::hint::black_box(flashomni_attention(&q, &k, &v, &fc_plan, block, block, None));
        });
        let m_bss = bencher.run(&format!("seq={seq} BSS80"), || {
            std::hint::black_box(flashomni_attention(&q, &k, &v, &bss_plan, block, block, None));
        });
        println!(
            "FC vs BSS at ~80%: FC {:.2}x  BSS {:.2}x (paper: FC 4.97x > BSS 4.6x)",
            m_fc.speedup_vs(&dense),
            m_bss.speedup_vs(&dense)
        );
        json_rows.push(json_row("attention", &format!("FC80_seq{seq}"), 0.8, &m_fc, m_fc.speedup_vs(&dense)));
        json_rows.push(json_row("attention", &format!("BSS80_seq{seq}"), 0.8, &m_bss, m_bss.speedup_vs(&dense)));
        rows.push((m_fc, None));
        rows.push((m_bss, None));
    }
    let _ = write_csv("reports/fig10_attention.csv", &rows);
    let tune_cache = flashomni::kernels::tune::cache_path().unwrap_or_default();
    match write_bench_json_tagged(
        "BENCH_fig10.json",
        "fig10_attention",
        &[
            ("block", block as f64),
            ("head_dim", d as f64),
            ("exec_pool_threads", pool.size() as f64),
            ("fo_tune", flashomni::kernels::tune::enabled() as u8 as f64),
            (
                "simd_available",
                flashomni::kernels::microkernel::simd_available() as u8 as f64,
            ),
        ],
        &[
            (
                "isa",
                flashomni::kernels::microkernel::isa_name(
                    flashomni::kernels::microkernel::active(),
                ),
            ),
            ("fo_tune_cache", &tune_cache),
        ],
        &json_rows,
    ) {
        Ok(()) => println!("\nwrote BENCH_fig10.json ({} rows)", json_rows.len()),
        Err(e) => eprintln!("could not write BENCH_fig10.json: {e}"),
    }
    for p in flashomni::obs::export_if_enabled() {
        println!("wrote {p}");
    }
}
