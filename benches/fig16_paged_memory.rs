//! Figure 16 (repo extension) — **paged memory under budget pressure**:
//! peak resident pages and serving throughput for a batch of B requests,
//! shared (symbol-identical prompts) vs distinct, unbounded vs a tight
//! `FO_PAGE_BUDGET`.
//!
//! Every (scenario, budget) cell runs on a **private** [`PagePool`] so
//! the numbers are isolated from the process-global pool. Before any
//! timing, each cell passes the correctness gates the paged-memory PR
//! promises:
//!
//! * batched outputs under *any* budget are **bitwise-identical** to
//!   unbudgeted solo runs (eviction only ever touches released blocks,
//!   so it is invisible to numerics);
//! * tight-budget cells really evict (`blocks_evicted > 0`) and keep
//!   retained pages under the budget
//!   (`peak_resident ≤ max(budget, peak_live)` — live state is never
//!   evicted, so the budget is soft against live growth);
//! * the shared cell really prefix-shares: B symbol-identical requests
//!   keep **one physical copy** of their content-identical resident
//!   state (`share_hits > 0`, `peak_block_refs ≥ B`).
//!
//! Emits `BENCH_fig16.json`: one row per (scenario, budget) with wall
//! time, requests/s, speedup vs the scenario's unbounded row, and the
//! gate run's pool accounting (peak resident/live pages, allocations,
//! evictions, share hits, CoW copies, peak block refcount). Row schema
//! (custom): `{case, budget_pages, batch, steps, median_ns, min_ns,
//! iters, req_per_s, speedup_vs_unbounded, peak_resident_pages,
//! peak_live_pages, pages_allocated, pages_evicted, share_hits,
//! cow_copies, peak_block_refs}`.
//!
//! Env: FO_BATCH (batch size B, default 4), FO_STEPS (denoising steps,
//! default 9), FO_LAYERS (default 2), FO_PAGE_BUDGET (tight budget in
//! pages, default 32), FO_PAGE_BYTES (page size, default 1024),
//! FO_BUDGET (seconds per measurement, default 0.3). Knobs + the
//! `BENCH_fig16.json` schema: `docs/benchmarks.md`.

use flashomni::batch::{BatchResult, BatchedEngine};
use flashomni::bench::{print_table, write_bench_json, Bencher, Measurement};
use flashomni::config::{ModelConfig, SparsityConfig};
use flashomni::engine::{DiTEngine, Policy};
use flashomni::exec::ExecPool;
use flashomni::mem::PagePool;
use flashomni::model::{weights::Weights, MiniMMDiT};
use flashomni::tensor::Tensor;
use flashomni::workload::{caption_ids, Request};
use std::hint::black_box;
use std::time::Instant;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_u64(key: &str, default: u64) -> u64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn build_model(layers: usize) -> MiniMMDiT {
    let cfg = ModelConfig {
        dim: 32,
        heads: 2,
        layers,
        text_tokens: 8,
        patch_h: 4,
        patch_w: 4,
        patch_size: 2,
        channels: 3,
        mlp_ratio: 2,
        vocab: 256,
    };
    MiniMMDiT::new(cfg.clone(), Weights::random(&cfg, 0xf16))
}

fn policy() -> Policy {
    Policy::flashomni(SparsityConfig {
        tau_q: 0.6,
        tau_kv: 0.3,
        interval: 3,
        order: 1,
        s_q: 0.0,
        block_q: 8,
        block_k: 8,
        pool: 1,
        warmup: 2,
        ramp_steps: 1,
    })
}

fn requests(b: usize, steps: usize, text_tokens: usize, case: &str) -> Vec<Request> {
    (0..b as u64)
        .map(|i| {
            let (scene, seed) =
                if case == "shared" { (5, 1234) } else { (3 * i as usize + 1, 1000 + i) };
            Request {
                id: i,
                scene,
                prompt_ids: caption_ids(scene, text_tokens),
                seed,
                steps,
                arrival_s: 0.0,
                patch_hw: None,
            }
        })
        .collect()
}

/// Unbudgeted solo reference image (private unbounded pool).
fn solo_image(model: &MiniMMDiT, pol: &Policy, req: &Request) -> Tensor {
    let mut engine = DiTEngine::new(model.clone(), pol.clone(), 8, 8);
    engine.set_page_pool(&PagePool::unbounded());
    engine.generate(&req.prompt_ids, req.seed, req.steps).image
}

/// One batched run on an explicit pool, results sorted by request id.
fn run_batch(
    model: &MiniMMDiT,
    pol: &Policy,
    reqs: &[Request],
    pool: &PagePool,
) -> Vec<BatchResult> {
    let mut engine = BatchedEngine::new(model.clone(), pol.clone(), 8, 8, reqs.len());
    engine.set_page_pool(pool);
    for r in reqs {
        engine.admit(r.clone(), Instant::now());
    }
    let mut out = engine.run_to_completion();
    out.sort_by_key(|r| r.id);
    out
}

fn main() {
    let b = env_usize("FO_BATCH", 4);
    let steps = env_usize("FO_STEPS", 9);
    let layers = env_usize("FO_LAYERS", 2);
    let tight = env_u64("FO_PAGE_BUDGET", 32).max(1);
    let page_bytes = env_usize("FO_PAGE_BYTES", 1024);
    let bencher = Bencher { warmup: 1, min_iters: 3, budget_s: env_f64("FO_BUDGET", 0.3) };
    let model = build_model(layers);
    let pol = policy();

    println!(
        "# Figure 16 — paged memory: B={b} × {steps} steps, {layers} layers, \
         page {page_bytes} B, tight budget {tight} pages, exec pool {} threads",
        ExecPool::global().size()
    );

    let mut rows: Vec<(Measurement, Option<f64>)> = Vec::new();
    let mut json_rows: Vec<String> = Vec::new();
    for case in ["shared", "distinct"] {
        let reqs = requests(b, steps, model.cfg.text_tokens, case);
        let solo: Vec<Tensor> = reqs.iter().map(|r| solo_image(&model, &pol, r)).collect();
        let mut base: Option<f64> = None;
        for budget in [0u64, tight] {
            // Correctness gates before timing anything.
            let pool = PagePool::with_budget(budget, page_bytes);
            let results = run_batch(&model, &pol, &reqs, &pool);
            for (r, want) in results.iter().zip(&solo) {
                assert_eq!(
                    &r.image, want,
                    "case {case} budget {budget}: request {} must be bitwise-identical \
                     to its unbudgeted solo run",
                    r.id
                );
            }
            let ps = pool.stats();
            if budget > 0 {
                assert!(
                    ps.blocks_evicted > 0,
                    "tight budget must actually evict (case {case}): {ps:?}"
                );
                assert!(
                    ps.peak_resident_pages <= ps.peak_live_pages.max(budget),
                    "retained pages must stay under the budget (case {case}): {ps:?}"
                );
            }
            if case == "shared" && b > 1 {
                assert!(ps.share_hits > 0, "identical batch must prefix-share: {ps:?}");
                assert!(
                    ps.peak_block_refs >= b as u64,
                    "B symbol-identical requests must ride one physical copy \
                     (refcount ≥ {b}): {ps:?}"
                );
            }
            println!(
                "  gate {case} budget={budget}: peak resident {} / live {} pages, \
                 {} pages evicted, {} share hits, peak refs {}",
                ps.peak_resident_pages,
                ps.peak_live_pages,
                ps.pages_evicted,
                ps.share_hits,
                ps.peak_block_refs
            );

            let m = bencher.run(&format!("{case} budget={budget}"), || {
                let pool = PagePool::with_budget(budget, page_bytes);
                black_box(run_batch(&model, &pol, &reqs, &pool));
            });
            let rps = b as f64 / m.median_s;
            if budget == 0 {
                base = Some(m.median_s);
            }
            let speedup = base.map(|b0| b0 / m.median_s).unwrap_or(1.0);
            json_rows.push(format!(
                "{{\"case\":\"{case}\",\"budget_pages\":{budget},\"batch\":{b},\
                 \"steps\":{steps},\"median_ns\":{:.0},\"min_ns\":{:.0},\"iters\":{},\
                 \"req_per_s\":{rps:.4},\"speedup_vs_unbounded\":{speedup:.4},\
                 \"peak_resident_pages\":{},\"peak_live_pages\":{},\
                 \"pages_allocated\":{},\"pages_evicted\":{},\"share_hits\":{},\
                 \"cow_copies\":{},\"peak_block_refs\":{}}}",
                m.median_s * 1e9,
                m.min_s * 1e9,
                m.iters,
                ps.peak_resident_pages,
                ps.peak_live_pages,
                ps.pages_allocated,
                ps.pages_evicted,
                ps.share_hits,
                ps.cow_copies,
                ps.peak_block_refs,
            ));
            rows.push((m, Some(speedup)));
        }
    }
    print_table("fig16 — paged memory: throughput vs page budget", &rows);

    match write_bench_json(
        "BENCH_fig16.json",
        "fig16_paged_memory",
        &[
            ("batch", b as f64),
            ("steps", steps as f64),
            ("layers", layers as f64),
            ("dim", model.cfg.dim as f64),
            ("heads", model.cfg.heads as f64),
            ("seq", model.cfg.seq_len() as f64),
            ("page_bytes", page_bytes as f64),
            ("tight_budget_pages", tight as f64),
            ("exec_pool_threads", ExecPool::global().size() as f64),
        ],
        &json_rows,
    ) {
        Ok(()) => println!("\nwrote BENCH_fig16.json ({} rows)", json_rows.len()),
        Err(e) => eprintln!("could not write BENCH_fig16.json: {e}"),
    }
    for p in flashomni::obs::export_if_enabled() {
        println!("wrote {p}");
    }
}
