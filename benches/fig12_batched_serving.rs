//! Figure 12 (repo extension) — **batched serving throughput** and the
//! cross-request plan-sharing invariant.
//!
//! Three scenarios per batch size B ∈ {1, 2, 4, 8} (clamped by FO_BATCH),
//! each on a fresh engine + plan cache:
//!
//! * **shared** — B symbol-identical requests (same prompt + seed, the
//!   repeated-prompt burst). The `RunStats.plan_cache_misses` sum must be
//!   exactly `layers × refresh points` — **one plan compile per (layer,
//!   refresh) per batch**, with the other B−1 requests counted in
//!   `plan_cache_shared`. `compiles_per_refresh` in the JSON asserts it.
//! * **distinct** — B distinct prompts/seeds (worst case: no symbol
//!   collisions, the batch still amortizes head dispatch and tile-loop
//!   overheads but compiles B plans per refresh).
//! * **mixed** — B distinct prompts/seeds at **mixed resolutions**
//!   (`patch_hw` cycles 8×8 / 6×6 / 4×4, so sequence lengths 72/44/24
//!   ride one ragged kernel walk). Exercises the cu-seqlen path the
//!   dedicated fig14 bench measures against bucketing baselines.
//!
//! Emits `BENCH_fig12.json`: one row per (scenario, B) with wall time,
//! throughput, latency percentiles (p50/p95/p99 via `ServeReport`, split
//! into queue-wait and execution components), the plan-compile
//! accounting, and the uniform `plan_cache_*` counters every bench row
//! carries. Row schema (custom, documented here):
//! `{case, batch, requests, steps, wall_s, req_per_s, speedup_vs_b1,
//! plan_compiles, plan_shared, refresh_points, compiles_per_refresh,
//! p50_s, p95_s, p99_s, p50_queue_s, p95_queue_s, p99_queue_s,
//! p50_exec_s, p95_exec_s, p99_exec_s, plan_cache_hits,
//! plan_cache_misses, plan_cache_shared, plan_cache_delta}`.
//!
//! With `FO_METRICS`/`FO_TRACE` set, the run also exports the Prometheus
//! dump / Chrome trace at exit, and asserts the accounted per-kernel span
//! time covers ≥ 95% of `engine.step` wall time (the tentpole coverage
//! gate; `docs/observability.md`).
//!
//! Env: FO_REQUESTS (requests per run, default 8), FO_BATCH (max batch
//! size, default 8), FO_STEPS (denoising steps, default 8), FO_LAYERS
//! (default 2), FO_CHUNK (tile-loop chunk override, recorded in header),
//! FO_METRICS / FO_TRACE (observability exports).
//! Knobs + the `BENCH_fig12.json` schema: `docs/benchmarks.md`.

use flashomni::batch::{BatchScheduler, BatchedEngine};
use flashomni::bench::{write_bench_json_tagged, PlanCacheCounters};
use flashomni::config::{ModelConfig, SparsityConfig};
use flashomni::coordinator::{Response, ServeReport};
use flashomni::diffusion::plan_steps;
use flashomni::engine::Policy;
use flashomni::exec::ExecPool;
use flashomni::model::{weights::Weights, MiniMMDiT};
use flashomni::workload::{caption_ids, Request};
use std::time::Instant;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn build_model(layers: usize) -> MiniMMDiT {
    let cfg = ModelConfig {
        dim: 64,
        heads: 4,
        layers,
        text_tokens: 8,
        patch_h: 8,
        patch_w: 8,
        patch_size: 2,
        channels: 3,
        mlp_ratio: 2,
        vocab: 256,
    };
    MiniMMDiT::new(cfg.clone(), Weights::random(&cfg, 0xf12))
}

fn policy() -> Policy {
    Policy::flashomni(SparsityConfig {
        tau_q: 0.5,
        tau_kv: 0.2,
        interval: 3,
        order: 1,
        s_q: 0.0,
        block_q: 8,
        block_k: 8,
        pool: 1,
        warmup: 2,
        ramp_steps: 1,
    })
}

fn requests(n: usize, steps: usize, text_tokens: usize, case: &str) -> Vec<Request> {
    // Mixed-geometry stream: native 8×8 (seq 72) plus 6×6 (44) and 4×4 (24).
    const GRIDS: [Option<(usize, usize)>; 3] = [None, Some((6, 6)), Some((4, 4))];
    (0..n as u64)
        .map(|i| {
            let (scene, seed) =
                if case == "shared" { (5, 1234) } else { (3 * i as usize + 1, 1000 + i) };
            Request {
                id: i,
                scene,
                prompt_ids: caption_ids(scene, text_tokens),
                seed,
                steps,
                arrival_s: 0.0,
                patch_hw: if case == "mixed" { GRIDS[i as usize % GRIDS.len()] } else { None },
            }
        })
        .collect()
}

fn main() {
    let n_req = env_usize("FO_REQUESTS", 8);
    let max_b = env_usize("FO_BATCH", 8);
    let steps = env_usize("FO_STEPS", 8);
    let layers = env_usize("FO_LAYERS", 2);
    let model = build_model(layers);
    let pol = policy();
    let (warmup, interval) = pol.schedule();
    let full_steps =
        plan_steps(steps, warmup.min(steps), interval).iter().filter(|k| !k.is_sparse()).count();
    let refresh_points = (layers * full_steps) as u64;

    println!(
        "# Figure 12 — batched serving: {n_req} requests × {steps} steps, {layers} layers, policy {}",
        pol.name()
    );
    let mut json_rows: Vec<String> = Vec::new();

    for case in ["shared", "distinct", "mixed"] {
        let shared = case == "shared";
        // Throughput scaling is reported against this scenario's B = 1 run.
        let mut base_rps: Option<f64> = None;
        for b in [1usize, 2, 4, 8] {
            if b > max_b {
                continue;
            }
            let reqs = requests(n_req.max(b), steps, model.cfg.text_tokens, case);
            let mut sched =
                BatchScheduler::new(BatchedEngine::new(model.clone(), policy(), 8, 8, b));
            for r in &reqs {
                sched.submit(r.clone());
            }
            let t0 = Instant::now();
            let results = sched.run_to_completion();
            let wall = t0.elapsed().as_secs_f64();
            let cache = sched.engine().plan_cache_stats();

            let compiles: u64 = results.iter().map(|r| r.stats.plan_cache_misses).sum();
            let shared_hits: u64 = results.iter().map(|r| r.stats.plan_cache_shared).sum();
            assert_eq!(compiles, cache.misses, "per-request counters must cover the cache");
            // Per-batch compile rate: for the shared burst with B = batch
            // this is exactly 1.0 (the tentpole invariant); later cohorts
            // of the same run reuse earlier cohorts' plans outright.
            let cohorts = reqs.len().div_ceil(b) as u64;
            let compiles_per_refresh = compiles as f64 / (refresh_points * cohorts) as f64;
            let rps = results.len() as f64 / wall.max(1e-9);
            if b == 1 {
                base_rps = Some(rps);
            }
            let speedup = base_rps.map(|b0| rps / b0).unwrap_or(1.0);
            if shared {
                assert!(
                    compiles <= refresh_points,
                    "shared burst must never compile a plan twice (got {compiles} > {refresh_points})"
                );
            }

            // Latency percentiles through the coordinator's ServeReport
            // (the satellite: batched paths print p50/p95/p99).
            let responses: Vec<Response> = results
                .iter()
                .map(|r| Response {
                    id: r.id,
                    scene: r.scene,
                    image: r.image.clone(),
                    stats: r.stats.clone(),
                    queue_s: r.queue_s,
                    exec_s: r.exec_s,
                    latency_s: r.latency_s,
                    worker: 0,
                    batch_size: r.batch_size,
                })
                .collect();
            let report = ServeReport::from_responses(&responses, wall);
            report.print(&format!("fig12 {case} B={b}"));
            println!(
                "    plan compiles {compiles} (shared hits {shared_hits}, {:.3} compiles/refresh over {cohorts} cohort(s))",
                compiles_per_refresh
            );

            // The uniform plan-cache counter schema, from the per-request
            // stats (works with FO_METRICS unset).
            let counters = PlanCacheCounters {
                hits: results.iter().map(|r| r.stats.plan_cache_hits).sum(),
                misses: compiles,
                shared: shared_hits,
                delta: results.iter().map(|r| r.stats.plan_cache_delta).sum(),
            };
            json_rows.push(format!(
                "{{\"case\":\"{case}\",\"batch\":{b},\"requests\":{},\"steps\":{steps},\
                 \"wall_s\":{wall:.6},\"req_per_s\":{rps:.4},\"speedup_vs_b1\":{speedup:.4},\
                 \"plan_compiles\":{compiles},\"plan_shared\":{shared_hits},\
                 \"refresh_points\":{refresh_points},\"compiles_per_refresh\":{compiles_per_refresh:.4},\
                 \"p50_s\":{:.6},\"p95_s\":{:.6},\"p99_s\":{:.6},\
                 \"p50_queue_s\":{:.6},\"p95_queue_s\":{:.6},\"p99_queue_s\":{:.6},\
                 \"p50_exec_s\":{:.6},\"p95_exec_s\":{:.6},\"p99_exec_s\":{:.6},\
                 \"plan_cache_hits\":{},\"plan_cache_misses\":{},\
                 \"plan_cache_shared\":{},\"plan_cache_delta\":{}}}",
                results.len(),
                report.p50_latency_s,
                report.p95_latency_s,
                report.p99_latency_s,
                report.p50_queue_s,
                report.p95_queue_s,
                report.p99_queue_s,
                report.p50_exec_s,
                report.p95_exec_s,
                report.p99_exec_s,
                counters.hits,
                counters.misses,
                counters.shared,
                counters.delta,
            ));
        }
    }

    let tune_cache = flashomni::kernels::tune::cache_path().unwrap_or_default();
    match write_bench_json_tagged(
        "BENCH_fig12.json",
        "fig12_batched_serving",
        &[
            ("requests", n_req as f64),
            ("steps", steps as f64),
            ("layers", layers as f64),
            ("dim", model.cfg.dim as f64),
            ("heads", model.cfg.heads as f64),
            ("seq", model.cfg.seq_len() as f64),
            ("exec_pool_threads", ExecPool::global().size() as f64),
            ("fo_chunk", flashomni::exec::tile_chunk_override().unwrap_or(0) as f64),
            ("fo_tune", flashomni::kernels::tune::enabled() as u8 as f64),
            (
                "simd_available",
                flashomni::kernels::microkernel::simd_available() as u8 as f64,
            ),
        ],
        &[
            (
                "isa",
                flashomni::kernels::microkernel::isa_name(
                    flashomni::kernels::microkernel::active(),
                ),
            ),
            ("fo_tune_cache", &tune_cache),
        ],
        &json_rows,
    ) {
        Ok(()) => println!("\nwrote BENCH_fig12.json ({} rows)", json_rows.len()),
        Err(e) => eprintln!("could not write BENCH_fig12.json: {e}"),
    }

    // Tentpole coverage gate: with metrics on, the accounted per-kernel /
    // per-phase span time must explain ≥ 95% of engine.step wall time.
    if flashomni::obs::metrics_enabled() {
        let frac = flashomni::obs::accounted_step_fraction();
        println!("obs: accounted span time covers {:.2}% of engine.step", frac * 100.0);
        assert!(
            frac >= 0.95,
            "accounted kernel-family span time covers only {:.2}% of engine.step wall \
             time (bound: 95%)",
            frac * 100.0
        );
    }
    for p in flashomni::obs::export_if_enabled() {
        println!("wrote {p}");
    }
}
