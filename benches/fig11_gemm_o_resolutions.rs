//! Figure 11 (appendix A.3) — GEMM-O speedup across generation-task
//! resolutions (1K-image / 2K-image / video scale) and N ∈ {4, 6, 8}.
//!
//! Paper: ~2.5–3.4× at standard resolution (lower kernel parallelism →
//! decode overhead more visible), 2.7–3.9× at ultra-high resolution.
//! Our scaled token lengths: 272 (mini), 1088 (FLUX-1K scale), 4096
//! (video scale).
//!
//! PR 2: also times the pool-backed dispatch kernel and emits a
//! machine-readable `BENCH_fig11.json` perf trajectory like fig6.
//! Env: FO_BUDGET; FO_MAX_SEQ skips resolutions above the given token
//! length (CI smoke runs set it low to keep the bench to seconds).
//! Knobs + the `BENCH_fig11.json` schema: `docs/benchmarks.md`.

use flashomni::bench::{json_row, write_bench_json_tagged, write_csv, Bencher, Measurement};
use flashomni::exec::ExecPool;
use flashomni::kernels::flops;
use flashomni::kernels::gemm_o::{
    gemm_o_dispatch, gemm_o_dispatch_pool, gemm_o_update, WeightPanels,
};
use flashomni::plan::{DecodeMode, SparsePlan};
use flashomni::symbols::{random_symbols, LayerSymbols};
use flashomni::testutil::randn;
use flashomni::util::rng::Pcg32;

fn main() {
    let budget: f64 =
        std::env::var("FO_BUDGET").ok().and_then(|v| v.parse().ok()).unwrap_or(0.3);
    let max_seq: usize = std::env::var("FO_MAX_SEQ")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(usize::MAX);
    let bencher = Bencher { warmup: 1, min_iters: 3, budget_s: budget };
    let heads = 8;
    let d_h = 64;
    let d = heads * d_h;
    let sparsity = 0.8f64;
    let pool = ExecPool::global();
    let mut rows: Vec<(Measurement, Option<f64>)> = Vec::new();
    let mut json_rows: Vec<String> = Vec::new();

    println!("# Figure 11 — GEMM-O speedup across resolutions (sparsity {sparsity})");
    for (label, seq, block) in
        [("mini-272", 272usize, 16usize), ("flux1k-1088", 1088, 32), ("video-4096", 4096, 64)]
    {
        if seq > max_seq {
            println!("{label:<12} skipped (FO_MAX_SEQ={max_seq})");
            continue;
        }
        let mut rng = Pcg32::seeded(0xb11 + seq as u64);
        let t = seq.div_ceil(block);
        let o = randn(&mut rng, &[seq, d]);
        let w = randn(&mut rng, &[d, d]);
        let panels = WeightPanels::new(&w, heads);
        // Fair baseline: same tiled kernel, dense plan, zero bias.
        let dense_plan = SparsePlan::dense(heads, t, t, block, block);
        let zero_bias = flashomni::tensor::Tensor::zeros(&[seq, d]);
        let dense = bencher.run(&format!("{label} dense"), || {
            std::hint::black_box(gemm_o_dispatch(&o, &panels, &dense_plan, &zero_bias));
        });
        json_rows.push(json_row("gemm_o", &format!("dense_{label}"), 0.0, &dense, 1.0));
        rows.push((dense.clone(), Some(1.0)));
        for interval in [4usize, 6, 8] {
            let syms = LayerSymbols {
                heads: (0..heads)
                    .map(|_| random_symbols(&mut rng, t, t, 1, sparsity, 0.0))
                    .collect(),
            };
            let plan = SparsePlan::compile(&syms, t, t, block, block, DecodeMode::RowCached);
            let (_, bias, _) = gemm_o_update(&o, &panels, &plan);
            let update = bencher.run(&format!("{label} update N={interval}"), || {
                std::hint::black_box(gemm_o_update(&o, &panels, &plan));
            });
            let dispatch = bencher.run(&format!("{label} dispatch N={interval}"), || {
                std::hint::black_box(gemm_o_dispatch(&o, &panels, &plan, &bias));
            });
            let dispatch_pool =
                bencher.run(&format!("{label} dispatch pool N={interval}"), || {
                    std::hint::black_box(gemm_o_dispatch_pool(&o, &panels, &plan, &bias, &pool));
                });
            let fo = update.median_s + (interval - 1) as f64 * dispatch.median_s;
            let fo_pool = update.median_s + (interval - 1) as f64 * dispatch_pool.median_s;
            let speedup = interval as f64 * dense.median_s / fo;
            let speedup_pool = interval as f64 * dense.median_s / fo_pool;
            let theory = flops::gemm_o_theoretical_speedup(interval, sparsity);
            println!(
                "{label:<12} N={interval}  speedup {speedup:.2}x (pool {speedup_pool:.2}x)  theory {theory:.2}x  %of-theory {:.1}%",
                100.0 * speedup / theory
            );
            json_rows.push(json_row(
                "gemm_o_update",
                &format!("{label}_N{interval}"),
                sparsity,
                &update,
                0.0,
            ));
            json_rows.push(json_row(
                "gemm_o_dispatch",
                &format!("{label}_N{interval}"),
                sparsity,
                &dispatch,
                speedup,
            ));
            json_rows.push(json_row(
                "gemm_o_dispatch_pool",
                &format!("{label}_N{interval}"),
                sparsity,
                &dispatch_pool,
                speedup_pool,
            ));
            rows.push((update, None));
            rows.push((dispatch, Some(speedup)));
            rows.push((dispatch_pool, Some(speedup_pool)));
        }
    }
    let _ = write_csv("reports/fig11_gemm_o_resolutions.csv", &rows);
    let tune_cache = flashomni::kernels::tune::cache_path().unwrap_or_default();
    match write_bench_json_tagged(
        "BENCH_fig11.json",
        "fig11_gemm_o_resolutions",
        &[
            ("heads", heads as f64),
            ("head_dim", d_h as f64),
            ("sparsity", sparsity),
            ("exec_pool_threads", pool.size() as f64),
            ("fo_tune", flashomni::kernels::tune::enabled() as u8 as f64),
            (
                "simd_available",
                flashomni::kernels::microkernel::simd_available() as u8 as f64,
            ),
        ],
        &[
            (
                "isa",
                flashomni::kernels::microkernel::isa_name(
                    flashomni::kernels::microkernel::active(),
                ),
            ),
            ("fo_tune_cache", &tune_cache),
        ],
        &json_rows,
    ) {
        Ok(()) => println!("\nwrote BENCH_fig11.json ({} rows)", json_rows.len()),
        Err(e) => eprintln!("could not write BENCH_fig11.json: {e}"),
    }
    for p in flashomni::obs::export_if_enabled() {
        println!("wrote {p}");
    }
}
