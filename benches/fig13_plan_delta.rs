//! Figure 13 (repo extension) — **incremental plan recompile** latency:
//! Update-path symbol→plan compilation vs. the fraction of row-groups
//! whose symbols flipped since the previous refresh.
//!
//! Slowly-drifting masks are the common case for caching-style policies
//! (and per-step mask policies on slowly-evolving activations): between
//! refreshes most rows keep their `S_c`/`S_s` bytes, so recompiling the
//! whole layer wastes decode work. The delta path diffs the packed symbol
//! bytes against the cached plan's key (`PlanDelta::between`) and rebuilds
//! only the changed row-groups (`SparsePlan::apply_delta`), structurally
//! sharing the rest.
//!
//! For flip fractions {0%, 1%, 10%, 50%, 100%} this bench times four
//! compile paths on one layer's symbols — full/delta × serial/pool (the
//! pool variants fan per-head work over the shared `ExecPool`) — and
//! asserts the delta output equals the full recompile bitwise before
//! timing. The delta rows *include* the key-diff cost: they measure the
//! real Update-path alternative to a full compile.
//!
//! Emits `BENCH_fig13.json` (row schema and env knobs documented in
//! `docs/benchmarks.md`): `case` is `{full,delta}_{serial,pool}`, the
//! shared-schema `sparsity` column carries the flip fraction, and
//! `speedup` is that flip fraction's `full_serial` median over the row's
//! median.
//!
//! PR 6: the symbol **pooling factor** is a sweep axis. FlashOmni packs
//! `pool` logical blocks per symbol bit (§3.4's `n`), shrinking the
//! symbol bytes — and therefore the key diff and recompile work — by
//! `pool`² on the S_s grid. `FO_POOLS` (comma list, default `"1,4"`)
//! selects the factors; pool = 1 rows keep their original case names and
//! pool > 1 rows get a `_p<pool>` suffix, so existing trajectory diffs
//! stay aligned.
//!
//! Env: FO_SEQ (sequence length, default 4096), FO_HEADS (default 8),
//! FO_BUDGET (seconds per measurement, default 0.3), FO_POOLS, FO_CHUNK
//! (tile-chunk override, recorded in the header). Knobs + the
//! `BENCH_fig13.json` schema: `docs/benchmarks.md`.

use flashomni::bench::{json_row, print_table, write_bench_json_tagged, Bencher, Measurement};
use flashomni::exec::ExecPool;
use flashomni::plan::cache::symbol_key;
use flashomni::plan::{DecodeMode, PlanDelta, SparsePlan};
use flashomni::symbols::{HeadSymbols, LayerSymbols};
use flashomni::util::rng::Pcg32;
use std::hint::black_box;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

type Masks = Vec<(Vec<bool>, Vec<bool>)>;

fn pack(masks: &Masks, kg: usize, pool: usize) -> LayerSymbols {
    LayerSymbols {
        heads: masks
            .iter()
            .map(|(m_c, m_s)| HeadSymbols::from_masks(m_c, m_s, kg, pool))
            .collect(),
    }
}

/// Flip `flips` distinct, evenly-spread row-groups per head: toggle the
/// group's `S_c` bit and re-randomize its `S_s` row. Masks are over the
/// pooled `[qg × kg]` symbol grid, not raw blocks.
fn flip(rng: &mut Pcg32, base: &Masks, qg: usize, kg: usize, flips: usize) -> Masks {
    let mut out = base.clone();
    for (m_c, m_s) in out.iter_mut() {
        for i in 0..flips {
            let g = i * qg / flips.max(1);
            m_c[g] = !m_c[g];
            for j in 0..kg {
                m_s[g * kg + j] = rng.f64() >= 0.5;
            }
        }
    }
    out
}

fn main() {
    let seq = env_usize("FO_SEQ", 4096);
    let heads = env_usize("FO_HEADS", 8);
    let block = 16;
    let t = seq.div_ceil(block);
    let bencher = Bencher { warmup: 1, min_iters: 3, budget_s: env_f64("FO_BUDGET", 0.3) };
    let exec = ExecPool::global();
    let mut rng = Pcg32::seeded(0xf13);
    let pools_env = std::env::var("FO_POOLS").unwrap_or_else(|_| "1,4".to_string());
    let pools: Vec<usize> = pools_env
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .filter(|&p| p > 0 && p <= t)
        .collect();
    assert!(!pools.is_empty(), "FO_POOLS={pools_env:?} selected no valid pooling factors");

    println!(
        "# Figure 13 — incremental plan recompile: seq {seq}, {heads} heads, t_q {t}, \
         pools {pools:?}, exec pool {} threads",
        exec.size()
    );

    let mut rows: Vec<(Measurement, Option<f64>)> = Vec::new();
    let mut json_rows: Vec<String> = Vec::new();
    for &pool in &pools {
        // pool = 1 keeps the original case names so trajectory diffs stay
        // aligned; pooled sweeps get a `_p<pool>` suffix.
        let suffix = if pool == 1 { String::new() } else { format!("_p{pool}") };
        let qg = t.div_ceil(pool);
        let kg = t.div_ceil(pool);
        // Base refresh: ~30% cached row-groups, ~50% KV skips on live rows.
        let base_masks: Masks = (0..heads)
            .map(|_| {
                let m_c: Vec<bool> = (0..qg).map(|_| rng.f64() >= 0.3).collect();
                let m_s: Vec<bool> = (0..qg * kg).map(|_| rng.f64() >= 0.5).collect();
                (m_c, m_s)
            })
            .collect();
        let base_syms = pack(&base_masks, kg, pool);
        let geometry = [t, t, block, block];
        let base_key = symbol_key(&base_syms, &geometry);
        let base_plan =
            SparsePlan::compile(&base_syms, t, t, block, block, DecodeMode::RowCached);

        for frac in [0.0, 0.01, 0.1, 0.5, 1.0] {
            let flips = ((frac * qg as f64).ceil() as usize).min(qg);
            let new_masks = flip(&mut rng, &base_masks, qg, kg, flips);
            let new_syms = pack(&new_masks, kg, pool);
            let new_key = symbol_key(&new_syms, &geometry);
            let delta = PlanDelta::between(&base_key, &new_key, &new_syms, geometry.len())
                .expect("same geometry must be row-diffable");

            // Correctness gate before timing anything.
            let full =
                SparsePlan::compile(&new_syms, t, t, block, block, DecodeMode::RowCached);
            let inc = base_plan.apply_delta(&delta, &new_syms, DecodeMode::RowCached);
            assert_eq!(inc, full, "delta recompile must be bitwise-identical to full");
            drop(inc);

            let full_serial = bencher.run(&format!("full_serial{suffix} flip={frac}"), || {
                black_box(SparsePlan::compile(
                    &new_syms,
                    t,
                    t,
                    block,
                    block,
                    DecodeMode::RowCached,
                ));
            });
            let delta_serial = bencher.run(&format!("delta_serial{suffix} flip={frac}"), || {
                let d = PlanDelta::between(&base_key, &new_key, &new_syms, geometry.len())
                    .expect("diffable");
                black_box(base_plan.apply_delta(&d, &new_syms, DecodeMode::RowCached));
            });
            let full_pool = bencher.run(&format!("full_pool{suffix} flip={frac}"), || {
                black_box(SparsePlan::compile_on(
                    &new_syms,
                    t,
                    t,
                    block,
                    block,
                    DecodeMode::RowCached,
                    &exec,
                ));
            });
            let delta_pool = bencher.run(&format!("delta_pool{suffix} flip={frac}"), || {
                let d = PlanDelta::between(&base_key, &new_key, &new_syms, geometry.len())
                    .expect("diffable");
                black_box(base_plan.apply_delta_on(&d, &new_syms, DecodeMode::RowCached, &exec));
            });

            for m in [&full_serial, &delta_serial, &full_pool, &delta_pool] {
                let speedup = full_serial.median_s / m.median_s;
                let case = m.name.split_whitespace().next().unwrap_or("?").to_string();
                json_rows.push(json_row("plan_update", &case, frac, m, speedup));
                rows.push((m.clone(), Some(speedup)));
            }
        }
    }
    print_table("fig13 — plan Update/recompile latency vs rows flipped", &rows);

    match write_bench_json_tagged(
        "BENCH_fig13.json",
        "fig13_plan_delta",
        &[
            ("seq", seq as f64),
            ("heads", heads as f64),
            ("t_q", t as f64),
            ("block", block as f64),
            ("exec_pool_threads", exec.size() as f64),
            ("fo_chunk", flashomni::exec::tile_chunk_override().unwrap_or(0) as f64),
        ],
        &[("fo_pools", pools_env.as_str())],
        &json_rows,
    ) {
        Ok(()) => println!("\nwrote BENCH_fig13.json ({} rows)", json_rows.len()),
        Err(e) => eprintln!("could not write BENCH_fig13.json: {e}"),
    }
    for p in flashomni::obs::export_if_enabled() {
        println!("wrote {p}");
    }
}
