//! Configuration types: model architecture, sparsity policy parameters, and
//! engine/serving settings. Loadable from JSON files (see `configs/`).

use crate::util::json::Json;

/// MiniMMDiT architecture configuration (must match the JAX model that
/// produced the weights artifact).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    /// Transformer width.
    pub dim: usize,
    /// Number of attention heads (`dim % heads == 0`).
    pub heads: usize,
    /// Number of double-stream MMDiT blocks.
    pub layers: usize,
    /// Number of text tokens (fixed length, as in MMDiT).
    pub text_tokens: usize,
    /// Vision latent grid height in patches.
    pub patch_h: usize,
    /// Vision latent grid width in patches.
    pub patch_w: usize,
    /// Pixels per patch side.
    pub patch_size: usize,
    /// Image channels.
    pub channels: usize,
    /// MLP expansion ratio.
    pub mlp_ratio: usize,
    /// Text-embedding vocabulary (hash-embedding) size.
    pub vocab: usize,
}

impl ModelConfig {
    /// The small trained configuration shipped in `artifacts/weights.fot`.
    /// Sized so that the toy rectified-flow training run completes on one
    /// CPU core (~2.1M parameters, 24×24 RGB images, 160-token joint seq).
    pub fn mini() -> Self {
        ModelConfig {
            dim: 128,
            heads: 4,
            layers: 4,
            text_tokens: 16,
            patch_h: 12,
            patch_w: 12,
            patch_size: 2,
            channels: 3,
            mlp_ratio: 4,
            vocab: 256,
        }
    }

    pub fn head_dim(&self) -> usize {
        self.dim / self.heads
    }
    pub fn vision_tokens(&self) -> usize {
        self.patch_h * self.patch_w
    }
    /// Joint sequence length N = N_text + N_vision.
    pub fn seq_len(&self) -> usize {
        self.text_tokens + self.vision_tokens()
    }
    /// Image height/width in pixels.
    pub fn image_h(&self) -> usize {
        self.patch_h * self.patch_size
    }
    pub fn image_w(&self) -> usize {
        self.patch_w * self.patch_size
    }
    /// Patch feature dimension (pixels per patch × channels).
    pub fn patch_dim(&self) -> usize {
        self.patch_size * self.patch_size * self.channels
    }

    pub fn from_json(v: &Json) -> Result<Self, String> {
        let g = |k: &str| -> Result<usize, String> {
            v.req(k)?.as_usize().ok_or_else(|| format!("bad field {k}"))
        };
        Ok(ModelConfig {
            dim: g("dim")?,
            heads: g("heads")?,
            layers: g("layers")?,
            text_tokens: g("text_tokens")?,
            patch_h: g("patch_h")?,
            patch_w: g("patch_w")?,
            patch_size: g("patch_size")?,
            channels: g("channels")?,
            mlp_ratio: g("mlp_ratio")?,
            vocab: g("vocab")?,
        })
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dim", Json::Num(self.dim as f64)),
            ("heads", Json::Num(self.heads as f64)),
            ("layers", Json::Num(self.layers as f64)),
            ("text_tokens", Json::Num(self.text_tokens as f64)),
            ("patch_h", Json::Num(self.patch_h as f64)),
            ("patch_w", Json::Num(self.patch_w as f64)),
            ("patch_size", Json::Num(self.patch_size as f64)),
            ("channels", Json::Num(self.channels as f64)),
            ("mlp_ratio", Json::Num(self.mlp_ratio as f64)),
            ("vocab", Json::Num(self.vocab as f64)),
        ])
    }
}

/// FlashOmni sparsity configuration — the paper's `(τ_q, τ_kv, N, D, S_q)`
/// tuple (Appendix A.1.1) plus block sizes and warmup.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SparsityConfig {
    /// `τ_q` — cumulative-importance threshold for caching Q blocks
    /// (spatial sparsity / feature caching), in [0, 1].
    pub tau_q: f64,
    /// `τ_kv` — cumulative-importance threshold for skipping KV blocks
    /// (block-sparse skipping), in [0, 1].
    pub tau_kv: f64,
    /// `N` — cache interval: one Update step followed by `N−1` Dispatch
    /// steps.
    pub interval: usize,
    /// `D` — TaylorSeer expansion order (0 = direct reuse).
    pub order: usize,
    /// `S_q` — degradation threshold: if the fraction of Q blocks requiring
    /// compute falls below this, the layer degenerates to full feature
    /// caching.
    pub s_q: f64,
    /// Q block size `b_q` (tokens per block; also the caching granularity).
    pub block_q: usize,
    /// KV block size `b_k`.
    pub block_k: usize,
    /// Pooling factor `n` for the compressed attention map (so one symbol
    /// bit covers `n` logical blocks, §3.3).
    pub pool: usize,
    /// Full-attention warmup steps before any sparsity is applied.
    pub warmup: usize,
    /// Steps over which τ ramps from 0 to its target (A.1.1: thresholds
    /// "progressively converge" to their targets).
    pub ramp_steps: usize,
}

impl Default for SparsityConfig {
    fn default() -> Self {
        SparsityConfig {
            tau_q: 0.5,
            tau_kv: 0.15,
            interval: 5,
            order: 1,
            s_q: 0.3,
            block_q: 16,
            block_k: 16,
            pool: 1,
            warmup: 4,
            ramp_steps: 8,
        }
    }
}

impl SparsityConfig {
    /// Paper-style constructor: `(τ_q, τ_kv, N, D, S_q)`.
    pub fn paper(tau_q: f64, tau_kv: f64, interval: usize, order: usize, s_q: f64) -> Self {
        SparsityConfig { tau_q, tau_kv, interval, order, s_q, ..Default::default() }
    }

    /// τ value at a given (0-based) denoising step, ramping linearly from 0.
    pub fn tau_at(&self, target: f64, step: usize) -> f64 {
        if step < self.warmup {
            return 0.0;
        }
        let k = (step - self.warmup) as f64 + 1.0;
        let r = self.ramp_steps.max(1) as f64;
        target * (k / r).min(1.0)
    }

    /// Label matching the paper's configuration tuples, e.g.
    /// `(50%, 15%, 5, 1, 30%)`.
    pub fn label(&self) -> String {
        format!(
            "({:.0}%, {:.0}%, {}, {}, {:.0}%)",
            self.tau_q * 100.0,
            self.tau_kv * 100.0,
            self.interval,
            self.order,
            self.s_q * 100.0
        )
    }
}

/// Engine/serving configuration.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Denoising steps per request.
    pub steps: usize,
    /// Worker threads in the coordinator.
    pub workers: usize,
    /// Maximum batch size the batcher will form.
    pub max_batch: usize,
    /// Microseconds the batcher waits to fill a batch.
    pub batch_wait_us: u64,
    /// Path to the weights artifact.
    pub weights: String,
    /// Path to the artifacts directory (HLO text modules).
    pub artifacts_dir: String,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            steps: 50,
            workers: 1,
            max_batch: 4,
            batch_wait_us: 2_000,
            weights: "artifacts/weights.fot".into(),
            artifacts_dir: "artifacts".into(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mini_config_consistency() {
        let c = ModelConfig::mini();
        assert_eq!(c.dim % c.heads, 0);
        assert_eq!(c.seq_len(), 16 + 144);
        assert_eq!(c.image_h(), 24);
        assert_eq!(c.patch_dim(), 12);
    }

    #[test]
    fn model_config_json_roundtrip() {
        let c = ModelConfig::mini();
        let j = c.to_json().to_string();
        let c2 = ModelConfig::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn paper_label() {
        let s = SparsityConfig::paper(0.5, 0.15, 5, 1, 0.3);
        assert_eq!(s.label(), "(50%, 15%, 5, 1, 30%)");
    }

    #[test]
    fn tau_ramp() {
        let s = SparsityConfig { warmup: 2, ramp_steps: 4, ..Default::default() };
        assert_eq!(s.tau_at(0.8, 0), 0.0);
        assert_eq!(s.tau_at(0.8, 1), 0.0);
        assert!((s.tau_at(0.8, 2) - 0.2).abs() < 1e-9);
        assert!((s.tau_at(0.8, 5) - 0.8).abs() < 1e-9);
        assert!((s.tau_at(0.8, 40) - 0.8).abs() < 1e-9);
    }
}

#[cfg(test)]
mod preset_tests {
    use super::*;

    /// The shipped JSON presets must stay parseable and consistent with
    /// the trained model configuration.
    #[test]
    fn shipped_presets_parse() {
        for path in ["configs/flux_table1.json", "configs/hunyuan_video.json",
                     "../configs/flux_table1.json", "../configs/hunyuan_video.json"] {
            let Ok(text) = std::fs::read_to_string(path) else { continue };
            let v = Json::parse(&text).unwrap();
            assert!(v.get("policies").unwrap().as_arr().unwrap().len() >= 5);
            if let Some(m) = v.get("model") {
                let cfg = ModelConfig::from_json(m).unwrap();
                assert_eq!(cfg, ModelConfig::mini());
            }
        }
    }
}
