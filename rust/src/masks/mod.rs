//! Logical block-sparse mask generation (§3.3, "Logical Masks Generation").
//!
//! At every *Update* step FlashOmni builds a **compressed attention map**:
//! every `n·b_q` consecutive Q tokens (and `n·b_k` K tokens) are mean-pooled
//! into a single vector, forming a reduced map
//! `P̃ = softmax(q̃ k̃ᵀ / √d)` with one entry per (Q-group, KV-group). From
//! this map the module derives:
//!
//! * the **Vision-to-Text Contribution** `C_{i,v→t}` and **Text-to-Vision
//!   Guidance** `G_{i,t→v}` metrics of Observation 1,
//! * the Eq. 1 cumulative-threshold selection of cacheable vision blocks
//!   (`M_c`),
//! * a SpargeAttn-style block-skip mask (`M_s`) keeping the top probability
//!   mass per row,
//! * the static window / arrow patterns used by the DiTFastAttnV2 baseline.
//!
//! `true` = compute, `false` = cache/skip, matching [`crate::symbols`].

use crate::tensor::Tensor;

/// A generated pair of logical masks for one head.
#[derive(Clone, Debug)]
pub struct MaskSet {
    /// Per-Q-group caching mask (`M_c`), length `q_groups`.
    pub m_c: Vec<bool>,
    /// Row-major `[q_groups × kv_groups]` skip mask (`M_s`).
    pub m_s: Vec<bool>,
    pub q_groups: usize,
    pub kv_groups: usize,
}

impl MaskSet {
    /// Dense (no sparsity) masks.
    pub fn dense(q_groups: usize, kv_groups: usize) -> Self {
        MaskSet {
            m_c: vec![true; q_groups],
            m_s: vec![true; q_groups * kv_groups],
            q_groups,
            kv_groups,
        }
    }
}

/// The compressed attention map and the group geometry it was built from.
#[derive(Clone, Debug)]
pub struct CompressedMap {
    /// `P̃` row-major `[q_groups × kv_groups]` (post-softmax).
    pub p: Vec<f32>,
    pub q_groups: usize,
    pub kv_groups: usize,
    /// Number of groups covering the text prefix (`n_t` in §3.3).
    pub text_groups: usize,
}

/// Mean-pool rows of `x` (`[n, d]`) in consecutive groups of `group` rows.
pub fn pool_rows(x: &Tensor, group: usize) -> Tensor {
    let (n, d) = (x.rows(), x.cols());
    let groups = n.div_ceil(group);
    let mut out = Tensor::zeros(&[groups, d]);
    for g in 0..groups {
        let lo = g * group;
        let hi = ((g + 1) * group).min(n);
        let dst = out.row_mut(g);
        for r in lo..hi {
            let src = x.row(r);
            for c in 0..d {
                dst[c] += src[c];
            }
        }
        let inv = 1.0 / (hi - lo) as f32;
        for v in dst.iter_mut() {
            *v *= inv;
        }
    }
    out
}

fn softmax_rows(scores: &mut [f32], rows: usize, cols: usize) {
    for r in 0..rows {
        let row = &mut scores[r * cols..(r + 1) * cols];
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = (*v - m).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// Build the compressed attention map from one head's Q and K (`[N, d]`).
/// `group_q`/`group_k` are the pooling sizes `n·b_q` / `n·b_k`;
/// `text_tokens` is the length of the text prefix.
pub fn compressed_map(
    q: &Tensor,
    k: &Tensor,
    group_q: usize,
    group_k: usize,
    text_tokens: usize,
) -> CompressedMap {
    assert_eq!(q.cols(), k.cols(), "Q/K head dims differ");
    let d = q.cols();
    let qp = pool_rows(q, group_q);
    let kp = pool_rows(k, group_k);
    let (qg, kg) = (qp.rows(), kp.rows());
    let scale = 1.0 / (d as f32).sqrt();
    let mut p = vec![0.0f32; qg * kg];
    for i in 0..qg {
        let qi = qp.row(i);
        for j in 0..kg {
            let kj = kp.row(j);
            let mut s = 0.0;
            for c in 0..d {
                s += qi[c] * kj[c];
            }
            p[i * kg + j] = s * scale;
        }
    }
    softmax_rows(&mut p, qg, kg);
    CompressedMap {
        p,
        q_groups: qg,
        kv_groups: kg,
        text_groups: text_tokens.div_ceil(group_q),
    }
}

/// Observation-1 metrics on the compressed map.
///
/// Returns `(C, G)`, each indexed by vision group (0 = first vision group):
/// * `C[i]` — vision-to-text contribution `Σ_j α_{j,i}` over text rows `j`
///   of `P̃[:n_t, n_t:]`,
/// * `G[i]` — text-to-vision guidance `Σ_j β_{j,i}` where `β` is
///   `softmax(P̃[n_t:, :n_t]ᵀ)` row-normalised over the vision axis.
pub fn vision_metrics(map: &CompressedMap) -> (Vec<f32>, Vec<f32>) {
    let nt = map.text_groups;
    let kg = map.kv_groups;
    let qg = map.q_groups;
    let n_vision_cols = kg.saturating_sub(nt);
    let n_vision_rows = qg.saturating_sub(nt);
    // C: sum the vision columns of the text rows.
    let mut c = vec![0.0f32; n_vision_cols];
    for j in 0..nt.min(qg) {
        for i in 0..n_vision_cols {
            c[i] += map.p[j * kg + nt + i];
        }
    }
    // G: take P̃[nt:, :nt]ᵀ → [nt rows × vision cols], softmax rows, sum.
    let mut beta = vec![0.0f32; nt * n_vision_rows];
    for t in 0..nt {
        for v in 0..n_vision_rows {
            beta[t * n_vision_rows + v] = map.p[(nt + v) * kg + t];
        }
    }
    if n_vision_rows > 0 && nt > 0 {
        softmax_rows(&mut beta, nt, n_vision_rows);
    }
    let mut g = vec![0.0f32; n_vision_rows];
    for t in 0..nt {
        for v in 0..n_vision_rows {
            g[v] += beta[t * n_vision_rows + v];
        }
    }
    (c, g)
}

/// Eq. 1 selection: choose vision groups to **cache** whose ascending
/// cumulative `C` and `G` sums both stay within `τ_c` of the respective
/// totals. Returns the caching mask `M_c` over all q-groups (`true` =
/// compute; text groups are never cached, per Observation 1).
pub fn select_cached_blocks(map: &CompressedMap, c: &[f32], g: &[f32], tau_c: f64) -> Vec<bool> {
    let nt = map.text_groups;
    let n_vision = map.q_groups - nt;
    assert_eq!(c.len(), n_vision.min(c.len()));
    let mut m_c = vec![true; map.q_groups];
    if tau_c <= 0.0 || n_vision == 0 {
        return m_c;
    }
    let total_c: f64 = c.iter().map(|&x| x as f64).sum();
    let total_g: f64 = g.iter().map(|&x| x as f64).sum();
    // Sort vision groups ascending by normalized combined score.
    let mut order: Vec<usize> = (0..n_vision).collect();
    let score = |i: usize| -> f64 {
        let cn = if total_c > 0.0 { c[i] as f64 / total_c } else { 0.0 };
        let gn = if total_g > 0.0 { g[i] as f64 / total_g } else { 0.0 };
        cn + gn
    };
    order.sort_by(|&a, &b| score(a).partial_cmp(&score(b)).unwrap());
    let (mut cum_c, mut cum_g) = (0.0f64, 0.0f64);
    for &i in &order {
        let nc = cum_c + c[i] as f64;
        let ng = cum_g + g[i] as f64;
        if nc <= tau_c * total_c && ng <= tau_c * total_g {
            cum_c = nc;
            cum_g = ng;
            m_c[nt + i] = false; // cached
        } else {
            break;
        }
    }
    m_c
}

/// SpargeAttn-style block-skip selection (§3.3 "token selection follows the
/// compressed attention map"): per Q-group row, skip the KV groups with the
/// smallest probabilities whose cumulative mass stays within `τ_kv`; the
/// diagonal group is always kept.
pub fn select_skipped_blocks(map: &CompressedMap, tau_kv: f64) -> Vec<bool> {
    let (qg, kg) = (map.q_groups, map.kv_groups);
    let mut m_s = vec![true; qg * kg];
    if tau_kv <= 0.0 {
        return m_s;
    }
    for i in 0..qg {
        let row = &map.p[i * kg..(i + 1) * kg];
        let mut order: Vec<usize> = (0..kg).collect();
        order.sort_by(|&a, &b| row[a].partial_cmp(&row[b]).unwrap());
        let mut cum = 0.0f64;
        for &j in &order {
            if j == i.min(kg - 1) {
                continue; // keep the diagonal block
            }
            let nc = cum + row[j] as f64;
            if nc <= tau_kv {
                cum = nc;
                m_s[i * kg + j] = false;
            } else {
                break;
            }
        }
    }
    m_s
}

/// Full FlashOmni mask generation for one head at an Update step.
pub fn flashomni_masks(
    q: &Tensor,
    k: &Tensor,
    group_q: usize,
    group_k: usize,
    text_tokens: usize,
    tau_q: f64,
    tau_kv: f64,
) -> MaskSet {
    let map = compressed_map(q, k, group_q, group_k, text_tokens);
    let (c, g) = vision_metrics(&map);
    let m_c = select_cached_blocks(&map, &c, &g, tau_q);
    let m_s = select_skipped_blocks(&map, tau_kv);
    MaskSet { m_c, m_s, q_groups: map.q_groups, kv_groups: map.kv_groups }
}

/// Static sliding-window skip mask (DiTFastAttn-style): compute block pairs
/// with `|i − j| ≤ w`, plus all pairs touching the text prefix.
pub fn window_mask(q_groups: usize, kv_groups: usize, text_groups: usize, w: usize) -> Vec<bool> {
    let mut m = vec![false; q_groups * kv_groups];
    for i in 0..q_groups {
        for j in 0..kv_groups {
            let near = i.abs_diff(j) <= w;
            let text = i < text_groups || j < text_groups;
            m[i * kv_groups + j] = near || text;
        }
    }
    m
}

/// Arrow-attention skip mask (DiTFastAttnV2): sliding window plus full
/// first rows/columns — the "arrow" of global sink tokens.
pub fn arrow_mask(
    q_groups: usize,
    kv_groups: usize,
    text_groups: usize,
    w: usize,
    sink: usize,
) -> Vec<bool> {
    let mut m = window_mask(q_groups, kv_groups, text_groups, w);
    for i in 0..q_groups {
        for j in 0..kv_groups {
            if i < sink + text_groups || j < sink + text_groups {
                m[i * kv_groups + j] = true;
            }
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{prop_check, randn};

    #[test]
    fn pool_rows_means() {
        let x = Tensor::from_vec(&[4, 2], vec![1., 2., 3., 4., 5., 6., 7., 8.]);
        let p = pool_rows(&x, 2);
        assert_eq!(p.shape(), &[2, 2]);
        assert_eq!(p.row(0), &[2.0, 3.0]);
        assert_eq!(p.row(1), &[6.0, 7.0]);
    }

    #[test]
    fn pool_rows_ragged_tail() {
        let x = Tensor::from_vec(&[3, 1], vec![1., 2., 10.]);
        let p = pool_rows(&x, 2);
        assert_eq!(p.shape(), &[2, 1]);
        assert_eq!(p.row(0), &[1.5]);
        assert_eq!(p.row(1), &[10.0]);
    }

    #[test]
    fn compressed_map_rows_are_distributions() {
        prop_check("P̃ rows sum to 1", 20, |rng| {
            let n = 32 + rng.below(64);
            let d = 8 + rng.below(24);
            let q = randn(rng, &[n, d]);
            let k = randn(rng, &[n, d]);
            let map = compressed_map(&q, &k, 8, 8, 8);
            for i in 0..map.q_groups {
                let s: f32 = map.p[i * map.kv_groups..(i + 1) * map.kv_groups].iter().sum();
                assert!((s - 1.0).abs() < 1e-4, "row {i} sums to {s}");
            }
        });
    }

    #[test]
    fn eq1_respects_thresholds_and_text() {
        prop_check("Eq.1 cumsum bound", 20, |rng| {
            let q = randn(rng, &[64, 16]);
            let k = randn(rng, &[64, 16]);
            let map = compressed_map(&q, &k, 8, 8, 8);
            let (c, g) = vision_metrics(&map);
            let tau = 0.5;
            let m_c = select_cached_blocks(&map, &c, &g, tau);
            // Text groups never cached.
            for t in 0..map.text_groups {
                assert!(m_c[t]);
            }
            // Cached mass within threshold.
            let total_c: f64 = c.iter().map(|&x| x as f64).sum();
            let cached_c: f64 = m_c
                .iter()
                .skip(map.text_groups)
                .zip(&c)
                .filter(|(m, _)| !**m)
                .map(|(_, &x)| x as f64)
                .sum();
            assert!(cached_c <= tau * total_c + 1e-9);
        });
    }

    #[test]
    fn tau_zero_is_dense() {
        let mut rng = crate::util::rng::Pcg32::seeded(9);
        let q = randn(&mut rng, &[32, 8]);
        let k = randn(&mut rng, &[32, 8]);
        let m = flashomni_masks(&q, &k, 8, 8, 8, 0.0, 0.0);
        assert!(m.m_c.iter().all(|&b| b));
        assert!(m.m_s.iter().all(|&b| b));
    }

    #[test]
    fn higher_tau_caches_more() {
        let mut rng = crate::util::rng::Pcg32::seeded(10);
        let q = randn(&mut rng, &[128, 16]);
        let k = randn(&mut rng, &[128, 16]);
        let lo = flashomni_masks(&q, &k, 8, 8, 8, 0.1, 0.0);
        let hi = flashomni_masks(&q, &k, 8, 8, 8, 0.8, 0.0);
        let cached = |m: &MaskSet| m.m_c.iter().filter(|&&b| !b).count();
        assert!(cached(&hi) >= cached(&lo));
    }

    #[test]
    fn skip_mask_keeps_diagonal_and_respects_tau() {
        let mut rng = crate::util::rng::Pcg32::seeded(11);
        let q = randn(&mut rng, &[64, 8]);
        let k = randn(&mut rng, &[64, 8]);
        let map = compressed_map(&q, &k, 8, 8, 8);
        let m_s = select_skipped_blocks(&map, 0.3);
        for i in 0..map.q_groups {
            assert!(m_s[i * map.kv_groups + i], "diagonal must be kept");
            let skipped: f64 = (0..map.kv_groups)
                .filter(|&j| !m_s[i * map.kv_groups + j])
                .map(|j| map.p[i * map.kv_groups + j] as f64)
                .sum();
            assert!(skipped <= 0.3 + 1e-9);
        }
    }

    #[test]
    fn window_and_arrow_shapes() {
        let w = window_mask(8, 8, 1, 1);
        // (4,4) on the diagonal: computed; (0,7) text row: computed; (4,7): not.
        assert!(w[4 * 8 + 4]);
        assert!(w[7]);
        assert!(!w[4 * 8 + 7]);
        let a = arrow_mask(8, 8, 1, 1, 1);
        assert!(a[4 * 8 + 1], "arrow keeps sink column");
        assert!(a[1 * 8 + 7], "arrow keeps sink row");
    }

    #[test]
    fn metrics_lengths() {
        let mut rng = crate::util::rng::Pcg32::seeded(12);
        let q = randn(&mut rng, &[80, 8]);
        let k = randn(&mut rng, &[80, 8]);
        let map = compressed_map(&q, &k, 8, 8, 16);
        let (c, g) = vision_metrics(&map);
        assert_eq!(map.text_groups, 2);
        assert_eq!(c.len(), map.kv_groups - 2);
        assert_eq!(g.len(), map.q_groups - 2);
    }
}
