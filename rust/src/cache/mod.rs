//! Feature caching with **TaylorSeer** order-`D` forecasting, and the
//! GEMM-O bias cache.
//!
//! TaylorSeer (Liu et al. 2025b, used by the paper for cached blocks)
//! replaces direct feature reuse with a Taylor-series forecast built from
//! finite differences of the features observed at successive *Update*
//! steps:
//!
//! ```text
//! Ŷ(t₀ + k) = Σ_{d=0..D}  (kᵈ / d!) · Δᵈ Y(t₀)
//! ```
//!
//! where `ΔᵈY` is the d-th order finite difference over the update interval
//! (`Δ⁰Y = Y`, `Δ¹Y = (Y_new − Y_prev)/N`, …). Order `D = 0` degenerates to
//! direct reuse (FORA-style).
//!
//! [`TaylorCache`] maintains the difference stack for any tensor-valued
//! feature; the engine instantiates one per cached quantity (per-layer
//! attention outputs, GEMM-O bias stacks, whole-block deltas).
//!
//! Since the paged-memory refactor the difference stack lives in
//! [`PagePool`] blocks: every entry is interned by content digest, so the
//! stacks of symbol-identical requests across a batch share one physical
//! copy per entry (prefix sharing), and each finite-difference write goes
//! through the pool's copy-on-write path so a shared page is never
//! mutated in place.

use crate::mem::{digest_tensor, tensor_bytes, PagePool, Pooled};
use crate::tensor::Tensor;

/// Finite-difference Taylor forecaster for a tensor-valued feature.
#[derive(Clone, Debug)]
pub struct TaylorCache {
    /// Maximum expansion order `D`.
    pub order: usize,
    /// Difference stack: `stack[d]` = d-th finite difference (per step),
    /// each entry a pool block shared across content-identical caches.
    stack: Vec<Pooled<Tensor>>,
    /// How many stack entries are valid so far (grows with updates).
    filled: usize,
    /// Pool backing the stack entries.
    mem: PagePool,
}

impl TaylorCache {
    /// Cache of order `D`, backed by the process-global [`PagePool`].
    pub fn new(order: usize) -> Self {
        TaylorCache::new_in(order, PagePool::global())
    }

    /// Cache of order `D`, backed by an explicit pool (private budgets
    /// in tests and benches).
    pub fn new_in(order: usize, mem: &PagePool) -> Self {
        TaylorCache { order, stack: Vec::new(), filled: 0, mem: mem.clone() }
    }

    /// The pool backing this cache's stack.
    pub fn pool(&self) -> &PagePool {
        &self.mem
    }

    /// Whether at least one update has been recorded.
    pub fn is_ready(&self) -> bool {
        self.filled > 0
    }

    /// Effective order currently usable (limited by observed history).
    pub fn effective_order(&self) -> usize {
        self.filled.saturating_sub(1).min(self.order)
    }

    /// Record a freshly-computed feature at an Update step. `dt` is the
    /// number of denoising steps since the previous update (the cache
    /// interval `N`), used to normalize the finite differences to
    /// per-step units.
    pub fn update(&mut self, value: &Tensor, dt: f64) {
        let dt = dt.max(1.0) as f32;
        let mut new_stack: Vec<Pooled<Tensor>> = Vec::with_capacity(self.order + 1);
        let (v0, _) = self.mem.intern_digest(
            digest_tensor(b"taylor", value),
            tensor_bytes(value),
            value.clone(),
        );
        new_stack.push(v0);
        // Δᵈ_new = (Δᵈ⁻¹_new − Δᵈ⁻¹_old) / dt, while history exists.
        for d in 1..=self.order {
            if d > self.filled {
                break;
            }
            // Clone the (shared, interned) handle and write the difference
            // through the pool's copy-on-write path …
            let mut diff = new_stack[d - 1].clone();
            {
                let t = diff.make_mut();
                t.sub_assign(&self.stack[d - 1]);
                t.scale(1.0 / dt);
            }
            // … then re-intern the result so content-identical caches
            // (symbol-identical batch slots) share one physical copy.
            let dg = digest_tensor(b"taylor", &diff);
            diff.make_shared(dg);
            new_stack.push(diff);
        }
        self.filled = (self.filled + 1).min(self.order + 1);
        self.stack = new_stack;
    }

    /// Forecast the feature `k` steps after the last update.
    /// `k = 0` returns the stored value exactly.
    pub fn forecast(&self, k: f64) -> Tensor {
        assert!(self.is_ready(), "forecast before any update");
        let mut out = Tensor::clone(&self.stack[0]);
        let mut coeff = 1.0f64;
        for d in 1..self.stack.len() {
            coeff *= k / d as f64;
            let mut term = Tensor::clone(&self.stack[d]);
            term.scale(coeff as f32);
            out.add_assign(&term);
        }
        out
    }

    /// Borrow the difference stack (used by the GEMM-O bias construction,
    /// which projects each difference separately — Eq. 4 linearity).
    pub fn stack(&self) -> &[Pooled<Tensor>] {
        &self.stack[..self.filled.min(self.stack.len())]
    }

    /// Taylor coefficient `kᵈ/d!` for each valid stack entry at offset `k`.
    pub fn coefficients(&self, k: f64) -> Vec<f32> {
        let mut coeffs = Vec::with_capacity(self.stack.len());
        let mut c = 1.0f64;
        coeffs.push(1.0);
        for d in 1..self.stack.len() {
            c *= k / d as f64;
            coeffs.push(c as f32);
        }
        coeffs
    }

    /// Bytes held by the difference stack.
    pub fn bytes(&self) -> usize {
        self.stack.iter().map(|t| t.numel() * 4).sum()
    }

    /// Drop all history (used when a request finishes).
    pub fn reset(&mut self) {
        self.stack.clear();
        self.filled = 0;
    }
}

/// Linear combination of a set of bias tensors with Taylor coefficients —
/// the Dispatch-step `OP_reuse(B_c)` (elementwise, cheap). Generic over
/// plain `Tensor`s and pool-backed [`Pooled<Tensor>`] handles.
pub fn combine_bias_stack<S: std::borrow::Borrow<Tensor>>(stack: &[S], coeffs: &[f32]) -> Tensor {
    assert!(!stack.is_empty());
    let mut out = stack[0].borrow().clone();
    for (d, t) in stack.iter().enumerate().skip(1) {
        if d >= coeffs.len() || coeffs[d] == 0.0 {
            continue;
        }
        let c = coeffs[d];
        for (o, &x) in out.data_mut().iter_mut().zip(t.borrow().data()) {
            *o += c * x;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::assert_close;

    fn scalar(v: f32) -> Tensor {
        Tensor::from_vec(&[1], vec![v])
    }

    #[test]
    fn order0_is_direct_reuse() {
        let mut c = TaylorCache::new(0);
        c.update(&scalar(3.0), 5.0);
        c.update(&scalar(7.0), 5.0);
        assert_eq!(c.forecast(4.0).data()[0], 7.0);
    }

    #[test]
    fn order1_exact_on_linear_signal() {
        // y(t) = 2t + 1 sampled at updates t = 0, 5 (dt = 5).
        let mut c = TaylorCache::new(1);
        c.update(&scalar(1.0), 5.0);
        c.update(&scalar(11.0), 5.0);
        // forecast k steps after t=5: y = 11 + 2k.
        for k in 0..5 {
            let want = 11.0 + 2.0 * k as f32;
            assert!((c.forecast(k as f64).data()[0] - want).abs() < 1e-5);
        }
    }

    #[test]
    fn order2_forward_difference_formula() {
        // y(t) = t² sampled at t = 0, 4, 8 (dt = 4). Backward differences
        // at t=8: Δ¹ = (64−16)/4 = 12, Δ² = (12−4)/4 = 2
        // → ŷ(8+k) = 64 + 12k + k².
        let y = |t: f32| t * t;
        let mut c = TaylorCache::new(2);
        c.update(&scalar(y(0.0)), 4.0);
        c.update(&scalar(y(4.0)), 4.0);
        c.update(&scalar(y(8.0)), 4.0);
        for k in [0.0f32, 1.0, 3.0] {
            let want = 64.0 + 12.0 * k + k * k;
            let got = c.forecast(k as f64).data()[0];
            assert!((got - want).abs() < 1e-4, "k={k}: {got} vs {want}");
        }
    }

    #[test]
    fn effective_order_grows_with_history() {
        let mut c = TaylorCache::new(2);
        assert!(!c.is_ready());
        c.update(&scalar(1.0), 1.0);
        assert_eq!(c.effective_order(), 0);
        c.update(&scalar(2.0), 1.0);
        assert_eq!(c.effective_order(), 1);
        c.update(&scalar(3.0), 1.0);
        assert_eq!(c.effective_order(), 2);
        c.update(&scalar(4.0), 1.0);
        assert_eq!(c.effective_order(), 2);
    }

    #[test]
    fn forecast_at_zero_returns_stored() {
        let mut c = TaylorCache::new(2);
        let v = Tensor::from_vec(&[3], vec![1.0, -2.0, 0.5]);
        c.update(&v, 3.0);
        assert_close(&c.forecast(0.0), &v, 0.0, 0.0);
    }

    #[test]
    fn combine_matches_forecast() {
        let mut c = TaylorCache::new(2);
        c.update(&scalar(2.0), 2.0);
        c.update(&scalar(6.0), 2.0);
        c.update(&scalar(14.0), 2.0);
        let k = 1.7;
        let coeffs = c.coefficients(k);
        let combined = combine_bias_stack(c.stack(), &coeffs);
        assert_close(&combined, &c.forecast(k), 1e-6, 1e-6);
    }

    #[test]
    fn reset_clears() {
        let mut c = TaylorCache::new(1);
        c.update(&scalar(5.0), 1.0);
        c.reset();
        assert!(!c.is_ready());
    }

    #[test]
    fn bytes_accounting() {
        let mut c = TaylorCache::new(1);
        c.update(&Tensor::zeros(&[10, 10]), 1.0);
        assert_eq!(c.bytes(), 400);
        c.update(&Tensor::zeros(&[10, 10]), 1.0);
        assert_eq!(c.bytes(), 800);
    }
}
