//! Serving coordinator: request queue, FIFO batcher, worker pool, and
//! latency/throughput accounting.
//!
//! tokio is unavailable in this offline image (DESIGN.md), so the
//! coordinator is built on `std::thread` + `Mutex<VecDeque>/Condvar`. The
//! design mirrors a vLLM-style router at small scale: requests enter a
//! queue, and each worker **feeds a continuous-batching
//! [`BatchScheduler`](crate::batch::BatchScheduler)** instead of running
//! one request per engine step. A worker claims a FIFO prefix of the
//! queue via `claim_batch` (the ragged engine batches mixed step counts
//! and mixed resolutions, so no step-count bucketing is needed), advances
//! its batch one lockstep step at a time, tops the batch up with
//! front-of-queue late arrivals between steps (admitted at refresh
//! boundaries, under the scheduler's token budget), and emits per-request
//! latency breakdowns as requests retire. Batched execution is
//! bitwise-identical per request to a solo engine run, so serving results
//! do not depend on batch composition or worker count.
//!
//! All workers share one [`SharedPlanCache`]: a sparse plan compiled for
//! any request is reused by every symbol-identical refresh — in the same
//! batch (one compile per (layer, refresh) per batch), in later requests,
//! and across workers.
//!
//! Idle workers **block** on the queue condvar; [`Coordinator::close`]
//! flips the closed flag under the queue lock and `notify_all`s, so they
//! exit promptly instead of spinning on wait timeouts. Closing drains: a
//! worker only exits once the queue is empty and its batch has retired,
//! so every submitted request still gets served.
//!
//! Workers are **panic-isolated**: each engine step runs under
//! `catch_unwind`, so a request that trips an engine assertion (bad vocab
//! id, NaN latent, …) fails *that worker's current batch* instead of the
//! process. The worker reports every in-flight/pending request it owned
//! as [`Rejected::WorkerPanicked`](crate::router::Rejected) on the result
//! channel, rebuilds its engine from the factory, and keeps serving.
//! Queue locks go through the poison-recovering helpers in
//! [`crate::util::sync`], so even a panic elsewhere never cascades into
//! `close()`/`Drop` re-panicking — shutdown always drains.
//!
//! Worker engines default to the process-wide
//! [`ExecPool`](crate::exec::ExecPool), so N workers × H attention heads
//! share one fixed thread set instead of oversubscribing N×H scoped
//! threads (pass a custom pool via `DiTEngine::set_exec_pool` in the
//! factory to change that).

use crate::batch::{BatchScheduler, BatchedEngine};
use crate::engine::{DiTEngine, LayerPlans, RunStats};
use crate::plan::cache::SharedPlanCache;
use crate::report::percentiles;
use crate::router::Rejected;
use crate::tensor::Tensor;
use crate::util::sync::{lock_recover, wait_recover};
use crate::workload::Request;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// Capacity of the coordinator-wide shared plan cache (larger than the
/// per-engine default: it serves every worker's refreshes at once).
const COORD_PLAN_CACHE_CAP: usize = 256;

/// A finished request.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub scene: usize,
    pub image: Tensor,
    pub stats: RunStats,
    /// Seconds spent waiting in the queue (enqueue → batch admission).
    pub queue_s: f64,
    /// Seconds of batched engine execution (admission → completion).
    pub exec_s: f64,
    /// End-to-end seconds (queue + exec).
    pub latency_s: f64,
    /// Worker that served it and the peak batch occupancy it rode in.
    pub worker: usize,
    pub batch_size: usize,
}

struct Job {
    req: Request,
    enqueued: Instant,
}

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    cv: Condvar,
    closed: AtomicBool,
}

/// Claim a FIFO prefix of the queue: up to `max_batch` front jobs,
/// regardless of step count or resolution (the ragged engine batches
/// mixed shapes; the scheduler's token budget meters actual admission).
/// Returns an empty batch only when the queue is empty.
fn claim_batch(q: &mut VecDeque<Job>, max_batch: usize) -> Vec<Job> {
    claim_upto(q, max_batch)
}

/// Top-up claim for a running batch: take up to `room` front-of-queue
/// jobs in FIFO order. The worker computes `room` from the scheduler's
/// remaining slot capacity so a worker never hoards jobs it cannot run.
fn claim_upto(q: &mut VecDeque<Job>, room: usize) -> Vec<Job> {
    let take = room.min(q.len());
    q.drain(..take).collect()
}

/// Per-request serving outcome: the response, or why it never produced
/// one (today only [`Rejected::WorkerPanicked`]; the router adds shed and
/// deadline rejections on top of the same type).
pub type RequestResult = Result<Response, Rejected>;

/// Extract a human-readable message from a `catch_unwind` payload.
pub(crate) fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "worker panicked (non-string payload)".to_string()
    }
}

/// Worker-pool coordinator.
pub struct Coordinator {
    shared: Arc<Shared>,
    out_rx: std::sync::mpsc::Receiver<(u64, RequestResult)>,
    handles: Vec<JoinHandle<()>>,
}

impl Coordinator {
    /// Start `workers` threads, each driving a [`BatchScheduler`] over a
    /// batched engine built from `factory`'s single-request engine.
    /// `max_batch` bounds how many requests a worker's batch holds at once
    /// (requests in one batch advance in lockstep and share plan compiles
    /// per (layer, refresh)); all workers share one plan cache.
    pub fn start<F>(factory: F, workers: usize, max_batch: usize) -> Self
    where
        F: Fn(usize) -> DiTEngine + Send + Sync + 'static,
    {
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            closed: AtomicBool::new(false),
        });
        let (out_tx, out_rx) = std::sync::mpsc::channel::<(u64, RequestResult)>();
        let factory = Arc::new(factory);
        let plan_cache: SharedPlanCache<LayerPlans> =
            SharedPlanCache::new(COORD_PLAN_CACHE_CAP);
        let mut handles = Vec::new();
        for wid in 0..workers.max(1) {
            let shared = Arc::clone(&shared);
            let out_tx = out_tx.clone();
            let factory = Arc::clone(&factory);
            let plan_cache = plan_cache.clone();
            handles.push(std::thread::spawn(move || {
                let make_sched = || {
                    let mut engine = BatchedEngine::from_engine(factory(wid), max_batch);
                    engine.set_plan_cache(plan_cache.clone());
                    BatchScheduler::new(engine)
                };
                let mut sched = make_sched();
                // Request ids this worker has claimed but not yet answered
                // — the set that gets a `WorkerPanicked` rejection if an
                // engine step unwinds.
                let mut owned: Vec<u64> = Vec::new();
                loop {
                    // Acquire work. With an idle scheduler, block for the
                    // first job (a plain condvar wait — `close()` notifies
                    // all waiters under the queue lock, so there is no
                    // lost-wakeup window) and claim a fresh FIFO prefix.
                    // With a running batch, top up without blocking:
                    // front-of-queue jobs up to the scheduler's remaining
                    // slot capacity (admission itself is still metered by
                    // the scheduler's refresh-boundary + token-budget
                    // checks).
                    let jobs: Vec<Job> = {
                        let mut q = lock_recover(&shared.queue);
                        while q.is_empty() && sched.is_idle() {
                            if shared.closed.load(Ordering::SeqCst) {
                                return;
                            }
                            q = wait_recover(&shared.cv, q);
                        }
                        if sched.is_idle() {
                            claim_batch(&mut q, max_batch)
                        } else {
                            let room = max_batch
                                .saturating_sub(sched.active() + sched.pending_len());
                            claim_upto(&mut q, room)
                        }
                    };
                    // Submit + one lockstep step, panic-isolated: an
                    // engine assertion fails this batch, not the process.
                    let stepped = catch_unwind(AssertUnwindSafe(|| {
                        for job in jobs {
                            owned.push(job.req.id);
                            sched.submit_at(job.req, job.enqueued);
                        }
                        sched.step()
                    }));
                    match stepped {
                        Ok(results) => {
                            for r in results {
                                owned.retain(|&id| id != r.id);
                                let _ = out_tx.send((
                                    r.id,
                                    Ok(Response {
                                        id: r.id,
                                        scene: r.scene,
                                        image: r.image,
                                        stats: r.stats,
                                        queue_s: r.queue_s,
                                        exec_s: r.exec_s,
                                        latency_s: r.latency_s,
                                        worker: wid,
                                        batch_size: r.batch_size,
                                    }),
                                ));
                            }
                        }
                        Err(payload) => {
                            // Scheduler/engine state is suspect after an
                            // unwind: answer every owned request with the
                            // panic, then rebuild from the factory and
                            // keep serving.
                            let message = panic_message(payload.as_ref());
                            for id in owned.drain(..) {
                                let _ = out_tx.send((
                                    id,
                                    Err(Rejected::WorkerPanicked {
                                        worker: wid,
                                        message: message.clone(),
                                    }),
                                ));
                            }
                            sched = make_sched();
                        }
                    }
                }
            }));
        }
        Coordinator { shared, out_rx, handles }
    }

    /// Enqueue a request.
    pub fn submit(&self, req: Request) {
        crate::obs::metrics::REQUESTS_ENQUEUED.inc();
        let mut q = lock_recover(&self.shared.queue);
        q.push_back(Job { req, enqueued: Instant::now() });
        self.shared.cv.notify_one();
    }

    /// Blockingly collect `n` per-request outcomes: `(id, Ok(response))`
    /// for served requests, `(id, Err(rejection))` for requests lost to a
    /// worker panic. Never panics on a failed request — callers that need
    /// the all-success invariant use [`Self::collect`].
    pub fn collect_results(&self, n: usize) -> Vec<(u64, RequestResult)> {
        (0..n).map(|_| self.out_rx.recv().expect("all workers exited")).collect()
    }

    /// Blockingly collect `n` responses, panicking with the rejection
    /// detail if any request failed (the strict variant of
    /// [`Self::collect_results`] for callers that expect every request to
    /// succeed, e.g. trace replay).
    pub fn collect(&self, n: usize) -> Vec<Response> {
        self.collect_results(n)
            .into_iter()
            .map(|(id, r)| match r {
                Ok(resp) => resp,
                Err(rej) => panic!("request {id} failed: {rej}"),
            })
            .collect()
    }

    /// Signal that no more work will be submitted and wake every idle
    /// worker. Queued requests are still drained: a worker only exits when
    /// it finds the queue empty. Setting the flag under the queue lock
    /// pairs with the workers' check-then-wait, so no worker can slip
    /// between its empty-queue check and the condvar wait and sleep
    /// through the close notification.
    pub fn close(&self) {
        {
            let _q = lock_recover(&self.shared.queue);
            self.shared.closed.store(true, Ordering::SeqCst);
        }
        self.shared.cv.notify_all();
    }

    /// Close and join workers (drains already-queued requests first).
    pub fn shutdown(self) {
        // Drop does the work; the method exists for call-site clarity.
        drop(self);
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.close();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Aggregate serving statistics. End-to-end latency percentiles are
/// split into their queue-wait and execution components (each with its
/// own p50/p95/p99 over the per-request breakdowns in [`Response`]), so
/// "slow because overloaded" (queue grows) and "slow because steps are
/// expensive" (exec grows) are distinguishable at a glance.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub requests: usize,
    pub wall_s: f64,
    pub throughput_rps: f64,
    pub p50_latency_s: f64,
    pub p95_latency_s: f64,
    pub p99_latency_s: f64,
    pub p50_queue_s: f64,
    pub p95_queue_s: f64,
    pub p99_queue_s: f64,
    pub p50_exec_s: f64,
    pub p95_exec_s: f64,
    pub p99_exec_s: f64,
    pub mean_exec_s: f64,
    pub mean_queue_s: f64,
    pub mean_batch: f64,
    pub mean_attn_sparsity: f64,
}

impl ServeReport {
    /// Aggregate per-request breakdowns into the serving report. All
    /// percentile columns go through the shared NaN-safe nearest-rank
    /// helper [`crate::report::percentiles`] (the old local copy
    /// truncated the rank — biasing every tail percentile low, e.g.
    /// p95 of 10 samples reported the 9th instead of the 10th — and
    /// panicked on NaN latencies).
    pub fn from_responses(rs: &[Response], wall_s: f64) -> Self {
        let lat = percentiles(rs.iter().map(|r| r.latency_s).collect());
        let que = percentiles(rs.iter().map(|r| r.queue_s).collect());
        let exe = percentiles(rs.iter().map(|r| r.exec_s).collect());
        ServeReport {
            requests: rs.len(),
            wall_s,
            throughput_rps: rs.len() as f64 / wall_s.max(1e-9),
            p50_latency_s: lat(0.5),
            p95_latency_s: lat(0.95),
            p99_latency_s: lat(0.99),
            p50_queue_s: que(0.5),
            p95_queue_s: que(0.95),
            p99_queue_s: que(0.99),
            p50_exec_s: exe(0.5),
            p95_exec_s: exe(0.95),
            p99_exec_s: exe(0.99),
            mean_exec_s: rs.iter().map(|r| r.exec_s).sum::<f64>() / rs.len() as f64,
            mean_queue_s: rs.iter().map(|r| r.queue_s).sum::<f64>() / rs.len() as f64,
            mean_batch: rs.iter().map(|r| r.batch_size as f64).sum::<f64>() / rs.len() as f64,
            mean_attn_sparsity: rs.iter().map(|r| r.stats.attn_sparsity()).sum::<f64>()
                / rs.len() as f64,
        }
    }

    pub fn print(&self, label: &str) {
        println!(
            "{label:<32} req={:<4} wall={:>7.2}s thpt={:>6.3}/s p50={:>7.3}s p95={:>7.3}s p99={:>7.3}s exec={:>7.3}s queue={:>6.3}s batch={:>4.1} sparsity={:>5.1}%",
            self.requests,
            self.wall_s,
            self.throughput_rps,
            self.p50_latency_s,
            self.p95_latency_s,
            self.p99_latency_s,
            self.mean_exec_s,
            self.mean_queue_s,
            self.mean_batch,
            self.mean_attn_sparsity * 100.0
        );
        println!(
            "{:<32} queue p50={:>7.3}s p95={:>7.3}s p99={:>7.3}s | exec p50={:>7.3}s p95={:>7.3}s p99={:>7.3}s",
            "",
            self.p50_queue_s,
            self.p95_queue_s,
            self.p99_queue_s,
            self.p50_exec_s,
            self.p95_exec_s,
            self.p99_exec_s
        );
    }
}

/// Replay a trace honoring arrival times; returns responses + report.
pub fn replay_trace<F>(
    factory: F,
    trace: &[Request],
    workers: usize,
    max_batch: usize,
    time_scale: f64,
) -> (Vec<Response>, ServeReport)
where
    F: Fn(usize) -> DiTEngine + Send + Sync + 'static,
{
    let coord = Coordinator::start(factory, workers, max_batch);
    let t0 = Instant::now();
    for req in trace {
        let target = req.arrival_s * time_scale;
        let now = t0.elapsed().as_secs_f64();
        if target > now {
            std::thread::sleep(std::time::Duration::from_secs_f64(target - now));
        }
        coord.submit(req.clone());
    }
    let responses = coord.collect(trace.len());
    let wall = t0.elapsed().as_secs_f64();
    coord.shutdown();
    let report = ServeReport::from_responses(&responses, wall);
    (responses, report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::engine::Policy;
    use crate::model::{weights::Weights, MiniMMDiT};
    use crate::workload::poisson_trace;

    fn tiny_engine(_wid: usize) -> DiTEngine {
        let cfg = ModelConfig {
            dim: 32,
            heads: 2,
            layers: 1,
            text_tokens: 8,
            patch_h: 4,
            patch_w: 4,
            patch_size: 2,
            channels: 3,
            mlp_ratio: 2,
            vocab: 256,
        };
        DiTEngine::new(MiniMMDiT::new(cfg.clone(), Weights::random(&cfg, 1)), Policy::full(), 8, 8)
    }

    #[test]
    fn serves_all_requests() {
        let trace = poisson_trace(1, 6, 1000.0, 3, 8);
        let (responses, report) = replay_trace(tiny_engine, &trace, 1, 2, 0.0);
        assert_eq!(responses.len(), 6);
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..6).collect::<Vec<u64>>());
        assert!(report.throughput_rps > 0.0);
        assert!(report.p95_latency_s >= report.p50_latency_s);
        assert!(report.p99_latency_s >= report.p95_latency_s);
        assert!(report.p95_queue_s >= report.p50_queue_s);
        assert!(report.p99_queue_s >= report.p95_queue_s);
        assert!(report.p95_exec_s >= report.p50_exec_s);
        assert!(report.p99_exec_s >= report.p95_exec_s);
        for r in &responses {
            assert!((r.queue_s + r.exec_s - r.latency_s).abs() < 1e-6);
        }
        for r in &responses {
            assert!(r.image.data().iter().all(|x| x.is_finite()));
            assert!(r.batch_size >= 1 && r.batch_size <= 2);
        }
    }

    #[test]
    fn deterministic_output_per_seed() {
        let trace = poisson_trace(2, 2, 1000.0, 3, 8);
        let (r1, _) = replay_trace(tiny_engine, &trace, 1, 1, 0.0);
        let (r2, _) = replay_trace(tiny_engine, &trace, 1, 1, 0.0);
        // A missing id is a test failure with a message, not a bare
        // `unwrap` panic deep in a closure.
        let find = |rs: &[Response], id: u64| -> Tensor {
            rs.iter()
                .find(|r| r.id == id)
                .unwrap_or_else(|| panic!("response for request {id} missing"))
                .image
                .clone()
        };
        assert_eq!(find(&r1, 0), find(&r2, 0));
        assert_eq!(find(&r1, 1), find(&r2, 1));
    }

    #[test]
    fn collect_results_pairs_every_id_with_an_outcome() {
        let coord = Coordinator::start(tiny_engine, 1, 2);
        let trace = poisson_trace(5, 4, 1000.0, 3, 8);
        for req in &trace {
            coord.submit(req.clone());
        }
        let results = coord.collect_results(4);
        let mut ids: Vec<u64> = results.iter().map(|(id, _)| *id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..4).collect::<Vec<u64>>());
        for (id, r) in &results {
            let resp = r.as_ref().unwrap_or_else(|e| panic!("request {id} failed: {e}"));
            assert_eq!(resp.id, *id);
        }
        coord.shutdown();
    }

    /// Regression pin for the percentile bias bug: the old local helper
    /// computed `((n-1)*p) as usize` (rank truncation), so p95 of 10
    /// samples returned the 9th-smallest. ServeReport now routes through
    /// the shared nearest-rank helper.
    #[test]
    fn serve_report_percentiles_are_nearest_rank() {
        let rs: Vec<Response> = (1..=10)
            .map(|i| Response {
                id: i as u64,
                scene: 0,
                image: Tensor::zeros(&[1]),
                stats: RunStats::default(),
                queue_s: i as f64,
                exec_s: 10.0 * i as f64,
                latency_s: 11.0 * i as f64,
                worker: 0,
                batch_size: 1,
            })
            .collect();
        let report = ServeReport::from_responses(&rs, 1.0);
        assert_eq!(report.p50_queue_s, 5.0);
        assert_eq!(report.p95_queue_s, 10.0); // old helper said 9.0
        assert_eq!(report.p99_queue_s, 10.0);
        assert_eq!(report.p95_exec_s, 100.0);
        assert_eq!(report.p95_latency_s, 110.0);
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let coord = Coordinator::start(tiny_engine, 1, 1);
        coord.shutdown();
    }

    #[test]
    fn close_wakes_idle_workers_promptly() {
        // Workers are blocked on the condvar (no jobs); close() must get
        // them out well under the old 50 ms polling period.
        let coord = Coordinator::start(tiny_engine, 4, 2);
        std::thread::sleep(std::time::Duration::from_millis(20));
        let t0 = Instant::now();
        coord.shutdown();
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(2),
            "close + join took {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn close_drains_queued_requests() {
        let coord = Coordinator::start(tiny_engine, 1, 2);
        let trace = poisson_trace(3, 5, 1000.0, 3, 8);
        for req in &trace {
            coord.submit(req.clone());
        }
        // Close immediately: every already-queued request must still be
        // served before the worker exits.
        coord.close();
        let responses = coord.collect(5);
        assert_eq!(responses.len(), 5);
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..5).collect::<Vec<u64>>());
        coord.shutdown();
    }

    fn job_with_steps(id: u64, steps: usize) -> Job {
        let mut req = poisson_trace(9, 1, 1000.0, 3, 8).remove(0);
        req.id = id;
        req.steps = steps;
        Job { req, enqueued: Instant::now() }
    }

    #[test]
    fn claim_batch_takes_fifo_prefix_across_step_counts() {
        let mut q: VecDeque<Job> = VecDeque::new();
        for (id, steps) in [(0u64, 4usize), (1, 4), (2, 6), (3, 4)] {
            q.push_back(job_with_steps(id, steps));
        }
        // Mixed step counts ride one batch: the ragged engine does not
        // need homogeneous cohorts, so a step-count change no longer
        // splits the claim.
        let b1 = claim_batch(&mut q, 8);
        assert_eq!(b1.iter().map(|j| j.req.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert!(claim_batch(&mut q, 8).is_empty());
    }

    #[test]
    fn claim_batch_respects_max_batch() {
        let mut q: VecDeque<Job> = VecDeque::new();
        for id in 0..5u64 {
            q.push_back(job_with_steps(id, 4));
        }
        let b = claim_batch(&mut q, 2);
        assert_eq!(b.len(), 2);
        assert_eq!(q.len(), 3);
    }

    #[test]
    fn claim_upto_respects_room_and_fifo_order() {
        let mut q: VecDeque<Job> = VecDeque::new();
        for (id, steps) in [(0u64, 4usize), (1, 4), (2, 6), (3, 4)] {
            q.push_back(job_with_steps(id, steps));
        }
        // No room → nothing claimed, queue untouched.
        assert!(claim_upto(&mut q, 0).is_empty());
        assert_eq!(q.len(), 4);
        // Takes exactly `room` front jobs in order, mixed step counts
        // included (the ragged engine batches them).
        let got = claim_upto(&mut q, 3);
        assert_eq!(got.iter().map(|j| j.req.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        // Remaining tail is claimed next, even when room exceeds it.
        let got = claim_upto(&mut q, 5);
        assert_eq!(got.iter().map(|j| j.req.id).collect::<Vec<_>>(), vec![3]);
        assert!(q.is_empty());
    }
}
