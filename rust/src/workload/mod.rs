//! Workload generation: procedural prompts (matching `python/compile/
//! dataset.py`), request traces with Poisson arrivals, and the video /
//! editing task variants.

use crate::util::rng::Pcg32;

/// Number of distinct procedural scenes (must match dataset.py).
pub const N_SHAPES: usize = 4;
pub const N_COLORS: usize = 6;
pub const N_POS: usize = 3;
pub const N_SIZE: usize = 3;
pub const N_BG: usize = 4;

pub fn num_scenes() -> usize {
    N_SHAPES * N_COLORS * N_POS * N_POS * N_SIZE * N_BG
}

/// Caption token ids for a scene — identical formula to dataset.py
/// (semantic fields + LCG filler words).
pub fn caption_ids(scene_id: usize, text_tokens: usize) -> Vec<usize> {
    let mut s = scene_id % num_scenes();
    let shape = s % N_SHAPES;
    s /= N_SHAPES;
    let color = s % N_COLORS;
    s /= N_COLORS;
    let px = s % N_POS;
    s /= N_POS;
    let py = s % N_POS;
    s /= N_POS;
    let size = s % N_SIZE;
    s /= N_SIZE;
    let bg = s % N_BG;
    let mut ids = vec![
        10 + shape,
        20 + color,
        30 + px,
        40 + py,
        50 + size,
        60 + bg,
    ];
    let mut h = scene_id as u64;
    while ids.len() < text_tokens {
        h = (h.wrapping_mul(1103515245).wrapping_add(12345)) & 0x7FFF_FFFF;
        ids.push(100 + (h % 100) as usize);
    }
    ids.truncate(text_tokens);
    ids
}

/// Prompt variant for "video frame f": same scene, one token replaced by a
/// frame marker so frames share content but differ slightly (the video-task
/// substitute described in DESIGN.md).
pub fn video_frame_ids(scene_id: usize, frame: usize, text_tokens: usize) -> Vec<usize> {
    let mut ids = caption_ids(scene_id, text_tokens);
    let last = ids.len() - 1;
    ids[last] = 200 + frame % 50;
    ids
}

/// One generation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub scene: usize,
    pub prompt_ids: Vec<usize>,
    pub seed: u64,
    pub steps: usize,
    /// Arrival offset from trace start, seconds.
    pub arrival_s: f64,
    /// Vision latent grid override `(patch_h, patch_w)` — `None` keeps the
    /// model's native resolution. A request with an override runs with a
    /// per-request `ModelConfig`/`Geometry` (same weights, different
    /// sequence length) and can share a ragged batch with requests of any
    /// other resolution.
    pub patch_hw: Option<(usize, usize)>,
}

impl Request {
    /// Joint sequence length this request will run at under `base`:
    /// `text_tokens + patch_h·patch_w`, with the resolution override
    /// applied. This is the scheduler's token-budget cost.
    pub fn token_cost(&self, base: &crate::config::ModelConfig) -> usize {
        let (ph, pw) = self.patch_hw.unwrap_or((base.patch_h, base.patch_w));
        base.text_tokens + ph * pw
    }
}

/// A synthetic serving trace with Poisson arrivals.
pub fn poisson_trace(
    seed: u64,
    n_requests: usize,
    rate_per_s: f64,
    steps: usize,
    text_tokens: usize,
) -> Vec<Request> {
    let mut rng = Pcg32::seeded(seed);
    let mut t = 0.0;
    (0..n_requests)
        .map(|i| {
            t += rng.exp(rate_per_s);
            let scene = rng.below(num_scenes());
            Request {
                id: i as u64,
                scene,
                prompt_ids: caption_ids(scene, text_tokens),
                seed: rng.next_u64(),
                steps,
                arrival_s: t,
                patch_hw: None,
            }
        })
        .collect()
}

/// A fixed evaluation prompt set (deterministic scene ids spread over the
/// scene space) used by the quality tables so every method sees identical
/// workloads.
pub fn eval_scenes(n: usize) -> Vec<usize> {
    let total = num_scenes();
    (0..n).map(|i| (i * 997 + 13) % total).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn captions_deterministic_and_in_vocab() {
        let a = caption_ids(123, 16);
        let b = caption_ids(123, 16);
        assert_eq!(a, b);
        assert_eq!(a.len(), 16);
        assert!(a.iter().all(|&id| id < 256));
    }

    #[test]
    fn matches_python_dataset_formula() {
        // Golden values computed from dataset.py for scene 123:
        // shape = 123 % 4 = 3; 123/4=30; color = 30 % 6 = 0; 30/6=5;
        // px = 5 % 3 = 2; 5/3=1; py = 1 % 3 = 1; 1/3=0; size = 0; bg = 0.
        let ids = caption_ids(123, 8);
        assert_eq!(&ids[..6], &[13, 20, 32, 41, 50, 60]);
        // First filler: h = (123*1103515245+12345) & 0x7fffffff.
        let h = (123u64 * 1103515245 + 12345) & 0x7FFF_FFFF;
        assert_eq!(ids[6], 100 + (h % 100) as usize);
    }

    #[test]
    fn video_ids_differ_only_in_marker() {
        let a = video_frame_ids(5, 0, 16);
        let b = video_frame_ids(5, 1, 16);
        assert_eq!(a[..15], b[..15]);
        assert_ne!(a[15], b[15]);
    }

    #[test]
    fn poisson_trace_monotone_arrivals() {
        let tr = poisson_trace(1, 20, 5.0, 10, 16);
        assert_eq!(tr.len(), 20);
        for w in tr.windows(2) {
            assert!(w[1].arrival_s >= w[0].arrival_s);
        }
        // Mean inter-arrival ≈ 1/rate.
        let mean = tr.last().unwrap().arrival_s / 20.0;
        assert!(mean > 0.05 && mean < 0.6, "mean={mean}");
    }

    #[test]
    fn eval_scenes_distinct() {
        let s = eval_scenes(8);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 8);
    }
}
