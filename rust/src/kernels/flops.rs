//! Operation counting and the paper's theoretical-speedup formulas.
//!
//! The paper reports **TOPS** (`attn / t` — operations of a *standard*
//! dense attention divided by measured latency) and **Sparsity**
//! (`skip / total` block pairs). These helpers compute the operation
//! counts, the Eq. 5 GEMM-O speedup bound, and the normalized TOPS used in
//! Tables 1–2.

/// FLOPs of one dense attention head: `QKᵀ` + `P·V`, counting one
/// multiply-add as 2 FLOPs → `4 · n_q · n_kv · d`.
pub fn attention_flops(n_q: usize, n_kv: usize, d: usize) -> f64 {
    4.0 * n_q as f64 * n_kv as f64 * d as f64
}

/// FLOPs of a dense GEMM `m×k×n`.
pub fn gemm_flops(m: usize, k: usize, n: usize) -> f64 {
    2.0 * m as f64 * k as f64 * n as f64
}

/// Theoretical attention speedup at block-pair sparsity `s` (linear law —
/// the paper's "near-linear, closely matching the sparsity ratio (1:1)").
pub fn attention_theoretical_speedup(s: f64) -> f64 {
    1.0 / (1.0 - s).max(1e-9)
}

/// Eq. 5: amortized GEMM-O speedup over one Update + `N−1` Dispatch steps
/// at sparsity `s`: `N / (1 + (N−1)(1−s))`.
///
/// The Update step always pays the full projection (both stages touch every
/// tile); each Dispatch step pays only the `(1−s)` computed fraction.
pub fn gemm_o_theoretical_speedup(interval: usize, s: f64) -> f64 {
    let n = interval as f64;
    n / (1.0 + (n - 1.0) * (1.0 - s))
}

/// Per-step (single Dispatch inference) GEMM-O speedup bound — linear.
pub fn gemm_o_single_step_speedup(s: f64) -> f64 {
    1.0 / (1.0 - s).max(1e-9)
}

/// TOPS metric: standard-attention operation count over latency, scaled to
/// tera-ops. On this CPU testbed the absolute value is tiny; Tables 1–2
/// therefore also report it normalized to the dense baseline.
pub fn tops(standard_flops: f64, seconds: f64) -> f64 {
    standard_flops / seconds / 1e12
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq5_paper_example() {
        // §A.1.2: s = 0.9, N = 6 → 6 / (1 + 5·0.1) = 4.
        let x = gemm_o_theoretical_speedup(6, 0.9);
        assert!((x - 4.0).abs() < 1e-12, "{x}");
    }

    #[test]
    fn eq5_limits() {
        // s = 0 → no speedup.
        assert!((gemm_o_theoretical_speedup(6, 0.0) - 1.0).abs() < 1e-12);
        // s = 1 → speedup = N (only the Update step computes).
        assert!((gemm_o_theoretical_speedup(6, 1.0) - 6.0).abs() < 1e-12);
        // Larger N → larger bound at fixed s.
        assert!(
            gemm_o_theoretical_speedup(8, 0.9) > gemm_o_theoretical_speedup(4, 0.9)
        );
    }

    #[test]
    fn attention_linear_law() {
        assert!((attention_theoretical_speedup(0.9) - 10.0).abs() < 1e-6);
        assert!((attention_theoretical_speedup(0.5) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn counts() {
        assert_eq!(attention_flops(10, 20, 4), 4.0 * 10.0 * 20.0 * 4.0);
        assert_eq!(gemm_flops(2, 3, 4), 48.0);
        assert!((tops(2e12, 2.0) - 1.0).abs() < 1e-12);
    }
}
