//! **Kernel autotuner** — grows the static `FO_CHUNK` knob into a measured,
//! per-geometry tuning table (PR 6 tentpole).
//!
//! Every kernel family resolves a [`KernelConfig`] at entry:
//!
//! * **microkernel ISA** — scalar vs. SIMD ([`Isa`]), keyed per
//!   `(family, tile geometry)` and deliberately *not* per thread count, so
//!   the serial, pool-backed and batched variants of one kernel always run
//!   the same float sequences (the bitwise-equivalence invariant of
//!   `rust/tests/` survives tuning).
//! * **tile-loop chunking** — stored as *tasks per thread* rather than a
//!   raw chunk so a tuned value transfers across tile counts:
//!   `chunk = tiles.div_ceil(threads · tasks_per_thread)`. Only the
//!   GEMM-Q tile loop chunks (GEMM-O and attention parallelize over row
//!   blocks / heads), so chunk candidates are measured for
//!   [`Family::GemmQ`] with `threads > 1` and everything else tunes ISA
//!   only.
//!
//! Resolution order at a kernel entry point: an explicit `FO_CHUNK`
//! override always wins the chunk decision; otherwise a tuning-table hit
//! (measured earlier this process, or loaded from **`FO_TUNE_CACHE`**)
//! supplies the config; otherwise, when tuning is enabled (**`FO_TUNE=1`**
//! or [`set_enabled`]), candidates are measured **at first use** on
//! synthetic same-geometry inputs and the winner is cached; otherwise the
//! heuristic config ([`KernelConfig::heuristic`]: the process-wide
//! [`active`] ISA and the seed `tiles/(4·threads)` chunking) applies.
//!
//! Measurements call only the explicit `_isa` kernel variants, which skip
//! config resolution — tuning never recurses. The table is process-wide
//! (`Mutex<HashMap>`); the mutex is released while measuring, so
//! concurrent first uses at worst measure twice and agree on the result
//! shape. `FO_TUNE_CACHE=<path>` loads the table lazily at first use and
//! rewrites the file after each insert, making warmed tables shareable
//! across processes; [`dump`]/[`load`] expose the same text format
//! programmatically.

#![warn(missing_docs)]

use crate::kernels::microkernel::{self, Isa};
use std::collections::HashMap;
use std::sync::atomic::{AtomicI8, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Kernel family a tuned configuration applies to.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Family {
    /// Sparse query projection — tile GEMM `[block_q × d_in] · [d_in × d_h]`,
    /// chunked `(head, block)` tile loop on the pool.
    GemmQ,
    /// Sparse output projection — tile GEMM `[block_q × d_h] · [d_h × d_out]`,
    /// row-block parallel (no chunking).
    GemmO,
    /// FlashOmni attention — `QKᵀ` dot products and `P·V` axpy updates per
    /// `(block_q × block_k)` tile (no chunking).
    Attention,
}

impl Family {
    /// Stable name used in the `FO_TUNE_CACHE` text format.
    pub fn name(self) -> &'static str {
        match self {
            Family::GemmQ => "gemm_q",
            Family::GemmO => "gemm_o",
            Family::Attention => "attention",
        }
    }

    /// Inverse of [`Family::name`].
    pub fn parse(s: &str) -> Option<Family> {
        match s {
            "gemm_q" => Some(Family::GemmQ),
            "gemm_o" => Some(Family::GemmO),
            "attention" => Some(Family::Attention),
            _ => None,
        }
    }
}

/// One resolved kernel configuration: which microkernel flavor to run and
/// how to chunk the pool tile loop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KernelConfig {
    /// Microkernel flavor for the kernel's inner loops.
    pub isa: Isa,
    /// Target pool tasks per worker for chunked tile loops; the effective
    /// chunk is [`KernelConfig::chunk`]. The seed heuristic is 4.
    pub tasks_per_thread: usize,
}

impl KernelConfig {
    /// The untuned fallback: the process-wide [`active`] ISA and the seed
    /// `tiles/(4·threads)` chunking heuristic.
    pub fn heuristic() -> KernelConfig {
        KernelConfig { isa: microkernel::active(), tasks_per_thread: 4 }
    }

    /// Effective tile-loop chunk for `tiles` work items on `threads`
    /// workers. An explicit `FO_CHUNK` override always wins; otherwise
    /// `tiles.div_ceil(threads · tasks_per_thread)`, clamped to ≥ 1.
    pub fn chunk(&self, tiles: usize, threads: usize) -> usize {
        match crate::exec::tile_chunk_override() {
            Some(c) => c,
            None => tiles
                .div_ceil((threads * self.tasks_per_thread).max(1))
                .max(1),
        }
    }
}

type Key = (Family, [usize; 3], usize);

fn table() -> &'static Mutex<HashMap<Key, KernelConfig>> {
    static TABLE: OnceLock<Mutex<HashMap<Key, KernelConfig>>> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut map = HashMap::new();
        if let Some(path) = cache_path() {
            match std::fs::read_to_string(&path) {
                Ok(body) => {
                    parse_cache(&body, &mut map);
                }
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
                Err(e) => eprintln!(
                    "flashomni: warning: FO_TUNE_CACHE {path:?} unreadable ({e}); starting empty"
                ),
            }
        }
        Mutex::new(map)
    })
}

/// The `FO_TUNE_CACHE` path, if set (read once per process). Recorded in
/// `BENCH_*.json` headers so a trajectory row is traceable to its table.
pub fn cache_path() -> Option<String> {
    static PATH: OnceLock<Option<String>> = OnceLock::new();
    PATH.get_or_init(|| std::env::var("FO_TUNE_CACHE").ok().filter(|p| !p.is_empty()))
        .clone()
}

// -1 = follow FO_TUNE, 0 = forced off, 1 = forced on.
static FORCED: AtomicI8 = AtomicI8::new(-1);

/// Whether first-use measurement is active: a [`set_enabled`] override if
/// one was made, else the **`FO_TUNE`** environment variable (`1`/`on`).
/// Table *lookups* happen regardless — a table loaded via
/// `FO_TUNE_CACHE` applies even with tuning off; only new measurements are
/// gated.
pub fn enabled() -> bool {
    match FORCED.load(Ordering::Relaxed) {
        1 => true,
        0 => false,
        _ => {
            static ENV: OnceLock<bool> = OnceLock::new();
            *ENV.get_or_init(|| {
                matches!(std::env::var("FO_TUNE").as_deref(), Ok("1") | Ok("on") | Ok("true"))
            })
        }
    }
}

/// Force tuning on/off for this process, overriding `FO_TUNE`. Meant for
/// bench binaries that interleave tuned and untuned rows; tests should use
/// [`tune_now`] instead (this is process-global state).
pub fn set_enabled(on: bool) {
    FORCED.store(if on { 1 } else { 0 }, Ordering::Relaxed);
}

/// Resolve the configuration for one kernel call.
///
/// `dims` is the family's tile geometry (`[m, k, n]` of the tile GEMM for
/// GEMM-Q/GEMM-O, `[block_q, head_dim, block_k]` for attention) and
/// `threads` the pool size driving the call (1 for serial kernels). The
/// ISA decision is keyed on `(family, dims)` only — every thread count
/// resolves the same flavor — while chunking is keyed per thread count.
pub fn config_for(family: Family, dims: [usize; 3], threads: usize) -> KernelConfig {
    // ISA: threads-normalized key so serial == pool == batched flavors.
    let isa_key: Key = (family, dims, 1);
    let mut cfg = {
        let map = table().lock().unwrap();
        map.get(&isa_key).copied()
    }
    .unwrap_or_else(|| {
        if enabled() {
            let tuned = tune_isa(family, dims);
            insert(isa_key, tuned);
            tuned
        } else {
            KernelConfig::heuristic()
        }
    });

    // Chunking: only the GEMM-Q pool tile loop chunks.
    if family == Family::GemmQ && threads > 1 {
        let key: Key = (family, dims, threads);
        let hit = { table().lock().unwrap().get(&key).copied() };
        cfg = match hit {
            Some(c) => KernelConfig { isa: cfg.isa, ..c },
            None if enabled() => {
                let tuned = tune_chunk(dims, threads, cfg.isa);
                insert(key, tuned);
                tuned
            }
            None => KernelConfig { isa: cfg.isa, ..KernelConfig::heuristic() },
        };
    }
    cfg
}

/// Measure candidates for `(family, dims, threads)` and return the winner
/// **without** touching the process-wide table or the `enabled` gate —
/// the side-effect-free probe used by the autotuner regression test.
pub fn tune_now(family: Family, dims: [usize; 3], threads: usize) -> KernelConfig {
    let isa_cfg = tune_isa(family, dims);
    if family == Family::GemmQ && threads > 1 {
        tune_chunk(dims, threads, isa_cfg.isa)
    } else {
        isa_cfg
    }
}

fn insert(key: Key, cfg: KernelConfig) {
    crate::obs::metrics::TUNE_MEASUREMENTS.inc();
    table().lock().unwrap().insert(key, cfg);
    if let Some(path) = cache_path() {
        if let Err(e) = dump(&path) {
            static WARNED: OnceLock<()> = OnceLock::new();
            WARNED.get_or_init(|| {
                eprintln!("flashomni: warning: cannot write FO_TUNE_CACHE {path:?}: {e}");
            });
        }
    }
}

// ---- measurement ----

/// Min-of-3 wall time (seconds) after one warmup call.
fn time_min(mut f: impl FnMut()) -> f64 {
    f(); // warmup
    let mut best = f64::INFINITY;
    for _ in 0..3 {
        let t = Instant::now();
        f();
        best = best.min(t.elapsed().as_secs_f64());
    }
    best
}

fn synth(len: usize, seed: u64) -> Vec<f32> {
    crate::util::rng::Pcg32::seeded(seed).normal_vec(len)
}

/// Candidate ISAs: scalar always; the vector path only when the process
/// default allows it (respects `FO_SIMD=scalar`).
fn isa_candidates() -> Vec<Isa> {
    if microkernel::active() == Isa::Simd {
        vec![Isa::Scalar, Isa::Simd]
    } else {
        vec![Isa::Scalar]
    }
}

/// Time one tile of `family` at `dims` under `isa`, on synthetic inputs
/// (GEMM-O accumulates in place, so real buffers cannot be re-run — the
/// synthetic same-geometry proxy sidesteps that).
fn measure_tile(family: Family, dims: [usize; 3], isa: Isa) -> f64 {
    let [m, k, n] = [dims[0].max(1), dims[1].max(1), dims[2].max(1)];
    match family {
        Family::GemmQ | Family::GemmO => {
            let a = synth(m * k, 0x7e57);
            let b = synth(k * n, 0x7e58);
            let mut c = vec![0.0f32; m * n];
            time_min(|| {
                c.fill(0.0);
                crate::kernels::gemm::matmul_into_isa(isa, &a, &b, &mut c, m, k, n);
                std::hint::black_box(&c);
            })
        }
        Family::Attention => {
            // QKᵀ (dot form) + P·V (axpy form) for one (block_q × block_k)
            // tile pair with head_dim k.
            let q = synth(m * k, 0x7e59);
            let kv = synth(n * k, 0x7e5a);
            let p = synth(m * n, 0x7e5b);
            let mut s = vec![0.0f32; m * n];
            let mut acc = vec![0.0f32; m * k];
            time_min(|| {
                s.fill(0.0);
                crate::kernels::gemm::matmul_nt_into_isa(isa, &q, &kv, &mut s, m, k, n);
                acc.fill(0.0);
                crate::kernels::gemm::matmul_into_isa(isa, &p, &kv, &mut acc, m, n, k);
                std::hint::black_box((&s, &acc));
            })
        }
    }
}

fn tune_isa(family: Family, dims: [usize; 3]) -> KernelConfig {
    let mut best = (f64::INFINITY, Isa::Scalar);
    for isa in isa_candidates() {
        let t = measure_tile(family, dims, isa);
        if t < best.0 {
            best = (t, isa);
        }
    }
    KernelConfig { isa: best.1, tasks_per_thread: 4 }
}

/// Measure chunk candidates for the GEMM-Q pool tile loop: a synthetic
/// work list of `16 · threads` tiles of the given geometry, dispatched on
/// a dedicated pool of the caller's size with each candidate granularity.
fn tune_chunk(dims: [usize; 3], threads: usize, isa: Isa) -> KernelConfig {
    let [m, k, n] = [dims[0].max(1), dims[1].max(1), dims[2].max(1)];
    let pool = crate::exec::ExecPool::new(threads);
    let tiles = 16 * threads;
    let a = synth(m * k, 0x7e5c);
    let b = synth(k * n, 0x7e5d);
    let (a, b) = (&a, &b);
    let mut best = (f64::INFINITY, 4usize);
    for tpt in [1usize, 2, 4, 8, 16] {
        let chunk = tiles.div_ceil((threads * tpt).max(1)).max(1);
        let n_tasks = tiles.div_ceil(chunk);
        let t = time_min(|| {
            pool.parallel_for(n_tasks, |t| {
                let lo = t * chunk;
                let hi = (lo + chunk).min(tiles);
                for _ in lo..hi {
                    let mut c = vec![0.0f32; m * n];
                    crate::kernels::gemm::matmul_into_isa(isa, a, b, &mut c, m, k, n);
                    std::hint::black_box(&c);
                }
            });
        });
        if t < best.0 {
            best = (t, tpt);
        }
    }
    KernelConfig { isa, tasks_per_thread: best.1 }
}

// ---- persistence (FO_TUNE_CACHE text format) ----

fn isa_tag(isa: Isa) -> &'static str {
    match isa {
        Isa::Scalar => "scalar",
        Isa::Simd => "simd",
    }
}

fn parse_cache(body: &str, map: &mut HashMap<Key, KernelConfig>) -> usize {
    let mut loaded = 0;
    for line in body.lines() {
        let f: Vec<&str> = line.split_whitespace().collect();
        if f.len() != 8 || f[0] != "v1" {
            continue; // ignore comments / foreign versions
        }
        let (Some(family), Ok(d0), Ok(d1), Ok(d2), Ok(threads), Some(isa), Ok(tpt)) = (
            Family::parse(f[1]),
            f[2].parse::<usize>(),
            f[3].parse::<usize>(),
            f[4].parse::<usize>(),
            f[5].parse::<usize>(),
            microkernel::parse_isa(f[6]),
            f[7].parse::<usize>(),
        ) else {
            continue;
        };
        map.insert(
            (family, [d0, d1, d2], threads),
            KernelConfig { isa, tasks_per_thread: tpt.max(1) },
        );
        loaded += 1;
    }
    loaded
}

/// Load tuning-table entries from `path` (the [`dump`] text format) into
/// the process-wide table, returning how many entries were read.
/// Malformed lines are skipped, not errors.
pub fn load(path: &str) -> std::io::Result<usize> {
    let body = std::fs::read_to_string(path)?;
    let mut fresh = HashMap::new();
    let n = parse_cache(&body, &mut fresh);
    table().lock().unwrap().extend(fresh);
    Ok(n)
}

/// Write the process-wide tuning table to `path` as sorted
/// `v1 <family> <m> <k> <n> <threads> <isa> <tasks_per_thread>` lines.
pub fn dump(path: &str) -> std::io::Result<()> {
    let mut lines: Vec<String> = {
        let map = table().lock().unwrap();
        map.iter()
            .map(|(&(family, d, threads), cfg)| {
                format!(
                    "v1 {} {} {} {} {threads} {} {}",
                    family.name(),
                    d[0],
                    d[1],
                    d[2],
                    isa_tag(cfg.isa),
                    cfg.tasks_per_thread
                )
            })
            .collect()
    };
    lines.sort();
    std::fs::write(path, lines.join("\n") + "\n")
}

/// Number of entries currently in the process-wide table (bench reporting).
pub fn table_len() -> usize {
    table().lock().unwrap().len()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heuristic_chunk_matches_seed_formula() {
        let h = KernelConfig::heuristic();
        assert_eq!(h.tasks_per_thread, 4);
        if crate::exec::tile_chunk_override().is_none() {
            // Same numbers the seed `tile_chunk` heuristic produced.
            assert_eq!(h.chunk(256, 8), 8);
            assert_eq!(h.chunk(0, 8), 1);
            assert_eq!(h.chunk(1, 8), 1);
            let fine = KernelConfig { tasks_per_thread: 16, ..h };
            assert_eq!(fine.chunk(256, 8), 2);
        }
    }

    #[test]
    fn config_for_disabled_falls_back_to_heuristic() {
        // Tests never call set_enabled (process-global); with FO_TUNE
        // unset in the test environment this exercises the fallback arm.
        if !enabled() && cache_path().is_none() {
            let cfg = config_for(Family::GemmO, [9999, 7, 3], 1);
            assert_eq!(cfg, KernelConfig::heuristic());
        }
    }

    #[test]
    fn tune_now_is_side_effect_free_and_valid() {
        let before = table_len();
        let cfg = tune_now(Family::GemmQ, [8, 8, 8], 1);
        assert_eq!(table_len(), before, "tune_now must not touch the table");
        assert!(cfg.tasks_per_thread >= 1);
        if crate::kernels::microkernel::active() == Isa::Scalar {
            assert_eq!(cfg.isa, Isa::Scalar, "tuner must respect FO_SIMD=scalar");
        }
    }

    #[test]
    fn cache_roundtrip_and_malformed_lines() {
        let mut map = HashMap::new();
        let body = "v1 gemm_q 64 512 64 1 simd 4\n\
                    v1 attention 64 64 64 2 scalar 8\n\
                    # comment\n\
                    v1 bogus_family 1 2 3 4 simd 4\n\
                    v2 gemm_q 1 2 3 4 simd 4\n\
                    v1 gemm_o not_a_number 2 3 4 simd 4\n";
        assert_eq!(parse_cache(body, &mut map), 2);
        assert_eq!(
            map.get(&(Family::GemmQ, [64, 512, 64], 1)),
            Some(&KernelConfig { isa: Isa::Simd, tasks_per_thread: 4 })
        );
        assert_eq!(
            map.get(&(Family::Attention, [64, 64, 64], 2)),
            Some(&KernelConfig { isa: Isa::Scalar, tasks_per_thread: 8 })
        );

        // dump → load roundtrip through the global table.
        let path = std::env::temp_dir().join("flashomni_tune_cache_test.txt");
        let p = path.to_str().unwrap();
        let probe: Key = (Family::GemmO, [5, 6, 7], 1);
        table()
            .lock()
            .unwrap()
            .insert(probe, KernelConfig { isa: Isa::Scalar, tasks_per_thread: 2 });
        dump(p).unwrap();
        table().lock().unwrap().remove(&probe);
        let n = load(p).unwrap();
        assert!(n >= 1);
        assert_eq!(
            table().lock().unwrap().get(&probe),
            Some(&KernelConfig { isa: Isa::Scalar, tasks_per_thread: 2 })
        );
        table().lock().unwrap().remove(&probe);
        let _ = std::fs::remove_file(p);
    }
}
