//! **FlashOmni GEMM-O** — sparse output projection with the cached bias
//! `B_c` (§3.5, Observation 3, Eq. 3–4).
//!
//! The output projection mixes heads: `Out_i = Σ_h O_i^h W^h`. Splitting
//! the heads into the computed set `H_i` and the cached complement, the
//! cached partial sum `B_c[i] = Σ_{h∉H_i} Õ_i^h W^h` commutes with the
//! element-wise `OP_reuse` (Eq. 4), so it is computed **once at the Update
//! step** and replayed (optionally Taylor-forecast) at every Dispatch step:
//!
//! * [`gemm_o_update`] — two stages: stage 1 projects the tiles that will
//!   be *cached* during the upcoming Dispatch steps and records them in
//!   `B_c`; stage 2 projects the always-computed tiles and adds `B_c`,
//!   producing the exact dense result for the Update step itself.
//! * [`gemm_o_dispatch`] — initializes the output with (the forecast of)
//!   `B_c` and projects only the computed tiles.
//!
//! The primary kernels consume a compiled
//! [`SparsePlan`](crate::plan::SparsePlan): stage 1 walks the cached-block
//! list, stage 2 / dispatch walk the live-block list — no per-tile symbol
//! decode. The seed symbol-decoding variants (`*_symbols`) are retained
//! for the plan-equivalence property tests.
//!
//! The `*_pool` variants run the tile loop on a persistent
//! [`ExecPool`], parallelized over **row blocks** (heads accumulate into
//! the same output rows, so the head loop must stay inside one task to
//! preserve the serial per-element accumulation order — which is exactly
//! what makes the pool outputs bitwise-identical to the serial kernels).
//! The per-row-block head lists come from inverting the plan's CSR live /
//! cached lists once per call (`RowTiles`). The `*_batched` variants
//! stack a whole batch of request activations over **one shared plan**
//! (one `RowTiles` inversion per batch, `batch × row-block` pool lanes)
//! and are bitwise-identical per request to the serial kernels — the
//! serving layer's cross-request plan sharing.
//!
//! This removes the reduction-axis redundancy *and* the need to keep the
//! per-head cached features `Õ^h` in memory (the attention kernel's
//! cache-then-reuse branch can terminate without writing).

use crate::exec::{ExecPool, SendPtr};
use crate::kernels::gemm::matmul_into_isa;
use crate::kernels::microkernel::Isa;
use crate::kernels::tune::{self, Family};
use crate::plan::{GemmStats, SparsePlan};
use crate::symbols::LayerSymbols;
use crate::tensor::Tensor;

/// Resolve the microkernel flavor for a GEMM-O call from the tuning table
/// (falling back to the process default). Keyed on the tile geometry
/// `(block_q, d_h, d_out)` only — every variant (serial, pool, batched,
/// symbols) with the same geometry resolves the same flavor, so their
/// bitwise-equivalence tests survive tuning.
fn resolve_isa(block_q: usize, d_h: usize, d_out: usize) -> Isa {
    tune::config_for(Family::GemmO, [block_q, d_h, d_out], 1).isa
}

/// Contiguous per-head weight panels for `W_out` (`[H·d_h × d_out]`), so
/// each tile GEMM reads a dense panel. Build once per layer, reuse.
#[derive(Clone, Debug)]
pub struct WeightPanels {
    pub panels: Vec<Vec<f32>>, // per head: [d_h × d_out]
    pub d_h: usize,
    pub d_out: usize,
}

impl WeightPanels {
    pub fn new(w: &Tensor, heads: usize) -> Self {
        let d_in = w.rows();
        let d_out = w.cols();
        assert_eq!(d_in % heads, 0);
        let d_h = d_in / heads;
        let panels = (0..heads)
            .map(|h| w.data()[h * d_h * d_out..(h + 1) * d_h * d_out].to_vec())
            .collect();
        WeightPanels { panels, d_h, d_out }
    }
}

/// Accumulate one `(block, head)` tile into a row slab covering rows
/// `lo..hi`: `out_rows += O_tile · W^h`. Shared by the serial and pool
/// kernels so both run the identical float sequence. No lane padding here:
/// the tile GEMM accumulates in place into `out_rows`, whose `d_out`
/// stride is fixed by the caller.
#[allow(clippy::too_many_arguments)]
#[inline]
fn project_tile_rows(
    isa: Isa,
    o_cat: &Tensor,
    panels: &WeightPanels,
    h: usize,
    lo: usize,
    hi: usize,
    heads: usize,
    out_rows: &mut [f32],
) {
    let d_h = panels.d_h;
    let d_out = panels.d_out;
    let d_cat = heads * d_h;
    // Gather the head's slice of O rows into a contiguous tile.
    let bq = hi - lo;
    debug_assert_eq!(out_rows.len(), bq * d_out);
    let mut tile = vec![0.0f32; bq * d_h];
    for r in 0..bq {
        tile[r * d_h..(r + 1) * d_h].copy_from_slice(
            &o_cat.data()[(lo + r) * d_cat + h * d_h..(lo + r) * d_cat + (h + 1) * d_h],
        );
    }
    matmul_into_isa(isa, &tile, &panels.panels[h], out_rows, bq, d_h, d_out);
}

/// Project one `(block, head)` tile: `out[lo..hi] += O_tile · W^h`, where
/// `out` is the full `[N × d_out]` buffer.
#[allow(clippy::too_many_arguments)]
#[inline]
fn project_tile(
    isa: Isa,
    o_cat: &Tensor,
    panels: &WeightPanels,
    h: usize,
    lo: usize,
    hi: usize,
    heads: usize,
    out: &mut [f32],
) {
    let d_out = panels.d_out;
    project_tile_rows(isa, o_cat, panels, h, lo, hi, heads, &mut out[lo * d_out..hi * d_out]);
}

/// Per-row-block head lists, inverted once per call from a plan's CSR
/// live/cached Q-block lists. `live[bi]` / `cached[bi]` hold the heads
/// whose tile at row block `bi` is live / cached, in ascending head order
/// (the plan lists are walked head-major, so ascending order — and with it
/// the serial kernels' per-element accumulation order — is preserved).
struct RowTiles {
    live: Vec<Vec<u32>>,
    cached: Vec<Vec<u32>>,
}

impl RowTiles {
    fn from_plan(plan: &SparsePlan) -> Self {
        let mut live: Vec<Vec<u32>> = vec![Vec::new(); plan.t_q];
        let mut cached: Vec<Vec<u32>> = vec![Vec::new(); plan.t_q];
        for (h, hp) in plan.heads.iter().enumerate() {
            for &bi in &hp.live_q {
                live[bi as usize].push(h as u32);
            }
            for &bi in &hp.cached_q {
                cached[bi as usize].push(h as u32);
            }
        }
        RowTiles { live, cached }
    }
}

/// Run `body(bi, out_rows_ptr)` for every row block on the pool. Each task
/// owns a disjoint slab of output rows, reconstructed from the raw base
/// pointer — sound because row blocks partition `0..n`.
fn for_each_row_block(
    pool: &ExecPool,
    t_q: usize,
    n: usize,
    block_q: usize,
    d_out: usize,
    base: *mut f32,
    body: impl Fn(usize, usize, usize, &mut [f32]) + Sync,
) {
    let ptr = SendPtr(base);
    pool.parallel_for(t_q, |bi| {
        let lo = bi * block_q;
        let hi = (lo + block_q).min(n);
        // SAFETY: row blocks `[lo, hi)` are disjoint across tasks and
        // together cover at most `0..n`; the buffer outlives the parallel
        // section (ExecPool joins every task before returning).
        let rows = unsafe {
            std::slice::from_raw_parts_mut(ptr.0.add(lo * d_out), (hi - lo) * d_out)
        };
        body(bi, lo, hi, rows);
    });
}

/// Dense output projection baseline.
pub fn gemm_o_dense(o_cat: &Tensor, w: &Tensor) -> Tensor {
    crate::kernels::gemm::matmul(o_cat, w)
}

/// Update-step GEMM-O driven by a compiled plan.
///
/// * `o_cat` — `[N × H·d_h]` attention outputs (all heads valid — the
///   Update step ran full attention),
/// * `plan` — the plan that will govern the upcoming Dispatch steps: tile
///   `(i, h)` with `i ∈ plan.heads[h].cached_q` is a *to-be-cached* tile,
/// * returns `(out, bias)` where `out` is the exact projection for this
///   step and `bias` is the refreshed `B_c` (`[N × d_out]`).
///
/// Runs the tuned/default microkernel flavor; [`gemm_o_update_isa`] pins
/// one explicitly.
pub fn gemm_o_update(
    o_cat: &Tensor,
    panels: &WeightPanels,
    plan: &SparsePlan,
) -> (Tensor, Tensor, GemmStats) {
    let isa = resolve_isa(plan.block_q, panels.d_h, panels.d_out);
    gemm_o_update_isa(isa, o_cat, panels, plan)
}

/// [`gemm_o_update`] with an explicit microkernel flavor ([`Isa::Scalar`]
/// reproduces the seed float sequence bit-for-bit).
pub fn gemm_o_update_isa(
    isa: Isa,
    o_cat: &Tensor,
    panels: &WeightPanels,
    plan: &SparsePlan,
) -> (Tensor, Tensor, GemmStats) {
    let block_q = plan.block_q;
    let n = o_cat.rows();
    let heads = plan.heads.len();
    let d_out = panels.d_out;
    assert_eq!(plan.t_q, n.div_ceil(block_q), "plan Q-block geometry mismatch");
    let mut bias = Tensor::zeros(&[n, d_out]);
    let mut out = Tensor::zeros(&[n, d_out]);

    for (h, hp) in plan.heads.iter().enumerate() {
        // Stage 2 tiles: always updated during Dispatch.
        for &bi in &hp.live_q {
            let lo = bi as usize * block_q;
            let hi = (lo + block_q).min(n);
            project_tile(isa, o_cat, panels, h, lo, hi, heads, out.data_mut());
        }
        // Stage 1 tiles: record in the cached bias.
        for &bi in &hp.cached_q {
            let lo = bi as usize * block_q;
            let hi = (lo + block_q).min(n);
            project_tile(isa, o_cat, panels, h, lo, hi, heads, bias.data_mut());
        }
    }
    // The Update step needs the exact dense output: add the bias.
    out.add_assign(&bias);
    (out, bias, plan.gemm_stats())
}

/// [`gemm_o_update`] with both tile loops run on a persistent worker pool,
/// parallelized over row blocks (see the module docs for why the head loop
/// stays inside each task). Bitwise-identical to the serial kernel.
pub fn gemm_o_update_pool(
    o_cat: &Tensor,
    panels: &WeightPanels,
    plan: &SparsePlan,
    pool: &ExecPool,
) -> (Tensor, Tensor, GemmStats) {
    let block_q = plan.block_q;
    let n = o_cat.rows();
    let heads = plan.heads.len();
    let d_out = panels.d_out;
    assert_eq!(plan.t_q, n.div_ceil(block_q), "plan Q-block geometry mismatch");
    let isa = resolve_isa(block_q, panels.d_h, d_out);
    let mut bias = Tensor::zeros(&[n, d_out]);
    let mut out = Tensor::zeros(&[n, d_out]);
    let tiles = RowTiles::from_plan(plan);

    // One fused section: a row-block task projects its live tiles into
    // `out` and its cached tiles into `bias` (disjoint buffers), so the
    // Update path pays a single pool dispatch instead of two barriers.
    {
        let out_ptr = SendPtr(out.data_mut().as_mut_ptr());
        let bias_ptr = SendPtr(bias.data_mut().as_mut_ptr());
        pool.parallel_for(plan.t_q, |bi| {
            let lo = bi * block_q;
            let hi = (lo + block_q).min(n);
            let len = (hi - lo) * d_out;
            // SAFETY: row blocks `[lo, hi)` are disjoint across tasks and
            // the two slabs live in different buffers; both outlive the
            // parallel section (ExecPool joins before returning).
            let out_rows =
                unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(lo * d_out), len) };
            let bias_rows =
                unsafe { std::slice::from_raw_parts_mut(bias_ptr.0.add(lo * d_out), len) };
            for &h in &tiles.live[bi] {
                project_tile_rows(isa, o_cat, panels, h as usize, lo, hi, heads, out_rows);
            }
            for &h in &tiles.cached[bi] {
                project_tile_rows(isa, o_cat, panels, h as usize, lo, hi, heads, bias_rows);
            }
        });
    }
    out.add_assign(&bias);
    (out, bias, plan.gemm_stats())
}

/// Stage 1 only: project the *to-be-cached* tiles of `o_cat` into a bias
/// tensor. Used to build the per-Taylor-order bias stacks (Eq. 4: the
/// projection commutes with the element-wise forecast, so each finite
/// difference of `O` is projected separately at the Update step).
pub fn gemm_o_stage1(o_cat: &Tensor, panels: &WeightPanels, plan: &SparsePlan) -> Tensor {
    let block_q = plan.block_q;
    let n = o_cat.rows();
    let heads = plan.heads.len();
    let d_out = panels.d_out;
    assert_eq!(plan.t_q, n.div_ceil(block_q), "plan Q-block geometry mismatch");
    let isa = resolve_isa(block_q, panels.d_h, d_out);
    let mut bias = Tensor::zeros(&[n, d_out]);
    for (h, hp) in plan.heads.iter().enumerate() {
        for &bi in &hp.cached_q {
            let lo = bi as usize * block_q;
            let hi = (lo + block_q).min(n);
            project_tile(isa, o_cat, panels, h, lo, hi, heads, bias.data_mut());
        }
    }
    bias
}

/// [`gemm_o_stage1`] on a persistent worker pool (row-block parallel);
/// bitwise-identical to the serial kernel.
pub fn gemm_o_stage1_pool(
    o_cat: &Tensor,
    panels: &WeightPanels,
    plan: &SparsePlan,
    pool: &ExecPool,
) -> Tensor {
    let block_q = plan.block_q;
    let n = o_cat.rows();
    let heads = plan.heads.len();
    let d_out = panels.d_out;
    assert_eq!(plan.t_q, n.div_ceil(block_q), "plan Q-block geometry mismatch");
    let isa = resolve_isa(block_q, panels.d_h, d_out);
    let mut bias = Tensor::zeros(&[n, d_out]);
    let tiles = RowTiles::from_plan(plan);
    for_each_row_block(pool, plan.t_q, n, block_q, d_out, bias.data_mut().as_mut_ptr(), |bi, lo, hi, rows| {
        for &h in &tiles.cached[bi] {
            project_tile_rows(isa, o_cat, panels, h as usize, lo, hi, heads, rows);
        }
    });
    bias
}

/// Dispatch-step GEMM-O driven by a compiled plan.
///
/// * `o_cat` — `[N × H·d_h]` attention outputs where **only computed tiles
///   are valid** (cached tiles were never written — that is the point),
/// * `bias` — `OP_reuse(B_c)`: the (possibly Taylor-forecast) cached bias,
/// * returns the projected output plus tile statistics.
///
/// Runs the tuned/default microkernel flavor; [`gemm_o_dispatch_isa`] pins
/// one explicitly.
pub fn gemm_o_dispatch(
    o_cat: &Tensor,
    panels: &WeightPanels,
    plan: &SparsePlan,
    bias: &Tensor,
) -> (Tensor, GemmStats) {
    let isa = resolve_isa(plan.block_q, panels.d_h, panels.d_out);
    gemm_o_dispatch_isa(isa, o_cat, panels, plan, bias)
}

/// [`gemm_o_dispatch`] with an explicit microkernel flavor ([`Isa::Scalar`]
/// reproduces the seed float sequence bit-for-bit).
pub fn gemm_o_dispatch_isa(
    isa: Isa,
    o_cat: &Tensor,
    panels: &WeightPanels,
    plan: &SparsePlan,
    bias: &Tensor,
) -> (Tensor, GemmStats) {
    let block_q = plan.block_q;
    let n = o_cat.rows();
    let heads = plan.heads.len();
    let d_out = panels.d_out;
    assert_eq!(bias.shape(), &[n, d_out]);
    assert_eq!(plan.t_q, n.div_ceil(block_q), "plan Q-block geometry mismatch");
    // "The GEMM-O output space is initialized via OP_reuse" (§3.5).
    let mut out = bias.clone();

    for (h, hp) in plan.heads.iter().enumerate() {
        for &bi in &hp.live_q {
            let lo = bi as usize * block_q;
            let hi = (lo + block_q).min(n);
            project_tile(isa, o_cat, panels, h, lo, hi, heads, out.data_mut());
        }
    }
    (out, plan.gemm_stats())
}

/// [`gemm_o_dispatch`] on a persistent worker pool (row-block parallel);
/// bitwise-identical to the serial kernel.
pub fn gemm_o_dispatch_pool(
    o_cat: &Tensor,
    panels: &WeightPanels,
    plan: &SparsePlan,
    bias: &Tensor,
    pool: &ExecPool,
) -> (Tensor, GemmStats) {
    let block_q = plan.block_q;
    let n = o_cat.rows();
    let heads = plan.heads.len();
    let d_out = panels.d_out;
    assert_eq!(bias.shape(), &[n, d_out]);
    assert_eq!(plan.t_q, n.div_ceil(block_q), "plan Q-block geometry mismatch");
    let isa = resolve_isa(block_q, panels.d_h, d_out);
    let mut out = bias.clone();
    let tiles = RowTiles::from_plan(plan);
    for_each_row_block(pool, plan.t_q, n, block_q, d_out, out.data_mut().as_mut_ptr(), |bi, lo, hi, rows| {
        for &h in &tiles.live[bi] {
            project_tile_rows(isa, o_cat, panels, h as usize, lo, hi, heads, rows);
        }
    });
    (out, plan.gemm_stats())
}

// ---- batched variants: one shared plan, a whole batch of requests ----

/// Check that every tensor of a batched GEMM-O call shares the expected
/// geometry, returning `(n, heads, d_out)`.
fn batched_geometry(
    os: &[&Tensor],
    panels: &WeightPanels,
    plan: &SparsePlan,
) -> (usize, usize, usize) {
    assert!(!os.is_empty(), "empty batch");
    let n = os[0].rows();
    let heads = plan.heads.len();
    let d_out = panels.d_out;
    for o in os {
        assert_eq!(o.rows(), n, "batch inputs must share a shape");
        assert_eq!(o.cols(), heads * panels.d_h, "batch inputs must share a shape");
    }
    assert_eq!(plan.t_q, n.div_ceil(plan.block_q), "plan Q-block geometry mismatch");
    (n, heads, d_out)
}

/// Batched [`gemm_o_dispatch_pool`]: one shared plan's live-tile structure
/// (the [`RowTiles`] inversion) is built **once for the batch** and drives
/// every request's dispatch projection. Work is dispatched over
/// `batch × row-block` pool lanes; within a lane the head loop stays in
/// ascending order, so output `r` is **bitwise-identical** to
/// `gemm_o_dispatch(os[r], panels, plan, biases[r])`.
pub fn gemm_o_dispatch_batched(
    os: &[&Tensor],
    panels: &WeightPanels,
    plan: &SparsePlan,
    biases: &[&Tensor],
    pool: &ExecPool,
) -> Vec<(Tensor, GemmStats)> {
    let (n, heads, d_out) = batched_geometry(os, panels, plan);
    assert_eq!(os.len(), biases.len());
    let block_q = plan.block_q;
    let isa = resolve_isa(block_q, panels.d_h, d_out);
    let mut outs: Vec<Tensor> = biases
        .iter()
        .map(|b| {
            assert_eq!(b.shape(), &[n, d_out]);
            (*b).clone()
        })
        .collect();
    let tiles = RowTiles::from_plan(plan);
    let t_q = plan.t_q;
    {
        let ptrs: Vec<SendPtr<f32>> =
            outs.iter_mut().map(|o| SendPtr(o.data_mut().as_mut_ptr())).collect();
        let ptrs = &ptrs;
        let tiles = &tiles;
        pool.parallel_for(os.len() * t_q, |task| {
            let r = task / t_q;
            let bi = task % t_q;
            let lo = bi * block_q;
            let hi = (lo + block_q).min(n);
            // SAFETY: (request, row-block) pairs are unique across tasks,
            // so the row slabs are disjoint; every `outs[r]` outlives the
            // parallel section (ExecPool joins before returning).
            let rows = unsafe {
                std::slice::from_raw_parts_mut(ptrs[r].0.add(lo * d_out), (hi - lo) * d_out)
            };
            for &h in &tiles.live[bi] {
                project_tile_rows(isa, os[r], panels, h as usize, lo, hi, heads, rows);
            }
        });
    }
    outs.into_iter().map(|o| (o, plan.gemm_stats())).collect()
}

/// Batched [`gemm_o_stage1_pool`]: project every request's *to-be-cached*
/// tiles into per-request bias tensors, walking one shared plan once.
/// Bitwise-identical per request to [`gemm_o_stage1`].
pub fn gemm_o_stage1_batched(
    os: &[&Tensor],
    panels: &WeightPanels,
    plan: &SparsePlan,
    pool: &ExecPool,
) -> Vec<Tensor> {
    let (n, heads, d_out) = batched_geometry(os, panels, plan);
    let block_q = plan.block_q;
    let isa = resolve_isa(block_q, panels.d_h, d_out);
    let mut biases: Vec<Tensor> =
        (0..os.len()).map(|_| Tensor::zeros(&[n, d_out])).collect();
    let tiles = RowTiles::from_plan(plan);
    let t_q = plan.t_q;
    {
        let ptrs: Vec<SendPtr<f32>> =
            biases.iter_mut().map(|b| SendPtr(b.data_mut().as_mut_ptr())).collect();
        let ptrs = &ptrs;
        let tiles = &tiles;
        pool.parallel_for(os.len() * t_q, |task| {
            let r = task / t_q;
            let bi = task % t_q;
            let lo = bi * block_q;
            let hi = (lo + block_q).min(n);
            // SAFETY: as in `gemm_o_dispatch_batched`.
            let rows = unsafe {
                std::slice::from_raw_parts_mut(ptrs[r].0.add(lo * d_out), (hi - lo) * d_out)
            };
            for &h in &tiles.cached[bi] {
                project_tile_rows(isa, os[r], panels, h as usize, lo, hi, heads, rows);
            }
        });
    }
    biases
}

/// Batched [`gemm_o_update_pool`]: per request, the exact Update-step
/// output plus the refreshed bias `B_c`, all driven by one shared plan.
/// Bitwise-identical per request to [`gemm_o_update`].
pub fn gemm_o_update_batched(
    os: &[&Tensor],
    panels: &WeightPanels,
    plan: &SparsePlan,
    pool: &ExecPool,
) -> Vec<(Tensor, Tensor, GemmStats)> {
    let (n, heads, d_out) = batched_geometry(os, panels, plan);
    let block_q = plan.block_q;
    let isa = resolve_isa(block_q, panels.d_h, d_out);
    let mut outs: Vec<Tensor> = (0..os.len()).map(|_| Tensor::zeros(&[n, d_out])).collect();
    let mut biases: Vec<Tensor> =
        (0..os.len()).map(|_| Tensor::zeros(&[n, d_out])).collect();
    let tiles = RowTiles::from_plan(plan);
    let t_q = plan.t_q;
    {
        let out_ptrs: Vec<SendPtr<f32>> =
            outs.iter_mut().map(|o| SendPtr(o.data_mut().as_mut_ptr())).collect();
        let bias_ptrs: Vec<SendPtr<f32>> =
            biases.iter_mut().map(|b| SendPtr(b.data_mut().as_mut_ptr())).collect();
        let (out_ptrs, bias_ptrs) = (&out_ptrs, &bias_ptrs);
        let tiles = &tiles;
        pool.parallel_for(os.len() * t_q, |task| {
            let r = task / t_q;
            let bi = task % t_q;
            let lo = bi * block_q;
            let hi = (lo + block_q).min(n);
            let len = (hi - lo) * d_out;
            // SAFETY: as in `gemm_o_dispatch_batched`; the out and bias
            // slabs live in different buffers.
            let out_rows =
                unsafe { std::slice::from_raw_parts_mut(out_ptrs[r].0.add(lo * d_out), len) };
            let bias_rows =
                unsafe { std::slice::from_raw_parts_mut(bias_ptrs[r].0.add(lo * d_out), len) };
            for &h in &tiles.live[bi] {
                project_tile_rows(isa, os[r], panels, h as usize, lo, hi, heads, out_rows);
            }
            for &h in &tiles.cached[bi] {
                project_tile_rows(isa, os[r], panels, h as usize, lo, hi, heads, bias_rows);
            }
        });
    }
    outs.iter_mut().zip(&biases).for_each(|(o, b)| o.add_assign(b));
    outs.into_iter()
        .zip(biases)
        .map(|(o, b)| (o, b, plan.gemm_stats()))
        .collect()
}

// ---- ragged variants: per-request plans, one concatenated buffer ----

/// Validate a ragged GEMM-O call (`indptr` layout, shared head count /
/// block size across plans, per-request plan geometry), returning
/// `(heads, d_out, block_q)`.
fn ragged_geometry(
    o_cat: &Tensor,
    indptr: &[usize],
    panels: &WeightPanels,
    plans: &[&SparsePlan],
) -> (usize, usize, usize) {
    let batch = plans.len();
    assert!(batch > 0, "empty ragged batch");
    assert_eq!(indptr.len(), batch + 1, "indptr must have batch+1 entries");
    assert_eq!(indptr[0], 0, "indptr must start at 0");
    assert_eq!(indptr[batch], o_cat.rows(), "indptr must cover o_cat");
    let heads = plans[0].heads.len();
    let block_q = plans[0].block_q;
    assert_eq!(o_cat.cols(), heads * panels.d_h);
    for (r, plan) in plans.iter().enumerate() {
        assert!(indptr[r] <= indptr[r + 1], "indptr must be monotone");
        let n_r = indptr[r + 1] - indptr[r];
        assert_eq!(plan.heads.len(), heads, "ragged batch must share heads");
        assert_eq!(plan.block_q, block_q, "ragged batch must share block_q");
        assert_eq!(plan.t_q, n_r.div_ceil(block_q), "plan Q-block geometry mismatch");
    }
    (heads, panels.d_out, block_q)
}

/// Flatten per-request row blocks into one `(request, block)` work list.
fn ragged_row_tasks(plans: &[&SparsePlan]) -> Vec<(u32, u32)> {
    let mut tasks = Vec::new();
    for (r, plan) in plans.iter().enumerate() {
        for bi in 0..plan.t_q {
            tasks.push((r as u32, bi as u32));
        }
    }
    tasks
}

/// Ragged [`gemm_o_dispatch_batched`]: **per-request plans** over one
/// concatenated `[ΣNᵣ × H·d_h]` attention-output buffer with cu-seqlen
/// offsets — the varlen analogue for mixed-resolution batches. Request `r`
/// owns rows `indptr[r]..indptr[r+1]`; its [`RowTiles`] inversion drives
/// its own row blocks, reading at global row offsets and writing into its
/// own `[Nᵣ × d_out]` output (initialized from `biases[r]`). Within a row
/// block the head loop stays in ascending order, so output `r` is
/// **bitwise-identical** to `gemm_o_dispatch(o_r, panels, plans[r],
/// biases[r])` (property-tested below, tail blocks clamped at
/// `indptr[r+1]`).
pub fn gemm_o_dispatch_ragged(
    o_cat: &Tensor,
    indptr: &[usize],
    panels: &WeightPanels,
    plans: &[&SparsePlan],
    biases: &[&Tensor],
    pool: &ExecPool,
) -> Vec<(Tensor, GemmStats)> {
    let (heads, d_out, block_q) = ragged_geometry(o_cat, indptr, panels, plans);
    assert_eq!(plans.len(), biases.len());
    let isa = resolve_isa(block_q, panels.d_h, d_out);
    let mut outs: Vec<Tensor> = biases
        .iter()
        .enumerate()
        .map(|(r, b)| {
            assert_eq!(b.shape(), &[indptr[r + 1] - indptr[r], d_out]);
            (*b).clone()
        })
        .collect();
    let row_tiles: Vec<RowTiles> = plans.iter().map(|p| RowTiles::from_plan(p)).collect();
    let tasks = ragged_row_tasks(plans);
    {
        let ptrs: Vec<SendPtr<f32>> =
            outs.iter_mut().map(|o| SendPtr(o.data_mut().as_mut_ptr())).collect();
        let ptrs = &ptrs;
        let row_tiles = &row_tiles;
        pool.parallel_for(tasks.len(), |task| {
            let (r, bi) = tasks[task];
            let (r, bi) = (r as usize, bi as usize);
            // Global read offsets; the tail block clamps at the request's
            // end, exactly like the solo kernel clamps at `n`.
            let lo = indptr[r] + bi * block_q;
            let hi = (lo + block_q).min(indptr[r + 1]);
            // SAFETY: (request, row-block) pairs are unique across tasks,
            // so the row slabs are disjoint; every `outs[r]` outlives the
            // parallel section (ExecPool joins before returning).
            let rows = unsafe {
                std::slice::from_raw_parts_mut(
                    ptrs[r].0.add(bi * block_q * d_out),
                    (hi - lo) * d_out,
                )
            };
            for &h in &row_tiles[r].live[bi] {
                project_tile_rows(isa, o_cat, panels, h as usize, lo, hi, heads, rows);
            }
        });
    }
    outs.into_iter().zip(plans).map(|(o, p)| (o, p.gemm_stats())).collect()
}

/// Ragged [`gemm_o_stage1_batched`]: per-request *to-be-cached* tiles
/// projected into per-request bias tensors off one concatenated buffer.
/// Bitwise-identical per request to [`gemm_o_stage1`].
pub fn gemm_o_stage1_ragged(
    o_cat: &Tensor,
    indptr: &[usize],
    panels: &WeightPanels,
    plans: &[&SparsePlan],
    pool: &ExecPool,
) -> Vec<Tensor> {
    let (heads, d_out, block_q) = ragged_geometry(o_cat, indptr, panels, plans);
    let isa = resolve_isa(block_q, panels.d_h, d_out);
    let mut biases: Vec<Tensor> = (0..plans.len())
        .map(|r| Tensor::zeros(&[indptr[r + 1] - indptr[r], d_out]))
        .collect();
    let row_tiles: Vec<RowTiles> = plans.iter().map(|p| RowTiles::from_plan(p)).collect();
    let tasks = ragged_row_tasks(plans);
    {
        let ptrs: Vec<SendPtr<f32>> =
            biases.iter_mut().map(|b| SendPtr(b.data_mut().as_mut_ptr())).collect();
        let ptrs = &ptrs;
        let row_tiles = &row_tiles;
        pool.parallel_for(tasks.len(), |task| {
            let (r, bi) = tasks[task];
            let (r, bi) = (r as usize, bi as usize);
            let lo = indptr[r] + bi * block_q;
            let hi = (lo + block_q).min(indptr[r + 1]);
            // SAFETY: as in `gemm_o_dispatch_ragged`.
            let rows = unsafe {
                std::slice::from_raw_parts_mut(
                    ptrs[r].0.add(bi * block_q * d_out),
                    (hi - lo) * d_out,
                )
            };
            for &h in &row_tiles[r].cached[bi] {
                project_tile_rows(isa, o_cat, panels, h as usize, lo, hi, heads, rows);
            }
        });
    }
    biases
}

/// Ragged [`gemm_o_update_batched`]: per request, the exact Update-step
/// output plus the refreshed bias `B_c`, each driven by its own plan off
/// one concatenated buffer. Bitwise-identical per request to
/// [`gemm_o_update`].
pub fn gemm_o_update_ragged(
    o_cat: &Tensor,
    indptr: &[usize],
    panels: &WeightPanels,
    plans: &[&SparsePlan],
    pool: &ExecPool,
) -> Vec<(Tensor, Tensor, GemmStats)> {
    let (heads, d_out, block_q) = ragged_geometry(o_cat, indptr, panels, plans);
    let isa = resolve_isa(block_q, panels.d_h, d_out);
    let mut outs: Vec<Tensor> = (0..plans.len())
        .map(|r| Tensor::zeros(&[indptr[r + 1] - indptr[r], d_out]))
        .collect();
    let mut biases: Vec<Tensor> = (0..plans.len())
        .map(|r| Tensor::zeros(&[indptr[r + 1] - indptr[r], d_out]))
        .collect();
    let row_tiles: Vec<RowTiles> = plans.iter().map(|p| RowTiles::from_plan(p)).collect();
    let tasks = ragged_row_tasks(plans);
    {
        let out_ptrs: Vec<SendPtr<f32>> =
            outs.iter_mut().map(|o| SendPtr(o.data_mut().as_mut_ptr())).collect();
        let bias_ptrs: Vec<SendPtr<f32>> =
            biases.iter_mut().map(|b| SendPtr(b.data_mut().as_mut_ptr())).collect();
        let (out_ptrs, bias_ptrs) = (&out_ptrs, &bias_ptrs);
        let row_tiles = &row_tiles;
        pool.parallel_for(tasks.len(), |task| {
            let (r, bi) = tasks[task];
            let (r, bi) = (r as usize, bi as usize);
            let lo = indptr[r] + bi * block_q;
            let hi = (lo + block_q).min(indptr[r + 1]);
            let len = (hi - lo) * d_out;
            // SAFETY: as in `gemm_o_dispatch_ragged`; the out and bias
            // slabs live in different buffers.
            let out_rows = unsafe {
                std::slice::from_raw_parts_mut(out_ptrs[r].0.add(bi * block_q * d_out), len)
            };
            let bias_rows = unsafe {
                std::slice::from_raw_parts_mut(bias_ptrs[r].0.add(bi * block_q * d_out), len)
            };
            for &h in &row_tiles[r].live[bi] {
                project_tile_rows(isa, o_cat, panels, h as usize, lo, hi, heads, out_rows);
            }
            for &h in &row_tiles[r].cached[bi] {
                project_tile_rows(isa, o_cat, panels, h as usize, lo, hi, heads, bias_rows);
            }
        });
    }
    outs.iter_mut().zip(&biases).for_each(|(o, b)| o.add_assign(b));
    outs.into_iter()
        .zip(biases)
        .zip(plans)
        .map(|((o, b), p)| (o, b, p.gemm_stats()))
        .collect()
}

// ---- seed symbol-decoding variants (plan-equivalence references) ----

/// [`gemm_o_update`] decoding `F(S_c, i)` per tile (seed implementation).
pub fn gemm_o_update_symbols(
    o_cat: &Tensor,
    panels: &WeightPanels,
    syms: &LayerSymbols,
    block_q: usize,
) -> (Tensor, Tensor, GemmStats) {
    let n = o_cat.rows();
    let heads = syms.heads.len();
    let d_out = panels.d_out;
    // Same geometry key as the plan-based kernel, so plan == symbols stays
    // bitwise under tuning.
    let isa = resolve_isa(block_q, panels.d_h, d_out);
    let t_q = n.div_ceil(block_q);
    let mut bias = Tensor::zeros(&[n, d_out]);
    let mut out = Tensor::zeros(&[n, d_out]);
    let mut stats = GemmStats { total_tiles: t_q * heads, ..Default::default() };

    for (h, hs) in syms.heads.iter().enumerate() {
        for bi in 0..t_q {
            let lo = bi * block_q;
            let hi = (lo + block_q).min(n);
            if hs.f(bi) {
                // Stage 2 tile: always updated during Dispatch.
                project_tile(isa, o_cat, panels, h, lo, hi, heads, out.data_mut());
                stats.computed_tiles += 1;
            } else {
                // Stage 1 tile: record in the cached bias.
                project_tile(isa, o_cat, panels, h, lo, hi, heads, bias.data_mut());
            }
        }
    }
    out.add_assign(&bias);
    (out, bias, stats)
}

/// [`gemm_o_stage1`] decoding symbols per tile (seed implementation).
pub fn gemm_o_stage1_symbols(
    o_cat: &Tensor,
    panels: &WeightPanels,
    syms: &LayerSymbols,
    block_q: usize,
) -> Tensor {
    let n = o_cat.rows();
    let heads = syms.heads.len();
    let d_out = panels.d_out;
    let isa = resolve_isa(block_q, panels.d_h, d_out);
    let t_q = n.div_ceil(block_q);
    let mut bias = Tensor::zeros(&[n, d_out]);
    for (h, hs) in syms.heads.iter().enumerate() {
        for bi in 0..t_q {
            if hs.f(bi) {
                continue;
            }
            let lo = bi * block_q;
            let hi = (lo + block_q).min(n);
            project_tile(isa, o_cat, panels, h, lo, hi, heads, bias.data_mut());
        }
    }
    bias
}

/// [`gemm_o_dispatch`] decoding symbols per tile (seed implementation).
pub fn gemm_o_dispatch_symbols(
    o_cat: &Tensor,
    panels: &WeightPanels,
    syms: &LayerSymbols,
    block_q: usize,
    bias: &Tensor,
) -> (Tensor, GemmStats) {
    let n = o_cat.rows();
    let heads = syms.heads.len();
    let d_out = panels.d_out;
    let isa = resolve_isa(block_q, panels.d_h, d_out);
    assert_eq!(bias.shape(), &[n, d_out]);
    let t_q = n.div_ceil(block_q);
    let mut out = bias.clone();
    let mut stats = GemmStats { total_tiles: t_q * heads, ..Default::default() };

    for (h, hs) in syms.heads.iter().enumerate() {
        for bi in 0..t_q {
            if !hs.f(bi) {
                continue; // cached tile: already inside the bias
            }
            stats.computed_tiles += 1;
            let lo = bi * block_q;
            let hi = (lo + block_q).min(n);
            project_tile(isa, o_cat, panels, h, lo, hi, heads, out.data_mut());
        }
    }
    (out, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::DecodeMode;
    use crate::symbols::{HeadSymbols, LayerSymbols};
    use crate::testutil::{assert_close, prop_check, rand_mask, randn};

    fn syms_from_cache_masks(masks: &[Vec<bool>]) -> LayerSymbols {
        let t_q = masks[0].len();
        LayerSymbols {
            heads: masks
                .iter()
                .map(|m| HeadSymbols::from_masks(m, &vec![true; t_q * t_q], t_q, 1))
                .collect(),
        }
    }

    fn plan_of(syms: &LayerSymbols, block_q: usize) -> SparsePlan {
        let t_q = syms.heads[0].q_groups;
        SparsePlan::compile(syms, t_q, t_q, block_q, block_q, DecodeMode::RowCached)
    }

    #[test]
    fn update_is_exact_dense_projection() {
        prop_check("gemm_o_update == dense", 20, |rng| {
            let n = 16 + rng.below(24);
            let heads = 1 + rng.below(4);
            let d_h = 2 + rng.below(6);
            let d_out = 4 + rng.below(12);
            let b = 4 + rng.below(8);
            let t_q = n.div_ceil(b);
            let o = randn(rng, &[n, heads * d_h]);
            let w = randn(rng, &[heads * d_h, d_out]);
            let panels = WeightPanels::new(&w, heads);
            let masks: Vec<Vec<bool>> =
                (0..heads).map(|_| rand_mask(rng, t_q, 0.5)).collect();
            let syms = syms_from_cache_masks(&masks);
            let plan = plan_of(&syms, b);
            let (out, _bias, _stats) = gemm_o_update(&o, &panels, &plan);
            assert_close(&out, &gemm_o_dense(&o, &w), 1e-3, 1e-3);
        });
    }

    #[test]
    fn dispatch_equals_dense_when_cached_features_static() {
        // If cached tiles keep their Update-step values (OP_reuse =
        // identity), dispatch(bias) must equal the dense projection of the
        // full O. This is exactly Eq. 3/4 with direct reuse.
        prop_check("dispatch + bias == dense", 20, |rng| {
            let n = 16 + rng.below(24);
            let heads = 1 + rng.below(3);
            let d_h = 2 + rng.below(6);
            let d_out = 4 + rng.below(8);
            let b = 8;
            let t_q = n.div_ceil(b);
            let o_full = randn(rng, &[n, heads * d_h]);
            let w = randn(rng, &[heads * d_h, d_out]);
            let panels = WeightPanels::new(&w, heads);
            let masks: Vec<Vec<bool>> =
                (0..heads).map(|_| rand_mask(rng, t_q, 0.5)).collect();
            let syms = syms_from_cache_masks(&masks);
            let plan = plan_of(&syms, b);
            let (_, bias, _) = gemm_o_update(&o_full, &panels, &plan);
            // Dispatch step: only computed tiles valid; cached tiles zeroed
            // to prove they are never read.
            let mut o_partial = o_full.clone();
            let d_cat = heads * d_h;
            for (h, m) in masks.iter().enumerate() {
                for (bi, &compute) in m.iter().enumerate() {
                    if compute {
                        continue;
                    }
                    let lo = bi * b;
                    let hi = (lo + b).min(n);
                    for r in lo..hi {
                        for c in h * d_h..(h + 1) * d_h {
                            o_partial.data_mut()[r * d_cat + c] = f32::NAN; // poison
                        }
                    }
                }
            }
            let (out, stats) = gemm_o_dispatch(&o_partial, &panels, &plan, &bias);
            assert!(out.data().iter().all(|x| x.is_finite()), "read a poisoned tile");
            assert_close(&out, &gemm_o_dense(&o_full, &w), 1e-3, 1e-3);
            let computed: usize =
                masks.iter().map(|m| m.iter().filter(|&&x| x).count()).sum();
            assert_eq!(stats.computed_tiles, computed);
        });
    }

    #[test]
    fn pool_variants_are_bitwise_identical() {
        let pool = crate::exec::ExecPool::new(3);
        prop_check("gemm_o *_pool == serial", 10, |rng| {
            let n = 16 + rng.below(32);
            let heads = 1 + rng.below(4);
            let d_h = 2 + rng.below(6);
            let d_out = 4 + rng.below(10);
            let b = 4 + rng.below(8);
            let t_q = n.div_ceil(b);
            let o = randn(rng, &[n, heads * d_h]);
            let w = randn(rng, &[heads * d_h, d_out]);
            let panels = WeightPanels::new(&w, heads);
            let masks: Vec<Vec<bool>> =
                (0..heads).map(|_| rand_mask(rng, t_q, 0.5)).collect();
            let syms = syms_from_cache_masks(&masks);
            let plan = SparsePlan::compile(&syms, t_q, t_q, b, b, DecodeMode::RowCached);
            let (out_s, bias_s, st_s) = gemm_o_update(&o, &panels, &plan);
            let (out_p, bias_p, st_p) = gemm_o_update_pool(&o, &panels, &plan, &pool);
            assert_eq!(out_s.data(), out_p.data(), "update out must be bitwise equal");
            assert_eq!(bias_s.data(), bias_p.data(), "update bias must be bitwise equal");
            assert_eq!(st_s.computed_tiles, st_p.computed_tiles);
            let stage_s = gemm_o_stage1(&o, &panels, &plan);
            let stage_p = gemm_o_stage1_pool(&o, &panels, &plan, &pool);
            assert_eq!(stage_s.data(), stage_p.data(), "stage1 must be bitwise equal");
            let (d_s, _) = gemm_o_dispatch(&o, &panels, &plan, &bias_s);
            let (d_p, _) = gemm_o_dispatch_pool(&o, &panels, &plan, &bias_s, &pool);
            assert_eq!(d_s.data(), d_p.data(), "dispatch must be bitwise equal");
        });
    }

    #[test]
    fn batched_variants_are_bitwise_identical_per_request() {
        let pool = crate::exec::ExecPool::new(3);
        prop_check("gemm_o *_batched[r] == serial(os[r])", 10, |rng| {
            let n = 16 + rng.below(32);
            let heads = 1 + rng.below(4);
            let d_h = 2 + rng.below(6);
            let d_out = 4 + rng.below(10);
            let b = 4 + rng.below(8);
            let batch = 1 + rng.below(4);
            let t_q = n.div_ceil(b);
            let os: Vec<Tensor> = (0..batch).map(|_| randn(rng, &[n, heads * d_h])).collect();
            let w = randn(rng, &[heads * d_h, d_out]);
            let panels = WeightPanels::new(&w, heads);
            let masks: Vec<Vec<bool>> =
                (0..heads).map(|_| rand_mask(rng, t_q, 0.5)).collect();
            let syms = syms_from_cache_masks(&masks);
            let plan = SparsePlan::compile(&syms, t_q, t_q, b, b, DecodeMode::RowCached);
            let o_refs: Vec<&Tensor> = os.iter().collect();

            let updates = gemm_o_update_batched(&o_refs, &panels, &plan, &pool);
            let stages = gemm_o_stage1_batched(&o_refs, &panels, &plan, &pool);
            let serial: Vec<(Tensor, Tensor, GemmStats)> =
                os.iter().map(|o| gemm_o_update(o, &panels, &plan)).collect();
            for (r, ((out_b, bias_b, st_b), (out_s, bias_s, st_s))) in
                updates.iter().zip(&serial).enumerate()
            {
                assert_eq!(out_s.data(), out_b.data(), "update out, request {r}");
                assert_eq!(bias_s.data(), bias_b.data(), "update bias, request {r}");
                assert_eq!(st_s.computed_tiles, st_b.computed_tiles);
                assert_eq!(stages[r].data(), bias_s.data(), "stage1, request {r}");
            }

            let bias_refs: Vec<&Tensor> = serial.iter().map(|(_, b, _)| b).collect();
            let dispatched =
                gemm_o_dispatch_batched(&o_refs, &panels, &plan, &bias_refs, &pool);
            for (r, (d_b, _)) in dispatched.iter().enumerate() {
                let (d_s, _) = gemm_o_dispatch(&os[r], &panels, &plan, bias_refs[r]);
                assert_eq!(d_s.data(), d_b.data(), "dispatch, request {r}");
            }
        });
    }

    #[test]
    fn ragged_variants_are_bitwise_identical_per_request() {
        let pool = crate::exec::ExecPool::new(3);
        prop_check("gemm_o *_ragged[r] == serial(o_r)", 10, |rng| {
            let heads = 1 + rng.below(4);
            let d_h = 2 + rng.below(6);
            let d_out = 4 + rng.below(10);
            let b = 4 + rng.below(8);
            let batch = 1 + rng.below(4);
            // Mixed (often odd) per-request lengths exercise tail clamping.
            let ns: Vec<usize> = (0..batch).map(|_| 9 + rng.below(39)).collect();
            let w = randn(rng, &[heads * d_h, d_out]);
            let panels = WeightPanels::new(&w, heads);
            let os: Vec<Tensor> = ns.iter().map(|&n| randn(rng, &[n, heads * d_h])).collect();
            let plans: Vec<SparsePlan> = ns
                .iter()
                .map(|&n| {
                    let t_q = n.div_ceil(b);
                    let masks: Vec<Vec<bool>> =
                        (0..heads).map(|_| rand_mask(rng, t_q, 0.5)).collect();
                    let syms = syms_from_cache_masks(&masks);
                    SparsePlan::compile(&syms, t_q, t_q, b, b, DecodeMode::RowCached)
                })
                .collect();
            let mut indptr = vec![0usize];
            let mut cat = Vec::new();
            for o in &os {
                cat.extend_from_slice(o.data());
                indptr.push(indptr.last().unwrap() + o.rows());
            }
            let o_cat = Tensor::from_vec(&[indptr[batch], heads * d_h], cat);
            let plan_refs: Vec<&SparsePlan> = plans.iter().collect();

            let updates = gemm_o_update_ragged(&o_cat, &indptr, &panels, &plan_refs, &pool);
            let stages = gemm_o_stage1_ragged(&o_cat, &indptr, &panels, &plan_refs, &pool);
            let serial: Vec<(Tensor, Tensor, GemmStats)> = os
                .iter()
                .zip(&plans)
                .map(|(o, p)| gemm_o_update(o, &panels, p))
                .collect();
            for (r, ((out_b, bias_b, st_b), (out_s, bias_s, st_s))) in
                updates.iter().zip(&serial).enumerate()
            {
                assert_eq!(out_s.data(), out_b.data(), "update out, request {r}");
                assert_eq!(bias_s.data(), bias_b.data(), "update bias, request {r}");
                assert_eq!(st_s.computed_tiles, st_b.computed_tiles);
                assert_eq!(stages[r].data(), bias_s.data(), "stage1, request {r}");
            }

            let bias_refs: Vec<&Tensor> = serial.iter().map(|(_, bb, _)| bb).collect();
            let dispatched =
                gemm_o_dispatch_ragged(&o_cat, &indptr, &panels, &plan_refs, &bias_refs, &pool);
            for (r, (d_b, _)) in dispatched.iter().enumerate() {
                let (d_s, _) = gemm_o_dispatch(&os[r], &panels, &plans[r], bias_refs[r]);
                assert_eq!(d_s.data(), d_b.data(), "dispatch, request {r}");
            }
        });
    }

    #[test]
    fn all_cached_dispatch_is_pure_bias() {
        let mut rng = crate::util::rng::Pcg32::seeded(8);
        let (n, heads, d_h, d_out, b) = (16, 2, 4, 6, 8);
        let o = randn(&mut rng, &[n, heads * d_h]);
        let w = randn(&mut rng, &[heads * d_h, d_out]);
        let panels = WeightPanels::new(&w, heads);
        let syms = syms_from_cache_masks(&[vec![false; 2], vec![false; 2]]);
        let plan = plan_of(&syms, b);
        let (out_u, bias, _) = gemm_o_update(&o, &panels, &plan);
        // Everything cached → bias IS the dense output.
        assert_close(&bias, &gemm_o_dense(&o, &w), 1e-4, 1e-4);
        assert_close(&out_u, &bias, 1e-4, 1e-4);
        let garbage = Tensor::full(&[n, heads * d_h], f32::NAN);
        let (out_d, stats) = gemm_o_dispatch(&garbage, &panels, &plan, &bias);
        assert_eq!(stats.computed_tiles, 0);
        assert_close(&out_d, &bias, 0.0, 0.0);
    }

    #[test]
    fn stage1_matches_update_bias() {
        let mut rng = crate::util::rng::Pcg32::seeded(9);
        let (n, heads, d_h, d_out, b) = (24, 3, 4, 8, 8);
        let o = randn(&mut rng, &[n, heads * d_h]);
        let w = randn(&mut rng, &[heads * d_h, d_out]);
        let panels = WeightPanels::new(&w, heads);
        let masks: Vec<Vec<bool>> = (0..heads).map(|_| rand_mask(&mut rng, 3, 0.5)).collect();
        let syms = syms_from_cache_masks(&masks);
        let plan = plan_of(&syms, b);
        let (_, bias, _) = gemm_o_update(&o, &panels, &plan);
        let stage1 = gemm_o_stage1(&o, &panels, &plan);
        assert_close(&stage1, &bias, 0.0, 0.0);
    }

    #[test]
    fn weight_panels_layout() {
        let w = Tensor::from_vec(&[4, 3], (0..12).map(|x| x as f32).collect());
        let p = WeightPanels::new(&w, 2);
        assert_eq!(p.d_h, 2);
        assert_eq!(p.panels[0], vec![0., 1., 2., 3., 4., 5.]);
        assert_eq!(p.panels[1], vec![6., 7., 8., 9., 10., 11.]);
    }
}
