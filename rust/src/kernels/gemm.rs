//! Dense GEMM primitives.
//!
//! Row-major f32 matmul with an axpy-style inner loop (`C[i,:] += a * B[p,:]`)
//! plus a dot-product variant for `A·Bᵀ` (used by `QKᵀ`). Since PR 6 the
//! inner loops run through the explicit [`microkernel`] layer: the `_isa`
//! entry points take a [`Isa`] flavor (the scalar flavor reproduces the
//! seed float sequences bit-for-bit; the SIMD flavor uses AVX2/NEON behind
//! runtime detection), and the plain entry points resolve the process-wide
//! default ([`microkernel::active`]). These are the building blocks the
//! sparse kernels skip over.

use crate::kernels::microkernel::{self, Isa};
use crate::tensor::Tensor;

/// `C = A · B` for row-major `A [m×k]`, `B [k×n]` → `C [m×n]`.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k, k2, "matmul inner dims: {k} vs {k2}");
    let mut c = Tensor::zeros(&[m, n]);
    matmul_into(a.data(), b.data(), c.data_mut(), m, k, n);
    c
}

/// `C += A · B` on raw slices (row-major). The workhorse; runs the
/// process-wide default microkernel flavor.
#[inline]
pub fn matmul_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    matmul_into_isa(microkernel::active(), a, b, c, m, k, n);
}

/// [`matmul_into`] with an explicit microkernel flavor. The scalar flavor
/// is the seed kernel's exact float sequence (register-blocked over p with
/// the axpy inner loop, p unrolled by 4); the SIMD flavor runs the same
/// structure through vector axpy microkernels.
#[inline]
pub fn matmul_into_isa(isa: Isa, a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    // Register-blocked over p (k axis) with the axpy inner loop; unrolling p
    // by 4 cuts loop overhead and keeps one store stream into C.
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        let mut p = 0;
        while p + 4 <= k {
            let coef = [arow[p], arow[p + 1], arow[p + 2], arow[p + 3]];
            let b0 = &b[p * n..(p + 1) * n];
            let b1 = &b[(p + 1) * n..(p + 2) * n];
            let b2 = &b[(p + 2) * n..(p + 3) * n];
            let b3 = &b[(p + 3) * n..(p + 4) * n];
            microkernel::axpy4(isa, crow, coef, b0, b1, b2, b3);
            p += 4;
        }
        while p < k {
            let ap = arow[p];
            let brow = &b[p * n..(p + 1) * n];
            microkernel::axpy1(isa, crow, ap, brow);
            p += 1;
        }
    }
}

/// `C = A · Bᵀ` for row-major `A [m×k]`, `B [n×k]` → `C [m×n]`
/// (dot-product form; this is `Q Kᵀ`).
pub fn matmul_nt(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let (n, k2) = (b.rows(), b.cols());
    assert_eq!(k, k2, "matmul_nt inner dims: {k} vs {k2}");
    let mut c = Tensor::zeros(&[m, n]);
    matmul_nt_into(a.data(), b.data(), c.data_mut(), m, k, n);
    c
}

/// `C += A · Bᵀ` on raw slices; runs the process-wide default microkernel
/// flavor.
#[inline]
pub fn matmul_nt_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    matmul_nt_into_isa(microkernel::active(), a, b, c, m, k, n);
}

/// [`matmul_nt_into`] with an explicit microkernel flavor (the scalar
/// flavor is the seed kernel's plain left-to-right dot accumulation).
#[inline]
pub fn matmul_nt_into_isa(
    isa: Isa,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            c[i * n + j] += microkernel::dot(isa, arow, brow);
        }
    }
}

/// Naive triple-loop reference used only by tests.
pub fn matmul_naive(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let n = b.cols();
    assert_eq!(k, b.rows());
    let mut c = Tensor::zeros(&[m, n]);
    for i in 0..m {
        for j in 0..n {
            let mut s = 0.0;
            for p in 0..k {
                s += a.data()[i * k + p] * b.data()[p * n + j];
            }
            c.data_mut()[i * n + j] = s;
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{assert_close, prop_check, randn};

    #[test]
    fn matmul_matches_naive() {
        prop_check("matmul == naive", 25, |rng| {
            let m = 1 + rng.below(17);
            let k = 1 + rng.below(33);
            let n = 1 + rng.below(17);
            let a = randn(rng, &[m, k]);
            let b = randn(rng, &[k, n]);
            assert_close(&matmul(&a, &b), &matmul_naive(&a, &b), 1e-4, 1e-4);
        });
    }

    #[test]
    fn matmul_nt_matches_transposed() {
        prop_check("matmul_nt == matmul(A, Bᵀ)", 25, |rng| {
            let m = 1 + rng.below(9);
            let k = 1 + rng.below(33);
            let n = 1 + rng.below(9);
            let a = randn(rng, &[m, k]);
            let bt = randn(rng, &[n, k]);
            // Manually transpose bt → b.
            let mut b = Tensor::zeros(&[k, n]);
            for j in 0..n {
                for p in 0..k {
                    b.data_mut()[p * n + j] = bt.data()[j * k + p];
                }
            }
            assert_close(&matmul_nt(&a, &bt), &matmul(&a, &b), 1e-4, 1e-4);
        });
    }

    #[test]
    fn identity() {
        let mut eye = Tensor::zeros(&[4, 4]);
        for i in 0..4 {
            eye.data_mut()[i * 4 + i] = 1.0;
        }
        let mut rng = crate::util::rng::Pcg32::seeded(3);
        let a = randn(&mut rng, &[4, 4]);
        assert_close(&matmul(&a, &eye), &a, 1e-6, 0.0);
    }

    #[test]
    fn accumulating_into() {
        let a = Tensor::full(&[2, 2], 1.0);
        let b = Tensor::full(&[2, 2], 1.0);
        let mut c = Tensor::full(&[2, 2], 10.0);
        matmul_into(a.data(), b.data(), c.data_mut(), 2, 2, 2);
        assert_eq!(c.data(), &[12.0; 4]);
    }
}
