//! Element-wise / per-token operators: RMSNorm, RoPE, GELU, SiLU, adaLN
//! modulation, LayerNorm, softmax.
//!
//! Observation 2 of the paper relies on RMSNorm and RoPE operating **only
//! along the feature dimension** of each token — no cross-token computation
//! — which is what makes skipping the query projection of cached blocks
//! sound. These implementations preserve that property and mirror the JAX
//! definitions in `python/compile/model.py` bit-for-bit (same formulas,
//! same θ for RoPE).

use crate::tensor::Tensor;

/// Token-wise RMSNorm with learned scale `w` (`[d]`): `x / rms(x) * w`.
pub fn rmsnorm(x: &mut Tensor, w: &[f32], eps: f32) {
    let d = x.cols();
    assert_eq!(w.len(), d);
    for r in 0..x.rows() {
        let row = x.row_mut(r);
        let mut ss = 0.0f32;
        for &v in row.iter() {
            ss += v * v;
        }
        let inv = 1.0 / (ss / d as f32 + eps).sqrt();
        for (v, &wi) in row.iter_mut().zip(w) {
            *v = *v * inv * wi;
        }
    }
}

/// LayerNorm without affine parameters (used pre-modulation in adaLN-zero).
pub fn layernorm(x: &mut Tensor, eps: f32) {
    let d = x.cols();
    for r in 0..x.rows() {
        let row = x.row_mut(r);
        let mean = row.iter().sum::<f32>() / d as f32;
        let mut var = 0.0f32;
        for &v in row.iter() {
            var += (v - mean) * (v - mean);
        }
        var /= d as f32;
        let inv = 1.0 / (var + eps).sqrt();
        for v in row.iter_mut() {
            *v = (*v - mean) * inv;
        }
    }
}

/// Rotary positional embedding, 1-D positions, pair convention
/// `(x[2i], x[2i+1])`, frequency base `theta` (10000 in the model).
/// `positions[r]` is the absolute position of row `r`.
pub fn rope(x: &mut Tensor, positions: &[usize], theta: f32) {
    let d = x.cols();
    assert_eq!(positions.len(), x.rows());
    assert_eq!(d % 2, 0, "RoPE needs an even head dim");
    let half = d / 2;
    for r in 0..x.rows() {
        let pos = positions[r] as f32;
        let row = x.row_mut(r);
        for i in 0..half {
            let freq = theta.powf(-2.0 * i as f32 / d as f32);
            let angle = pos * freq;
            let (sin, cos) = angle.sin_cos();
            let (a, b) = (row[2 * i], row[2 * i + 1]);
            row[2 * i] = a * cos - b * sin;
            row[2 * i + 1] = a * sin + b * cos;
        }
    }
}

/// Tanh-approximation GELU (matches `jax.nn.gelu` default).
#[inline]
pub fn gelu_scalar(x: f32) -> f32 {
    const C: f32 = 0.797_884_6; // sqrt(2/π)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

pub fn gelu(x: &mut Tensor) {
    for v in x.data_mut() {
        *v = gelu_scalar(*v);
    }
}

/// SiLU (used on the timestep-conditioning MLP).
pub fn silu(x: &mut Tensor) {
    for v in x.data_mut() {
        *v = *v / (1.0 + (-*v).exp());
    }
}

/// adaLN-zero modulation: `x * (1 + scale) + shift`, with `shift`/`scale`
/// broadcast per feature (`[d]`).
pub fn modulate(x: &mut Tensor, shift: &[f32], scale: &[f32]) {
    let d = x.cols();
    assert_eq!(shift.len(), d);
    assert_eq!(scale.len(), d);
    for r in 0..x.rows() {
        let row = x.row_mut(r);
        for c in 0..d {
            row[c] = row[c] * (1.0 + scale[c]) + shift[c];
        }
    }
}

/// Gated residual add: `x += gate ⊙ y` (gate broadcast per feature).
pub fn gated_add(x: &mut Tensor, gate: &[f32], y: &Tensor) {
    let d = x.cols();
    assert_eq!(x.shape(), y.shape());
    assert_eq!(gate.len(), d);
    for r in 0..x.rows() {
        let xr = x.row_mut(r);
        let yr = y.row(r);
        for c in 0..d {
            xr[c] += gate[c] * yr[c];
        }
    }
}

/// In-place row softmax.
pub fn softmax_rows(x: &mut Tensor) {
    let d = x.cols();
    for r in 0..x.rows() {
        let row = x.row_mut(r);
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut s = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - m).exp();
            s += *v;
        }
        let inv = 1.0 / s;
        let _ = d;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{prop_check, randn};

    #[test]
    fn rmsnorm_unit_scale_gives_unit_rms() {
        prop_check("rmsnorm rms≈1", 10, |rng| {
            let mut x = randn(rng, &[4, 16]);
            rmsnorm(&mut x, &[1.0; 16], 1e-6);
            for r in 0..4 {
                let ss: f32 = x.row(r).iter().map(|v| v * v).sum();
                assert!(((ss / 16.0).sqrt() - 1.0).abs() < 1e-3);
            }
        });
    }

    #[test]
    fn layernorm_zero_mean_unit_var() {
        let mut rng = crate::util::rng::Pcg32::seeded(2);
        let mut x = randn(&mut rng, &[3, 32]);
        layernorm(&mut x, 1e-6);
        for r in 0..3 {
            let mean: f32 = x.row(r).iter().sum::<f32>() / 32.0;
            let var: f32 = x.row(r).iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 32.0;
            assert!(mean.abs() < 1e-4);
            assert!((var - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn rope_preserves_norm_and_is_position_dependent() {
        let mut rng = crate::util::rng::Pcg32::seeded(3);
        let x0 = randn(&mut rng, &[2, 8]);
        let mut a = x0.clone();
        rope(&mut a, &[0, 5], 10000.0);
        // Position 0 is the identity rotation.
        assert_eq!(a.row(0), x0.row(0));
        // Norm preserved (rotation).
        let n0: f32 = x0.row(1).iter().map(|v| v * v).sum();
        let n1: f32 = a.row(1).iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() / n0 < 1e-4);
        // Different position → different vector.
        assert!(a.row(1) != x0.row(1));
    }

    #[test]
    fn rope_relative_property() {
        // ⟨rope(q,p1), rope(k,p2)⟩ depends only on p1−p2.
        let mut rng = crate::util::rng::Pcg32::seeded(4);
        let q = randn(&mut rng, &[1, 16]);
        let k = randn(&mut rng, &[1, 16]);
        let dot = |a: &Tensor, b: &Tensor| -> f32 {
            a.data().iter().zip(b.data()).map(|(x, y)| x * y).sum()
        };
        let mut q1 = q.clone();
        let mut k1 = k.clone();
        rope(&mut q1, &[3], 10000.0);
        rope(&mut k1, &[1], 10000.0);
        let mut q2 = q.clone();
        let mut k2 = k.clone();
        rope(&mut q2, &[10], 10000.0);
        rope(&mut k2, &[8], 10000.0);
        assert!((dot(&q1, &k1) - dot(&q2, &k2)).abs() < 1e-3);
    }

    #[test]
    fn gelu_fixed_points() {
        assert!(gelu_scalar(0.0).abs() < 1e-7);
        assert!((gelu_scalar(10.0) - 10.0).abs() < 1e-4);
        assert!(gelu_scalar(-10.0).abs() < 1e-4);
    }

    #[test]
    fn modulate_identity_at_zero() {
        let mut rng = crate::util::rng::Pcg32::seeded(5);
        let x0 = randn(&mut rng, &[2, 4]);
        let mut x = x0.clone();
        modulate(&mut x, &[0.0; 4], &[0.0; 4]);
        assert_eq!(x, x0);
        modulate(&mut x, &[1.0; 4], &[1.0; 4]);
        for (a, b) in x.data().iter().zip(x0.data()) {
            assert!((a - (2.0 * b + 1.0)).abs() < 1e-6);
        }
    }

    #[test]
    fn softmax_rows_normalized() {
        let mut rng = crate::util::rng::Pcg32::seeded(6);
        let mut x = randn(&mut rng, &[5, 9]);
        softmax_rows(&mut x);
        for r in 0..5 {
            let s: f32 = x.row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(x.row(r).iter().all(|&v| v >= 0.0));
        }
    }

    #[test]
    fn gated_add_zero_gate_is_noop() {
        let mut rng = crate::util::rng::Pcg32::seeded(7);
        let x0 = randn(&mut rng, &[2, 4]);
        let y = randn(&mut rng, &[2, 4]);
        let mut x = x0.clone();
        gated_add(&mut x, &[0.0; 4], &y);
        assert_eq!(x, x0);
    }
}
