//! **SIMD microkernels** — the explicit vector layer under every GEMM /
//! attention inner loop (PR 6 tentpole).
//!
//! The seed kernels relied on LLVM auto-vectorizing an axpy loop; this
//! module makes the lane structure explicit. Each primitive exists in two
//! flavors selected by an [`Isa`] value threaded through the kernel entry
//! points:
//!
//! * [`Isa::Scalar`] — portable loops that replicate the seed kernels'
//!   float sequences **exactly** (same association order, no FMA). This is
//!   the property-test oracle: every pool/batched/plan bitwise-equivalence
//!   invariant in `rust/tests/` is stated against it.
//! * [`Isa::Simd`] — `core::arch` vector paths behind runtime feature
//!   detection: AVX2+FMA on `x86_64` (via `is_x86_feature_detected!`),
//!   NEON on `aarch64` (baseline feature), scalar elsewhere. FMA and
//!   lane-wise horizontal sums change the reduction order, so Simd results
//!   are *tolerance*-close, not bitwise-equal, to Scalar (bound documented
//!   in `rust/tests/simd_tune.rs`).
//!
//! A [`Isa::Simd`] request on hardware without the detected features
//! silently degrades to the scalar loops — constructing the enum is never
//! unsafe; the `unsafe` target-feature calls are confined behind the
//! runtime check in this module.
//!
//! The primitives mirror the exact shapes the kernels need:
//! [`axpy4`]/[`axpy1`] are the `matmul_into` register-blocked update,
//! [`axpy2`]/[`axpy1`] the attention `P·V` update, [`dot`] the
//! `matmul_nt_into` inner product and [`dot8`] the attention `QKᵀ`
//! 8-lane-accumulator inner product (two distinct scalar flavors because
//! the seed kernels used two distinct float sequences).

#![warn(missing_docs)]

/// Accumulator lane width of the scalar `dot8` flavor and the unit the
/// GEMM-Q panel shim pads row lengths to (see
/// [`gemm_q`](crate::kernels::gemm_q)); matches one AVX2 `f32x8` register.
pub const LANES: usize = 8;

/// Which microkernel flavor a kernel call runs with.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// Portable scalar loops — bit-for-bit the seed kernels' float
    /// sequences; the oracle every SIMD path is property-tested against.
    Scalar,
    /// Runtime-detected vector path: AVX2+FMA on `x86_64`, NEON on
    /// `aarch64`; degrades to [`Isa::Scalar`] loops when the features are
    /// absent.
    Simd,
}

/// Whether a vector path exists on this machine (`x86_64`: AVX2 and FMA
/// detected at runtime; `aarch64`: always — NEON is a baseline feature;
/// other targets: never). Detection runs once and is cached.
pub fn simd_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        static AVAIL: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
        *AVAIL.get_or_init(|| {
            is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
        })
    }
    #[cfg(target_arch = "aarch64")]
    {
        true
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        false
    }
}

/// Process-wide default ISA, resolved once from the **`FO_SIMD`**
/// environment variable: `0`/`scalar`/`off` forces [`Isa::Scalar`];
/// anything else (including unset) selects [`Isa::Simd`] when
/// [`simd_available`], else [`Isa::Scalar`]. Kernel entry points without
/// an explicit `_isa` suffix resolve through this (possibly refined by the
/// [`tune`](crate::kernels::tune) table), so one process always picks one
/// deterministic flavor — which is what keeps the pool/batched bitwise
/// invariants intact.
pub fn active() -> Isa {
    static ACTIVE: std::sync::OnceLock<Isa> = std::sync::OnceLock::new();
    *ACTIVE.get_or_init(|| match std::env::var("FO_SIMD").as_deref() {
        Ok("0") | Ok("scalar") | Ok("off") => Isa::Scalar,
        _ => {
            if simd_available() {
                Isa::Simd
            } else {
                Isa::Scalar
            }
        }
    })
}

/// Short name of the path `isa` actually executes on this machine
/// (`"scalar"`, `"avx2"` or `"neon"`) — recorded in `BENCH_*.json`
/// headers and the tune-cache file.
pub fn isa_name(isa: Isa) -> &'static str {
    match isa {
        Isa::Scalar => "scalar",
        Isa::Simd => {
            if cfg!(target_arch = "aarch64") {
                "neon"
            } else if simd_available() {
                "avx2"
            } else {
                "scalar"
            }
        }
    }
}

/// Parse an ISA name as written by [`isa_name`] / accepted by `FO_SIMD`.
pub fn parse_isa(s: &str) -> Option<Isa> {
    match s {
        "scalar" => Some(Isa::Scalar),
        "simd" | "avx2" | "neon" => Some(Isa::Simd),
        _ => None,
    }
}

// ---- public dispatched primitives ----

/// `c[j] += a * b[j]` — the seed `matmul_into` remainder / attention `P·V`
/// single-column update.
#[inline]
pub fn axpy1(isa: Isa, c: &mut [f32], a: f32, b: &[f32]) {
    match isa {
        Isa::Scalar => scalar::axpy1(c, a, b),
        Isa::Simd => vec::axpy1(c, a, b),
    }
}

/// `c[j] += a0 * b0[j] + a1 * b1[j]` — the attention `P·V` two-column
/// update.
#[inline]
pub fn axpy2(isa: Isa, c: &mut [f32], a0: f32, b0: &[f32], a1: f32, b1: &[f32]) {
    match isa {
        Isa::Scalar => scalar::axpy2(c, a0, b0, a1, b1),
        Isa::Simd => vec::axpy2(c, a0, b0, a1, b1),
    }
}

/// `c[j] += a[0]·b0[j] + a[1]·b1[j] + a[2]·b2[j] + a[3]·b3[j]` — the seed
/// `matmul_into` register-blocked (p-unrolled-by-4) update.
#[inline]
pub fn axpy4(isa: Isa, c: &mut [f32], a: [f32; 4], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) {
    match isa {
        Isa::Scalar => scalar::axpy4(c, a, b0, b1, b2, b3),
        Isa::Simd => vec::axpy4(c, a, b0, b1, b2, b3),
    }
}

/// `Σ a[p]·b[p]` with the seed `matmul_nt_into` float sequence (plain
/// left-to-right accumulation) under [`Isa::Scalar`].
#[inline]
pub fn dot(isa: Isa, a: &[f32], b: &[f32]) -> f32 {
    match isa {
        Isa::Scalar => scalar::dot(a, b),
        Isa::Simd => vec::dot(a, b),
    }
}

/// `Σ a[p]·b[p]` with the seed attention `QKᵀ` float sequence (8 lane
/// accumulators summed left-to-right, then a scalar tail) under
/// [`Isa::Scalar`]. Under [`Isa::Simd`] this coincides with [`dot`].
#[inline]
pub fn dot8(isa: Isa, a: &[f32], b: &[f32]) -> f32 {
    match isa {
        Isa::Scalar => scalar::dot8(a, b),
        Isa::Simd => vec::dot(a, b),
    }
}

// ---- scalar oracle (the seed kernels' exact float sequences) ----

mod scalar {
    use super::LANES;

    #[inline]
    pub fn axpy1(c: &mut [f32], a: f32, b: &[f32]) {
        for (cv, &bv) in c.iter_mut().zip(b) {
            *cv += a * bv;
        }
    }

    #[inline]
    pub fn axpy2(c: &mut [f32], a0: f32, b0: &[f32], a1: f32, b1: &[f32]) {
        for ((cv, &x), &y) in c.iter_mut().zip(b0).zip(b1) {
            *cv += a0 * x + a1 * y;
        }
    }

    #[inline]
    pub fn axpy4(c: &mut [f32], a: [f32; 4], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) {
        let n = c.len();
        for j in 0..n {
            c[j] += a[0] * b0[j] + a[1] * b1[j] + a[2] * b2[j] + a[3] * b3[j];
        }
    }

    #[inline]
    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        let mut s = 0.0f32;
        for (&x, &y) in a.iter().zip(b) {
            s += x * y;
        }
        s
    }

    #[inline]
    pub fn dot8(a: &[f32], b: &[f32]) -> f32 {
        let mut acc = [0.0f32; LANES];
        let ac = a.chunks_exact(LANES);
        let bc = b.chunks_exact(LANES);
        let (ar, br) = (ac.remainder(), bc.remainder());
        for (xa, ya) in ac.zip(bc) {
            for l in 0..LANES {
                acc[l] += xa[l] * ya[l];
            }
        }
        let mut s: f32 = acc.iter().sum();
        for (&x, &y) in ar.iter().zip(br) {
            s += x * y;
        }
        s
    }
}

// ---- per-arch vector dispatch ----

#[cfg(target_arch = "x86_64")]
mod vec {
    use super::{avx2, scalar, simd_available};

    #[inline]
    pub fn axpy1(c: &mut [f32], a: f32, b: &[f32]) {
        if simd_available() {
            // SAFETY: avx2+fma verified present by `simd_available`.
            unsafe { avx2::axpy1(c, a, b) }
        } else {
            scalar::axpy1(c, a, b)
        }
    }

    #[inline]
    pub fn axpy2(c: &mut [f32], a0: f32, b0: &[f32], a1: f32, b1: &[f32]) {
        if simd_available() {
            // SAFETY: avx2+fma verified present by `simd_available`.
            unsafe { avx2::axpy2(c, a0, b0, a1, b1) }
        } else {
            scalar::axpy2(c, a0, b0, a1, b1)
        }
    }

    #[inline]
    pub fn axpy4(c: &mut [f32], a: [f32; 4], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) {
        if simd_available() {
            // SAFETY: avx2+fma verified present by `simd_available`.
            unsafe { avx2::axpy4(c, a, b0, b1, b2, b3) }
        } else {
            scalar::axpy4(c, a, b0, b1, b2, b3)
        }
    }

    #[inline]
    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        if simd_available() {
            // SAFETY: avx2+fma verified present by `simd_available`.
            unsafe { avx2::dot(a, b) }
        } else {
            scalar::dot(a, b)
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod vec {
    pub use super::neon::{axpy1, axpy2, axpy4, dot};
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
mod vec {
    pub use super::scalar::{axpy1, axpy2, axpy4, dot};
}

// ---- AVX2+FMA implementations (x86_64) ----

#[cfg(target_arch = "x86_64")]
mod avx2 {
    //! All functions require AVX2 and FMA; callers must verify via
    //! `simd_available()` before dispatching here.

    use core::arch::x86_64::*;

    /// Horizontal sum of one `f32x8` register.
    #[inline]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn hsum(v: __m256) -> f32 {
        let lo = _mm256_castps256_ps128(v);
        let hi = _mm256_extractf128_ps::<1>(v);
        let s = _mm_add_ps(lo, hi);
        let shuf = _mm_movehdup_ps(s);
        let sums = _mm_add_ps(s, shuf);
        let hi2 = _mm_movehl_ps(shuf, sums);
        _mm_cvtss_f32(_mm_add_ss(sums, hi2))
    }

    /// # Safety
    /// Requires AVX2+FMA.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn axpy1(c: &mut [f32], a: f32, b: &[f32]) {
        let n = c.len().min(b.len());
        let av = _mm256_set1_ps(a);
        let mut j = 0;
        while j + 8 <= n {
            let cv = _mm256_loadu_ps(c.as_ptr().add(j));
            let bv = _mm256_loadu_ps(b.as_ptr().add(j));
            _mm256_storeu_ps(c.as_mut_ptr().add(j), _mm256_fmadd_ps(av, bv, cv));
            j += 8;
        }
        while j < n {
            c[j] += a * b[j];
            j += 1;
        }
    }

    /// # Safety
    /// Requires AVX2+FMA.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn axpy2(c: &mut [f32], a0: f32, b0: &[f32], a1: f32, b1: &[f32]) {
        let n = c.len().min(b0.len()).min(b1.len());
        let a0v = _mm256_set1_ps(a0);
        let a1v = _mm256_set1_ps(a1);
        let mut j = 0;
        while j + 8 <= n {
            let mut cv = _mm256_loadu_ps(c.as_ptr().add(j));
            cv = _mm256_fmadd_ps(a0v, _mm256_loadu_ps(b0.as_ptr().add(j)), cv);
            cv = _mm256_fmadd_ps(a1v, _mm256_loadu_ps(b1.as_ptr().add(j)), cv);
            _mm256_storeu_ps(c.as_mut_ptr().add(j), cv);
            j += 8;
        }
        while j < n {
            c[j] += a0 * b0[j] + a1 * b1[j];
            j += 1;
        }
    }

    /// # Safety
    /// Requires AVX2+FMA.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn axpy4(
        c: &mut [f32],
        a: [f32; 4],
        b0: &[f32],
        b1: &[f32],
        b2: &[f32],
        b3: &[f32],
    ) {
        let n = c.len();
        let a0v = _mm256_set1_ps(a[0]);
        let a1v = _mm256_set1_ps(a[1]);
        let a2v = _mm256_set1_ps(a[2]);
        let a3v = _mm256_set1_ps(a[3]);
        let mut j = 0;
        while j + 8 <= n {
            let mut cv = _mm256_loadu_ps(c.as_ptr().add(j));
            cv = _mm256_fmadd_ps(a0v, _mm256_loadu_ps(b0.as_ptr().add(j)), cv);
            cv = _mm256_fmadd_ps(a1v, _mm256_loadu_ps(b1.as_ptr().add(j)), cv);
            cv = _mm256_fmadd_ps(a2v, _mm256_loadu_ps(b2.as_ptr().add(j)), cv);
            cv = _mm256_fmadd_ps(a3v, _mm256_loadu_ps(b3.as_ptr().add(j)), cv);
            _mm256_storeu_ps(c.as_mut_ptr().add(j), cv);
            j += 8;
        }
        while j < n {
            c[j] += a[0] * b0[j] + a[1] * b1[j] + a[2] * b2[j] + a[3] * b3[j];
            j += 1;
        }
    }

    /// # Safety
    /// Requires AVX2+FMA.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let k = a.len().min(b.len());
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut p = 0;
        while p + 16 <= k {
            acc0 = _mm256_fmadd_ps(
                _mm256_loadu_ps(a.as_ptr().add(p)),
                _mm256_loadu_ps(b.as_ptr().add(p)),
                acc0,
            );
            acc1 = _mm256_fmadd_ps(
                _mm256_loadu_ps(a.as_ptr().add(p + 8)),
                _mm256_loadu_ps(b.as_ptr().add(p + 8)),
                acc1,
            );
            p += 16;
        }
        if p + 8 <= k {
            acc0 = _mm256_fmadd_ps(
                _mm256_loadu_ps(a.as_ptr().add(p)),
                _mm256_loadu_ps(b.as_ptr().add(p)),
                acc0,
            );
            p += 8;
        }
        let mut s = hsum(_mm256_add_ps(acc0, acc1));
        while p < k {
            s += a[p] * b[p];
            p += 1;
        }
        s
    }
}

// ---- NEON implementations (aarch64; baseline feature, safe wrappers) ----

#[cfg(target_arch = "aarch64")]
mod neon {
    use core::arch::aarch64::*;

    #[inline]
    pub fn axpy1(c: &mut [f32], a: f32, b: &[f32]) {
        let n = c.len().min(b.len());
        // SAFETY: NEON is a baseline aarch64 feature; loads/stores stay in
        // bounds (j + 4 <= n).
        unsafe {
            let av = vdupq_n_f32(a);
            let mut j = 0;
            while j + 4 <= n {
                let cv = vld1q_f32(c.as_ptr().add(j));
                let bv = vld1q_f32(b.as_ptr().add(j));
                vst1q_f32(c.as_mut_ptr().add(j), vfmaq_f32(cv, av, bv));
                j += 4;
            }
            while j < n {
                c[j] += a * b[j];
                j += 1;
            }
        }
    }

    #[inline]
    pub fn axpy2(c: &mut [f32], a0: f32, b0: &[f32], a1: f32, b1: &[f32]) {
        let n = c.len().min(b0.len()).min(b1.len());
        // SAFETY: as in `axpy1`.
        unsafe {
            let a0v = vdupq_n_f32(a0);
            let a1v = vdupq_n_f32(a1);
            let mut j = 0;
            while j + 4 <= n {
                let mut cv = vld1q_f32(c.as_ptr().add(j));
                cv = vfmaq_f32(cv, a0v, vld1q_f32(b0.as_ptr().add(j)));
                cv = vfmaq_f32(cv, a1v, vld1q_f32(b1.as_ptr().add(j)));
                vst1q_f32(c.as_mut_ptr().add(j), cv);
                j += 4;
            }
            while j < n {
                c[j] += a0 * b0[j] + a1 * b1[j];
                j += 1;
            }
        }
    }

    #[inline]
    pub fn axpy4(c: &mut [f32], a: [f32; 4], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) {
        let n = c.len();
        // SAFETY: as in `axpy1`.
        unsafe {
            let a0v = vdupq_n_f32(a[0]);
            let a1v = vdupq_n_f32(a[1]);
            let a2v = vdupq_n_f32(a[2]);
            let a3v = vdupq_n_f32(a[3]);
            let mut j = 0;
            while j + 4 <= n {
                let mut cv = vld1q_f32(c.as_ptr().add(j));
                cv = vfmaq_f32(cv, a0v, vld1q_f32(b0.as_ptr().add(j)));
                cv = vfmaq_f32(cv, a1v, vld1q_f32(b1.as_ptr().add(j)));
                cv = vfmaq_f32(cv, a2v, vld1q_f32(b2.as_ptr().add(j)));
                cv = vfmaq_f32(cv, a3v, vld1q_f32(b3.as_ptr().add(j)));
                vst1q_f32(c.as_mut_ptr().add(j), cv);
                j += 4;
            }
            while j < n {
                c[j] += a[0] * b0[j] + a[1] * b1[j] + a[2] * b2[j] + a[3] * b3[j];
                j += 1;
            }
        }
    }

    #[inline]
    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        let k = a.len().min(b.len());
        // SAFETY: as in `axpy1`.
        unsafe {
            let mut acc = vdupq_n_f32(0.0);
            let mut p = 0;
            while p + 4 <= k {
                acc = vfmaq_f32(
                    acc,
                    vld1q_f32(a.as_ptr().add(p)),
                    vld1q_f32(b.as_ptr().add(p)),
                );
                p += 4;
            }
            let mut s = vaddvq_f32(acc);
            while p < k {
                s += a[p] * b[p];
                p += 1;
            }
            s
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn randv(rng: &mut Pcg32, n: usize) -> Vec<f32> {
        rng.normal_vec(n)
    }

    fn close(a: f32, b: f32, atol: f32, rtol: f32) -> bool {
        (a - b).abs() <= atol + rtol * b.abs()
    }

    #[test]
    fn scalar_dot_flavors_match_seed_sequences() {
        let mut rng = Pcg32::seeded(0x51f0);
        for k in [1usize, 7, 8, 9, 16, 31, 64] {
            let a = randv(&mut rng, k);
            let b = randv(&mut rng, k);
            // dot: plain left-to-right accumulation.
            let mut want = 0.0f32;
            for p in 0..k {
                want += a[p] * b[p];
            }
            assert_eq!(dot(Isa::Scalar, &a, &b), want, "dot k={k}");
            // dot8: 8-lane accumulator then tail (the attention sequence).
            let mut acc = [0.0f32; 8];
            let mut p = 0;
            while p + 8 <= k {
                for l in 0..8 {
                    acc[l] += a[p + l] * b[p + l];
                }
                p += 8;
            }
            let mut want8: f32 = acc.iter().sum();
            while p < k {
                want8 += a[p] * b[p];
                p += 1;
            }
            assert_eq!(dot8(Isa::Scalar, &a, &b), want8, "dot8 k={k}");
        }
    }

    #[test]
    fn simd_ops_are_tolerance_close_to_scalar() {
        // FMA + lane-order sums change the reduction order, so the SIMD
        // path is tolerance-close, not bitwise: for k ≤ 512 N(0,1) data,
        // 1e-4 absolute + 1e-4 relative comfortably bounds the drift.
        let mut rng = Pcg32::seeded(0x51f1);
        for n in [1usize, 3, 5, 7, 8, 9, 15, 16, 17, 33, 64, 100] {
            let b0 = randv(&mut rng, n);
            let b1 = randv(&mut rng, n);
            let b2 = randv(&mut rng, n);
            let b3 = randv(&mut rng, n);
            let base = randv(&mut rng, n);
            let coef = [0.3f32, -1.2, 0.7, 2.1];

            let mut cs = base.clone();
            let mut cv = base.clone();
            axpy1(Isa::Scalar, &mut cs, 0.5, &b0);
            axpy1(Isa::Simd, &mut cv, 0.5, &b0);
            for j in 0..n {
                assert!(close(cv[j], cs[j], 1e-4, 1e-4), "axpy1 n={n} j={j}");
            }

            let mut cs = base.clone();
            let mut cv = base.clone();
            axpy2(Isa::Scalar, &mut cs, 0.5, &b0, -0.25, &b1);
            axpy2(Isa::Simd, &mut cv, 0.5, &b0, -0.25, &b1);
            for j in 0..n {
                assert!(close(cv[j], cs[j], 1e-4, 1e-4), "axpy2 n={n} j={j}");
            }

            let mut cs = base.clone();
            let mut cv = base.clone();
            axpy4(Isa::Scalar, &mut cs, coef, &b0, &b1, &b2, &b3);
            axpy4(Isa::Simd, &mut cv, coef, &b0, &b1, &b2, &b3);
            for j in 0..n {
                assert!(close(cv[j], cs[j], 1e-4, 1e-4), "axpy4 n={n} j={j}");
            }

            let ds = dot(Isa::Scalar, &b0, &b1);
            let dv = dot(Isa::Simd, &b0, &b1);
            assert!(close(dv, ds, 1e-3, 1e-4), "dot n={n}: {dv} vs {ds}");
            let d8v = dot8(Isa::Simd, &b0, &b1);
            assert!(close(d8v, ds, 1e-3, 1e-4), "dot8 n={n}: {d8v} vs {ds}");
        }
    }

    #[test]
    fn active_is_deterministic_and_named() {
        let a = active();
        assert_eq!(a, active(), "active() must be stable for the process");
        let name = isa_name(a);
        assert!(["scalar", "avx2", "neon"].contains(&name), "bad name {name}");
        assert_eq!(isa_name(Isa::Scalar), "scalar");
        assert_eq!(parse_isa("scalar"), Some(Isa::Scalar));
        let simd_name = isa_name(Isa::Simd);
        let parsed = parse_isa(simd_name).unwrap();
        if simd_name == "scalar" {
            assert_eq!(parsed, Isa::Scalar);
        } else {
            assert_eq!(parsed, Isa::Simd);
        }
    }
}
