//! Native blocked kernels — the performance twin of the paper's CUDA
//! kernels (see DESIGN.md §"Dual-engine design").
//!
//! One iteration of the outer Q-block loop plays the role of one CTA on the
//! A100. The sparse kernels consume compiled plans ([`crate::plan`]): the
//! symbol decode (`F`/`J`) ran once at plan-compile time, so the kernel
//! loops walk only live block indices — no bit math in the hot path. Work
//! that the symbols mark as skipped is *actually not executed*, so
//! wall-clock speedups here reproduce the paper's curves. Each kernel also
//! keeps its seed symbol-decoding variant (`*_symbols`) as the
//! plan-equivalence reference and §4.3 decode-ablation subject.
//!
//! Submodules:
//! * [`gemm`] — tiled dense GEMM primitives (the substrate for everything),
//! * [`attention`] — dense FlashAttention and the FlashOmni sparse
//!   attention kernel (Algorithm 1),
//! * [`gemm_q`] — sparse query projection (spatial-axis skipping, Obs. 2),
//! * [`gemm_o`] — sparse output projection with the cached bias `B_c`
//!   (reduction-axis skipping, Obs. 3, two-stage),
//! * [`elementwise`] — RMSNorm, RoPE, GELU, adaLN modulation, softmax,
//! * [`flops`] — operation counting and the paper's theoretical-speedup
//!   formulas (Eq. 5),
//! * [`microkernel`] — the explicit SIMD layer (scalar oracle + AVX2/NEON
//!   paths behind runtime detection) every inner loop above runs through,
//! * [`tune`] — the per-geometry autotuner resolving (ISA, chunking)
//!   configurations at first use (`FO_TUNE`/`FO_TUNE_CACHE`).

pub mod attention;
pub mod elementwise;
pub mod flops;
pub mod gemm;
pub mod gemm_o;
pub mod gemm_q;
pub mod microkernel;
pub mod tune;
