//! **FlashOmni GEMM-Q** — sparse query projection (§3.5, Observation 2).
//!
//! Since RMSNorm and RoPE act token-wise, a Q block that the caching
//! symbols mark as cached (`F(S_c, i) = 0`) never feeds the attention
//! computation, so its slice of the query projection `Q_i^h = X_i W^h` can
//! be skipped entirely. The CTA grid maps to `(row block × head)` tiles.
//!
//! The primary kernel ([`gemm_q`]) consumes a compiled
//! [`SparsePlan`](crate::plan::SparsePlan) and iterates only the live tile
//! indices — the symbol decode happened once at plan compile time.
//! [`gemm_q_pool`] is the same kernel with the `(head × live-block)` tile
//! loop chunked over a persistent [`ExecPool`]; tiles write disjoint
//! `(row-block × head-column)` rectangles, so its output is
//! **bitwise-identical** to [`gemm_q`] (property-tested in
//! `rust/tests/exec_runtime.rs`). The seed symbol-decoding variant is
//! retained as [`gemm_q_symbols`] for the plan-equivalence property tests.
//!
//! Under the SIMD microkernel flavor the per-head weight panels are
//! gathered with their rows zero-padded to the vector lane width
//! ([`microkernel::LANES`]), so the tile GEMM's column loop never enters a
//! scalar remainder; the pad columns are dropped on copy-out. The scalar
//! flavor gathers unpadded panels and is byte-identical to the seed kernel.

use crate::exec::{ExecPool, SendPtr};
use crate::kernels::gemm::matmul_into_isa;
use crate::kernels::microkernel::{self, Isa};
use crate::kernels::tune::{self, Family, KernelConfig};
use crate::plan::SparsePlan;
pub use crate::plan::GemmStats;
use crate::symbols::LayerSymbols;
use crate::tensor::Tensor;

/// Dense projection baseline: `Y = X · W`.
pub fn gemm_dense(x: &Tensor, w: &Tensor) -> Tensor {
    crate::kernels::gemm::matmul(x, w)
}

/// [`gemm_dense`] with an explicit microkernel flavor (benches pin
/// scalar/SIMD baseline rows).
pub fn gemm_dense_isa(isa: Isa, x: &Tensor, w: &Tensor) -> Tensor {
    let (m, k) = (x.rows(), x.cols());
    let n = w.cols();
    assert_eq!(w.rows(), k, "gemm_dense inner dims: {} vs {}", k, w.rows());
    let mut y = Tensor::zeros(&[m, n]);
    matmul_into_isa(isa, x.data(), w.data(), y.data_mut(), m, k, n);
    y
}

/// Resolve the kernel configuration for a GEMM-Q call from the tuning
/// table (falling back to the heuristic). Keyed on the tile geometry
/// `(block_q, d_in, d_h)`; the ISA component is threads-independent, so
/// the serial, pool, batched, and symbols variants all resolve the same
/// flavor and their bitwise-equivalence tests survive tuning.
fn resolve_cfg(block_q: usize, d_in: usize, d_h: usize, threads: usize) -> KernelConfig {
    tune::config_for(Family::GemmQ, [block_q, d_in, d_h], threads)
}

/// Panel row stride for a flavor: the SIMD flavor pads head panels to the
/// vector lane width so the column loop never enters a scalar remainder.
#[inline]
fn panel_stride(isa: Isa, d_h: usize) -> usize {
    match isa {
        Isa::Scalar => d_h,
        Isa::Simd => d_h.next_multiple_of(microkernel::LANES),
    }
}

/// Copy head `h`'s columns of `w` (`[d_in × heads·d_h]`) into a contiguous
/// `[d_in × d_pad]` panel; columns `d_h..d_pad` are zero padding.
fn gather_head_panel(w: &Tensor, h: usize, d_h: usize, d_pad: usize) -> Vec<f32> {
    let d_in = w.rows();
    let d_out = w.cols();
    let mut w_h = vec![0.0f32; d_in * d_pad];
    for r in 0..d_in {
        w_h[r * d_pad..r * d_pad + d_h]
            .copy_from_slice(&w.data()[r * d_out + h * d_h..r * d_out + (h + 1) * d_h]);
    }
    w_h
}

/// Compute one `(block, head)` tile of the projection into a local
/// `[bq × d_pad]` buffer (shared by the serial and pool kernels so both run
/// the identical float sequence). Columns `d_h..d_pad` are lane padding
/// and stay zero; callers copy out the first `d_h` of each row.
#[allow(clippy::too_many_arguments)]
#[inline]
fn compute_q_tile(
    isa: Isa,
    x: &Tensor,
    w_h: &[f32],
    h: usize,
    d_h: usize,
    d_pad: usize,
    lo: usize,
    hi: usize,
    bias: Option<&[f32]>,
) -> Vec<f32> {
    let d_in = x.cols();
    let bq = hi - lo;
    let mut tile = vec![0.0f32; bq * d_pad];
    matmul_into_isa(isa, &x.data()[lo * d_in..hi * d_in], w_h, &mut tile, bq, d_in, d_pad);
    if let Some(b) = bias {
        for row in tile.chunks_exact_mut(d_pad) {
            for (c, v) in row.iter_mut().take(d_h).enumerate() {
                *v += b[h * d_h + c];
            }
        }
    }
    tile
}

/// Project one `(block, head)` tile of `x` into `y`.
#[allow(clippy::too_many_arguments)]
#[inline]
fn project_q_tile(
    isa: Isa,
    x: &Tensor,
    w_h: &[f32],
    y: &mut Tensor,
    h: usize,
    d_h: usize,
    d_pad: usize,
    d_out: usize,
    lo: usize,
    hi: usize,
    bias: Option<&[f32]>,
) {
    let tile = compute_q_tile(isa, x, w_h, h, d_h, d_pad, lo, hi, bias);
    for (r, row) in tile.chunks_exact(d_pad).enumerate() {
        y.data_mut()[(lo + r) * d_out + h * d_h..(lo + r) * d_out + (h + 1) * d_h]
            .copy_from_slice(&row[..d_h]);
    }
}

/// Sparse query projection driven by a compiled plan.
///
/// * `x` — `[N × d_in]` input activations,
/// * `w` — `[d_in × H·d_h]` projection weight (heads concatenated on the
///   output axis),
/// * `plan` — per-head live Q-block lists; tile `(block i, head h)` is
///   computed iff `i ∈ plan.heads[h].live_q`.
///
/// Rows of skipped tiles are left zero — the attention kernel never reads
/// them (their CTA takes the cache-then-reuse path). `bias` (`[H·d_h]`),
/// when given, is added to computed tiles only. Runs the tuned/default
/// microkernel flavor; [`gemm_q_isa`] pins one explicitly.
pub fn gemm_q(
    x: &Tensor,
    w: &Tensor,
    plan: &SparsePlan,
    bias: Option<&[f32]>,
) -> (Tensor, GemmStats) {
    let heads = plan.heads.len().max(1);
    let isa = resolve_cfg(plan.block_q, x.cols(), w.cols() / heads, 1).isa;
    gemm_q_isa(isa, x, w, plan, bias)
}

/// [`gemm_q`] with an explicit microkernel flavor ([`Isa::Scalar`]
/// reproduces the seed float sequence bit-for-bit).
pub fn gemm_q_isa(
    isa: Isa,
    x: &Tensor,
    w: &Tensor,
    plan: &SparsePlan,
    bias: Option<&[f32]>,
) -> (Tensor, GemmStats) {
    let block_q = plan.block_q;
    let n = x.rows();
    let d_in = x.cols();
    let heads = plan.heads.len();
    assert!(heads > 0);
    let d_out = w.cols();
    assert_eq!(w.rows(), d_in);
    assert_eq!(d_out % heads, 0, "W output dim must split across heads");
    let d_h = d_out / heads;
    let d_pad = panel_stride(isa, d_h);
    assert_eq!(plan.t_q, n.div_ceil(block_q), "plan Q-block geometry mismatch");
    let mut y = Tensor::zeros(&[n, d_out]);

    for (h, hp) in plan.heads.iter().enumerate() {
        if hp.live_q.is_empty() {
            continue; // whole head cached: skip even the panel gather
        }
        let w_h = gather_head_panel(w, h, d_h, d_pad);
        for &bi in &hp.live_q {
            let lo = bi as usize * block_q;
            let hi = (lo + block_q).min(n);
            project_q_tile(isa, x, &w_h, &mut y, h, d_h, d_pad, d_out, lo, hi, bias);
        }
    }
    (y, plan.gemm_stats())
}

/// [`gemm_q`] with the `(head × live-block)` tile loop run on a persistent
/// worker pool: live tiles are flattened into one work list, chunked, and
/// dispatched dynamically. Each tile writes a disjoint
/// `(row-block × head-column)` rectangle of `y`, and every element is
/// produced by exactly one tile via the same `compute_q_tile` float
/// sequence — so the output is bitwise-identical to the serial kernel.
/// Resolves the tuned/default configuration; [`gemm_q_pool_with`] pins one
/// explicitly.
pub fn gemm_q_pool(
    x: &Tensor,
    w: &Tensor,
    plan: &SparsePlan,
    bias: Option<&[f32]>,
    pool: &ExecPool,
) -> (Tensor, GemmStats) {
    gemm_q_pool_with(x, w, plan, bias, pool, None)
}

/// [`gemm_q_pool`] with an explicit kernel configuration (`None` resolves
/// the tuned/default one). The configuration's chunking only regroups
/// tiles into tasks — any configuration yields bitwise-identical output
/// (property-tested in `rust/tests/simd_tune.rs`).
pub fn gemm_q_pool_with(
    x: &Tensor,
    w: &Tensor,
    plan: &SparsePlan,
    bias: Option<&[f32]>,
    pool: &ExecPool,
    cfg: Option<KernelConfig>,
) -> (Tensor, GemmStats) {
    let block_q = plan.block_q;
    let n = x.rows();
    let d_in = x.cols();
    let heads = plan.heads.len();
    assert!(heads > 0);
    let d_out = w.cols();
    assert_eq!(w.rows(), d_in);
    assert_eq!(d_out % heads, 0, "W output dim must split across heads");
    let d_h = d_out / heads;
    let cfg = cfg.unwrap_or_else(|| resolve_cfg(block_q, d_in, d_h, pool.size()));
    let d_pad = panel_stride(cfg.isa, d_h);
    assert_eq!(plan.t_q, n.div_ceil(block_q), "plan Q-block geometry mismatch");
    let mut y = Tensor::zeros(&[n, d_out]);

    // Gather the weight panels up front (once per head, as in the serial
    // kernel), then flatten the live tiles into `(head, block)` work items.
    let panels: Vec<Vec<f32>> = (0..heads)
        .map(|h| {
            if plan.heads[h].live_q.is_empty() {
                Vec::new()
            } else {
                gather_head_panel(w, h, d_h, d_pad)
            }
        })
        .collect();
    let tiles = plan.live_tiles();
    // Chunk so each task is a slab of tiles (amortizes dispatch overhead)
    // while still leaving tasks per worker for load balancing; precedence
    // is `FO_CHUNK` override > tuned tasks-per-thread > heuristic (see
    // `KernelConfig::chunk`).
    let chunk = cfg.chunk(tiles.len(), pool.size());
    let n_tasks = tiles.len().div_ceil(chunk);
    {
        let yp = SendPtr(y.data_mut().as_mut_ptr());
        pool.parallel_for(n_tasks, |t| {
            for &(h, bi) in &tiles[t * chunk..((t + 1) * chunk).min(tiles.len())] {
                let (h, bi) = (h as usize, bi as usize);
                let lo = bi * block_q;
                let hi = (lo + block_q).min(n);
                let tile = compute_q_tile(cfg.isa, x, &panels[h], h, d_h, d_pad, lo, hi, bias);
                for (r, row) in tile.chunks_exact(d_pad).enumerate() {
                    let off = (lo + r) * d_out + h * d_h;
                    // SAFETY: tiles are unique (head, block) pairs, so the
                    // `(rows lo..hi) × (cols h·d_h..)` rectangles written
                    // here are disjoint across tasks; `y` outlives the
                    // parallel section (ExecPool joins before returning).
                    unsafe {
                        std::ptr::copy_nonoverlapping(row.as_ptr(), yp.0.add(off), d_h);
                    }
                }
            }
        });
    }
    (y, plan.gemm_stats())
}

/// Batched [`gemm_q_pool`]: one **shared plan** drives the projections of
/// a whole batch of request activations (batched Dispatch steps whose
/// symbols coincide — the serving layer's cross-request plan sharing).
///
/// The live `(head, block)` tile list is flattened and the per-head weight
/// panels are gathered **once for the batch** — the plan's index lists are
/// iterated exactly once, not once per request. Work is dispatched over
/// `batch × tile-chunk` pool lanes; each lane computes one request's slab
/// of tiles via the same `compute_q_tile` float sequence as the serial
/// kernel, so output `r` is **bitwise-identical** to
/// `gemm_q(xs[r], w, plan, bias)` (property-tested below).
///
/// All inputs must share one shape (`[N × d_in]` — the scheduler's
/// geometry bucket guarantees this).
pub fn gemm_q_batched(
    xs: &[&Tensor],
    w: &Tensor,
    plan: &SparsePlan,
    bias: Option<&[f32]>,
    pool: &ExecPool,
) -> Vec<(Tensor, GemmStats)> {
    assert!(!xs.is_empty(), "empty batch");
    let block_q = plan.block_q;
    let n = xs[0].rows();
    let d_in = xs[0].cols();
    for x in xs {
        assert_eq!(x.rows(), n, "batch inputs must share a shape");
        assert_eq!(x.cols(), d_in, "batch inputs must share a shape");
    }
    let heads = plan.heads.len();
    assert!(heads > 0);
    let d_out = w.cols();
    assert_eq!(w.rows(), d_in);
    assert_eq!(d_out % heads, 0, "W output dim must split across heads");
    let d_h = d_out / heads;
    // Same `(block_q, d_in, d_h)` key as the serial kernel, so each
    // request's output stays bitwise-identical to `gemm_q` under tuning.
    let cfg = resolve_cfg(block_q, d_in, d_h, pool.size());
    let d_pad = panel_stride(cfg.isa, d_h);
    assert_eq!(plan.t_q, n.div_ceil(block_q), "plan Q-block geometry mismatch");
    let mut ys: Vec<Tensor> = (0..xs.len()).map(|_| Tensor::zeros(&[n, d_out])).collect();

    // Shared per-batch preparation: head panels + flattened live tiles.
    let panels: Vec<Vec<f32>> = (0..heads)
        .map(|h| {
            if plan.heads[h].live_q.is_empty() {
                Vec::new()
            } else {
                gather_head_panel(w, h, d_h, d_pad)
            }
        })
        .collect();
    let tiles = plan.live_tiles();
    let chunk = cfg.chunk(tiles.len(), pool.size());
    let chunks_per_req = tiles.len().div_ceil(chunk);
    let n_tasks = xs.len() * chunks_per_req;
    {
        let ptrs: Vec<SendPtr<f32>> =
            ys.iter_mut().map(|y| SendPtr(y.data_mut().as_mut_ptr())).collect();
        let ptrs = &ptrs;
        pool.parallel_for(n_tasks, |task| {
            let r = task / chunks_per_req;
            let c = task % chunks_per_req;
            let x = xs[r];
            for &(h, bi) in &tiles[c * chunk..((c + 1) * chunk).min(tiles.len())] {
                let (h, bi) = (h as usize, bi as usize);
                let lo = bi * block_q;
                let hi = (lo + block_q).min(n);
                let tile = compute_q_tile(cfg.isa, x, &panels[h], h, d_h, d_pad, lo, hi, bias);
                for (row_i, row) in tile.chunks_exact(d_pad).enumerate() {
                    let off = (lo + row_i) * d_out + h * d_h;
                    // SAFETY: (request, head, block) triples are unique
                    // across tasks, so the written rectangles are disjoint;
                    // each `ys[r]` outlives the parallel section (ExecPool
                    // joins before returning).
                    unsafe {
                        std::ptr::copy_nonoverlapping(row.as_ptr(), ptrs[r].0.add(off), d_h);
                    }
                }
            }
        });
    }
    ys.into_iter().map(|y| (y, plan.gemm_stats())).collect()
}

/// Ragged batched GEMM-Q: **per-request plans** over one concatenated
/// token buffer with cu-seqlen offsets — the varlen analogue of
/// [`gemm_q_batched`] for mixed-resolution batches.
///
/// * `x_cat` — `[ΣNᵣ × d_in]`, the batch's activations stacked row-wise,
/// * `indptr` — `batch+1` token offsets (`qo_indptr` layout): request `r`
///   owns rows `indptr[r]..indptr[r+1]`,
/// * `plans` — one compiled plan per request; each must satisfy its own
///   geometry (`plans[r].t_q == Nᵣ.div_ceil(block_q)`), but sequence
///   lengths may differ per request.
///
/// All plans must share `block_q` (the engine's block size is
/// batch-constant); the microkernel flavor is resolved from the same
/// `(block_q, d_in, d_h)` key as the serial kernel, and every tile runs the
/// identical `compute_q_tile` float sequence at its request's global row
/// offset — so output `r` is **bitwise-identical** to
/// `gemm_q(x_r, w, plans[r], bias)` (property-tested below, including
/// odd tail blocks clamped at `indptr[r+1]`).
pub fn gemm_q_ragged(
    x_cat: &Tensor,
    indptr: &[usize],
    w: &Tensor,
    plans: &[&SparsePlan],
    bias: Option<&[f32]>,
    pool: &ExecPool,
) -> Vec<(Tensor, GemmStats)> {
    let batch = plans.len();
    assert!(batch > 0, "empty ragged batch");
    assert_eq!(indptr.len(), batch + 1, "indptr must have batch+1 entries");
    assert_eq!(indptr[0], 0, "indptr must start at 0");
    assert_eq!(indptr[batch], x_cat.rows(), "indptr must cover x_cat");
    let block_q = plans[0].block_q;
    let d_in = x_cat.cols();
    let heads = plans[0].heads.len();
    assert!(heads > 0);
    let d_out = w.cols();
    assert_eq!(w.rows(), d_in);
    assert_eq!(d_out % heads, 0, "W output dim must split across heads");
    let d_h = d_out / heads;
    // Same `(block_q, d_in, d_h)` key as the serial kernel, so each
    // request's output stays bitwise-identical to `gemm_q` under tuning.
    let cfg = resolve_cfg(block_q, d_in, d_h, pool.size());
    let d_pad = panel_stride(cfg.isa, d_h);
    for (r, plan) in plans.iter().enumerate() {
        assert!(indptr[r] <= indptr[r + 1], "indptr must be monotone");
        let n_r = indptr[r + 1] - indptr[r];
        assert_eq!(plan.block_q, block_q, "ragged batch must share block_q");
        assert_eq!(plan.heads.len(), heads, "ragged batch must share heads");
        assert_eq!(plan.t_q, n_r.div_ceil(block_q), "plan Q-block geometry mismatch");
    }
    let mut ys: Vec<Tensor> =
        (0..batch).map(|r| Tensor::zeros(&[indptr[r + 1] - indptr[r], d_out])).collect();

    // Panels are shared across requests: gather head `h` once if any
    // request's plan keeps a live tile in it.
    let panels: Vec<Vec<f32>> = (0..heads)
        .map(|h| {
            if plans.iter().all(|p| p.heads[h].live_q.is_empty()) {
                Vec::new()
            } else {
                gather_head_panel(w, h, d_h, d_pad)
            }
        })
        .collect();
    // One global `(request, head, block)` work list — requests with more
    // live tiles naturally get more lanes (no per-geometry bucketing).
    let mut tiles: Vec<(u32, u32, u32)> = Vec::new();
    for (r, plan) in plans.iter().enumerate() {
        for (h, bi) in plan.live_tiles() {
            tiles.push((r as u32, h, bi));
        }
    }
    let chunk = cfg.chunk(tiles.len(), pool.size());
    let n_tasks = tiles.len().div_ceil(chunk);
    {
        let ptrs: Vec<SendPtr<f32>> =
            ys.iter_mut().map(|y| SendPtr(y.data_mut().as_mut_ptr())).collect();
        let ptrs = &ptrs;
        pool.parallel_for(n_tasks, |t| {
            for &(r, h, bi) in &tiles[t * chunk..((t + 1) * chunk).min(tiles.len())] {
                let (r, h, bi) = (r as usize, h as usize, bi as usize);
                // Global read offsets into the concatenated buffer; the
                // tail block clamps at the request's end, exactly like the
                // solo kernel clamps at `n`.
                let lo = indptr[r] + bi * block_q;
                let hi = (lo + block_q).min(indptr[r + 1]);
                let tile =
                    compute_q_tile(cfg.isa, x_cat, &panels[h], h, d_h, d_pad, lo, hi, bias);
                for (row_i, row) in tile.chunks_exact(d_pad).enumerate() {
                    // Request-local write offset into ys[r].
                    let off = (bi * block_q + row_i) * d_out + h * d_h;
                    // SAFETY: (request, head, block) triples are unique
                    // across tasks, so the written rectangles are disjoint;
                    // each `ys[r]` outlives the parallel section (ExecPool
                    // joins before returning).
                    unsafe {
                        std::ptr::copy_nonoverlapping(row.as_ptr(), ptrs[r].0.add(off), d_h);
                    }
                }
            }
        });
    }
    ys.into_iter().zip(plans).map(|(y, p)| (y, p.gemm_stats())).collect()
}

/// Seed symbol-decoding variant: decodes `F(S_c, i)` per tile. Kept as the
/// reference for the plan-equivalence property tests.
pub fn gemm_q_symbols(
    x: &Tensor,
    w: &Tensor,
    syms: &LayerSymbols,
    block_q: usize,
    bias: Option<&[f32]>,
) -> (Tensor, GemmStats) {
    let n = x.rows();
    let d_in = x.cols();
    let heads = syms.heads.len();
    assert!(heads > 0);
    let d_out = w.cols();
    assert_eq!(w.rows(), d_in);
    assert_eq!(d_out % heads, 0, "W output dim must split across heads");
    let d_h = d_out / heads;
    // Same geometry key as the plan-based kernel, so plan == symbols stays
    // bitwise under tuning.
    let isa = resolve_cfg(block_q, d_in, d_h, 1).isa;
    let d_pad = panel_stride(isa, d_h);
    let t_q = n.div_ceil(block_q);
    let mut y = Tensor::zeros(&[n, d_out]);
    let mut stats = GemmStats { total_tiles: t_q * heads, ..Default::default() };

    for (h, hs) in syms.heads.iter().enumerate() {
        let w_h = gather_head_panel(w, h, d_h, d_pad);
        for bi in 0..t_q {
            if !hs.f(bi) {
                continue; // CTA exits immediately (paper: "without any further operations")
            }
            stats.computed_tiles += 1;
            let lo = bi * block_q;
            let hi = (lo + block_q).min(n);
            project_q_tile(isa, x, &w_h, &mut y, h, d_h, d_pad, d_out, lo, hi, bias);
        }
    }
    (y, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::DecodeMode;
    use crate::symbols::{HeadSymbols, LayerSymbols};
    use crate::testutil::{assert_close, prop_check, rand_mask, randn};

    fn layer_syms_from_cache_masks(masks: &[Vec<bool>], kv_groups: usize, pool: usize) -> LayerSymbols {
        LayerSymbols {
            heads: masks
                .iter()
                .map(|m| {
                    HeadSymbols::from_masks(m, &vec![true; m.len() * kv_groups], kv_groups, pool)
                })
                .collect(),
        }
    }

    fn plan_of(syms: &LayerSymbols, t_q: usize, block_q: usize) -> SparsePlan {
        let kv = syms.heads[0].kv_groups * syms.heads[0].pool;
        SparsePlan::compile(syms, t_q, kv, block_q, block_q, DecodeMode::RowCached)
    }

    #[test]
    fn dense_plan_matches_dense_gemm() {
        let mut rng = crate::util::rng::Pcg32::seeded(1);
        let (n, d_in, heads, d_h, b) = (32, 12, 3, 4, 8);
        let x = randn(&mut rng, &[n, d_in]);
        let w = randn(&mut rng, &[d_in, heads * d_h]);
        let plan = SparsePlan::dense(heads, n / b, n / b, b, b);
        let (y, stats) = gemm_q(&x, &w, &plan, None);
        assert_close(&y, &gemm_dense(&x, &w), 1e-4, 1e-4);
        assert_eq!(stats.sparsity(), 0.0);
    }

    #[test]
    fn cached_tiles_stay_zero_and_computed_match() {
        prop_check("gemm_q partial correctness", 20, |rng| {
            let n = 16 + rng.below(32);
            let d_in = 4 + rng.below(12);
            let heads = 1 + rng.below(4);
            let d_h = 2 + rng.below(6);
            let b = 4 + rng.below(8);
            let t_q = n.div_ceil(b);
            let x = randn(rng, &[n, d_in]);
            let w = randn(rng, &[d_in, heads * d_h]);
            let masks: Vec<Vec<bool>> =
                (0..heads).map(|_| rand_mask(rng, t_q, 0.6)).collect();
            let syms = layer_syms_from_cache_masks(&masks, t_q, 1);
            let plan = plan_of(&syms, t_q, b);
            let (y, stats) = gemm_q(&x, &w, &plan, None);
            let dense = gemm_dense(&x, &w);
            let d_out = heads * d_h;
            let mut computed = 0;
            for h in 0..heads {
                for bi in 0..t_q {
                    let lo = bi * b;
                    let hi = (lo + b).min(n);
                    for r in lo..hi {
                        for c in h * d_h..(h + 1) * d_h {
                            let got = y.data()[r * d_out + c];
                            if masks[h][bi] {
                                let want = dense.data()[r * d_out + c];
                                assert!(
                                    (got - want).abs() <= 1e-4 + 1e-4 * want.abs(),
                                    "computed tile mismatch"
                                );
                            } else {
                                assert_eq!(got, 0.0, "cached tile must stay zero");
                            }
                        }
                    }
                    if masks[h][bi] {
                        computed += 1;
                    }
                }
            }
            assert_eq!(stats.computed_tiles, computed);
            // Plan kernel is bitwise-identical to the symbol kernel.
            let (y_sym, s_sym) = gemm_q_symbols(&x, &w, &syms, b, None);
            assert_eq!(y.data(), y_sym.data());
            assert_eq!(stats.computed_tiles, s_sym.computed_tiles);
        });
    }

    #[test]
    fn pool_variant_is_bitwise_identical() {
        let pool = crate::exec::ExecPool::new(3);
        prop_check("gemm_q_pool == gemm_q", 10, |rng| {
            let n = 16 + rng.below(48);
            let d_in = 4 + rng.below(12);
            let heads = 1 + rng.below(4);
            let d_h = 2 + rng.below(6);
            let b = 4 + rng.below(8);
            let t_q = n.div_ceil(b);
            let x = randn(rng, &[n, d_in]);
            let w = randn(rng, &[d_in, heads * d_h]);
            let bias: Vec<f32> = (0..heads * d_h).map(|i| i as f32 * 0.01).collect();
            let masks: Vec<Vec<bool>> =
                (0..heads).map(|_| rand_mask(rng, t_q, 0.6)).collect();
            let syms = layer_syms_from_cache_masks(&masks, t_q, 1);
            let plan = plan_of(&syms, t_q, b);
            let (serial, s1) = gemm_q(&x, &w, &plan, Some(&bias));
            let (pooled, s2) = gemm_q_pool(&x, &w, &plan, Some(&bias), &pool);
            assert_eq!(serial.data(), pooled.data(), "pool output must be bitwise equal");
            assert_eq!(s1.computed_tiles, s2.computed_tiles);
        });
    }

    #[test]
    fn batched_variant_is_bitwise_identical_per_request() {
        let pool = crate::exec::ExecPool::new(3);
        prop_check("gemm_q_batched[r] == gemm_q(xs[r])", 10, |rng| {
            let n = 16 + rng.below(48);
            let d_in = 4 + rng.below(12);
            let heads = 1 + rng.below(4);
            let d_h = 2 + rng.below(6);
            let b = 4 + rng.below(8);
            let batch = 1 + rng.below(4);
            let t_q = n.div_ceil(b);
            let xs: Vec<Tensor> = (0..batch).map(|_| randn(rng, &[n, d_in])).collect();
            let w = randn(rng, &[d_in, heads * d_h]);
            let bias: Vec<f32> = (0..heads * d_h).map(|i| i as f32 * 0.01).collect();
            let masks: Vec<Vec<bool>> =
                (0..heads).map(|_| rand_mask(rng, t_q, 0.6)).collect();
            let syms = layer_syms_from_cache_masks(&masks, t_q, 1);
            let plan = plan_of(&syms, t_q, b);
            let refs: Vec<&Tensor> = xs.iter().collect();
            let batched = gemm_q_batched(&refs, &w, &plan, Some(&bias), &pool);
            assert_eq!(batched.len(), batch);
            for (x, (yb, sb)) in xs.iter().zip(&batched) {
                let (ys, ss) = gemm_q(x, &w, &plan, Some(&bias));
                assert_eq!(ys.data(), yb.data(), "batched output must be bitwise equal");
                assert_eq!(ss.computed_tiles, sb.computed_tiles);
            }
        });
    }

    #[test]
    fn ragged_variant_is_bitwise_identical_per_request() {
        let pool = crate::exec::ExecPool::new(3);
        prop_check("gemm_q_ragged[r] == gemm_q(x_r)", 10, |rng| {
            let d_in = 4 + rng.below(12);
            let heads = 1 + rng.below(4);
            let d_h = 2 + rng.below(6);
            let b = 4 + rng.below(8);
            let batch = 1 + rng.below(4);
            // Mixed (often odd) per-request lengths exercise tail clamping.
            let ns: Vec<usize> = (0..batch).map(|_| 7 + rng.below(57)).collect();
            let w = randn(rng, &[d_in, heads * d_h]);
            let bias: Vec<f32> = (0..heads * d_h).map(|i| i as f32 * 0.01).collect();
            let xs: Vec<Tensor> = ns.iter().map(|&n| randn(rng, &[n, d_in])).collect();
            let plans: Vec<SparsePlan> = ns
                .iter()
                .map(|&n| {
                    let t_q = n.div_ceil(b);
                    let masks: Vec<Vec<bool>> =
                        (0..heads).map(|_| rand_mask(rng, t_q, 0.6)).collect();
                    plan_of(&layer_syms_from_cache_masks(&masks, t_q, 1), t_q, b)
                })
                .collect();
            let mut indptr = vec![0usize];
            let mut cat = Vec::new();
            for x in &xs {
                cat.extend_from_slice(x.data());
                indptr.push(indptr.last().unwrap() + x.rows());
            }
            let x_cat = Tensor::from_vec(&[indptr[batch], d_in], cat);
            let plan_refs: Vec<&SparsePlan> = plans.iter().collect();
            let ragged = gemm_q_ragged(&x_cat, &indptr, &w, &plan_refs, Some(&bias), &pool);
            assert_eq!(ragged.len(), batch);
            for ((x, plan), (yr, sr)) in xs.iter().zip(&plans).zip(&ragged) {
                let (ys, ss) = gemm_q(x, &w, plan, Some(&bias));
                assert_eq!(ys.data(), yr.data(), "ragged output must be bitwise equal");
                assert_eq!(ss.computed_tiles, sr.computed_tiles);
            }
        });
    }

    #[test]
    fn per_head_independence() {
        // Head 0 fully cached, head 1 fully computed.
        let mut rng = crate::util::rng::Pcg32::seeded(2);
        let (n, d_in, d_h, b) = (16, 8, 4, 8);
        let x = randn(&mut rng, &[n, d_in]);
        let w = randn(&mut rng, &[d_in, 2 * d_h]);
        let syms = layer_syms_from_cache_masks(&[vec![false; 2], vec![true; 2]], 2, 1);
        let plan = plan_of(&syms, 2, b);
        let (y, stats) = gemm_q(&x, &w, &plan, None);
        assert_eq!(stats.computed_tiles, 2);
        for r in 0..n {
            for c in 0..d_h {
                assert_eq!(y.data()[r * 2 * d_h + c], 0.0);
            }
            let any: f32 = (d_h..2 * d_h).map(|c| y.data()[r * 2 * d_h + c].abs()).sum();
            assert!(any > 0.0);
        }
    }
}
