//! Dense FlashAttention and the **FlashOmni sparse attention kernel**
//! (paper Algorithm 1).
//!
//! Both operate on one head: `Q, K, V ∈ [N × d]` row-major. The primary
//! sparse kernel ([`flashomni_attention`]) consumes a compiled
//! [`HeadPlan`]: the bitwise symbol decode of §3.4 happened once at plan
//! compile time, so the kernel's loops walk only live block indices —
//! zero per-tile bit math:
//!
//! ```text
//! for each cached Q block i in plan.cached_q:
//!     O_i = OP_reuse(Õ_i)          (skipped entirely under the GEMM-O
//!                                   bias optimization)
//! for each live Q block i in plan.live_q (one "CTA"):
//!     for each live KV block j in plan.live_kv(i):
//!         online-softmax update with K_j, V_j
//!     O_i = diag(l)⁻¹ · acc
//! ```
//!
//! The seed symbol-decoding kernel is retained as
//! [`flashomni_attention_symbols`]: it follows Algorithm 1 literally
//! (per-CTA `F` decode, per-tile `J` decode under a [`DecodeMode`]) and is
//! the reference for the plan-equivalence property tests and the §4.3
//! decode-overhead ablation in `benches/fig10_attention.rs`.
//!
//! Skipped work is *really* skipped — no loads, no FLOPs — which is what
//! makes the wall-clock measurements in `benches/` meaningful.

use crate::kernels::microkernel::{self, Isa};
use crate::kernels::tune::{self, Family};
use crate::plan::HeadPlan;
pub use crate::plan::{AttnStats, DecodeMode};
use crate::symbols::HeadSymbols;
use crate::tensor::Tensor;

/// Resolve the microkernel flavor for an attention call from the tuning
/// table (falling back to the process default). Keyed on the tile geometry
/// `(block_q, head_dim, block_k)` only — every variant (dense, plan,
/// symbols, batched) with the same geometry resolves the same flavor, so
/// their bitwise-equivalence tests survive tuning.
fn resolve_isa(block_q: usize, d: usize, block_k: usize) -> Isa {
    tune::config_for(Family::Attention, [block_q, d, block_k], 1).isa
}

/// Dense FlashAttention (block-partitioned, online softmax). Reference
/// baseline for every speedup measurement. Runs the tuned/default
/// microkernel flavor; [`attention_dense_isa`] pins one explicitly.
pub fn attention_dense(q: &Tensor, k: &Tensor, v: &Tensor, block_q: usize, block_k: usize) -> Tensor {
    attention_dense_isa(resolve_isa(block_q, q.cols(), block_k), q, k, v, block_q, block_k)
}

/// [`attention_dense`] with an explicit microkernel flavor (benches pin
/// scalar/SIMD rows; [`Isa::Scalar`] reproduces the seed float sequence
/// bit-for-bit).
pub fn attention_dense_isa(
    isa: Isa,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    block_q: usize,
    block_k: usize,
) -> Tensor {
    let n = q.rows();
    let d = q.cols();
    assert_eq!(k.rows(), v.rows());
    assert_eq!(k.cols(), d);
    assert_eq!(v.cols(), d);
    let n_kv = k.rows();
    let scale = 1.0 / (d as f32).sqrt();
    let mut o = Tensor::zeros(&[n, d]);

    let t_q = n.div_ceil(block_q);
    let t_kv = n_kv.div_ceil(block_k);
    let mut scores = vec![0.0f32; block_q * block_k];
    let mut acc = vec![0.0f32; block_q * d];
    let mut m = vec![f32::NEG_INFINITY; block_q];
    let mut l = vec![0.0f32; block_q];

    for bi in 0..t_q {
        let q_lo = bi * block_q;
        let q_hi = (q_lo + block_q).min(n);
        let bq = q_hi - q_lo;
        acc[..bq * d].fill(0.0);
        m[..bq].fill(f32::NEG_INFINITY);
        l[..bq].fill(0.0);
        for bj in 0..t_kv {
            let k_lo = bj * block_k;
            let k_hi = (k_lo + block_k).min(n_kv);
            let bk = k_hi - k_lo;
            attention_block_update(
                isa,
                &q.data()[q_lo * d..q_hi * d],
                &k.data()[k_lo * d..k_hi * d],
                &v.data()[k_lo * d..k_hi * d],
                bq,
                bk,
                d,
                scale,
                &mut scores,
                &mut m,
                &mut l,
                &mut acc,
            );
        }
        finalize_block(&mut o.data_mut()[q_lo * d..q_hi * d], &acc, &l, bq, d);
    }
    o
}

/// One online-softmax update with a `(bq × bk)` tile.
#[allow(clippy::too_many_arguments)]
#[inline]
fn attention_block_update(
    isa: Isa,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    bq: usize,
    bk: usize,
    d: usize,
    scale: f32,
    scores: &mut [f32],
    m: &mut [f32],
    l: &mut [f32],
    acc: &mut [f32],
) {
    // S = Q Kᵀ · scale (dot-product form). The scalar microkernel keeps the
    // seed's 8-lane accumulator (bounds checks vanish, LLVM emits packed
    // FMAs at target-cpu=native); the SIMD flavor issues explicit FMAs.
    for i in 0..bq {
        let qrow = &q[i * d..(i + 1) * d];
        for j in 0..bk {
            let krow = &k[j * d..(j + 1) * d];
            scores[i * bk + j] = microkernel::dot8(isa, qrow, krow) * scale;
        }
    }
    // Online softmax per row.
    for i in 0..bq {
        let row = &mut scores[i * bk..i * bk + bk];
        let mut blk_max = f32::NEG_INFINITY;
        for &s in row.iter() {
            blk_max = blk_max.max(s);
        }
        let new_m = m[i].max(blk_max);
        let correction = if m[i] == f32::NEG_INFINITY { 0.0 } else { (m[i] - new_m).exp() };
        // Rescale previous accumulator and l.
        if correction != 1.0 {
            l[i] *= correction;
            for p in 0..d {
                acc[i * d + p] *= correction;
            }
        }
        let mut row_sum = 0.0f32;
        for s in row.iter_mut() {
            *s = (*s - new_m).exp();
            row_sum += *s;
        }
        l[i] += row_sum;
        m[i] = new_m;
        // acc += P̃ · V  (slice zip ⇒ packed adds; two j at a time for ILP)
        let arow = &mut acc[i * d..(i + 1) * d];
        let mut j = 0;
        while j + 2 <= bk {
            let (p0, p1) = (row[j], row[j + 1]);
            let v0 = &v[j * d..(j + 1) * d];
            let v1 = &v[(j + 1) * d..(j + 2) * d];
            microkernel::axpy2(isa, arow, p0, v0, p1, v1);
            j += 2;
        }
        if j < bk {
            let pij = row[j];
            let vrow = &v[j * d..(j + 1) * d];
            microkernel::axpy1(isa, arow, pij, vrow);
        }
    }
}

#[inline]
fn finalize_block(o: &mut [f32], acc: &[f32], l: &[f32], bq: usize, d: usize) {
    for i in 0..bq {
        let inv = if l[i] > 0.0 { 1.0 / l[i] } else { 0.0 };
        for p in 0..d {
            o[i * d + p] = acc[i * d + p] * inv;
        }
    }
}

/// FlashOmni sparse attention driven by a compiled [`HeadPlan`].
///
/// * `plan` — live block indices compiled once from the unified symbols
///   ([`crate::plan`]); the inner loops do **no** symbol decoding.
/// * `cached_o` — the forecast features `OP_reuse(Õ)` for cached blocks;
///   when `Some`, cached rows of the output are filled from it
///   (cache-then-reuse path). When `None`, cached rows are left at zero —
///   the caller is using the GEMM-O bias optimization, which makes the
///   element-wise reuse write unnecessary (§3.5, Obs. 3).
///
/// Returns the output and the plan-derived skip statistics. Runs the
/// tuned/default microkernel flavor; [`flashomni_attention_isa`] pins one
/// explicitly.
pub fn flashomni_attention(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    plan: &HeadPlan,
    block_q: usize,
    block_k: usize,
    cached_o: Option<&Tensor>,
) -> (Tensor, AttnStats) {
    flashomni_attention_isa(
        resolve_isa(block_q, q.cols(), block_k),
        q,
        k,
        v,
        plan,
        block_q,
        block_k,
        cached_o,
    )
}

/// [`flashomni_attention`] with an explicit microkernel flavor (benches pin
/// scalar/SIMD rows; [`Isa::Scalar`] reproduces the seed float sequence
/// bit-for-bit).
#[allow(clippy::too_many_arguments)]
pub fn flashomni_attention_isa(
    isa: Isa,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    plan: &HeadPlan,
    block_q: usize,
    block_k: usize,
    cached_o: Option<&Tensor>,
) -> (Tensor, AttnStats) {
    let n = q.rows();
    let d = q.cols();
    let n_kv = k.rows();
    let scale = 1.0 / (d as f32).sqrt();
    let mut o = Tensor::zeros(&[n, d]);
    debug_assert_eq!(plan.t_q, n.div_ceil(block_q), "plan Q geometry mismatch");
    debug_assert_eq!(plan.t_kv, n_kv.div_ceil(block_k), "plan KV geometry mismatch");

    // Cache-then-reuse path: a plain gather over the cached block list.
    if let Some(co) = cached_o {
        for &bi in &plan.cached_q {
            let q_lo = bi as usize * block_q;
            let q_hi = (q_lo + block_q).min(n);
            o.data_mut()[q_lo * d..q_hi * d].copy_from_slice(&co.data()[q_lo * d..q_hi * d]);
        }
    }

    let mut scores = vec![0.0f32; block_q * block_k];
    let mut acc = vec![0.0f32; block_q * d];
    let mut m = vec![f32::NEG_INFINITY; block_q];
    let mut l = vec![0.0f32; block_q];

    for (li, &bi) in plan.live_q.iter().enumerate() {
        let q_lo = bi as usize * block_q;
        let q_hi = (q_lo + block_q).min(n);
        let bq = q_hi - q_lo;
        acc[..bq * d].fill(0.0);
        m[..bq].fill(f32::NEG_INFINITY);
        l[..bq].fill(0.0);
        for &bj in plan.live_kv(li) {
            let k_lo = bj as usize * block_k;
            let k_hi = (k_lo + block_k).min(n_kv);
            let bk = k_hi - k_lo;
            attention_block_update(
                isa,
                &q.data()[q_lo * d..q_hi * d],
                &k.data()[k_lo * d..k_hi * d],
                &v.data()[k_lo * d..k_hi * d],
                bq,
                bk,
                d,
                scale,
                &mut scores,
                &mut m,
                &mut l,
                &mut acc,
            );
        }
        finalize_block(&mut o.data_mut()[q_lo * d..q_hi * d], &acc, &l, bq, d);
    }
    (o, plan.attn_stats())
}

/// Batched multi-head dispatch of [`flashomni_attention`]: one shared
/// [`SparsePlan`](crate::plan::SparsePlan) drives **`batch × heads`** pool
/// lanes, each extracting its `(request, head)` slice of the joint
/// `[N × H·d]` tensors and running Algorithm 1 against the shared per-head
/// plan. Results come back `[request][head]` in index order, so the output
/// is bitwise-identical to the engine's per-request head loop.
///
/// `cached_o` is always `None` here: the batched engine runs with the
/// GEMM-O bias optimization, which makes the cache-then-reuse write
/// unnecessary (§3.5, Obs. 3).
pub fn flashomni_attention_batched(
    qs: &[&Tensor],
    ks: &[&Tensor],
    vs: &[&Tensor],
    plan: &crate::plan::SparsePlan,
    pool: &crate::exec::ExecPool,
) -> Vec<Vec<(Tensor, AttnStats)>> {
    use crate::model::blocks::extract_head;
    let b = qs.len();
    assert_eq!(ks.len(), b);
    assert_eq!(vs.len(), b);
    assert!(b > 0, "empty batch");
    let heads = plan.heads.len();
    let (bq, bk) = (plan.block_q, plan.block_k);
    // Resolve the flavor once on the caller thread (same `(bq, d_h, bk)`
    // key each per-head call would use, so lanes stay bitwise-identical to
    // the serial head loop) instead of racing first-use tuning in workers.
    let d_h = qs[0].cols() / heads.max(1);
    let isa = resolve_isa(bq, d_h, bk);
    let lanes: Vec<(Tensor, AttnStats)> = pool.parallel_map_indexed(b * heads, |lane| {
        let (r, h) = (lane / heads, lane % heads);
        let qh = extract_head(qs[r], heads, h);
        let kh = extract_head(ks[r], heads, h);
        let vh = extract_head(vs[r], heads, h);
        flashomni_attention_isa(isa, &qh, &kh, &vh, &plan.heads[h], bq, bk, None)
    });
    let mut out = Vec::with_capacity(b);
    let mut it = lanes.into_iter();
    for _ in 0..b {
        out.push(it.by_ref().take(heads).collect());
    }
    out
}

/// Copy head `h` of rows `lo..hi` of a concatenated `[ΣN × H·d]` buffer
/// into a contiguous `[hi-lo × d]` tensor. Row-for-row the same copies as
/// `extract_head` on the request's own tensor, so the ragged dispatch sees
/// byte-identical head inputs.
fn extract_head_rows(x: &Tensor, heads: usize, h: usize, lo: usize, hi: usize) -> Tensor {
    let d = x.cols();
    let hd = d / heads;
    let mut out = Tensor::zeros(&[hi - lo, hd]);
    for r in lo..hi {
        out.row_mut(r - lo).copy_from_slice(&x.row(r)[h * hd..(h + 1) * hd]);
    }
    out
}

/// Ragged batched dispatch of [`flashomni_attention`]: **per-request
/// plans** over concatenated `[ΣNᵣ × H·d]` Q/K/V buffers with cu-seqlen
/// offsets — the varlen analogue of [`flashomni_attention_batched`] for
/// mixed-resolution batches. Request `r` owns rows
/// `indptr[r]..indptr[r+1]`; `batch × heads` pool lanes each extract their
/// `(request, head)` row range and run Algorithm 1 against
/// `plans[r].heads[h]`. Results come back `[request][head]` in index
/// order; output `r` is **bitwise-identical** to the per-request head loop
/// on request `r`'s own tensors (property-tested below).
///
/// All plans must share `(block_q, block_k)` (engine-constant); sequence
/// lengths may differ per request. `cached_o` is always `None` — the
/// ragged engine runs with the GEMM-O bias optimization (§3.5, Obs. 3).
pub fn flashomni_attention_ragged(
    q_cat: &Tensor,
    k_cat: &Tensor,
    v_cat: &Tensor,
    indptr: &[usize],
    plans: &[&crate::plan::SparsePlan],
    pool: &crate::exec::ExecPool,
) -> Vec<Vec<(Tensor, AttnStats)>> {
    let b = plans.len();
    assert!(b > 0, "empty ragged batch");
    assert_eq!(indptr.len(), b + 1, "indptr must have batch+1 entries");
    assert_eq!(indptr[0], 0, "indptr must start at 0");
    assert_eq!(indptr[b], q_cat.rows(), "indptr must cover q_cat");
    assert_eq!(k_cat.rows(), q_cat.rows());
    assert_eq!(v_cat.rows(), q_cat.rows());
    let heads = plans[0].heads.len();
    let (bq, bk) = (plans[0].block_q, plans[0].block_k);
    for (r, plan) in plans.iter().enumerate() {
        assert!(indptr[r] <= indptr[r + 1], "indptr must be monotone");
        assert_eq!(plan.heads.len(), heads, "ragged batch must share heads");
        assert_eq!(plan.block_q, bq, "ragged batch must share block_q");
        assert_eq!(plan.block_k, bk, "ragged batch must share block_k");
    }
    // Resolve the flavor once on the caller thread; the `(bq, d_h, bk)` key
    // is sequence-length independent, so every request resolves the same
    // flavor its solo run would.
    let d_h = q_cat.cols() / heads.max(1);
    let isa = resolve_isa(bq, d_h, bk);
    let lanes: Vec<(Tensor, AttnStats)> = pool.parallel_map_indexed(b * heads, |lane| {
        let (r, h) = (lane / heads, lane % heads);
        let (lo, hi) = (indptr[r], indptr[r + 1]);
        let qh = extract_head_rows(q_cat, heads, h, lo, hi);
        let kh = extract_head_rows(k_cat, heads, h, lo, hi);
        let vh = extract_head_rows(v_cat, heads, h, lo, hi);
        flashomni_attention_isa(isa, &qh, &kh, &vh, &plans[r].heads[h], bq, bk, None)
    });
    let mut out = Vec::with_capacity(b);
    let mut it = lanes.into_iter();
    for _ in 0..b {
        out.push(it.by_ref().take(heads).collect());
    }
    out
}

/// FlashOmni sparse attention (Algorithm 1) decoding the symbols in the
/// kernel loops — the seed implementation, kept as the reference for the
/// plan-equivalence property tests and the §4.3 decode-overhead ablation.
///
/// * `sym` — unified sparse symbols for this head.
/// * `cached_o` — as in [`flashomni_attention`].
/// * `decode` — inner-loop symbol decode strategy (see [`DecodeMode`]).
#[allow(clippy::too_many_arguments)]
pub fn flashomni_attention_symbols(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    sym: &HeadSymbols,
    block_q: usize,
    block_k: usize,
    cached_o: Option<&Tensor>,
    decode: DecodeMode,
) -> (Tensor, AttnStats) {
    let n = q.rows();
    let d = q.cols();
    let n_kv = k.rows();
    let scale = 1.0 / (d as f32).sqrt();
    // Same geometry key as the plan-based kernel, so plan == symbols stays
    // bitwise under tuning.
    let isa = resolve_isa(block_q, d, block_k);
    let mut o = Tensor::zeros(&[n, d]);
    let t_q = n.div_ceil(block_q);
    let t_kv = n_kv.div_ceil(block_k);
    debug_assert_eq!(sym.q_groups, t_q.div_ceil(sym.pool), "S_c geometry mismatch");
    debug_assert_eq!(sym.kv_groups, t_kv.div_ceil(sym.pool), "S_s geometry mismatch");

    let mut stats = AttnStats {
        total_pairs: t_q * t_kv,
        q_blocks: t_q,
        ..Default::default()
    };
    let mut scores = vec![0.0f32; block_q * block_k];
    let mut acc = vec![0.0f32; block_q * d];
    let mut m = vec![f32::NEG_INFINITY; block_q];
    let mut l = vec![0.0f32; block_q];

    for bi in 0..t_q {
        let q_lo = bi * block_q;
        let q_hi = (q_lo + block_q).min(n);
        let bq = q_hi - q_lo;

        // Line 5: spatial-axis decode F(S_c, i) — once per CTA.
        if !sym.f(bi) {
            // Cache-then-reuse path (lines 6–9).
            stats.cached_blocks += 1;
            if let Some(co) = cached_o {
                o.data_mut()[q_lo * d..q_hi * d]
                    .copy_from_slice(&co.data()[q_lo * d..q_hi * d]);
            }
            continue; // line 7: the CTA returns immediately
        }

        // Compute-on-demand path (lines 11–19).
        acc[..bq * d].fill(0.0);
        m[..bq].fill(f32::NEG_INFINITY);
        l[..bq].fill(0.0);
        let mut row_dec = sym.row_decoder(bi);
        for bj in 0..t_kv {
            // Line 13: reduction-axis decode J(S_s, i, j).
            let keep = match decode {
                DecodeMode::RowCached => row_dec.j(bj),
                DecodeMode::PerAccess => sym.j(bi, bj),
            };
            if !keep {
                continue;
            }
            stats.computed_pairs += 1;
            let k_lo = bj * block_k;
            let k_hi = (k_lo + block_k).min(n_kv);
            let bk = k_hi - k_lo;
            attention_block_update(
                isa,
                &q.data()[q_lo * d..q_hi * d],
                &k.data()[k_lo * d..k_hi * d],
                &v.data()[k_lo * d..k_hi * d],
                bq,
                bk,
                d,
                scale,
                &mut scores,
                &mut m,
                &mut l,
                &mut acc,
            );
        }
        finalize_block(&mut o.data_mut()[q_lo * d..q_hi * d], &acc, &l, bq, d);
    }
    (o, stats)
}

/// Slow masked reference with identical semantics, used by tests:
/// softmax with `-inf` on skipped blocks; cached rows copied from
/// `cached_o` (or zero).
pub fn masked_reference(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    sym: &HeadSymbols,
    block_q: usize,
    block_k: usize,
    cached_o: Option<&Tensor>,
) -> Tensor {
    let n = q.rows();
    let d = q.cols();
    let n_kv = k.rows();
    let scale = 1.0 / (d as f32).sqrt();
    let mut o = Tensor::zeros(&[n, d]);
    for r in 0..n {
        let bi = r / block_q;
        if !sym.f(bi) {
            if let Some(co) = cached_o {
                o.row_mut(r).copy_from_slice(co.row(r));
            }
            continue;
        }
        let mut s = vec![f32::NEG_INFINITY; n_kv];
        for c in 0..n_kv {
            let bj = c / block_k;
            if !sym.j(bi, bj) {
                continue;
            }
            let mut dot = 0.0f32;
            for p in 0..d {
                dot += q.row(r)[p] * k.row(c)[p];
            }
            s[c] = dot * scale;
        }
        let mx = s.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        if mx == f32::NEG_INFINITY {
            continue; // fully-masked row → zeros
        }
        let mut denom = 0.0f32;
        for x in s.iter_mut() {
            *x = (*x - mx).exp();
            denom += *x;
        }
        let orow = o.row_mut(r);
        for c in 0..n_kv {
            let w = s[c] / denom;
            if w == 0.0 {
                continue;
            }
            for p in 0..d {
                orow[p] += w * v.row(c)[p];
            }
        }
    }
    o
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::symbols::HeadSymbols;
    use crate::testutil::{assert_close, prop_check, rand_mask, randn};

    fn plan_of(sym: &HeadSymbols, n: usize, n_kv: usize, bq: usize, bk: usize) -> HeadPlan {
        HeadPlan::from_symbols(sym, n.div_ceil(bq), n_kv.div_ceil(bk), DecodeMode::RowCached)
    }

    #[test]
    fn batched_dispatch_is_bitwise_identical_per_request() {
        use crate::model::blocks::{extract_head, insert_head};
        use crate::plan::SparsePlan;
        use crate::symbols::LayerSymbols;
        let pool = crate::exec::ExecPool::new(3);
        prop_check("attention batch×heads lanes == per-request head loop", 8, |rng| {
            let heads = 1 + rng.below(4);
            let d_h = 4 + rng.below(8);
            let n = 16 + rng.below(48);
            let (bq, bk) = (8, 8);
            let batch = 1 + rng.below(4);
            let t_q = n.div_ceil(bq);
            let t_kv = n.div_ceil(bk);
            let syms = LayerSymbols {
                heads: (0..heads)
                    .map(|_| {
                        let m_c = rand_mask(rng, t_q, 0.7);
                        let m_s = rand_mask(rng, t_q * t_kv, 0.6);
                        HeadSymbols::from_masks(&m_c, &m_s, t_kv, 1)
                    })
                    .collect(),
            };
            let plan = SparsePlan::compile(&syms, t_q, t_kv, bq, bk, DecodeMode::RowCached);
            let d = heads * d_h;
            let qs: Vec<Tensor> = (0..batch).map(|_| randn(rng, &[n, d])).collect();
            let ks: Vec<Tensor> = (0..batch).map(|_| randn(rng, &[n, d])).collect();
            let vs: Vec<Tensor> = (0..batch).map(|_| randn(rng, &[n, d])).collect();
            let qr: Vec<&Tensor> = qs.iter().collect();
            let kr: Vec<&Tensor> = ks.iter().collect();
            let vr: Vec<&Tensor> = vs.iter().collect();
            let batched = flashomni_attention_batched(&qr, &kr, &vr, &plan, &pool);
            assert_eq!(batched.len(), batch);
            for r in 0..batch {
                assert_eq!(batched[r].len(), heads);
                let mut got = Tensor::zeros(&[n, d]);
                let mut want = Tensor::zeros(&[n, d]);
                for h in 0..heads {
                    let qh = extract_head(&qs[r], heads, h);
                    let kh = extract_head(&ks[r], heads, h);
                    let vh = extract_head(&vs[r], heads, h);
                    let (oh, st) =
                        flashomni_attention(&qh, &kh, &vh, &plan.heads[h], bq, bk, None);
                    insert_head(&mut want, &oh, heads, h);
                    insert_head(&mut got, &batched[r][h].0, heads, h);
                    assert_eq!(st.computed_pairs, batched[r][h].1.computed_pairs);
                }
                assert_eq!(got.data(), want.data(), "request {r} differs");
            }
        });
    }

    #[test]
    fn ragged_dispatch_is_bitwise_identical_per_request() {
        use crate::model::blocks::extract_head;
        use crate::plan::SparsePlan;
        use crate::symbols::LayerSymbols;
        let pool = crate::exec::ExecPool::new(3);
        prop_check("ragged attention lanes == per-request head loop", 8, |rng| {
            let heads = 1 + rng.below(4);
            let d_h = 4 + rng.below(8);
            let (bq, bk) = (8, 8);
            let batch = 1 + rng.below(4);
            let d = heads * d_h;
            // Mixed (often odd) per-request lengths.
            let ns: Vec<usize> = (0..batch).map(|_| 9 + rng.below(55)).collect();
            let mut plans = Vec::new();
            let mut qs = Vec::new();
            let mut ks = Vec::new();
            let mut vs = Vec::new();
            for &n in &ns {
                let t_q = n.div_ceil(bq);
                let t_kv = n.div_ceil(bk);
                let syms = LayerSymbols {
                    heads: (0..heads)
                        .map(|_| {
                            let m_c = rand_mask(rng, t_q, 0.7);
                            let m_s = rand_mask(rng, t_q * t_kv, 0.6);
                            HeadSymbols::from_masks(&m_c, &m_s, t_kv, 1)
                        })
                        .collect(),
                };
                plans.push(SparsePlan::compile(&syms, t_q, t_kv, bq, bk, DecodeMode::RowCached));
                qs.push(randn(rng, &[n, d]));
                ks.push(randn(rng, &[n, d]));
                vs.push(randn(rng, &[n, d]));
            }
            let mut indptr = vec![0usize];
            let cat = |ts: &[Tensor]| {
                let mut data = Vec::new();
                for t in ts {
                    data.extend_from_slice(t.data());
                }
                Tensor::from_vec(&[ts.iter().map(|t| t.rows()).sum(), d], data)
            };
            for &n in &ns {
                indptr.push(indptr.last().unwrap() + n);
            }
            let (q_cat, k_cat, v_cat) = (cat(&qs), cat(&ks), cat(&vs));
            let plan_refs: Vec<&SparsePlan> = plans.iter().collect();
            let ragged =
                flashomni_attention_ragged(&q_cat, &k_cat, &v_cat, &indptr, &plan_refs, &pool);
            assert_eq!(ragged.len(), batch);
            for r in 0..batch {
                assert_eq!(ragged[r].len(), heads);
                for h in 0..heads {
                    let qh = extract_head(&qs[r], heads, h);
                    let kh = extract_head(&ks[r], heads, h);
                    let vh = extract_head(&vs[r], heads, h);
                    let (oh, st) =
                        flashomni_attention(&qh, &kh, &vh, &plans[r].heads[h], bq, bk, None);
                    assert_eq!(oh.data(), ragged[r][h].0.data(), "request {r} head {h} differs");
                    assert_eq!(st.computed_pairs, ragged[r][h].1.computed_pairs);
                }
            }
        });
    }

    #[test]
    fn dense_matches_masked_reference() {
        prop_check("dense attention == reference", 15, |rng| {
            let n = 8 + rng.below(56);
            let d = 4 + rng.below(28);
            let q = randn(rng, &[n, d]);
            let k = randn(rng, &[n, d]);
            let v = randn(rng, &[n, d]);
            let bq = 1 + rng.below(16);
            let bk = 1 + rng.below(16);
            let t_q = n.div_ceil(bq);
            let t_kv = n.div_ceil(bk);
            let sym = HeadSymbols::dense(t_q, t_kv, 1);
            let want = masked_reference(&q, &k, &v, &sym, bq, bk, None);
            let got = attention_dense(&q, &k, &v, bq, bk);
            assert_close(&got, &want, 1e-4, 1e-3);
        });
    }

    #[test]
    fn sparse_matches_masked_reference() {
        prop_check("Algorithm 1 == masked reference", 25, |rng| {
            let n = 16 + rng.below(64);
            let d = 4 + rng.below(12);
            let bq = 4 + rng.below(8);
            let bk = 4 + rng.below(8);
            let pool = 1 + rng.below(2);
            let t_q = n.div_ceil(bq);
            let t_kv = n.div_ceil(bk);
            let qg = t_q.div_ceil(pool);
            let kg = t_kv.div_ceil(pool);
            let q = randn(rng, &[n, d]);
            let k = randn(rng, &[n, d]);
            let v = randn(rng, &[n, d]);
            let cached = randn(rng, &[n, d]);
            let m_c = rand_mask(rng, qg, 0.7);
            let m_s = rand_mask(rng, qg * kg, 0.6);
            let sym = HeadSymbols::from_masks(&m_c, &m_s, kg, pool);
            let want = masked_reference(&q, &k, &v, &sym, bq, bk, Some(&cached));
            // Symbol-decoding reference kernel under both decode modes.
            for decode in [DecodeMode::RowCached, DecodeMode::PerAccess] {
                let (got, stats) =
                    flashomni_attention_symbols(&q, &k, &v, &sym, bq, bk, Some(&cached), decode);
                assert_close(&got, &want, 1e-4, 1e-3);
                assert_eq!(stats.total_pairs, t_q * t_kv);
                assert!(stats.computed_pairs <= stats.total_pairs);
            }
            // Plan-based kernel.
            let plan = plan_of(&sym, n, n, bq, bk);
            let (got, stats) = flashomni_attention(&q, &k, &v, &plan, bq, bk, Some(&cached));
            assert_close(&got, &want, 1e-4, 1e-3);
            assert_eq!(stats.total_pairs, t_q * t_kv);
        });
    }

    #[test]
    fn dense_plan_reduces_to_dense_attention() {
        let mut rng = crate::util::rng::Pcg32::seeded(42);
        let (n, d, b) = (40, 8, 8);
        let q = randn(&mut rng, &[n, d]);
        let k = randn(&mut rng, &[n, d]);
        let v = randn(&mut rng, &[n, d]);
        let plan = HeadPlan::dense(n.div_ceil(b), n.div_ceil(b));
        let (sparse, stats) = flashomni_attention(&q, &k, &v, &plan, b, b, None);
        let dense = attention_dense(&q, &k, &v, b, b);
        assert_close(&sparse, &dense, 1e-5, 1e-4);
        assert_eq!(stats.sparsity(), 0.0);
        assert_eq!(stats.cached_blocks, 0);
    }

    #[test]
    fn cached_rows_skip_write_when_bias_optimized() {
        let mut rng = crate::util::rng::Pcg32::seeded(43);
        let (n, d, b) = (16, 4, 8);
        let q = randn(&mut rng, &[n, d]);
        let k = randn(&mut rng, &[n, d]);
        let v = randn(&mut rng, &[n, d]);
        // Block 0 cached, block 1 computed.
        let sym = HeadSymbols::from_masks(&[false, true], &[true, true, true, true], 2, 1);
        let plan = plan_of(&sym, n, n, b, b);
        let (o, stats) = flashomni_attention(&q, &k, &v, &plan, b, b, None);
        assert_eq!(stats.cached_blocks, 1);
        // Cached rows left zero (no element-wise write — bias path).
        assert!(o.data()[..b * d].iter().all(|&x| x == 0.0));
        // Computed rows are not all zero.
        assert!(o.data()[b * d..].iter().any(|&x| x != 0.0));
    }

    #[test]
    fn fully_skipped_row_yields_zeros() {
        let mut rng = crate::util::rng::Pcg32::seeded(44);
        let (n, d, b) = (8, 4, 4);
        let q = randn(&mut rng, &[n, d]);
        let k = randn(&mut rng, &[n, d]);
        let v = randn(&mut rng, &[n, d]);
        // Row block 0: computed spatially but all KV pairs skipped.
        let sym = HeadSymbols::from_masks(&[true, true], &[false, false, true, true], 2, 1);
        let plan = plan_of(&sym, n, n, b, b);
        let (o, stats) = flashomni_attention(&q, &k, &v, &plan, b, b, None);
        assert!(o.data()[..b * d].iter().all(|&x| x == 0.0));
        assert_eq!(stats.computed_pairs, 2);
    }

    #[test]
    fn stats_sparsity_accounting() {
        let mut rng = crate::util::rng::Pcg32::seeded(45);
        let (n, d, b) = (32, 4, 8);
        let q = randn(&mut rng, &[n, d]);
        let k = randn(&mut rng, &[n, d]);
        let v = randn(&mut rng, &[n, d]);
        // 4 q-blocks × 4 kv-blocks; cache 2 rows; skip nothing else.
        let sym =
            HeadSymbols::from_masks(&[false, true, false, true], &[true; 16], 4, 1);
        let plan = plan_of(&sym, n, n, b, b);
        let (_, stats) = flashomni_attention(&q, &k, &v, &plan, b, b, None);
        assert_eq!(stats.computed_pairs, 8);
        assert_eq!(stats.total_pairs, 16);
        assert!((stats.sparsity() - 0.5).abs() < 1e-12);
        // Plan-derived sparsity must agree with the symbol-predicted one.
        assert!((stats.sparsity() - sym.pair_sparsity()).abs() < 1e-12);
    }
}
