//! Minimal JSON parser / serializer (no external crates available offline).
//!
//! Supports the full JSON value grammar with the restrictions this crate
//! needs: numbers are stored as `f64`, strings support the standard escape
//! set plus `\uXXXX` (BMP only). Used for `.fot` tensor headers, config
//! files, and report output.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(src: &str) -> Result<Json, String> {
        let mut p = Parser { s: src.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.s.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }
    /// Shorthand: required object field or error message.
    pub fn req(&self, key: &str) -> Result<&Json, String> {
        self.get(key).ok_or_else(|| format!("missing field '{key}'"))
    }

    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr_usize(v: &[usize]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }
    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    s: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.s.len() && matches!(self.s[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.s.get(self.i).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.i))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.s[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = *self.s.get(self.i).ok_or("unterminated string")?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = *self.s.get(self.i).ok_or("bad escape")?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.s.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.s[self.i..self.i + 4])
                                .map_err(|_| "bad \\u escape")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape \\{}", e as char)),
                    }
                }
                _ => {
                    // Re-decode UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let end = (start + len).min(self.s.len());
                        let chunk = std::str::from_utf8(&self.s[start..end])
                            .map_err(|_| "invalid utf-8 in string")?;
                        out.push_str(chunk);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.s[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number '{txt}'"))
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("bad array at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("bad object at byte {}", self.i)),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        0xf0..=0xf7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": "hi\nthere", "c": true, "d": null}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("b").unwrap().as_str().unwrap(), "hi\nthere");
        assert_eq!(v.get("c").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("d"), Some(&Json::Null));
        // Round-trip through Display.
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn nested() {
        let v = Json::parse(r#"[{"x": {"y": [[]]}}]"#).unwrap();
        assert!(v.as_arr().unwrap()[0].get("x").unwrap().get("y").is_some());
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-0.25").unwrap().as_f64(), Some(-0.25));
        assert_eq!(Json::parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(Json::parse("42").unwrap().as_usize(), Some(42));
    }

    #[test]
    fn unicode_escape_and_multibyte() {
        let v = Json::parse(r#""é café ✓""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é café ✓");
    }

    #[test]
    fn errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn escaped_output() {
        let s = Json::Str("a\"b\\c\n".into()).to_string();
        assert_eq!(s, "\"a\\\"b\\\\c\\n\"");
        assert_eq!(Json::parse(&s).unwrap().as_str().unwrap(), "a\"b\\c\n");
    }
}
