//! Poison-tolerant synchronization helpers and a tiny counting semaphore.
//!
//! A panicking worker thread poisons any `Mutex` it held; the std default
//! then makes every *later* `lock()`/`wait()` unwrap panic too, turning
//! one engine bug into a poisoned-shutdown cascade (`close()`/`Drop`
//! re-panic while joining). The serving layers only guard plain queues and
//! maps behind their mutexes — data that stays structurally valid across a
//! panic at any await point — so the right policy is to **recover**: take
//! the guard out of the `PoisonError` and keep going.
//!
//! [`Semaphore`] is the admission-control primitive the router uses for
//! its in-flight cap: a lock-free permit counter with `try_acquire` (shed
//! on exhaustion — serving must never block the submitter) and `release`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard};

/// Lock a mutex, recovering the guard if a previous holder panicked.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Wait on a condvar, recovering the re-acquired guard from poison.
pub fn wait_recover<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(|e| e.into_inner())
}

/// A counting semaphore over an atomic permit counter. Non-blocking by
/// design: admission control *sheds* on permit exhaustion instead of
/// queueing the caller.
pub struct Semaphore {
    permits: AtomicUsize,
    capacity: usize,
}

impl Semaphore {
    /// Semaphore holding `capacity` permits.
    pub fn new(capacity: usize) -> Self {
        Semaphore { permits: AtomicUsize::new(capacity), capacity }
    }

    /// Total permits the semaphore was created with.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Permits currently available.
    pub fn available(&self) -> usize {
        self.permits.load(Ordering::Acquire)
    }

    /// Permits currently held (capacity − available).
    pub fn in_use(&self) -> usize {
        self.capacity - self.available().min(self.capacity)
    }

    /// Take one permit if any is available. Never blocks.
    pub fn try_acquire(&self) -> bool {
        self.permits
            .fetch_update(Ordering::AcqRel, Ordering::Acquire, |p| p.checked_sub(1))
            .is_ok()
    }

    /// Return one permit. Debug-asserts against releasing past capacity
    /// (a double-release bug in the caller).
    pub fn release(&self) {
        let prev = self.permits.fetch_add(1, Ordering::AcqRel);
        debug_assert!(prev < self.capacity, "semaphore released past capacity");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn lock_recover_survives_poison() {
        let m = Arc::new(Mutex::new(vec![1, 2, 3]));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the mutex");
        })
        .join();
        assert!(m.is_poisoned());
        let g = lock_recover(&m);
        assert_eq!(*g, vec![1, 2, 3], "data must survive the poisoning panic");
    }

    #[test]
    fn semaphore_counts_permits() {
        let s = Semaphore::new(2);
        assert_eq!(s.available(), 2);
        assert!(s.try_acquire());
        assert!(s.try_acquire());
        assert_eq!(s.in_use(), 2);
        assert!(!s.try_acquire(), "exhausted semaphore must shed, not block");
        s.release();
        assert_eq!(s.available(), 1);
        assert!(s.try_acquire());
    }

    #[test]
    fn semaphore_concurrent_acquires_never_oversubscribe() {
        let s = Arc::new(Semaphore::new(8));
        let acquired = Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let s = Arc::clone(&s);
                let acquired = Arc::clone(&acquired);
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        if s.try_acquire() {
                            acquired.fetch_add(1, Ordering::Relaxed);
                            s.release();
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.available(), 8, "all permits returned");
        assert!(acquired.load(Ordering::Relaxed) > 0);
    }
}
