//! Deterministic PCG32 random number generator.
//!
//! Used everywhere randomness is needed (synthetic workloads, random sparse
//! symbols for the kernel benches, proxy-metric projection matrices) so that
//! every experiment in EXPERIMENTS.md is exactly reproducible from a seed.

/// PCG32 (XSH-RR variant), O'Neill 2014. Deterministic and fast.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience constructor with the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Next raw 32-bit output.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next u64 (two draws).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free approximation is fine here (non-crypto).
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f32().max(1e-9);
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Exponential inter-arrival time with the given rate (per second).
    pub fn exp(&mut self, rate: f64) -> f64 {
        -(1.0 - self.f64()).ln() / rate
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Vector of standard-normal f32s.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::seeded(7);
        let mut b = Pcg32::seeded(7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Pcg32::seeded(3);
        for _ in 0..1000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Pcg32::seeded(4);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(5);
        let xs = r.normal_vec(20_000);
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(6);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
