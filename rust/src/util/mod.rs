//! Small self-contained utilities: JSON, RNG, tensor file IO, timing.
//!
//! The offline crate registry for this build only carries the `xla` crate's
//! dependency closure, so serde/serde_json/rand are unavailable; these
//! modules provide the minimal replacements the rest of the crate needs.

pub mod fot;
pub mod json;
pub mod rng;
pub mod sync;

use std::time::Instant;

/// Measure wall-clock seconds of a closure, returning `(result, seconds)`.
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Ceiling division for usize.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// Human-readable engineering formatting (e.g. `1.23G`, `45.6M`).
pub fn eng(x: f64) -> String {
    let ax = x.abs();
    if ax >= 1e12 {
        format!("{:.2}T", x / 1e12)
    } else if ax >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if ax >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if ax >= 1e3 {
        format!("{:.2}K", x / 1e3)
    } else {
        format!("{x:.2}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
    }

    #[test]
    fn eng_format() {
        assert_eq!(eng(1_500_000.0), "1.50M");
        assert_eq!(eng(2.0e9), "2.00G");
        assert_eq!(eng(12.0), "12.00");
    }

    #[test]
    fn timed_returns_value() {
        let (v, s) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }
}
