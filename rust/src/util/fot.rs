//! `.fot` — "FlashOmni tensors" — a minimal safetensors-like container.
//!
//! Layout: 4-byte magic `FOT1`, little-endian u64 header length, a JSON
//! header `{ "tensors": { name: {"dtype": "f32"|"u8"|"i32", "shape": [...],
//! "offset": n, "nbytes": n }, ... }, "meta": {...} }`, then the raw
//! little-endian payload. Written by `python/compile/export.py` and by this
//! module; read by both sides. Used for model weights, golden test vectors,
//! and generated images.

use super::json::Json;
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"FOT1";

/// Element type of a stored tensor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    U8,
    I32,
}

impl Dtype {
    pub fn name(self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::U8 => "u8",
            Dtype::I32 => "i32",
        }
    }
    pub fn size(self) -> usize {
        match self {
            Dtype::F32 | Dtype::I32 => 4,
            Dtype::U8 => 1,
        }
    }
    pub fn from_name(s: &str) -> Result<Self, String> {
        match s {
            "f32" => Ok(Dtype::F32),
            "u8" => Ok(Dtype::U8),
            "i32" => Ok(Dtype::I32),
            other => Err(format!("unknown dtype '{other}'")),
        }
    }
}

/// A tensor as stored in a `.fot` file.
#[derive(Clone, Debug)]
pub struct FotTensor {
    pub dtype: Dtype,
    pub shape: Vec<usize>,
    pub data: Vec<u8>,
}

impl FotTensor {
    pub fn from_f32(shape: &[usize], values: &[f32]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        let mut data = Vec::with_capacity(values.len() * 4);
        for v in values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        FotTensor { dtype: Dtype::F32, shape: shape.to_vec(), data }
    }

    pub fn from_u8(shape: &[usize], values: &[u8]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        FotTensor { dtype: Dtype::U8, shape: shape.to_vec(), data: values.to_vec() }
    }

    pub fn to_f32(&self) -> Result<Vec<f32>, String> {
        if self.dtype != Dtype::F32 {
            return Err(format!("tensor is {}, not f32", self.dtype.name()));
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn to_u8(&self) -> Result<Vec<u8>, String> {
        if self.dtype != Dtype::U8 {
            return Err(format!("tensor is {}, not u8", self.dtype.name()));
        }
        Ok(self.data.clone())
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// An in-memory `.fot` file: named tensors plus a free-form metadata object.
#[derive(Clone, Debug, Default)]
pub struct FotFile {
    pub tensors: BTreeMap<String, FotTensor>,
    pub meta: BTreeMap<String, Json>,
}

impl FotFile {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert_f32(&mut self, name: &str, shape: &[usize], values: &[f32]) {
        self.tensors.insert(name.to_string(), FotTensor::from_f32(shape, values));
    }

    pub fn insert_u8(&mut self, name: &str, shape: &[usize], values: &[u8]) {
        self.tensors.insert(name.to_string(), FotTensor::from_u8(shape, values));
    }

    /// Required tensor lookup.
    pub fn get(&self, name: &str) -> Result<&FotTensor, String> {
        self.tensors.get(name).ok_or_else(|| {
            let have: Vec<&str> = self.tensors.keys().map(|s| s.as_str()).take(8).collect();
            format!("tensor '{name}' not found (have e.g. {have:?})")
        })
    }

    /// Serialize to bytes.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut offset = 0usize;
        let mut hdr = BTreeMap::new();
        for (name, t) in &self.tensors {
            hdr.insert(
                name.clone(),
                Json::obj(vec![
                    ("dtype", Json::Str(t.dtype.name().into())),
                    ("shape", Json::arr_usize(&t.shape)),
                    ("offset", Json::Num(offset as f64)),
                    ("nbytes", Json::Num(t.data.len() as f64)),
                ]),
            );
            offset += t.data.len();
        }
        let header = Json::obj(vec![
            ("tensors", Json::Obj(hdr)),
            ("meta", Json::Obj(self.meta.clone())),
        ])
        .to_string();
        let mut out = Vec::with_capacity(12 + header.len() + offset);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&(header.len() as u64).to_le_bytes());
        out.extend_from_slice(header.as_bytes());
        for t in self.tensors.values() {
            out.extend_from_slice(&t.data);
        }
        out
    }

    /// Parse from bytes.
    pub fn from_bytes(bytes: &[u8]) -> Result<Self, String> {
        if bytes.len() < 12 || &bytes[..4] != MAGIC {
            return Err("not a FOT1 file".into());
        }
        let hlen = u64::from_le_bytes(bytes[4..12].try_into().unwrap()) as usize;
        if hlen > bytes.len().saturating_sub(12) {
            return Err("truncated header".into());
        }
        let header = std::str::from_utf8(&bytes[12..12 + hlen])
            .map_err(|_| "header not utf-8".to_string())?;
        let hv = Json::parse(header)?;
        let body = &bytes[12 + hlen..];
        let mut tensors = BTreeMap::new();
        for (name, spec) in hv.req("tensors")?.as_obj().ok_or("bad tensors field")? {
            let dtype = Dtype::from_name(spec.req("dtype")?.as_str().ok_or("bad dtype")?)?;
            let shape: Vec<usize> = spec
                .req("shape")?
                .as_arr()
                .ok_or("bad shape")?
                .iter()
                .map(|x| x.as_usize().ok_or("bad dim".to_string()))
                .collect::<Result<_, _>>()?;
            let offset = spec.req("offset")?.as_usize().ok_or("bad offset")?;
            let nbytes = spec.req("nbytes")?.as_usize().ok_or("bad nbytes")?;
            if offset + nbytes > body.len() {
                return Err(format!("tensor '{name}' out of bounds"));
            }
            if shape.iter().product::<usize>() * dtype.size() != nbytes {
                return Err(format!("tensor '{name}' shape/nbytes mismatch"));
            }
            tensors.insert(
                name.clone(),
                FotTensor { dtype, shape, data: body[offset..offset + nbytes].to_vec() },
            );
        }
        let meta = hv
            .get("meta")
            .and_then(|m| m.as_obj().cloned())
            .unwrap_or_default();
        Ok(FotFile { tensors, meta })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<(), String> {
        let bytes = self.to_bytes();
        let mut f = std::fs::File::create(path.as_ref())
            .map_err(|e| format!("create {}: {e}", path.as_ref().display()))?;
        f.write_all(&bytes).map_err(|e| e.to_string())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self, String> {
        let mut f = std::fs::File::open(path.as_ref())
            .map_err(|e| format!("open {}: {e}", path.as_ref().display()))?;
        let mut bytes = Vec::new();
        f.read_to_end(&mut bytes).map_err(|e| e.to_string())?;
        Self::from_bytes(&bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut f = FotFile::new();
        f.insert_f32("w", &[2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.5]);
        f.insert_u8("sym", &[4], &[224, 235, 197, 0]);
        f.meta.insert("note".into(), Json::Str("hello".into()));
        let bytes = f.to_bytes();
        let g = FotFile::from_bytes(&bytes).unwrap();
        assert_eq!(g.get("w").unwrap().shape, vec![2, 3]);
        assert_eq!(g.get("w").unwrap().to_f32().unwrap()[5], 6.5);
        assert_eq!(g.get("sym").unwrap().to_u8().unwrap(), vec![224, 235, 197, 0]);
        assert_eq!(g.meta.get("note").unwrap().as_str(), Some("hello"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(FotFile::from_bytes(b"nope").is_err());
        assert!(FotFile::from_bytes(b"FOT1\xff\xff\xff\xff\xff\xff\xff\xff").is_err());
    }

    #[test]
    fn missing_tensor_message() {
        let f = FotFile::new();
        let err = f.get("absent").unwrap_err();
        assert!(err.contains("absent"));
    }

    #[test]
    fn file_io() {
        let dir = std::env::temp_dir().join("fot_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.fot");
        let mut f = FotFile::new();
        f.insert_f32("x", &[3], &[0.5, -1.5, 2.0]);
        f.save(&path).unwrap();
        let g = FotFile::load(&path).unwrap();
        assert_eq!(g.get("x").unwrap().to_f32().unwrap(), vec![0.5, -1.5, 2.0]);
    }
}
