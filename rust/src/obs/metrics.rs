//! The process-wide **metrics registry**: statically-declared atomic
//! counters, gauges, and log₂-ns-bucketed latency histograms, plus the
//! Prometheus text exporter.
//!
//! Every instrument is a `static` declared in this file — registration is
//! the `const` initializer, enumeration is the explicit `all_*()` slices,
//! and the hot path is a handful of relaxed `fetch_add`s with no locking,
//! no hashing and no allocation. Counters and gauges self-gate on
//! [`metrics_enabled`](super::metrics_enabled); histogram recording is
//! driven by [`Span`](super::Span) guards which carry the gate decision
//! from construction time.

use super::metrics_enabled;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};

/// Number of log₂ latency buckets: bucket `i` covers `[2^i, 2^{i+1})` ns
/// (bucket 0 also absorbs 0 ns), so 40 buckets span 1 ns … ~18 minutes.
pub const HIST_BUCKETS: usize = 40;

// ---------------------------------------------------------------------------
// Instruments
// ---------------------------------------------------------------------------

/// Monotonic counter (Prometheus `counter`).
pub struct Counter {
    name: &'static str,
    help: &'static str,
    value: AtomicU64,
}

impl Counter {
    /// Declare a counter (const: used in `static` initializers).
    pub const fn new(name: &'static str, help: &'static str) -> Self {
        Counter { name, help, value: AtomicU64::new(0) }
    }
    /// Add `n`, if metrics are enabled (one relaxed load when disabled).
    #[inline]
    pub fn add(&self, n: u64) {
        if metrics_enabled() {
            self.value.fetch_add(n, Ordering::Relaxed);
        }
    }
    /// Increment by one (gated like [`Counter::add`]).
    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }
    /// Add unconditionally — internal bookkeeping that must count even
    /// when only tracing is enabled (e.g. dropped trace events).
    #[inline]
    pub(crate) fn add_ungated(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }
    /// Current value.
    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
    /// Metric name (Prometheus identifier).
    pub fn name(&self) -> &'static str {
        self.name
    }
}

/// Instantaneous gauge (Prometheus `gauge`): signed so transient
/// dec-past-zero interleavings under concurrency can never wrap.
pub struct Gauge {
    name: &'static str,
    help: &'static str,
    value: AtomicI64,
}

impl Gauge {
    /// Declare a gauge (const: used in `static` initializers).
    pub const fn new(name: &'static str, help: &'static str) -> Self {
        Gauge { name, help, value: AtomicI64::new(0) }
    }
    /// Set to an absolute value (gated on metrics being enabled).
    #[inline]
    pub fn set(&self, v: i64) {
        if metrics_enabled() {
            self.value.store(v, Ordering::Relaxed);
        }
    }
    /// Add `n` (gated).
    #[inline]
    pub fn add(&self, n: i64) {
        if metrics_enabled() {
            self.value.fetch_add(n, Ordering::Relaxed);
        }
    }
    /// Subtract `n` (gated).
    #[inline]
    pub fn sub(&self, n: i64) {
        self.add(-n);
    }
    /// Current value.
    pub fn get(&self) -> i64 {
        self.value.load(Ordering::Relaxed)
    }
    /// Metric name (Prometheus identifier).
    pub fn name(&self) -> &'static str {
        self.name
    }
}

/// Log₂-bucketed latency histogram over nanoseconds (Prometheus
/// `histogram` with power-of-two `le` bounds), with p50/p95/p99 readout.
pub struct Histogram {
    name: &'static str,
    help: &'static str,
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    sum_ns: AtomicU64,
}

/// Bucket index for a duration: `floor(log2(ns))` clamped to the table
/// (0 and 1 ns land in bucket 0).
#[inline]
pub fn bucket_index(ns: u64) -> usize {
    if ns < 2 {
        0
    } else {
        ((63 - ns.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
    }
}

/// Inclusive lower bound of bucket `i` in ns.
pub fn bucket_lo(i: usize) -> u64 {
    if i == 0 {
        0
    } else {
        1u64 << i
    }
}

/// Exclusive upper bound of bucket `i` in ns.
pub fn bucket_hi(i: usize) -> u64 {
    1u64 << (i + 1)
}

impl Histogram {
    /// Declare a histogram (const: used in `static` initializers).
    pub const fn new(name: &'static str, help: &'static str) -> Self {
        #[allow(clippy::declare_interior_mutable_const)]
        const ZERO: AtomicU64 = AtomicU64::new(0);
        Histogram {
            name,
            help,
            buckets: [ZERO; HIST_BUCKETS],
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
        }
    }
    /// Record one observation, **unconditionally** — callers carry the
    /// gate (a [`Span`](super::Span) decides at construction time so a
    /// run cannot tear between enter and drop).
    #[inline]
    pub fn record_ns(&self, ns: u64) {
        self.buckets[bucket_index(ns)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
    }
    /// Record one observation iff metrics are enabled.
    #[inline]
    pub fn observe_ns(&self, ns: u64) {
        if metrics_enabled() {
            self.record_ns(ns);
        }
    }
    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }
    /// Sum of all observations in ns.
    pub fn sum_ns(&self) -> u64 {
        self.sum_ns.load(Ordering::Relaxed)
    }
    /// Metric name (Prometheus identifier).
    pub fn name(&self) -> &'static str {
        self.name
    }
    /// Estimated `q`-quantile (0 < q ≤ 1) in ns: walk the cumulative
    /// bucket counts and interpolate linearly inside the target bucket.
    /// 0 when empty.
    pub fn quantile_ns(&self, q: f64) -> f64 {
        let counts: Vec<u64> =
            self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let target = q.clamp(0.0, 1.0) * total as f64;
        let mut cum = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let next = cum + c;
            if (next as f64) >= target {
                let frac = ((target - cum as f64) / c as f64).clamp(0.0, 1.0);
                let lo = bucket_lo(i) as f64;
                let hi = bucket_hi(i) as f64;
                return lo + frac * (hi - lo);
            }
            cum = next;
        }
        bucket_hi(HIST_BUCKETS - 1) as f64
    }
    /// p50 in seconds.
    pub fn p50_s(&self) -> f64 {
        self.quantile_ns(0.50) * 1e-9
    }
    /// p95 in seconds.
    pub fn p95_s(&self) -> f64 {
        self.quantile_ns(0.95) * 1e-9
    }
    /// p99 in seconds.
    pub fn p99_s(&self) -> f64 {
        self.quantile_ns(0.99) * 1e-9
    }
    fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum_ns.store(0, Ordering::Relaxed);
    }
}

// ---------------------------------------------------------------------------
// The registry: every instrument in the process, statically declared.
// ---------------------------------------------------------------------------

/// Plan-cache refresh outcomes (mirrors `RunStats::plan_cache_*`).
pub static PLAN_CACHE_HITS: Counter =
    Counter::new("fo_plan_cache_hits_total", "Symbol refreshes served from the plan cache");
/// Plan-cache misses (full or delta compiles).
pub static PLAN_CACHE_MISSES: Counter =
    Counter::new("fo_plan_cache_misses_total", "Symbol refreshes that compiled a plan");
/// Hits on a plan another request of the same batch step compiled.
pub static PLAN_CACHE_SHARED: Counter = Counter::new(
    "fo_plan_cache_shared_total",
    "Refreshes served by a plan compiled by a batch peer",
);
/// Misses served by an incremental (delta) recompile.
pub static PLAN_CACHE_DELTA: Counter = Counter::new(
    "fo_plan_cache_delta_total",
    "Cache misses served by an incremental (delta) recompile",
);
/// Requests entering a scheduler/coordinator queue.
pub static REQUESTS_ENQUEUED: Counter =
    Counter::new("fo_requests_enqueued_total", "Requests submitted to a scheduler queue");
/// Requests admitted into an engine slot.
pub static REQUESTS_ADMITTED: Counter =
    Counter::new("fo_requests_admitted_total", "Requests admitted into an engine slot");
/// Requests retired with a finished image.
pub static REQUESTS_RETIRED: Counter =
    Counter::new("fo_requests_retired_total", "Requests retired with a finished image");
/// Requests shed at admission (in-flight cap or queue bound hit).
pub static REQUESTS_SHED: Counter = Counter::new(
    "fo_request_shed_total",
    "Requests shed at admission (in-flight cap or queue bound hit)",
);
/// Requests retired unserved because their deadline expired while queued.
pub static REQUESTS_DEADLINE_MISS: Counter = Counter::new(
    "fo_request_deadline_miss_total",
    "Requests whose deadline expired before they reached a batch slot",
);
/// Streaming preview frames decoded and emitted.
pub static REQUESTS_PREVIEW: Counter = Counter::new(
    "fo_request_preview_total",
    "Streaming preview frames decoded mid-denoise",
);
/// Engine steps executed (solo or batched lockstep ticks).
pub static ENGINE_STEPS: Counter =
    Counter::new("fo_engine_steps_total", "Denoising engine steps executed");
/// Autotuner measurements committed to the process-wide tune cache.
pub static TUNE_MEASUREMENTS: Counter = Counter::new(
    "fo_tune_measurements_total",
    "Autotuner configs measured and cached (FO_TUNE=1)",
);
/// Parallel sections dispatched on the exec pool.
pub static EXEC_SECTIONS: Counter =
    Counter::new("fo_exec_sections_total", "Parallel sections dispatched on the ExecPool");
/// Trace events discarded once the bounded buffer filled.
pub static TRACE_EVENTS_DROPPED: Counter = Counter::new(
    "fo_trace_events_dropped_total",
    "Trace events discarded after the bounded buffer filled",
);
/// Pages allocated by the paged memory pool (`mem::PagePool`).
pub static MEM_PAGES_ALLOCATED: Counter =
    Counter::new("fo_mem_pages_allocated_total", "Pages allocated by the paged memory pool");
/// Pages freed by eviction under `FO_PAGE_BUDGET` pressure.
pub static MEM_PAGES_EVICTED: Counter = Counter::new(
    "fo_mem_pages_evicted_total",
    "Pages evicted from the paged memory pool under budget pressure",
);
/// Allocations served by an existing content-identical block.
pub static MEM_SHARE_HITS: Counter = Counter::new(
    "fo_mem_share_hits_total",
    "Pool allocations served by prefix-sharing an existing block",
);
/// Copy-on-write copies of shared or interned pool blocks.
pub static MEM_COW_COPIES: Counter = Counter::new(
    "fo_mem_cow_copies_total",
    "Copy-on-write copies of shared or interned pool blocks",
);

/// Jobs pending in the exec pool queue at dispatch time.
pub static EXEC_QUEUE_DEPTH: Gauge =
    Gauge::new("fo_exec_queue_depth", "Jobs pending in the ExecPool queue at dispatch");
/// Worker lanes participating in the current parallel section.
pub static EXEC_ACTIVE_LANES: Gauge = Gauge::new(
    "fo_exec_active_lanes",
    "Worker lanes participating in the current parallel section",
);
/// Requests waiting in the router's admission queue.
pub static ROUTER_QUEUE_DEPTH: Gauge =
    Gauge::new("fo_router_queue_depth", "Requests waiting in the router admission queue");
/// Pages resident in the paged memory pool (live + retained).
pub static MEM_RESIDENT_PAGES: Gauge =
    Gauge::new("fo_mem_resident_pages", "Pages resident in the paged memory pool");
/// Pages referenced by at least one live pool handle.
pub static MEM_LIVE_PAGES: Gauge =
    Gauge::new("fo_mem_live_pages", "Pages referenced by at least one live pool handle");

/// GEMM-Q dense (full path: joint QKV projection region).
pub static KERNEL_GEMM_Q_DENSE: Histogram =
    Histogram::new("fo_kernel_gemm_q_dense_ns", "Dense QKV projection region (full path)");
/// GEMM-Q sparse (plan-driven Q projection with tile skipping).
pub static KERNEL_GEMM_Q_SPARSE: Histogram =
    Histogram::new("fo_kernel_gemm_q_sparse_ns", "Sparse GEMM-Q region (Dispatch path)");
/// GEMM-Q ragged (stacked multi-request projection walk).
pub static KERNEL_GEMM_Q_RAGGED: Histogram =
    Histogram::new("fo_kernel_gemm_q_ragged_ns", "Ragged GEMM-Q region (batched walk)");
/// Attention dense (full-path joint attention).
pub static KERNEL_ATTENTION_DENSE: Histogram =
    Histogram::new("fo_kernel_attention_dense_ns", "Dense joint attention (full path)");
/// Attention sparse (Algorithm 1 with block skipping).
pub static KERNEL_ATTENTION_SPARSE: Histogram =
    Histogram::new("fo_kernel_attention_sparse_ns", "Sparse FlashOmni attention (Alg. 1)");
/// Attention ragged (one kernel walk over concatenated requests).
pub static KERNEL_ATTENTION_RAGGED: Histogram =
    Histogram::new("fo_kernel_attention_ragged_ns", "Ragged FlashOmni attention walk");
/// GEMM-O dense (full-path output projection + bias-stack build).
pub static KERNEL_GEMM_O_DENSE: Histogram =
    Histogram::new("fo_kernel_gemm_o_dense_ns", "Dense GEMM-O region (full path)");
/// GEMM-O sparse (bias init + computed tiles only).
pub static KERNEL_GEMM_O_SPARSE: Histogram =
    Histogram::new("fo_kernel_gemm_o_sparse_ns", "Sparse GEMM-O dispatch region");
/// GEMM-O ragged.
pub static KERNEL_GEMM_O_RAGGED: Histogram =
    Histogram::new("fo_kernel_gemm_o_ragged_ns", "Ragged GEMM-O region (batched walk)");
/// MLP + residual tail, dense/full path.
pub static KERNEL_MLP_DENSE: Histogram =
    Histogram::new("fo_kernel_mlp_dense_ns", "MLP + residual tail (full path)");
/// MLP + residual tail, sparse path.
pub static KERNEL_MLP_SPARSE: Histogram =
    Histogram::new("fo_kernel_mlp_sparse_ns", "MLP + residual tail (Dispatch path)");
/// MLP + residual tail, ragged path.
pub static KERNEL_MLP_RAGGED: Histogram =
    Histogram::new("fo_kernel_mlp_ragged_ns", "MLP + residual tail (ragged walk)");

/// Full (from-scratch) plan compiles.
pub static PLAN_COMPILE_FULL: Histogram =
    Histogram::new("fo_plan_compile_full_ns", "Full (from-scratch) plan compiles");
/// Incremental (delta) plan recompiles.
pub static PLAN_COMPILE_DELTA: Histogram =
    Histogram::new("fo_plan_compile_delta_ns", "Incremental (delta) plan recompiles");
/// Whole symbol-refresh region: mask emission + packing + [delta-]compile
/// + TaylorSeer update ([`PLAN_COMPILE_FULL`]/[`PLAN_COMPILE_DELTA`] nest
/// inside and are excluded from step-coverage accounting).
pub static PLAN_REFRESH: Histogram = Histogram::new(
    "fo_plan_refresh_ns",
    "Symbol refresh region (masks + packing + plan [delta-]compile)",
);
/// Whole-block forecast path (CachedBlock).
pub static BLOCK_CACHED: Histogram =
    Histogram::new("fo_block_cached_ns", "Whole-block forecast path (CachedBlock)");
/// Per-request noise/patchify/embedding region of a batched step.
pub static MODEL_EMBED: Histogram =
    Histogram::new("fo_model_embed_ns", "Embedding/patchify region of an engine step");
/// Per-request sampler/decode region of a batched step.
pub static MODEL_DECODE: Histogram =
    Histogram::new("fo_model_decode_ns", "Sampler/decode region of an engine step");
/// One engine step (solo `DiTEngine` or batched lockstep tick).
pub static ENGINE_STEP: Histogram =
    Histogram::new("fo_engine_step_ns", "One engine step (solo or batched lockstep tick)");
/// Retirement sweep: unpatchify + stats finalization for finished slots.
pub static ENGINE_RETIRE: Histogram =
    Histogram::new("fo_engine_retire_ns", "Retirement sweep for finished slots");
/// One parallel section on the exec pool (dispatch → last lane done).
pub static EXEC_SECTION: Histogram =
    Histogram::new("fo_exec_section_ns", "One parallel section on the ExecPool");
/// Per-request queue wait (enqueue → admit).
pub static REQUEST_QUEUE_WAIT: Histogram =
    Histogram::new("fo_request_queue_wait_ns", "Per-request queue wait (enqueue to admit)");
/// Per-request execution time (admit → retire).
pub static REQUEST_EXEC: Histogram =
    Histogram::new("fo_request_exec_ns", "Per-request execution time (admit to retire)");
/// Streaming-preview decode region (cheap mid-denoise unpatchify).
pub static REQUEST_PREVIEW_DECODE: Histogram = Histogram::new(
    "fo_request_preview_ns",
    "Streaming-preview decode region of an engine step",
);

/// Every counter in the process, for exporters and tests.
pub fn all_counters() -> &'static [&'static Counter] {
    &[
        &PLAN_CACHE_HITS,
        &PLAN_CACHE_MISSES,
        &PLAN_CACHE_SHARED,
        &PLAN_CACHE_DELTA,
        &REQUESTS_ENQUEUED,
        &REQUESTS_ADMITTED,
        &REQUESTS_RETIRED,
        &REQUESTS_SHED,
        &REQUESTS_DEADLINE_MISS,
        &REQUESTS_PREVIEW,
        &ENGINE_STEPS,
        &TUNE_MEASUREMENTS,
        &EXEC_SECTIONS,
        &TRACE_EVENTS_DROPPED,
        &MEM_PAGES_ALLOCATED,
        &MEM_PAGES_EVICTED,
        &MEM_SHARE_HITS,
        &MEM_COW_COPIES,
    ]
}

/// Every gauge in the process.
pub fn all_gauges() -> &'static [&'static Gauge] {
    &[
        &EXEC_QUEUE_DEPTH,
        &EXEC_ACTIVE_LANES,
        &ROUTER_QUEUE_DEPTH,
        &MEM_RESIDENT_PAGES,
        &MEM_LIVE_PAGES,
    ]
}

/// Every histogram in the process.
pub fn all_histograms() -> &'static [&'static Histogram] {
    &[
        &KERNEL_GEMM_Q_DENSE,
        &KERNEL_GEMM_Q_SPARSE,
        &KERNEL_GEMM_Q_RAGGED,
        &KERNEL_ATTENTION_DENSE,
        &KERNEL_ATTENTION_SPARSE,
        &KERNEL_ATTENTION_RAGGED,
        &KERNEL_GEMM_O_DENSE,
        &KERNEL_GEMM_O_SPARSE,
        &KERNEL_GEMM_O_RAGGED,
        &KERNEL_MLP_DENSE,
        &KERNEL_MLP_SPARSE,
        &KERNEL_MLP_RAGGED,
        &PLAN_COMPILE_FULL,
        &PLAN_COMPILE_DELTA,
        &PLAN_REFRESH,
        &BLOCK_CACHED,
        &MODEL_EMBED,
        &MODEL_DECODE,
        &ENGINE_STEP,
        &ENGINE_RETIRE,
        &EXEC_SECTION,
        &REQUEST_QUEUE_WAIT,
        &REQUEST_EXEC,
        &REQUEST_PREVIEW_DECODE,
    ]
}

/// The mutually-exclusive regions that tile an engine step: the twelve
/// kernel-family histograms plus refresh/cache/embed/decode/preview/
/// retire. Their `sum_ns` over [`ENGINE_STEP`]'s `sum_ns` is the step
/// coverage the fig12 acceptance gate asserts ≥ 0.95 (`plan.compile_*`
/// nests inside `plan.refresh` and is deliberately absent).
pub fn accounted_histograms() -> &'static [&'static Histogram] {
    &[
        &KERNEL_GEMM_Q_DENSE,
        &KERNEL_GEMM_Q_SPARSE,
        &KERNEL_GEMM_Q_RAGGED,
        &KERNEL_ATTENTION_DENSE,
        &KERNEL_ATTENTION_SPARSE,
        &KERNEL_ATTENTION_RAGGED,
        &KERNEL_GEMM_O_DENSE,
        &KERNEL_GEMM_O_SPARSE,
        &KERNEL_GEMM_O_RAGGED,
        &KERNEL_MLP_DENSE,
        &KERNEL_MLP_SPARSE,
        &KERNEL_MLP_RAGGED,
        &PLAN_REFRESH,
        &BLOCK_CACHED,
        &MODEL_EMBED,
        &MODEL_DECODE,
        &ENGINE_RETIRE,
        &REQUEST_PREVIEW_DECODE,
    ]
}

/// Fraction of [`ENGINE_STEP`] wall time covered by the accounted
/// per-kernel-family regions ([`accounted_histograms`]). 0 when no steps
/// were recorded.
pub fn accounted_step_fraction() -> f64 {
    let step = ENGINE_STEP.sum_ns();
    if step == 0 {
        return 0.0;
    }
    let covered: u64 = accounted_histograms().iter().map(|h| h.sum_ns()).sum();
    covered as f64 / step as f64
}

/// Zero every instrument (tests only: the registry is process-global).
pub fn reset_metrics() {
    for c in all_counters() {
        c.value.store(0, Ordering::Relaxed);
    }
    for g in all_gauges() {
        g.value.store(0, Ordering::Relaxed);
    }
    for h in all_histograms() {
        h.reset();
    }
}

/// Render the whole registry in Prometheus text exposition format.
/// Histograms use power-of-two `le` bounds in ns plus `+Inf`, with a
/// comment line carrying the p50/p95/p99 readout.
pub fn prometheus_text() -> String {
    let mut out = String::with_capacity(1 << 14);
    for c in all_counters() {
        out.push_str(&format!("# HELP {} {}\n", c.name, c.help));
        out.push_str(&format!("# TYPE {} counter\n", c.name));
        out.push_str(&format!("{} {}\n", c.name, c.get()));
    }
    for g in all_gauges() {
        out.push_str(&format!("# HELP {} {}\n", g.name, g.help));
        out.push_str(&format!("# TYPE {} gauge\n", g.name));
        out.push_str(&format!("{} {}\n", g.name, g.get()));
    }
    for h in all_histograms() {
        out.push_str(&format!("# HELP {} {}\n", h.name, h.help));
        out.push_str(&format!("# TYPE {} histogram\n", h.name));
        out.push_str(&format!(
            "# p50 {:.0}ns p95 {:.0}ns p99 {:.0}ns\n",
            h.quantile_ns(0.50),
            h.quantile_ns(0.95),
            h.quantile_ns(0.99)
        ));
        let mut cum = 0u64;
        for (i, b) in h.buckets.iter().enumerate() {
            let n = b.load(Ordering::Relaxed);
            // Keep the dump short: only emit buckets once data appears.
            cum += n;
            if n > 0 {
                out.push_str(&format!(
                    "{}_bucket{{le=\"{}\"}} {}\n",
                    h.name,
                    bucket_hi(i),
                    cum
                ));
            }
        }
        out.push_str(&format!("{}_bucket{{le=\"+Inf\"}} {}\n", h.name, h.count()));
        out.push_str(&format!("{}_sum {}\n", h.name, h.sum_ns()));
        out.push_str(&format!("{}_count {}\n", h.name, h.count()));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 0);
        assert_eq!(bucket_index(2), 1);
        assert_eq!(bucket_index(3), 1);
        assert_eq!(bucket_index(4), 2);
        assert_eq!(bucket_index(1023), 9);
        assert_eq!(bucket_index(1024), 10);
        assert_eq!(bucket_index(u64::MAX), HIST_BUCKETS - 1);
        assert_eq!(bucket_lo(0), 0);
        assert_eq!(bucket_hi(0), 2);
        assert_eq!(bucket_lo(10), 1024);
        assert_eq!(bucket_hi(10), 2048);
    }

    #[test]
    fn registry_names_are_unique() {
        let mut names: Vec<&str> = all_counters().iter().map(|c| c.name).collect();
        names.extend(all_gauges().iter().map(|g| g.name));
        names.extend(all_histograms().iter().map(|h| h.name));
        let total = names.len();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), total, "duplicate metric names in the registry");
    }
}
