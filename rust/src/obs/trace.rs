//! Chrome **trace-event** collection: a bounded, process-global buffer of
//! complete (`"X"`) slices written as Trace Event Format JSON —
//! loadable directly in [Perfetto](https://ui.perfetto.dev) or
//! `chrome://tracing`.
//!
//! Two tracks keep nesting trivially valid:
//!
//! * **pid 1 — engine threads.** Every [`Span`](super::Span) becomes a
//!   slice on its OS thread's own `tid` (assigned in first-use order), so
//!   per-thread slices nest exactly as the call stack did.
//! * **pid 2 — requests.** Per-request lifecycle slices
//!   (`request.queue_wait`, `request.exec`) use `tid = request id`: one
//!   row per request, two adjacent slices, never interleaved with kernel
//!   spans.
//!
//! The buffer is bounded ([`EVENT_CAP`]); once full, new events are
//! counted in `fo_trace_events_dropped_total` and discarded — tracing
//! must never grow without bound inside a serving process.

use super::metrics::TRACE_EVENTS_DROPPED;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::{Duration, Instant};

/// Engine-thread track (every [`Span`](super::Span) slice).
pub const PID_ENGINE: u32 = 1;
/// Request-lifecycle track (`tid` = request id).
pub const PID_REQUESTS: u32 = 2;

/// Maximum buffered events; beyond this, events are dropped (and counted).
pub const EVENT_CAP: usize = 1 << 20;

#[derive(Clone, Copy)]
struct TraceEvent {
    name: &'static str,
    pid: u32,
    tid: u64,
    ts_ns: u64,
    dur_ns: u64,
}

static EVENTS: Mutex<Vec<TraceEvent>> = Mutex::new(Vec::new());
static EPOCH: OnceLock<Instant> = OnceLock::new();
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    /// Stable per-thread trace `tid`, assigned in first-use order.
    static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
}

/// The process trace epoch (`ts = 0`), pinned on first use.
fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

fn push(ev: TraceEvent) {
    let mut events = EVENTS.lock().unwrap_or_else(|e| e.into_inner());
    if events.len() >= EVENT_CAP {
        TRACE_EVENTS_DROPPED.add_ungated(1);
        return;
    }
    events.push(ev);
}

/// Append a complete slice for the current thread on the engine track.
/// Called by [`Span`](super::Span) on drop; the span already checked the
/// gate.
pub(crate) fn push_complete(name: &'static str, start: Instant, dur: Duration) {
    let ts_ns = start.saturating_duration_since(epoch()).as_nanos() as u64;
    push(TraceEvent {
        name,
        pid: PID_ENGINE,
        tid: TID.with(|t| *t),
        ts_ns,
        dur_ns: dur.as_nanos() as u64,
    });
}

/// Append a per-request lifecycle slice (`request.queue_wait` /
/// `request.exec`) on the request track, `tid = request id`. No-op when
/// tracing is disabled.
pub fn push_request_slice(name: &'static str, request_id: u64, start: Instant, dur: Duration) {
    if !super::trace_enabled() {
        return;
    }
    let ts_ns = start.saturating_duration_since(epoch()).as_nanos() as u64;
    push(TraceEvent {
        name,
        pid: PID_REQUESTS,
        tid: request_id,
        ts_ns,
        dur_ns: dur.as_nanos() as u64,
    });
}

/// Number of buffered events (tests and export logging).
pub fn event_count() -> usize {
    EVENTS.lock().unwrap_or_else(|e| e.into_inner()).len()
}

/// Drop all buffered events (tests: the buffer is process-global).
pub fn clear() {
    EVENTS.lock().unwrap_or_else(|e| e.into_inner()).clear();
}

/// Serialize the buffer as Trace Event Format JSON. Events are sorted by
/// `(pid, tid, ts, −dur)` so each track reads top-down as a well-nested
/// stack; `ts`/`dur` are microseconds (the format's unit) with ns
/// precision kept in the fraction.
pub fn chrome_trace_json() -> String {
    let events: Vec<TraceEvent> = {
        let guard = EVENTS.lock().unwrap_or_else(|e| e.into_inner());
        guard.clone()
    };
    let mut sorted = events;
    sorted.sort_by(|a, b| {
        (a.pid, a.tid, a.ts_ns)
            .cmp(&(b.pid, b.tid, b.ts_ns))
            .then(b.dur_ns.cmp(&a.dur_ns))
    });
    let mut out = String::with_capacity(64 + sorted.len() * 96);
    out.push_str("{\"traceEvents\":[\n");
    out.push_str(&format!(
        "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{PID_ENGINE},\"tid\":0,\
         \"args\":{{\"name\":\"flashomni engine\"}}}},\n"
    ));
    out.push_str(&format!(
        "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{PID_REQUESTS},\"tid\":0,\
         \"args\":{{\"name\":\"requests\"}}}}"
    ));
    for ev in &sorted {
        out.push_str(&format!(
            ",\n{{\"name\":\"{}\",\"cat\":\"fo\",\"ph\":\"X\",\"pid\":{},\"tid\":{},\
             \"ts\":{:.3},\"dur\":{:.3}}}",
            ev.name,
            ev.pid,
            ev.tid,
            ev.ts_ns as f64 / 1e3,
            ev.dur_ns as f64 / 1e3,
        ));
    }
    out.push_str("\n],\"displayTimeUnit\":\"ms\"}\n");
    out
}

/// Write the buffered trace to `path`; returns the number of slices
/// written (metadata records excluded).
pub fn write_chrome_trace(path: &str) -> std::io::Result<usize> {
    let n = event_count();
    std::fs::write(path, chrome_trace_json())?;
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::super::{set_trace_enabled, TEST_GATE};
    use super::*;

    #[test]
    fn request_slices_buffer_and_serialize() {
        let _g = TEST_GATE.lock().unwrap_or_else(|e| e.into_inner());
        set_trace_enabled(Some(true));
        clear();
        let t0 = Instant::now();
        push_request_slice("request.queue_wait", 7, t0, Duration::from_micros(5));
        push_request_slice("request.exec", 7, t0, Duration::from_micros(9));
        assert_eq!(event_count(), 2);
        let json = chrome_trace_json();
        assert!(json.contains("\"request.queue_wait\""));
        assert!(json.contains("\"request.exec\""));
        assert!(json.contains("\"traceEvents\""));
        clear();
        set_trace_enabled(None);
        // Disabled: push is a no-op.
        set_trace_enabled(Some(false));
        push_request_slice("request.exec", 8, Instant::now(), Duration::ZERO);
        assert_eq!(event_count(), 0);
        set_trace_enabled(None);
    }
}
