//! Process-wide **observability layer**: metrics registry, timing spans,
//! request-lifecycle tracing, and Prometheus / Chrome-trace export.
//!
//! Everything here is std-only and **off by default**. Two env knobs gate
//! the two concerns independently:
//!
//! * `FO_METRICS` — atomic counters, gauges and log₂-ns-bucketed latency
//!   histograms ([`metrics`]). `FO_METRICS=1` enables recording and makes
//!   [`export_if_enabled`] write the registry in Prometheus text format
//!   to `fo_metrics.prom`; any other truthy value is used as the output
//!   path instead.
//! * `FO_TRACE` — Chrome trace-event collection ([`trace`]): every
//!   [`Span`] becomes a complete (`"X"`) slice, every request a pair of
//!   `request.queue_wait` / `request.exec` slices on a dedicated track.
//!   `FO_TRACE=1` writes `fo_trace.json` on [`export_if_enabled`]; any
//!   other truthy value is the output path. The file loads directly in
//!   [Perfetto](https://ui.perfetto.dev) or `chrome://tracing`.
//!
//! With both unset the layer is inert: a [`Span`] is two relaxed atomic
//! loads and no `Instant::now()`, counters are a single load, and nothing
//! allocates — engine outputs are bitwise-identical either way
//! (`rust/tests/observability.rs`).
//!
//! The full metric/span vocabulary and both exporter schemas are
//! documented in `docs/observability.md`.

pub mod metrics;
pub mod span;
pub mod trace;

pub use metrics::{
    accounted_step_fraction, prometheus_text, reset_metrics, Counter, Gauge, Histogram,
};
pub use span::Span;

use std::sync::atomic::{AtomicI8, Ordering};
use std::sync::OnceLock;

/// Tri-state override: −1 = follow the env knob, 0 = forced off,
/// 1 = forced on (tests flip these process-wide).
static METRICS_FORCED: AtomicI8 = AtomicI8::new(-1);
static TRACE_FORCED: AtomicI8 = AtomicI8::new(-1);

static METRICS_ENV: OnceLock<Option<String>> = OnceLock::new();
static TRACE_ENV: OnceLock<Option<String>> = OnceLock::new();

/// Read a gate knob once: `None` when unset/off ("", "0", "off",
/// "false"), otherwise the raw value (truthy).
fn knob(name: &str) -> Option<String> {
    match std::env::var(name) {
        Ok(v) if !matches!(v.as_str(), "" | "0" | "off" | "false") => Some(v),
        _ => None,
    }
}

fn metrics_knob() -> &'static Option<String> {
    METRICS_ENV.get_or_init(|| knob("FO_METRICS"))
}

fn trace_knob() -> &'static Option<String> {
    TRACE_ENV.get_or_init(|| knob("FO_TRACE"))
}

/// Is metric recording on (`FO_METRICS` truthy, or forced by
/// [`set_metrics_enabled`])? Hot-path cheap: one relaxed load plus a
/// cached env lookup.
#[inline]
pub fn metrics_enabled() -> bool {
    match METRICS_FORCED.load(Ordering::Relaxed) {
        0 => false,
        1 => true,
        _ => metrics_knob().is_some(),
    }
}

/// Is trace-event collection on (`FO_TRACE` truthy, or forced by
/// [`set_trace_enabled`])?
#[inline]
pub fn trace_enabled() -> bool {
    match TRACE_FORCED.load(Ordering::Relaxed) {
        0 => false,
        1 => true,
        _ => trace_knob().is_some(),
    }
}

/// Force metrics on/off for this process (`None` = follow `FO_METRICS`).
/// Test hook — the knob itself is read once and cached.
pub fn set_metrics_enabled(on: Option<bool>) {
    let v = match on {
        None => -1,
        Some(false) => 0,
        Some(true) => 1,
    };
    METRICS_FORCED.store(v, Ordering::Relaxed);
}

/// Force tracing on/off for this process (`None` = follow `FO_TRACE`).
pub fn set_trace_enabled(on: Option<bool>) {
    let v = match on {
        None => -1,
        Some(false) => 0,
        Some(true) => 1,
    };
    TRACE_FORCED.store(v, Ordering::Relaxed);
}

/// Default Prometheus dump path when `FO_METRICS` is a bare "1"/"on"/"true".
pub const DEFAULT_METRICS_PATH: &str = "fo_metrics.prom";
/// Default Chrome-trace path when `FO_TRACE` is a bare "1"/"on"/"true".
pub const DEFAULT_TRACE_PATH: &str = "fo_trace.json";

fn export_path(raw: &Option<String>, default: &str) -> String {
    match raw {
        Some(v) if !matches!(v.as_str(), "1" | "on" | "true") => v.clone(),
        _ => default.to_string(),
    }
}

/// Where [`export_if_enabled`] writes the Prometheus text dump.
pub fn metrics_export_path() -> String {
    export_path(metrics_knob(), DEFAULT_METRICS_PATH)
}

/// Where [`export_if_enabled`] writes the Chrome trace JSON.
pub fn trace_export_path() -> String {
    export_path(trace_knob(), DEFAULT_TRACE_PATH)
}

/// Export whatever is enabled: the Prometheus text dump when metrics are
/// on, the Chrome trace JSON when tracing is on. Returns the paths
/// written (empty when both knobs are off); write errors go to stderr
/// rather than panicking — telemetry must never take a run down.
pub fn export_if_enabled() -> Vec<String> {
    let mut written = Vec::new();
    if metrics_enabled() {
        let path = metrics_export_path();
        match std::fs::write(&path, prometheus_text()) {
            Ok(()) => written.push(path),
            Err(e) => eprintln!("obs: could not write {path}: {e}"),
        }
    }
    if trace_enabled() {
        let path = trace_export_path();
        match trace::write_chrome_trace(&path) {
            Ok(_) => written.push(path),
            Err(e) => eprintln!("obs: could not write {path}: {e}"),
        }
    }
    written
}

/// Serializes tests that flip the process-global gates (the registry and
/// the gates are shared by every test thread in a binary).
#[cfg(test)]
pub(crate) static TEST_GATE: std::sync::Mutex<()> = std::sync::Mutex::new(());

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forced_gates_override() {
        let _g = TEST_GATE.lock().unwrap_or_else(|e| e.into_inner());
        // Default: follows env (unset in the test harness → off).
        set_metrics_enabled(Some(true));
        assert!(metrics_enabled());
        set_metrics_enabled(Some(false));
        assert!(!metrics_enabled());
        set_metrics_enabled(None);
        set_trace_enabled(Some(true));
        assert!(trace_enabled());
        set_trace_enabled(None);
    }

    #[test]
    fn export_paths_default() {
        // With the knobs unset (or bare "1"), the defaults apply.
        assert_eq!(export_path(&None, DEFAULT_METRICS_PATH), DEFAULT_METRICS_PATH);
        assert_eq!(
            export_path(&Some("1".to_string()), DEFAULT_TRACE_PATH),
            DEFAULT_TRACE_PATH
        );
        assert_eq!(
            export_path(&Some("/tmp/x.json".to_string()), DEFAULT_TRACE_PATH),
            "/tmp/x.json"
        );
    }
}
