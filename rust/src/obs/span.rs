//! Scoped **timing spans**: RAII guards that time a region into a
//! [`Histogram`] (when `FO_METRICS` is on) and append a Chrome
//! trace-event slice (when `FO_TRACE` is on).
//!
//! The gate is sampled once at [`Span::enter`]: a disabled span stores
//! `None` and its drop is a single branch — no `Instant::now()`, no
//! allocation, nothing observable from the timed region.

use super::metrics::Histogram;
use super::{metrics_enabled, trace_enabled};
use std::time::Instant;

/// RAII timing guard over a named region. Construct with [`Span::enter`]
/// at the top of the region; the measurement is recorded when the guard
/// drops.
#[must_use = "a Span measures the scope it is alive in — bind it with `let _span = …`"]
pub struct Span {
    /// `Some` iff either sink was enabled at enter time.
    start: Option<Instant>,
    name: &'static str,
    hist: &'static Histogram,
}

impl Span {
    /// Open a span named `name`, recording into `hist` on drop. The
    /// trace-event slice reuses `name` verbatim, so span names double as
    /// the vocabulary in `fo_trace.json` (see `docs/observability.md`).
    #[inline]
    pub fn enter(name: &'static str, hist: &'static Histogram) -> Span {
        let start =
            if metrics_enabled() || trace_enabled() { Some(Instant::now()) } else { None };
        Span { start, name, hist }
    }

    /// The region's name (also the trace-event name).
    pub fn name(&self) -> &'static str {
        self.name
    }
}

impl Drop for Span {
    #[inline]
    fn drop(&mut self) {
        if let Some(t0) = self.start {
            let dur = t0.elapsed();
            if metrics_enabled() {
                self.hist.record_ns(dur.as_nanos() as u64);
            }
            if trace_enabled() {
                super::trace::push_complete(self.name, t0, dur);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::metrics::ENGINE_STEP;
    use super::super::{set_metrics_enabled, set_trace_enabled, TEST_GATE};
    use super::*;

    #[test]
    fn span_gating() {
        let _g = TEST_GATE.lock().unwrap_or_else(|e| e.into_inner());
        // Disabled: the guard must not touch the histogram.
        set_metrics_enabled(Some(false));
        set_trace_enabled(Some(false));
        let before = ENGINE_STEP.count();
        {
            let _s = Span::enter("engine.step", &ENGINE_STEP);
        }
        assert_eq!(ENGINE_STEP.count(), before);
        // Enabled: exactly this guard's observation lands (other tests may
        // also record concurrently, so assert growth, not equality).
        set_metrics_enabled(Some(true));
        let before = ENGINE_STEP.count();
        {
            let _s = Span::enter("engine.step", &ENGINE_STEP);
            std::hint::black_box(1 + 1);
        }
        assert!(ENGINE_STEP.count() > before);
        set_metrics_enabled(None);
        set_trace_enabled(None);
    }
}
