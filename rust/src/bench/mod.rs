//! In-tree micro-benchmark harness.
//!
//! criterion is not available in this offline environment, so the bench
//! binaries under `benches/` use this small harness instead: fixed warmup,
//! adaptive iteration count targeting a measurement budget, and robust
//! statistics (min / median / median-absolute-deviation).

use std::time::Instant;

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    /// Median seconds per iteration.
    pub median_s: f64,
    /// Minimum seconds per iteration (least-noise estimate).
    pub min_s: f64,
    /// Median absolute deviation in seconds.
    pub mad_s: f64,
    pub iters: usize,
}

impl Measurement {
    /// Speedup of `baseline` relative to this measurement (how many times
    /// faster `self` is than `baseline`): `baseline.median / self.median`.
    pub fn speedup_vs(&self, baseline: &Measurement) -> f64 {
        baseline.median_s / self.median_s
    }
}

/// Benchmark runner with a wall-clock budget per benchmark.
pub struct Bencher {
    /// Warmup iterations before measuring.
    pub warmup: usize,
    /// Minimum measured iterations.
    pub min_iters: usize,
    /// Target measurement budget in seconds.
    pub budget_s: f64,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup: 2, min_iters: 5, budget_s: 1.0 }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher { warmup: 1, min_iters: 3, budget_s: 0.3 }
    }

    /// Run a closure repeatedly and collect robust timing statistics.
    /// The closure must do the full unit of work each call; use `std::hint::
    /// black_box` inside it to defeat DCE.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> Measurement {
        for _ in 0..self.warmup {
            f();
        }
        // Estimate single-iteration time to size the loop.
        let t0 = Instant::now();
        f();
        let est = t0.elapsed().as_secs_f64().max(1e-9);
        let iters = ((self.budget_s / est).ceil() as usize).clamp(self.min_iters, 10_000);
        let mut samples = Vec::with_capacity(iters + 1);
        samples.push(est);
        for _ in 0..iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = samples[samples.len() / 2];
        let min = samples[0];
        let mut devs: Vec<f64> = samples.iter().map(|s| (s - median).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mad = devs[devs.len() / 2];
        Measurement {
            name: name.to_string(),
            median_s: median,
            min_s: min,
            mad_s: mad,
            iters: samples.len(),
        }
    }
}

/// Render a set of measurements as an aligned text table with an optional
/// baseline row for speedup computation.
pub fn print_table(title: &str, rows: &[(Measurement, Option<f64>)]) {
    println!("\n== {title} ==");
    println!(
        "{:<44} {:>12} {:>12} {:>8} {:>10}",
        "case", "median", "min", "iters", "extra"
    );
    for (m, extra) in rows {
        println!(
            "{:<44} {:>10.3}ms {:>10.3}ms {:>8} {:>10}",
            m.name,
            m.median_s * 1e3,
            m.min_s * 1e3,
            m.iters,
            extra.map(|x| format!("{x:.3}")).unwrap_or_default()
        );
    }
}

/// Plan-cache counters attached to every `BENCH_*.json` row so the
/// trajectory files share one counter schema. Kernel-level benches carry
/// zeros (no plan cache in play); engine-level benches splice in real
/// values with [`with_plan_cache`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PlanCacheCounters {
    pub hits: u64,
    pub misses: u64,
    pub shared: u64,
    pub delta: u64,
}

impl PlanCacheCounters {
    /// Snapshot the process-wide [`obs`](crate::obs) plan-cache counters
    /// (all zero unless `FO_METRICS` is on — engine benches that must work
    /// without the knob read their plan cache's own stats instead).
    pub fn snapshot() -> Self {
        use crate::obs::metrics as m;
        PlanCacheCounters {
            hits: m::PLAN_CACHE_HITS.get(),
            misses: m::PLAN_CACHE_MISSES.get(),
            shared: m::PLAN_CACHE_SHARED.get(),
            delta: m::PLAN_CACHE_DELTA.get(),
        }
    }

    /// Counters accumulated since an `earlier` snapshot.
    pub fn since(&self, earlier: &Self) -> Self {
        PlanCacheCounters {
            hits: self.hits.saturating_sub(earlier.hits),
            misses: self.misses.saturating_sub(earlier.misses),
            shared: self.shared.saturating_sub(earlier.shared),
            delta: self.delta.saturating_sub(earlier.delta),
        }
    }

    fn json_fields(&self) -> String {
        format!(
            "\"plan_cache_hits\":{},\"plan_cache_misses\":{},\
             \"plan_cache_shared\":{},\"plan_cache_delta\":{}",
            self.hits, self.misses, self.shared, self.delta
        )
    }
}

/// Replace the plan-cache counter fields of a [`json_row`] /
/// [`json_row_ratio`] row with measured values. Panics if the row does
/// not carry the counter fields (i.e. was not built by those helpers).
pub fn with_plan_cache(row: &str, c: &PlanCacheCounters) -> String {
    let at = row
        .find(",\"plan_cache_hits\":")
        .expect("row has no plan-cache fields; build it with json_row/json_row_ratio");
    let end = row.rfind('}').expect("row is not a JSON object");
    format!("{},{}{}", &row[..at], c.json_fields(), &row[end..])
}

/// One machine-readable result row for the `BENCH_*.json` perf-trajectory
/// files (shared by every fig bench so rows stay schema-compatible).
/// Every row carries the four `plan_cache_*` counter fields (zero here;
/// see [`with_plan_cache`]).
pub fn json_row(kernel: &str, case: &str, sparsity: f64, m: &Measurement, speedup: f64) -> String {
    format!(
        "{{\"kernel\":\"{kernel}\",\"case\":\"{case}\",\"sparsity\":{sparsity:.6},\
         \"median_ns\":{:.0},\"min_ns\":{:.0},\"iters\":{},\"speedup\":{speedup:.4},{}}}",
        m.median_s * 1e9,
        m.min_s * 1e9,
        m.iters,
        PlanCacheCounters::default().json_fields()
    )
}

/// [`json_row`] plus a `ratio` field: the row's sparsity:speedup ratio
/// (`speedup / (1 / (1 - sparsity))`, i.e. achieved speedup over the ideal
/// work-proportional speedup; 1.0 = perfectly linear, 0 when the row is
/// dense). The fig6/fig8 benches emit this per row so the trajectory files
/// track how close each kernel stays to the paper's near-linear claim.
pub fn json_row_ratio(
    kernel: &str,
    case: &str,
    sparsity: f64,
    m: &Measurement,
    speedup: f64,
) -> String {
    let ideal = 1.0 / (1.0 - sparsity).max(1e-9);
    let ratio = if sparsity > 0.0 { speedup / ideal } else { 0.0 };
    format!(
        "{{\"kernel\":\"{kernel}\",\"case\":\"{case}\",\"sparsity\":{sparsity:.6},\
         \"median_ns\":{:.0},\"min_ns\":{:.0},\"iters\":{},\"speedup\":{speedup:.4},\
         \"ratio\":{ratio:.4},{}}}",
        m.median_s * 1e9,
        m.min_s * 1e9,
        m.iters,
        PlanCacheCounters::default().json_fields()
    )
}

/// Write a `BENCH_<name>.json` perf-trajectory file: a `bench` tag, flat
/// numeric header fields, and the [`json_row`] rows. Later PRs diff these
/// files to catch perf regressions.
pub fn write_bench_json(
    path: &str,
    bench: &str,
    header: &[(&str, f64)],
    rows: &[String],
) -> std::io::Result<()> {
    write_bench_json_tagged(path, bench, header, &[], rows)
}

/// [`write_bench_json`] with additional *string* header tags (e.g. the
/// microkernel ISA and `FO_TUNE_CACHE` path the run used) alongside the
/// numeric header fields. The numeric-only helper delegates here so every
/// `BENCH_*.json` keeps one shape.
pub fn write_bench_json_tagged(
    path: &str,
    bench: &str,
    header: &[(&str, f64)],
    tags: &[(&str, &str)],
    rows: &[String],
) -> std::io::Result<()> {
    let mut head = format!("\"bench\":\"{bench}\"");
    for (k, v) in header {
        head.push_str(&format!(",\"{k}\":{v}"));
    }
    for (k, v) in tags {
        head.push_str(&format!(",\"{k}\":\"{v}\""));
    }
    let json = format!("{{{head},\"rows\":[\n{}\n]}}\n", rows.join(",\n"));
    std::fs::write(path, json)
}

/// Emit a CSV file of `(case, median_s, min_s, mad_s, iters, extra)` rows.
pub fn write_csv(
    path: &str,
    rows: &[(Measurement, Option<f64>)],
) -> std::io::Result<()> {
    use std::io::Write;
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "case,median_s,min_s,mad_s,iters,extra")?;
    for (m, extra) in rows {
        writeln!(
            f,
            "{},{},{},{},{},{}",
            m.name,
            m.median_s,
            m.min_s,
            m.mad_s,
            m.iters,
            extra.map(|x| x.to_string()).unwrap_or_default()
        )?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bencher { warmup: 1, min_iters: 3, budget_s: 0.01 };
        let m = b.run("noop-ish", || {
            let mut s = 0u64;
            for i in 0..1000 {
                s = s.wrapping_add(i);
            }
            std::hint::black_box(s);
        });
        assert!(m.median_s > 0.0);
        assert!(m.min_s <= m.median_s);
        assert!(m.iters >= 3);
    }

    #[test]
    fn json_helpers_emit_expected_shape() {
        let m = Measurement {
            name: "x".into(),
            median_s: 1e-3,
            min_s: 1e-3,
            mad_s: 0.0,
            iters: 3,
        };
        let row = json_row("k", "c", 0.5, &m, 2.0);
        assert!(row.starts_with('{') && row.ends_with('}'));
        assert!(row.contains("\"kernel\":\"k\""));
        assert!(row.contains("\"speedup\":2.0000"));
        // Every row carries the uniform plan-cache counter schema.
        assert!(row.contains("\"plan_cache_hits\":0"));
        assert!(row.contains("\"plan_cache_delta\":0"));
        let c = PlanCacheCounters { hits: 7, misses: 3, shared: 2, delta: 1 };
        let spliced = with_plan_cache(&row, &c);
        assert!(spliced.contains("\"plan_cache_hits\":7"));
        assert!(spliced.contains("\"plan_cache_misses\":3"));
        assert!(spliced.contains("\"plan_cache_shared\":2"));
        assert!(spliced.contains("\"plan_cache_delta\":1"));
        assert!(!spliced.contains("\"plan_cache_hits\":0"));
        assert!(spliced.ends_with('}') && spliced.contains("\"speedup\":2.0000"));
        let path = std::env::temp_dir().join("flashomni_bench_json_test.json");
        let p = path.to_str().unwrap();
        write_bench_json(p, "t", &[("seq", 512.0)], &[row]).unwrap();
        let body = std::fs::read_to_string(p).unwrap();
        assert!(body.contains("\"bench\":\"t\""));
        assert!(body.contains("\"seq\":512"));
        assert!(body.trim_end().ends_with("]}"));
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn tagged_json_and_ratio_rows() {
        let m = Measurement {
            name: "x".into(),
            median_s: 1e-3,
            min_s: 1e-3,
            mad_s: 0.0,
            iters: 3,
        };
        // sparsity 0.5 → ideal 2×; measured 1.5× → ratio 0.75.
        let row = json_row_ratio("k", "c", 0.5, &m, 1.5);
        assert!(row.contains("\"ratio\":0.7500"), "row: {row}");
        assert!(row.contains("\"plan_cache_shared\":0"), "row: {row}");
        // Dense rows carry ratio 0 (no skip → no meaningful ratio).
        let dense = json_row_ratio("k", "dense", 0.0, &m, 1.0);
        assert!(dense.contains("\"ratio\":0.0000"), "row: {dense}");
        let path = std::env::temp_dir().join("flashomni_bench_json_tagged_test.json");
        let p = path.to_str().unwrap();
        write_bench_json_tagged(p, "t", &[("seq", 512.0)], &[("isa", "avx2")], &[row])
            .unwrap();
        let body = std::fs::read_to_string(p).unwrap();
        assert!(body.contains("\"isa\":\"avx2\""));
        assert!(body.contains("\"seq\":512"));
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn plan_cache_counter_diffs() {
        let a = PlanCacheCounters { hits: 10, misses: 4, shared: 3, delta: 2 };
        let b = PlanCacheCounters { hits: 7, misses: 4, shared: 1, delta: 0 };
        assert_eq!(a.since(&b), PlanCacheCounters { hits: 3, misses: 0, shared: 2, delta: 2 });
    }

    #[test]
    fn speedup_direction() {
        let fast = Measurement {
            name: "f".into(),
            median_s: 0.5,
            min_s: 0.5,
            mad_s: 0.0,
            iters: 1,
        };
        let slow = Measurement {
            name: "s".into(),
            median_s: 1.0,
            min_s: 1.0,
            mad_s: 0.0,
            iters: 1,
        };
        assert!((fast.speedup_vs(&slow) - 2.0).abs() < 1e-9);
    }
}
