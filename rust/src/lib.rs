//! # FlashOmni — a unified sparse attention engine for Diffusion Transformers
//!
//! Reproduction of *FlashOmni: A Unified Sparse Attention Engine for
//! Diffusion Transformers* (CS.LG 2025) as a three-layer rust + JAX + Pallas
//! stack. This crate is Layer 3: the engine itself.
//!
//! The paper's contribution is reproduced as:
//!
//! * [`symbols`] — the compact 8-bit **sparse symbols** `S_c` (feature
//!   caching, spatial axis) and `S_s` (block-sparse skipping, reduction
//!   axis), with the bitwise decode functions `F` and `J` of §3.3–3.4.
//! * [`masks`] — logical block-sparse mask generation from the compressed
//!   attention map: the `C_{v→t}` / `G_{t→v}` metrics, Eq. 1 selection, and
//!   the baseline mask families (SpargeAttn-style dynamic, window/arrow
//!   static).
//! * [`plan`] — compiled **sparse execution plans**: the symbols are
//!   decoded once per (layer, refresh) into CSR live-block index lists
//!   (`SparsePlan`) that every sparse kernel consumes with zero decode
//!   work in its inner loops; tile/pair statistics derive from the plan.
//!   Plans own rows in `Arc`-shared row-group segments, so a refresh that
//!   differs in a few rows is **delta-compiled** (`PlanDelta` +
//!   `SparsePlan::apply_delta`) instead of rebuilt from scratch.
//! * [`kernels`] — the **general sparse attention kernel** (Algorithm 1)
//!   plus **GEMM-Q** / **GEMM-O** with real block skipping, and the dense
//!   references they are tested against.
//! * [`cache`] — the feature cache with TaylorSeer order-`D` forecasting and
//!   the GEMM-O bias cache `B_c`.
//! * [`mem`] — the paged memory pool (TGI/vLLM paged-KV idiom): fixed-size
//!   pages, ref-counted blocks, copy-on-write, content-keyed prefix
//!   sharing, and `FO_PAGE_BUDGET` eviction-under-pressure backing cached
//!   feature stacks, batched text K/V, plan segments and symbol keys.
//! * [`engine`] — the **Update–Dispatch** execution engine over denoising
//!   steps, and every baseline of the paper expressed as a policy emitting
//!   unified symbols.
//! * [`exec`] — the shared execution runtime: a persistent worker pool
//!   (`ExecPool`) with deterministic `parallel_for`/`parallel_map`; every
//!   sparse kernel's hot loop (attention heads, GEMM-Q / GEMM-O tiles)
//!   runs on it, and the serving coordinator's workers share one pool.
//! * [`model`] / [`diffusion`] — the MiniMMDiT substrate (double-stream
//!   multimodal DiT) and a rectified-flow sampler.
//! * `runtime` (behind the `pjrt` feature, so not linked in default
//!   builds) — PJRT loading/execution of the AOT artifacts produced by
//!   `python/compile/aot.py` (the L2/L1 numerics oracle). Behind the
//!   off-by-default `pjrt` feature: it needs the vendored `xla` crate,
//!   which the offline build does not carry.
//! * [`batch`] — the **batched generation subsystem**: a lockstep
//!   `BatchedEngine` that advances a whole batch of requests per step with
//!   cross-request plan sharing (one plan compile per (layer, refresh) per
//!   batch; batched GEMM-Q / attention / GEMM-O entry points over
//!   `batch × heads` and `batch × row-block` pool lanes, bitwise-identical
//!   per request to a solo run), plus a continuous-batching
//!   `BatchScheduler` with refresh-boundary admission.
//! * [`coordinator`] — the serving layer: request queue, shape-bucketing
//!   batcher, worker pool feeding per-worker batch schedulers (panic-
//!   isolated, per-request `Result`s), latency/throughput accounting
//!   (p50/p95/p99).
//! * [`router`] — the admission-controlled serving front-end: in-flight
//!   permit cap + bounded queue with explicit load shedding, per-request
//!   deadlines enforced at claim time, two priority classes, and
//!   streaming previews (bitwise prefixes of the final decode) every K
//!   denoising steps.
//! * [`metrics`] / [`report`] — the paper's quality + efficiency metrics and
//!   the harness that regenerates every table and figure.
//! * [`obs`] — the process-wide observability layer: atomic
//!   counters/gauges/histograms, RAII timing spans over every kernel
//!   family and engine phase, per-request lifecycle events, and the
//!   Prometheus / Chrome-trace exporters behind `FO_METRICS`/`FO_TRACE`
//!   (no-ops when unset).
//! * [`workload`] — synthetic workload generation: prompts, scenes and
//!   Poisson arrival traces that feed the serving layers.
//!
//! See `DESIGN.md` for the full experiment index and every substitution made
//! relative to the paper's A100/FLUX/Hunyuan testbed.

pub mod batch;
pub mod bench;
pub mod cache;
pub mod config;
pub mod coordinator;
pub mod diffusion;
pub mod engine;
pub mod exec;
pub mod kernels;
pub mod masks;
pub mod mem;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod plan;
pub mod report;
pub mod router;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod symbols;
pub mod tensor;
pub mod testutil;
pub mod util;
pub mod workload;

/// Crate version string (mirrors `Cargo.toml`).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
