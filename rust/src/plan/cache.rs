//! **Plan cache** — skip symbol→plan recompilation when the packed symbol
//! bytes have not changed.
//!
//! Plan compilation is cheap relative to a Dispatch step but it is pure
//! overhead on the Update path, and it repeats byte-for-byte identical
//! work in two common regimes:
//!
//! * **Repeated prompts** — the serving layer replays the same request
//!   (same seed, same text), so every Update window re-emits the exact
//!   same symbol stream it emitted last time.
//! * **Slowly-changing masks** — policies whose masks stabilize across
//!   refresh points (late denoising steps, static window/arrow baselines)
//!   emit unchanged `S_c`/`S_s` bytes for many consecutive windows.
//!
//! [`PlanCache`] is a FIFO-evicting map from the **packed symbol bytes +
//! geometry** ([`symbol_key`]) to an `Arc` of whatever plan bundle the
//! caller compiles (the engine stores its joint + per-stream slice set).
//! Keying on the packed bytes — not the logical masks — means the key is
//! exactly the paper's transport format: two plans collide iff every
//! `S_c`/`S_s` byte and every geometry parameter agree, in which case the
//! compiled plans are identical by construction.
//!
//! Hit/miss/eviction counters are kept inside the cache and surfaced per
//! run through `RunStats` by the engine.

use crate::symbols::LayerSymbols;
use std::collections::{HashMap, VecDeque};
use std::sync::Arc;

/// Cache accounting counters (monotonic over the cache's lifetime).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

/// Build the cache key for a layer's symbols under a given block geometry.
///
/// The key is the concatenation of the geometry parameters (little-endian
/// `u64`s) and every head's packed `S_c`/`S_s` byte streams plus its own
/// group geometry. `geometry` carries whatever parameters the compiled
/// plan depends on besides the symbols themselves (the engine passes
/// `[t_q, t_kv, block_q, block_k, text_blocks]` — the text/vision split
/// changes the per-stream slices even for identical joint symbols).
pub fn symbol_key(syms: &LayerSymbols, geometry: &[usize]) -> Vec<u8> {
    let mut key = Vec::with_capacity(
        8 * (geometry.len() + 1 + 3 * syms.heads.len())
            + syms.heads.iter().map(|h| h.packed_bytes()).sum::<usize>(),
    );
    for &g in geometry {
        key.extend_from_slice(&(g as u64).to_le_bytes());
    }
    key.extend_from_slice(&(syms.heads.len() as u64).to_le_bytes());
    for h in &syms.heads {
        for g in [h.pool, h.q_groups, h.kv_groups] {
            key.extend_from_slice(&(g as u64).to_le_bytes());
        }
        key.extend_from_slice(h.s_c.bytes());
        key.extend_from_slice(h.s_s.bytes());
    }
    key
}

/// FIFO-evicting compile cache keyed by packed symbol bytes.
///
/// Values are handed out as `Arc`s so the engine's per-layer state can
/// hold a plan across Dispatch steps while the cache stays free to evict.
pub struct PlanCache<V> {
    map: HashMap<Vec<u8>, Arc<V>>,
    /// Insertion order for FIFO eviction.
    order: VecDeque<Vec<u8>>,
    cap: usize,
    stats: CacheStats,
}

impl<V> PlanCache<V> {
    /// Cache holding at most `cap` compiled plans (clamped to ≥ 1).
    pub fn new(cap: usize) -> Self {
        PlanCache {
            map: HashMap::new(),
            order: VecDeque::new(),
            cap: cap.max(1),
            stats: CacheStats::default(),
        }
    }

    /// Look up `key`, compiling (and inserting) on miss. Returns the plan
    /// and whether this was a hit.
    pub fn get_or_compile(&mut self, key: &[u8], compile: impl FnOnce() -> V) -> (Arc<V>, bool) {
        if let Some(v) = self.map.get(key) {
            self.stats.hits += 1;
            return (Arc::clone(v), true);
        }
        self.stats.misses += 1;
        let v = Arc::new(compile());
        if self.map.len() >= self.cap {
            if let Some(oldest) = self.order.pop_front() {
                self.map.remove(&oldest);
                self.stats.evictions += 1;
            }
        }
        self.map.insert(key.to_vec(), Arc::clone(&v));
        self.order.push_back(key.to_vec());
        (v, false)
    }

    /// Drop every cached plan (counters are preserved). Call when the
    /// geometry regime changes wholesale, e.g. a policy swap mid-process.
    pub fn clear(&mut self) {
        self.map.clear();
        self.order.clear();
    }

    /// Number of cached plans.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Lifetime hit/miss/eviction counters.
    pub fn stats(&self) -> CacheStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::symbols::HeadSymbols;

    fn syms(bit: bool) -> LayerSymbols {
        LayerSymbols {
            heads: vec![HeadSymbols::from_masks(
                &[true, bit],
                &[true, true, bit, true],
                2,
                1,
            )],
        }
    }

    #[test]
    fn hit_and_miss_counting() {
        let mut cache: PlanCache<usize> = PlanCache::new(4);
        let k1 = symbol_key(&syms(true), &[2, 2, 8, 8, 0]);
        let k2 = symbol_key(&syms(false), &[2, 2, 8, 8, 0]);
        assert_ne!(k1, k2, "different symbol bytes must key differently");
        let (v, hit) = cache.get_or_compile(&k1, || 11);
        assert!(!hit);
        assert_eq!(*v, 11);
        let (v, hit) = cache.get_or_compile(&k1, || unreachable!("must not recompile"));
        assert!(hit);
        assert_eq!(*v, 11);
        let (_, hit) = cache.get_or_compile(&k2, || 22);
        assert!(!hit);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.evictions), (1, 2, 0));
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn geometry_changes_the_key() {
        let s = syms(true);
        let a = symbol_key(&s, &[2, 2, 8, 8, 0]);
        let b = symbol_key(&s, &[2, 2, 8, 8, 1]);
        assert_ne!(a, b, "text split must be part of the key");
    }

    #[test]
    fn fifo_eviction_at_capacity() {
        let mut cache: PlanCache<u32> = PlanCache::new(2);
        let keys: Vec<Vec<u8>> = (0..3u8).map(|i| vec![i]).collect();
        cache.get_or_compile(&keys[0], || 0);
        cache.get_or_compile(&keys[1], || 1);
        cache.get_or_compile(&keys[2], || 2); // evicts keys[0]
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.stats().evictions, 1);
        let (_, hit) = cache.get_or_compile(&keys[0], || 0);
        assert!(!hit, "evicted entry must recompile");
        let (_, hit) = cache.get_or_compile(&keys[2], || 2);
        assert!(hit, "newest entry must survive");
    }

    #[test]
    fn clear_keeps_counters() {
        let mut cache: PlanCache<u32> = PlanCache::new(2);
        cache.get_or_compile(&[1], || 1);
        cache.get_or_compile(&[1], || 1);
        cache.clear();
        assert!(cache.is_empty());
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        let (_, hit) = cache.get_or_compile(&[1], || 1);
        assert!(!hit, "cleared entry must recompile");
    }
}
