//! **Plan cache** — skip symbol→plan recompilation when the packed symbol
//! bytes have not changed.
//!
//! Plan compilation is cheap relative to a Dispatch step but it is pure
//! overhead on the Update path, and it repeats byte-for-byte identical
//! work in two common regimes:
//!
//! * **Repeated prompts** — the serving layer replays the same request
//!   (same seed, same text), so every Update window re-emits the exact
//!   same symbol stream it emitted last time.
//! * **Slowly-changing masks** — policies whose masks stabilize across
//!   refresh points (late denoising steps, static window/arrow baselines)
//!   emit unchanged `S_c`/`S_s` bytes for many consecutive windows.
//!
//! [`PlanCache`] is a FIFO-evicting map from the **packed symbol bytes +
//! geometry** ([`symbol_key`]) to an `Arc` of whatever plan bundle the
//! caller compiles (the engine stores its joint + per-stream slice set).
//! Keying on the packed bytes — not the logical masks — means the key is
//! exactly the paper's transport format: two plans collide iff every
//! `S_c`/`S_s` byte and every geometry parameter agree, in which case the
//! compiled plans are identical by construction.
//!
//! Hit/miss/eviction counters are kept inside the cache and surfaced per
//! run through `RunStats` by the engine.
//!
//! **Incremental recompiles** ride the miss path: when a lookup misses but
//! the caller holds the previous refresh's plan, it can diff the two keys
//! with [`PlanDelta`](super::PlanDelta) and build the new value via
//! [`SparsePlan::apply_delta`](super::SparsePlan::apply_delta) instead of
//! a full compile. The cache itself stays policy-free — the caller passes
//! the built value tagged as [`Compiled::Full`] or [`Compiled::Delta`]
//! through [`PlanCache::get_or_build_shared`], and the cache accounts the
//! delta case in [`CacheStats::delta_hits`] /
//! [`CacheOutcome::DeltaHit`] (a *partial* hit: the key missed, but the
//! base plan's unchanged rows were reused). `hits + misses` still equals
//! the number of lookups; `delta_hits` counts the subset of misses served
//! incrementally.
//!
//! **Batched serving** adds two layers on top:
//!
//! * **Epoch ids** ([`PlanCache::begin_epoch`] *allocates* a fresh id) —
//!   the batched engine opens one epoch per lockstep step and tags every
//!   lookup of that step with the id plus the requesting slot's *lane*.
//!   A hit on an entry inserted under the **same epoch id by a different
//!   lane** means another request of the same batch step just compiled it
//!   ([`CacheOutcome::SharedHit`], counted in [`CacheStats::shared_hits`]).
//!   Because ids are allocated from the cache's own counter, they stay
//!   unique across engines sharing one cache: another worker opening its
//!   epoch concurrently can neither steal nor spoil this batch's sharing
//!   attribution, and a slot re-hitting its own compile (same lane) is a
//!   plain hit. This is the counter that proves "one plan compile per
//!   (layer, refresh) per batch": for a batch of B symbol-identical
//!   requests every refresh produces exactly 1 miss and B−1 shared hits.
//! * **[`SharedPlanCache`]** — a `Mutex`-guarded handle cloneable across
//!   coordinator workers, so plan compiles are shared process-wide. The
//!   compile closure runs *under the lock*: plan compilation is cheap
//!   relative to a Dispatch step, and holding the lock is what makes the
//!   counters exact (never two compiles for one key, no lost counts) under
//!   `ExecPool` contention.
//!
//! **Key storage** is pool-backed: the packed symbol bytes are interned
//! into the cache's [`PagePool`] (`b"plankey"` namespace) as
//! [`PooledBytes`], so the map key, its FIFO eviction entry, and the
//! engine's per-layer `LayerPlans.key` copy all share **one** physical
//! allocation per distinct key (refcount bumps instead of `Vec<u8>`
//! clones). [`PlanCache::get_or_build_keyed`] hands the build closure the
//! interned handle so callers can keep it without re-copying the bytes.

use crate::mem::{PagePool, PooledBytes};
use crate::symbols::LayerSymbols;
use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex};

/// Cache accounting counters (monotonic over the cache's lifetime).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups whose key was already cached.
    pub hits: u64,
    /// Lookups whose key was absent — a (full or delta) compile ran.
    pub misses: u64,
    /// Entries dropped by FIFO eviction at capacity.
    pub evictions: u64,
    /// Hits on entries inserted *in the same epoch by a different lane* —
    /// i.e. refreshes served by a plan another request of the same batch
    /// step compiled. Always 0 for callers that never open an epoch.
    pub shared_hits: u64,
    /// Misses filled by an **incremental recompile** ([`Compiled::Delta`]):
    /// the key was absent, but the value was delta-compiled from the
    /// previous refresh's plan instead of from scratch. A subset of
    /// [`Self::misses`]; always 0 for callers that never delta-compile.
    pub delta_hits: u64,
}

/// Outcome of one [`PlanCache::get_or_compile_outcome`] lookup.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheOutcome {
    /// Key absent: the compile closure ran a full compile.
    Miss,
    /// Key present from an earlier epoch / another engine's epoch / this
    /// very lane.
    Hit,
    /// Key present *and* inserted under the caller's epoch id by a
    /// different lane: another request in the same batched step paid for
    /// this compile.
    SharedHit,
    /// Key absent, but the value was **delta-compiled** from the caller's
    /// base plan ([`Compiled::Delta`]) — only the changed row-groups were
    /// re-decoded. Counted as a miss *and* in [`CacheStats::delta_hits`].
    DeltaHit,
}

impl CacheOutcome {
    /// Whether the key was already cached (a delta compile is *not* a hit:
    /// the key was absent and a — cheaper — compile still ran).
    pub fn is_hit(&self) -> bool {
        matches!(self, CacheOutcome::Hit | CacheOutcome::SharedHit)
    }
}

/// How a cache-miss value was built — the tag callers pass through
/// [`PlanCache::get_or_build_shared`] so the cache can account
/// incremental recompiles without owning the delta policy.
pub enum Compiled<V> {
    /// Compiled from scratch (symbols decoded in full).
    Full(V),
    /// Delta-compiled from the previous refresh's plan (only changed
    /// row-groups decoded; unchanged segments structurally shared).
    Delta(V),
}

/// Build the cache key for a layer's symbols under a given block geometry.
///
/// The key is the concatenation of the geometry parameters (little-endian
/// `u64`s) and every head's packed `S_c`/`S_s` byte streams plus its own
/// group geometry. `geometry` carries whatever parameters the compiled
/// plan depends on besides the symbols themselves (the engine passes
/// `[t_q, t_kv, block_q, block_k, text_blocks]` — the text/vision split
/// changes the per-stream slices even for identical joint symbols).
pub fn symbol_key(syms: &LayerSymbols, geometry: &[usize]) -> Vec<u8> {
    let mut key = Vec::with_capacity(
        8 * (geometry.len() + 1 + 3 * syms.heads.len())
            + syms.heads.iter().map(|h| h.packed_bytes()).sum::<usize>(),
    );
    for &g in geometry {
        key.extend_from_slice(&(g as u64).to_le_bytes());
    }
    key.extend_from_slice(&(syms.heads.len() as u64).to_le_bytes());
    for h in &syms.heads {
        for g in [h.pool, h.q_groups, h.kv_groups] {
            key.extend_from_slice(&(g as u64).to_le_bytes());
        }
        key.extend_from_slice(h.s_c.bytes());
        key.extend_from_slice(h.s_s.bytes());
    }
    key
}

/// FIFO-evicting compile cache keyed by packed symbol bytes.
///
/// Values are handed out as `Arc`s so the engine's per-layer state can
/// hold a plan across Dispatch steps while the cache stays free to evict.
///
/// ```
/// use flashomni::plan::cache::{CacheOutcome, PlanCache};
///
/// let mut cache: PlanCache<u32> = PlanCache::new(4);
/// let (v, outcome) = cache.get_or_compile_outcome(b"key", || 7);
/// assert_eq!((*v, outcome), (7, CacheOutcome::Miss));
/// let (v, outcome) = cache.get_or_compile_outcome(b"key", || unreachable!());
/// assert_eq!((*v, outcome), (7, CacheOutcome::Hit));
/// assert_eq!((cache.stats().hits, cache.stats().misses), (1, 1));
/// ```
pub struct PlanCache<V> {
    /// Value plus the (epoch id, lane) it was inserted under
    /// (epoch 0 = outside any epoch). Keys are pool-interned byte
    /// strings probed with plain `&[u8]` slices.
    map: HashMap<PooledBytes, (Arc<V>, u64, u64)>,
    /// Insertion order for FIFO eviction (refcount bumps of the map
    /// keys, not byte copies).
    order: VecDeque<PooledBytes>,
    cap: usize,
    /// Last allocated epoch id (ids start at 1; 0 is "no epoch").
    epoch: u64,
    /// Pool the keys are interned into.
    mem: PagePool,
    stats: CacheStats,
}

impl<V> PlanCache<V> {
    /// Cache holding at most `cap` compiled plans (clamped to ≥ 1),
    /// interning keys into the process-global [`PagePool`].
    pub fn new(cap: usize) -> Self {
        PlanCache::new_in(cap, PagePool::global())
    }

    /// [`Self::new`] with an explicit key pool (private budgets in tests
    /// and benches).
    pub fn new_in(cap: usize, mem: &PagePool) -> Self {
        PlanCache {
            map: HashMap::new(),
            order: VecDeque::new(),
            cap: cap.max(1),
            epoch: 0,
            mem: mem.clone(),
            stats: CacheStats::default(),
        }
    }

    /// The pool this cache interns its keys into.
    pub fn pool(&self) -> &PagePool {
        &self.mem
    }

    /// Allocate a fresh sharing-epoch id (the batched engine calls this
    /// once per lockstep step and tags that step's lookups with it via
    /// [`Self::get_or_compile_shared`]). Ids are unique per cache, so
    /// concurrent engines sharing one cache cannot confuse each other's
    /// sharing attribution.
    pub fn begin_epoch(&mut self) -> u64 {
        self.epoch += 1;
        self.epoch
    }

    /// Look up `key`, compiling (and inserting) on miss. Returns the plan
    /// and whether this was a hit.
    pub fn get_or_compile(&mut self, key: &[u8], compile: impl FnOnce() -> V) -> (Arc<V>, bool) {
        let (v, outcome) = self.get_or_compile_outcome(key, compile);
        (v, outcome.is_hit())
    }

    /// [`Self::get_or_compile`] with a [`CacheOutcome`] (never
    /// `SharedHit`: this entry point runs outside any epoch).
    pub fn get_or_compile_outcome(
        &mut self,
        key: &[u8],
        compile: impl FnOnce() -> V,
    ) -> (Arc<V>, CacheOutcome) {
        self.get_or_compile_shared(key, 0, 0, compile)
    }

    /// Epoch-tagged lookup: `epoch` is an id from [`Self::begin_epoch`]
    /// (or 0 for "outside any epoch") and `lane` identifies the requesting
    /// slot within that epoch. A hit on an entry inserted under the same
    /// epoch id by a **different** lane reports
    /// [`CacheOutcome::SharedHit`] (see the module docs).
    pub fn get_or_compile_shared(
        &mut self,
        key: &[u8],
        epoch: u64,
        lane: u64,
        compile: impl FnOnce() -> V,
    ) -> (Arc<V>, CacheOutcome) {
        self.get_or_build_shared(key, epoch, lane, || Compiled::Full(compile()))
    }

    /// The general entry point: like [`Self::get_or_compile_shared`], but
    /// the build closure reports *how* it built the value — a miss filled
    /// by [`Compiled::Delta`] (an incremental recompile off the caller's
    /// base plan) is returned as [`CacheOutcome::DeltaHit`] and counted in
    /// [`CacheStats::delta_hits`] on top of the plain miss count.
    pub fn get_or_build_shared(
        &mut self,
        key: &[u8],
        epoch: u64,
        lane: u64,
        build: impl FnOnce() -> Compiled<V>,
    ) -> (Arc<V>, CacheOutcome) {
        self.get_or_build_keyed(key, epoch, lane, |_| build())
    }

    /// [`Self::get_or_build_shared`], additionally handing the build
    /// closure the **pool-interned key handle** so the caller can retain
    /// it (e.g. as `LayerPlans.key`) as a refcount bump on the very block
    /// the cache maps under — one physical key allocation instead of two
    /// `Vec<u8>` copies.
    pub fn get_or_build_keyed(
        &mut self,
        key: &[u8],
        epoch: u64,
        lane: u64,
        build: impl FnOnce(&PooledBytes) -> Compiled<V>,
    ) -> (Arc<V>, CacheOutcome) {
        if let Some((v, e, l)) = self.map.get(key) {
            self.stats.hits += 1;
            let outcome = if epoch > 0 && *e == epoch && *l != lane {
                self.stats.shared_hits += 1;
                CacheOutcome::SharedHit
            } else {
                CacheOutcome::Hit
            };
            return (Arc::clone(v), outcome);
        }
        self.stats.misses += 1;
        let (pooled_key, _) = self.mem.intern_bytes(b"plankey", key);
        let (v, outcome) = match build(&pooled_key) {
            Compiled::Full(v) => (Arc::new(v), CacheOutcome::Miss),
            Compiled::Delta(v) => {
                self.stats.delta_hits += 1;
                (Arc::new(v), CacheOutcome::DeltaHit)
            }
        };
        if self.map.len() >= self.cap {
            if let Some(oldest) = self.order.pop_front() {
                self.map.remove(&oldest);
                self.stats.evictions += 1;
            }
        }
        self.map.insert(pooled_key.clone(), (Arc::clone(&v), epoch, lane));
        self.order.push_back(pooled_key);
        (v, outcome)
    }

    /// Drop every cached plan (counters are preserved). Call when the
    /// geometry regime changes wholesale, e.g. a policy swap mid-process.
    pub fn clear(&mut self) {
        self.map.clear();
        self.order.clear();
    }

    /// Number of cached plans.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// No plans cached.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Lifetime hit/miss/eviction/shared/delta counters.
    pub fn stats(&self) -> CacheStats {
        self.stats
    }
}

/// Thread-safe, cloneable handle to one [`PlanCache`] — the batched
/// serving layer's **cross-request, cross-worker** compile cache.
///
/// Cloning shares the underlying cache (it is an `Arc<Mutex<..>>`), so a
/// coordinator can hand every worker's `BatchedEngine` the same handle and
/// a plan compiled for one request is reused by every other request — in
/// the same batch (a [`CacheOutcome::SharedHit`] if within the same
/// epoch), a later batch, or another worker's batch.
///
/// The compile closure runs **while holding the lock**. That serializes
/// compiles, but it is what makes the guarantees exact under `ExecPool`
/// contention: a key is compiled at most once process-wide, and
/// `hits + misses` equals the number of lookups with no interleaving
/// races. Plan compilation is cheap relative to the Dispatch work the plan
/// then drives (see the fig6 compile-cost rows), so the critical section
/// stays short.
pub struct SharedPlanCache<V> {
    inner: Arc<Mutex<PlanCache<V>>>,
}

impl<V> Clone for SharedPlanCache<V> {
    fn clone(&self) -> Self {
        SharedPlanCache { inner: Arc::clone(&self.inner) }
    }
}

impl<V> SharedPlanCache<V> {
    /// Shared cache holding at most `cap` compiled plans (keys interned
    /// into the process-global [`PagePool`]).
    pub fn new(cap: usize) -> Self {
        SharedPlanCache { inner: Arc::new(Mutex::new(PlanCache::new(cap))) }
    }

    /// [`Self::new`] with an explicit key pool.
    pub fn new_in(cap: usize, mem: &PagePool) -> Self {
        SharedPlanCache { inner: Arc::new(Mutex::new(PlanCache::new_in(cap, mem))) }
    }

    /// Allocate a fresh sharing-epoch id (see [`PlanCache::begin_epoch`]).
    /// Unique across every engine sharing this cache.
    pub fn begin_epoch(&self) -> u64 {
        self.inner.lock().unwrap().begin_epoch()
    }

    /// Look up `key`, compiling under the lock on miss (outside any
    /// epoch — never reports `SharedHit`).
    pub fn get_or_compile(
        &self,
        key: &[u8],
        compile: impl FnOnce() -> V,
    ) -> (Arc<V>, CacheOutcome) {
        self.inner.lock().unwrap().get_or_compile_outcome(key, compile)
    }

    /// Epoch-tagged lookup (see [`PlanCache::get_or_compile_shared`]).
    pub fn get_or_compile_shared(
        &self,
        key: &[u8],
        epoch: u64,
        lane: u64,
        compile: impl FnOnce() -> V,
    ) -> (Arc<V>, CacheOutcome) {
        self.inner.lock().unwrap().get_or_compile_shared(key, epoch, lane, compile)
    }

    /// Epoch-tagged lookup with a full/delta build closure (see
    /// [`PlanCache::get_or_build_shared`]). The closure runs under the
    /// lock, like every compile on this handle.
    pub fn get_or_build_shared(
        &self,
        key: &[u8],
        epoch: u64,
        lane: u64,
        build: impl FnOnce() -> Compiled<V>,
    ) -> (Arc<V>, CacheOutcome) {
        self.inner.lock().unwrap().get_or_build_shared(key, epoch, lane, build)
    }

    /// Epoch-tagged lookup handing the build closure the pool-interned
    /// key handle (see [`PlanCache::get_or_build_keyed`]).
    pub fn get_or_build_keyed(
        &self,
        key: &[u8],
        epoch: u64,
        lane: u64,
        build: impl FnOnce(&PooledBytes) -> Compiled<V>,
    ) -> (Arc<V>, CacheOutcome) {
        self.inner.lock().unwrap().get_or_build_keyed(key, epoch, lane, build)
    }

    /// Lifetime hit/miss/eviction/shared/delta counters.
    pub fn stats(&self) -> CacheStats {
        self.inner.lock().unwrap().stats()
    }

    /// Number of cached plans.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    /// No plans cached.
    pub fn is_empty(&self) -> bool {
        self.inner.lock().unwrap().is_empty()
    }

    /// Drop every cached plan (counters are preserved).
    pub fn clear(&self) {
        self.inner.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::symbols::HeadSymbols;

    fn syms(bit: bool) -> LayerSymbols {
        LayerSymbols {
            heads: vec![HeadSymbols::from_masks(
                &[true, bit],
                &[true, true, bit, true],
                2,
                1,
            )],
        }
    }

    #[test]
    fn hit_and_miss_counting() {
        let mut cache: PlanCache<usize> = PlanCache::new(4);
        let k1 = symbol_key(&syms(true), &[2, 2, 8, 8, 0]);
        let k2 = symbol_key(&syms(false), &[2, 2, 8, 8, 0]);
        assert_ne!(k1, k2, "different symbol bytes must key differently");
        let (v, hit) = cache.get_or_compile(&k1, || 11);
        assert!(!hit);
        assert_eq!(*v, 11);
        let (v, hit) = cache.get_or_compile(&k1, || unreachable!("must not recompile"));
        assert!(hit);
        assert_eq!(*v, 11);
        let (_, hit) = cache.get_or_compile(&k2, || 22);
        assert!(!hit);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.evictions), (1, 2, 0));
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn geometry_changes_the_key() {
        let s = syms(true);
        let a = symbol_key(&s, &[2, 2, 8, 8, 0]);
        let b = symbol_key(&s, &[2, 2, 8, 8, 1]);
        assert_ne!(a, b, "text split must be part of the key");
    }

    #[test]
    fn fifo_eviction_at_capacity() {
        let mut cache: PlanCache<u32> = PlanCache::new(2);
        let keys: Vec<Vec<u8>> = (0..3u8).map(|i| vec![i]).collect();
        cache.get_or_compile(&keys[0], || 0);
        cache.get_or_compile(&keys[1], || 1);
        cache.get_or_compile(&keys[2], || 2); // evicts keys[0]
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.stats().evictions, 1);
        let (_, hit) = cache.get_or_compile(&keys[0], || 0);
        assert!(!hit, "evicted entry must recompile");
        let (_, hit) = cache.get_or_compile(&keys[2], || 2);
        assert!(hit, "newest entry must survive");
    }

    #[test]
    fn epoch_distinguishes_shared_hits() {
        let mut cache: PlanCache<u32> = PlanCache::new(4);
        // Outside any epoch: hits are plain hits.
        cache.get_or_compile(&[1], || 1);
        let (_, o) = cache.get_or_compile_outcome(&[1], || unreachable!());
        assert_eq!(o, CacheOutcome::Hit);
        // Epoch e: lane 0 compiles; lanes 1 and 2 ride it (shared); lane 0
        // re-hitting its own compile is a plain hit; the pre-epoch entry
        // stays a plain hit.
        let e = cache.begin_epoch();
        let (_, o) = cache.get_or_compile_shared(&[2], e, 0, || 2);
        assert_eq!(o, CacheOutcome::Miss);
        let (_, o) = cache.get_or_compile_shared(&[2], e, 1, || unreachable!());
        assert_eq!(o, CacheOutcome::SharedHit);
        let (_, o) = cache.get_or_compile_shared(&[2], e, 2, || unreachable!());
        assert_eq!(o, CacheOutcome::SharedHit);
        let (_, o) = cache.get_or_compile_shared(&[2], e, 0, || unreachable!());
        assert_eq!(o, CacheOutcome::Hit, "own compile is not a shared hit");
        let (_, o) = cache.get_or_compile_shared(&[1], e, 1, || unreachable!());
        assert_eq!(o, CacheOutcome::Hit, "pre-epoch entry is not shared");
        // A different epoch id (another step, or another engine on a
        // shared cache) sees only plain hits — even for lane values that
        // collide with the inserting epoch's lanes.
        let e2 = cache.begin_epoch();
        assert_ne!(e, e2);
        let (_, o) = cache.get_or_compile_shared(&[2], e2, 1, || unreachable!());
        assert_eq!(o, CacheOutcome::Hit);
        let s = cache.stats();
        assert_eq!(s.shared_hits, 2);
        assert_eq!(s.hits, 6);
        assert_eq!(s.misses, 2);
    }

    #[test]
    fn delta_builds_count_as_delta_hits() {
        let mut cache: PlanCache<u32> = PlanCache::new(4);
        // A delta-built miss: key absent, value built incrementally.
        let (v, o) = cache.get_or_build_shared(&[1], 0, 0, || Compiled::Delta(10));
        assert_eq!((*v, o), (10, CacheOutcome::DeltaHit));
        assert!(!o.is_hit(), "a delta compile is not a key hit");
        // Re-lookup is a plain hit; no extra delta accounting.
        let (_, o) = cache.get_or_build_shared(&[1], 0, 0, || unreachable!());
        assert_eq!(o, CacheOutcome::Hit);
        // A full-built miss on a fresh key.
        let (_, o) = cache.get_or_build_shared(&[2], 0, 0, || Compiled::Full(20));
        assert_eq!(o, CacheOutcome::Miss);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.delta_hits), (1, 2, 1));
        // Epoch sharing still works for delta-inserted entries.
        let e = cache.begin_epoch();
        let (_, o) = cache.get_or_build_shared(&[3], e, 0, || Compiled::Delta(30));
        assert_eq!(o, CacheOutcome::DeltaHit);
        let (_, o) = cache.get_or_build_shared(&[3], e, 1, || unreachable!());
        assert_eq!(o, CacheOutcome::SharedHit);
        assert_eq!(cache.stats().delta_hits, 2);
    }

    #[test]
    fn keys_are_interned_once() {
        let pool = crate::mem::PagePool::with_budget(0, 64);
        let mut cache: PlanCache<u32> = PlanCache::new_in(4, &pool);
        let mut kept = None;
        cache.get_or_build_keyed(b"shared-key", 0, 0, |pk| {
            kept = Some(pk.clone());
            Compiled::Full(1)
        });
        let kept = kept.unwrap();
        // Map key + FIFO entry + caller's retained copy: three handles,
        // one physical block.
        assert_eq!(kept.ref_count(), 3);
        assert_eq!(pool.stats().blocks_allocated, 1);
        // A re-lookup is a hit — no new interning, no new allocation.
        let (_, o) = cache.get_or_build_keyed(b"shared-key", 0, 0, |_| unreachable!());
        assert_eq!(o, CacheOutcome::Hit);
        assert_eq!(pool.stats().blocks_allocated, 1);
    }

    #[test]
    fn shared_cache_clones_share_state() {
        let a: SharedPlanCache<u32> = SharedPlanCache::new(4);
        let b = a.clone();
        let (_, o) = a.get_or_compile(&[7], || 70);
        assert_eq!(o, CacheOutcome::Miss);
        let (v, o) = b.get_or_compile(&[7], || unreachable!("must share"));
        assert_eq!(*v, 70);
        assert_eq!(o, CacheOutcome::Hit);
        assert_eq!(a.len(), 1);
        assert_eq!(b.stats().misses, 1);
    }

    #[test]
    fn clear_keeps_counters() {
        let mut cache: PlanCache<u32> = PlanCache::new(2);
        cache.get_or_compile(&[1], || 1);
        cache.get_or_compile(&[1], || 1);
        cache.clear();
        assert!(cache.is_empty());
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        let (_, hit) = cache.get_or_compile(&[1], || 1);
        assert!(!hit, "cleared entry must recompile");
    }
}
