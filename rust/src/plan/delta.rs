//! **Plan deltas** — which symbol row-groups changed between two refreshes.
//!
//! The [`PlanCache`](super::cache::PlanCache) already makes byte-identical
//! refreshes free, but the common serving regimes (caching-style policies
//! late in denoising, per-step mask policies on slowly-evolving
//! activations) emit symbol streams that differ in a *few rows* — and a
//! one-bit flip used to recompile the whole layer. [`PlanDelta`] closes
//! that gap: it diffs the **packed symbol bytes** of an incoming refresh
//! against the cached plan's key (the exact bytes
//! [`symbol_key`](super::cache::symbol_key) hashed, so no extra state has
//! to be retained) and reports, per head, the ascending list of changed
//! **row-groups** — the granularity at which
//! [`SparsePlan::apply_delta`](super::SparsePlan::apply_delta) can rebuild
//! a plan incrementally.
//!
//! Granularity: `S_c` flips are resolved to exact groups (the spatial
//! symbol stream is one bit per group). `S_s` flips are resolved at *byte*
//! granularity — a changed byte marks every row-group whose bit range
//! touches that byte, which can conservatively include one unchanged
//! neighbour row when rows are not byte-aligned. Over-marking only costs a
//! little extra decode work; it can never change the result, because a
//! re-decoded unchanged row compiles to identical indices.
//!
//! A structural mismatch (different geometry prefix, head count, pooling,
//! or group shape) is not diffable: [`PlanDelta::between`] returns `None`
//! and the caller falls back to a full compile.

use crate::symbols::LayerSymbols;

/// Changed row-groups between two symbol refreshes of one layer, per head.
///
/// Produced by [`PlanDelta::between`]; consumed by
/// [`SparsePlan::apply_delta`](super::SparsePlan::apply_delta) /
/// [`HeadPlan::apply_delta`](super::HeadPlan::apply_delta). See the
/// [module docs](self) for the diff granularity.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PlanDelta {
    /// Per head: ascending, deduplicated changed row-group indices.
    heads: Vec<Vec<u32>>,
    /// Total row-groups across heads (denominator of
    /// [`Self::group_fraction`]).
    total_groups: usize,
}

impl PlanDelta {
    /// Diff two plan-cache keys at row-group granularity.
    ///
    /// `old_key` is the key the cached base plan was compiled under;
    /// `new_key` is the key of the incoming refresh, and `syms` the
    /// symbols it was built from (they describe the key's layout:
    /// `geometry_len` little-endian `u64` geometry parameters, the head
    /// count, then per head its pooling/group geometry and the packed
    /// `S_c`/`S_s` bytes — exactly what
    /// [`symbol_key`](super::cache::symbol_key) emits).
    ///
    /// Returns `None` when the keys are not structurally diffable (any
    /// geometry byte differs, or the lengths disagree) — the caller must
    /// fall back to a full compile. Identical keys yield an
    /// [empty](Self::is_empty) delta.
    pub fn between(
        old_key: &[u8],
        new_key: &[u8],
        syms: &LayerSymbols,
        geometry_len: usize,
    ) -> Option<PlanDelta> {
        if old_key.len() != new_key.len() {
            return None;
        }
        // Geometry prefix + head count must agree byte-for-byte.
        let mut off = geometry_len * 8 + 8;
        if old_key.len() < off || old_key[..off] != new_key[..off] {
            return None;
        }
        let mut heads = Vec::with_capacity(syms.heads.len());
        let mut total_groups = 0usize;
        for h in &syms.heads {
            // Per-head (pool, q_groups, kv_groups) triplet.
            let geom_end = off + 24;
            if old_key.len() < geom_end || old_key[off..geom_end] != new_key[off..geom_end] {
                return None;
            }
            off = geom_end;
            let (qg, kg) = (h.q_groups, h.kv_groups);
            total_groups += qg;
            let sc_len = qg.div_ceil(8);
            let ss_len = (qg * kg).div_ceil(8);
            if old_key.len() < off + sc_len + ss_len {
                return None;
            }
            let old_sc = &old_key[off..off + sc_len];
            let new_sc = &new_key[off..off + sc_len];
            off += sc_len;
            let old_ss = &old_key[off..off + ss_len];
            let new_ss = &new_key[off..off + ss_len];
            off += ss_len;

            let mut changed: Vec<u32> = Vec::new();
            // S_c: one bit per group — exact resolution.
            for (i, (&o, &n)) in old_sc.iter().zip(new_sc).enumerate() {
                let x = o ^ n;
                if x == 0 {
                    continue;
                }
                for bit in 0..8 {
                    if (x >> (7 - bit)) & 1 == 1 {
                        let g = i * 8 + bit;
                        if g < qg {
                            changed.push(g as u32);
                        }
                    }
                }
            }
            // S_s: rows are kv_groups bits long and not byte-aligned in
            // general — map each changed byte to the (conservative) range
            // of row-groups whose bits it holds.
            if kg > 0 {
                for (i, (&o, &n)) in old_ss.iter().zip(new_ss).enumerate() {
                    if o == n {
                        continue;
                    }
                    let first = (i * 8) / kg;
                    let last = ((i * 8 + 7) / kg).min(qg.saturating_sub(1));
                    for g in first..=last {
                        if g < qg {
                            changed.push(g as u32);
                        }
                    }
                }
            }
            changed.sort_unstable();
            changed.dedup();
            heads.push(changed);
        }
        if off != old_key.len() {
            return None;
        }
        Some(PlanDelta { heads, total_groups })
    }

    /// Number of heads this delta describes.
    pub fn head_count(&self) -> usize {
        self.heads.len()
    }

    /// Ascending changed row-group indices of `head`.
    pub fn changed(&self, head: usize) -> &[u32] {
        &self.heads[head]
    }

    /// No row-group changed in any head (the refresh was byte-identical —
    /// normally caught earlier by a plan-cache hit, but reachable after an
    /// eviction).
    pub fn is_empty(&self) -> bool {
        self.heads.iter().all(|h| h.is_empty())
    }

    /// Total changed row-groups summed over heads.
    pub fn changed_groups(&self) -> usize {
        self.heads.iter().map(|h| h.len()).sum()
    }

    /// Changed fraction of all row-groups (0.0 for an empty layer).
    pub fn group_fraction(&self) -> f64 {
        if self.total_groups == 0 {
            return 0.0;
        }
        self.changed_groups() as f64 / self.total_groups as f64
    }

    /// Restrict the delta to row-groups `[lo, hi)` of every head, rebased
    /// to the slice — used to delta-recompile the engine's text/vision
    /// row-slice plans alongside the joint plan.
    pub fn slice_groups(&self, lo: usize, hi: usize) -> PlanDelta {
        assert!(lo <= hi, "bad group slice [{lo}, {hi})");
        PlanDelta {
            heads: self
                .heads
                .iter()
                .map(|h| {
                    h.iter()
                        .filter(|&&g| (g as usize) >= lo && (g as usize) < hi)
                        .map(|&g| g - lo as u32)
                        .collect()
                })
                .collect(),
            total_groups: (hi - lo) * self.heads.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::cache::symbol_key;
    use super::*;
    use crate::symbols::HeadSymbols;

    fn layer(m_c: &[bool], m_s: &[bool], kg: usize, pool: usize) -> LayerSymbols {
        LayerSymbols { heads: vec![HeadSymbols::from_masks(m_c, m_s, kg, pool)] }
    }

    const GEO: [usize; 3] = [4, 4, 8];

    #[test]
    fn identical_keys_give_empty_delta() {
        let s = layer(&[true; 4], &[true; 16], 4, 1);
        let k = symbol_key(&s, &GEO);
        let d = PlanDelta::between(&k, &k, &s, GEO.len()).unwrap();
        assert!(d.is_empty());
        assert_eq!(d.changed_groups(), 0);
        assert_eq!(d.group_fraction(), 0.0);
    }

    #[test]
    fn sc_flip_is_exact() {
        let old = layer(&[true, true, true, true], &[true; 16], 4, 1);
        let mut m_c = [true; 4];
        m_c[2] = false;
        let new = layer(&m_c, &[true; 16], 4, 1);
        let d = PlanDelta::between(
            &symbol_key(&old, &GEO),
            &symbol_key(&new, &GEO),
            &new,
            GEO.len(),
        )
        .unwrap();
        assert_eq!(d.changed(0), &[2]);
        assert!((d.group_fraction() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn ss_flip_marks_the_rows_sharing_the_byte() {
        // kv_groups = 4 → rows are nibble-sized: flipping a bit of row 1
        // conservatively marks rows 0 and 1 (they share byte 0).
        let old = layer(&[true; 4], &[true; 16], 4, 1);
        let mut m_s = [true; 16];
        m_s[5] = false; // row 1, kv 1
        let new = layer(&[true; 4], &m_s, 4, 1);
        let d = PlanDelta::between(
            &symbol_key(&old, &GEO),
            &symbol_key(&new, &GEO),
            &new,
            GEO.len(),
        )
        .unwrap();
        assert_eq!(d.changed(0), &[0, 1]);
    }

    #[test]
    fn geometry_mismatch_is_not_diffable() {
        let a = layer(&[true; 4], &[true; 16], 4, 1);
        let b = layer(&[true; 8], &[true; 64], 8, 1);
        let ka = symbol_key(&a, &GEO);
        let kb = symbol_key(&b, &GEO);
        assert!(PlanDelta::between(&ka, &kb, &b, GEO.len()).is_none());
        // Same symbols, different geometry parameters.
        let ka2 = symbol_key(&a, &[4, 4, 16]);
        assert!(PlanDelta::between(&ka, &ka2, &a, 3).is_none());
        // Different pooling factor changes the per-head geometry triplet.
        let c = layer(&[true; 4], &[true; 16], 4, 2);
        let kc = symbol_key(&c, &GEO);
        assert!(PlanDelta::between(&ka, &kc, &c, GEO.len()).is_none());
    }

    #[test]
    fn slice_groups_filters_and_rebase() {
        let d = PlanDelta { heads: vec![vec![0, 2, 3], vec![1]], total_groups: 8 };
        let s = d.slice_groups(2, 4);
        assert_eq!(s.changed(0), &[0, 1]);
        assert!(s.changed(1).is_empty());
        assert_eq!(s.changed_groups(), 2);
    }
}
