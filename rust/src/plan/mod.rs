//! Compiled **sparse execution plans** — the bridge from symbols to kernels.
//!
//! The paper's unified symbols (`S_c`, `S_s`, [`crate::symbols`]) are a
//! compact *transport* format: one bit per block group. Executing directly
//! from them forces every kernel to re-run the bitwise decode functions
//! `F`/`J` per tile, per head, per call — the overhead the paper's §4.3
//! register-cache optimization fights on the GPU. FlashInfer-style engines
//! instead *compile* the mask once into compact block-index lists
//! (`indptr`/`indices`) that every kernel consumes with zero decode work in
//! its inner loop. This module is that compile step:
//!
//! * [`HeadPlan`] — one head's live structure: the list of computed
//!   (`live_q`) and cached (`cached_q`) Q-block indices from `S_c`, plus a
//!   CSR (`kv_indptr`/`kv_indices`) of live KV-block indices per live Q
//!   block from `S_s`.
//! * [`SparsePlan`] — all heads of one layer plus the block geometry,
//!   compiled once per (layer, symbol refresh) and reused across every
//!   Dispatch step until the policy refreshes the symbols.
//!
//! [`DecodeMode`] lives here because decode strategy is now a
//! *plan-construction* concern: both modes must (and are property-tested
//! to) produce identical plans; the §4.3 decode-overhead benchmark times
//! plan compilation — and the legacy symbol-decoding kernels — under both.
//!
//! [`AttnStats`] and [`GemmStats`] are also defined here and *derived from
//! the plan* (`attn_stats()` / `gemm_stats()`), so the engine, `metrics/`
//! and `report/` all read one source of truth for tile/pair accounting.
//!
//! Index lists are packed to **`u32`** (the FlashInfer idiom): block
//! counts never approach 2³², and halving the index footprint matters at
//! video-scale sequences where the CSR lists are the kernels' hottest
//! metadata stream. [`HeadPlan::from_symbols`] asserts the geometry fits.

pub mod cache;

use crate::symbols::{HeadSymbols, LayerSymbols};

/// How the reduction-axis symbols are decoded while *compiling* a plan —
/// retained to reproduce the paper's FC-vs-BSS decode-overhead analysis
/// (§4.3). Both modes yield identical plans.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecodeMode {
    /// Decode a symbol byte once per 8 groups and keep it in a register
    /// (the paper's optimization).
    RowCached,
    /// Re-run the full bitwise decode `J(S_s, i, j)` for every KV block
    /// (the naive scheme the paper says burns CUDA-core cycles).
    PerAccess,
}

/// Execution statistics for one attention call, derived from a plan.
#[derive(Clone, Copy, Debug, Default)]
pub struct AttnStats {
    /// (Qi, Kj) block pairs actually computed.
    pub computed_pairs: usize,
    /// Total block pairs in a dense computation.
    pub total_pairs: usize,
    /// Q blocks served from cache.
    pub cached_blocks: usize,
    /// Total Q blocks.
    pub q_blocks: usize,
}

impl AttnStats {
    /// The paper's Sparsity metric: `skip / total`.
    pub fn sparsity(&self) -> f64 {
        if self.total_pairs == 0 {
            return 0.0;
        }
        1.0 - self.computed_pairs as f64 / self.total_pairs as f64
    }
}

/// Tile statistics for the sparse GEMMs, derived from a plan.
#[derive(Clone, Copy, Debug, Default)]
pub struct GemmStats {
    pub computed_tiles: usize,
    pub total_tiles: usize,
}

impl GemmStats {
    pub fn sparsity(&self) -> f64 {
        if self.total_tiles == 0 {
            return 0.0;
        }
        1.0 - self.computed_tiles as f64 / self.total_tiles as f64
    }
}

/// Compiled sparse structure for one attention head.
///
/// All indices are *raw* block indices (`0..t_q` / `0..t_kv`), i.e. the
/// symbol pooling factor `n` has already been resolved at compile time.
/// Indices are packed to `u32` (FlashInfer idiom — half the cache
/// footprint of `usize` on 64-bit targets); kernels widen with `as usize`
/// at the loop head, which costs nothing.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HeadPlan {
    /// Total Q blocks (`ceil(n / block_q)`).
    pub t_q: usize,
    /// Total KV blocks (`ceil(n_kv / block_k)`).
    pub t_kv: usize,
    /// Q-block indices computed this step (`F(S_c, i) = 1`), ascending.
    pub live_q: Vec<u32>,
    /// Q-block indices served from the feature cache (`F = 0`), ascending.
    pub cached_q: Vec<u32>,
    /// CSR row pointers into [`Self::kv_indices`]; `len = live_q.len() + 1`.
    pub kv_indptr: Vec<u32>,
    /// Live KV-block indices (`J(S_s, i, j) = 1`) per live Q block,
    /// ascending within each row.
    pub kv_indices: Vec<u32>,
}

impl HeadPlan {
    /// Compile one head's symbols into index lists. `t_q`/`t_kv` are the
    /// raw block counts of the sequence the plan will execute on.
    pub fn from_symbols(sym: &HeadSymbols, t_q: usize, t_kv: usize, decode: DecodeMode) -> Self {
        assert_eq!(sym.q_groups, t_q.div_ceil(sym.pool.max(1)), "S_c geometry mismatch");
        assert_eq!(sym.kv_groups, t_kv.div_ceil(sym.pool.max(1)), "S_s geometry mismatch");
        assert!(
            t_q <= u32::MAX as usize && t_kv <= u32::MAX as usize,
            "block counts exceed the u32 index range"
        );
        let mut live_q = Vec::new();
        let mut cached_q = Vec::new();
        let mut kv_indptr = vec![0u32];
        let mut kv_indices: Vec<u32> = Vec::new();
        for bi in 0..t_q {
            if !sym.f(bi) {
                cached_q.push(bi as u32);
                continue;
            }
            live_q.push(bi as u32);
            match decode {
                DecodeMode::RowCached => {
                    let mut dec = sym.row_decoder(bi);
                    for bj in 0..t_kv {
                        if dec.j(bj) {
                            kv_indices.push(bj as u32);
                        }
                    }
                }
                DecodeMode::PerAccess => {
                    for bj in 0..t_kv {
                        if sym.j(bi, bj) {
                            kv_indices.push(bj as u32);
                        }
                    }
                }
            }
            let end = u32::try_from(kv_indices.len()).expect("kv index count exceeds u32");
            kv_indptr.push(end);
        }
        HeadPlan { t_q, t_kv, live_q, cached_q, kv_indptr, kv_indices }
    }

    /// Fully-dense plan (every block live, every pair computed).
    pub fn dense(t_q: usize, t_kv: usize) -> Self {
        assert!(
            t_q <= u32::MAX as usize && t_q.saturating_mul(t_kv) <= u32::MAX as usize,
            "dense plan exceeds the u32 index range"
        );
        let live_q: Vec<u32> = (0..t_q as u32).collect();
        let kv_indptr: Vec<u32> = (0..=t_q).map(|i| (i * t_kv) as u32).collect();
        let mut kv_indices: Vec<u32> = Vec::with_capacity(t_q * t_kv);
        for _ in 0..t_q {
            kv_indices.extend(0..t_kv as u32);
        }
        HeadPlan { t_q, t_kv, live_q, cached_q: Vec::new(), kv_indptr, kv_indices }
    }

    /// Live KV-block indices of the `li`-th *live* Q block.
    #[inline]
    pub fn live_kv(&self, li: usize) -> &[u32] {
        &self.kv_indices[self.kv_indptr[li] as usize..self.kv_indptr[li + 1] as usize]
    }

    /// (Qi, Kj) pairs the plan will compute.
    #[inline]
    pub fn computed_pairs(&self) -> usize {
        self.kv_indices.len()
    }

    /// Pairs of a dense computation.
    #[inline]
    pub fn total_pairs(&self) -> usize {
        self.t_q * self.t_kv
    }

    /// Attention statistics this plan implies (single source of truth —
    /// the kernel no longer counts anything in its inner loop).
    pub fn attn_stats(&self) -> AttnStats {
        AttnStats {
            computed_pairs: self.computed_pairs(),
            total_pairs: self.total_pairs(),
            cached_blocks: self.cached_q.len(),
            q_blocks: self.t_q,
        }
    }

    /// GEMM tile statistics (spatial axis only: one tile per Q block).
    pub fn gemm_stats(&self) -> GemmStats {
        GemmStats { computed_tiles: self.live_q.len(), total_tiles: self.t_q }
    }

    /// Fraction of block pairs *not* computed (block-granular Sparsity).
    pub fn pair_sparsity(&self) -> f64 {
        self.attn_stats().sparsity()
    }

    /// Fraction of Q blocks served from cache.
    pub fn cache_sparsity(&self) -> f64 {
        self.gemm_stats().sparsity()
    }

    /// Planned attention FLOPs for head dim `d` (`QKᵀ` + `P·V`, one
    /// multiply-add = 2 FLOPs) — precomputed from the live pair count.
    pub fn attention_flops(&self, block_q: usize, block_k: usize, d: usize) -> f64 {
        4.0 * self.computed_pairs() as f64 * (block_q * block_k * d) as f64
    }

    /// Restrict the plan to Q blocks `[lo, hi)`, rebasing indices to the
    /// slice — used to hand each stream (text prefix / vision suffix) of
    /// the joint sequence its own plan for GEMM-Q / GEMM-O.
    pub fn slice_q(&self, lo: usize, hi: usize) -> HeadPlan {
        assert!(lo <= hi && hi <= self.t_q, "bad Q-block slice [{lo}, {hi})");
        let (lo32, hi32) = (lo as u32, hi as u32);
        let mut live_q = Vec::new();
        let mut kv_indptr = vec![0u32];
        let mut kv_indices: Vec<u32> = Vec::new();
        for (li, &bi) in self.live_q.iter().enumerate() {
            if bi < lo32 || bi >= hi32 {
                continue;
            }
            live_q.push(bi - lo32);
            kv_indices.extend_from_slice(self.live_kv(li));
            kv_indptr.push(kv_indices.len() as u32);
        }
        let cached_q = self
            .cached_q
            .iter()
            .filter(|&&bi| bi >= lo32 && bi < hi32)
            .map(|&bi| bi - lo32)
            .collect();
        HeadPlan { t_q: hi - lo, t_kv: self.t_kv, live_q, cached_q, kv_indptr, kv_indices }
    }

    /// Number of `u32` entries across all index lists.
    pub fn index_len(&self) -> usize {
        self.live_q.len() + self.cached_q.len() + self.kv_indptr.len() + self.kv_indices.len()
    }

    /// Bytes held by the index lists (plan memory footprint; `u32`-packed).
    pub fn index_bytes(&self) -> usize {
        self.index_len() * std::mem::size_of::<u32>()
    }
}

/// Compiled plans for all heads of one layer, plus the block geometry the
/// kernels need. Built once per (layer, symbol refresh); every sparse
/// kernel of the layer consumes it read-only.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SparsePlan {
    pub heads: Vec<HeadPlan>,
    pub t_q: usize,
    pub t_kv: usize,
    pub block_q: usize,
    pub block_k: usize,
}

impl SparsePlan {
    /// Compile a layer's symbols into per-head plans.
    pub fn compile(
        syms: &LayerSymbols,
        t_q: usize,
        t_kv: usize,
        block_q: usize,
        block_k: usize,
        decode: DecodeMode,
    ) -> Self {
        SparsePlan {
            heads: syms
                .heads
                .iter()
                .map(|h| HeadPlan::from_symbols(h, t_q, t_kv, decode))
                .collect(),
            t_q,
            t_kv,
            block_q,
            block_k,
        }
    }

    /// Fully-dense plan for `heads` heads.
    pub fn dense(heads: usize, t_q: usize, t_kv: usize, block_q: usize, block_k: usize) -> Self {
        SparsePlan {
            heads: (0..heads).map(|_| HeadPlan::dense(t_q, t_kv)).collect(),
            t_q,
            t_kv,
            block_q,
            block_k,
        }
    }

    /// Row-slice every head (see [`HeadPlan::slice_q`]).
    pub fn slice_q(&self, lo: usize, hi: usize) -> SparsePlan {
        SparsePlan {
            heads: self.heads.iter().map(|h| h.slice_q(lo, hi)).collect(),
            t_q: hi - lo,
            t_kv: self.t_kv,
            block_q: self.block_q,
            block_k: self.block_k,
        }
    }

    /// Aggregated GEMM tile statistics across heads.
    pub fn gemm_stats(&self) -> GemmStats {
        let mut s = GemmStats::default();
        for h in &self.heads {
            let hs = h.gemm_stats();
            s.computed_tiles += hs.computed_tiles;
            s.total_tiles += hs.total_tiles;
        }
        s
    }

    /// Aggregated attention statistics across heads.
    pub fn attn_stats(&self) -> AttnStats {
        let mut s = AttnStats::default();
        for h in &self.heads {
            let hs = h.attn_stats();
            s.computed_pairs += hs.computed_pairs;
            s.total_pairs += hs.total_pairs;
            s.cached_blocks += hs.cached_blocks;
            s.q_blocks += hs.q_blocks;
        }
        s
    }

    /// Mean fraction of block pairs not computed across heads.
    pub fn pair_sparsity(&self) -> f64 {
        self.attn_stats().sparsity()
    }

    /// Mean fraction of Q blocks served from cache across heads.
    pub fn cache_sparsity(&self) -> f64 {
        self.gemm_stats().sparsity()
    }

    /// Density = fraction of pairs computed.
    pub fn density(&self) -> f64 {
        1.0 - self.pair_sparsity()
    }

    /// Planned attention FLOPs for head dim `d`, summed over heads.
    pub fn attention_flops(&self, d: usize) -> f64 {
        self.heads
            .iter()
            .map(|h| h.attention_flops(self.block_q, self.block_k, d))
            .sum()
    }

    /// Number of `u32` entries across all heads' index lists.
    pub fn index_len(&self) -> usize {
        self.heads.iter().map(|h| h.index_len()).sum()
    }

    /// Bytes held by all index lists (`u32`-packed).
    pub fn index_bytes(&self) -> usize {
        self.heads.iter().map(|h| h.index_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::symbols::HeadSymbols;
    use crate::testutil::{prop_check, rand_mask};

    #[test]
    fn dense_plan_covers_everything() {
        let p = HeadPlan::dense(3, 5);
        assert_eq!(p.live_q, vec![0, 1, 2]);
        assert!(p.cached_q.is_empty());
        assert_eq!(p.computed_pairs(), 15);
        assert_eq!(p.total_pairs(), 15);
        assert_eq!(p.live_kv(1), &[0, 1, 2, 3, 4]);
        assert_eq!(p.attn_stats().sparsity(), 0.0);
        assert_eq!(p.gemm_stats().sparsity(), 0.0);
        let d = HeadPlan::from_symbols(&HeadSymbols::dense(3, 5, 1), 3, 5, DecodeMode::RowCached);
        assert_eq!(p, d);
    }

    #[test]
    fn compile_matches_naive_decode() {
        prop_check("plan == per-block F/J decode", 50, |rng| {
            let pool = 1 + rng.below(3);
            let t_q = 1 + rng.below(30);
            let t_kv = 1 + rng.below(30);
            let qg = t_q.div_ceil(pool);
            let kg = t_kv.div_ceil(pool);
            let m_c = rand_mask(rng, qg, 0.6);
            let m_s = rand_mask(rng, qg * kg, 0.5);
            let sym = HeadSymbols::from_masks(&m_c, &m_s, kg, pool);
            let plan = HeadPlan::from_symbols(&sym, t_q, t_kv, DecodeMode::RowCached);
            let mut li = 0;
            for bi in 0..t_q {
                if !sym.f(bi) {
                    assert!(plan.cached_q.contains(&(bi as u32)));
                    continue;
                }
                assert_eq!(plan.live_q[li], bi as u32);
                let want: Vec<u32> =
                    (0..t_kv).filter(|&bj| sym.j(bi, bj)).map(|bj| bj as u32).collect();
                assert_eq!(plan.live_kv(li), &want[..]);
                li += 1;
            }
            assert_eq!(li, plan.live_q.len());
            assert_eq!(plan.live_q.len() + plan.cached_q.len(), t_q);
        });
    }

    #[test]
    fn slice_rebases_indices() {
        let sym = HeadSymbols::from_masks(
            &[true, false, true, true],
            &[
                true, false, true, true, // row 0
                true, true, true, true, // row 1 (cached)
                false, false, true, false, // row 2
                true, true, false, true, // row 3
            ],
            4,
            1,
        );
        let plan = HeadPlan::from_symbols(&sym, 4, 4, DecodeMode::RowCached);
        let head = plan.slice_q(0, 2);
        assert_eq!(head.live_q, vec![0]);
        assert_eq!(head.cached_q, vec![1]);
        assert_eq!(head.live_kv(0), &[0, 2, 3]);
        let tail = plan.slice_q(2, 4);
        assert_eq!(tail.live_q, vec![0, 1]);
        assert!(tail.cached_q.is_empty());
        assert_eq!(tail.live_kv(0), &[2]);
        assert_eq!(tail.live_kv(1), &[0, 1, 3]);
        // The two slices partition the pair count.
        assert_eq!(
            head.computed_pairs() + tail.computed_pairs(),
            plan.computed_pairs()
        );
    }

    #[test]
    fn layer_aggregation_and_sparsity() {
        let syms = LayerSymbols {
            heads: vec![
                HeadSymbols::from_masks(&[false, true], &[true; 4], 2, 1),
                HeadSymbols::from_masks(&[true, true], &[true; 4], 2, 1),
            ],
        };
        let plan = SparsePlan::compile(&syms, 2, 2, 8, 8, DecodeMode::RowCached);
        let g = plan.gemm_stats();
        assert_eq!(g.computed_tiles, 3);
        assert_eq!(g.total_tiles, 4);
        let a = plan.attn_stats();
        assert_eq!(a.computed_pairs, 6);
        assert_eq!(a.total_pairs, 8);
        assert!((plan.cache_sparsity() - 0.25).abs() < 1e-12);
        assert!((plan.pair_sparsity() - 0.25).abs() < 1e-12);
        assert!(plan.index_bytes() > 0);
        // FLOP precomputation follows the live pair count.
        assert!((plan.attention_flops(4) - 4.0 * 6.0 * (8 * 8 * 4) as f64).abs() < 1e-9);
    }

    #[test]
    fn stats_match_symbol_accounting_at_pool_1() {
        prop_check("plan sparsity == symbol sparsity (pool 1)", 30, |rng| {
            let t_q = 1 + rng.below(20);
            let t_kv = 1 + rng.below(20);
            let m_c = rand_mask(rng, t_q, 0.7);
            let m_s = rand_mask(rng, t_q * t_kv, 0.6);
            let sym = HeadSymbols::from_masks(&m_c, &m_s, t_kv, 1);
            let plan = HeadPlan::from_symbols(&sym, t_q, t_kv, DecodeMode::RowCached);
            assert!((plan.pair_sparsity() - sym.pair_sparsity()).abs() < 1e-12);
            assert!((plan.cache_sparsity() - sym.cache_sparsity()).abs() < 1e-12);
        });
    }
}
