//! Compiled **sparse execution plans** — the bridge from symbols to kernels.
//!
//! The paper's unified symbols (`S_c`, `S_s`, [`crate::symbols`]) are a
//! compact *transport* format: one bit per block group. Executing directly
//! from them forces every kernel to re-run the bitwise decode functions
//! `F`/`J` per tile, per head, per call — the overhead the paper's §4.3
//! register-cache optimization fights on the GPU. FlashInfer-style engines
//! instead *compile* the mask once into compact block-index lists
//! (`indptr`/`indices`) that every kernel consumes with zero decode work in
//! its inner loop. This module is that compile step:
//!
//! * [`HeadPlan`] — one head's live structure: the list of computed
//!   (`live_q`) and cached (`cached_q`) Q-block indices from `S_c`, plus a
//!   CSR (`kv_indptr`/`kv_indices`) of live KV-block indices per live Q
//!   block from `S_s`.
//! * [`SparsePlan`] — all heads of one layer plus the block geometry,
//!   compiled once per (layer, symbol refresh) and reused across every
//!   Dispatch step until the policy refreshes the symbols.
//! * [`PlanDelta`] ([`delta`]) — the *changed row-groups* between two
//!   symbol refreshes, computed by diffing packed symbol bytes;
//!   [`SparsePlan::apply_delta`] turns it into an **incremental recompile**
//!   that decodes only the changed rows.
//!
//! # Plan storage: segmented, pool-shared row-groups
//!
//! A plan's row structure is owned in **segments**: one ref-counted
//! [`crate::mem::PagePool`] block (`Pooled<RowSegment>`) per symbol
//! row-group (`pool` consecutive Q-block rows — the granularity at which
//! a symbol refresh can change anything). [`SparsePlan::apply_delta`]
//! recompiles only the segments named by a [`PlanDelta`] and
//! handle-clones every other segment from the base plan (a refcount bump
//! on the same pool block), so an incremental recompile does
//! `O(changed rows · t_kv)` decode work instead of `O(t_q · t_kv)`, and
//! unchanged KV index lists are *shared* (not copied) between
//! consecutive plans — and counted once in the pool's resident pages.
//!
//! The tradeoff vs. the `Arc`-per-row alternative: per-row `Arc`s would
//! make the delta granularity exact (a one-row flip re-decodes one row,
//! not `pool` rows) but cost one allocation + refcount per row and scatter
//! each row's KV list into its own heap cell — bad for the kernels, which
//! stream the CSR lists as their hottest metadata. Per-group segments
//! amortize the `Arc` overhead over `pool` rows, keep each group's KV
//! indices contiguous, and line up exactly with the unit a symbol byte
//! diff can report — which is why the whole delta pipeline (diff → apply)
//! speaks row-groups. The small kernel-facing flat views (`live_q`,
//! `cached_q`, and the per-live-row segment locators behind
//! [`HeadPlan::live_kv`]) are rebuilt in `O(t_q)` on every delta, so the
//! kernels keep dense, branch-free iteration and did not change at all.
//!
//! [`DecodeMode`] lives here because decode strategy is now a
//! *plan-construction* concern: both modes must (and are property-tested
//! to) produce identical plans; the §4.3 decode-overhead benchmark times
//! plan compilation — and the legacy symbol-decoding kernels — under both.
//!
//! [`AttnStats`] and [`GemmStats`] are also defined here and *derived from
//! the plan* (`attn_stats()` / `gemm_stats()`), so the engine, `metrics/`
//! and `report/` all read one source of truth for tile/pair accounting.
//!
//! Index lists are packed to **`u32`** (the FlashInfer idiom): block
//! counts never approach 2³², and halving the index footprint matters at
//! video-scale sequences where the CSR lists are the kernels' hottest
//! metadata stream. [`HeadPlan::from_symbols`] asserts the geometry fits.

#![warn(missing_docs)]

pub mod cache;
pub mod delta;

pub use delta::PlanDelta;

use crate::exec::ExecPool;
use crate::mem::{PagePool, Pooled};
use crate::symbols::{HeadSymbols, LayerSymbols};

/// How the reduction-axis symbols are decoded while *compiling* a plan —
/// retained to reproduce the paper's FC-vs-BSS decode-overhead analysis
/// (§4.3). Both modes yield identical plans.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecodeMode {
    /// Decode a symbol byte once per 8 groups and keep it in a register
    /// (the paper's optimization).
    RowCached,
    /// Re-run the full bitwise decode `J(S_s, i, j)` for every KV block
    /// (the naive scheme the paper says burns CUDA-core cycles).
    PerAccess,
}

/// Execution statistics for one attention call, derived from a plan.
#[derive(Clone, Copy, Debug, Default)]
pub struct AttnStats {
    /// (Qi, Kj) block pairs actually computed.
    pub computed_pairs: usize,
    /// Total block pairs in a dense computation.
    pub total_pairs: usize,
    /// Q blocks served from cache.
    pub cached_blocks: usize,
    /// Total Q blocks.
    pub q_blocks: usize,
}

impl AttnStats {
    /// The paper's Sparsity metric: `skip / total`.
    pub fn sparsity(&self) -> f64 {
        if self.total_pairs == 0 {
            return 0.0;
        }
        1.0 - self.computed_pairs as f64 / self.total_pairs as f64
    }
}

/// Tile statistics for the sparse GEMMs, derived from a plan.
#[derive(Clone, Copy, Debug, Default)]
pub struct GemmStats {
    /// Row-block tiles actually projected.
    pub computed_tiles: usize,
    /// Tiles of the dense equivalent.
    pub total_tiles: usize,
}

impl GemmStats {
    /// Fraction of tiles skipped: `1 - computed / total`.
    pub fn sparsity(&self) -> f64 {
        if self.total_tiles == 0 {
            return 0.0;
        }
        1.0 - self.computed_tiles as f64 / self.total_tiles as f64
    }
}

/// One contiguous run of Q-block rows compiled as a unit — the plan's
/// ownership (and delta) granularity. Indices are in the owning plan's
/// frame; `kv_indptr` is local to the segment (`kv_indptr[0] == 0`).
#[derive(Clone, Debug, PartialEq, Eq)]
struct RowSegment {
    /// First Q-block row this segment covers (plan frame).
    start: u32,
    /// Number of Q-block rows covered.
    rows: u32,
    /// Live (computed) Q-block indices within the covered range, ascending.
    live: Vec<u32>,
    /// Cached Q-block indices within the covered range, ascending.
    cached: Vec<u32>,
    /// Local CSR row pointers into `kv_indices`; `len = live.len() + 1`.
    kv_indptr: Vec<u32>,
    /// Live KV-block indices per live row, ascending within each row.
    kv_indices: Vec<u32>,
}

impl RowSegment {
    /// Decode rows `[start, start + rows)` (plan frame) of one head's
    /// symbols. `off` rebases plan-frame rows into the symbols' frame
    /// (`raw = off + bi`): 0 for a full plan, the slice's first raw row
    /// for a row-slice plan delta-compiled straight off the joint symbols.
    fn from_symbols(
        sym: &HeadSymbols,
        off: usize,
        start: usize,
        rows: usize,
        t_kv: usize,
        decode: DecodeMode,
    ) -> RowSegment {
        let mut live = Vec::new();
        let mut cached = Vec::new();
        let mut kv_indptr = vec![0u32];
        let mut kv_indices: Vec<u32> = Vec::new();
        for bi in start..start + rows {
            let raw = off + bi;
            if !sym.f(raw) {
                cached.push(bi as u32);
                continue;
            }
            live.push(bi as u32);
            match decode {
                DecodeMode::RowCached => {
                    let mut dec = sym.row_decoder(raw);
                    for bj in 0..t_kv {
                        if dec.j(bj) {
                            kv_indices.push(bj as u32);
                        }
                    }
                }
                DecodeMode::PerAccess => {
                    for bj in 0..t_kv {
                        if sym.j(raw, bj) {
                            kv_indices.push(bj as u32);
                        }
                    }
                }
            }
            let end = u32::try_from(kv_indices.len()).expect("kv index count exceeds u32");
            kv_indptr.push(end);
        }
        RowSegment {
            start: start as u32,
            rows: rows as u32,
            live,
            cached,
            kv_indptr,
            kv_indices,
        }
    }

    /// KV indices of the segment's `r`-th live row.
    #[inline]
    fn kv_row(&self, r: usize) -> &[u32] {
        &self.kv_indices[self.kv_indptr[r] as usize..self.kv_indptr[r + 1] as usize]
    }

    /// Copy of rows `[a, b)` (plan frame of the parent), rebased by `off`.
    fn sliced(&self, a: usize, b: usize, off: usize) -> RowSegment {
        let mut live = Vec::new();
        let mut cached = Vec::new();
        let mut kv_indptr = vec![0u32];
        let mut kv_indices: Vec<u32> = Vec::new();
        for (r, &bi) in self.live.iter().enumerate() {
            let bi = bi as usize;
            if bi < a || bi >= b {
                continue;
            }
            live.push((bi - off) as u32);
            kv_indices.extend_from_slice(self.kv_row(r));
            kv_indptr.push(kv_indices.len() as u32);
        }
        for &bi in &self.cached {
            let bi = bi as usize;
            if bi >= a && bi < b {
                cached.push((bi - off) as u32);
            }
        }
        RowSegment {
            start: (a - off) as u32,
            rows: (b - a) as u32,
            live,
            cached,
            kv_indptr,
            kv_indices,
        }
    }

    /// `u32` entries held by this segment's index lists.
    fn index_len(&self) -> usize {
        self.live.len() + self.cached.len() + self.kv_indptr.len() + self.kv_indices.len()
    }

    /// Bytes this segment occupies, for pool page accounting.
    fn bytes(&self) -> usize {
        self.index_len() * std::mem::size_of::<u32>() + std::mem::size_of::<RowSegment>()
    }

    /// Move the segment into a pool block.
    fn into_pool(self, mem: &PagePool) -> Pooled<RowSegment> {
        let bytes = self.bytes();
        mem.alloc(bytes, self)
    }
}

/// Compiled sparse structure for one attention head.
///
/// All indices are *raw* block indices (`0..t_q` / `0..t_kv`), i.e. the
/// symbol pooling factor `n` has already been resolved at compile time.
/// Indices are packed to `u32` (FlashInfer idiom — half the cache
/// footprint of `usize` on 64-bit targets); kernels widen with `as usize`
/// at the loop head, which costs nothing.
///
/// Rows are *owned* in ref-counted [`PagePool`] segments of one symbol
/// row-group each (see the [module docs](self) for the
/// segmented-vs-per-row tradeoff); the flat `live_q`/`cached_q` views and
/// [`Self::live_kv`] keep the kernel-facing access pattern of a plain
/// CSR. Two plans compare equal ([`PartialEq`]) iff their *logical* index
/// content is identical, independent of how the rows are segmented — this
/// is the "bitwise identical" relation the delta-recompile property tests
/// assert.
#[derive(Clone, Debug)]
pub struct HeadPlan {
    /// Total Q blocks (`ceil(n / block_q)`).
    pub t_q: usize,
    /// Total KV blocks (`ceil(n_kv / block_k)`).
    pub t_kv: usize,
    /// Q-block indices computed this step (`F(S_c, i) = 1`), ascending.
    pub live_q: Vec<u32>,
    /// Q-block indices served from the feature cache (`F = 0`), ascending.
    pub cached_q: Vec<u32>,
    /// Row-group segments owning the CSR data, ordered by `start`.
    segs: Vec<Pooled<RowSegment>>,
    /// Per live row: `(segment index, local live-row index)` — the locator
    /// behind [`Self::live_kv`], rebuilt on every (delta) compile.
    row_locs: Vec<(u32, u32)>,
}

impl PartialEq for HeadPlan {
    fn eq(&self, other: &Self) -> bool {
        self.t_q == other.t_q
            && self.t_kv == other.t_kv
            && self.live_q == other.live_q
            && self.cached_q == other.cached_q
            && (0..self.live_q.len()).all(|li| self.live_kv(li) == other.live_kv(li))
    }
}

impl Eq for HeadPlan {}

impl HeadPlan {
    /// Build the flat kernel-facing views over a segment list.
    fn assemble(t_q: usize, t_kv: usize, segs: Vec<Pooled<RowSegment>>) -> Self {
        let live_n: usize = segs.iter().map(|s| s.live.len()).sum();
        let cached_n: usize = segs.iter().map(|s| s.cached.len()).sum();
        let mut live_q = Vec::with_capacity(live_n);
        let mut cached_q = Vec::with_capacity(cached_n);
        let mut row_locs = Vec::with_capacity(live_n);
        for (si, seg) in segs.iter().enumerate() {
            live_q.extend_from_slice(&seg.live);
            cached_q.extend_from_slice(&seg.cached);
            for r in 0..seg.live.len() {
                row_locs.push((si as u32, r as u32));
            }
        }
        HeadPlan { t_q, t_kv, live_q, cached_q, segs, row_locs }
    }

    /// Compile one head's symbols into index lists. `t_q`/`t_kv` are the
    /// raw block counts of the sequence the plan will execute on. One
    /// segment is built per symbol row-group, so the plan can later be
    /// delta-recompiled at that granularity ([`Self::apply_delta`]).
    /// Segments land in the process-global [`PagePool`]; engines with a
    /// private pool compile through [`Self::from_symbols_in`].
    pub fn from_symbols(sym: &HeadSymbols, t_q: usize, t_kv: usize, decode: DecodeMode) -> Self {
        Self::from_symbols_in(sym, t_q, t_kv, decode, PagePool::global())
    }

    /// [`Self::from_symbols`] with the segments allocated in an explicit
    /// [`PagePool`].
    pub fn from_symbols_in(
        sym: &HeadSymbols,
        t_q: usize,
        t_kv: usize,
        decode: DecodeMode,
        mem: &PagePool,
    ) -> Self {
        let pool = sym.pool.max(1);
        assert_eq!(sym.q_groups, t_q.div_ceil(pool), "S_c geometry mismatch");
        assert_eq!(sym.kv_groups, t_kv.div_ceil(pool), "S_s geometry mismatch");
        assert!(
            t_q <= u32::MAX as usize && t_kv <= u32::MAX as usize,
            "block counts exceed the u32 index range"
        );
        let segs = (0..sym.q_groups)
            .map(|g| {
                let start = g * pool;
                let rows = pool.min(t_q - start);
                RowSegment::from_symbols(sym, 0, start, rows, t_kv, decode).into_pool(mem)
            })
            .collect();
        Self::assemble(t_q, t_kv, segs)
    }

    /// The pool this plan's segments live in (the first segment's pool;
    /// plans built through one compile path keep all segments in one
    /// pool). Falls back to the global pool for segment-less plans.
    fn seg_pool(&self) -> PagePool {
        self.segs
            .first()
            .map(|s| s.pool().clone())
            .unwrap_or_else(|| PagePool::global().clone())
    }

    /// Incremental recompile: re-decode only the row-groups listed in
    /// `changed` (ascending, as produced by [`PlanDelta`]) from the *new*
    /// symbols `sym`, and share every other segment with `self` by `Arc`
    /// clone. The result is logically identical to
    /// [`Self::from_symbols`]`(sym, ..)` — property-tested bitwise across
    /// random mask flips in `rust/tests/plan_delta.rs`.
    ///
    /// Panics if `sym`'s geometry disagrees with the plan's, or if the
    /// plan was not compiled at symbol row-group granularity (plans from
    /// [`Self::from_symbols`] always are; [`Self::dense`] plans and
    /// arbitrary [`Self::slice_q`] slices are not).
    pub fn apply_delta(&self, changed: &[u32], sym: &HeadSymbols, decode: DecodeMode) -> Self {
        let pool = sym.pool.max(1);
        assert_eq!(
            sym.q_groups,
            self.t_q.div_ceil(pool),
            "delta symbols disagree with the plan's Q geometry"
        );
        self.apply_delta_at(changed, sym, 0, decode)
    }

    /// [`Self::apply_delta`] for a **row-slice** plan, reading the *joint*
    /// symbols at a row-group offset: this plan covers the symbols' groups
    /// `[group_off, group_off + groups)`, and `changed` is in the slice's
    /// group frame. Avoids materializing sliced symbol copies on the
    /// engine's delta path — changed segments decode straight out of the
    /// joint `S_c`/`S_s` streams, rebased into the slice frame.
    pub fn apply_delta_at(
        &self,
        changed: &[u32],
        sym: &HeadSymbols,
        group_off: usize,
        decode: DecodeMode,
    ) -> Self {
        let pool = sym.pool.max(1);
        let groups = self.t_q.div_ceil(pool);
        assert!(
            group_off + groups <= sym.q_groups,
            "slice [{group_off}, {}) exceeds the symbols' {} row-groups",
            group_off + groups,
            sym.q_groups
        );
        assert_eq!(
            sym.kv_groups,
            self.t_kv.div_ceil(pool),
            "delta symbols disagree with the plan's KV geometry"
        );
        assert_eq!(
            self.segs.len(),
            groups,
            "base plan is not segmented at symbol row-group granularity"
        );
        let off_blocks = group_off * pool;
        let mem = self.seg_pool();
        let mut next = changed.iter().peekable();
        let segs: Vec<Pooled<RowSegment>> = (0..groups)
            .map(|g| {
                let start = g * pool;
                let rows = pool.min(self.t_q - start);
                debug_assert_eq!(self.segs[g].start as usize, start, "segment misaligned");
                debug_assert_eq!(self.segs[g].rows as usize, rows, "segment misaligned");
                if next.peek().is_some_and(|&&c| c as usize == g) {
                    next.next();
                    RowSegment::from_symbols(sym, off_blocks, start, rows, self.t_kv, decode)
                        .into_pool(&mem)
                } else {
                    self.segs[g].clone()
                }
            })
            .collect();
        assert!(
            next.peek().is_none(),
            "changed row-groups must be ascending and < q_groups"
        );
        Self::assemble(self.t_q, self.t_kv, segs)
    }

    /// Fully-dense plan (every block live, every pair computed). Owned as
    /// a single segment — dense plans are never delta-recompiled.
    pub fn dense(t_q: usize, t_kv: usize) -> Self {
        assert!(
            t_q <= u32::MAX as usize && t_q.saturating_mul(t_kv) <= u32::MAX as usize,
            "dense plan exceeds the u32 index range"
        );
        let live: Vec<u32> = (0..t_q as u32).collect();
        let kv_indptr: Vec<u32> = (0..=t_q).map(|i| (i * t_kv) as u32).collect();
        let mut kv_indices: Vec<u32> = Vec::with_capacity(t_q * t_kv);
        for _ in 0..t_q {
            kv_indices.extend(0..t_kv as u32);
        }
        let seg = RowSegment {
            start: 0,
            rows: t_q as u32,
            live,
            cached: Vec::new(),
            kv_indptr,
            kv_indices,
        }
        .into_pool(PagePool::global());
        Self::assemble(t_q, t_kv, vec![seg])
    }

    /// Live KV-block indices of the `li`-th *live* Q block.
    #[inline]
    pub fn live_kv(&self, li: usize) -> &[u32] {
        let (si, r) = self.row_locs[li];
        self.segs[si as usize].kv_row(r as usize)
    }

    /// (Qi, Kj) pairs the plan will compute.
    #[inline]
    pub fn computed_pairs(&self) -> usize {
        self.segs.iter().map(|s| s.kv_indices.len()).sum()
    }

    /// Pairs of a dense computation.
    #[inline]
    pub fn total_pairs(&self) -> usize {
        self.t_q * self.t_kv
    }

    /// Attention statistics this plan implies (single source of truth —
    /// the kernel no longer counts anything in its inner loop).
    pub fn attn_stats(&self) -> AttnStats {
        AttnStats {
            computed_pairs: self.computed_pairs(),
            total_pairs: self.total_pairs(),
            cached_blocks: self.cached_q.len(),
            q_blocks: self.t_q,
        }
    }

    /// GEMM tile statistics (spatial axis only: one tile per Q block).
    pub fn gemm_stats(&self) -> GemmStats {
        GemmStats { computed_tiles: self.live_q.len(), total_tiles: self.t_q }
    }

    /// Fraction of block pairs *not* computed (block-granular Sparsity).
    pub fn pair_sparsity(&self) -> f64 {
        self.attn_stats().sparsity()
    }

    /// Fraction of Q blocks served from cache.
    pub fn cache_sparsity(&self) -> f64 {
        self.gemm_stats().sparsity()
    }

    /// Planned attention FLOPs for head dim `d` (`QKᵀ` + `P·V`, one
    /// multiply-add = 2 FLOPs) — precomputed from the live pair count.
    pub fn attention_flops(&self, block_q: usize, block_k: usize, d: usize) -> f64 {
        4.0 * self.computed_pairs() as f64 * (block_q * block_k * d) as f64
    }

    /// Restrict the plan to Q blocks `[lo, hi)`, rebasing indices to the
    /// slice — used to hand each stream (text prefix / vision suffix) of
    /// the joint sequence its own plan for GEMM-Q / GEMM-O.
    ///
    /// Segments that fall entirely inside a `lo == 0` slice are shared by
    /// handle clone (the engine's text slice — a refcount bump on the
    /// same pool block); every other overlap is copied and rebased.
    pub fn slice_q(&self, lo: usize, hi: usize) -> HeadPlan {
        assert!(lo <= hi && hi <= self.t_q, "bad Q-block slice [{lo}, {hi})");
        let mut segs: Vec<Pooled<RowSegment>> = Vec::new();
        for seg in &self.segs {
            let s = seg.start as usize;
            let e = s + seg.rows as usize;
            let (a, b) = (s.max(lo), e.min(hi));
            if a >= b {
                continue;
            }
            if lo == 0 && a == s && b == e {
                segs.push(seg.clone());
            } else {
                segs.push(seg.sliced(a, b, lo).into_pool(seg.pool()));
            }
        }
        Self::assemble(hi - lo, self.t_kv, segs)
    }

    /// Number of `u32` entries across all index lists (flat views, the
    /// per-live-row locators, and the owning segments).
    pub fn index_len(&self) -> usize {
        self.live_q.len()
            + self.cached_q.len()
            + 2 * self.row_locs.len()
            + self.segs.iter().map(|s| s.index_len()).sum::<usize>()
    }

    /// Bytes held by the index lists (plan memory footprint; `u32`-packed).
    /// Segments shared with other plans are counted once per plan.
    pub fn index_bytes(&self) -> usize {
        self.index_len() * std::mem::size_of::<u32>()
    }

    /// How many of this plan's segments share their pool block with
    /// `other` (same allocation, not merely equal content) — the
    /// structural-sharing measure the delta tests and the fig13 bench
    /// report.
    pub fn shared_segments_with(&self, other: &HeadPlan) -> usize {
        self.segs
            .iter()
            .filter(|s| other.segs.iter().any(|o| Pooled::ptr_eq(s, o)))
            .count()
    }

    /// Number of row-group segments owning this plan's rows.
    pub fn segments(&self) -> usize {
        self.segs.len()
    }
}

/// Compiled plans for all heads of one layer, plus the block geometry the
/// kernels need. Built once per (layer, symbol refresh) — in full via
/// [`SparsePlan::compile`], or incrementally from the previous refresh via
/// [`SparsePlan::apply_delta`] — and consumed read-only by every sparse
/// kernel of the layer.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SparsePlan {
    /// Per-head compiled plans (one entry per attention head).
    pub heads: Vec<HeadPlan>,
    /// Total Q blocks per head.
    pub t_q: usize,
    /// Total KV blocks per head.
    pub t_kv: usize,
    /// Q-block side length in tokens.
    pub block_q: usize,
    /// KV-block side length in tokens.
    pub block_k: usize,
}

impl SparsePlan {
    /// Compile a layer's symbols into per-head plans.
    ///
    /// ```
    /// use flashomni::plan::{DecodeMode, SparsePlan};
    /// use flashomni::symbols::LayerSymbols;
    ///
    /// let syms = LayerSymbols::dense(2, 4, 4, 1);
    /// let plan = SparsePlan::compile(&syms, 4, 4, 8, 8, DecodeMode::RowCached);
    /// assert_eq!(plan.heads.len(), 2);
    /// assert_eq!(plan.attn_stats().sparsity(), 0.0);
    /// ```
    pub fn compile(
        syms: &LayerSymbols,
        t_q: usize,
        t_kv: usize,
        block_q: usize,
        block_k: usize,
        decode: DecodeMode,
    ) -> Self {
        Self::compile_in(syms, t_q, t_kv, block_q, block_k, decode, PagePool::global())
    }

    /// [`Self::compile`] with the per-head segments allocated in an
    /// explicit [`PagePool`] (engines with a private page budget).
    pub fn compile_in(
        syms: &LayerSymbols,
        t_q: usize,
        t_kv: usize,
        block_q: usize,
        block_k: usize,
        decode: DecodeMode,
        mem: &PagePool,
    ) -> Self {
        SparsePlan {
            heads: syms
                .heads
                .iter()
                .map(|h| HeadPlan::from_symbols_in(h, t_q, t_kv, decode, mem))
                .collect(),
            t_q,
            t_kv,
            block_q,
            block_k,
        }
    }

    /// [`Self::compile`] with the per-head decode fanned out over an
    /// [`ExecPool`] — the "pool" variant the fig13 bench compares against
    /// the serial compile. Bitwise-identical to the serial path (heads are
    /// independent and results are placed by head index).
    pub fn compile_on(
        syms: &LayerSymbols,
        t_q: usize,
        t_kv: usize,
        block_q: usize,
        block_k: usize,
        decode: DecodeMode,
        exec: &ExecPool,
    ) -> Self {
        SparsePlan {
            heads: exec.parallel_map_indexed(syms.heads.len(), |h| {
                HeadPlan::from_symbols(&syms.heads[h], t_q, t_kv, decode)
            }),
            t_q,
            t_kv,
            block_q,
            block_k,
        }
    }

    /// Incremental recompile: rebuild only the row-groups a [`PlanDelta`]
    /// marks as changed (per head) from the new symbols `syms`, sharing
    /// every unchanged segment of `self` by `Arc` clone.
    ///
    /// Logically identical to [`Self::compile`]`(syms, ..)` — see the
    /// module docs for the delta pipeline and `rust/tests/plan_delta.rs`
    /// for the bitwise property tests.
    ///
    /// ```
    /// use flashomni::plan::{DecodeMode, PlanDelta, SparsePlan};
    /// use flashomni::plan::cache::symbol_key;
    /// use flashomni::symbols::{HeadSymbols, LayerSymbols};
    ///
    /// let m_c = [true; 4];
    /// let old_m = [true; 16];
    /// let mut new_m = old_m;
    /// new_m[5] = false; // flip one KV pair in row-group 1
    /// let old = LayerSymbols { heads: vec![HeadSymbols::from_masks(&m_c, &old_m, 4, 1)] };
    /// let new = LayerSymbols { heads: vec![HeadSymbols::from_masks(&m_c, &new_m, 4, 1)] };
    ///
    /// let geometry = [4usize, 4, 8, 8];
    /// let delta = PlanDelta::between(
    ///     &symbol_key(&old, &geometry),
    ///     &symbol_key(&new, &geometry),
    ///     &new,
    ///     geometry.len(),
    /// )
    /// .expect("matching geometry diffs at row granularity");
    /// assert!(!delta.is_empty());
    ///
    /// let base = SparsePlan::compile(&old, 4, 4, 8, 8, DecodeMode::RowCached);
    /// let fast = base.apply_delta(&delta, &new, DecodeMode::RowCached);
    /// let full = SparsePlan::compile(&new, 4, 4, 8, 8, DecodeMode::RowCached);
    /// assert_eq!(fast, full); // bitwise-identical index content
    /// ```
    pub fn apply_delta(&self, delta: &PlanDelta, syms: &LayerSymbols, decode: DecodeMode) -> Self {
        assert_eq!(self.heads.len(), syms.heads.len(), "head count changed");
        assert_eq!(self.heads.len(), delta.head_count(), "delta head count mismatch");
        SparsePlan {
            heads: self
                .heads
                .iter()
                .enumerate()
                .map(|(h, hp)| hp.apply_delta(delta.changed(h), &syms.heads[h], decode))
                .collect(),
            t_q: self.t_q,
            t_kv: self.t_kv,
            block_q: self.block_q,
            block_k: self.block_k,
        }
    }

    /// [`Self::apply_delta`] for a layer of **row-slice** plans, reading
    /// the *joint* symbols at row-group offset `group_off` (see
    /// [`HeadPlan::apply_delta_at`]) — the engine's text/vision slices
    /// delta-compile through this without materializing sliced symbols.
    pub fn apply_delta_at(
        &self,
        delta: &PlanDelta,
        syms: &LayerSymbols,
        group_off: usize,
        decode: DecodeMode,
    ) -> Self {
        assert_eq!(self.heads.len(), syms.heads.len(), "head count changed");
        assert_eq!(self.heads.len(), delta.head_count(), "delta head count mismatch");
        SparsePlan {
            heads: self
                .heads
                .iter()
                .enumerate()
                .map(|(h, hp)| hp.apply_delta_at(delta.changed(h), &syms.heads[h], group_off, decode))
                .collect(),
            t_q: self.t_q,
            t_kv: self.t_kv,
            block_q: self.block_q,
            block_k: self.block_k,
        }
    }

    /// [`Self::apply_delta`] with the per-head work fanned out over an
    /// [`ExecPool`] (fig13's "pool" delta path). Bitwise-identical to the
    /// serial delta.
    pub fn apply_delta_on(
        &self,
        delta: &PlanDelta,
        syms: &LayerSymbols,
        decode: DecodeMode,
        exec: &ExecPool,
    ) -> Self {
        assert_eq!(self.heads.len(), syms.heads.len(), "head count changed");
        assert_eq!(self.heads.len(), delta.head_count(), "delta head count mismatch");
        SparsePlan {
            heads: exec.parallel_map_indexed(self.heads.len(), |h| {
                self.heads[h].apply_delta(delta.changed(h), &syms.heads[h], decode)
            }),
            t_q: self.t_q,
            t_kv: self.t_kv,
            block_q: self.block_q,
            block_k: self.block_k,
        }
    }

    /// Fully-dense plan for `heads` heads.
    pub fn dense(heads: usize, t_q: usize, t_kv: usize, block_q: usize, block_k: usize) -> Self {
        SparsePlan {
            heads: (0..heads).map(|_| HeadPlan::dense(t_q, t_kv)).collect(),
            t_q,
            t_kv,
            block_q,
            block_k,
        }
    }

    /// Row-slice every head (see [`HeadPlan::slice_q`]).
    pub fn slice_q(&self, lo: usize, hi: usize) -> SparsePlan {
        SparsePlan {
            heads: self.heads.iter().map(|h| h.slice_q(lo, hi)).collect(),
            t_q: hi - lo,
            t_kv: self.t_kv,
            block_q: self.block_q,
            block_k: self.block_k,
        }
    }

    /// Flatten the per-head live Q-block lists into one `(head, block)`
    /// work list — the tile order the GEMM-Q kernels walk (head-major,
    /// ascending block within a head). The pool kernels chunk this list
    /// into tasks; sharing the flattening here keeps every variant's task
    /// decomposition identical.
    pub fn live_tiles(&self) -> Vec<(u32, u32)> {
        let mut tiles = Vec::new();
        for (h, hp) in self.heads.iter().enumerate() {
            for &bi in &hp.live_q {
                tiles.push((h as u32, bi));
            }
        }
        tiles
    }

    /// Aggregated GEMM tile statistics across heads.
    pub fn gemm_stats(&self) -> GemmStats {
        let mut s = GemmStats::default();
        for h in &self.heads {
            let hs = h.gemm_stats();
            s.computed_tiles += hs.computed_tiles;
            s.total_tiles += hs.total_tiles;
        }
        s
    }

    /// Aggregated attention statistics across heads.
    pub fn attn_stats(&self) -> AttnStats {
        let mut s = AttnStats::default();
        for h in &self.heads {
            let hs = h.attn_stats();
            s.computed_pairs += hs.computed_pairs;
            s.total_pairs += hs.total_pairs;
            s.cached_blocks += hs.cached_blocks;
            s.q_blocks += hs.q_blocks;
        }
        s
    }

    /// Mean fraction of block pairs not computed across heads.
    pub fn pair_sparsity(&self) -> f64 {
        self.attn_stats().sparsity()
    }

    /// Mean fraction of Q blocks served from cache across heads.
    pub fn cache_sparsity(&self) -> f64 {
        self.gemm_stats().sparsity()
    }

    /// Density = fraction of pairs computed.
    pub fn density(&self) -> f64 {
        1.0 - self.pair_sparsity()
    }

    /// Planned attention FLOPs for head dim `d`, summed over heads.
    pub fn attention_flops(&self, d: usize) -> f64 {
        self.heads
            .iter()
            .map(|h| h.attention_flops(self.block_q, self.block_k, d))
            .sum()
    }

    /// Number of `u32` entries across all heads' index lists.
    pub fn index_len(&self) -> usize {
        self.heads.iter().map(|h| h.index_len()).sum()
    }

    /// Bytes held by all index lists (`u32`-packed).
    pub fn index_bytes(&self) -> usize {
        self.heads.iter().map(|h| h.index_bytes()).sum()
    }

    /// Total segments `Arc`-shared with `other`, summed over heads (see
    /// [`HeadPlan::shared_segments_with`]).
    pub fn shared_segments_with(&self, other: &SparsePlan) -> usize {
        self.heads
            .iter()
            .zip(&other.heads)
            .map(|(a, b)| a.shared_segments_with(b))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::symbols::HeadSymbols;
    use crate::testutil::{prop_check, rand_mask};

    #[test]
    fn dense_plan_covers_everything() {
        let p = HeadPlan::dense(3, 5);
        assert_eq!(p.live_q, vec![0, 1, 2]);
        assert!(p.cached_q.is_empty());
        assert_eq!(p.computed_pairs(), 15);
        assert_eq!(p.total_pairs(), 15);
        assert_eq!(p.live_kv(1), &[0, 1, 2, 3, 4]);
        assert_eq!(p.attn_stats().sparsity(), 0.0);
        assert_eq!(p.gemm_stats().sparsity(), 0.0);
        let d = HeadPlan::from_symbols(&HeadSymbols::dense(3, 5, 1), 3, 5, DecodeMode::RowCached);
        assert_eq!(p, d);
        // Logical equality is independent of segmentation: the dense plan
        // is one segment, the compiled plan one per row-group.
        assert_eq!(p.segments(), 1);
        assert_eq!(d.segments(), 3);
    }

    #[test]
    fn compile_matches_naive_decode() {
        prop_check("plan == per-block F/J decode", 50, |rng| {
            let pool = 1 + rng.below(3);
            let t_q = 1 + rng.below(30);
            let t_kv = 1 + rng.below(30);
            let qg = t_q.div_ceil(pool);
            let kg = t_kv.div_ceil(pool);
            let m_c = rand_mask(rng, qg, 0.6);
            let m_s = rand_mask(rng, qg * kg, 0.5);
            let sym = HeadSymbols::from_masks(&m_c, &m_s, kg, pool);
            let plan = HeadPlan::from_symbols(&sym, t_q, t_kv, DecodeMode::RowCached);
            let mut li = 0;
            for bi in 0..t_q {
                if !sym.f(bi) {
                    assert!(plan.cached_q.contains(&(bi as u32)));
                    continue;
                }
                assert_eq!(plan.live_q[li], bi as u32);
                let want: Vec<u32> =
                    (0..t_kv).filter(|&bj| sym.j(bi, bj)).map(|bj| bj as u32).collect();
                assert_eq!(plan.live_kv(li), &want[..]);
                li += 1;
            }
            assert_eq!(li, plan.live_q.len());
            assert_eq!(plan.live_q.len() + plan.cached_q.len(), t_q);
            assert_eq!(plan.segments(), qg, "one segment per symbol row-group");
        });
    }

    #[test]
    fn slice_rebases_indices() {
        let sym = HeadSymbols::from_masks(
            &[true, false, true, true],
            &[
                true, false, true, true, // row 0
                true, true, true, true, // row 1 (cached)
                false, false, true, false, // row 2
                true, true, false, true, // row 3
            ],
            4,
            1,
        );
        let plan = HeadPlan::from_symbols(&sym, 4, 4, DecodeMode::RowCached);
        let head = plan.slice_q(0, 2);
        assert_eq!(head.live_q, vec![0]);
        assert_eq!(head.cached_q, vec![1]);
        assert_eq!(head.live_kv(0), &[0, 2, 3]);
        let tail = plan.slice_q(2, 4);
        assert_eq!(tail.live_q, vec![0, 1]);
        assert!(tail.cached_q.is_empty());
        assert_eq!(tail.live_kv(0), &[2]);
        assert_eq!(tail.live_kv(1), &[0, 1, 3]);
        // The two slices partition the pair count.
        assert_eq!(
            head.computed_pairs() + tail.computed_pairs(),
            plan.computed_pairs()
        );
        // A lo == 0 slice shares its segments with the parent plan.
        assert_eq!(head.shared_segments_with(&plan), 2);
    }

    #[test]
    fn apply_delta_recompiles_only_changed_groups() {
        let m_c = [true, true, false, true];
        let mut m_s = [true; 16];
        m_s[4] = false; // row 1 skips KV 0
        let sym_old = HeadSymbols::from_masks(&m_c, &m_s, 4, 1);
        let old = HeadPlan::from_symbols(&sym_old, 4, 4, DecodeMode::RowCached);
        // Flip row 1's skip and un-cache row 2.
        let m_c2 = [true, true, true, true];
        let m_s2 = [true; 16];
        let sym_new = HeadSymbols::from_masks(&m_c2, &m_s2, 4, 1);
        let got = old.apply_delta(&[1, 2], &sym_new, DecodeMode::RowCached);
        let want = HeadPlan::from_symbols(&sym_new, 4, 4, DecodeMode::RowCached);
        assert_eq!(got, want);
        // Rows 0 and 3 were untouched: their segments are shared.
        assert_eq!(got.shared_segments_with(&old), 2);
    }

    #[test]
    fn apply_delta_with_no_changes_shares_everything() {
        let sym = HeadSymbols::from_masks(&[true, false, true], &[true; 9], 3, 1);
        let old = HeadPlan::from_symbols(&sym, 3, 3, DecodeMode::RowCached);
        let got = old.apply_delta(&[], &sym, DecodeMode::RowCached);
        assert_eq!(got, old);
        assert_eq!(got.shared_segments_with(&old), 3);
    }

    #[test]
    fn apply_delta_at_reads_joint_symbols_at_offset() {
        // Joint: 4 rows (pool 1); the "vision" slice covers rows [2, 4).
        let old_sym = HeadSymbols::from_masks(&[true, true, true, false], &[true; 16], 4, 1);
        let joint = HeadPlan::from_symbols(&old_sym, 4, 4, DecodeMode::RowCached);
        let img = joint.slice_q(2, 4);
        // New refresh: row 3 becomes live but skips KV 1 — joint group 3,
        // slice-frame group 1.
        let mut m_s = [true; 16];
        m_s[3 * 4 + 1] = false;
        let new_sym = HeadSymbols::from_masks(&[true; 4], &m_s, 4, 1);
        let got = img.apply_delta_at(&[1], &new_sym, 2, DecodeMode::RowCached);
        let want =
            HeadPlan::from_symbols(&new_sym, 4, 4, DecodeMode::RowCached).slice_q(2, 4);
        assert_eq!(got, want);
        assert_eq!(got.live_q, vec![0, 1]);
        assert_eq!(got.live_kv(1), &[0, 2, 3]);
        // The unchanged slice group (joint row 2) stays Arc-shared.
        assert_eq!(got.shared_segments_with(&img), 1);
    }

    #[test]
    #[should_panic(expected = "row-group granularity")]
    fn apply_delta_rejects_dense_plans() {
        let sym = HeadSymbols::from_masks(&[true, true, true], &[true; 9], 3, 1);
        let dense = HeadPlan::dense(3, 3);
        let _ = dense.apply_delta(&[0], &sym, DecodeMode::RowCached);
    }

    #[test]
    fn layer_aggregation_and_sparsity() {
        let syms = LayerSymbols {
            heads: vec![
                HeadSymbols::from_masks(&[false, true], &[true; 4], 2, 1),
                HeadSymbols::from_masks(&[true, true], &[true; 4], 2, 1),
            ],
        };
        let plan = SparsePlan::compile(&syms, 2, 2, 8, 8, DecodeMode::RowCached);
        let g = plan.gemm_stats();
        assert_eq!(g.computed_tiles, 3);
        assert_eq!(g.total_tiles, 4);
        let a = plan.attn_stats();
        assert_eq!(a.computed_pairs, 6);
        assert_eq!(a.total_pairs, 8);
        assert!((plan.cache_sparsity() - 0.25).abs() < 1e-12);
        assert!((plan.pair_sparsity() - 0.25).abs() < 1e-12);
        assert!(plan.index_bytes() > 0);
        // FLOP precomputation follows the live pair count.
        assert!((plan.attention_flops(4) - 4.0 * 6.0 * (8 * 8 * 4) as f64).abs() < 1e-9);
    }

    #[test]
    fn pool_compile_matches_serial() {
        prop_check("compile_on == compile", 10, |rng| {
            let heads = 1 + rng.below(4);
            let t = 4 + rng.below(24);
            let syms = LayerSymbols {
                heads: (0..heads)
                    .map(|_| {
                        let m_c = rand_mask(rng, t, 0.6);
                        let m_s = rand_mask(rng, t * t, 0.5);
                        HeadSymbols::from_masks(&m_c, &m_s, t, 1)
                    })
                    .collect(),
            };
            let serial = SparsePlan::compile(&syms, t, t, 8, 8, DecodeMode::RowCached);
            let pool = SparsePlan::compile_on(
                &syms,
                t,
                t,
                8,
                8,
                DecodeMode::RowCached,
                &ExecPool::global(),
            );
            assert_eq!(serial, pool);
        });
    }

    #[test]
    fn stats_match_symbol_accounting_at_pool_1() {
        prop_check("plan sparsity == symbol sparsity (pool 1)", 30, |rng| {
            let t_q = 1 + rng.below(20);
            let t_kv = 1 + rng.below(20);
            let m_c = rand_mask(rng, t_q, 0.7);
            let m_s = rand_mask(rng, t_q * t_kv, 0.6);
            let sym = HeadSymbols::from_masks(&m_c, &m_s, t_kv, 1);
            let plan = HeadPlan::from_symbols(&sym, t_q, t_kv, DecodeMode::RowCached);
            assert!((plan.pair_sparsity() - sym.pair_sparsity()).abs() < 1e-12);
            assert!((plan.cache_sparsity() - sym.cache_sparsity()).abs() < 1e-12);
        });
    }
}
