//! The persistent worker pool backing every parallel hot path.
//!
//! See the [module docs](crate::exec) for the design rationale. The pool is
//! deliberately minimal: `std::thread` workers blocking on a
//! `Mutex<VecDeque>` + `Condvar` job queue ("work-stealing-lite" — one
//! shared deque with an atomic index counter per parallel section rather
//! than per-worker deques), and a latch per parallel call so borrows of the
//! caller's stack provably outlive every job that uses them.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

/// A queued unit of work. Jobs created by the `parallel_*` entry points
/// borrow the caller's stack; the lifetime is erased (see the `SAFETY`
/// comment in [`ExecPool::run_indexed`]) because the caller blocks on a
/// latch until every such job has finished.
type Job = Box<dyn FnOnce() + Send + 'static>;

/// Raw-pointer wrapper that is `Send`/`Sync` so parallel sections can write
/// to *disjoint* regions of one output buffer from several workers.
///
/// Safety contract (on the code that uses it, not on construction): no two
/// concurrent tasks may write the same element, and the pointed-to buffer
/// must outlive the parallel section — which [`ExecPool`] guarantees by
/// joining every job before `parallel_for`/`parallel_map` returns.
#[derive(Clone, Copy)]
pub struct SendPtr<T>(pub *mut T);

unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

struct PoolShared {
    queue: Mutex<VecDeque<Job>>,
    work_cv: Condvar,
    shutdown: AtomicBool,
}

/// Completion latch for one parallel section: counts helper jobs still
/// running; the caller blocks in [`ExecPool::wait_helping`] until it
/// reaches zero. Completion is signalled through the pool's queue condvar
/// (the same one job enqueues notify), so the waiting caller needs no
/// timed polling: it sleeps on one condvar and is woken both by new work
/// it can help with and by its own section finishing.
struct Latch<'p> {
    remaining: Mutex<usize>,
    shared: &'p PoolShared,
}

impl<'p> Latch<'p> {
    fn new(n: usize, shared: &'p PoolShared) -> Self {
        Latch { remaining: Mutex::new(n), shared }
    }

    fn count_down(&self) {
        let mut r = self.remaining.lock().unwrap();
        *r -= 1;
        let done = *r == 0;
        drop(r);
        if done {
            // Pair with the check-then-wait in `wait_helping`: the waiter
            // performs its `is_done` check while holding the queue lock,
            // so after we take-and-release that lock it is either parked
            // on `work_cv` (the broadcast reaches it) or has not yet
            // checked (it will observe remaining == 0). Never notify while
            // holding the lock chain remaining → queue: `r` is dropped
            // above, keeping lock order queue → remaining acyclic.
            drop(self.shared.queue.lock().unwrap());
            self.shared.work_cv.notify_all();
        }
    }

    fn is_done(&self) -> bool {
        *self.remaining.lock().unwrap() == 0
    }
}

/// Persistent worker pool with deterministic parallel iteration.
///
/// * Threads are spawned **once** (at pool construction) and reused by every
///   parallel section — no per-step `thread::scope` spawn cost.
/// * [`parallel_map`](Self::parallel_map) returns results **in input
///   order** regardless of which worker computed what, so pool-backed
///   kernels are bitwise-identical to their serial loops.
/// * The calling thread is itself a full worker lane: a pool of size 1
///   degenerates to the plain serial loop, and a busy pool never stalls a
///   caller that could make progress on its own items.
pub struct ExecPool {
    shared: Arc<PoolShared>,
    threads: usize,
    handles: Vec<JoinHandle<()>>,
}

fn worker_loop(shared: Arc<PoolShared>) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(j) = q.pop_front() {
                    break j;
                }
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                q = shared.work_cv.wait(q).unwrap();
            }
        };
        // A panicking job must not kill the worker: the panic is recorded
        // by the parallel section that queued it and re-raised on the
        // caller's thread.
        let _ = catch_unwind(AssertUnwindSafe(job));
    }
}

impl ExecPool {
    /// Spawn a pool with `threads` persistent workers (clamped to ≥ 1).
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(VecDeque::new()),
            work_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let handles = (0..threads)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("fo-exec-{i}"))
                    .spawn(move || worker_loop(shared))
                    .expect("failed to spawn exec worker")
            })
            .collect();
        ExecPool { shared, threads, handles }
    }

    /// The process-wide shared pool, sized to the hardware parallelism.
    /// Engines default to this pool, so N coordinator workers × H heads
    /// share one fixed set of threads instead of oversubscribing.
    pub fn global() -> Arc<ExecPool> {
        static GLOBAL: OnceLock<Arc<ExecPool>> = OnceLock::new();
        Arc::clone(GLOBAL.get_or_init(|| {
            let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
            Arc::new(ExecPool::new(n))
        }))
    }

    /// Number of persistent worker threads.
    pub fn size(&self) -> usize {
        self.threads
    }

    /// Run `f(0..n)` across the pool. `f` must only touch state that is
    /// safe to share (`Sync`) — use [`SendPtr`] for disjoint output writes.
    pub fn parallel_for<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        if n <= 1 || self.threads <= 1 {
            for i in 0..n {
                f(i);
            }
            return;
        }
        self.run_indexed(n, &f);
    }

    /// Map `f` over `0..n`, returning results in index order. Dynamic
    /// scheduling (workers grab the next index as they free up) with
    /// deterministic output placement: slot `i` always holds `f(i)`.
    pub fn parallel_map_indexed<R, F>(&self, n: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        if n <= 1 || self.threads <= 1 {
            return (0..n).map(f).collect();
        }
        let mut slots: Vec<Option<R>> = Vec::with_capacity(n);
        slots.resize_with(n, || None);
        {
            let out = SendPtr(slots.as_mut_ptr());
            self.run_indexed(n, &move |i| {
                let r = f(i);
                // SAFETY: run_indexed hands each index to exactly one task,
                // so slot writes are disjoint; the latch in run_indexed
                // keeps `slots` alive until every task has finished.
                unsafe { *out.0.add(i) = Some(r) };
            });
        }
        slots.into_iter().map(|s| s.expect("parallel_map slot left unfilled")).collect()
    }

    /// Map `f` over a slice, returning results in input order.
    pub fn parallel_map<T, R, F>(&self, items: &[T], f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(usize, &T) -> R + Sync,
    {
        self.parallel_map_indexed(items.len(), |i| f(i, &items[i]))
    }

    fn submit_locked(q: &mut VecDeque<Job>, job: Job) {
        q.push_back(job);
    }

    /// Core dispatcher: an atomic counter hands indices `0..n` to the
    /// caller plus up to `threads` helper jobs; the caller drains alongside
    /// the helpers and then blocks on a latch (helping with any queued
    /// foreign jobs while it waits, so nested sections cannot deadlock).
    fn run_indexed<F>(&self, n: usize, f: &F)
    where
        F: Fn(usize) + Sync,
    {
        debug_assert!(n >= 2 && self.threads >= 2);
        let _section_span =
            crate::obs::Span::enter("exec.section", &crate::obs::metrics::EXEC_SECTION);
        crate::obs::metrics::EXEC_SECTIONS.inc();
        let next = AtomicUsize::new(0);
        let drain = || loop {
            let i = next.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            f(i);
        };
        let helpers = self.threads.min(n - 1);
        // Occupancy telemetry: lanes = caller + helpers; queue depth is
        // sampled after this section's jobs are enqueued (both no-ops
        // unless FO_METRICS is on).
        crate::obs::metrics::EXEC_ACTIVE_LANES.set(helpers as i64 + 1);
        let latch = Latch::new(helpers, &self.shared);
        let panicked = AtomicBool::new(false);
        {
            let drain_ref = &drain;
            let latch_ref = &latch;
            let panicked_ref = &panicked;
            let mut q = self.shared.queue.lock().unwrap();
            for _ in 0..helpers {
                let job = move || {
                    if catch_unwind(AssertUnwindSafe(drain_ref)).is_err() {
                        panicked_ref.store(true, Ordering::SeqCst);
                    }
                    latch_ref.count_down();
                };
                let boxed: Box<dyn FnOnce() + Send + '_> = Box::new(job);
                // SAFETY: the job borrows `drain`/`latch`/`panicked` (and,
                // through `drain`, the caller's `f` and data). We block on
                // `latch` below until every helper has counted down, so the
                // borrows strictly outlive the job's execution; the 'static
                // bound on `Job` is erased only for queue storage.
                let boxed: Job = unsafe {
                    std::mem::transmute::<Box<dyn FnOnce() + Send + '_>, Job>(boxed)
                };
                Self::submit_locked(&mut q, boxed);
            }
            crate::obs::metrics::EXEC_QUEUE_DEPTH.set(q.len() as i64);
            drop(q);
            self.shared.work_cv.notify_all();
        }
        // The caller is a full lane; even if every worker is busy the
        // section completes at single-thread speed.
        let caller = catch_unwind(AssertUnwindSafe(&drain));
        self.wait_helping(&latch);
        if let Err(payload) = caller {
            std::panic::resume_unwind(payload);
        }
        if panicked.load(Ordering::SeqCst) {
            panic!("ExecPool: a parallel worker panicked");
        }
    }

    /// Block until `latch` opens, executing queued jobs in the meantime.
    /// Helping keeps nested parallel sections live when every worker is
    /// occupied. No timed polling: the caller sleeps on the queue condvar,
    /// which is notified both on job enqueue and (via
    /// [`Latch::count_down`]) on section completion; the `is_done` check
    /// happens under the queue lock, closing the lost-wakeup window.
    fn wait_helping(&self, latch: &Latch<'_>) {
        loop {
            let job = {
                let mut q = self.shared.queue.lock().unwrap();
                loop {
                    if latch.is_done() {
                        return;
                    }
                    if let Some(j) = q.pop_front() {
                        break j;
                    }
                    q = self.shared.work_cv.wait(q).unwrap();
                }
            };
            let _ = catch_unwind(AssertUnwindSafe(job));
        }
    }
}

impl Drop for ExecPool {
    fn drop(&mut self) {
        {
            // Setting the flag under the queue lock pairs with the
            // check-then-wait in `worker_loop`: no worker can slip between
            // its empty-queue check and the condvar wait and miss the
            // shutdown notification.
            let _q = self.shared.queue.lock().unwrap();
            self.shared.shutdown.store(true, Ordering::SeqCst);
        }
        self.shared.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    #[test]
    fn map_preserves_input_order() {
        for threads in [1, 2, 3, 8] {
            let pool = ExecPool::new(threads);
            let got = pool.parallel_map_indexed(100, |i| {
                // Stagger so completion order differs from index order.
                if i % 7 == 0 {
                    std::thread::sleep(Duration::from_micros(50));
                }
                i * i
            });
            let want: Vec<usize> = (0..100).map(|i| i * i).collect();
            assert_eq!(got, want, "pool size {threads}");
        }
    }

    #[test]
    fn map_over_slice_matches_serial() {
        let items: Vec<f64> = (0..64).map(|i| i as f64 * 0.5).collect();
        let pool = ExecPool::new(4);
        let got = pool.parallel_map(&items, |i, x| x * 2.0 + i as f64);
        let want: Vec<f64> =
            items.iter().enumerate().map(|(i, x)| x * 2.0 + i as f64).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn for_visits_every_index_once() {
        let pool = ExecPool::new(3);
        let hits = AtomicUsize::new(0);
        let mut flags = vec![0u8; 200];
        {
            let ptr = SendPtr(flags.as_mut_ptr());
            pool.parallel_for(200, |i| {
                hits.fetch_add(1, Ordering::SeqCst);
                // SAFETY: each index is dispatched exactly once.
                unsafe { *ptr.0.add(i) += 1 };
            });
        }
        assert_eq!(hits.load(Ordering::SeqCst), 200);
        assert!(flags.iter().all(|&f| f == 1));
    }

    #[test]
    fn empty_and_single_item_sections() {
        let pool = ExecPool::new(4);
        let empty: Vec<usize> = pool.parallel_map_indexed(0, |i| i);
        assert!(empty.is_empty());
        assert_eq!(pool.parallel_map_indexed(1, |i| i + 41), vec![41]);
        pool.parallel_for(0, |_| panic!("must not run"));
    }

    #[test]
    fn nested_sections_complete() {
        // Outer tasks spawn inner sections on the same pool; the
        // help-while-waiting loop must keep everything live.
        let pool = ExecPool::new(2);
        let got = pool.parallel_map_indexed(4, |i| {
            let inner = pool.parallel_map_indexed(8, |j| i * 100 + j);
            inner.iter().sum::<usize>()
        });
        let want: Vec<usize> =
            (0..4).map(|i| (0..8).map(|j| i * 100 + j).sum()).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let pool = ExecPool::new(2);
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.parallel_for(16, |i| {
                if i == 5 {
                    panic!("boom");
                }
            });
        }));
        assert!(result.is_err(), "panic must propagate to the caller");
        // The pool must still be usable afterwards.
        let ok = pool.parallel_map_indexed(8, |i| i + 1);
        assert_eq!(ok, (1..=8).collect::<Vec<usize>>());
    }

    #[test]
    fn global_pool_is_shared() {
        let a = ExecPool::global();
        let b = ExecPool::global();
        assert!(Arc::ptr_eq(&a, &b));
        assert!(a.size() >= 1);
    }
}
