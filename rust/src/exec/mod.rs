//! Shared **execution runtime**: the persistent worker pool every sparse
//! kernel runs on.
//!
//! FlashOmni's near-linear sparsity:speedup claim depends on the kernel
//! layer actually saturating the hardware. Before this module existed the
//! engine spawned a fresh `std::thread::scope` per Dispatch step (paying
//! thread-spawn latency on every denoising step) and only attention heads
//! ran in parallel — the GEMM-Q / GEMM-O tile loops were serial. The
//! runtime fixes both:
//!
//! * [`ExecPool`] — a persistent, work-stealing-lite worker pool built on
//!   `std::thread` + `Mutex<VecDeque>`/`Condvar` (zero external deps, per
//!   DESIGN.md's offline constraint). Workers are spawned once and reused
//!   by every parallel section; a per-section atomic index counter gives
//!   dynamic load balancing, and results are always placed by input index
//!   so pool-backed kernels are **bitwise-identical** to their serial
//!   loops (property-tested in `rust/tests/exec_runtime.rs`).
//! * [`ExecPool::global`] — the process-wide pool, sized to
//!   `available_parallelism`. Engines default to it, so the serving
//!   coordinator's N workers × H heads share one fixed thread set instead
//!   of oversubscribing N×H scoped threads.
//! * [`SendPtr`] — the one escape hatch for parallel tile loops that write
//!   disjoint regions of a shared output tensor (GEMM-Q tiles touch
//!   `(row-block × head-column)` rectangles; GEMM-O row-block tasks touch
//!   disjoint row slabs).
//!
//! Scheduling model: the calling thread is itself a worker lane. A
//! parallel section enqueues at most `pool.size()` helper jobs, then the
//! caller drains the same index counter; when the caller finishes first it
//! executes other queued jobs while waiting on the section latch, which
//! keeps nested sections (and many concurrent callers, e.g. coordinator
//! workers) deadlock-free. A pool of size 1 — or a 1-item section —
//! degenerates to the plain serial loop.
//!
//! The plan-compilation cache that rides on top of this runtime lives in
//! [`crate::plan::cache`] (it is keyed by plan-layer types); the engine
//! wires the two together: symbols → cached plan → pool-backed kernels.

mod pool;

pub use pool::{ExecPool, SendPtr};

/// Chunk size for the GEMM tile loops: how many `(head, block)` tiles one
/// pool task processes before grabbing the next index.
///
/// Default heuristic: `tiles / (4·threads)` — large enough to amortize
/// dispatch overhead, small enough to leave ~4 tasks per worker for
/// dynamic load balancing. The **`FO_CHUNK`** environment variable (parsed
/// once per process) overrides it outright, giving the ROADMAP's
/// chunk-size autotuner a knob to sweep; the fig6/fig8/fig12 benches
/// record the effective setting in their `BENCH_*.json` headers.
pub fn tile_chunk(tiles: usize, threads: usize) -> usize {
    match tile_chunk_override() {
        Some(c) => c,
        None => tiles.div_ceil((threads * 4).max(1)).max(1),
    }
}

/// The `FO_CHUNK` override, if set to a positive integer (`None` = use the
/// built-in heuristic). Parsed once and cached for the process lifetime.
/// A set-but-unparseable (or zero) value is ignored with a one-time
/// warning on stderr rather than silently dropped — a mistyped sweep knob
/// would otherwise masquerade as the heuristic.
pub fn tile_chunk_override() -> Option<usize> {
    static OVERRIDE: std::sync::OnceLock<Option<usize>> = std::sync::OnceLock::new();
    *OVERRIDE.get_or_init(|| match std::env::var("FO_CHUNK") {
        Err(_) => None,
        Ok(v) => match v.parse::<usize>() {
            Ok(c) if c > 0 => Some(c),
            _ => {
                eprintln!(
                    "warning: ignoring FO_CHUNK={v:?} (expected a positive integer); \
                     using the built-in chunk heuristic"
                );
                None
            }
        },
    })
}

#[cfg(test)]
mod tests {
    #[test]
    fn tile_chunk_heuristic_bounds() {
        // Without FO_CHUNK in the test environment the heuristic applies:
        // ≥ 1 always, and ~4 tasks per worker for big tile counts.
        if super::tile_chunk_override().is_none() {
            assert_eq!(super::tile_chunk(0, 8), 1);
            assert_eq!(super::tile_chunk(1, 8), 1);
            assert_eq!(super::tile_chunk(256, 8), 8);
        } else {
            assert!(super::tile_chunk(256, 8) >= 1);
        }
    }
}
