//! Paged memory pool for resident engine state — the TGI/vLLM paged-KV
//! idiom, CPU-resident.
//!
//! Batched video-scale serving multiplies resident state: cached feature
//! stacks ([`crate::cache::TaylorCache`]), batched text-stream K/V
//! projections (`batch::engine`), and compiled plan row-group segments
//! plus their packed symbol keys (`plan`). [`PagePool`] gives all of them
//! one **block allocator** with:
//!
//! * **fixed-size pages** — every block is accounted in whole pages of
//!   [`PagePool::page_bytes`] (`FO_PAGE_BYTES`, default 4096), so "how
//!   much is resident" is a single page counter, not a heap walk;
//! * **ref-counted blocks** — a [`Pooled<T>`] handle is a block-table
//!   entry; cloning a handle bumps the block's refcount instead of
//!   copying bytes, and the last drop releases the block;
//! * **prefix sharing** — [`PagePool::intern_digest`] maps
//!   content-identical state (e.g. the text-conditioning K/V of
//!   symbol-identical requests across a batch) onto the *same* physical
//!   block (`ref_count == B`, one copy), with a full content compare on
//!   every digest hit so a hash collision can never alias distinct data;
//! * **copy-on-write** — [`Pooled::make_mut`] mutates in place only when
//!   the block is unshared and unkeyed; otherwise the write lands in a
//!   fresh private block, so a shared page is never written through;
//! * **eviction under pressure** — with a page budget (`FO_PAGE_BUDGET`,
//!   in pages; 0/unset = unbounded) set, released keyed blocks are
//!   *retained* (resurrectable by digest) until an allocation would
//!   exceed the budget, then evicted FIFO. Live blocks (refs > 0) are
//!   never evicted, so eviction is invisible to numerics: all
//!   bitwise-identity invariants survive any budget.
//!
//! Allocation/share/CoW/eviction traffic is counted in [`MemStats`]
//! (surfaced per run through `RunStats::mem_*`) and exported through the
//! `fo_mem_*` observability instruments.

use crate::obs::metrics as om;
use crate::tensor::Tensor;
use crate::util::sync::lock_recover;
use std::any::Any;
use std::borrow::Borrow;
use std::collections::{HashMap, VecDeque};
use std::fmt;
use std::hash::{Hash, Hasher};
use std::ops::Deref;
use std::sync::{Arc, Mutex, OnceLock};

/// Default page size in bytes (`FO_PAGE_BYTES` overrides for the global
/// pool).
pub const DEFAULT_PAGE_BYTES: usize = 4096;

// ---------------------------------------------------------------------------
// Stats
// ---------------------------------------------------------------------------

/// Cumulative counters + current/peak occupancy of one [`PagePool`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MemStats {
    /// Blocks ever allocated (fresh physical allocations, not share hits).
    pub blocks_allocated: u64,
    /// Pages ever allocated.
    pub pages_allocated: u64,
    /// Digest hits served by an existing block (one physical copy kept).
    pub share_hits: u64,
    /// Copy-on-write copies (writes to shared or keyed blocks).
    pub cow_copies: u64,
    /// Retained blocks evicted to stay under the page budget.
    pub blocks_evicted: u64,
    /// Pages freed by eviction.
    pub pages_evicted: u64,
    /// Pages currently resident (live + retained-for-resurrection).
    pub resident_pages: u64,
    /// Pages currently referenced by at least one live handle.
    pub live_pages: u64,
    /// High-water mark of `resident_pages`.
    pub peak_resident_pages: u64,
    /// High-water mark of `live_pages`.
    pub peak_live_pages: u64,
    /// Highest refcount any single block ever reached (a symbol-identical
    /// batch of `B` requests drives this to `B` on its shared blocks).
    pub peak_block_refs: u64,
}

// ---------------------------------------------------------------------------
// Block table
// ---------------------------------------------------------------------------

struct Block {
    pages: u64,
    bytes: usize,
    refs: u64,
    /// Content digest for shared (interned) blocks; `None` = private.
    key: Option<[u8; 16]>,
    /// Payload kept by the pool only for keyed blocks, so a digest hit
    /// can hand out the same `Arc` and verify content equality.
    payload: Option<Arc<dyn Any + Send + Sync>>,
    /// In the retained (refs == 0, evictable, resurrectable) state.
    retained: bool,
}

struct Inner {
    page_bytes: usize,
    /// Resident-page budget; 0 = unbounded (released blocks free eagerly,
    /// nothing is retained, nothing ever needs evicting).
    budget_pages: u64,
    blocks: HashMap<u64, Block>,
    by_key: HashMap<[u8; 16], u64>,
    /// Eviction FIFO of retained block ids (may hold stale ids of blocks
    /// that were resurrected or already freed; eviction skips those).
    retained: VecDeque<u64>,
    next_id: u64,
    stats: MemStats,
}

impl Inner {
    fn pages_for(&self, bytes: usize) -> u64 {
        (bytes.max(1)).div_ceil(self.page_bytes) as u64
    }

    fn publish_gauges(&self) {
        om::MEM_RESIDENT_PAGES.set(self.stats.resident_pages as i64);
        om::MEM_LIVE_PAGES.set(self.stats.live_pages as i64);
    }

    /// Evict retained blocks (FIFO) until `extra` more pages fit under
    /// the budget or nothing evictable remains. Returns dropped payloads
    /// so their destructors run outside the pool lock.
    fn evict_for(&mut self, extra: u64) -> Vec<Arc<dyn Any + Send + Sync>> {
        let mut dropped = Vec::new();
        if self.budget_pages == 0 {
            return dropped;
        }
        while self.stats.resident_pages + extra > self.budget_pages {
            let Some(id) = self.retained.pop_front() else { break };
            let evictable = matches!(self.blocks.get(&id), Some(b) if b.retained && b.refs == 0);
            if !evictable {
                continue; // stale queue entry (resurrected or already freed)
            }
            let block = self.blocks.remove(&id).expect("checked above");
            if let Some(k) = block.key {
                self.by_key.remove(&k);
            }
            if let Some(p) = block.payload {
                dropped.push(p);
            }
            self.stats.resident_pages -= block.pages;
            self.stats.blocks_evicted += 1;
            self.stats.pages_evicted += block.pages;
            om::MEM_PAGES_EVICTED.add(block.pages);
        }
        dropped
    }

    /// Insert a fresh block (evicting first if a budget is set) and
    /// return its id plus any payloads to drop outside the lock.
    fn insert_block(
        &mut self,
        bytes: usize,
        key: Option<[u8; 16]>,
        payload: Option<Arc<dyn Any + Send + Sync>>,
    ) -> (u64, Vec<Arc<dyn Any + Send + Sync>>) {
        let pages = self.pages_for(bytes);
        let dropped = self.evict_for(pages);
        let id = self.next_id;
        self.next_id += 1;
        self.blocks.insert(id, Block { pages, bytes, refs: 1, key, payload, retained: false });
        if let Some(k) = key {
            self.by_key.insert(k, id);
        }
        self.stats.blocks_allocated += 1;
        self.stats.pages_allocated += pages;
        self.stats.resident_pages += pages;
        self.stats.live_pages += pages;
        self.stats.peak_resident_pages =
            self.stats.peak_resident_pages.max(self.stats.resident_pages);
        self.stats.peak_live_pages = self.stats.peak_live_pages.max(self.stats.live_pages);
        self.stats.peak_block_refs = self.stats.peak_block_refs.max(1);
        om::MEM_PAGES_ALLOCATED.add(pages);
        self.publish_gauges();
        (id, dropped)
    }
}

// ---------------------------------------------------------------------------
// PagePool
// ---------------------------------------------------------------------------

/// A paged block allocator. Cheap to clone (handles hold one); see the
/// module docs for semantics.
#[derive(Clone)]
pub struct PagePool {
    inner: Arc<Mutex<Inner>>,
}

impl fmt::Debug for PagePool {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = self.stats();
        f.debug_struct("PagePool")
            .field("resident_pages", &s.resident_pages)
            .field("live_pages", &s.live_pages)
            .field("budget_pages", &self.budget_pages())
            .finish()
    }
}

impl PagePool {
    /// Pool with an explicit resident-page budget (0 = unbounded) and
    /// page size in bytes.
    pub fn with_budget(budget_pages: u64, page_bytes: usize) -> PagePool {
        PagePool {
            inner: Arc::new(Mutex::new(Inner {
                page_bytes: page_bytes.max(1),
                budget_pages,
                blocks: HashMap::new(),
                by_key: HashMap::new(),
                retained: VecDeque::new(),
                next_id: 0,
                stats: MemStats::default(),
            })),
        }
    }

    /// Unbounded pool with the default page size.
    pub fn unbounded() -> PagePool {
        PagePool::with_budget(0, DEFAULT_PAGE_BYTES)
    }

    /// The process-wide pool every engine uses unless handed a private
    /// one. Reads `FO_PAGE_BUDGET` (pages, 0/unset = unbounded) and
    /// `FO_PAGE_BYTES` once, at first use.
    pub fn global() -> &'static PagePool {
        static GLOBAL: OnceLock<PagePool> = OnceLock::new();
        GLOBAL.get_or_init(|| {
            let budget = std::env::var("FO_PAGE_BUDGET")
                .ok()
                .and_then(|v| v.trim().parse::<u64>().ok())
                .unwrap_or(0);
            let page_bytes = std::env::var("FO_PAGE_BYTES")
                .ok()
                .and_then(|v| v.trim().parse::<usize>().ok())
                .filter(|&v| v > 0)
                .unwrap_or(DEFAULT_PAGE_BYTES);
            PagePool::with_budget(budget, page_bytes)
        })
    }

    /// Page size in bytes.
    pub fn page_bytes(&self) -> usize {
        lock_recover(&self.inner).page_bytes
    }

    /// Resident-page budget (0 = unbounded).
    pub fn budget_pages(&self) -> u64 {
        lock_recover(&self.inner).budget_pages
    }

    /// Pages a block of `bytes` occupies (always ≥ 1).
    pub fn pages_for(&self, bytes: usize) -> u64 {
        lock_recover(&self.inner).pages_for(bytes)
    }

    /// Snapshot of the pool's counters and occupancy.
    pub fn stats(&self) -> MemStats {
        lock_recover(&self.inner).stats
    }

    /// Whether two pools are the same physical pool.
    pub fn same_pool(a: &PagePool, b: &PagePool) -> bool {
        Arc::ptr_eq(&a.inner, &b.inner)
    }

    /// Drop every retained (refs == 0) block, keyed or not.
    pub fn purge(&self) {
        let dropped = {
            let mut g = lock_recover(&self.inner);
            let ids: Vec<u64> = g.retained.drain(..).collect();
            let mut dropped = Vec::new();
            for id in ids {
                let evictable = matches!(g.blocks.get(&id), Some(b) if b.retained && b.refs == 0);
                if !evictable {
                    continue;
                }
                let block = g.blocks.remove(&id).expect("checked above");
                if let Some(k) = block.key {
                    g.by_key.remove(&k);
                }
                if let Some(p) = block.payload {
                    dropped.push(p);
                }
                g.stats.resident_pages -= block.pages;
                g.stats.blocks_evicted += 1;
                g.stats.pages_evicted += block.pages;
                om::MEM_PAGES_EVICTED.add(block.pages);
            }
            g.publish_gauges();
            dropped
        };
        drop(dropped); // payload destructors run outside the pool lock
    }

    /// Allocate a private (unshared, unkeyed) block of `bytes` holding
    /// `value`.
    pub fn alloc<T: Send + Sync + 'static>(&self, bytes: usize, value: T) -> Pooled<T> {
        let data = Arc::new(value);
        let (id, dropped) = lock_recover(&self.inner).insert_block(bytes, None, None);
        drop(dropped);
        Pooled { data, pool: self.clone(), id }
    }

    fn alloc_cow<T: Send + Sync + 'static>(&self, bytes: usize, value: T) -> Pooled<T> {
        let handle = self.alloc(bytes, value);
        {
            let mut g = lock_recover(&self.inner);
            g.stats.cow_copies += 1;
        }
        om::MEM_COW_COPIES.inc();
        handle
    }

    /// Intern `value` under a content `digest`: a digest hit whose stored
    /// payload compares equal returns the existing block (refcount bump,
    /// one physical copy — this is prefix sharing); a digest collision
    /// (payload differs) falls back to a private block so sharing can
    /// never change bytes. Returns `(handle, shared)`.
    pub fn intern_digest<T: Send + Sync + PartialEq + 'static>(
        &self,
        digest: [u8; 16],
        bytes: usize,
        value: T,
    ) -> (Pooled<T>, bool) {
        let mut g = lock_recover(&self.inner);
        if let Some(&id) = g.by_key.get(&digest) {
            let hit = g
                .blocks
                .get(&id)
                .and_then(|b| b.payload.clone())
                .and_then(|p| p.downcast::<T>().ok())
                .filter(|existing| **existing == value);
            if let Some(existing) = hit {
                let block = g.blocks.get_mut(&id).expect("keyed block exists");
                block.refs += 1;
                let (refs, pages) = (block.refs, block.pages);
                let resurrected = std::mem::take(&mut block.retained);
                if resurrected {
                    // Resurrect: pages move back from retained to live.
                    g.stats.live_pages += pages;
                    g.stats.peak_live_pages = g.stats.peak_live_pages.max(g.stats.live_pages);
                }
                g.stats.peak_block_refs = g.stats.peak_block_refs.max(refs);
                g.stats.share_hits += 1;
                g.publish_gauges();
                drop(g);
                om::MEM_SHARE_HITS.inc();
                return (Pooled { data: existing, pool: self.clone(), id }, true);
            }
            // Digest collision with different content: private block.
            let data = Arc::new(value);
            let (id, dropped) = g.insert_block(bytes, None, None);
            drop(g);
            drop(dropped);
            return (Pooled { data, pool: self.clone(), id }, false);
        }
        let data = Arc::new(value);
        let payload: Arc<dyn Any + Send + Sync> = data.clone();
        let (id, dropped) = g.insert_block(bytes, Some(digest), Some(payload));
        drop(g);
        drop(dropped);
        (Pooled { data, pool: self.clone(), id }, false)
    }

    /// Intern a byte string (namespaced), deduping content-identical
    /// keys onto one block. Returns `(handle, shared)`.
    pub fn intern_bytes(&self, ns: &[u8], bytes: &[u8]) -> (PooledBytes, bool) {
        let mut d = Digest::new(ns);
        d.update(bytes);
        let (handle, shared) = self.intern_digest(d.finish(), bytes.len(), bytes.to_vec());
        (PooledBytes(handle), shared)
    }

    fn retain(&self, id: u64) {
        let mut g = lock_recover(&self.inner);
        let block = g.blocks.get_mut(&id).expect("retain of freed pool block");
        debug_assert!(block.refs > 0, "retain through a live handle implies refs > 0");
        block.refs += 1;
        let refs = block.refs;
        g.stats.peak_block_refs = g.stats.peak_block_refs.max(refs);
    }

    fn release(&self, id: u64) {
        let dropped = {
            let mut g = lock_recover(&self.inner);
            let block = g.blocks.get_mut(&id).expect("release of freed pool block");
            debug_assert!(block.refs > 0, "double release of a pool block");
            block.refs -= 1;
            if block.refs > 0 {
                return;
            }
            let (pages, keyed) = (block.pages, block.key.is_some());
            if keyed && g.budget_pages > 0 {
                // Retain for digest resurrection; evictable under pressure.
                g.blocks.get_mut(&id).expect("still present").retained = true;
                g.stats.live_pages -= pages;
                g.retained.push_back(id);
                // A release can itself push the pool over budget only via
                // earlier live-over-budget growth; trim opportunistically.
                let dropped = g.evict_for(0);
                g.publish_gauges();
                dropped
            } else {
                let block = g.blocks.remove(&id).expect("still present");
                if let Some(k) = block.key {
                    g.by_key.remove(&k);
                }
                g.stats.resident_pages -= pages;
                g.stats.live_pages -= pages;
                g.publish_gauges();
                block.payload.into_iter().collect()
            }
        };
        drop(dropped);
    }

    fn block_refs(&self, id: u64) -> u64 {
        lock_recover(&self.inner).blocks.get(&id).map_or(0, |b| b.refs)
    }

    fn block_pages(&self, id: u64) -> u64 {
        lock_recover(&self.inner).blocks.get(&id).map_or(0, |b| b.pages)
    }
}

// ---------------------------------------------------------------------------
// Pooled<T>
// ---------------------------------------------------------------------------

/// A ref-counted handle to one pool block holding a `T`. Clones share
/// the block (refcount bump, no bytes copied); the last drop releases
/// it. Reads deref lock-free; writes go through [`Pooled::make_mut`]
/// (copy-on-write when shared).
pub struct Pooled<T> {
    data: Arc<T>,
    pool: PagePool,
    id: u64,
}

impl<T> Deref for Pooled<T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.data
    }
}

impl<T> Borrow<T> for Pooled<T> {
    fn borrow(&self) -> &T {
        &self.data
    }
}

impl<T> Clone for Pooled<T> {
    fn clone(&self) -> Self {
        self.pool.retain(self.id);
        Pooled { data: self.data.clone(), pool: self.pool.clone(), id: self.id }
    }
}

impl<T> Drop for Pooled<T> {
    fn drop(&mut self) {
        self.pool.release(self.id);
    }
}

impl<T: fmt::Debug> fmt::Debug for Pooled<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Pooled(")?;
        self.data.fmt(f)?;
        write!(f, ")")
    }
}

impl<T> Pooled<T> {
    /// Current refcount of the underlying block (≥ 1 while this handle
    /// lives).
    pub fn ref_count(&self) -> u64 {
        self.pool.block_refs(self.id)
    }

    /// Pages the underlying block occupies.
    pub fn pages(&self) -> u64 {
        self.pool.block_pages(self.id)
    }

    /// Whether two handles share one physical block.
    pub fn ptr_eq(a: &Pooled<T>, b: &Pooled<T>) -> bool {
        Arc::ptr_eq(&a.data, &b.data)
    }

    /// The pool this handle's block lives in.
    pub fn pool(&self) -> &PagePool {
        &self.pool
    }
}

impl<T: Clone + Send + Sync + 'static> Pooled<T> {
    /// Mutable access with copy-on-write: in place iff this is the only
    /// handle and the block is private; otherwise the contents move to a
    /// fresh private block first, so a shared or interned block is never
    /// written through.
    pub fn make_mut(&mut self) -> &mut T {
        let (unique, bytes) = {
            let g = lock_recover(&self.pool.inner);
            let b = g.blocks.get(&self.id).expect("make_mut on freed pool block");
            (b.refs == 1 && b.key.is_none(), b.bytes)
        };
        if !unique {
            *self = self.pool.alloc_cow(bytes, (*self.data).clone());
        }
        Arc::get_mut(&mut self.data).expect("private block with one handle is unique")
    }

    /// Promote this (typically just-CoW-written) handle to a shared
    /// block under `digest`: if an equal block already exists the handle
    /// swaps onto it (share hit, this copy is freed); otherwise this
    /// block becomes the interned copy. Returns `true` on dedupe.
    pub fn make_shared(&mut self, digest: [u8; 16]) -> bool
    where
        T: PartialEq,
    {
        let (swap_to, attach) = {
            let mut g = lock_recover(&self.pool.inner);
            match g.by_key.get(&digest).copied() {
                Some(id) if id == self.id => return true,
                Some(id) => {
                    let hit = g
                        .blocks
                        .get(&id)
                        .and_then(|b| b.payload.clone())
                        .and_then(|p| p.downcast::<T>().ok())
                        .filter(|existing| **existing == *self.data);
                    match hit {
                        Some(existing) => {
                            let block = g.blocks.get_mut(&id).expect("keyed block exists");
                            block.refs += 1;
                            let (refs, pages) = (block.refs, block.pages);
                            let resurrected = std::mem::take(&mut block.retained);
                            if resurrected {
                                g.stats.live_pages += pages;
                                g.stats.peak_live_pages =
                                    g.stats.peak_live_pages.max(g.stats.live_pages);
                            }
                            g.stats.peak_block_refs = g.stats.peak_block_refs.max(refs);
                            g.stats.share_hits += 1;
                            g.publish_gauges();
                            (Some((existing, id)), false)
                        }
                        None => (None, false), // collision: stay private
                    }
                }
                None => {
                    let block = g.blocks.get_mut(&self.id).expect("live handle block");
                    if block.key.is_some() {
                        (None, false) // already interned under another digest
                    } else {
                        block.key = Some(digest);
                        block.payload = Some(self.data.clone() as Arc<dyn Any + Send + Sync>);
                        g.by_key.insert(digest, self.id);
                        (None, true)
                    }
                }
            }
        };
        if let Some((existing, id)) = swap_to {
            om::MEM_SHARE_HITS.inc();
            *self = Pooled { data: existing, pool: self.pool.clone(), id };
            return true;
        }
        attach
    }
}

// ---------------------------------------------------------------------------
// PooledBytes — interned byte strings (packed symbol keys)
// ---------------------------------------------------------------------------

/// An interned, pool-backed byte string: the packed symbol-key type.
/// Hash/Eq/Borrow follow the byte content, so a `HashMap<PooledBytes, _>`
/// can be probed with a plain `&[u8]`, while clones are refcount bumps —
/// the `PlanCache` map key, its FIFO entry, and `LayerPlans.key` all
/// share one physical copy.
#[derive(Clone, Debug)]
pub struct PooledBytes(Pooled<Vec<u8>>);

impl PooledBytes {
    /// Current refcount of the backing block.
    pub fn ref_count(&self) -> u64 {
        self.0.ref_count()
    }

    /// Whether two keys share one physical block.
    pub fn ptr_eq(a: &PooledBytes, b: &PooledBytes) -> bool {
        Pooled::ptr_eq(&a.0, &b.0)
    }

    /// The pool backing this key.
    pub fn pool(&self) -> &PagePool {
        self.0.pool()
    }
}

impl Deref for PooledBytes {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        &self.0
    }
}

impl Borrow<[u8]> for PooledBytes {
    fn borrow(&self) -> &[u8] {
        self
    }
}

impl Hash for PooledBytes {
    fn hash<H: Hasher>(&self, state: &mut H) {
        <[u8] as Hash>::hash(self, state)
    }
}

impl PartialEq for PooledBytes {
    fn eq(&self, other: &PooledBytes) -> bool {
        **self == **other
    }
}
impl Eq for PooledBytes {}

impl PartialEq<[u8]> for PooledBytes {
    fn eq(&self, other: &[u8]) -> bool {
        **self == *other
    }
}

// ---------------------------------------------------------------------------
// Digest — 128-bit content fingerprints for prefix sharing
// ---------------------------------------------------------------------------

/// Streaming 128-bit FNV-1a content fingerprint (two independent 64-bit
/// lanes). Collisions are tolerated — every digest hit re-verifies full
/// content before sharing — the width just keeps false candidates rare.
pub struct Digest {
    a: u64,
    b: u64,
}

const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

impl Digest {
    /// Start a fingerprint in namespace `ns` (kept out of each other's
    /// key spaces: `b"plankey"`, `b"taylor"`, `b"kvtxt"`, …).
    pub fn new(ns: &[u8]) -> Digest {
        let mut d = Digest { a: 0xcbf2_9ce4_8422_2325, b: 0x6c62_272e_07bb_0142 };
        d.update(ns);
        d.update(&[0xff]); // namespace terminator
        d
    }

    /// Absorb raw bytes.
    pub fn update(&mut self, bytes: &[u8]) {
        for &x in bytes {
            self.a = (self.a ^ x as u64).wrapping_mul(FNV_PRIME);
            self.b = (self.b ^ x as u64).wrapping_mul(FNV_PRIME).rotate_left(1);
        }
    }

    /// Absorb one u32 (little-endian).
    pub fn update_u32(&mut self, v: u32) {
        self.update(&v.to_le_bytes());
    }

    /// Absorb one f32 by bit pattern (so `-0.0` and `0.0` differ; the
    /// content verify on hit makes that a non-issue either way).
    pub fn update_f32(&mut self, v: f32) {
        self.update(&v.to_bits().to_le_bytes());
    }

    /// Finish into a 16-byte key.
    pub fn finish(self) -> [u8; 16] {
        let mut out = [0u8; 16];
        out[..8].copy_from_slice(&self.a.to_le_bytes());
        out[8..].copy_from_slice(&self.b.to_le_bytes());
        out
    }
}

/// Fingerprint a tensor's shape + contents under namespace `ns`.
pub fn digest_tensor(ns: &[u8], t: &Tensor) -> [u8; 16] {
    let mut d = Digest::new(ns);
    d.update_u32(t.shape().len() as u32);
    for &s in t.shape() {
        d.update_u32(s as u32);
    }
    for &v in t.data() {
        d.update_f32(v);
    }
    d.finish()
}

/// Bytes a tensor's payload occupies (for page accounting).
pub fn tensor_bytes(t: &Tensor) -> usize {
    t.numel() * std::mem::size_of::<f32>()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_clone_drop_accounting() {
        let pool = PagePool::with_budget(0, 64);
        let a = pool.alloc(100, vec![1u8; 100]); // 2 pages
        assert_eq!(a.pages(), 2);
        assert_eq!(pool.stats().live_pages, 2);
        let b = a.clone();
        assert_eq!(a.ref_count(), 2);
        assert!(Pooled::ptr_eq(&a, &b));
        drop(a);
        assert_eq!(b.ref_count(), 1);
        drop(b);
        let s = pool.stats();
        assert_eq!(s.live_pages, 0);
        assert_eq!(s.resident_pages, 0, "unbounded pool frees on release");
        assert_eq!(s.peak_live_pages, 2);
    }

    #[test]
    fn intern_shares_one_physical_copy() {
        let pool = PagePool::with_budget(0, 64);
        let (a, s1) = pool.intern_bytes(b"k", b"same-bytes");
        let (b, s2) = pool.intern_bytes(b"k", b"same-bytes");
        let (c, s3) = pool.intern_bytes(b"k", b"other-bytes");
        assert!(!s1 && s2 && !s3);
        assert!(PooledBytes::ptr_eq(&a, &b));
        assert!(!PooledBytes::ptr_eq(&a, &c));
        assert_eq!(a.ref_count(), 2);
        assert_eq!(pool.stats().share_hits, 1);
        assert_eq!(pool.stats().blocks_allocated, 2);
    }

    #[test]
    fn namespaces_separate_key_spaces() {
        let pool = PagePool::with_budget(0, 64);
        let (a, _) = pool.intern_bytes(b"ns1", b"payload");
        let (b, shared) = pool.intern_bytes(b"ns2", b"payload");
        assert!(!shared, "distinct namespaces must not share");
        assert!(!PooledBytes::ptr_eq(&a, &b));
    }

    #[test]
    fn collision_verify_prevents_aliasing() {
        let pool = PagePool::with_budget(0, 64);
        let d = [7u8; 16];
        let (a, s1) = pool.intern_digest(d, 4, vec![1u8]);
        let (b, s2) = pool.intern_digest(d, 4, vec![2u8]); // forced collision
        assert!(!s1 && !s2);
        assert_eq!(*a, vec![1u8]);
        assert_eq!(*b, vec![2u8], "collision must fall back to a private block");
        let (c, s3) = pool.intern_digest(d, 4, vec![1u8]);
        assert!(s3, "equal content still shares");
        assert!(Pooled::ptr_eq(&a, &c));
    }

    #[test]
    fn cow_never_writes_through_a_shared_block() {
        let pool = PagePool::with_budget(0, 64);
        let a = pool.alloc(4, vec![1u8, 2, 3]);
        let mut b = a.clone();
        b.make_mut()[0] = 9;
        assert_eq!(*a, vec![1, 2, 3], "CoW must not alias the shared page");
        assert_eq!(*b, vec![9, 2, 3]);
        assert!(!Pooled::ptr_eq(&a, &b));
        assert_eq!(a.ref_count(), 1);
        assert_eq!(pool.stats().cow_copies, 1);

        // Unique + private: mutates in place, no copy.
        let mut c = pool.alloc(4, vec![5u8]);
        c.make_mut()[0] = 6;
        assert_eq!(pool.stats().cow_copies, 1);
    }

    #[test]
    fn keyed_block_copies_even_when_unique() {
        let pool = PagePool::with_budget(0, 64);
        let (mut a, _) = pool.intern_bytes(b"k", b"abc");
        // Writing an interned block must detach it from its digest.
        let inner: &mut Pooled<Vec<u8>> = &mut a.0;
        inner.make_mut()[0] = b'z';
        assert_eq!(&**inner, b"zbc");
        let (b, shared) = pool.intern_bytes(b"k", b"abc");
        assert!(!shared, "the interned copy was released, not mutated");
        assert_eq!(&*b, b"abc");
    }

    #[test]
    fn make_shared_dedupes_after_cow() {
        let pool = PagePool::with_budget(0, 64);
        let d = {
            let mut dg = Digest::new(b"t");
            dg.update(b"v1");
            dg.finish()
        };
        let (a, _) = pool.intern_digest(d, 2, b"v1".to_vec());
        let mut b = pool.alloc(2, b"v1".to_vec());
        assert!(b.make_shared(d), "equal content must swap onto the interned block");
        assert!(Pooled::ptr_eq(&a, &b));
        assert_eq!(a.ref_count(), 2);
    }

    #[test]
    fn budget_retains_then_evicts_fifo() {
        let pool = PagePool::with_budget(4, 64); // 4-page budget
        let (a, _) = pool.intern_bytes(b"k", &[1u8; 64]); // 1 page
        let (b, _) = pool.intern_bytes(b"k", &[2u8; 64]);
        drop(a);
        drop(b);
        let s = pool.stats();
        assert_eq!(s.live_pages, 0);
        assert_eq!(s.resident_pages, 2, "budgeted pool retains released keyed blocks");
        // Resurrect from retained: no new allocation.
        let (a2, shared) = pool.intern_bytes(b"k", &[1u8; 64]);
        assert!(shared);
        assert_eq!(pool.stats().blocks_allocated, 2);
        assert_eq!(pool.stats().live_pages, 1);
        drop(a2);
        // Push past the budget: the oldest retained block must go.
        let big = pool.alloc(3 * 64, [0u8; 192]); // 3 pages
        let s = pool.stats();
        assert!(s.blocks_evicted >= 1, "allocation past budget must evict");
        assert!(s.resident_pages <= 4, "resident bounded by budget: {s:?}");
        drop(big);
        pool.purge();
        assert_eq!(pool.stats().resident_pages, 0);
    }

    #[test]
    fn live_blocks_are_never_evicted() {
        let pool = PagePool::with_budget(2, 64);
        let a = pool.alloc(64, vec![1u8; 64]);
        let b = pool.alloc(64, vec![2u8; 64]);
        // Over budget with only live blocks: nothing evictable, both stay.
        let c = pool.alloc(64, vec![3u8; 64]);
        assert_eq!(pool.stats().blocks_evicted, 0);
        assert_eq!(pool.stats().live_pages, 3, "live pages may exceed a soft budget");
        assert_eq!(*a, vec![1u8; 64]);
        assert_eq!(*b, vec![2u8; 64]);
        assert_eq!(*c, vec![3u8; 64]);
    }

    #[test]
    fn pooled_bytes_probes_as_slice() {
        let pool = PagePool::with_budget(0, 64);
        let (k, _) = pool.intern_bytes(b"key", b"abc");
        let mut map: HashMap<PooledBytes, u32> = HashMap::new();
        map.insert(k.clone(), 7);
        assert_eq!(map.get(b"abc".as_slice()), Some(&7));
        assert_eq!(map.get(b"abd".as_slice()), None);
        assert_eq!(k.ref_count(), 2, "map key is a refcount bump, not a byte copy");
    }

    #[test]
    fn digest_is_order_and_length_sensitive() {
        let h = |ns: &[u8], parts: &[&[u8]]| {
            let mut d = Digest::new(ns);
            for p in parts {
                d.update(p);
            }
            d.finish()
        };
        assert_eq!(h(b"n", &[b"ab", b"c"]), h(b"n", &[b"abc"]));
        assert_ne!(h(b"n", &[b"abc"]), h(b"n", &[b"acb"]));
        assert_ne!(h(b"n", &[b"abc"]), h(b"m", &[b"abc"]));
    }
}
