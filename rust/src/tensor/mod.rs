//! Row-major f32 tensor used throughout the native engine.
//!
//! Deliberately minimal: contiguous storage, shape bookkeeping, and the
//! handful of views/ops the kernels need. The heavy math lives in
//! [`crate::kernels`]; this type stays allocation-transparent so the hot
//! path can reuse buffers.

use crate::util::fot::{FotFile, FotTensor};

/// A dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Zero-filled tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    /// Tensor from existing data (length must match shape).
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} does not match data length {}",
            shape,
            data.len()
        );
        Tensor { shape: shape.to_vec(), data }
    }

    /// Filled with a constant.
    pub fn full(shape: &[usize], v: f32) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Number of rows / row length for a 2-D view of the trailing dimension.
    /// `[a, b, c]` is viewed as `a*b` rows of length `c`.
    pub fn rows(&self) -> usize {
        let cols = self.cols();
        if cols == 0 {
            0
        } else {
            self.data.len() / cols
        }
    }

    pub fn cols(&self) -> usize {
        *self.shape.last().unwrap_or(&0)
    }

    /// Borrow row `r` of the 2-D view.
    pub fn row(&self, r: usize) -> &[f32] {
        let c = self.cols();
        &self.data[r * c..(r + 1) * c]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        let c = self.cols();
        &mut self.data[r * c..(r + 1) * c]
    }

    /// Reshape in place (numel must be preserved).
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// Elementwise a += b.
    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// Elementwise a -= b.
    pub fn sub_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a -= b;
        }
    }

    /// Elementwise scale.
    pub fn scale(&mut self, s: f32) {
        for a in &mut self.data {
            *a *= s;
        }
    }

    /// Max |a - b| over all elements.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape, "shape mismatch in max_abs_diff");
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }

    /// Relative L2 error ‖a−b‖ / (‖b‖ + eps).
    pub fn rel_l2(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (a, b) in self.data.iter().zip(&other.data) {
            num += ((a - b) * (a - b)) as f64;
            den += (b * b) as f64;
        }
        (num.sqrt() / (den.sqrt() + 1e-12)) as f32
    }

    /// Convert to a `.fot` tensor record.
    pub fn to_fot(&self) -> FotTensor {
        FotTensor::from_f32(&self.shape, &self.data)
    }

    /// Read a named tensor out of a `.fot` file.
    pub fn from_fot(file: &FotFile, name: &str) -> Result<Tensor, String> {
        let t = file.get(name)?;
        Ok(Tensor::from_vec(&t.shape.clone(), t.to_f32()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_views() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.rows(), 2);
        assert_eq!(t.cols(), 3);
        assert_eq!(t.row(1), &[4., 5., 6.]);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Tensor::from_vec(&[2, 2], vec![1.0]);
    }

    #[test]
    fn arithmetic() {
        let mut a = Tensor::full(&[4], 2.0);
        let b = Tensor::full(&[4], 0.5);
        a.add_assign(&b);
        assert_eq!(a.data(), &[2.5; 4]);
        a.sub_assign(&b);
        a.scale(2.0);
        assert_eq!(a.data(), &[4.0; 4]);
    }

    #[test]
    fn diffs() {
        let a = Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0]);
        let b = Tensor::from_vec(&[3], vec![1.0, 2.5, 3.0]);
        assert!((a.max_abs_diff(&b) - 0.5).abs() < 1e-6);
        assert!(a.rel_l2(&a) < 1e-6);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]).reshape(&[4]);
        assert_eq!(t.shape(), &[4]);
        assert_eq!(t.data(), &[1., 2., 3., 4.]);
    }

    #[test]
    fn multi_dim_rows() {
        let t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.rows(), 6);
        assert_eq!(t.cols(), 4);
    }
}
