//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation (§4) on the MiniMMDiT substrate. See DESIGN.md §4 for the
//! index. Output goes to stdout (markdown tables) and `reports/*.csv`.

use crate::config::SparsityConfig;
use crate::diffusion::{euler_step, initial_noise, unpatchify};
use crate::engine::{DiTEngine, GenResult, Policy, RunStats};
use crate::metrics;
use crate::model::MiniMMDiT;
use crate::tensor::Tensor;
use crate::workload::{caption_ids, eval_scenes, video_frame_ids};
use std::fmt::Write as _;
use std::io::Write as _;

/// Shared evaluation settings.
pub struct Reporter {
    pub model: MiniMMDiT,
    pub out_dir: String,
    pub scenes: Vec<usize>,
    pub steps: usize,
    pub block: usize,
}

/// One method's evaluation against the dense baseline.
#[derive(Clone, Debug)]
pub struct EvalRow {
    pub name: String,
    pub tops_norm: f64,
    pub sparsity: f64,
    pub psnr: f64,
    pub rpips: f64,
    pub ssim: f64,
    pub iqa: f64,
    pub rfid: f64,
    pub wall_s: f64,
    pub flop_speedup: f64,
}

impl Reporter {
    pub fn new(weights: &str, out_dir: &str, scenes: usize, steps: usize) -> Result<Self, String> {
        let model = MiniMMDiT::load(weights)?;
        std::fs::create_dir_all(out_dir).map_err(|e| e.to_string())?;
        Ok(Reporter {
            model,
            out_dir: out_dir.into(),
            scenes: eval_scenes(scenes),
            steps,
            block: 8,
        })
    }

    fn engine(&self, policy: Policy) -> DiTEngine {
        DiTEngine::new(self.model.clone(), policy, self.block, self.block)
    }

    /// Generate the evaluation image set under a policy.
    fn run_images(&self, policy: Policy) -> (Vec<Tensor>, RunStats) {
        let mut engine = self.engine(policy);
        let mut images = Vec::new();
        let mut agg = RunStats::default();
        for (i, &scene) in self.scenes.iter().enumerate() {
            let ids = caption_ids(scene, self.model.cfg.text_tokens);
            let r = engine.generate(&ids, 1000 + i as u64, self.steps);
            merge_stats(&mut agg, &r.stats);
            images.push(r.image);
        }
        (images, agg)
    }

    fn eval_against(
        &self,
        name: &str,
        images: &[Tensor],
        baseline: &[Tensor],
        stats: &RunStats,
        baseline_stats: &RunStats,
    ) -> EvalRow {
        let n = images.len() as f64;
        let psnr = images.iter().zip(baseline).map(|(a, b)| metrics::psnr(a, b).min(99.0)).sum::<f64>() / n;
        let rpips = images.iter().zip(baseline).map(|(a, b)| metrics::rpips(a, b)).sum::<f64>() / n;
        let ssim = images.iter().zip(baseline).map(|(a, b)| metrics::ssim(a, b)).sum::<f64>() / n;
        let iqa = images.iter().map(metrics::iqa_proxy).sum::<f64>() / n;
        let rfid = metrics::rfid(images, baseline);
        EvalRow {
            name: name.into(),
            tops_norm: baseline_stats.wall_s / stats.wall_s.max(1e-12),
            sparsity: stats.attn_sparsity() * 100.0,
            psnr,
            rpips,
            ssim,
            iqa,
            rfid,
            wall_s: stats.wall_s,
            flop_speedup: stats.flop_speedup(),
        }
    }

    fn print_rows(&self, title: &str, rows: &[EvalRow], csv: &str) {
        println!("\n## {title}\n");
        println!(
            "| {:<34} | {:>9} | {:>8} | {:>7} | {:>7} | {:>6} | {:>6} | {:>7} | {:>8} |",
            "Method", "TOPSnorm↑", "Spars.%", "PSNR↑", "RPIPS↓", "SSIM↑", "IQA↑", "rFID↓", "FLOPspd↑"
        );
        println!("|{}|", "-".repeat(112));
        let mut csv_text = String::from(
            "method,tops_norm,sparsity,psnr,rpips,ssim,iqa,rfid,wall_s,flop_speedup\n",
        );
        for r in rows {
            println!(
                "| {:<34} | {:>9.3} | {:>8.1} | {:>7.3} | {:>7.4} | {:>6.4} | {:>6.4} | {:>7.3} | {:>8.3} |",
                r.name, r.tops_norm, r.sparsity, r.psnr, r.rpips, r.ssim, r.iqa, r.rfid, r.flop_speedup
            );
            let _ = writeln!(
                csv_text,
                "{},{},{},{},{},{},{},{},{},{}",
                r.name, r.tops_norm, r.sparsity, r.psnr, r.rpips, r.ssim, r.iqa, r.rfid, r.wall_s, r.flop_speedup
            );
        }
        let path = format!("{}/{}", self.out_dir, csv);
        if let Ok(mut f) = std::fs::File::create(&path) {
            let _ = f.write_all(csv_text.as_bytes());
            println!("(csv: {path})");
        }
    }

    /// Table 1 — end-to-end comparison vs block-sparse-skipping baselines.
    pub fn table1(&self) {
        let (base_imgs, base_stats) = self.run_images(Policy::full());
        let mut rows =
            vec![self.eval_against("Full-Attention", &base_imgs, &base_imgs, &base_stats, &base_stats)];
        let configs: Vec<Policy> = vec![
            Policy::dfa2(0.2, 4),
            Policy::sparge(0.065, 0.07, 4),
            // "Dyn-Sparse": FlashOmni masks with direct reuse, no GEMM opts
            // (emulated: quality path identical to FlashOmni D=0).
            Policy::flashomni(SparsityConfig::paper(0.05, 0.15, 4, 0, 0.0)),
            Policy::flashomni(SparsityConfig::paper(0.05, 0.15, 4, 0, 0.0)),
            Policy::flashomni(SparsityConfig::paper(0.50, 0.15, 4, 1, 0.0)),
            Policy::flashomni(SparsityConfig::paper(0.50, 0.15, 5, 1, 0.0)),
            Policy::flashomni(SparsityConfig::paper(0.50, 0.15, 5, 2, 0.3)),
        ];
        let labels = [
            "DiTFastAttnV2 (θ=0.2)".to_string(),
            "SpargeAttn (l1=6.5%, l2=7%)".to_string(),
            "Dyn-Sparse (5%, 15%, 4, 0, 0%)".to_string(),
            "FlashOmni (5%, 15%, 4, 0, 0%)".to_string(),
            "FlashOmni (50%, 15%, 4, 1, 0%)".to_string(),
            "FlashOmni (50%, 15%, 5, 1, 0%)".to_string(),
            "FlashOmni (50%, 15%, 5, 2, 30%)".to_string(),
        ];
        for (policy, label) in configs.into_iter().zip(labels) {
            let (imgs, stats) = self.run_images(policy);
            rows.push(self.eval_against(&label, &imgs, &base_imgs, &stats, &base_stats));
        }
        self.print_rows("Table 1 — vs block-sparse skipping (image task)", &rows, "table1.csv");
    }

    /// Table 2 — vs feature-caching baselines.
    pub fn table2(&self) {
        let (base_imgs, base_stats) = self.run_images(Policy::full());
        let mut rows =
            vec![self.eval_against("Full-Attention", &base_imgs, &base_imgs, &base_stats, &base_stats)];
        let cases: Vec<(Policy, &str)> = vec![
            (Policy::fora(5, 4), "FORA (N=5)"),
            (Policy::toca(SparsityConfig::paper(0.5, 0.0, 5, 0, 0.0)), "ToCa (N=5)"),
            (Policy::taylorseer(5, 1, 4), "TaylorSeer (N=5, D=1)"),
            (Policy::taylorseer(5, 2, 4), "TaylorSeer (N=5, D=2)"),
            (
                Policy::flashomni(SparsityConfig::paper(0.5, 0.15, 5, 0, 0.3)),
                "FlashOmni (50%, 15%, 5, 0, 30%)",
            ),
            (
                Policy::flashomni(SparsityConfig::paper(0.5, 0.15, 5, 1, 0.3)),
                "FlashOmni (50%, 15%, 5, 1, 30%)",
            ),
            (
                Policy::flashomni(SparsityConfig::paper(0.5, 0.15, 5, 1, 0.0)),
                "FlashOmni (50%, 15%, 5, 1, 0%)",
            ),
            (Policy::taylorseer(6, 2, 4), "TaylorSeer (N=6, D=2)"),
            (
                Policy::flashomni(SparsityConfig::paper(0.5, 0.15, 6, 1, 0.3)),
                "FlashOmni (50%, 15%, 6, 1, 30%)",
            ),
        ];
        for (policy, label) in cases {
            let (imgs, stats) = self.run_images(policy);
            rows.push(self.eval_against(label, &imgs, &base_imgs, &stats, &base_stats));
        }
        self.print_rows("Table 2 — vs feature caching (image task)", &rows, "table2.csv");
    }

    /// Table 3 — ablation over interval `N` and order `D`.
    pub fn table3(&self) {
        let (base_imgs, base_stats) = self.run_images(Policy::full());
        let mut rows = Vec::new();
        for n in 3..=7 {
            let p = Policy::flashomni(SparsityConfig::paper(0.05, 0.15, n, 1, 0.0));
            let (imgs, stats) = self.run_images(p);
            rows.push(self.eval_against(
                &format!("(5%, 15%, N={n}, 1, 0)"),
                &imgs,
                &base_imgs,
                &stats,
                &base_stats,
            ));
        }
        for d in 0..=2 {
            let p = Policy::flashomni(SparsityConfig::paper(0.5, 0.15, 5, d, 0.3));
            let (imgs, stats) = self.run_images(p);
            rows.push(self.eval_against(
                &format!("(50%, 15%, 5, D={d}, 30%)"),
                &imgs,
                &base_imgs,
                &stats,
                &base_stats,
            ));
        }
        self.print_rows("Table 3 — ablation over N and D", &rows, "table3.csv");
    }

    /// Table 5 — text-guided editing (SDEdit-style conditioning substitute).
    pub fn table5(&self) {
        let t_start = 0.6;
        let run = |policy: Policy| -> (Vec<Tensor>, RunStats) {
            let mut engine = self.engine(policy);
            let mut images = Vec::new();
            let mut agg = RunStats::default();
            for (i, &scene) in self.scenes.iter().enumerate() {
                // Edit: start from a *different* scene's trajectory blended
                // with noise, guided by this scene's caption.
                let src_scene = (scene + 37) % crate::workload::num_scenes();
                let ids = caption_ids(scene, self.model.cfg.text_tokens);
                let r = self.generate_edit(&mut engine, &ids, src_scene, 2000 + i as u64, t_start);
                merge_stats(&mut agg, &r.stats);
                images.push(r.image);
            }
            (images, agg)
        };
        let (base_imgs, base_stats) = run(Policy::full());
        let mut rows =
            vec![self.eval_against("Full-Attention", &base_imgs, &base_imgs, &base_stats, &base_stats)];
        let cases: Vec<(Policy, &str)> = vec![
            (Policy::dfa2(0.2, 2), "DiTFastAttnV2 (θ=0.2)"),
            (Policy::sparge(0.06, 0.065, 2), "SpargeAttn (l1=6%, l2=6.5%)"),
            (
                Policy::flashomni(SparsityConfig::paper(0.5, 0.15, 5, 1, 0.0)),
                "FlashOmni (50%, 15%, 5, 1, 0)",
            ),
            (Policy::taylorseer(5, 1, 2), "TaylorSeer (N=5, D=1)"),
            (
                Policy::flashomni(SparsityConfig::paper(0.5, 0.15, 5, 1, 0.2)),
                "FlashOmni (50%, 15%, 5, 1, 20%)",
            ),
        ];
        for (policy, label) in cases {
            let (imgs, stats) = run(policy);
            rows.push(self.eval_against(label, &imgs, &base_imgs, &stats, &base_stats));
        }
        self.print_rows("Table 5 — text-guided editing task", &rows, "table5.csv");
    }

    /// SDEdit-style editing generation: start the ODE at `t_start` from a
    /// noised rendering of the source scene.
    fn generate_edit(
        &self,
        engine: &mut DiTEngine,
        ids: &[usize],
        src_scene: usize,
        seed: u64,
        t_start: f64,
    ) -> GenResult {
        // Build the source patches from the *model itself* generating the
        // source scene densely (keeps everything self-contained).
        let src_ids = caption_ids(src_scene, self.model.cfg.text_tokens);
        let mut dense = self.engine(Policy::full());
        let src = dense.generate(&src_ids, seed ^ 0x5eed, self.steps.min(12));
        let src_patches = crate::diffusion::patchify(&src.image, &self.model.cfg);
        // x_{t_start} = (1−t)·x_src + t·ε, then integrate t_start → 0.
        let noise = initial_noise(&self.model.cfg, seed);
        let mut x = src_patches.clone();
        x.scale(1.0 - t_start as f32);
        let mut eps = noise.clone();
        eps.scale(t_start as f32);
        x.add_assign(&eps);
        engine.reset();
        let sub_steps = (self.steps as f64 * t_start).ceil() as usize;
        let grid: Vec<f64> = (0..=sub_steps)
            .map(|k| t_start * (1.0 - k as f64 / sub_steps as f64))
            .collect();
        let plan = crate::diffusion::plan_steps(
            sub_steps,
            engine.policy.schedule().0.min(sub_steps),
            engine.policy.schedule().1,
        );
        // Reuse engine internals through generate_with_grid.
        engine.generate_with_grid(ids, x, &grid, &plan)
    }

    /// Figure 7 — density vs timestep, FlashOmni vs SpargeAttn.
    pub fn fig7(&self) {
        println!("\n## Figure 7 — attention density per denoising step\n");
        let mut csv = String::from("step,flashomni,sparge\n");
        let ids = caption_ids(self.scenes[0], self.model.cfg.text_tokens);
        let mut fo = self.engine(Policy::flashomni(SparsityConfig::paper(0.5, 0.15, 5, 1, 0.3)));
        let r_fo = fo.generate(&ids, 1, self.steps);
        let mut sp = self.engine(Policy::sparge(0.065, 0.07, 4));
        let r_sp = sp.generate(&ids, 1, self.steps);
        println!("step  FlashOmni  SpargeAttn");
        for s in 0..self.steps {
            println!(
                "{s:>4}  {:>9.3}  {:>10.3}",
                r_fo.stats.per_step_density[s], r_sp.stats.per_step_density[s]
            );
            let _ = writeln!(
                csv,
                "{s},{},{}",
                r_fo.stats.per_step_density[s], r_sp.stats.per_step_density[s]
            );
        }
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        println!(
            "mean density: FlashOmni {:.3} vs SpargeAttn {:.3} (paper: FlashOmni lower)",
            mean(&r_fo.stats.per_step_density),
            mean(&r_sp.stats.per_step_density)
        );
        let _ = std::fs::write(format!("{}/fig7.csv", self.out_dir), csv);
    }

    /// Figure 9 — warmup-steps sensitivity, FlashOmni vs TaylorSeer.
    pub fn fig9(&self) {
        println!("\n## Figure 9 — warmup-step sensitivity (PSNR / SSIM / RPIPS / rFID)\n");
        let (base_imgs, base_stats) = self.run_images(Policy::full());
        let mut csv = String::from("warmup,method,psnr,ssim,rpips,rfid\n");
        println!(
            "{:<8} {:<28} {:>7} {:>7} {:>8} {:>8}",
            "warmup", "method", "PSNR", "SSIM", "RPIPS", "rFID"
        );
        for warmup in [1usize, 2, 4, 6] {
            let cases: Vec<(Policy, String)> = vec![
                (
                    Policy::flashomni(SparsityConfig {
                        warmup,
                        ..SparsityConfig::paper(0.5, 0.15, 5, 1, 0.3)
                    }),
                    "FlashOmni (50%,15%,5,1,30%)".to_string(),
                ),
                (Policy::taylorseer(5, 1, warmup), "TaylorSeer (N=5, D=1)".to_string()),
            ];
            for (policy, label) in cases {
                let (imgs, stats) = self.run_images(policy);
                let row = self.eval_against(&label, &imgs, &base_imgs, &stats, &base_stats);
                println!(
                    "{warmup:<8} {label:<28} {:>7.3} {:>7.4} {:>8.4} {:>8.3}",
                    row.psnr, row.ssim, row.rpips, row.rfid
                );
                let _ = writeln!(csv, "{warmup},{label},{},{},{},{}", row.psnr, row.ssim, row.rpips, row.rfid);
            }
        }
        let _ = std::fs::write(format!("{}/fig9.csv", self.out_dir), csv);
    }

    /// Figure 1 / video table rows — "video" task: frame sequence with a
    /// shared scene and per-frame marker tokens; VBench-proxy metrics.
    pub fn video_table(&self) {
        println!("\n## Video task (Hunyuan substitute) — VBench-proxy metrics\n");
        let frames_n = 6;
        let scene = self.scenes[0];
        let run = |policy: Policy| -> (Vec<Tensor>, RunStats) {
            let mut engine = self.engine(policy);
            let mut frames = Vec::new();
            let mut agg = RunStats::default();
            for f in 0..frames_n {
                let ids = video_frame_ids(scene, f, self.model.cfg.text_tokens);
                let r = engine.generate(&ids, 777, self.steps);
                merge_stats(&mut agg, &r.stats);
                frames.push(r.image);
            }
            (frames, agg)
        };
        let (base_frames, base_stats) = run(Policy::full());
        let cases: Vec<(Policy, &str)> = vec![
            (Policy::full(), "Full-Attention"),
            (Policy::dfa2(0.2, 4), "DiTFastAttnV2 (θ=0.2)"),
            (Policy::sparge(0.06, 0.065, 4), "SpargeAttn (l1=6%,l2=6.5%)"),
            (Policy::taylorseer(6, 1, 4), "TaylorSeer (N=6, D=1)"),
            (
                Policy::flashomni(SparsityConfig::paper(0.4, 0.01, 5, 1, 0.3)),
                "FlashOmni (40%, 1%, 5, 1, 30%)",
            ),
            (
                Policy::flashomni(SparsityConfig::paper(0.5, 0.05, 6, 1, 0.3)),
                "FlashOmni (50%, 5%, 6, 1, 30%)",
            ),
        ];
        println!(
            "| {:<28} | {:>8} | {:>7} | {:>7} | {:>7} | {:>8} | {:>8} | {:>7} | {:>6} |",
            "Method", "TOPSn↑", "Spars%", "PSNR↑", "SSIM↑", "Smooth↑", "Consis↑", "Flick↑", "Style↑"
        );
        let mut csv = String::from("method,tops_norm,sparsity,psnr,ssim,smooth,consistency,flicker,style\n");
        for (policy, label) in cases {
            let (frames, stats) = run(policy);
            let n = frames.len() as f64;
            let psnr = frames
                .iter()
                .zip(&base_frames)
                .map(|(a, b)| metrics::psnr(a, b).min(99.0))
                .sum::<f64>()
                / n;
            let ssim =
                frames.iter().zip(&base_frames).map(|(a, b)| metrics::ssim(a, b)).sum::<f64>() / n;
            let sm = metrics::smoothness(&frames);
            let co = metrics::consistency(&frames);
            let fl = metrics::flicker(&frames);
            let st = metrics::style(&frames);
            let tops_n = base_stats.wall_s / stats.wall_s.max(1e-12);
            println!(
                "| {:<28} | {:>8.3} | {:>7.1} | {:>7.3} | {:>7.4} | {:>8.2} | {:>8.2} | {:>7.2} | {:>6.4} |",
                label,
                tops_n,
                stats.attn_sparsity() * 100.0,
                psnr,
                ssim,
                sm,
                co,
                fl,
                st
            );
            let _ = writeln!(
                csv,
                "{label},{tops_n},{},{psnr},{ssim},{sm},{co},{fl},{st}",
                stats.attn_sparsity() * 100.0
            );
        }
        let _ = std::fs::write(format!("{}/video_table.csv", self.out_dir), csv);
    }

    /// Figure 1 right panel — end-to-end speedup bar.
    pub fn fig1(&self) {
        println!("\n## Figure 1 — end-to-end acceleration (video-scale config)\n");
        let ids = caption_ids(self.scenes[0], self.model.cfg.text_tokens);
        let mut dense = self.engine(Policy::full());
        let r0 = dense.generate(&ids, 5, self.steps);
        let mut fo =
            self.engine(Policy::flashomni(SparsityConfig::paper(0.5, 0.05, 6, 1, 0.3)));
        let r1 = fo.generate(&ids, 5, self.steps);
        println!(
            "dense wall {:.3}s | FlashOmni wall {:.3}s | e2e speedup {:.2}× at {:.0}% sparsity (paper: ~1.5× at 46%)",
            r0.stats.wall_s,
            r1.stats.wall_s,
            r0.stats.wall_s / r1.stats.wall_s,
            r1.stats.attn_sparsity() * 100.0
        );
        let _ = std::fs::write(
            format!("{}/fig1.csv", self.out_dir),
            format!(
                "dense_s,flashomni_s,speedup,sparsity\n{},{},{},{}\n",
                r0.stats.wall_s,
                r1.stats.wall_s,
                r0.stats.wall_s / r1.stats.wall_s,
                r1.stats.attn_sparsity()
            ),
        );
    }

    /// Run everything.
    pub fn all(&self) {
        self.table1();
        self.table2();
        self.table3();
        self.table5();
        self.video_table();
        self.fig1();
        self.fig7();
        self.fig9();
    }
}

/// Nearest-rank percentile over an **ascending-sorted** sample: the
/// smallest element such that at least `p·n` of the sample is ≤ it
/// (rank `⌈p·n⌉`, 1-indexed; `p = 0` maps to the minimum). 0 when empty.
///
/// This is the single percentile definition every latency column in the
/// repo uses — the coordinator's `ServeReport`, the serving benches, and
/// the router all route through it. Nearest-rank always returns an actual
/// sample (no interpolation) and, unlike the truncating
/// `((n-1)·p) as usize` indexing it replaced, never biases a high
/// percentile down a rank (n = 10, p95: rank 10, not index 8).
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let n = sorted.len();
    let rank = (p.clamp(0.0, 1.0) * n as f64).ceil() as usize;
    sorted[rank.clamp(1, n) - 1]
}

/// Sort a sample (NaN-safe: `total_cmp` orders NaNs last instead of
/// panicking mid-comparison) and return a nearest-rank percentile
/// accessor over it. See [`percentile_sorted`] for the rank convention.
pub fn percentiles(mut xs: Vec<f64>) -> impl Fn(f64) -> f64 {
    xs.sort_by(f64::total_cmp);
    move |p: f64| percentile_sorted(&xs, p)
}

/// Accumulate run statistics across generations.
pub fn merge_stats(agg: &mut RunStats, s: &RunStats) {
    agg.steps += s.steps;
    agg.wall_s += s.wall_s;
    agg.attn_computed_pairs += s.attn_computed_pairs;
    agg.attn_total_pairs += s.attn_total_pairs;
    agg.gq_computed += s.gq_computed;
    agg.gq_total += s.gq_total;
    agg.go_computed += s.go_computed;
    agg.go_total += s.go_total;
    agg.cached_layer_steps += s.cached_layer_steps;
    agg.total_layer_steps += s.total_layer_steps;
    agg.flops_done += s.flops_done;
    agg.flops_dense += s.flops_dense;
    for i in 0..4 {
        agg.phase_s[i] += s.phase_s[i];
    }
    agg.per_step_density.extend_from_slice(&s.per_step_density);
}

/// The missing piece for editing: drive the engine over a custom time grid
/// starting from given patches. Declared here, implemented on DiTEngine.
impl DiTEngine {
    /// Generate starting from explicit initial patches over an explicit
    /// (descending) time grid and step plan.
    pub fn generate_with_grid(
        &mut self,
        text_ids: &[usize],
        mut x: Tensor,
        grid: &[f64],
        plan: &[crate::diffusion::StepKind],
    ) -> GenResult {
        assert_eq!(grid.len(), plan.len() + 1);
        self.reset();
        let mut stats = RunStats { steps: plan.len(), ..Default::default() };
        let t0 = std::time::Instant::now();
        for (step, kind) in plan.iter().enumerate() {
            let before = (stats.attn_computed_pairs, stats.attn_total_pairs);
            let v = self.step_forward(text_ids, &x, grid[step], *kind, step, &mut stats);
            euler_step(&mut x, &v, grid[step] - grid[step + 1]);
            let dp = stats.attn_computed_pairs - before.0;
            let dt = stats.attn_total_pairs - before.1;
            stats.per_step_density.push(if dt == 0 {
                if kind.is_sparse() {
                    0.0
                } else {
                    1.0
                }
            } else {
                dp as f64 / dt as f64
            });
        }
        stats.wall_s = t0.elapsed().as_secs_f64();
        GenResult { image: unpatchify(&x, &self.model.cfg), stats }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::model::weights::Weights;

    fn reporter() -> Reporter {
        let cfg = ModelConfig {
            dim: 32,
            heads: 2,
            layers: 1,
            text_tokens: 8,
            patch_h: 4,
            patch_w: 4,
            patch_size: 2,
            channels: 3,
            mlp_ratio: 2,
            vocab: 256,
        };
        Reporter {
            model: MiniMMDiT::new(cfg.clone(), Weights::random(&cfg, 2)),
            out_dir: std::env::temp_dir().join("fo_reports").to_str().unwrap().into(),
            scenes: vec![1, 2],
            steps: 5,
            block: 8,
        }
    }

    #[test]
    fn run_images_and_eval() {
        let r = reporter();
        std::fs::create_dir_all(&r.out_dir).unwrap();
        let (base, bs) = r.run_images(Policy::full());
        assert_eq!(base.len(), 2);
        let (imgs, st) = r.run_images(Policy::fora(2, 1));
        let row = r.eval_against("fora", &imgs, &base, &st, &bs);
        assert!(row.psnr.is_finite());
        assert!(row.sparsity >= 0.0);
        // Self-comparison is perfect.
        let row0 = r.eval_against("base", &base, &base, &bs, &bs);
        assert!(row0.psnr > 90.0);
        assert!(row0.rfid.abs() < 1e-9);
    }

    #[test]
    fn percentiles_nearest_rank_on_known_sample() {
        // n = 10, values 1..=10: nearest-rank pins p50 = 5 (rank ⌈5⌉),
        // p95 = 10 (rank ⌈9.5⌉ = 10) and p99 = 10. The old truncating
        // `((n-1)·p) as usize` indexing returned 9.0 for p95 (index 8) —
        // this sample is the regression pin for that bug.
        let xs: Vec<f64> = (1..=10).map(|i| i as f64).collect();
        let pct = percentiles(xs);
        assert_eq!(pct(0.50), 5.0);
        assert_eq!(pct(0.95), 10.0);
        assert_eq!(pct(0.99), 10.0);
        // Edges: p0 = min, p100 = max; input order must not matter.
        assert_eq!(pct(0.0), 1.0);
        assert_eq!(pct(1.0), 10.0);
        let shuffled = percentiles(vec![7.0, 2.0, 9.0, 1.0, 5.0]);
        assert_eq!(shuffled(0.5), 5.0);
        assert_eq!(shuffled(1.0), 9.0);
        // Singleton: every percentile is the one sample.
        let one = percentiles(vec![3.25]);
        assert_eq!(one(0.01), 3.25);
        assert_eq!(one(0.99), 3.25);
        // Empty sample reads as 0 instead of panicking.
        let empty = percentiles(Vec::new());
        assert_eq!(empty(0.5), 0.0);
    }

    #[test]
    fn percentiles_tolerate_nan() {
        // A NaN latency (e.g. a 0/0 rate upstream) must not panic the
        // sort; total_cmp orders NaNs after every real sample, so finite
        // percentiles still read finite values.
        let pct = percentiles(vec![2.0, f64::NAN, 1.0, 3.0]);
        assert_eq!(pct(0.5), 2.0);
        assert!(pct(0.25).is_finite());
    }

    #[test]
    fn generate_with_grid_matches_generate_for_full_grid() {
        let r = reporter();
        let mut e1 = r.engine(Policy::full());
        let a = e1.generate(&vec![1; 8], 3, 4);
        let grid = crate::diffusion::time_grid(4);
        let plan = crate::diffusion::plan_steps(4, usize::MAX, 1);
        let mut e2 = r.engine(Policy::full());
        let x0 = crate::diffusion::initial_noise(&r.model.cfg, 3);
        let b = e2.generate_with_grid(&vec![1; 8], x0, &grid, &plan);
        assert!(a.image.max_abs_diff(&b.image) < 1e-5);
    }
}
