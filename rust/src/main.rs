//! FlashOmni CLI — the leader entrypoint.
//!
//! Subcommands (hand-rolled arg parsing; clap is unavailable offline):
//!
//! ```text
//! flashomni generate  [--weights P] [--policy NAME] [--steps N] [--scene N] [--seed N] [--out img.fot]
//! flashomni serve     [--weights P] [--requests N] [--rate R] [--workers N] [--batch N] [--policy NAME]
//! flashomni reproduce [--weights P] [--table 1|2|3|5] [--fig 1|7|9|video] [--all] [--scenes N] [--steps N] [--out DIR]
//! flashomni inspect   [--weights P] [--scene N] [--steps N]     # symbol/density dump
//! flashomni selfcheck [--artifacts DIR]                          # PJRT oracle round-trip
//! ```
//!
//! Policies: `full`, `flashomni:tq,tkv,N,D,sq` (e.g. flashomni:0.5,0.15,5,1,0.3),
//! `taylorseer:N,D`, `fora:N`, `toca:tq,N`, `sparge:l1,l2`, `dfa2:theta`.

use flashomni::config::SparsityConfig;
use flashomni::coordinator::replay_trace;
use flashomni::engine::{DiTEngine, Policy};
use flashomni::model::MiniMMDiT;
use flashomni::report::Reporter;
use flashomni::workload::{caption_ids, poisson_trace};
use std::collections::HashMap;
use std::process::ExitCode;

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                out.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                out.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    out
}

fn parse_policy(spec: &str, warmup: usize) -> Result<Policy, String> {
    let (name, rest) = spec.split_once(':').unwrap_or((spec, ""));
    let nums: Vec<f64> = rest
        .split(',')
        .filter(|s| !s.is_empty())
        .map(|s| s.parse::<f64>().map_err(|e| format!("bad number '{s}': {e}")))
        .collect::<Result<_, _>>()?;
    let get = |i: usize, default: f64| nums.get(i).copied().unwrap_or(default);
    Ok(match name {
        "full" => Policy::full(),
        "flashomni" => Policy::flashomni(SparsityConfig {
            warmup,
            ..SparsityConfig::paper(
                get(0, 0.5),
                get(1, 0.15),
                get(2, 5.0) as usize,
                get(3, 1.0) as usize,
                get(4, 0.3),
            )
        }),
        "taylorseer" => Policy::taylorseer(get(0, 5.0) as usize, get(1, 1.0) as usize, warmup),
        "fora" => Policy::fora(get(0, 3.0) as usize, warmup),
        "toca" => Policy::toca(SparsityConfig {
            warmup,
            ..SparsityConfig::paper(get(0, 0.5), 0.0, get(1, 5.0) as usize, 0, 0.0)
        }),
        "sparge" => Policy::sparge(get(0, 0.065), get(1, 0.07), warmup),
        "dfa2" => Policy::dfa2(get(0, 0.2), warmup),
        other => return Err(format!("unknown policy '{other}'")),
    })
}

fn flag<T: std::str::FromStr>(flags: &HashMap<String, String>, key: &str, default: T) -> T {
    flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn cmd_generate(flags: HashMap<String, String>) -> Result<(), String> {
    let weights = flags.get("weights").cloned().unwrap_or("artifacts/weights.fot".into());
    let model = MiniMMDiT::load(&weights)?;
    let steps = flag(&flags, "steps", 20usize);
    let scene = flag(&flags, "scene", 0usize);
    let seed = flag(&flags, "seed", 0u64);
    let policy = parse_policy(flags.get("policy").map(|s| s.as_str()).unwrap_or("full"), 4)?;
    println!(
        "model: {} params, seq {} | policy: {}",
        model.param_count(),
        model.cfg.seq_len(),
        policy.name()
    );
    let ids = caption_ids(scene, model.cfg.text_tokens);
    let mut engine = DiTEngine::new(model, policy, 8, 8);
    let r = engine.generate(&ids, seed, steps);
    println!(
        "generated in {:.3}s | sparsity {:.1}% | FLOP speedup {:.2}×",
        r.stats.wall_s,
        r.stats.attn_sparsity() * 100.0,
        r.stats.flop_speedup(),
    );
    if let Some(out) = flags.get("out") {
        let mut f = flashomni::util::fot::FotFile::new();
        f.insert_f32("image", r.image.shape(), r.image.data());
        f.save(out)?;
        println!("image tensor written to {out}");
    }
    Ok(())
}

fn cmd_serve(flags: HashMap<String, String>) -> Result<(), String> {
    let weights = flags.get("weights").cloned().unwrap_or("artifacts/weights.fot".into());
    let model = MiniMMDiT::load(&weights)?;
    let n = flag(&flags, "requests", 8usize);
    let rate = flag(&flags, "rate", 2.0f64);
    let workers = flag(&flags, "workers", 1usize);
    let batch = flag(&flags, "batch", 4usize);
    let steps = flag(&flags, "steps", 16usize);
    let spec = flags.get("policy").cloned().unwrap_or("flashomni:0.5,0.15,5,1,0.3".into());
    let policy = parse_policy(&spec, 4)?;
    let trace = poisson_trace(7, n, rate, steps, model.cfg.text_tokens);
    println!(
        "serving {n} requests (rate {rate}/s, {workers} workers, batch {batch}, policy {})",
        policy.name()
    );
    let model2 = model.clone();
    let policy2 = policy.clone();
    let (_responses, report) = replay_trace(
        move |_wid| DiTEngine::new(model2.clone(), policy2.clone(), 8, 8),
        &trace,
        workers,
        batch,
        1.0,
    );
    report.print(&policy.name());
    Ok(())
}

fn cmd_reproduce(flags: HashMap<String, String>) -> Result<(), String> {
    let weights = flags.get("weights").cloned().unwrap_or("artifacts/weights.fot".into());
    let out = flags.get("out").cloned().unwrap_or("reports".into());
    let scenes = flag(&flags, "scenes", 4usize);
    let steps = flag(&flags, "steps", 20usize);
    let r = Reporter::new(&weights, &out, scenes, steps)?;
    println!(
        "reproduction harness: {} scenes × {} steps, model {} params",
        scenes,
        steps,
        r.model.param_count()
    );
    if flags.contains_key("all") {
        r.all();
        return Ok(());
    }
    match flags.get("table").map(|s| s.as_str()) {
        Some("1") => r.table1(),
        Some("2") => r.table2(),
        Some("3") => r.table3(),
        Some("5") => r.table5(),
        Some(other) => return Err(format!("unknown table '{other}'")),
        None => {}
    }
    match flags.get("fig").map(|s| s.as_str()) {
        Some("1") => r.fig1(),
        Some("7") => r.fig7(),
        Some("9") => r.fig9(),
        Some("video") => r.video_table(),
        Some(other) => return Err(format!("unknown fig '{other}'")),
        None => {}
    }
    Ok(())
}

fn cmd_inspect(flags: HashMap<String, String>) -> Result<(), String> {
    let weights = flags.get("weights").cloned().unwrap_or("artifacts/weights.fot".into());
    let model = MiniMMDiT::load(&weights)?;
    let steps = flag(&flags, "steps", 15usize);
    let scene = flag(&flags, "scene", 0usize);
    let spec = flags.get("policy").cloned().unwrap_or("flashomni:0.5,0.15,5,1,0.3".into());
    let policy = parse_policy(&spec, 4)?;
    let ids = caption_ids(scene, model.cfg.text_tokens);
    let mut engine = DiTEngine::new(model, policy, 8, 8);
    let r = engine.generate(&ids, 0, steps);
    println!("policy {} | per-step attention density:", engine.policy.name());
    for (s, d) in r.stats.per_step_density.iter().enumerate() {
        let bars = (d * 40.0).round() as usize;
        println!("step {s:>3} {d:>6.3} {}", "#".repeat(bars));
    }
    println!(
        "pairs {}/{} | GEMM-Q tiles {}/{} | GEMM-O tiles {}/{} | cached layer-steps {}/{}",
        r.stats.attn_computed_pairs,
        r.stats.attn_total_pairs,
        r.stats.gq_computed,
        r.stats.gq_total,
        r.stats.go_computed,
        r.stats.go_total,
        r.stats.cached_layer_steps,
        r.stats.total_layer_steps
    );
    println!(
        "phase seconds: qkv {:.3} attn {:.3} proj {:.3} mlp {:.3}",
        r.stats.phase_s[0], r.stats.phase_s[1], r.stats.phase_s[2], r.stats.phase_s[3]
    );
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_selfcheck(_flags: HashMap<String, String>) -> Result<(), String> {
    Err("selfcheck needs the PJRT oracle — rebuild with `--features pjrt` \
         (requires the vendored `xla`/`anyhow` crates, see Cargo.toml)"
        .into())
}

#[cfg(feature = "pjrt")]
fn cmd_selfcheck(flags: HashMap<String, String>) -> Result<(), String> {
    let dir = flags.get("artifacts").cloned().unwrap_or("artifacts".into());
    println!("PJRT self-check against {dir}/ ...");
    selfcheck(&dir).map_err(|e| format!("{e:#}"))
}

#[cfg(feature = "pjrt")]
fn selfcheck(dir: &str) -> anyhow::Result<()> {
    use flashomni::runtime::{ArtifactRuntime, Input};
    use flashomni::tensor::Tensor;
    use flashomni::util::fot::FotFile;
    let err = anyhow::Error::msg;
    let mut rt = ArtifactRuntime::cpu(dir)?;
    println!("platform: {}", rt.platform());
    let golden = FotFile::load(format!("{dir}/golden.fot")).map_err(err)?;
    // Attention artifact.
    rt.load("attention_masked")?;
    let q = Tensor::from_fot(&golden, "attn.q").map_err(err)?;
    let k = Tensor::from_fot(&golden, "attn.k").map_err(err)?;
    let v = Tensor::from_fot(&golden, "attn.v").map_err(err)?;
    let want = Tensor::from_fot(&golden, "attn.out").map_err(err)?;
    let s_c: Vec<i32> = golden
        .get("attn.s_c")
        .map_err(err)?
        .to_u8()
        .map_err(err)?
        .iter()
        .map(|&b| b as i32)
        .collect();
    let s_s_t = golden.get("attn.s_s").map_err(err)?.clone();
    let s_s: Vec<i32> =
        s_s_t.to_u8().map_err(err)?.iter().map(|&b| b as i32).collect();
    let out = rt.execute(
        "attention_masked",
        &[
            Input::F32(&q),
            Input::F32(&k),
            Input::F32(&v),
            Input::I32(&s_c, &[s_c.len()]),
            Input::I32(&s_s, &s_s_t.shape),
        ],
        &[q.shape()],
    )?;
    let diff = out[0].max_abs_diff(&want);
    anyhow::ensure!(diff < 1e-4, "attention artifact mismatch: {diff}");
    println!("attention_masked OK (max |diff| = {diff:.2e})");
    // Full model step.
    rt.load("mmdit_step")?;
    let params = flashomni::runtime::load_param_list(dir)?;
    let ids_raw = golden.get("mmdit.ids").map_err(err)?;
    let ids: Vec<i32> = ids_raw
        .data
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    let patches = Tensor::from_fot(&golden, "mmdit.patches").map_err(err)?;
    let want = Tensor::from_fot(&golden, "mmdit.velocity").map_err(err)?;
    let got = rt.mmdit_step(&params, &ids, &patches, 0.5, want.shape())?;
    let diff = got.max_abs_diff(&want);
    anyhow::ensure!(diff < 1e-3, "mmdit_step artifact mismatch: {diff}");
    println!("mmdit_step OK (max |diff| = {diff:.2e})");
    println!("selfcheck passed");
    Ok(())
}

fn usage() -> &'static str {
    "flashomni <generate|serve|reproduce|inspect|selfcheck|version> [--flags]\n\
     see `rust/src/main.rs` header for the full flag list"
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        eprintln!("{}", usage());
        return ExitCode::FAILURE;
    };
    let flags = parse_flags(&args[1..]);
    let result = match cmd.as_str() {
        "generate" => cmd_generate(flags),
        "serve" => cmd_serve(flags),
        "reproduce" => cmd_reproduce(flags),
        "inspect" => cmd_inspect(flags),
        "selfcheck" => cmd_selfcheck(flags),
        "version" => {
            println!("flashomni {}", flashomni::VERSION);
            Ok(())
        }
        other => Err(format!("unknown command '{other}'\n{}", usage())),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}
