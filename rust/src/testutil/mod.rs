//! Test utilities: seeded generators and a lightweight property-test loop.
//!
//! proptest is unavailable offline; `prop_check` runs a closure over many
//! seeded random cases and reports the failing seed so a failure can be
//! reproduced exactly with `prop_case`.

use crate::tensor::Tensor;
use crate::util::rng::Pcg32;

/// Random tensor with standard-normal entries.
pub fn randn(rng: &mut Pcg32, shape: &[usize]) -> Tensor {
    Tensor::from_vec(shape, rng.normal_vec(shape.iter().product()))
}

/// Random tensor with uniform entries in [lo, hi).
pub fn rand_uniform(rng: &mut Pcg32, shape: &[usize], lo: f32, hi: f32) -> Tensor {
    let n = shape.iter().product();
    Tensor::from_vec(shape, (0..n).map(|_| rng.range_f32(lo, hi)).collect())
}

/// Random bitmask of `n` bits with approximately `density` ones.
pub fn rand_mask(rng: &mut Pcg32, n: usize, density: f64) -> Vec<bool> {
    (0..n).map(|_| rng.f64() < density).collect()
}

/// Run `cases` property-test iterations; the closure gets a per-case RNG.
/// Panics with the failing case index + seed on the first failure.
pub fn prop_check(name: &str, cases: usize, mut f: impl FnMut(&mut Pcg32)) {
    for case in 0..cases {
        let seed = 0xf1a5_0000u64 + case as u64;
        let mut rng = Pcg32::seeded(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            eprintln!("property '{name}' failed at case {case} (seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Re-run a single property case by seed (for debugging failures).
pub fn prop_case(seed: u64, mut f: impl FnMut(&mut Pcg32)) {
    let mut rng = Pcg32::seeded(seed);
    f(&mut rng);
}

/// Assert two tensors are elementwise close.
#[track_caller]
pub fn assert_close(a: &Tensor, b: &Tensor, atol: f32, rtol: f32) {
    assert_eq!(a.shape(), b.shape(), "shape mismatch");
    for (i, (x, y)) in a.data().iter().zip(b.data()).enumerate() {
        let tol = atol + rtol * y.abs();
        assert!(
            (x - y).abs() <= tol,
            "element {i}: {x} vs {y} (|diff|={} > tol={tol})",
            (x - y).abs()
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn randn_shape() {
        let mut r = Pcg32::seeded(1);
        let t = randn(&mut r, &[3, 5]);
        assert_eq!(t.shape(), &[3, 5]);
        assert_eq!(t.numel(), 15);
    }

    #[test]
    fn mask_density() {
        let mut r = Pcg32::seeded(2);
        let m = rand_mask(&mut r, 10_000, 0.3);
        let ones = m.iter().filter(|&&b| b).count();
        assert!((ones as f64 / 10_000.0 - 0.3).abs() < 0.03);
    }

    #[test]
    fn prop_check_runs_all_cases() {
        let mut count = 0;
        prop_check("counting", 17, |_| count += 1);
        assert_eq!(count, 17);
    }

    #[test]
    #[should_panic]
    fn assert_close_catches_mismatch() {
        let a = Tensor::from_vec(&[2], vec![1.0, 1.0]);
        let b = Tensor::from_vec(&[2], vec![1.0, 1.2]);
        assert_close(&a, &b, 1e-3, 1e-3);
    }
}
