//! The lockstep **batched engine**: N in-flight requests, one denoising
//! step per [`BatchedEngine::step_forward`] call, cross-request plan
//! sharing per layer. See the [module docs](crate::batch) for the design.

use crate::cache::combine_bias_stack;
use crate::config::ModelConfig;
use crate::diffusion::{euler_step, initial_noise, plan_steps, time_grid, unpatchify, StepKind};
use crate::engine::{
    add_row_bias, build_plans, plan_key, post_attention_preprojected, sparse_step_flops,
    DiTEngine, EngineExec, Geometry, LayerPanels, LayerPlans, LayerState, PlanProvider, Policy,
    RunStats, PLAN_CACHE_CAP,
};
use crate::exec::ExecPool;
use crate::kernels::attention::flashomni_attention_ragged;
use crate::kernels::gemm_o::gemm_o_dispatch_ragged;
use crate::kernels::gemm_q::gemm_q_ragged;
use crate::mem::{digest_tensor, tensor_bytes, PagePool, Pooled};
use crate::model::blocks::{
    headwise_rmsnorm, headwise_rope, insert_head, linear, mlp_stream, pre_attention, vsplit,
    vstack, vstack_all, PreAttn,
};
use crate::model::{BlockExec, BlockWeights, MiniMMDiT};
use crate::obs::{self, Span};
use crate::plan::cache::{CacheOutcome, CacheStats, SharedPlanCache};
use crate::plan::SparsePlan;
use crate::symbols::LayerSymbols;
use crate::tensor::Tensor;
use crate::workload::Request;
use std::sync::Arc;
use std::time::Instant;

/// A request that finished inside the batched engine.
#[derive(Clone, Debug)]
pub struct BatchResult {
    /// Request id (as submitted).
    pub id: u64,
    /// Scene/prompt id of the request.
    pub scene: usize,
    /// `[H × W × C]` image, bitwise-identical to a solo `DiTEngine` run
    /// of the same request.
    pub image: Tensor,
    /// Per-request run statistics (FLOPs, densities, plan-cache outcomes).
    pub stats: RunStats,
    /// Seconds between enqueue and admission into the batch.
    pub queue_s: f64,
    /// Seconds between admission and completion (lockstep wall time).
    pub exec_s: f64,
    /// End-to-end seconds (queue + exec).
    pub latency_s: f64,
    /// Peak batch occupancy observed while this request was in flight.
    pub batch_size: usize,
}

/// A streaming preview: the decode of an in-flight request's latent after
/// `step` of `steps` denoising steps. Because the sampler trajectory is
/// deterministic and causal (each step depends only on earlier state),
/// the preview is **bitwise-identical** to what a solo run decoded after
/// the same step prefix — previews are prefixes of the final decode, the
/// diffusion-native analogue of token streaming.
#[derive(Clone, Debug)]
pub struct Preview {
    /// Request id (as submitted).
    pub id: u64,
    /// Scene/prompt id of the request.
    pub scene: usize,
    /// Denoising steps completed when this preview was decoded.
    pub step: usize,
    /// Total steps the request will run.
    pub steps: usize,
    /// `[H × W × C]` decode of the current latent.
    pub image: Tensor,
}

/// One in-flight request: its own denoising state, policy clone, and
/// per-layer engine state — everything a solo `DiTEngine::generate` would
/// hold, minus the model/panels/pool, which the batch shares.
struct Slot {
    req: Request,
    /// Per-request model config: the engine's config with the request's
    /// `patch_hw` override applied (weights are resolution-independent,
    /// so only the vision grid — and thus the sequence length — differs).
    cfg: ModelConfig,
    /// Per-request tile geometry derived from `cfg` (same `block_q` /
    /// `block_k` / `pool` as the engine — those are engine-constant).
    geo: Geometry,
    policy: Policy,
    state: Vec<LayerState>,
    /// Current latent patches `x_t`.
    x: Tensor,
    kinds: Vec<StepKind>,
    grid: Vec<f64>,
    step: usize,
    stats: RunStats,
    enqueued: Instant,
    admitted: Instant,
    batch_peak: usize,
}

/// Per-slot scratch for one lockstep step.
struct StepCtx {
    txt: Tensor,
    img: Tensor,
    cvec: Vec<f32>,
    kind: StepKind,
    density_before: (u64, u64),
}

/// [`PlanProvider`] over the process-shared compile cache, tagged with
/// the batch step's epoch id and the requesting slot's lane so the cache
/// can attribute same-step cross-request sharing exactly (even when other
/// engines hammer the same cache concurrently). On a miss, the slot's
/// previous plan set (its per-layer `base`) is offered for an incremental
/// recompile — so a batch whose symbols drift by a few rows between
/// refreshes pays one *delta* compile (plus B−1 shared hits) instead of a
/// full one.
struct SharedPlanProvider<'c> {
    cache: &'c SharedPlanCache<LayerPlans>,
    epoch: u64,
    lane: u64,
    /// Delta compilation on a miss (mirrors `DiTEngine::set_delta_compile`).
    delta: bool,
    /// Pool compiled segments are allocated in.
    mem: &'c PagePool,
}

impl PlanProvider for SharedPlanProvider<'_> {
    fn plans_for(
        &mut self,
        syms: &LayerSymbols,
        geo: &Geometry,
        base: Option<&LayerPlans>,
    ) -> (Arc<LayerPlans>, CacheOutcome) {
        let key = plan_key(syms, geo);
        let base = if self.delta { base } else { None };
        let mem = self.mem;
        self.cache.get_or_build_keyed(&key, self.epoch, self.lane, |pk| {
            build_plans(syms, geo, pk.clone(), base, mem)
        })
    }
}

/// Lockstep batched engine (see the [module docs](crate::batch)).
pub struct BatchedEngine {
    model: MiniMMDiT,
    policy: Policy,
    geo: Geometry,
    panels: Vec<LayerPanels>,
    exec: Arc<ExecPool>,
    cache: SharedPlanCache<LayerPlans>,
    slots: Vec<Slot>,
    max_batch: usize,
    /// Delta-compile refreshes that miss the shared cache but row-diff
    /// against the slot's previous plan (on by default).
    delta_enabled: bool,
    /// Emit a [`Preview`] every `preview_interval` completed steps
    /// (0 = previews off, the default).
    preview_interval: usize,
    /// Previews decoded since the last [`Self::take_previews`] drain.
    previews: Vec<Preview>,
    /// Paged pool backing every slot's resident state (TaylorSeer + bias
    /// stacks, plan segments, plan keys, deduped text K/V). Shared across
    /// the batch — that is what makes prefix sharing work.
    mem: PagePool,
}

impl BatchedEngine {
    /// Batched engine with symbol pooling factor 1.
    pub fn new(
        model: MiniMMDiT,
        policy: Policy,
        block_q: usize,
        block_k: usize,
        max_batch: usize,
    ) -> Self {
        Self::with_pool(model, policy, block_q, block_k, 1, max_batch)
    }

    /// Batched engine with an explicit symbol pooling factor (mirrors
    /// [`DiTEngine::with_pool`]).
    pub fn with_pool(
        model: MiniMMDiT,
        policy: Policy,
        block_q: usize,
        block_k: usize,
        pool: usize,
        max_batch: usize,
    ) -> Self {
        let geo = Geometry::from_model(&model.cfg, block_q, block_k, pool);
        let panels = LayerPanels::for_model(&model);
        let mem = PagePool::global().clone();
        BatchedEngine {
            model,
            policy,
            geo,
            panels,
            exec: ExecPool::global(),
            cache: SharedPlanCache::new_in(PLAN_CACHE_CAP, &mem),
            slots: Vec::new(),
            max_batch: max_batch.max(1),
            delta_enabled: true,
            preview_interval: 0,
            previews: Vec::new(),
            mem,
        }
    }

    /// Build from a configured single-request engine, moving its model,
    /// policy, geometry, prebuilt panels, and exec pool (no weight clone,
    /// no panel re-gather). The plan cache starts fresh — swap in a
    /// shared one via [`Self::set_plan_cache`].
    pub fn from_engine(engine: DiTEngine, max_batch: usize) -> Self {
        let (model, policy, geo, panels, exec, mem) = engine.into_batch_parts();
        BatchedEngine {
            model,
            policy,
            geo,
            panels,
            exec,
            cache: SharedPlanCache::new_in(PLAN_CACHE_CAP, &mem),
            slots: Vec::new(),
            max_batch: max_batch.max(1),
            delta_enabled: true,
            preview_interval: 0,
            previews: Vec::new(),
            mem,
        }
    }

    /// Emit a streaming [`Preview`] every `k` completed denoising steps
    /// for every in-flight request (0 disables previews — the default).
    /// The final step never emits a preview: its decode *is* the
    /// [`BatchResult`] image delivered at retirement.
    pub fn set_preview_interval(&mut self, k: usize) {
        self.preview_interval = k;
    }

    /// The configured preview interval (0 = previews off).
    pub fn preview_interval(&self) -> usize {
        self.preview_interval
    }

    /// Drain the previews decoded since the last call, in emission order
    /// (by lockstep step, then slot order within a step).
    pub fn take_previews(&mut self) -> Vec<Preview> {
        std::mem::take(&mut self.previews)
    }

    /// Enable/disable incremental plan recompiles for this batch (on by
    /// default; see `DiTEngine::set_delta_compile`).
    pub fn set_delta_compile(&mut self, on: bool) {
        self.delta_enabled = on;
    }

    /// Swap the execution pool every kernel of this batch dispatches on.
    pub fn set_exec_pool(&mut self, pool: Arc<ExecPool>) {
        self.exec = pool;
    }

    /// The pool this batch dispatches kernels on.
    pub fn exec_pool(&self) -> &Arc<ExecPool> {
        &self.exec
    }

    /// Share a plan-compile cache with other engines (the coordinator
    /// hands every worker one handle → cross-worker plan sharing).
    pub fn set_plan_cache(&mut self, cache: SharedPlanCache<LayerPlans>) {
        self.cache = cache;
    }

    /// The (possibly shared) plan-compile cache handle.
    pub fn plan_cache(&self) -> &SharedPlanCache<LayerPlans> {
        &self.cache
    }

    /// Swap the paged pool backing every slot's resident state (private
    /// budgets in tests and benches). Rebuilds the plan cache on the new
    /// pool so plan keys/segments live there too (a cache installed via
    /// [`Self::set_plan_cache`] is discarded — swap pools first when
    /// combining the two). Call before admitting requests:
    /// already-admitted slots keep their old pool's blocks.
    pub fn set_page_pool(&mut self, mem: &PagePool) {
        self.mem = mem.clone();
        self.cache = SharedPlanCache::new_in(PLAN_CACHE_CAP, mem);
    }

    /// The paged pool backing this batch's resident state.
    pub fn page_pool(&self) -> &PagePool {
        &self.mem
    }

    /// Lifetime counters of the (possibly shared) plan cache.
    pub fn plan_cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Number of in-flight requests.
    pub fn active(&self) -> usize {
        self.slots.len()
    }

    /// Maximum number of concurrently in-flight requests.
    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    /// Step count of the oldest in-flight request, `None` when the batch
    /// is empty. Historical name: the scheduler used to bucket admissions
    /// by exact step count; the token-budget packer admits mixed step
    /// counts, so this is now diagnostic only.
    pub fn bucket_steps(&self) -> Option<usize> {
        self.slots.first().map(|s| s.req.steps)
    }

    /// Total tokens (text + vision) currently in flight — the quantity
    /// the token-budget packer caps (`FO_TOKEN_BUDGET`).
    pub fn tokens_in_flight(&self) -> usize {
        self.slots.iter().map(|s| s.geo.seq).sum()
    }

    /// Token cost a request would add to the batch if admitted — its
    /// sequence length under this engine's base config plus the request's
    /// `patch_hw` override.
    pub fn token_cost(&self, req: &Request) -> usize {
        req.token_cost(&self.model.cfg)
    }

    /// True when every in-flight slot is about to run a Full (Warmup /
    /// Update) step — i.e. no Dispatch window would be broken by growing
    /// the batch. Trivially true for an empty batch; a slot past its last
    /// step (e.g. a zero-step request awaiting retirement) counts as at
    /// the boundary.
    pub fn at_refresh_boundary(&self) -> bool {
        self.slots.iter().all(|s| s.kinds.get(s.step).is_none_or(|k| !k.is_sparse()))
    }

    /// Capacity *and* boundary check for admission.
    pub fn can_admit(&self) -> bool {
        self.slots.len() < self.max_batch && self.at_refresh_boundary()
    }

    /// Admit a request into the batch. Panics unless [`Self::can_admit`];
    /// the scheduler checks first. `enqueued` is when the request entered
    /// the serving queue (for latency accounting).
    pub fn admit(&mut self, req: Request, enqueued: Instant) {
        assert!(self.slots.len() < self.max_batch, "batch is full");
        assert!(
            self.at_refresh_boundary(),
            "admission is only allowed at refresh boundaries"
        );
        let mut policy = self.policy.clone();
        policy.reset();
        let (warmup, interval) = policy.schedule();
        let kinds = plan_steps(req.steps, warmup.min(req.steps), interval);
        let grid = time_grid(req.steps);
        let order = policy.order();
        let state =
            (0..self.model.cfg.layers).map(|_| LayerState::new_in(order, &self.mem)).collect();
        // Per-request resolution: apply the request's vision-grid override
        // to a copy of the engine config and rederive the tile geometry.
        // Weight-shaping fields are untouched, so the same weights serve
        // every slot; only the sequence length (and plan keys) differ.
        let mut cfg = self.model.cfg.clone();
        if let Some((ph, pw)) = req.patch_hw {
            cfg.patch_h = ph;
            cfg.patch_w = pw;
        }
        let geo = Geometry::from_model(&cfg, self.geo.block_q, self.geo.block_k, self.geo.pool);
        let x = initial_noise(&cfg, req.seed);
        let stats = RunStats { steps: req.steps, ..Default::default() };
        self.slots.push(Slot {
            req,
            cfg,
            geo,
            policy,
            state,
            x,
            kinds,
            grid,
            step: 0,
            stats,
            enqueued,
            admitted: Instant::now(),
            batch_peak: 0,
        });
        let occupancy = self.slots.len();
        for s in &mut self.slots {
            s.batch_peak = s.batch_peak.max(occupancy);
        }
        obs::metrics::REQUESTS_ADMITTED.inc();
    }

    /// Whether a slot takes the batched sparse path at this layer — the
    /// exact complement of the paths `EngineExec::block` would special-case
    /// (Full steps, whole-block forecasts, per-step-mask policies).
    fn batched_eligible(slot: &Slot, layer: usize, kind: StepKind) -> bool {
        if !matches!(kind, StepKind::Dispatch { .. }) {
            return false;
        }
        if slot.policy.per_step_masks() {
            return false;
        }
        let st = &slot.state[layer];
        if st.plans.is_none() {
            return false;
        }
        let block_cached =
            (slot.policy.block_caching() || st.degraded) && st.delta_txt.is_ready();
        !block_cached
    }

    /// Advance every in-flight request by one denoising step and retire
    /// the ones that finished. Per layer, every Dispatch-step slot rides
    /// one **ragged** kernel walk over a concatenated token buffer with
    /// cu-seqlen offsets, each keeping its own compiled plan view (plans
    /// are still shared through the compile cache when symbols + geometry
    /// match); everything else reuses the single-request block executor —
    /// both bitwise-identical per request to a solo run.
    pub fn step_forward(&mut self) -> Vec<BatchResult> {
        let _step_span = Span::enter("engine.step", &obs::metrics::ENGINE_STEP);
        // Already-finished slots (zero-step requests) retire without
        // running a step — matching the solo engine's `generate(steps=0)`
        // semantics, where the image is the unpatchified initial noise.
        let mut finished = self.retire_finished();
        if self.slots.is_empty() {
            return finished;
        }
        obs::metrics::ENGINE_STEPS.inc();
        // One sharing epoch per lockstep step: a hit on an entry another
        // slot compiled earlier in this same step counts as shared
        // (RunStats.plan_cache_shared). The id is allocated by the cache,
        // so concurrent engines sharing it cannot cross-attribute.
        let epoch = self.cache.begin_epoch();
        let layers = self.model.cfg.layers;
        let mem0 = self.mem.stats();

        // ---- Phase A: per-slot embeddings + conditioning. ----
        let mut ctxs: Vec<StepCtx> = Vec::with_capacity(self.slots.len());
        for slot in &self.slots {
            let t = slot.grid[slot.step];
            let (txt, img) =
                self.model.embed_streams_with(&slot.cfg, &slot.req.prompt_ids, &slot.x);
            ctxs.push(StepCtx {
                txt,
                img,
                cvec: self.model.conditioning(t),
                kind: slot.kinds[slot.step],
                density_before: (slot.stats.attn_computed_pairs, slot.stats.attn_total_pairs),
            });
        }

        // ---- Phase B: layer loop — one ragged group per layer. ----
        {
            let BatchedEngine { model, panels, exec, cache, slots, delta_enabled, mem, .. } =
                self;
            let model: &MiniMMDiT = model;
            let exec: &Arc<ExecPool> = exec;
            for layer in 0..layers {
                let bw = &model.w.blocks[layer];
                let mut ragged: Vec<usize> = Vec::new();
                let mut singles: Vec<usize> = Vec::new();
                for (i, slot) in slots.iter().enumerate() {
                    if Self::batched_eligible(slot, layer, ctxs[i].kind) {
                        ragged.push(i);
                    } else {
                        singles.push(i);
                    }
                }
                if ragged.len() >= 2 {
                    sparse_block_ragged(
                        model, &panels[layer], exec, mem, slots, &mut ctxs, &ragged, layer, bw,
                    );
                } else {
                    singles.extend(ragged);
                    singles.sort_unstable();
                }
                for i in singles {
                    let slot = &mut slots[i];
                    let slot_cfg = slot.cfg.clone();
                    let slot_geo = slot.geo;
                    let ctx = &mut ctxs[i];
                    let mut provider = SharedPlanProvider {
                        cache: &*cache,
                        epoch,
                        lane: i as u64,
                        delta: *delta_enabled,
                        mem,
                    };
                    let mut block_exec = EngineExec {
                        policy: &mut slot.policy,
                        geo: slot_geo,
                        state: &mut slot.state,
                        panels,
                        exec,
                        plans: &mut provider,
                        kind: ctx.kind,
                        step: slot.step,
                        stats: &mut slot.stats,
                        mem,
                    };
                    block_exec.block(layer, bw, &slot_cfg, &ctx.cvec, &mut ctx.txt, &mut ctx.img);
                }
            }
        }

        // ---- Phase C: decode, integrate, account, retire. ----
        for (slot, ctx) in self.slots.iter_mut().zip(&ctxs) {
            let v = self.model.decode_with(&slot.cfg, &ctx.cvec, &ctx.img);
            let dt = slot.grid[slot.step] - slot.grid[slot.step + 1];
            euler_step(&mut slot.x, &v, dt);
            let dp = slot.stats.attn_computed_pairs - ctx.density_before.0;
            let dtot = slot.stats.attn_total_pairs - ctx.density_before.1;
            slot.stats.per_step_density.push(if dtot == 0 {
                if ctx.kind.is_sparse() {
                    0.0
                } else {
                    1.0
                }
            } else {
                dp as f64 / dtot as f64
            });
            slot.step += 1;
            // Streaming preview: decode the current latent every K
            // completed steps. `unpatchify` is exactly the retirement
            // decode, so emitting it here (and the final image at retire)
            // makes every preview a bitwise prefix of the final decode.
            if self.preview_interval > 0
                && slot.step < slot.req.steps
                && slot.step % self.preview_interval == 0
            {
                let _sp =
                    Span::enter("request.preview", &obs::metrics::REQUEST_PREVIEW_DECODE);
                self.previews.push(Preview {
                    id: slot.req.id,
                    scene: slot.req.scene,
                    step: slot.step,
                    steps: slot.req.steps,
                    image: unpatchify(&slot.x, &slot.cfg),
                });
                obs::metrics::REQUESTS_PREVIEW.inc();
            }
        }
        // Attribute this step's pool traffic to every in-flight slot (the
        // pool is batch-shared, so each slot experienced the batch-wide
        // footprint), before retiring slots that just finished.
        let mem1 = self.mem.stats();
        for slot in &mut self.slots {
            slot.stats.mem_pages_allocated += mem1.pages_allocated - mem0.pages_allocated;
            slot.stats.mem_pages_evicted += mem1.pages_evicted - mem0.pages_evicted;
            slot.stats.mem_share_hits += mem1.share_hits - mem0.share_hits;
            slot.stats.mem_cow_copies += mem1.cow_copies - mem0.cow_copies;
            slot.stats.mem_peak_pages = slot.stats.mem_peak_pages.max(mem1.peak_resident_pages);
        }
        finished.extend(self.retire_finished());
        finished
    }

    /// Remove every slot that has run all its steps and convert it into a
    /// [`BatchResult`].
    fn retire_finished(&mut self) -> Vec<BatchResult> {
        let _sp = Span::enter("engine.retire", &obs::metrics::ENGINE_RETIRE);
        let mut finished = Vec::new();
        let mut i = 0;
        while i < self.slots.len() {
            if self.slots[i].step >= self.slots[i].req.steps {
                let mut slot = self.slots.remove(i);
                let queue_d = slot.admitted.saturating_duration_since(slot.enqueued);
                let exec_d = slot.admitted.elapsed();
                slot.stats.wall_s = exec_d.as_secs_f64();
                // Lifecycle telemetry: retire counter, queue-wait vs
                // execution histograms, and the per-request trace slices
                // (one row per request id on the request track).
                obs::metrics::REQUESTS_RETIRED.inc();
                obs::metrics::REQUEST_QUEUE_WAIT.observe_ns(queue_d.as_nanos() as u64);
                obs::metrics::REQUEST_EXEC.observe_ns(exec_d.as_nanos() as u64);
                obs::trace::push_request_slice(
                    "request.queue_wait",
                    slot.req.id,
                    slot.enqueued,
                    queue_d,
                );
                obs::trace::push_request_slice("request.exec", slot.req.id, slot.admitted, exec_d);
                finished.push(BatchResult {
                    id: slot.req.id,
                    scene: slot.req.scene,
                    image: unpatchify(&slot.x, &slot.cfg),
                    queue_s: queue_d.as_secs_f64(),
                    exec_s: slot.stats.wall_s,
                    latency_s: (queue_d + exec_d).as_secs_f64(),
                    batch_size: slot.batch_peak,
                    stats: slot.stats,
                });
            } else {
                i += 1;
            }
        }
        finished
    }

    /// Drive the current batch to completion (no further admissions).
    pub fn run_to_completion(&mut self) -> Vec<BatchResult> {
        let mut out = Vec::new();
        while !self.slots.is_empty() {
            out.extend(self.step_forward());
        }
        out
    }
}

/// Copy out row block `idx` (of `rows` rows each) of a row-concatenated
/// tensor — the per-unique split of the deduped text K/V projection.
fn row_block(cat: &Tensor, idx: usize, rows: usize) -> Tensor {
    let d = cat.cols();
    Tensor::from_vec(&[rows, d], cat.data()[idx * rows * d..(idx + 1) * rows * d].to_vec())
}

/// Interleave two stream-major concatenations into joint order: for each
/// request `r`, its text rows (`t_cat[txt_indptr[r]..txt_indptr[r+1]]`)
/// followed by its image rows (`i_cat[img_indptr[r]..img_indptr[r+1]]`) —
/// the ragged equivalent of per-request `vstack(t, i)`.
fn interleave_joint(
    t_cat: &Tensor,
    i_cat: &Tensor,
    txt_indptr: &[usize],
    img_indptr: &[usize],
) -> Tensor {
    let d = t_cat.cols();
    assert_eq!(i_cat.cols(), d);
    let batch = txt_indptr.len() - 1;
    let mut data = Vec::with_capacity((t_cat.rows() + i_cat.rows()) * d);
    for r in 0..batch {
        data.extend_from_slice(&t_cat.data()[txt_indptr[r] * d..txt_indptr[r + 1] * d]);
        data.extend_from_slice(&i_cat.data()[img_indptr[r] * d..img_indptr[r + 1] * d]);
    }
    Tensor::from_vec(&[t_cat.rows() + i_cat.rows(), d], data)
}

/// Ragged sparse path for the group of Dispatch-step slots: every member
/// rides one kernel walk over a concatenated token buffer with cu-seqlen
/// offsets (`indptr`), each keeping its **own** compiled plan view — so
/// mixed resolutions, mixed step counts, and per-request sparsity ride
/// the same GEMM-Q / attention / GEMM-O sweep. All heavy lifting is
/// row-local or request-tiled, so per-request float sequences are
/// identical to the serial kernels and every slot's streams end up
/// bitwise-identical to a solo run.
#[allow(clippy::too_many_arguments)]
fn sparse_block_ragged(
    model: &MiniMMDiT,
    panels: &LayerPanels,
    exec: &Arc<ExecPool>,
    mem: &PagePool,
    slots: &mut [Slot],
    ctxs: &mut [StepCtx],
    group: &[usize],
    layer: usize,
    bw: &BlockWeights,
) {
    let heads = model.cfg.heads;
    let dim = model.cfg.dim;
    let text = model.cfg.text_tokens;
    // The gemm_q.ragged span opens here so plan/indptr gathering is
    // accounted to the projection phase it feeds.
    let sp = Span::enter("gemm_q.ragged", &obs::metrics::KERNEL_GEMM_Q_RAGGED);
    let plans: Vec<Arc<LayerPlans>> = group
        .iter()
        .map(|&i| Arc::clone(slots[i].state[layer].plans.as_ref().unwrap()))
        .collect();
    for &i in group {
        slots[i].stats.total_layer_steps += 1;
        slots[i].stats.flops_dense += DiTEngine::dense_layer_flops(&slots[i].cfg);
    }
    let txt_plans: Vec<&SparsePlan> = plans.iter().map(|p| &p.txt).collect();
    let img_plans: Vec<&SparsePlan> = plans.iter().map(|p| &p.img).collect();
    let joint_plans: Vec<&SparsePlan> = plans.iter().map(|p| &p.joint).collect();

    // Cu-seqlen offsets per stream. Text prefixes are engine-constant
    // (uniform), vision suffixes are ragged.
    let seqs: Vec<usize> = group.iter().map(|&i| slots[i].geo.seq).collect();
    let mut txt_indptr = vec![0usize];
    let mut img_indptr = vec![0usize];
    let mut joint_indptr = vec![0usize];
    for (gi, &s) in seqs.iter().enumerate() {
        txt_indptr.push(txt_indptr[gi] + text);
        img_indptr.push(img_indptr[gi] + (s - text));
        joint_indptr.push(joint_indptr[gi] + s);
    }

    // ---- Phase 0: pre-attention, stacked K/V projection, GEMM-Q. ----
    let p0 = Instant::now();
    let mut pres: Vec<PreAttn> = Vec::with_capacity(group.len());
    for &i in group {
        let ctx = &ctxs[i];
        pres.push(pre_attention(bw, &ctx.cvec, &ctx.txt, &ctx.img));
    }
    let txt_cat = vstack_all(&pres.iter().map(|p| &p.txt_mod).collect::<Vec<_>>());
    let img_cat = vstack_all(&pres.iter().map(|p| &p.img_mod).collect::<Vec<_>>());
    // Text-stream K/V dedupe: `linear` and `headwise_rmsnorm` are
    // row-local, so slots whose modulated text streams are byte-identical
    // (same-prompt requests in lockstep) produce identical text K/V.
    // Project each **distinct** stream once, intern the result in the
    // page pool, and hand duplicates a refcount bump on the same physical
    // block — one copy for the whole batch (prefix sharing). With all
    // streams distinct, `uniq` is the identity in group order, so the
    // projected rows are exactly the ones the plain concatenated GEMM
    // would produce (single code path, bitwise-identical either way).
    let mut uniq: Vec<usize> = Vec::new();
    let mut rep: Vec<usize> = Vec::with_capacity(group.len());
    for (gi, p) in pres.iter().enumerate() {
        match uniq.iter().position(|&u| pres[u].txt_mod == p.txt_mod) {
            Some(pos) => rep.push(pos),
            None => {
                rep.push(uniq.len());
                uniq.push(gi);
            }
        }
    }
    let txt_uniq_cat = vstack_all(&uniq.iter().map(|&u| &pres[u].txt_mod).collect::<Vec<_>>());
    let mut k_t_uniq = linear(&txt_uniq_cat, &bw.txt.wk, &bw.txt.bk);
    let v_t_uniq = linear(&txt_uniq_cat, &bw.txt.wv, &bw.txt.bv);
    headwise_rmsnorm(&mut k_t_uniq, heads, &bw.txt.k_rms);
    let kv_uniq: Vec<(Pooled<Tensor>, Pooled<Tensor>)> = (0..uniq.len())
        .map(|u| {
            let kt = row_block(&k_t_uniq, u, text);
            let vt = row_block(&v_t_uniq, u, text);
            let kh = mem.intern_digest(digest_tensor(b"kvtxt", &kt), tensor_bytes(&kt), kt).0;
            let vh = mem.intern_digest(digest_tensor(b"kvtxt", &vt), tensor_bytes(&vt), vt).0;
            (kh, vh)
        })
        .collect();
    // Per-slot handles: clones are refcount bumps, not byte copies. A
    // batch of B same-prompt slots drives each text K/V block to
    // ref_count == B here.
    let kv_slots: Vec<&(Pooled<Tensor>, Pooled<Tensor>)> =
        rep.iter().map(|&p| &kv_uniq[p]).collect();
    let k_t_cat = vstack_all(&kv_slots.iter().map(|kv| &*kv.0).collect::<Vec<_>>());
    let v_t_cat = vstack_all(&kv_slots.iter().map(|kv| &*kv.1).collect::<Vec<_>>());
    // Stacked image K/V: one GEMM per projection for the whole group
    // instead of a per-request `project_kv_joint` loop (vision suffixes
    // are ragged and seed-distinct, so no dedupe attempt there).
    let mut k_i_cat = linear(&img_cat, &bw.img.wk, &bw.img.bk);
    let v_i_cat = linear(&img_cat, &bw.img.wv, &bw.img.bv);
    headwise_rmsnorm(&mut k_i_cat, heads, &bw.img.k_rms);
    let q_txt =
        gemm_q_ragged(&txt_cat, &txt_indptr, &bw.txt.wq, &txt_plans, Some(&bw.txt.bq), exec);
    let q_img =
        gemm_q_ragged(&img_cat, &img_indptr, &bw.img.wq, &img_plans, Some(&bw.img.bq), exec);
    let mut q_t_cat = vstack_all(&q_txt.iter().map(|(q, _)| q).collect::<Vec<_>>());
    let mut q_i_cat = vstack_all(&q_img.iter().map(|(q, _)| q).collect::<Vec<_>>());
    for (gi, &i) in group.iter().enumerate() {
        let (_, s_t) = &q_txt[gi];
        let (_, s_i) = &q_img[gi];
        slots[i].stats.gq_computed += (s_t.computed_tiles + s_i.computed_tiles) as u64;
        slots[i].stats.gq_total += (s_t.total_tiles + s_i.total_tiles) as u64;
    }
    headwise_rmsnorm(&mut q_t_cat, heads, &bw.txt.q_rms);
    headwise_rmsnorm(&mut q_i_cat, heads, &bw.img.q_rms);
    // Interleave the stream buffers into joint order (txt_r then img_r
    // per request) and rotate once with per-request positions `0..seq_r`
    // — row-local, so identical to each solo `norm_rope_joint_q` /
    // joint-K rope.
    let mut qj_cat = interleave_joint(&q_t_cat, &q_i_cat, &txt_indptr, &img_indptr);
    let mut kj_cat = interleave_joint(&k_t_cat, &k_i_cat, &txt_indptr, &img_indptr);
    let vj_cat = interleave_joint(&v_t_cat, &v_i_cat, &txt_indptr, &img_indptr);
    let positions: Vec<usize> = seqs.iter().flat_map(|&s| 0..s).collect();
    headwise_rope(&mut qj_cat, heads, &positions);
    headwise_rope(&mut kj_cat, heads, &positions);
    let p0_s = p0.elapsed().as_secs_f64();
    drop(sp);

    // ---- Phase 1: attention over batch × heads pool lanes. ----
    let sp = Span::enter("attention.ragged", &obs::metrics::KERNEL_ATTENTION_RAGGED);
    let p1 = Instant::now();
    let per_req =
        flashomni_attention_ragged(&qj_cat, &kj_cat, &vj_cat, &joint_indptr, &joint_plans, exec);
    let mut o_ts: Vec<Tensor> = Vec::with_capacity(group.len());
    let mut o_is: Vec<Tensor> = Vec::with_capacity(group.len());
    for (gi, &i) in group.iter().enumerate() {
        let mut o_cat = Tensor::zeros(&[seqs[gi], dim]);
        for (h, (oh, st)) in per_req[gi].iter().enumerate() {
            slots[i].stats.attn_computed_pairs += st.computed_pairs as u64;
            slots[i].stats.attn_total_pairs += st.total_pairs as u64;
            insert_head(&mut o_cat, oh, heads, h);
        }
        let (o_t, o_i) = vsplit(&o_cat, text);
        o_ts.push(o_t);
        o_is.push(o_i);
    }
    let p1_s = p1.elapsed().as_secs_f64();
    drop(sp);

    // ---- Phase 2: bias combine per request, GEMM-O dispatch ragged. ----
    let sp = Span::enter("gemm_o.ragged", &obs::metrics::KERNEL_GEMM_O_RAGGED);
    let p2 = Instant::now();
    let mut bias_ts: Vec<Tensor> = Vec::with_capacity(group.len());
    let mut bias_is: Vec<Tensor> = Vec::with_capacity(group.len());
    for &i in group {
        let st = &slots[i].state[layer];
        let k_off = match ctxs[i].kind {
            StepKind::Dispatch { k } => k,
            _ => unreachable!("batched path only runs Dispatch steps"),
        };
        let coeffs = st.o_taylor.coefficients(k_off as f64);
        bias_ts.push(if st.bias_txt.is_empty() {
            Tensor::zeros(&[text, dim])
        } else {
            combine_bias_stack(&st.bias_txt, &coeffs)
        });
        bias_is.push(if st.bias_img.is_empty() {
            Tensor::zeros(&[slots[i].cfg.vision_tokens(), dim])
        } else {
            combine_bias_stack(&st.bias_img, &coeffs)
        });
    }
    let o_t_cat = vstack_all(&o_ts.iter().collect::<Vec<_>>());
    let o_i_cat = vstack_all(&o_is.iter().collect::<Vec<_>>());
    let bt_refs: Vec<&Tensor> = bias_ts.iter().collect();
    let bi_refs: Vec<&Tensor> = bias_is.iter().collect();
    let mut out_ts =
        gemm_o_dispatch_ragged(&o_t_cat, &txt_indptr, &panels.txt, &txt_plans, &bt_refs, exec)
            .into_iter();
    let mut out_is =
        gemm_o_dispatch_ragged(&o_i_cat, &img_indptr, &panels.img, &img_plans, &bi_refs, exec)
            .into_iter();
    for (gi, &i) in group.iter().enumerate() {
        let (mut out_t, g_t) = out_ts.next().unwrap();
        let (mut out_i, g_i) = out_is.next().unwrap();
        slots[i].stats.go_computed += (g_t.computed_tiles + g_i.computed_tiles) as u64;
        slots[i].stats.go_total += (g_t.total_tiles + g_i.total_tiles) as u64;
        add_row_bias(&mut out_t, &bw.txt.bo);
        add_row_bias(&mut out_i, &bw.img.bo);
        let o_joint = vstack(&out_t, &out_i);
        let ctx = &mut ctxs[i];
        post_attention_preprojected(&pres[gi], &o_joint, text, &mut ctx.txt, &mut ctx.img);
    }
    let p2_s = p2.elapsed().as_secs_f64();
    drop(sp);

    // ---- Phase 3: per-request MLPs. ----
    let _sp = Span::enter("mlp.ragged", &obs::metrics::KERNEL_MLP_RAGGED);
    let p3 = Instant::now();
    for (gi, &i) in group.iter().enumerate() {
        let ctx = &mut ctxs[i];
        mlp_stream(&bw.txt, &pres[gi].ada_txt, &mut ctx.txt);
        mlp_stream(&bw.img, &pres[gi].ada_img, &mut ctx.img);
    }
    let p3_s = p3.elapsed().as_secs_f64();

    // FLOP + phase accounting per slot, read off its own plan (same
    // numbers the per-request path derives via the same helper). Wall
    // time of the fused group phases is attributed to every member (each
    // experienced it).
    for (gi, &i) in group.iter().enumerate() {
        slots[i].stats.flops_done += sparse_step_flops(&slots[i].cfg, &plans[gi]);
        slots[i].stats.phase_s[0] += p0_s;
        slots[i].stats.phase_s[1] += p1_s;
        slots[i].stats.phase_s[2] += p2_s;
        slots[i].stats.phase_s[3] += p3_s;
    }
}
