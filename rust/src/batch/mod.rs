//! **Batched generation** — the continuous-batching serving layer on top
//! of the plan compiler ([`crate::plan`]) and the shared execution runtime
//! ([`crate::exec`]).
//!
//! FlashOmni's sparse symbols are a pure function of a request's
//! activations per (layer, refresh), and in the serving regimes that
//! matter — repeated prompts, shared-seed bursts, slowly-changing masks —
//! whole batches of requests emit **byte-identical symbol streams**. The
//! single-request engine already deduplicates those through its
//! [`PlanCache`](crate::plan::cache::PlanCache), but each coordinator
//! worker still ran one request per engine step, paying plan lookup, head
//! dispatch, and tile-loop overheads per request. This module amortizes
//! all three across a batch:
//!
//! * [`BatchedEngine`] — advances a group of requests **in lockstep**, one
//!   denoising step per call. Requests may have **different resolutions**
//!   (a per-request `patch_hw` override; weights are resolution-
//!   independent) and different step counts. Each layer partitions the
//!   batch: every Dispatch-step slot rides the **ragged sparse path** —
//!   one walk of `gemm_q_ragged` / `flashomni_attention_ragged` /
//!   `gemm_o_dispatch_ragged` over a concatenated token buffer with
//!   cu-seqlen (`indptr`) offsets, each slot keeping its *own* compiled
//!   [`LayerPlans`](crate::engine::LayerPlans) view (plans still dedupe
//!   through the compile cache when symbols + geometry match); everything
//!   else (Full steps, CachedBlock forecasts, per-step-mask policies)
//!   reuses the single-request block executor verbatim. Either way every
//!   request's output is **bitwise-identical** to a solo [`DiTEngine`]
//!   run (property-tested in `rust/tests/batch_serving.rs` and
//!   `rust/tests/ragged_batching.rs`).
//! * Plan compiles go through a process-shared
//!   [`SharedPlanCache`](crate::plan::cache::SharedPlanCache) with one
//!   sharing *epoch* per lockstep step, so
//!   [`RunStats::plan_cache_shared`](crate::engine::RunStats) proves the
//!   "one plan compile per (layer, refresh) per batch" invariant that
//!   `benches/fig12_batched_serving.rs` measures. Misses whose symbols
//!   row-diff against the slot's previous plan are served by an
//!   **incremental recompile** ([`crate::plan::PlanDelta`] +
//!   [`SparsePlan::apply_delta`](crate::plan::SparsePlan::apply_delta)):
//!   a batch whose masks drift by a few rows between refreshes pays one
//!   delta compile (plus B−1 shared hits) instead of a full compile.
//! * [`BatchScheduler`] — continuous batching over a pending queue:
//!   admission is FIFO under a **total-token budget** (`FO_TOKEN_BUDGET`:
//!   the sum of in-flight sequence lengths; 0 = unbounded, capped only by
//!   `max_batch` slots), late arrivals are admitted only at **refresh
//!   boundaries** (every in-flight slot about to run a Full step, so no
//!   Dispatch window is broken mid-flight), and finished requests retire
//!   without stalling the rest of the batch, returning their tokens to
//!   the budget immediately. Requests may carry an absolute **deadline**:
//!   a pending request past it is dropped at the next tick ([`Expired`],
//!   drained via `take_expired`) before it can consume a batch slot — an
//!   admitted request is never killed mid-refresh.
//! * Streaming **previews** ([`Preview`]): with a nonzero preview
//!   interval, the engine decodes each in-flight latent every K completed
//!   steps. The decode is exactly the retirement decode, so previews are
//!   bitwise prefixes of the final image — the diffusion-native analogue
//!   of token streaming, surfaced per request by the
//!   [`Router`](crate::router::Router).
//!
//! The serving [`Coordinator`](crate::coordinator) feeds each worker's
//! scheduler from the shared request queue and hands every worker one
//! `SharedPlanCache`, so plan compiles are shared across requests *and*
//! across workers. The [`Router`](crate::router::Router) layers admission
//! control (in-flight cap, bounded queue, load shedding, priorities,
//! deadlines) on the same scheduler.
//!
//! [`DiTEngine`]: crate::engine::DiTEngine

#![warn(missing_docs)]

mod engine;
mod scheduler;

pub use engine::{BatchResult, BatchedEngine, Preview};
pub use scheduler::{BatchScheduler, Expired};
