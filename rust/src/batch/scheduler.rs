//! The **continuous-batching scheduler**: a pending queue in front of one
//! [`BatchedEngine`], with token-budget packing and refresh-boundary
//! admission.
//!
//! Admission used to bucket by exact step count; the ragged engine runs
//! mixed step counts and mixed resolutions in one kernel walk, so the
//! packer's only capacity constraint is the **total-token budget**: the
//! sum of in-flight sequence lengths (text + vision tokens per request)
//! must stay within `token_budget` (`FO_TOKEN_BUDGET`, 0 = unbounded —
//! then only the engine's `max_batch` slot count caps the batch). Pending
//! requests are admitted in FIFO order; a front request that does not fit
//! the remaining budget waits until enough in-flight tokens retire
//! (head-of-line discipline — no reordering, no starvation). A request
//! larger than the whole budget is still admitted when the engine is
//! empty, so it runs solo instead of stalling the queue forever.
//!
//! Admission happens only when the engine reports a **refresh boundary**
//! (every in-flight slot about to run a Full step): joining mid-window
//! would leave the newcomer on its dense Warmup steps while the cohort is
//! mid-Dispatch anyway, and boundary alignment maximizes the window in
//! which batch members share plan compiles. Finished requests retire
//! without stalling the rest of the batch, and their tokens return to the
//! budget immediately.

use super::engine::{BatchResult, BatchedEngine, Preview};
use crate::workload::Request;
use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// A queued request waiting for admission.
struct PendingReq {
    req: Request,
    enqueued: Instant,
    /// Absolute deadline; an entry still pending past it retires unserved
    /// (checked every tick, **before** it can consume a batch slot — an
    /// already-admitted request is never killed mid-refresh).
    deadline: Option<Instant>,
}

/// A pending request that retired unserved because its deadline expired
/// before it reached a batch slot.
#[derive(Clone, Debug)]
pub struct Expired {
    /// The request that missed its deadline.
    pub req: Request,
    /// How long it waited in the pending queue before expiring.
    pub waited: Duration,
}

/// Continuous-batching scheduler over one batched engine.
pub struct BatchScheduler {
    engine: BatchedEngine,
    pending: VecDeque<PendingReq>,
    /// Max total in-flight tokens (0 = unbounded).
    token_budget: usize,
    /// Deadline-expired pending requests since the last
    /// [`Self::take_expired`] drain.
    expired: Vec<Expired>,
}

impl BatchScheduler {
    /// Scheduler over one batched engine with an empty pending queue. The
    /// token budget comes from `FO_TOKEN_BUDGET` (unset or 0 = unbounded).
    pub fn new(engine: BatchedEngine) -> Self {
        let budget = std::env::var("FO_TOKEN_BUDGET")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(0);
        Self::with_token_budget(engine, budget)
    }

    /// Scheduler with an explicit token budget (0 = unbounded), ignoring
    /// `FO_TOKEN_BUDGET`.
    pub fn with_token_budget(engine: BatchedEngine, token_budget: usize) -> Self {
        BatchScheduler { engine, pending: VecDeque::new(), token_budget, expired: Vec::new() }
    }

    /// Enqueue a request (enqueue time = now).
    pub fn submit(&mut self, req: Request) {
        crate::obs::metrics::REQUESTS_ENQUEUED.inc();
        self.submit_at(req, Instant::now());
    }

    /// Enqueue a request with an explicit enqueue timestamp (the serving
    /// coordinator passes the time the request entered its shared queue,
    /// so queue-wait accounting spans both queues).
    pub fn submit_at(&mut self, req: Request, enqueued: Instant) {
        self.submit_with_deadline(req, enqueued, None);
    }

    /// Enqueue a request with an explicit enqueue timestamp and an
    /// optional absolute deadline. A pending request past its deadline is
    /// dropped at the next tick — it never consumes a batch slot — and
    /// surfaces through [`Self::take_expired`]; once admitted, a request
    /// always runs to completion (deadlines are claim-time only).
    pub fn submit_with_deadline(
        &mut self,
        req: Request,
        enqueued: Instant,
        deadline: Option<Instant>,
    ) {
        self.pending.push_back(PendingReq { req, enqueued, deadline });
    }

    /// In-flight request count.
    pub fn active(&self) -> usize {
        self.engine.active()
    }

    /// Requests waiting for admission.
    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Nothing in flight and nothing pending.
    pub fn is_idle(&self) -> bool {
        self.engine.active() == 0 && self.pending.is_empty()
    }

    /// Step count of the oldest in-flight request, or of the front pending
    /// request when the engine is empty. Kept for diagnostics; the packer
    /// no longer buckets admissions by it.
    pub fn bucket_steps(&self) -> Option<usize> {
        self.engine.bucket_steps().or_else(|| self.pending.front().map(|p| p.req.steps))
    }

    /// The configured max total in-flight tokens (0 = unbounded).
    pub fn token_budget(&self) -> usize {
        self.token_budget
    }

    /// The engine (plan-cache stats, boundary state, …).
    pub fn engine(&self) -> &BatchedEngine {
        &self.engine
    }

    /// Whether the front pending request fits the remaining token budget.
    /// An oversized request (cost > whole budget) fits an **empty** engine
    /// so it can run solo rather than stall the queue.
    fn front_fits(&self, req: &Request) -> bool {
        if self.token_budget == 0 {
            return true;
        }
        let in_flight = self.engine.tokens_in_flight();
        in_flight + self.engine.token_cost(req) <= self.token_budget || in_flight == 0
    }

    /// Admit pending requests in FIFO order while the engine has slot
    /// capacity, sits at a refresh boundary, and the front request fits
    /// the token budget.
    fn admit_ready(&mut self) {
        while self.engine.can_admit() {
            match self.pending.front() {
                Some(p) if self.front_fits(&p.req) => {
                    let p = self.pending.pop_front().unwrap();
                    self.engine.admit(p.req, p.enqueued);
                }
                _ => break,
            }
        }
    }

    /// Drop every pending request whose deadline has passed (an expired
    /// entry at the *front* of the queue also releases its head-of-line
    /// claim on the token budget, unblocking the requests behind it).
    /// Runs every tick, so expiry is checked before a slot is consumed
    /// and never interrupts an in-flight request.
    fn expire_pending(&mut self) {
        let now = Instant::now();
        let mut kept: VecDeque<PendingReq> = VecDeque::with_capacity(self.pending.len());
        for p in self.pending.drain(..) {
            match p.deadline {
                Some(d) if d <= now => {
                    let waited = now.saturating_duration_since(p.enqueued);
                    crate::obs::metrics::REQUESTS_DEADLINE_MISS.inc();
                    crate::obs::trace::push_request_slice(
                        "request.deadline_miss",
                        p.req.id,
                        p.enqueued,
                        waited,
                    );
                    self.expired.push(Expired { req: p.req, waited });
                }
                _ => kept.push_back(p),
            }
        }
        self.pending = kept;
    }

    /// Drain the pending requests that missed their deadline since the
    /// last call (in expiry order).
    pub fn take_expired(&mut self) -> Vec<Expired> {
        std::mem::take(&mut self.expired)
    }

    /// Drain the streaming previews the engine decoded since the last
    /// call (see [`BatchedEngine::take_previews`]).
    pub fn take_previews(&mut self) -> Vec<Preview> {
        self.engine.take_previews()
    }

    /// One scheduler tick: retire deadline-expired pending requests,
    /// admit what can be admitted, then advance the batch one lockstep
    /// step. Returns the requests that finished.
    pub fn step(&mut self) -> Vec<BatchResult> {
        self.expire_pending();
        self.admit_ready();
        self.engine.step_forward()
    }

    /// Drain everything: tick until no request is in flight or pending.
    pub fn run_to_completion(&mut self) -> Vec<BatchResult> {
        let mut out = Vec::new();
        while !self.is_idle() {
            out.extend(self.step());
        }
        out
    }
}
