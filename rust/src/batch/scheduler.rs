//! The **continuous-batching scheduler**: a pending queue in front of one
//! [`BatchedEngine`], with shape bucketing and refresh-boundary admission.
//!
//! Bucketing: geometry and policy are fixed per engine (every coordinator
//! worker serves one model/policy pair), so the runtime bucket key is the
//! request's **step count** — together with the policy's `(warmup,
//! interval)` schedule it determines the refresh pattern a cohort shares.
//! Pending requests are admitted in FIFO order; a front request whose step
//! count differs from the active cohort waits until the cohort drains
//! (head-of-line discipline, mirroring the coordinator's `claim_batch`),
//! which keeps cohorts homogeneous without reordering.
//!
//! Admission happens only when the engine reports a **refresh boundary**
//! (every in-flight slot about to run a Full step): joining mid-window
//! would leave the newcomer on its dense Warmup steps while the cohort is
//! mid-Dispatch anyway, and boundary alignment maximizes the window in
//! which cohort members share plan compiles. Requests admitted together
//! stay aligned for their whole run; stragglers admitted late simply
//! retire later — retirement never stalls the rest of the batch.

use super::engine::{BatchResult, BatchedEngine};
use crate::trace::Request;
use std::collections::VecDeque;
use std::time::Instant;

/// Continuous-batching scheduler over one batched engine.
pub struct BatchScheduler {
    engine: BatchedEngine,
    pending: VecDeque<(Request, Instant)>,
}

impl BatchScheduler {
    /// Scheduler over one batched engine with an empty pending queue.
    pub fn new(engine: BatchedEngine) -> Self {
        BatchScheduler { engine, pending: VecDeque::new() }
    }

    /// Enqueue a request (enqueue time = now).
    pub fn submit(&mut self, req: Request) {
        self.submit_at(req, Instant::now());
    }

    /// Enqueue a request with an explicit enqueue timestamp (the serving
    /// coordinator passes the time the request entered its shared queue,
    /// so queue-wait accounting spans both queues).
    pub fn submit_at(&mut self, req: Request, enqueued: Instant) {
        self.pending.push_back((req, enqueued));
    }

    /// In-flight request count.
    pub fn active(&self) -> usize {
        self.engine.active()
    }

    /// Requests waiting for admission.
    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Nothing in flight and nothing pending.
    pub fn is_idle(&self) -> bool {
        self.engine.active() == 0 && self.pending.is_empty()
    }

    /// Step count of the active cohort, or of the front pending request
    /// when the engine is empty (the bucket the scheduler will fill next).
    pub fn bucket_steps(&self) -> Option<usize> {
        self.engine.bucket_steps().or_else(|| self.pending.front().map(|(r, _)| r.steps))
    }

    /// The engine (plan-cache stats, boundary state, …).
    pub fn engine(&self) -> &BatchedEngine {
        &self.engine
    }

    /// Admit pending requests while the engine has capacity, is at a
    /// refresh boundary, and the front request matches the active bucket.
    fn admit_ready(&mut self) {
        while self.engine.can_admit() {
            let bucket = self.engine.bucket_steps();
            match self.pending.front() {
                Some((r, _)) if bucket.is_none_or(|b| r.steps == b) => {
                    let (req, enqueued) = self.pending.pop_front().unwrap();
                    self.engine.admit(req, enqueued);
                }
                _ => break,
            }
        }
    }

    /// One scheduler tick: admit what can be admitted, then advance the
    /// batch one lockstep step. Returns the requests that finished.
    pub fn step(&mut self) -> Vec<BatchResult> {
        self.admit_ready();
        self.engine.step_forward()
    }

    /// Drain everything: tick until no request is in flight or pending.
    pub fn run_to_completion(&mut self) -> Vec<BatchResult> {
        let mut out = Vec::new();
        while !self.is_idle() {
            out.extend(self.step());
        }
        out
    }
}
