//! Rectified-flow sampling and the **Update–Dispatch step planner**.
//!
//! The model is trained with the rectified-flow objective
//! `x_t = (1−t)·x₀ + t·ε`, `v* = ε − x₀`, so sampling integrates the ODE
//! `dx/dt = v̂(x, t)` from `t = 1` (noise) to `t = 0` with explicit Euler.
//!
//! The planner realizes §3.2: after `warmup` full steps, every `N`-th step
//! is an *Update* (full attention, symbol + cache refresh) and the `N−1`
//! steps in between are *Dispatch* steps that run the sparse kernels with
//! the symbols produced at the preceding Update.

use crate::config::ModelConfig;
use crate::tensor::Tensor;
use crate::util::rng::Pcg32;

/// Kind of a denoising step in the Update–Dispatch paradigm.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepKind {
    /// Full computation during the warmup prefix (no symbols yet).
    Warmup,
    /// Full computation + symbol/cache refresh.
    Update,
    /// Sparse execution, `k` steps after the last Update (`k ≥ 1`).
    Dispatch { k: usize },
}

impl StepKind {
    pub fn is_sparse(&self) -> bool {
        matches!(self, StepKind::Dispatch { .. })
    }
}

/// Plan the step kinds for a sampling run.
pub fn plan_steps(total: usize, warmup: usize, interval: usize) -> Vec<StepKind> {
    let interval = interval.max(1);
    (0..total)
        .map(|s| {
            if s < warmup {
                StepKind::Warmup
            } else {
                let k = (s - warmup) % interval;
                if k == 0 {
                    StepKind::Update
                } else {
                    StepKind::Dispatch { k }
                }
            }
        })
        .collect()
}

/// Linear rectified-flow time grid from 1 → 0 (`steps + 1` points).
pub fn time_grid(steps: usize) -> Vec<f64> {
    (0..=steps).map(|k| 1.0 - k as f64 / steps as f64).collect()
}

/// Patchify an image `[H × W × C]` into `[num_patches × patch_dim]`
/// (row-major patches, channel-last within a patch).
pub fn patchify(img: &Tensor, cfg: &ModelConfig) -> Tensor {
    let (h, w, c) = (cfg.image_h(), cfg.image_w(), cfg.channels);
    assert_eq!(img.shape(), &[h, w, c]);
    let p = cfg.patch_size;
    let mut out = Tensor::zeros(&[cfg.vision_tokens(), cfg.patch_dim()]);
    for ph in 0..cfg.patch_h {
        for pw in 0..cfg.patch_w {
            let token = ph * cfg.patch_w + pw;
            let dst = out.row_mut(token);
            let mut idx = 0;
            for dy in 0..p {
                for dx in 0..p {
                    for ch in 0..c {
                        dst[idx] = img.data()[((ph * p + dy) * w + (pw * p + dx)) * c + ch];
                        idx += 1;
                    }
                }
            }
        }
    }
    out
}

/// Inverse of [`patchify`].
pub fn unpatchify(patches: &Tensor, cfg: &ModelConfig) -> Tensor {
    let (h, w, c) = (cfg.image_h(), cfg.image_w(), cfg.channels);
    let p = cfg.patch_size;
    assert_eq!(patches.shape(), &[cfg.vision_tokens(), cfg.patch_dim()]);
    let mut img = Tensor::zeros(&[h, w, c]);
    for ph in 0..cfg.patch_h {
        for pw in 0..cfg.patch_w {
            let token = ph * cfg.patch_w + pw;
            let src = patches.row(token);
            let mut idx = 0;
            for dy in 0..p {
                for dx in 0..p {
                    for ch in 0..c {
                        img.data_mut()[((ph * p + dy) * w + (pw * p + dx)) * c + ch] = src[idx];
                        idx += 1;
                    }
                }
            }
        }
    }
    img
}

/// Standard-normal initial latent patches for a given seed.
pub fn initial_noise(cfg: &ModelConfig, seed: u64) -> Tensor {
    let mut rng = Pcg32::seeded(seed);
    Tensor::from_vec(
        &[cfg.vision_tokens(), cfg.patch_dim()],
        rng.normal_vec(cfg.vision_tokens() * cfg.patch_dim()),
    )
}

/// One Euler integration step: `x ← x − v̂ · dt`.
pub fn euler_step(x: &mut Tensor, v: &Tensor, dt: f64) {
    assert_eq!(x.shape(), v.shape());
    let dtf = dt as f32;
    for (xi, &vi) in x.data_mut().iter_mut().zip(v.data()) {
        *xi -= vi * dtf;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_structure() {
        let plan = plan_steps(12, 3, 4);
        assert_eq!(plan.len(), 12);
        assert!(plan[..3].iter().all(|s| *s == StepKind::Warmup));
        assert_eq!(plan[3], StepKind::Update);
        assert_eq!(plan[4], StepKind::Dispatch { k: 1 });
        assert_eq!(plan[6], StepKind::Dispatch { k: 3 });
        assert_eq!(plan[7], StepKind::Update);
    }

    #[test]
    fn plan_interval_one_is_all_updates() {
        let plan = plan_steps(5, 1, 1);
        assert_eq!(plan[0], StepKind::Warmup);
        assert!(plan[1..].iter().all(|s| *s == StepKind::Update));
    }

    #[test]
    fn time_grid_endpoints() {
        let g = time_grid(10);
        assert_eq!(g.len(), 11);
        assert!((g[0] - 1.0).abs() < 1e-12);
        assert!(g[10].abs() < 1e-12);
        assert!(g.windows(2).all(|w| w[0] > w[1]));
    }

    #[test]
    fn patchify_roundtrip() {
        let cfg = crate::config::ModelConfig {
            dim: 16,
            heads: 2,
            layers: 1,
            text_tokens: 2,
            patch_h: 3,
            patch_w: 2,
            patch_size: 2,
            channels: 3,
            mlp_ratio: 2,
            vocab: 4,
        };
        let mut rng = Pcg32::seeded(5);
        let img = crate::testutil::randn(&mut rng, &[cfg.image_h(), cfg.image_w(), 3]);
        let p = patchify(&img, &cfg);
        assert_eq!(p.shape(), &[6, 12]);
        let img2 = unpatchify(&p, &cfg);
        assert_eq!(img, img2);
    }

    #[test]
    fn euler_integrates_linear_field() {
        // dx/dt = 2 → integrating from 1 to 0 reduces x by 2.
        let mut x = Tensor::full(&[4], 5.0);
        let v = Tensor::full(&[4], 2.0);
        let steps = 100;
        for _ in 0..steps {
            euler_step(&mut x, &v, 1.0 / steps as f64);
        }
        for &xi in x.data() {
            assert!((xi - 3.0).abs() < 1e-4);
        }
    }

    #[test]
    fn noise_is_seed_deterministic() {
        let cfg = crate::config::ModelConfig::mini();
        assert_eq!(initial_noise(&cfg, 9), initial_noise(&cfg, 9));
        assert_ne!(
            initial_noise(&cfg, 9).data()[0],
            initial_noise(&cfg, 10).data()[0]
        );
    }
}
