//! PJRT runtime — loads the AOT artifacts produced by `python/compile/aot.py`
//! and executes them on the CPU PJRT client via the `xla` crate.
//!
//! This is the L2/L1 **numerics oracle** path: the same model and Pallas
//! kernels, lowered once at build time to HLO *text* (see aot.py for why
//! text, not serialized protos), compiled here with
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Integration tests in `rust/tests/` assert the native engine reproduces
//! these outputs; the dense PJRT step is also servable through the
//! coordinator as the reference engine.

use crate::tensor::Tensor;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// An input value for an artifact execution.
pub enum Input<'a> {
    /// f32 tensor (any rank; row-major).
    F32(&'a Tensor),
    /// i32 array with explicit shape.
    I32(&'a [i32], &'a [usize]),
    /// f32 scalar.
    Scalar(f32),
}

/// A compiled artifact registry bound to one PJRT client.
pub struct ArtifactRuntime {
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    dir: PathBuf,
}

impl ArtifactRuntime {
    /// Create a CPU PJRT client rooted at an artifacts directory.
    pub fn cpu(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(ArtifactRuntime {
            client,
            executables: HashMap::new(),
            dir: artifacts_dir.as_ref().to_path_buf(),
        })
    }

    /// Platform string (e.g. "cpu") — useful for logs.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile `<dir>/<name>.hlo.txt` under the key `name`.
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact '{name}'"))?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute a loaded artifact. The artifact must have been lowered with
    /// `return_tuple=True`; returns each tuple element as an f32 tensor
    /// with the given output shapes.
    pub fn execute(
        &self,
        name: &str,
        inputs: &[Input<'_>],
        out_shapes: &[&[usize]],
    ) -> Result<Vec<Tensor>> {
        let exe = self
            .executables
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not loaded"))?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|inp| -> Result<xla::Literal> {
                Ok(match inp {
                    Input::F32(t) => {
                        let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
                        xla::Literal::vec1(t.data()).reshape(&dims)?
                    }
                    Input::I32(v, shape) => {
                        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                        xla::Literal::vec1(v).reshape(&dims)?
                    }
                    Input::Scalar(x) => xla::Literal::from(*x),
                })
            })
            .collect::<Result<_>>()?;
        let result = exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?
            .to_tuple()?;
        if result.len() != out_shapes.len() {
            return Err(anyhow!(
                "artifact '{name}' returned {} outputs, expected {}",
                result.len(),
                out_shapes.len()
            ));
        }
        result
            .into_iter()
            .zip(out_shapes)
            .map(|(lit, shape)| {
                let v = lit.to_vec::<f32>()?;
                Ok(Tensor::from_vec(shape, v))
            })
            .collect()
    }

    /// Convenience: run the `mmdit_step` artifact (params in sorted-name
    /// order + ids + patches + t → velocity).
    pub fn mmdit_step(
        &self,
        params: &[Tensor],
        ids: &[i32],
        patches: &Tensor,
        t: f32,
        out_shape: &[usize],
    ) -> Result<Tensor> {
        let mut inputs: Vec<Input<'_>> = params.iter().map(Input::F32).collect();
        let id_shape = [ids.len()];
        inputs.push(Input::I32(ids, &id_shape));
        inputs.push(Input::F32(patches));
        inputs.push(Input::Scalar(t));
        let mut out = self.execute("mmdit_step", &inputs, &[out_shape])?;
        Ok(out.remove(0))
    }
}

/// A full denoising generator running every step through the AOT-compiled
/// PJRT artifact — the L2/L1 oracle **as a servable engine**. Dense only
/// (the lowered HLO is the dense step); used as the reference service and
/// to prove the artifact path composes at L3 (DESIGN.md dual-engine).
pub struct PjRtGenerator {
    rt: ArtifactRuntime,
    params: Vec<Tensor>,
    cfg: crate::config::ModelConfig,
}

impl PjRtGenerator {
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let dir = artifacts_dir.as_ref();
        let mut rt = ArtifactRuntime::cpu(dir)?;
        rt.load("mmdit_step")?;
        let params = load_param_list(dir)?;
        let weights = crate::util::fot::FotFile::load(dir.join("weights.fot"))
            .map_err(anyhow::Error::msg)?;
        let cfg = crate::config::ModelConfig::from_json(
            weights.meta.get("config").ok_or_else(|| anyhow!("weights missing config"))?,
        )
        .map_err(anyhow::Error::msg)?;
        Ok(PjRtGenerator { rt, params, cfg })
    }

    pub fn config(&self) -> &crate::config::ModelConfig {
        &self.cfg
    }

    /// Rectified-flow sampling with every velocity evaluation executed on
    /// the PJRT artifact. Returns the `[H × W × C]` image and wall seconds.
    pub fn generate(&self, text_ids: &[usize], seed: u64, steps: usize) -> Result<(Tensor, f64)> {
        use crate::diffusion::{euler_step, initial_noise, time_grid, unpatchify};
        let ids: Vec<i32> = text_ids.iter().map(|&i| i as i32).collect();
        let mut x = initial_noise(&self.cfg, seed);
        let grid = time_grid(steps);
        let shape = [self.cfg.vision_tokens(), self.cfg.patch_dim()];
        let t0 = std::time::Instant::now();
        for s in 0..steps {
            let v = self.rt.mmdit_step(&self.params, &ids, &x, grid[s] as f32, &shape)?;
            euler_step(&mut x, &v, grid[s] - grid[s + 1]);
        }
        Ok((unpatchify(&x, &self.cfg), t0.elapsed().as_secs_f64()))
    }
}

/// Load the `mmdit_step` parameter list (sorted-name order) from
/// `weights.fot` + `mmdit_step.params.json`.
pub fn load_param_list(artifacts_dir: impl AsRef<Path>) -> Result<Vec<Tensor>> {
    use crate::util::fot::FotFile;
    use crate::util::json::Json;
    let dir = artifacts_dir.as_ref();
    let meta = std::fs::read_to_string(dir.join("mmdit_step.params.json"))
        .context("reading mmdit_step.params.json")?;
    let meta = Json::parse(&meta).map_err(|e| anyhow!(e))?;
    let order = meta
        .req("order")
        .map_err(|e| anyhow!(e))?
        .as_arr()
        .ok_or_else(|| anyhow!("bad order field"))?;
    let weights = FotFile::load(dir.join("weights.fot")).map_err(|e| anyhow!(e))?;
    order
        .iter()
        .map(|name| {
            let name = name.as_str().ok_or_else(|| anyhow!("bad name"))?;
            Tensor::from_fot(&weights, name).map_err(|e| anyhow!(e))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    // PJRT-dependent tests live in rust/tests/pjrt_oracle.rs (integration)
    // so `cargo test --lib` stays fast and artifact-independent.

    #[test]
    fn input_enum_compiles() {
        use super::Input;
        let t = crate::tensor::Tensor::zeros(&[2, 2]);
        let _ = Input::F32(&t);
        let _ = Input::Scalar(1.0);
    }
}
