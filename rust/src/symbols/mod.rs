//! Unified **sparse symbols** (§3.3 of the paper).
//!
//! FlashOmni encodes every sparsity decision into two compact bit-packed
//! 8-bit symbol streams:
//!
//! * `S_c` — *feature-caching* symbols on the **spatial axis**: one bit per
//!   group of `n` consecutive Q blocks. Bit = 1 ⇒ the block's attention
//!   output is computed this step; bit = 0 ⇒ the output is reused from the
//!   feature cache (`OP_reuse`, TaylorSeer).
//! * `S_s` — *block-sparse-skipping* symbols on the **reduction axis**: one
//!   bit per (Q-block-group, KV-block-group) pair. Bit = 1 ⇒ the
//!   `Q_i K_j^T` / `P̃_ij V_j` pair is computed; bit = 0 ⇒ skipped.
//!
//! Bits are packed **big-end first** within each byte to match the paper's
//! Figure 5 example: a caching mask `[1,1,1,0,0]` zero-pads to `0b1110_0000`
//! and is stored as the uint8 `224`.
//!
//! The decode functions of §3.4 are provided both in their naive per-access
//! form (`F`, `J`) and in the register-cached form the paper uses on the
//! GPU: a whole symbol byte (covering 8 groups) is decoded once and reused
//! for the following blocks ([`RowDecoder`]).

mod bits;

pub use bits::BitSymbols;

use crate::util::ceil_div;

/// Sparse symbols for one attention head of one layer.
#[derive(Clone, Debug, PartialEq)]
pub struct HeadSymbols {
    /// Spatial-axis caching symbols (one bit per Q-block group).
    pub s_c: BitSymbols,
    /// Reduction-axis skipping symbols, row-major
    /// `[q_groups × kv_groups]`.
    pub s_s: BitSymbols,
    /// Number of Q-block groups.
    pub q_groups: usize,
    /// Number of KV-block groups.
    pub kv_groups: usize,
    /// Pooling factor `n`: logical blocks per symbol bit.
    pub pool: usize,
}

impl HeadSymbols {
    /// Fully-dense symbols (everything computed).
    pub fn dense(t_q: usize, t_kv: usize, pool: usize) -> Self {
        let q_groups = ceil_div(t_q, pool);
        let kv_groups = ceil_div(t_kv, pool);
        HeadSymbols {
            s_c: BitSymbols::ones(q_groups),
            s_s: BitSymbols::ones(q_groups * kv_groups),
            q_groups,
            kv_groups,
            pool,
        }
    }

    /// Build from logical block masks (`true` = compute). `m_c` has one
    /// entry per Q-block group; `m_s` is row-major `[q_groups][kv_groups]`.
    pub fn from_masks(m_c: &[bool], m_s: &[bool], kv_groups: usize, pool: usize) -> Self {
        assert_eq!(m_s.len(), m_c.len() * kv_groups, "mask shape mismatch");
        HeadSymbols {
            s_c: BitSymbols::from_bits(m_c),
            s_s: BitSymbols::from_bits(m_s),
            q_groups: m_c.len(),
            kv_groups,
            pool,
        }
    }

    /// Spatial-axis decode `F(S_c, i)` for a raw Q-block index `i`
    /// (§3.4: `(S_c >> i/n) & 1`, big-end within bytes).
    #[inline]
    pub fn f(&self, i: usize) -> bool {
        self.s_c.get(i / self.pool)
    }

    /// Reduction-axis decode `J(S_s, i, j)` for raw block indices.
    #[inline]
    pub fn j(&self, i: usize, j: usize) -> bool {
        self.s_s.get((i / self.pool) * self.kv_groups + j / self.pool)
    }

    /// Register-cached decoder for row `i` (raw Q-block index): decodes the
    /// symbol bytes of that row once, so the inner K-loop does no bit math.
    pub fn row_decoder(&self, i: usize) -> RowDecoder<'_> {
        RowDecoder {
            sym: self,
            row: i / self.pool,
            cached_byte: 0,
            cached_base: usize::MAX,
        }
    }

    /// Fraction of Q-block groups that are *cached* (spatial sparsity).
    pub fn cache_sparsity(&self) -> f64 {
        1.0 - self.s_c.count_ones() as f64 / self.q_groups.max(1) as f64
    }

    /// Overall fraction of (Qi, Kj) pairs *not computed*, counting both
    /// cached rows (whole row skipped) and S_s skips on computed rows —
    /// the paper's `skip/total` Sparsity metric.
    pub fn pair_sparsity(&self) -> f64 {
        let total = self.q_groups * self.kv_groups;
        if total == 0 {
            return 0.0;
        }
        let mut computed = 0usize;
        for i in 0..self.q_groups {
            if !self.s_c.get(i) {
                continue; // whole row cached
            }
            for j in 0..self.kv_groups {
                if self.s_s.get(i * self.kv_groups + j) {
                    computed += 1;
                }
            }
        }
        1.0 - computed as f64 / total as f64
    }

    /// Density = fraction of pairs computed (Fig. 7 metric).
    pub fn density(&self) -> f64 {
        1.0 - self.pair_sparsity()
    }

    /// Byte size of the packed symbols (the paper's storage-overhead
    /// argument: 8 blocks per byte).
    pub fn packed_bytes(&self) -> usize {
        self.s_c.bytes().len() + self.s_s.bytes().len()
    }

    /// Restrict to Q-block-group rows `[lo, hi)` — used to hand each
    /// stream (text prefix / vision suffix) of the joint sequence its own
    /// view of the symbols for GEMM-Q / GEMM-O.
    pub fn slice_rows(&self, lo: usize, hi: usize) -> HeadSymbols {
        assert!(lo <= hi && hi <= self.q_groups);
        let m_c: Vec<bool> = (lo..hi).map(|g| self.s_c.get(g)).collect();
        let mut m_s = Vec::with_capacity((hi - lo) * self.kv_groups);
        for g in lo..hi {
            for j in 0..self.kv_groups {
                m_s.push(self.s_s.get(g * self.kv_groups + j));
            }
        }
        HeadSymbols::from_masks(&m_c, &m_s, self.kv_groups, self.pool)
    }
}

/// Random symbols at target sparsities — used by the kernel benches
/// (Figs 6, 8, 10, 11 use "randomly generated sparse symbols", §4.3).
/// `fc` is the fraction of *cached* Q groups; `bss` the fraction of
/// *skipped* KV pairs among computed rows.
pub fn random_symbols(
    rng: &mut crate::util::rng::Pcg32,
    q_groups: usize,
    kv_groups: usize,
    pool: usize,
    fc: f64,
    bss: f64,
) -> HeadSymbols {
    let m_c: Vec<bool> = (0..q_groups).map(|_| rng.f64() >= fc).collect();
    let m_s: Vec<bool> = (0..q_groups * kv_groups).map(|_| rng.f64() >= bss).collect();
    HeadSymbols::from_masks(&m_c, &m_s, kv_groups, pool)
}

/// Decoded-once row view of `S_s` mimicking the paper's register cache:
/// "undecoded bits are processed only once when first encountered, and the
/// results — covering up to 8n consecutive blocks — are stored in registers
/// for subsequent reuse" (§3.4).
pub struct RowDecoder<'a> {
    sym: &'a HeadSymbols,
    row: usize,
    cached_byte: u8,
    cached_base: usize,
}

impl<'a> RowDecoder<'a> {
    /// Decode `J` for raw KV-block index `j`, refreshing the cached byte
    /// only when crossing an 8-group boundary.
    #[inline]
    pub fn j(&mut self, j: usize) -> bool {
        let group = j / self.sym.pool;
        let bit_index = self.row * self.sym.kv_groups + group;
        let base = bit_index / 8;
        if base != self.cached_base {
            self.cached_base = base;
            self.cached_byte = self.sym.s_s.bytes()[base];
        }
        (self.cached_byte >> (7 - bit_index % 8)) & 1 == 1
    }
}

/// Symbols for all heads of one layer.
#[derive(Clone, Debug)]
pub struct LayerSymbols {
    pub heads: Vec<HeadSymbols>,
}

impl LayerSymbols {
    pub fn dense(heads: usize, t_q: usize, t_kv: usize, pool: usize) -> Self {
        LayerSymbols {
            heads: (0..heads).map(|_| HeadSymbols::dense(t_q, t_kv, pool)).collect(),
        }
    }

    /// Row-slice every head (see [`HeadSymbols::slice_rows`]).
    pub fn slice_rows(&self, lo: usize, hi: usize) -> LayerSymbols {
        LayerSymbols { heads: self.heads.iter().map(|h| h.slice_rows(lo, hi)).collect() }
    }

    /// Mean pair-sparsity across heads.
    pub fn pair_sparsity(&self) -> f64 {
        if self.heads.is_empty() {
            return 0.0;
        }
        self.heads.iter().map(|h| h.pair_sparsity()).sum::<f64>() / self.heads.len() as f64
    }

    pub fn cache_sparsity(&self) -> f64 {
        if self.heads.is_empty() {
            return 0.0;
        }
        self.heads.iter().map(|h| h.cache_sparsity()).sum::<f64>() / self.heads.len() as f64
    }

    pub fn density(&self) -> f64 {
        1.0 - self.pair_sparsity()
    }

    /// Total packed symbol bytes for the layer.
    pub fn packed_bytes(&self) -> usize {
        self.heads.iter().map(|h| h.packed_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{prop_check, rand_mask};

    /// The paper's Figure 5 example: caching mask [1,1,1,0,0] → 224.
    #[test]
    fn figure5_encoding() {
        let m_c = [true, true, true, false, false];
        let m_s = vec![true; 5 * 1];
        let h = HeadSymbols::from_masks(&m_c, &m_s, 1, 2);
        assert_eq!(h.s_c.bytes()[0], 0b1110_0000);
        assert_eq!(h.s_c.bytes()[0], 224);
        // M_c[4] = 0 skips blocks 7 and 8 (raw indices with n=2: 8/2=4).
        assert!(!h.f(8));
        assert!(!h.f(9));
        assert!(h.f(0));
        assert!(h.f(5)); // 5/2 = 2 → group 2 = 1
    }

    #[test]
    fn dense_symbols_compute_everything() {
        let h = HeadSymbols::dense(7, 9, 1);
        assert_eq!(h.q_groups, 7);
        assert_eq!(h.kv_groups, 9);
        for i in 0..7 {
            assert!(h.f(i));
            for j in 0..9 {
                assert!(h.j(i, j));
            }
        }
        assert_eq!(h.pair_sparsity(), 0.0);
        assert_eq!(h.density(), 1.0);
    }

    #[test]
    fn pair_sparsity_counts_cached_rows() {
        // 2 q-groups, 2 kv-groups; row 0 cached entirely, row 1 dense.
        let h = HeadSymbols::from_masks(&[false, true], &[true, true, true, true], 2, 1);
        assert_eq!(h.cache_sparsity(), 0.5);
        assert_eq!(h.pair_sparsity(), 0.5);
        // Now additionally skip one pair in the computed row.
        let h = HeadSymbols::from_masks(&[false, true], &[true, true, false, true], 2, 1);
        assert_eq!(h.pair_sparsity(), 0.75);
    }

    #[test]
    fn row_decoder_matches_naive_j() {
        prop_check("row_decoder == J", 50, |rng| {
            let q_groups = 1 + rng.below(20);
            let kv_groups = 1 + rng.below(40);
            let pool = 1 + rng.below(3);
            let m_c = rand_mask(rng, q_groups, 0.6);
            let m_s = rand_mask(rng, q_groups * kv_groups, 0.5);
            let h = HeadSymbols::from_masks(&m_c, &m_s, kv_groups, pool);
            for i in 0..q_groups * pool {
                let mut dec = h.row_decoder(i);
                for j in 0..kv_groups * pool {
                    assert_eq!(dec.j(j), h.j(i, j), "mismatch at ({i},{j})");
                }
            }
        });
    }

    #[test]
    fn packed_size_is_one_bit_per_group() {
        let h = HeadSymbols::dense(64, 64, 1);
        // 64 bits = 8 bytes for s_c; 64*64 bits = 512 bytes for s_s.
        assert_eq!(h.packed_bytes(), 8 + 512);
    }

    #[test]
    fn sparsity_matches_mask_statistics() {
        prop_check("sparsity accounting", 30, |rng| {
            let q = 1 + rng.below(16);
            let kv = 1 + rng.below(16);
            let m_c = rand_mask(rng, q, 0.7);
            let m_s = rand_mask(rng, q * kv, 0.6);
            let h = HeadSymbols::from_masks(&m_c, &m_s, kv, 1);
            // Reference count.
            let mut computed = 0;
            for i in 0..q {
                for j in 0..kv {
                    if m_c[i] && m_s[i * kv + j] {
                        computed += 1;
                    }
                }
            }
            let want = 1.0 - computed as f64 / (q * kv) as f64;
            assert!((h.pair_sparsity() - want).abs() < 1e-12);
        });
    }

    #[test]
    fn layer_aggregation() {
        let l = LayerSymbols::dense(4, 8, 8, 1);
        assert_eq!(l.density(), 1.0);
        assert_eq!(l.packed_bytes(), 4 * (1 + 8));
    }
}
