//! Big-end-first packed bit vector — the raw storage for sparse symbols.

/// A bit vector packed MSB-first into bytes (paper Figure 5 convention:
/// logical index 0 is the most-significant bit of byte 0).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BitSymbols {
    bytes: Vec<u8>,
    nbits: usize,
}

impl BitSymbols {
    /// All-zero (everything cached/skipped) symbols.
    pub fn zeros(nbits: usize) -> Self {
        BitSymbols { bytes: vec![0; nbits.div_ceil(8)], nbits }
    }

    /// All-one (everything computed) symbols.
    pub fn ones(nbits: usize) -> Self {
        let mut s = BitSymbols { bytes: vec![0xff; nbits.div_ceil(8)], nbits };
        s.clear_padding();
        s
    }

    /// Pack a bool slice (`true` = 1).
    pub fn from_bits(bits: &[bool]) -> Self {
        let mut s = Self::zeros(bits.len());
        for (i, &b) in bits.iter().enumerate() {
            if b {
                s.set(i, true);
            }
        }
        s
    }

    /// Wrap raw bytes (e.g. symbols read from a `.fot` file).
    pub fn from_bytes(bytes: Vec<u8>, nbits: usize) -> Self {
        assert!(bytes.len() * 8 >= nbits, "byte buffer too small for {nbits} bits");
        let mut s = BitSymbols { bytes, nbits };
        s.clear_padding();
        s
    }

    fn clear_padding(&mut self) {
        let pad = self.bytes.len() * 8 - self.nbits;
        if pad > 0 {
            let last = self.bytes.len() - 1;
            self.bytes[last] &= 0xffu8 << pad;
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.nbits
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.nbits == 0
    }

    /// Get bit `i` (MSB-first within each byte).
    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.nbits, "bit index {i} out of range {}", self.nbits);
        (self.bytes[i / 8] >> (7 - i % 8)) & 1 == 1
    }

    /// Set bit `i`.
    #[inline]
    pub fn set(&mut self, i: usize, v: bool) {
        debug_assert!(i < self.nbits);
        let mask = 1u8 << (7 - i % 8);
        if v {
            self.bytes[i / 8] |= mask;
        } else {
            self.bytes[i / 8] &= !mask;
        }
    }

    /// Underlying packed bytes.
    pub fn bytes(&self) -> &[u8] {
        &self.bytes
    }

    /// Number of set bits.
    pub fn count_ones(&self) -> usize {
        self.bytes.iter().map(|b| b.count_ones() as usize).sum()
    }

    /// Indices of set bits.
    pub fn ones_idx(&self) -> Vec<usize> {
        (0..self.nbits).filter(|&i| self.get(i)).collect()
    }

    /// Indices of clear bits.
    pub fn zeros_idx(&self) -> Vec<usize> {
        (0..self.nbits).filter(|&i| !self.get(i)).collect()
    }

    /// Unpack to bools.
    pub fn to_bits(&self) -> Vec<bool> {
        (0..self.nbits).map(|i| self.get(i)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn msb_first_packing() {
        let b = BitSymbols::from_bits(&[true, true, true, false, false]);
        assert_eq!(b.bytes(), &[0b1110_0000]);
        assert!(b.get(0) && b.get(2));
        assert!(!b.get(3) && !b.get(4));
    }

    #[test]
    fn set_get_roundtrip() {
        let mut b = BitSymbols::zeros(19);
        b.set(0, true);
        b.set(8, true);
        b.set(18, true);
        assert_eq!(b.count_ones(), 3);
        assert_eq!(b.ones_idx(), vec![0, 8, 18]);
        b.set(8, false);
        assert_eq!(b.count_ones(), 2);
    }

    #[test]
    fn ones_clears_padding() {
        let b = BitSymbols::ones(5);
        assert_eq!(b.bytes(), &[0b1111_1000]);
        assert_eq!(b.count_ones(), 5);
    }

    #[test]
    fn from_bytes_matches_paper_examples() {
        // 224, 235, 197 are the uint8 values in §3.3.
        let b = BitSymbols::from_bytes(vec![224], 5);
        assert_eq!(b.to_bits(), vec![true, true, true, false, false]);
        let b = BitSymbols::from_bytes(vec![235], 8);
        assert_eq!(
            b.to_bits(),
            vec![true, true, true, false, true, false, true, true]
        );
    }

    #[test]
    fn roundtrip_bits() {
        let bits: Vec<bool> = (0..37).map(|i| i % 3 == 0).collect();
        let b = BitSymbols::from_bits(&bits);
        assert_eq!(b.to_bits(), bits);
        assert_eq!(b.zeros_idx().len() + b.count_ones(), 37);
    }
}
