//! **Serving router**: the admission-controlled front-end over the
//! continuous-batching stack ([`BatchScheduler`] per worker, shared plan
//! cache across workers).
//!
//! The [`Coordinator`](crate::coordinator) accepts every request and
//! queues without bound — fine for offline trace replay, wrong for a
//! front-end: under sustained overload an unbounded queue turns into
//! unbounded latency and every request eventually misses its deadline.
//! The router makes overload explicit:
//!
//! * **Admission control** — a non-blocking counting semaphore caps total
//!   in-flight requests (queued + executing, `FO_MAX_IN_FLIGHT`), and a
//!   bounded queue (`FO_QUEUE_CAP`) backpressures on top. A submit that
//!   finds no permit or a full queue is **shed immediately** with
//!   [`Rejected::Overloaded`] — the caller learns in microseconds, not
//!   after its deadline has already passed. Shedding counts into
//!   `fo_request_shed_total`.
//! * **Deadlines** — [`SubmitOptions::deadline`] attaches a relative
//!   deadline. Expiry is enforced at **claim time** (a worker about to
//!   submit an expired job retires it with [`Rejected::DeadlineExceeded`]
//!   before it can consume a batch slot) and every scheduler tick for
//!   jobs waiting in the per-worker pending queue — never mid-refresh: an
//!   admitted request always runs to completion.
//! * **Two priority classes** — [`Priority::Interactive`] jobs are
//!   claimed strictly before [`Priority::Bulk`] jobs (FIFO within each
//!   class). Strict priority is deliberate: bulk work is the offline kind
//!   that tolerates starvation under interactive bursts.
//! * **Streaming previews** — with a nonzero preview interval
//!   (`FO_PREVIEW_INTERVAL`), the engine decodes each in-flight latent
//!   every K denoising steps and the router forwards each decode as a
//!   [`RequestEvent::Preview`] on the submitter's channel. The preview
//!   decode is exactly the retirement decode, so previews are **bitwise
//!   prefixes** of the final image — the diffusion-native analogue of
//!   token streaming (property-tested in `rust/tests/router.rs`).
//!
//! Request lifecycle: `submit` → admit (permit + queue slot) or shed →
//! claimed by a worker (deadline check) → batched execution (previews
//! stream every K steps) → retire ([`RequestEvent::Done`]) — or
//! [`RequestEvent::Rejected`] at any pre-execution stage. Every submitted
//! request receives exactly one terminal event; workers are
//! panic-isolated like the coordinator's (an engine panic rejects the
//! owned requests with [`Rejected::WorkerPanicked`] and the worker
//! rebuilds its engine).
//!
//! [`BatchScheduler`]: crate::batch::BatchScheduler

#![warn(missing_docs)]

use crate::batch::{BatchScheduler, BatchedEngine, Preview};
use crate::coordinator::Response;
use crate::engine::{DiTEngine, LayerPlans};
use crate::plan::cache::SharedPlanCache;
use crate::util::sync::{lock_recover, wait_recover, Semaphore};
use crate::workload::Request;
use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Capacity of the router-wide shared plan cache (mirrors the
/// coordinator's: it serves every worker's refreshes at once).
const ROUTER_PLAN_CACHE_CAP: usize = 256;

/// Why a request was refused or abandoned without a [`Response`].
#[derive(Clone, Debug, PartialEq)]
pub enum Rejected {
    /// Shed at admission: the in-flight cap or the bounded queue was
    /// full. The fields snapshot the load the router saw at that instant.
    Overloaded {
        /// Requests holding an in-flight permit (queued + executing).
        in_flight: usize,
        /// Requests waiting in the router queue.
        queued: usize,
    },
    /// The deadline passed while the request was still queued (checked at
    /// claim time and every scheduler tick — never mid-execution).
    DeadlineExceeded {
        /// Seconds the request waited in queue before expiring.
        waited_s: f64,
    },
    /// The router (or coordinator) was closed before the request could be
    /// accepted.
    Closed,
    /// The worker serving this request panicked mid-batch; the request's
    /// state was lost when the engine was rebuilt.
    WorkerPanicked {
        /// Index of the worker that panicked.
        worker: usize,
        /// The panic payload, if it was a string.
        message: String,
    },
}

impl std::fmt::Display for Rejected {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Rejected::Overloaded { in_flight, queued } => {
                write!(f, "overloaded: {in_flight} in flight, {queued} queued")
            }
            Rejected::DeadlineExceeded { waited_s } => {
                write!(f, "deadline exceeded after {waited_s:.3}s in queue")
            }
            Rejected::Closed => write!(f, "router closed"),
            Rejected::WorkerPanicked { worker, message } => {
                write!(f, "worker {worker} panicked: {message}")
            }
        }
    }
}

impl std::error::Error for Rejected {}

/// Scheduling class for a submitted request.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Priority {
    /// Latency-sensitive: claimed strictly before any bulk job.
    #[default]
    Interactive,
    /// Throughput work: claimed only when no interactive job waits.
    Bulk,
}

/// Per-request submission options.
#[derive(Clone, Copy, Debug, Default)]
pub struct SubmitOptions {
    /// Scheduling class (default [`Priority::Interactive`]).
    pub priority: Priority,
    /// Relative deadline: if the request has not been admitted into a
    /// batch within this duration of submission, it retires with
    /// [`Rejected::DeadlineExceeded`]. `None` = no deadline.
    pub deadline: Option<Duration>,
}

impl SubmitOptions {
    /// Interactive, no deadline.
    pub fn interactive() -> Self {
        SubmitOptions::default()
    }
    /// Bulk, no deadline.
    pub fn bulk() -> Self {
        SubmitOptions { priority: Priority::Bulk, deadline: None }
    }
    /// This options value with a relative deadline attached.
    pub fn with_deadline(mut self, d: Duration) -> Self {
        self.deadline = Some(d);
        self
    }
}

/// An event on a request's streaming channel. Every submitted request
/// sees zero or more `Preview`s followed by exactly one terminal event
/// (`Done` or `Rejected`).
#[derive(Debug)]
pub enum RequestEvent {
    /// An intermediate decode of the request's latent after K more
    /// denoising steps — a bitwise prefix of the final image.
    Preview(Preview),
    /// The request finished; terminal.
    Done(Box<Response>),
    /// The request was refused or abandoned; terminal.
    Rejected(Rejected),
}

/// The submitter's half of a request: its id plus the event channel the
/// serving worker streams into.
pub struct RequestHandle {
    /// The id of the submitted request (as assigned by the caller).
    pub id: u64,
    rx: mpsc::Receiver<RequestEvent>,
}

impl RequestHandle {
    /// Block for the next event, or `None` if the router dropped the
    /// channel without a terminal event (only possible after shutdown).
    pub fn recv(&self) -> Option<RequestEvent> {
        self.rx.recv().ok()
    }

    /// Non-blocking poll for the next event.
    pub fn try_recv(&self) -> Option<RequestEvent> {
        self.rx.try_recv().ok()
    }

    /// Drain the channel to the terminal event: the outcome plus every
    /// preview that streamed before it.
    pub fn wait(self) -> (Result<Response, Rejected>, Vec<Preview>) {
        let mut previews = Vec::new();
        for ev in self.rx.iter() {
            match ev {
                RequestEvent::Preview(p) => previews.push(p),
                RequestEvent::Done(r) => return (Ok(*r), previews),
                RequestEvent::Rejected(rej) => return (Err(rej), previews),
            }
        }
        (Err(Rejected::Closed), previews)
    }
}

/// Router sizing and behavior knobs.
#[derive(Clone, Copy, Debug)]
pub struct RouterConfig {
    /// Worker threads, each driving one [`BatchScheduler`].
    pub workers: usize,
    /// Max batch slots per worker.
    pub max_batch: usize,
    /// Cap on total admitted requests (queued + executing) across the
    /// router; 0 = unbounded. Admission past the cap sheds.
    pub max_in_flight: usize,
    /// Cap on requests waiting in the router queue (the non-executing
    /// part of in-flight); 0 = unbounded. A full queue sheds.
    pub queue_cap: usize,
    /// Emit a streaming preview every K completed denoising steps per
    /// request; 0 = previews off.
    pub preview_interval: usize,
}

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

impl RouterConfig {
    /// Defaults for a given pool shape: in-flight cap of twice the
    /// execution capacity (`2 * workers * max_batch`) so the queue can
    /// hold one full "next batch" per worker, queue cap equal to the
    /// in-flight cap, previews off.
    pub fn new(workers: usize, max_batch: usize) -> Self {
        let cap = 2 * workers.max(1) * max_batch.max(1);
        RouterConfig {
            workers,
            max_batch,
            max_in_flight: cap,
            queue_cap: cap,
            preview_interval: 0,
        }
    }

    /// [`Self::new`] with `FO_MAX_IN_FLIGHT`, `FO_QUEUE_CAP`, and
    /// `FO_PREVIEW_INTERVAL` overriding the corresponding fields.
    pub fn from_env(workers: usize, max_batch: usize) -> Self {
        let base = Self::new(workers, max_batch);
        RouterConfig {
            max_in_flight: env_usize("FO_MAX_IN_FLIGHT", base.max_in_flight),
            queue_cap: env_usize("FO_QUEUE_CAP", base.queue_cap),
            preview_interval: env_usize("FO_PREVIEW_INTERVAL", base.preview_interval),
            ..base
        }
    }
}

/// A queued request plus everything needed to answer it.
struct RoutedJob {
    req: Request,
    enqueued: Instant,
    deadline: Option<Instant>,
    tx: mpsc::Sender<RequestEvent>,
}

/// The two priority queues (strict interactive-over-bulk claiming, FIFO
/// within each class).
#[derive(Default)]
struct Queues {
    interactive: VecDeque<RoutedJob>,
    bulk: VecDeque<RoutedJob>,
}

impl Queues {
    fn len(&self) -> usize {
        self.interactive.len() + self.bulk.len()
    }
    fn is_empty(&self) -> bool {
        self.interactive.is_empty() && self.bulk.is_empty()
    }
    /// Claim up to `room` jobs, interactive strictly first.
    fn claim(&mut self, room: usize) -> Vec<RoutedJob> {
        let take_i = room.min(self.interactive.len());
        let mut out: Vec<RoutedJob> = self.interactive.drain(..take_i).collect();
        let take_b = (room - out.len()).min(self.bulk.len());
        out.extend(self.bulk.drain(..take_b));
        out
    }
}

struct Shared {
    queues: Mutex<Queues>,
    cv: Condvar,
    closed: AtomicBool,
    /// In-flight permits (queued + executing). `try_acquire` at submit —
    /// never blocks; a missing permit sheds.
    permits: Semaphore,
}

fn set_queue_depth(q: &Queues) {
    crate::obs::metrics::ROUTER_QUEUE_DEPTH.set(q.len() as i64);
}

/// Admission-controlled serving front-end: bounded queue + in-flight cap
/// + deadlines + priorities + streaming previews over a pool of
/// continuous-batching workers.
pub struct Router {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    queue_cap: usize,
}

impl Router {
    /// Start the worker pool. Each worker drives a [`BatchScheduler`]
    /// over a batched engine built from `factory`; all workers share one
    /// plan cache, so a plan compiled for any request is reused by every
    /// symbol-identical refresh across the pool.
    pub fn start<F>(factory: F, cfg: RouterConfig) -> Self
    where
        F: Fn(usize) -> DiTEngine + Send + Sync + 'static,
    {
        let permit_cap = if cfg.max_in_flight == 0 { usize::MAX / 2 } else { cfg.max_in_flight };
        let shared = Arc::new(Shared {
            queues: Mutex::new(Queues::default()),
            cv: Condvar::new(),
            closed: AtomicBool::new(false),
            permits: Semaphore::new(permit_cap),
        });
        let factory = Arc::new(factory);
        let plan_cache: SharedPlanCache<LayerPlans> =
            SharedPlanCache::new(ROUTER_PLAN_CACHE_CAP);
        let mut handles = Vec::new();
        for wid in 0..cfg.workers.max(1) {
            let shared = Arc::clone(&shared);
            let factory = Arc::clone(&factory);
            let plan_cache = plan_cache.clone();
            handles.push(std::thread::spawn(move || {
                worker_loop(wid, cfg, shared, factory.as_ref(), plan_cache)
            }));
        }
        Router { shared, handles, queue_cap: cfg.queue_cap }
    }

    /// Requests currently holding an in-flight permit (queued +
    /// executing).
    pub fn in_flight(&self) -> usize {
        self.shared.permits.in_use()
    }

    /// Requests waiting in the router queue.
    pub fn queued(&self) -> usize {
        lock_recover(&self.shared.queues).len()
    }

    /// Submit a request. Returns a [`RequestHandle`] streaming previews
    /// and the terminal outcome, or an immediate rejection:
    /// [`Rejected::Closed`] after [`Self::close`], or
    /// [`Rejected::Overloaded`] when the in-flight cap or the bounded
    /// queue is full (the shed path — counted in
    /// `fo_request_shed_total`, never blocks).
    pub fn submit(&self, req: Request, opts: SubmitOptions) -> Result<RequestHandle, Rejected> {
        if self.shared.closed.load(Ordering::SeqCst) {
            return Err(Rejected::Closed);
        }
        if !self.shared.permits.try_acquire() {
            crate::obs::metrics::REQUESTS_SHED.inc();
            return Err(Rejected::Overloaded {
                in_flight: self.shared.permits.in_use(),
                queued: self.queued(),
            });
        }
        let now = Instant::now();
        let (tx, rx) = mpsc::channel();
        let id = req.id;
        let job = RoutedJob {
            req,
            enqueued: now,
            deadline: opts.deadline.map(|d| now + d),
            tx,
        };
        {
            let mut q = lock_recover(&self.shared.queues);
            if self.queue_cap != 0 && q.len() >= self.queue_cap {
                drop(q);
                self.shared.permits.release();
                crate::obs::metrics::REQUESTS_SHED.inc();
                return Err(Rejected::Overloaded {
                    in_flight: self.shared.permits.in_use(),
                    queued: self.queue_cap,
                });
            }
            match opts.priority {
                Priority::Interactive => q.interactive.push_back(job),
                Priority::Bulk => q.bulk.push_back(job),
            }
            crate::obs::metrics::REQUESTS_ENQUEUED.inc();
            set_queue_depth(&q);
        }
        self.shared.cv.notify_one();
        Ok(RequestHandle { id, rx })
    }

    /// Refuse new submissions and wake every idle worker. Already-queued
    /// requests still drain: a worker only exits once the queue is empty
    /// and its batch has retired, so every accepted request gets its
    /// terminal event.
    pub fn close(&self) {
        {
            let _q = lock_recover(&self.shared.queues);
            self.shared.closed.store(true, Ordering::SeqCst);
        }
        self.shared.cv.notify_all();
    }

    /// Close and join workers (drains already-queued requests first).
    pub fn shutdown(self) {
        drop(self);
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.close();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// One router worker: claim (interactive first) → claim-time deadline
/// check → batched execution with preview/expiry draining → terminal
/// events, with the same panic isolation as the coordinator's workers.
fn worker_loop<F>(
    wid: usize,
    cfg: RouterConfig,
    shared: Arc<Shared>,
    factory: &F,
    plan_cache: SharedPlanCache<LayerPlans>,
) where
    F: Fn(usize) -> DiTEngine,
{
    let make_sched = || {
        let mut engine = BatchedEngine::from_engine(factory(wid), cfg.max_batch);
        engine.set_plan_cache(plan_cache.clone());
        engine.set_preview_interval(cfg.preview_interval);
        BatchScheduler::new(engine)
    };
    let mut sched = make_sched();
    // Event channels for requests this worker has claimed but not yet
    // answered (the set rejected on a panic).
    let mut owned: HashMap<u64, mpsc::Sender<RequestEvent>> = HashMap::new();
    loop {
        // Acquire work: block only when fully idle (close() notifies all
        // waiters under the queue lock — no lost-wakeup window); with a
        // running batch, top up without blocking.
        let jobs: Vec<RoutedJob> = {
            let mut q = lock_recover(&shared.queues);
            while q.is_empty() && sched.is_idle() {
                if shared.closed.load(Ordering::SeqCst) {
                    return;
                }
                q = wait_recover(&shared.cv, q);
            }
            let room = if sched.is_idle() {
                cfg.max_batch
            } else {
                cfg.max_batch.saturating_sub(sched.active() + sched.pending_len())
            };
            let jobs = q.claim(room);
            set_queue_depth(&q);
            jobs
        };
        // Claim-time deadline check: an expired job retires here, before
        // it can consume a batch slot.
        let now = Instant::now();
        let mut live: Vec<RoutedJob> = Vec::with_capacity(jobs.len());
        for job in jobs {
            match job.deadline {
                Some(d) if d <= now => {
                    let waited = now.saturating_duration_since(job.enqueued);
                    crate::obs::metrics::REQUESTS_DEADLINE_MISS.inc();
                    crate::obs::trace::push_request_slice(
                        "request.deadline_miss",
                        job.req.id,
                        job.enqueued,
                        waited,
                    );
                    let _ = job.tx.send(RequestEvent::Rejected(Rejected::DeadlineExceeded {
                        waited_s: waited.as_secs_f64(),
                    }));
                    shared.permits.release();
                }
                _ => live.push(job),
            }
        }
        // Submit + one lockstep step, panic-isolated.
        let stepped = catch_unwind(AssertUnwindSafe(|| {
            for job in live {
                owned.insert(job.req.id, job.tx);
                sched.submit_with_deadline(job.req, job.enqueued, job.deadline);
            }
            sched.step()
        }));
        match stepped {
            Ok(results) => {
                // Previews first: a preview always precedes its request's
                // terminal event on the channel.
                for p in sched.take_previews() {
                    if let Some(tx) = owned.get(&p.id) {
                        let _ = tx.send(RequestEvent::Preview(p));
                    }
                }
                for e in sched.take_expired() {
                    // The scheduler already counted the miss; the router
                    // answers the channel and returns the permit.
                    if let Some(tx) = owned.remove(&e.req.id) {
                        let _ = tx.send(RequestEvent::Rejected(Rejected::DeadlineExceeded {
                            waited_s: e.waited.as_secs_f64(),
                        }));
                    }
                    shared.permits.release();
                }
                for r in results {
                    let id = r.id;
                    if let Some(tx) = owned.remove(&id) {
                        let _ = tx.send(RequestEvent::Done(Box::new(Response {
                            id: r.id,
                            scene: r.scene,
                            image: r.image,
                            stats: r.stats,
                            queue_s: r.queue_s,
                            exec_s: r.exec_s,
                            latency_s: r.latency_s,
                            worker: wid,
                            batch_size: r.batch_size,
                        })));
                    }
                    shared.permits.release();
                }
            }
            Err(payload) => {
                let message = crate::coordinator::panic_message(payload.as_ref());
                for (_, tx) in owned.drain() {
                    let _ = tx.send(RequestEvent::Rejected(Rejected::WorkerPanicked {
                        worker: wid,
                        message: message.clone(),
                    }));
                    shared.permits.release();
                }
                sched = make_sched();
            }
        }
    }
}
