//! Quality + efficiency metrics for the evaluation harness.
//!
//! The paper reports PSNR / SSIM / LPIPS / FID / CLIP-IQA on images and
//! VBench dimensions on videos, plus TOPS and Sparsity for efficiency.
//! Proprietary-network metrics are replaced by deterministic random-feature
//! proxies (DESIGN.md substitution table):
//!
//! * **RPIPS** — LPIPS stand-in: L2 distance between unit-normalized
//!   activations of a fixed-seed random conv pyramid (3 scales × 8
//!   channels).
//! * **rFID** — FID stand-in: Fréchet distance between Gaussians fitted to
//!   fixed random-projection features of each image set.
//! * **IQA-proxy** — CLIP-IQA stand-in: sharpness/contrast/colorfulness
//!   statistic mapped to (0, 1).
//! * video proxies — smoothness, consistency, flicker, style (Gram), same
//!   spirit as the VBench dimensions the paper quotes.
//!
//! Metric *orderings* between methods are the reproduction target, not the
//! absolute values.

use crate::tensor::Tensor;
use crate::util::rng::Pcg32;

/// Peak-signal-to-noise ratio for images in [-1, 1] (peak = 2).
pub fn psnr(a: &Tensor, b: &Tensor) -> f64 {
    assert_eq!(a.shape(), b.shape());
    let mse: f64 = a
        .data()
        .iter()
        .zip(b.data())
        .map(|(x, y)| ((x - y) * (x - y)) as f64)
        .sum::<f64>()
        / a.numel() as f64;
    if mse == 0.0 {
        return f64::INFINITY;
    }
    10.0 * (4.0 / mse).log10()
}

/// Mean SSIM over 8×8 windows (stride 4), luminance-style on each channel.
pub fn ssim(a: &Tensor, b: &Tensor) -> f64 {
    assert_eq!(a.shape(), b.shape());
    let (h, w, c) = (a.shape()[0], a.shape()[1], a.shape()[2]);
    let win = 8usize.min(h).min(w);
    let stride = (win / 2).max(1);
    let (c1, c2) = (0.01f64 * 2.0, 0.03f64 * 2.0);
    let (c1, c2) = (c1 * c1, c2 * c2);
    let mut total = 0.0;
    let mut count = 0usize;
    let mut y = 0;
    while y + win <= h {
        let mut x = 0;
        while x + win <= w {
            for ch in 0..c {
                let (mut ma, mut mb) = (0.0f64, 0.0f64);
                for dy in 0..win {
                    for dx in 0..win {
                        ma += a.data()[((y + dy) * w + x + dx) * c + ch] as f64;
                        mb += b.data()[((y + dy) * w + x + dx) * c + ch] as f64;
                    }
                }
                let n = (win * win) as f64;
                ma /= n;
                mb /= n;
                let (mut va, mut vb, mut cov) = (0.0f64, 0.0f64, 0.0f64);
                for dy in 0..win {
                    for dx in 0..win {
                        let pa = a.data()[((y + dy) * w + x + dx) * c + ch] as f64 - ma;
                        let pb = b.data()[((y + dy) * w + x + dx) * c + ch] as f64 - mb;
                        va += pa * pa;
                        vb += pb * pb;
                        cov += pa * pb;
                    }
                }
                va /= n - 1.0;
                vb /= n - 1.0;
                cov /= n - 1.0;
                total += ((2.0 * ma * mb + c1) * (2.0 * cov + c2))
                    / ((ma * ma + mb * mb + c1) * (va + vb + c2));
                count += 1;
            }
            x += stride;
        }
        y += stride;
    }
    total / count.max(1) as f64
}

/// Fixed random conv filter bank (seeded) for RPIPS / style features.
fn conv_bank(in_c: usize, out_c: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::seeded(seed);
    let n = out_c * in_c * 9;
    let scale = (2.0 / (in_c as f32 * 9.0)).sqrt();
    (0..n).map(|_| rng.normal() * scale).collect()
}

/// 3×3 conv (stride 1, pad 1) + ReLU.
fn conv3x3_relu(img: &Tensor, filt: &[f32], out_c: usize) -> Tensor {
    let (h, w, c) = (img.shape()[0], img.shape()[1], img.shape()[2]);
    let mut out = Tensor::zeros(&[h, w, out_c]);
    for y in 0..h {
        for x in 0..w {
            for oc in 0..out_c {
                let mut s = 0.0f32;
                for dy in 0..3usize {
                    for dx in 0..3usize {
                        let yy = y as isize + dy as isize - 1;
                        let xx = x as isize + dx as isize - 1;
                        if yy < 0 || xx < 0 || yy >= h as isize || xx >= w as isize {
                            continue;
                        }
                        for ic in 0..c {
                            s += img.data()[(yy as usize * w + xx as usize) * c + ic]
                                * filt[((oc * c + ic) * 3 + dy) * 3 + dx];
                        }
                    }
                }
                out.data_mut()[(y * w + x) * out_c + oc] = s.max(0.0);
            }
        }
    }
    out
}

/// 2× average-pool.
fn avgpool2(img: &Tensor) -> Tensor {
    let (h, w, c) = (img.shape()[0], img.shape()[1], img.shape()[2]);
    let (oh, ow) = (h / 2, w / 2);
    let mut out = Tensor::zeros(&[oh, ow, c]);
    for y in 0..oh {
        for x in 0..ow {
            for ch in 0..c {
                let mut s = 0.0;
                for dy in 0..2 {
                    for dx in 0..2 {
                        s += img.data()[((2 * y + dy) * w + 2 * x + dx) * c + ch];
                    }
                }
                out.data_mut()[(y * ow + x) * c + ch] = s / 4.0;
            }
        }
    }
    out
}

/// Random-feature pyramid (3 scales × 8 channels), unit-normalized per
/// position. Shared by RPIPS and the style metric.
pub fn feature_pyramid(img: &Tensor) -> Vec<Tensor> {
    const OUT_C: usize = 8;
    let mut feats = Vec::new();
    let mut cur = img.clone();
    for level in 0..3 {
        let filt = conv_bank(cur.shape()[2], OUT_C, 0xfeed_0000 + level as u64);
        let mut f = conv3x3_relu(&cur, &filt, OUT_C);
        // Unit-normalize each spatial position's channel vector.
        let (h, w, c) = (f.shape()[0], f.shape()[1], f.shape()[2]);
        for p in 0..h * w {
            let seg = &mut f.data_mut()[p * c..(p + 1) * c];
            let norm = (seg.iter().map(|v| v * v).sum::<f32>() + 1e-10).sqrt();
            for v in seg.iter_mut() {
                *v /= norm;
            }
        }
        feats.push(f.clone());
        if level < 2 {
            cur = avgpool2(&f);
        }
    }
    feats
}

/// RPIPS — random perceptual distance (LPIPS proxy, lower = closer).
pub fn rpips(a: &Tensor, b: &Tensor) -> f64 {
    let fa = feature_pyramid(a);
    let fb = feature_pyramid(b);
    let mut total = 0.0;
    for (x, y) in fa.iter().zip(&fb) {
        let mut s = 0.0f64;
        for (u, v) in x.data().iter().zip(y.data()) {
            s += ((u - v) * (u - v)) as f64;
        }
        total += s / (x.shape()[0] * x.shape()[1]) as f64;
    }
    total / fa.len() as f64
}

/// Random-projection image features for rFID (fixed seed, 16-D).
fn fid_features(img: &Tensor) -> Vec<f64> {
    const D: usize = 16;
    // Downsample to 6×6×C via average pooling, flatten, project.
    let mut cur = img.clone();
    while cur.shape()[0] > 6 && cur.shape()[0] % 2 == 0 {
        cur = avgpool2(&cur);
    }
    let flat = cur.data();
    let mut rng = Pcg32::seeded(0xf1d0);
    let proj: Vec<f32> = (0..flat.len() * D).map(|_| rng.normal()).collect();
    (0..D)
        .map(|j| {
            flat.iter()
                .enumerate()
                .map(|(i, &v)| (v * proj[i * D + j]) as f64)
                .sum::<f64>()
                / (flat.len() as f64).sqrt()
        })
        .collect()
}

/// rFID — Fréchet distance between diagonal Gaussians fitted to the two
/// image sets' random-projection features (FID proxy, lower = closer).
pub fn rfid(set_a: &[Tensor], set_b: &[Tensor]) -> f64 {
    assert!(!set_a.is_empty() && !set_b.is_empty());
    let fa: Vec<Vec<f64>> = set_a.iter().map(fid_features).collect();
    let fb: Vec<Vec<f64>> = set_b.iter().map(fid_features).collect();
    let d = fa[0].len();
    let stats = |fs: &[Vec<f64>]| -> (Vec<f64>, Vec<f64>) {
        let n = fs.len() as f64;
        let mu: Vec<f64> = (0..d).map(|j| fs.iter().map(|f| f[j]).sum::<f64>() / n).collect();
        let var: Vec<f64> = (0..d)
            .map(|j| fs.iter().map(|f| (f[j] - mu[j]).powi(2)).sum::<f64>() / n.max(2.0))
            .collect();
        (mu, var)
    };
    let (mu_a, var_a) = stats(&fa);
    let (mu_b, var_b) = stats(&fb);
    let mut fid = 0.0;
    for j in 0..d {
        fid += (mu_a[j] - mu_b[j]).powi(2)
            + var_a[j]
            + var_b[j]
            - 2.0 * (var_a[j] * var_b[j]).sqrt();
    }
    fid
}

/// CLIP-IQA proxy: sharpness (gradient energy) + contrast + colorfulness,
/// squashed to (0, 1).
pub fn iqa_proxy(img: &Tensor) -> f64 {
    let (h, w, c) = (img.shape()[0], img.shape()[1], img.shape()[2]);
    let mut grad = 0.0f64;
    for y in 0..h - 1 {
        for x in 0..w - 1 {
            for ch in 0..c {
                let v = img.data()[(y * w + x) * c + ch];
                let vx = img.data()[(y * w + x + 1) * c + ch];
                let vy = img.data()[((y + 1) * w + x) * c + ch];
                grad += (((vx - v).abs() + (vy - v).abs()) / 2.0) as f64;
            }
        }
    }
    grad /= ((h - 1) * (w - 1) * c) as f64;
    let mean: f64 = img.data().iter().map(|&v| v as f64).sum::<f64>() / img.numel() as f64;
    let var: f64 =
        img.data().iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / img.numel() as f64;
    let score = 2.0 * grad + var.sqrt();
    score / (1.0 + score)
}

// ------------------------------------------------------------- video --

/// Motion-smoothness proxy: 1 − mean |f_{t+1} − f_t| / 2 (higher = smoother),
/// scaled ×100 like VBench.
pub fn smoothness(frames: &[Tensor]) -> f64 {
    if frames.len() < 2 {
        return 100.0;
    }
    let mut acc = 0.0;
    for wpair in frames.windows(2) {
        let d: f64 = wpair[0]
            .data()
            .iter()
            .zip(wpair[1].data())
            .map(|(a, b)| ((a - b).abs() / 2.0) as f64)
            .sum::<f64>()
            / wpair[0].numel() as f64;
        acc += d;
    }
    100.0 * (1.0 - acc / (frames.len() - 1) as f64)
}

/// Background-consistency proxy: mean correlation of border pixels across
/// frames (×100).
pub fn consistency(frames: &[Tensor]) -> f64 {
    if frames.len() < 2 {
        return 100.0;
    }
    let border = |img: &Tensor| -> Vec<f32> {
        let (h, w, c) = (img.shape()[0], img.shape()[1], img.shape()[2]);
        let mut v = Vec::new();
        for x in 0..w {
            for ch in 0..c {
                v.push(img.data()[x * c + ch]);
                v.push(img.data()[((h - 1) * w + x) * c + ch]);
            }
        }
        for y in 0..h {
            for ch in 0..c {
                v.push(img.data()[(y * w) * c + ch]);
                v.push(img.data()[(y * w + w - 1) * c + ch]);
            }
        }
        v
    };
    let corr = |a: &[f32], b: &[f32]| -> f64 {
        let n = a.len() as f64;
        let ma = a.iter().map(|&x| x as f64).sum::<f64>() / n;
        let mb = b.iter().map(|&x| x as f64).sum::<f64>() / n;
        let (mut num, mut da, mut db) = (0.0, 0.0, 0.0);
        for (x, y) in a.iter().zip(b) {
            num += (*x as f64 - ma) * (*y as f64 - mb);
            da += (*x as f64 - ma).powi(2);
            db += (*y as f64 - mb).powi(2);
        }
        num / (da.sqrt() * db.sqrt() + 1e-12)
    };
    let b0 = border(&frames[0]);
    let mut acc = 0.0;
    for f in &frames[1..] {
        acc += corr(&b0, &border(f));
    }
    100.0 * (acc / (frames.len() - 1) as f64).clamp(0.0, 1.0)
}

/// Temporal-flicker proxy: 100 × (1 − high-frequency energy of the mean
/// intensity across frames).
pub fn flicker(frames: &[Tensor]) -> f64 {
    if frames.len() < 3 {
        return 100.0;
    }
    let means: Vec<f64> = frames
        .iter()
        .map(|f| f.data().iter().map(|&v| v as f64).sum::<f64>() / f.numel() as f64)
        .collect();
    let mut hf = 0.0;
    for w in means.windows(3) {
        hf += (w[0] - 2.0 * w[1] + w[2]).abs();
    }
    hf /= (means.len() - 2) as f64;
    100.0 * (1.0 - hf.min(1.0))
}

/// Style-coherence proxy: mean cosine similarity of Gram matrices of the
/// level-0 random features between consecutive frames (0–1 scale, like the
/// paper's ~0.24 "Style" column it is only comparable within a table).
pub fn style(frames: &[Tensor]) -> f64 {
    if frames.len() < 2 {
        return 1.0;
    }
    let gram = |img: &Tensor| -> Vec<f64> {
        let f = &feature_pyramid(img)[0];
        let (h, w, c) = (f.shape()[0], f.shape()[1], f.shape()[2]);
        let mut g = vec![0.0f64; c * c];
        for p in 0..h * w {
            for i in 0..c {
                for j in 0..c {
                    g[i * c + j] +=
                        (f.data()[p * c + i] * f.data()[p * c + j]) as f64;
                }
            }
        }
        let n = (h * w) as f64;
        g.iter_mut().for_each(|v| *v /= n);
        g
    };
    let cos = |a: &[f64], b: &[f64]| -> f64 {
        let num: f64 = a.iter().zip(b).map(|(x, y)| x * y).sum();
        let da: f64 = a.iter().map(|x| x * x).sum::<f64>().sqrt();
        let db: f64 = b.iter().map(|x| x * x).sum::<f64>().sqrt();
        num / (da * db + 1e-12)
    };
    let grams: Vec<Vec<f64>> = frames.iter().map(gram).collect();
    let mut acc = 0.0;
    for w in grams.windows(2) {
        acc += cos(&w[0], &w[1]);
    }
    // Scale to the paper's ~0.24 magnitude band for table familiarity.
    0.25 * acc / (frames.len() - 1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::randn;
    use crate::util::rng::Pcg32;

    fn img(seed: u64) -> Tensor {
        let mut rng = Pcg32::seeded(seed);
        let mut t = randn(&mut rng, &[24, 24, 3]);
        for v in t.data_mut() {
            *v = v.clamp(-1.0, 1.0);
        }
        t
    }

    #[test]
    fn psnr_identity_and_ordering() {
        let a = img(1);
        assert!(psnr(&a, &a).is_infinite());
        let mut near = a.clone();
        near.data_mut()[0] += 0.05;
        let far = img(2);
        assert!(psnr(&a, &near) > psnr(&a, &far));
    }

    #[test]
    fn ssim_bounds_and_identity() {
        let a = img(3);
        let s = ssim(&a, &a);
        assert!((s - 1.0).abs() < 1e-9, "{s}");
        let s2 = ssim(&a, &img(4));
        assert!(s2 < s && s2 > -1.0);
    }

    #[test]
    fn rpips_identity_zero_and_ordering() {
        let a = img(5);
        assert!(rpips(&a, &a) < 1e-12);
        let mut near = a.clone();
        for v in near.data_mut().iter_mut().take(20) {
            *v += 0.02;
        }
        assert!(rpips(&a, &near) < rpips(&a, &img(6)));
    }

    #[test]
    fn rfid_same_set_near_zero() {
        let set: Vec<Tensor> = (0..6).map(img).collect();
        let f = rfid(&set, &set);
        assert!(f.abs() < 1e-9, "{f}");
        let other: Vec<Tensor> = (10..16).map(img).collect();
        assert!(rfid(&set, &other) > f);
    }

    #[test]
    fn iqa_in_unit_interval() {
        let v = iqa_proxy(&img(7));
        assert!(v > 0.0 && v < 1.0);
    }

    #[test]
    fn video_metrics_identical_frames() {
        let f = img(8);
        let frames = vec![f.clone(), f.clone(), f.clone(), f];
        assert!((smoothness(&frames) - 100.0).abs() < 1e-9);
        assert!(consistency(&frames) > 99.0);
        assert!((flicker(&frames) - 100.0).abs() < 1e-9);
        assert!(style(&frames) > 0.2);
    }

    #[test]
    fn video_metrics_penalize_noise() {
        let frames: Vec<Tensor> = (0..4).map(|i| img(20 + i)).collect();
        let f0 = img(8);
        let stable = vec![f0.clone(), f0.clone(), f0.clone(), f0];
        assert!(smoothness(&frames) < smoothness(&stable));
        assert!(flicker(&frames) <= flicker(&stable));
    }
}
