//! The FlashOmni **Update–Dispatch execution engine** (§3.2, Figure 4),
//! organized as a **symbols → plan → kernels** pipeline.
//!
//! [`DiTEngine`] drives a full denoising run of the MiniMMDiT model under a
//! sparsity [`Policy`]. The division of labour is:
//!
//! 1. **Policies emit symbols.** At every refresh point a [`Policy`]
//!    produces logical masks from the fresh per-head Q/K, which are packed
//!    into the paper's unified bit symbols (`S_c`/`S_s`,
//!    [`crate::symbols`]).
//! 2. **The engine compiles symbols into plans — through a cache.** The
//!    bit streams are decoded exactly once into a [`SparsePlan`] per layer
//!    ([`crate::plan`]): CSR live-block index lists for the joint sequence
//!    plus row-sliced views for the text and vision streams. Plans are
//!    **reused across every Dispatch step** of the Update window, and a
//!    [`PlanCache`] keyed by the packed symbol bytes + geometry
//!    ([`crate::plan::cache`]) skips recompilation entirely when a refresh
//!    re-emits unchanged symbols (repeated prompts, slowly-changing
//!    masks); hit/miss counts surface in [`RunStats`]. When a refresh
//!    *misses* the cache but differs from the layer's previous symbols in
//!    only a few rows, the engine **delta-compiles**: it diffs the packed
//!    bytes against the held plan's key ([`PlanDelta`](crate::plan::PlanDelta))
//!    and rebuilds only the changed row-groups via
//!    [`SparsePlan::apply_delta`], structurally sharing the rest —
//!    counted in [`RunStats::plan_cache_delta`].
//! 3. **Kernels consume plans on the shared execution runtime.** GEMM-Q,
//!    the FlashOmni attention kernel, and GEMM-O all iterate only live
//!    indices; attention heads and GEMM tile loops run on the persistent
//!    [`ExecPool`] ([`crate::exec`]) — no per-step thread spawn, and the
//!    pool-backed outputs are bitwise-identical to the serial kernels. All
//!    tile/pair statistics are derived from the plan (one source of truth
//!    for `metrics/` and `report/`).
//!
//! Per layer and step the engine takes one of three paths:
//!
//! * **Full** (Warmup / Update): dense QKV + attention; the policy refreshes
//!   the symbols, the engine recompiles the plans; the joint attention
//!   output is pushed into the layer's TaylorSeer cache; the GEMM-O
//!   stage-1 pass projects every finite difference of the cached tiles
//!   into the bias stacks `B_c` (Eq. 4 linearity).
//! * **Sparse** (Dispatch): GEMM-Q skips cached `(block, head)` tiles, the
//!   FlashOmni attention kernel executes Algorithm 1 with real skipping,
//!   and GEMM-O initializes its output from the Taylor-combined bias and
//!   projects only the computed tiles — all driven by the compiled plans.
//! * **CachedBlock** (degraded layer / whole-block caching policies): the
//!   entire block update is forecast from the cached residual deltas.
//!
//! Every baseline in the paper's tables is a [`Policy`] emitting symbols
//! into this same engine — the reproduction of the paper's "unified engine"
//! claim.

pub mod policy;

use crate::cache::{combine_bias_stack, TaylorCache};
use crate::config::ModelConfig;
use crate::diffusion::{euler_step, initial_noise, plan_steps, time_grid, unpatchify, StepKind};
use crate::exec::ExecPool;
use crate::kernels::attention::flashomni_attention;
use crate::kernels::flops;
use crate::kernels::gemm_o::{
    gemm_o_dispatch_pool, gemm_o_stage1_pool, gemm_o_update_pool, WeightPanels,
};
use crate::kernels::gemm_q::gemm_q_pool;
use crate::model::blocks::{
    self, extract_head, insert_head, linear, mlp_stream, post_attention, pre_attention,
    qkv_joint, vsplit, vstack,
};
use crate::mem::{digest_tensor, tensor_bytes, PagePool, Pooled, PooledBytes};
use crate::model::{BlockExec, BlockWeights, MiniMMDiT};
use crate::obs::{self, Span};
use crate::plan::cache::{symbol_key, CacheOutcome, CacheStats, Compiled, PlanCache};
use crate::plan::{AttnStats, DecodeMode, PlanDelta, SparsePlan};
use crate::symbols::LayerSymbols;
use crate::tensor::Tensor;
use crate::util::ceil_div;
use std::sync::Arc;
pub use policy::{Policy, PolicyKind};

/// Block/pool geometry shared by the whole run.
#[derive(Clone, Copy, Debug)]
pub struct Geometry {
    pub block_q: usize,
    pub block_k: usize,
    pub pool: usize,
    pub text_tokens: usize,
    pub seq: usize,
}

impl Geometry {
    pub fn from_model(cfg: &ModelConfig, block_q: usize, block_k: usize, pool: usize) -> Self {
        let g = Geometry { block_q, block_k, pool, text_tokens: cfg.text_tokens, seq: cfg.seq_len() };
        if cfg.text_tokens > 0 {
            assert_eq!(
                cfg.text_tokens % (block_q * pool),
                0,
                "text prefix must align to Q block groups"
            );
        }
        g
    }
    pub fn t_q(&self) -> usize {
        self.seq.div_ceil(self.block_q)
    }
    pub fn t_kv(&self) -> usize {
        self.seq.div_ceil(self.block_k)
    }
    pub fn q_groups(&self) -> usize {
        self.t_q().div_ceil(self.pool)
    }
    pub fn kv_groups(&self) -> usize {
        self.t_kv().div_ceil(self.pool)
    }
    /// Symbol groups covering the text prefix. 0-safe ceil-div: a
    /// text-free (pure-image) config yields 0 groups instead of relying on
    /// exact divisibility.
    pub fn text_groups(&self) -> usize {
        ceil_div(self.text_tokens, self.block_q * self.pool)
    }
    /// Raw Q blocks covering the text prefix (plan-slicing boundary).
    pub fn text_blocks(&self) -> usize {
        ceil_div(self.text_tokens, self.block_q)
    }
}

/// Aggregated run statistics (FLOP accounting + densities + wall time).
#[derive(Clone, Debug, Default)]
pub struct RunStats {
    pub steps: usize,
    pub wall_s: f64,
    /// Attention block pairs.
    pub attn_computed_pairs: u64,
    pub attn_total_pairs: u64,
    /// GEMM-Q / GEMM-O tiles.
    pub gq_computed: u64,
    pub gq_total: u64,
    pub go_computed: u64,
    pub go_total: u64,
    /// Layer-steps fully served from the block cache.
    pub cached_layer_steps: u64,
    pub total_layer_steps: u64,
    /// Plan-cache outcomes of this run's symbol refreshes: a hit means a
    /// refresh re-emitted byte-identical symbols and skipped recompilation.
    pub plan_cache_hits: u64,
    pub plan_cache_misses: u64,
    /// Batched serving: refreshes served by a plan that **another request
    /// in the same lockstep batch step** compiled (counted inside
    /// `plan_cache_hits` too). For a batch of B symbol-identical requests
    /// every (layer, refresh) costs exactly 1 miss + (B−1) shared hits —
    /// the "one plan compile per (layer, refresh) per batch" invariant the
    /// fig12 bench verifies. Always 0 on the single-request engine.
    pub plan_cache_shared: u64,
    /// Cache misses served by an **incremental recompile**: the refresh's
    /// symbols differed from the layer's previous plan in a few rows, so
    /// only those row-groups were re-decoded
    /// ([`SparsePlan::apply_delta`]) and the rest structurally shared.
    /// Counted inside `plan_cache_misses` too (a delta compile is still a
    /// key miss). 0 when delta compilation is disabled
    /// ([`DiTEngine::set_delta_compile`]).
    pub plan_cache_delta: u64,
    /// Paged-pool traffic attributed to this run: pages freshly allocated
    /// from the engine's [`PagePool`] while the run was in flight. On the
    /// batched engine the pool is shared by the whole batch, so each
    /// in-flight slot is attributed the batch-wide per-step delta (every
    /// slot experienced that resident footprint).
    pub mem_pages_allocated: u64,
    /// Pages evicted under `FO_PAGE_BUDGET` pressure during the run.
    pub mem_pages_evicted: u64,
    /// Prefix-share hits during the run: allocations served by an
    /// existing content-identical block (refcount bump, one physical
    /// copy). A batch of B symbol-identical requests drives this up by
    /// B−1 per interned quantity.
    pub mem_share_hits: u64,
    /// Copy-on-write copies during the run (writes to shared blocks).
    pub mem_cow_copies: u64,
    /// Pool-wide peak resident pages observed by the end of the run
    /// (bounded by `FO_PAGE_BUDGET` + live pages; see `[mem]`).
    pub mem_peak_pages: u64,
    /// Per-step mean attention density (Fig. 7).
    pub per_step_density: Vec<f64>,
    /// FLOPs actually executed vs the dense equivalent.
    pub flops_done: f64,
    pub flops_dense: f64,
    /// Coarse phase timings `[qkv, attention, proj, mlp/other]` (seconds).
    pub phase_s: [f64; 4],
}

impl RunStats {
    /// The paper's Sparsity metric over attention block pairs.
    pub fn attn_sparsity(&self) -> f64 {
        if self.attn_total_pairs == 0 {
            return 0.0;
        }
        1.0 - self.attn_computed_pairs as f64 / self.attn_total_pairs as f64
    }
    /// FLOP-level speedup proxy (dense / done).
    pub fn flop_speedup(&self) -> f64 {
        if self.flops_done <= 0.0 {
            return 1.0;
        }
        self.flops_dense / self.flops_done
    }
    /// TOPS (standard-attention ops over wall time, §4.1 definition applied
    /// to the whole-model dense FLOP count).
    pub fn tops(&self) -> f64 {
        flops::tops(self.flops_dense, self.wall_s.max(1e-12))
    }
}

/// Result of one generation.
#[derive(Clone, Debug)]
pub struct GenResult {
    /// `[H × W × C]` image (rectified-flow x₀ estimate).
    pub image: Tensor,
    pub stats: RunStats,
}

/// Plans compiled once per symbol refresh and reused, untouched, across
/// every Dispatch step of the Update window. Public because the batched
/// serving layer ([`crate::batch`]) shares these bundles across requests
/// through a process-wide [`SharedPlanCache`](crate::plan::cache::SharedPlanCache).
pub struct LayerPlans {
    /// Joint-sequence plan driving the attention kernel.
    pub joint: SparsePlan,
    /// Row slice covering the text prefix (GEMM-Q / GEMM-O, text stream).
    pub txt: SparsePlan,
    /// Row slice covering the vision suffix (GEMM-Q / GEMM-O, image stream).
    pub img: SparsePlan,
    /// The plan-cache key ([`LayerPlans::cache_key`]) this set was compiled
    /// under — the packed symbol bytes + geometry an incoming refresh is
    /// diffed against for an incremental recompile ([`LayerPlans::delta_from`]).
    /// Pool-interned: this handle, the `PlanCache` map key, and its FIFO
    /// entry are refcount bumps on **one** physical byte allocation.
    pub key: PooledBytes,
}

/// Number of geometry parameters in a plan-cache key (the prefix
/// [`PlanDelta::between`] verifies before diffing symbol bytes).
const PLAN_KEY_GEOMETRY_PARAMS: usize = 5;

/// Cache key for a layer's symbol refresh: packed symbol bytes + every
/// geometry parameter the compiled plan set depends on (the text/vision
/// split changes the per-stream slices even for identical joint symbols).
pub(crate) fn plan_key(syms: &LayerSymbols, geo: &Geometry) -> Vec<u8> {
    symbol_key(
        syms,
        &[geo.t_q(), geo.t_kv(), geo.block_q, geo.block_k, geo.text_blocks()],
    )
}

/// Intern a plan-cache key's bytes into `mem` (the `b"plankey"`
/// namespace the [`PlanCache`] interns under, so standalone compiles and
/// cache-driven compiles share key blocks when they share a pool).
fn intern_plan_key(syms: &LayerSymbols, geo: &Geometry, mem: &PagePool) -> PooledBytes {
    mem.intern_bytes(b"plankey", &plan_key(syms, geo)).0
}

/// Decode the layer's symbols exactly once into the plan set every sparse
/// kernel of the layer consumes (symbols → plan compile step). Row-group
/// segments are allocated in `mem`.
pub(crate) fn compile_plans(
    syms: &LayerSymbols,
    geo: &Geometry,
    key: PooledBytes,
    mem: &PagePool,
) -> LayerPlans {
    let joint = SparsePlan::compile_in(
        syms,
        geo.t_q(),
        geo.t_kv(),
        geo.block_q,
        geo.block_k,
        DecodeMode::RowCached,
        mem,
    );
    let tb = geo.text_blocks();
    LayerPlans { txt: joint.slice_q(0, tb), img: joint.slice_q(tb, geo.t_q()), joint, key }
}

/// Incremental recompile of a whole plan set: apply the delta to the
/// joint plan and to both row-slice plans, sharing every unchanged
/// segment with `base`. The slices delta-compile straight off the joint
/// symbols at a row-group offset (no sliced symbol copies), and a slice
/// whose delta is empty reuses the base slice outright.
fn apply_layer_delta(
    base: &LayerPlans,
    delta: &PlanDelta,
    syms: &LayerSymbols,
    geo: &Geometry,
    key: PooledBytes,
) -> LayerPlans {
    let tbg = geo.text_groups();
    let qg = geo.q_groups();
    let joint = base.joint.apply_delta(delta, syms, DecodeMode::RowCached);
    let txt_delta = delta.slice_groups(0, tbg);
    let txt = if txt_delta.is_empty() {
        base.txt.clone()
    } else {
        base.txt.apply_delta_at(&txt_delta, syms, 0, DecodeMode::RowCached)
    };
    let img_delta = delta.slice_groups(tbg, qg);
    let img = if img_delta.is_empty() {
        base.img.clone()
    } else {
        base.img.apply_delta_at(&img_delta, syms, tbg, DecodeMode::RowCached)
    };
    LayerPlans { joint, txt, img, key }
}

/// Build a plan set for a refresh: delta-compile off `base` when the keys
/// are row-diffable, else compile from scratch. The providers pass the
/// already-computed cache key in, so it is never recomputed.
pub(crate) fn build_plans(
    syms: &LayerSymbols,
    geo: &Geometry,
    key: PooledBytes,
    base: Option<&LayerPlans>,
    mem: &PagePool,
) -> Compiled<LayerPlans> {
    if let Some(b) = base {
        if let Some(delta) = PlanDelta::between(&b.key, &key, syms, PLAN_KEY_GEOMETRY_PARAMS) {
            let _sp = Span::enter("plan.compile_delta", &obs::metrics::PLAN_COMPILE_DELTA);
            return Compiled::Delta(apply_layer_delta(b, &delta, syms, geo, key));
        }
    }
    let _sp = Span::enter("plan.compile_full", &obs::metrics::PLAN_COMPILE_FULL);
    Compiled::Full(compile_plans(syms, geo, key, mem))
}

impl LayerPlans {
    /// The plan-cache key for a layer's symbols under `geo`: the packed
    /// `S_c`/`S_s` bytes plus every geometry parameter the compiled set
    /// depends on. Two refreshes collide iff their plans are identical by
    /// construction.
    pub fn cache_key(syms: &LayerSymbols, geo: &Geometry) -> Vec<u8> {
        plan_key(syms, geo)
    }

    /// Compile a layer's symbols from scratch into the joint plan plus the
    /// text/vision row slices (what the engine does on a plan-cache miss
    /// with no delta base). Segments and key live in the global pool.
    pub fn compile(syms: &LayerSymbols, geo: &Geometry) -> LayerPlans {
        let mem = PagePool::global();
        compile_plans(syms, geo, intern_plan_key(syms, geo, mem), mem)
    }

    /// Incremental recompile: diff `syms` against `base`'s key and rebuild
    /// only the changed row-groups of all three plans, structurally
    /// sharing the rest. `None` when the refreshes are not row-diffable
    /// (geometry changed) — fall back to [`LayerPlans::compile`]. The
    /// result is logically identical to a from-scratch compile
    /// (property-tested in `rust/tests/plan_delta.rs`).
    pub fn delta_from(
        base: &LayerPlans,
        syms: &LayerSymbols,
        geo: &Geometry,
    ) -> Option<LayerPlans> {
        let key = plan_key(syms, geo);
        let delta = PlanDelta::between(&base.key, &key, syms, PLAN_KEY_GEOMETRY_PARAMS)?;
        let key = base.key.pool().intern_bytes(b"plankey", &key).0;
        Some(apply_layer_delta(base, &delta, syms, geo, key))
    }
}

/// Per-layer mutable state across the denoising run (`pub(crate)`: the
/// batched engine keeps one of these vectors per in-flight request).
pub(crate) struct LayerState {
    /// Compiled sparse plans (None until the policy first emits symbols).
    /// Shared with the plan cache: Dispatch steps keep the window's plan
    /// alive even if the cache evicts it.
    pub(crate) plans: Option<Arc<LayerPlans>>,
    /// TaylorSeer stack over the joint attention output `O_cat`.
    pub(crate) o_taylor: TaylorCache,
    /// Projected bias stacks per stream (one pool block per Taylor
    /// order, content-interned so symbol-identical requests share one
    /// physical copy per entry).
    pub(crate) bias_txt: Vec<Pooled<Tensor>>,
    pub(crate) bias_img: Vec<Pooled<Tensor>>,
    /// Whole-block residual-delta caches (degradation + caching baselines).
    pub(crate) delta_txt: TaylorCache,
    pub(crate) delta_img: TaylorCache,
    /// This Update window degenerated to full-layer caching (`S_q`).
    pub(crate) degraded: bool,
    pub(crate) last_update_step: Option<usize>,
}

impl LayerState {
    /// Per-layer state whose caches allocate from `mem`.
    pub(crate) fn new_in(order: usize, mem: &PagePool) -> Self {
        LayerState {
            plans: None,
            o_taylor: TaylorCache::new_in(order, mem),
            bias_txt: Vec::new(),
            bias_img: Vec::new(),
            delta_txt: TaylorCache::new_in(order, mem),
            delta_img: TaylorCache::new_in(order, mem),
            degraded: false,
            last_update_step: None,
        }
    }
}

/// Pre-built output-projection panels per layer.
pub(crate) struct LayerPanels {
    pub(crate) txt: WeightPanels,
    pub(crate) img: WeightPanels,
}

impl LayerPanels {
    /// Build the per-layer panel set for a model (pure function of the
    /// weights — engines and the batched engine build identical sets).
    pub(crate) fn for_model(model: &MiniMMDiT) -> Vec<LayerPanels> {
        let heads = model.cfg.heads;
        model
            .w
            .blocks
            .iter()
            .map(|b| LayerPanels {
                txt: WeightPanels::new(&b.txt.wo, heads),
                img: WeightPanels::new(&b.img.wo, heads),
            })
            .collect()
    }
}

/// Default number of compiled plan sets the engine keeps per process
/// lifetime (per engine). Each entry is one layer refresh — big enough for
/// repeated prompts across every layer, small enough to bound memory under
/// per-step-mask policies that emit fresh symbols every Dispatch step.
pub(crate) const PLAN_CACHE_CAP: usize = 64;

/// Source of compiled plans for a symbol refresh. Abstracting the cache
/// lets the same block-execution code ([`EngineExec`]) run against the
/// single-request engine's private [`PlanCache`] *and* the batched
/// engine's process-shared
/// [`SharedPlanCache`](crate::plan::cache::SharedPlanCache).
pub(crate) trait PlanProvider {
    /// Symbols → compiled plan set, through whatever cache the provider
    /// wraps. `base` is the layer's previous plan set (if any): on a cache
    /// miss the provider may delta-compile off it instead of compiling
    /// from scratch. Returns the plans plus the cache outcome for
    /// accounting.
    fn plans_for(
        &mut self,
        syms: &LayerSymbols,
        geo: &Geometry,
        base: Option<&LayerPlans>,
    ) -> (Arc<LayerPlans>, CacheOutcome);
}

/// [`PlanProvider`] over the engine's own (single-threaded) cache.
pub(crate) struct LocalPlanProvider<'c> {
    pub(crate) cache: &'c mut PlanCache<LayerPlans>,
    /// Delta compilation on a miss (true unless disabled for A/B tests).
    pub(crate) delta: bool,
    /// Pool compiled segments are allocated in.
    pub(crate) mem: &'c PagePool,
}

impl PlanProvider for LocalPlanProvider<'_> {
    fn plans_for(
        &mut self,
        syms: &LayerSymbols,
        geo: &Geometry,
        base: Option<&LayerPlans>,
    ) -> (Arc<LayerPlans>, CacheOutcome) {
        let key = plan_key(syms, geo);
        let base = if self.delta { base } else { None };
        let mem = self.mem;
        self.cache
            .get_or_build_keyed(&key, 0, 0, |pk| build_plans(syms, geo, pk.clone(), base, mem))
    }
}

/// The engine: model + policy + per-layer state.
pub struct DiTEngine {
    pub model: MiniMMDiT,
    pub policy: Policy,
    pub geo: Geometry,
    state: Vec<LayerState>,
    panels: Vec<LayerPanels>,
    /// Shared execution pool every sparse kernel of this engine runs on.
    /// Defaults to [`ExecPool::global`], so coordinator workers share one
    /// thread set instead of oversubscribing worker×head scoped threads.
    exec: Arc<ExecPool>,
    /// Symbols → compiled-plan cache, persistent across `generate` calls
    /// (repeated prompts skip every recompilation).
    plan_cache: PlanCache<LayerPlans>,
    /// Delta-compile refreshes that miss the cache but row-diff against
    /// the layer's previous plan (on by default).
    delta_enabled: bool,
    /// Paged pool backing this engine's resident state: TaylorSeer
    /// stacks, bias stacks, plan segments, and plan-cache keys. Defaults
    /// to [`PagePool::global`] (which reads `FO_PAGE_BUDGET`).
    mem: PagePool,
}

impl DiTEngine {
    pub fn new(model: MiniMMDiT, policy: Policy, block_q: usize, block_k: usize) -> Self {
        Self::with_pool(model, policy, block_q, block_k, 1)
    }

    /// Engine with an explicit symbol pooling factor `n` (§3.3: one symbol
    /// bit covers `n` consecutive blocks, shrinking symbol storage and
    /// decode work by `n×` at the cost of coarser sparsity decisions).
    pub fn with_pool(
        model: MiniMMDiT,
        policy: Policy,
        block_q: usize,
        block_k: usize,
        pool: usize,
    ) -> Self {
        let geo = Geometry::from_model(&model.cfg, block_q, block_k, pool);
        let order = policy.order();
        let panels = LayerPanels::for_model(&model);
        let mem = PagePool::global().clone();
        let state = (0..model.cfg.layers).map(|_| LayerState::new_in(order, &mem)).collect();
        DiTEngine {
            model,
            policy,
            geo,
            state,
            panels,
            exec: ExecPool::global(),
            plan_cache: PlanCache::new_in(PLAN_CACHE_CAP, &mem),
            delta_enabled: true,
            mem,
        }
    }

    /// Decompose into the pieces the batched engine reuses — model,
    /// policy, geometry, prebuilt projection panels, exec pool — without
    /// re-cloning weights or re-gathering panels.
    #[allow(clippy::type_complexity)]
    pub(crate) fn into_batch_parts(
        self,
    ) -> (MiniMMDiT, Policy, Geometry, Vec<LayerPanels>, Arc<ExecPool>, PagePool) {
        (self.model, self.policy, self.geo, self.panels, self.exec, self.mem)
    }

    /// Swap the execution pool (tests exercise pool-size determinism; the
    /// serving layer can hand every worker engine one shared pool).
    pub fn set_exec_pool(&mut self, pool: Arc<ExecPool>) {
        self.exec = pool;
    }

    /// The pool this engine dispatches kernels on.
    pub fn exec_pool(&self) -> &Arc<ExecPool> {
        &self.exec
    }

    /// Lifetime plan-cache counters (hits/misses/evictions/deltas).
    pub fn plan_cache_stats(&self) -> CacheStats {
        self.plan_cache.stats()
    }

    /// Swap the paged pool backing this engine's resident state (private
    /// budgets in tests and benches). Resets per-layer caches and the
    /// plan cache so every block lives in the new pool.
    pub fn set_page_pool(&mut self, mem: &PagePool) {
        self.mem = mem.clone();
        self.plan_cache = PlanCache::new_in(PLAN_CACHE_CAP, mem);
        self.reset();
    }

    /// The paged pool backing this engine's resident state.
    pub fn page_pool(&self) -> &PagePool {
        &self.mem
    }

    /// Enable/disable incremental plan recompiles (on by default). With
    /// delta off, every cache miss compiles from scratch — outputs are
    /// identical either way (the delta path is property-tested bitwise
    /// against full compiles); the switch exists for A/B tests and the
    /// fig13 bench.
    pub fn set_delta_compile(&mut self, on: bool) {
        self.delta_enabled = on;
    }

    /// Reset all per-request state (symbol + cache history). The plan
    /// cache is deliberately **kept**: cross-request reuse is its point.
    pub fn reset(&mut self) {
        let order = self.policy.order();
        for s in self.state.iter_mut() {
            *s = LayerState::new_in(order, &self.mem);
        }
        self.policy.reset();
    }

    /// Run a full denoising generation.
    pub fn generate(&mut self, text_ids: &[usize], seed: u64, steps: usize) -> GenResult {
        self.reset();
        let (warmup, interval) = self.policy.schedule();
        let plan = plan_steps(steps, warmup.min(steps), interval);
        let grid = time_grid(steps);
        let mut x = initial_noise(&self.model.cfg, seed);
        let mut stats = RunStats { steps, ..Default::default() };
        let mem0 = self.mem.stats();
        let t0 = std::time::Instant::now();
        for (step, kind) in plan.iter().enumerate() {
            let t = grid[step];
            let dt = grid[step] - grid[step + 1];
            let density_before = (stats.attn_computed_pairs, stats.attn_total_pairs);
            let v = self.step_forward(text_ids, &x, t, *kind, step, &mut stats);
            euler_step(&mut x, &v, dt);
            let dp = stats.attn_computed_pairs - density_before.0;
            let dtot = stats.attn_total_pairs - density_before.1;
            // A step whose layers were all served from caches contributes
            // zero pairs → density 0 (Fig. 7 convention).
            stats.per_step_density.push(if dtot == 0 {
                if kind.is_sparse() {
                    0.0
                } else {
                    1.0
                }
            } else {
                dp as f64 / dtot as f64
            });
        }
        stats.wall_s = t0.elapsed().as_secs_f64();
        let mem1 = self.mem.stats();
        stats.mem_pages_allocated = mem1.pages_allocated - mem0.pages_allocated;
        stats.mem_pages_evicted = mem1.pages_evicted - mem0.pages_evicted;
        stats.mem_share_hits = mem1.share_hits - mem0.share_hits;
        stats.mem_cow_copies = mem1.cow_copies - mem0.cow_copies;
        stats.mem_peak_pages = mem1.peak_resident_pages;
        GenResult { image: unpatchify(&x, &self.model.cfg), stats }
    }

    /// One engine-driven forward pass of the model (a single denoising
    /// step under the Update–Dispatch plan). Public so custom samplers
    /// (editing task, report harness) can drive the engine directly.
    pub fn step_forward(
        &mut self,
        text_ids: &[usize],
        x: &Tensor,
        t: f64,
        kind: StepKind,
        step: usize,
        stats: &mut RunStats,
    ) -> Tensor {
        let _step_span = Span::enter("engine.step", &obs::metrics::ENGINE_STEP);
        obs::metrics::ENGINE_STEPS.inc();
        let DiTEngine { model, policy, geo, state, panels, exec, plan_cache, delta_enabled, mem } =
            self;
        let mut plans = LocalPlanProvider { cache: plan_cache, delta: *delta_enabled, mem };
        let mut block_exec = EngineExec {
            policy,
            geo: *geo,
            state,
            panels,
            exec,
            plans: &mut plans,
            kind,
            step,
            stats,
            mem,
        };
        model.forward_with(&mut block_exec, text_ids, x, t)
    }

    /// Dense-equivalent FLOPs of one transformer layer step (used for the
    /// normalized TOPS in Tables 1–2).
    pub fn dense_layer_flops(cfg: &ModelConfig) -> f64 {
        let n = cfg.seq_len() as f64;
        let d = cfg.dim as f64;
        let m = (cfg.mlp_ratio * cfg.dim) as f64;
        // QKV (3) + O-proj (1) + MLP (2 linears of width m) + attention.
        (4.0 * 2.0 * n * d * d) + (2.0 * 2.0 * n * d * m) + (4.0 * n * n * d)
    }
}

/// Per-step block executor implementing the three execution paths
/// (`pub(crate)`: the batched engine builds one per (request, step) to
/// reuse the Full / CachedBlock / per-request sparse paths verbatim).
pub(crate) struct EngineExec<'a> {
    pub(crate) policy: &'a mut Policy,
    pub(crate) geo: Geometry,
    pub(crate) state: &'a mut [LayerState],
    pub(crate) panels: &'a [LayerPanels],
    pub(crate) exec: &'a Arc<ExecPool>,
    pub(crate) plans: &'a mut dyn PlanProvider,
    pub(crate) kind: StepKind,
    pub(crate) step: usize,
    pub(crate) stats: &'a mut RunStats,
    /// Paged pool the bias stacks are interned into.
    pub(crate) mem: &'a PagePool,
}

impl<'a> EngineExec<'a> {
    /// Symbols → plans through the provider, with RunStats accounting.
    /// The layer's previous plan set (if any) is offered as the delta
    /// base: a miss whose symbols row-diff against it is served by an
    /// incremental recompile instead of a full one.
    fn cached_compile(&mut self, layer: usize, syms: &LayerSymbols) -> Arc<LayerPlans> {
        let geo = self.geo;
        let base = self.state[layer].plans.clone();
        let (plans, outcome) = self.plans.plans_for(syms, &geo, base.as_deref());
        match outcome {
            CacheOutcome::Miss => {
                self.stats.plan_cache_misses += 1;
                obs::metrics::PLAN_CACHE_MISSES.inc();
            }
            CacheOutcome::Hit => {
                self.stats.plan_cache_hits += 1;
                obs::metrics::PLAN_CACHE_HITS.inc();
            }
            CacheOutcome::SharedHit => {
                self.stats.plan_cache_hits += 1;
                self.stats.plan_cache_shared += 1;
                obs::metrics::PLAN_CACHE_HITS.inc();
                obs::metrics::PLAN_CACHE_SHARED.inc();
            }
            CacheOutcome::DeltaHit => {
                self.stats.plan_cache_misses += 1;
                self.stats.plan_cache_delta += 1;
                obs::metrics::PLAN_CACHE_MISSES.inc();
                obs::metrics::PLAN_CACHE_DELTA.inc();
            }
        }
        plans
    }
}

impl<'a> EngineExec<'a> {
    fn phase<T>(&mut self, idx: usize, f: impl FnOnce(&mut Self) -> T) -> T {
        let t0 = std::time::Instant::now();
        let out = f(self);
        self.stats.phase_s[idx] += t0.elapsed().as_secs_f64();
        out
    }
}

impl<'a> BlockExec for EngineExec<'a> {
    fn block(
        &mut self,
        layer: usize,
        bw: &BlockWeights,
        cfg: &ModelConfig,
        cvec: &[f32],
        txt: &mut Tensor,
        img: &mut Tensor,
    ) {
        self.stats.total_layer_steps += 1;
        self.stats.flops_dense += DiTEngine::dense_layer_flops(cfg);
        let geo = self.geo;
        let dispatch_k = match self.kind {
            StepKind::Dispatch { k } => Some(k),
            _ => None,
        };
        let block_cached = dispatch_k.is_some()
            && (self.policy.block_caching() || self.state[layer].degraded)
            && self.state[layer].delta_txt.is_ready();

        if let (Some(k), true) = (dispatch_k, block_cached) {
            // ---- CachedBlock path: forecast the whole block update. ----
            let _sp = Span::enter("block.cached", &obs::metrics::BLOCK_CACHED);
            self.stats.cached_layer_steps += 1;
            let st = &self.state[layer];
            txt.add_assign(&st.delta_txt.forecast(k as f64));
            img.add_assign(&st.delta_img.forecast(k as f64));
            return;
        }

        let sparse = dispatch_k.is_some() && self.state[layer].plans.is_some();
        if !sparse {
            self.full_block(layer, bw, cfg, cvec, txt, img);
        } else {
            self.sparse_block(layer, bw, cfg, cvec, dispatch_k.unwrap(), txt, img);
        }
        let _ = geo;
    }
}

impl<'a> EngineExec<'a> {
    /// Full path: dense compute + symbol/cache refresh.
    #[allow(clippy::too_many_arguments)]
    fn full_block(
        &mut self,
        layer: usize,
        bw: &BlockWeights,
        cfg: &ModelConfig,
        cvec: &[f32],
        txt: &mut Tensor,
        img: &mut Tensor,
    ) {
        let geo = self.geo;
        let sp = Span::enter("gemm_q.dense", &obs::metrics::KERNEL_GEMM_Q_DENSE);
        let txt0 = txt.clone();
        let img0 = img.clone();
        let pre = pre_attention(bw, cvec, txt, img);
        let (q, k, v) =
            self.phase(0, |_| qkv_joint(bw, cfg, &pre.txt_mod, &pre.img_mod));
        drop(sp);
        let sp = Span::enter("attention.dense", &obs::metrics::KERNEL_ATTENTION_DENSE);
        let o_cat = self.phase(1, |this| {
            blocks::joint_attention_dense_on(this.exec, &q, &k, &v, cfg.heads, geo.block_q)
        });

        // FLOP accounting: everything dense this step.
        let t_q = geo.t_q() as u64;
        let t_kv = geo.t_kv() as u64;
        let heads = cfg.heads as u64;
        self.stats.attn_computed_pairs += heads * t_q * t_kv;
        self.stats.attn_total_pairs += heads * t_q * t_kv;
        self.stats.gq_computed += heads * t_q;
        self.stats.gq_total += heads * t_q;
        self.stats.go_computed += heads * t_q;
        self.stats.go_total += heads * t_q;
        self.stats.flops_done += DiTEngine::dense_layer_flops(cfg);
        drop(sp);

        // Refresh symbols from the fresh per-head Q/K (Update semantics),
        // then compile them once into the plan set reused by every
        // Dispatch step of this window. The whole region — mask emission,
        // packing, [delta-]compile, TaylorSeer update — is `plan.refresh`.
        let sp = Span::enter("plan.refresh", &obs::metrics::PLAN_REFRESH);
        let uses_symbols = self.policy.uses_symbols();
        if uses_symbols {
            let mut heads_syms = Vec::with_capacity(cfg.heads);
            for h in 0..cfg.heads {
                let qh = extract_head(&q, cfg.heads, h);
                let kh = extract_head(&k, cfg.heads, h);
                let m = self.policy.masks(layer, h, self.step, &qh, &kh, &geo);
                heads_syms.push(crate::symbols::HeadSymbols::from_masks(
                    &m.m_c,
                    &m.m_s,
                    m.kv_groups,
                    geo.pool,
                ));
            }
            let syms = LayerSymbols { heads: heads_syms };
            let plans = self.cached_compile(layer, &syms);
            // S_q degradation: too few blocks need compute → full caching.
            let compute_fraction = 1.0 - plans.joint.cache_sparsity();
            let st = &mut self.state[layer];
            st.degraded =
                self.policy.s_q() > 0.0 && compute_fraction < self.policy.s_q();
            st.plans = Some(plans);
        }

        // Update the TaylorSeer stacks.
        let dt = self
            .state[layer]
            .last_update_step
            .map(|s| (self.step - s) as f64)
            .unwrap_or(1.0);
        self.state[layer].last_update_step = Some(self.step);
        self.state[layer].o_taylor.update(&o_cat, dt);
        drop(sp);

        // GEMM-O: exact projection now + bias stacks for Dispatch steps,
        // all walking the compiled per-stream plans.
        let sp = Span::enter("gemm_o.dense", &obs::metrics::KERNEL_GEMM_O_DENSE);
        self.phase(2, |this| {
            let exec = Arc::clone(this.exec);
            let panels = &this.panels[layer];
            let mem = this.mem;
            let LayerState { plans, bias_txt, bias_img, o_taylor, .. } =
                &mut this.state[layer];
            if let Some(pl) = plans.as_ref() {
                bias_txt.clear();
                bias_img.clear();
                for (d, stack_entry) in o_taylor.stack().iter().enumerate() {
                    let (e_txt, e_img) = vsplit(stack_entry, cfg.text_tokens);
                    if d == 0 {
                        // Exact output for this step + zeroth-order bias.
                        let (mut out_t, b_t, _) =
                            gemm_o_update_pool(&e_txt, &panels.txt, &pl.txt, &exec);
                        let (mut out_i, b_i, _) =
                            gemm_o_update_pool(&e_img, &panels.img, &pl.img, &exec);
                        add_row_bias(&mut out_t, &bw.txt.bo);
                        add_row_bias(&mut out_i, &bw.img.bo);
                        bias_txt.push(intern_bias(mem, b_t));
                        bias_img.push(intern_bias(mem, b_i));
                        let o_joint = vstack(&out_t, &out_i);
                        post_attention_preprojected(&pre, &o_joint, cfg.text_tokens, txt, img);
                    } else {
                        let b_t = gemm_o_stage1_pool(&e_txt, &panels.txt, &pl.txt, &exec);
                        let b_i = gemm_o_stage1_pool(&e_img, &panels.img, &pl.img, &exec);
                        bias_txt.push(intern_bias(mem, b_t));
                        bias_img.push(intern_bias(mem, b_i));
                    }
                }
            } else {
                // Policies without symbols: plain dense projection.
                post_attention(bw, &pre, &o_cat, txt, img);
            }
        });
        drop(sp);

        let _sp = Span::enter("mlp.dense", &obs::metrics::KERNEL_MLP_DENSE);
        self.phase(3, |_| {
            mlp_stream(&bw.txt, &pre.ada_txt, txt);
            mlp_stream(&bw.img, &pre.ada_img, img);
        });

        // Record whole-block deltas for caching baselines / degradation.
        let mut d_txt = txt.clone();
        d_txt.sub_assign(&txt0);
        let mut d_img = img.clone();
        d_img.sub_assign(&img0);
        self.state[layer].delta_txt.update(&d_txt, dt);
        self.state[layer].delta_img.update(&d_img, dt);
    }

    /// Sparse path: GEMM-Q → Algorithm 1 → GEMM-O with bias, every kernel
    /// consuming the plans compiled at the last symbol refresh.
    #[allow(clippy::too_many_arguments)]
    fn sparse_block(
        &mut self,
        layer: usize,
        bw: &BlockWeights,
        cfg: &ModelConfig,
        cvec: &[f32],
        k_off: usize,
        txt: &mut Tensor,
        img: &mut Tensor,
    ) {
        let geo = self.geo;
        let sp = Span::enter("gemm_q.sparse", &obs::metrics::KERNEL_GEMM_Q_SPARSE);
        let pre = pre_attention(bw, cvec, txt, img);

        // Per-step-mask policies (SpargeAttn) regenerate S_s from fresh Q/K.
        let per_step = self.policy.per_step_masks();

        // K/V are always projected in full (all rows may be attended to).
        let (q, k, v) = self.phase(0, |this| {
            let (kj, vj) = project_kv_joint(bw, cfg, &pre);

            // GEMM-Q with spatial skipping (per-head live tiles from the
            // pre-sliced stream plans — no per-step symbol slicing), tile
            // loops chunked over the shared pool.
            let (q_t, s_t, q_i, s_i) = {
                let plans = this.state[layer].plans.as_ref().unwrap();
                let (q_t, s_t) =
                    gemm_q_pool(&pre.txt_mod, &bw.txt.wq, &plans.txt, Some(&bw.txt.bq), this.exec);
                let (q_i, s_i) =
                    gemm_q_pool(&pre.img_mod, &bw.img.wq, &plans.img, Some(&bw.img.bq), this.exec);
                (q_t, s_t, q_i, s_i)
            };
            this.stats.gq_computed += (s_t.computed_tiles + s_i.computed_tiles) as u64;
            this.stats.gq_total += (s_t.total_tiles + s_i.total_tiles) as u64;
            let mut qj = vstack(&q_t, &q_i);
            blocks::norm_rope_joint_q(&mut qj, bw, cfg, cfg.text_tokens);
            (qj, kj, vj)
        });
        drop(sp);

        if per_step {
            let _sp = Span::enter("plan.refresh", &obs::metrics::PLAN_REFRESH);
            let mut heads_syms = Vec::with_capacity(cfg.heads);
            for h in 0..cfg.heads {
                let qh = extract_head(&q, cfg.heads, h);
                let kh = extract_head(&k, cfg.heads, h);
                let m = self.policy.masks(layer, h, self.step, &qh, &kh, &geo);
                heads_syms.push(crate::symbols::HeadSymbols::from_masks(
                    &m.m_c,
                    &m.m_s,
                    m.kv_groups,
                    geo.pool,
                ));
            }
            let syms = LayerSymbols { heads: heads_syms };
            let plans = self.cached_compile(layer, &syms);
            self.state[layer].plans = Some(plans);
        }

        // FlashOmni attention (Algorithm 1 with real skipping); independent
        // heads dispatched on the persistent pool — each task consumes its
        // head's compiled plan and produces that head's output slice (the
        // pool places results by head index, so the gather below is
        // order-deterministic and bitwise-identical to a serial loop).
        let sp = Span::enter("attention.sparse", &obs::metrics::KERNEL_ATTENTION_SPARSE);
        let o_cat = self.phase(1, |this| {
            let heads = cfg.heads;
            let per_head: Vec<(Tensor, AttnStats)> = {
                let plans = this.state[layer].plans.as_ref().unwrap();
                let joint = &plans.joint;
                let (bq, bk) = (geo.block_q, geo.block_k);
                let (qr, kr, vr) = (&q, &k, &v);
                this.exec.parallel_map_indexed(heads, |h| {
                    let qh = extract_head(qr, heads, h);
                    let kh = extract_head(kr, heads, h);
                    let vh = extract_head(vr, heads, h);
                    flashomni_attention(&qh, &kh, &vh, &joint.heads[h], bq, bk, None)
                })
            };
            let mut o_cat = Tensor::zeros(&[cfg.seq_len(), cfg.dim]);
            for (h, (oh, st)) in per_head.into_iter().enumerate() {
                this.stats.attn_computed_pairs += st.computed_pairs as u64;
                this.stats.attn_total_pairs += st.total_pairs as u64;
                insert_head(&mut o_cat, &oh, heads, h);
            }
            o_cat
        });
        drop(sp);

        // GEMM-O dispatch: bias init + computed tiles only.
        let sp = Span::enter("gemm_o.sparse", &obs::metrics::KERNEL_GEMM_O_SPARSE);
        self.phase(2, |this| {
            let st = &this.state[layer];
            let plans = st.plans.as_ref().unwrap();
            let (o_txt, o_img) = vsplit(&o_cat, cfg.text_tokens);
            let coeffs = st.o_taylor.coefficients(k_off as f64);
            let bias_t = if st.bias_txt.is_empty() {
                Tensor::zeros(&[cfg.text_tokens, cfg.dim])
            } else {
                combine_bias_stack(&st.bias_txt, &coeffs)
            };
            let bias_i = if st.bias_img.is_empty() {
                Tensor::zeros(&[cfg.vision_tokens(), cfg.dim])
            } else {
                combine_bias_stack(&st.bias_img, &coeffs)
            };
            let (mut out_t, g_t) =
                gemm_o_dispatch_pool(&o_txt, &this.panels[layer].txt, &plans.txt, &bias_t, this.exec);
            let (mut out_i, g_i) =
                gemm_o_dispatch_pool(&o_img, &this.panels[layer].img, &plans.img, &bias_i, this.exec);
            this.stats.go_computed += (g_t.computed_tiles + g_i.computed_tiles) as u64;
            this.stats.go_total += (g_t.total_tiles + g_i.total_tiles) as u64;
            add_row_bias(&mut out_t, &bw.txt.bo);
            add_row_bias(&mut out_i, &bw.img.bo);
            let o_joint = vstack(&out_t, &out_i);
            post_attention_preprojected(&pre, &o_joint, cfg.text_tokens, txt, img);
        });
        drop(sp);

        let _sp = Span::enter("mlp.sparse", &obs::metrics::KERNEL_MLP_SPARSE);
        self.phase(3, |_| {
            mlp_stream(&bw.txt, &pre.ada_txt, txt);
            mlp_stream(&bw.img, &pre.ada_img, img);
        });

        // Approximate FLOP accounting for the sparse step, read off the
        // plan's precomputed tile/pair counts.
        self.stats.flops_done +=
            sparse_step_flops(cfg, self.state[layer].plans.as_ref().unwrap());
    }
}

/// Sparse-path joint K/V: project both streams in full (all rows may be
/// attended to), RMS-norm the keys, stack, and rotate. One definition
/// shared by the single-request sparse path and the batched engine, so
/// the two can never drift apart numerically.
pub(crate) fn project_kv_joint(
    bw: &BlockWeights,
    cfg: &ModelConfig,
    pre: &blocks::PreAttn,
) -> (Tensor, Tensor) {
    let mut k_t = linear(&pre.txt_mod, &bw.txt.wk, &bw.txt.bk);
    let v_t = linear(&pre.txt_mod, &bw.txt.wv, &bw.txt.bv);
    let mut k_i = linear(&pre.img_mod, &bw.img.wk, &bw.img.bk);
    let v_i = linear(&pre.img_mod, &bw.img.wv, &bw.img.bv);
    blocks::headwise_rmsnorm(&mut k_t, cfg.heads, &bw.txt.k_rms);
    blocks::headwise_rmsnorm(&mut k_i, cfg.heads, &bw.img.k_rms);
    let mut kj = vstack(&k_t, &k_i);
    let positions: Vec<usize> = (0..cfg.seq_len()).collect();
    blocks::headwise_rope(&mut kj, cfg.heads, &positions);
    let vj = vstack(&v_t, &v_i);
    (kj, vj)
}

/// Approximate FLOPs actually executed by one sparse (Dispatch) layer
/// step, read off the compiled plan's tile/pair counts. One definition
/// shared by the single-request and batched sparse paths.
pub(crate) fn sparse_step_flops(cfg: &ModelConfig, plans: &LayerPlans) -> f64 {
    let density = plans.joint.density();
    let cache_density = 1.0 - plans.joint.cache_sparsity();
    let n = cfg.seq_len() as f64;
    let d = cfg.dim as f64;
    let m = (cfg.mlp_ratio * cfg.dim) as f64;
    let attn = 4.0 * n * n * d * density;
    let qproj = 2.0 * n * d * d * cache_density;
    let kv = 2.0 * 2.0 * n * d * d;
    let oproj = 2.0 * n * d * d * cache_density;
    let mlp = 2.0 * 2.0 * n * d * m;
    attn + qproj + kv + oproj + mlp
}

/// Intern one projected bias tensor into the engine pool: bias stacks of
/// symbol-identical requests (same attention outputs, same plans) land on
/// the same physical block (`b"bias"` namespace, content-verified).
fn intern_bias(mem: &PagePool, t: Tensor) -> Pooled<Tensor> {
    mem.intern_digest(digest_tensor(b"bias", &t), tensor_bytes(&t), t).0
}

/// Add a per-feature bias vector to every row.
pub(crate) fn add_row_bias(x: &mut Tensor, b: &[f32]) {
    let d = x.cols();
    assert_eq!(b.len(), d);
    for r in 0..x.rows() {
        let row = x.row_mut(r);
        for c in 0..d {
            row[c] += b[c];
        }
    }
}

/// Residual add of an already-projected joint attention output.
pub(crate) fn post_attention_preprojected(
    pre: &blocks::PreAttn,
    o_joint: &Tensor,
    text_tokens: usize,
    txt: &mut Tensor,
    img: &mut Tensor,
) {
    let (a_t, a_i) = vsplit(o_joint, text_tokens);
    crate::kernels::elementwise::gated_add(txt, &pre.ada_txt[2], &a_t);
    crate::kernels::elementwise::gated_add(img, &pre.ada_img[2], &a_i);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SparsityConfig;
    use crate::model::weights::Weights;

    fn tiny_model() -> MiniMMDiT {
        let cfg = ModelConfig {
            dim: 32,
            heads: 2,
            layers: 2,
            text_tokens: 8,
            patch_h: 4,
            patch_w: 4,
            patch_size: 2,
            channels: 3,
            mlp_ratio: 2,
            vocab: 16,
        };
        MiniMMDiT::new(cfg.clone(), Weights::random(&cfg, 11))
    }

    #[test]
    fn full_policy_matches_dense_reference() {
        let model = tiny_model();
        let ids: Vec<usize> = (0..model.cfg.text_tokens).collect();
        let mut engine = DiTEngine::new(model.clone(), Policy::full(), 8, 8);
        let res = engine.generate(&ids, 3, 6);
        // Re-run densely by hand.
        let mut x = initial_noise(&model.cfg, 3);
        let grid = time_grid(6);
        for s in 0..6 {
            let v = model.forward_dense(&ids, &x, grid[s]);
            euler_step(&mut x, &v, grid[s] - grid[s + 1]);
        }
        let want = unpatchify(&x, &model.cfg);
        assert!(
            res.image.max_abs_diff(&want) < 1e-3,
            "engine full path deviates: {}",
            res.image.max_abs_diff(&want)
        );
        assert_eq!(res.stats.attn_sparsity(), 0.0);
    }

    #[test]
    fn flashomni_policy_runs_and_skips() {
        let model = tiny_model();
        let ids: Vec<usize> = (0..model.cfg.text_tokens).collect();
        let scfg = SparsityConfig {
            tau_q: 0.6,
            tau_kv: 0.3,
            interval: 3,
            order: 1,
            s_q: 0.0,
            block_q: 8,
            block_k: 8,
            pool: 1,
            warmup: 2,
            ramp_steps: 1,
        };
        let mut engine = DiTEngine::new(model, Policy::flashomni(scfg), 8, 8);
        let res = engine.generate(&ids, 3, 10);
        assert!(res.image.data().iter().all(|x| x.is_finite()));
        assert!(
            res.stats.attn_sparsity() > 0.0,
            "expected some skipped pairs, got sparsity 0"
        );
        assert!(res.stats.flop_speedup() > 1.0);
        assert_eq!(res.stats.per_step_density.len(), 10);
        // Warmup steps are dense.
        assert_eq!(res.stats.per_step_density[0], 1.0);
        assert_eq!(res.stats.per_step_density[1], 1.0);
    }

    #[test]
    fn sparse_path_with_zero_tau_equals_dense() {
        // τ = 0 symbols are all-compute: the sparse machinery must agree
        // with the dense reference to float tolerance.
        let model = tiny_model();
        let ids: Vec<usize> = (0..model.cfg.text_tokens).collect();
        let scfg = SparsityConfig {
            tau_q: 0.0,
            tau_kv: 0.0,
            interval: 3,
            order: 1,
            s_q: 0.0,
            block_q: 8,
            block_k: 8,
            pool: 1,
            warmup: 1,
            ramp_steps: 1,
        };
        let mut engine = DiTEngine::new(model.clone(), Policy::flashomni(scfg), 8, 8);
        let res = engine.generate(&ids, 7, 6);
        let mut dense = DiTEngine::new(model, Policy::full(), 8, 8);
        let want = dense.generate(&ids, 7, 6);
        let diff = res.image.max_abs_diff(&want.image);
        assert!(diff < 1e-2, "zero-sparsity sparse path deviates by {diff}");
        assert_eq!(res.stats.attn_sparsity(), 0.0);
    }

    #[test]
    fn plan_cache_hits_on_repeated_prompts() {
        let model = tiny_model();
        let ids: Vec<usize> = (0..model.cfg.text_tokens).collect();
        let scfg = SparsityConfig {
            tau_q: 0.6,
            tau_kv: 0.3,
            interval: 3,
            order: 1,
            s_q: 0.0,
            block_q: 8,
            block_k: 8,
            pool: 1,
            warmup: 2,
            ramp_steps: 1,
        };
        let mut engine = DiTEngine::new(model, Policy::flashomni(scfg), 8, 8);
        let r1 = engine.generate(&ids, 3, 10);
        assert!(r1.stats.plan_cache_misses > 0, "first run must compile plans");
        // Identical request → byte-identical symbols → every refresh hits.
        let r2 = engine.generate(&ids, 3, 10);
        assert_eq!(
            r2.stats.plan_cache_misses, 0,
            "repeated prompt must hit the plan cache on every refresh"
        );
        assert!(r2.stats.plan_cache_hits > 0);
        assert_eq!(r1.image, r2.image, "cache reuse must not change the output");
        let cs = engine.plan_cache_stats();
        assert_eq!(cs.hits, r1.stats.plan_cache_hits + r2.stats.plan_cache_hits);
        assert_eq!(cs.misses, r1.stats.plan_cache_misses + r2.stats.plan_cache_misses);
    }

    #[test]
    fn taylorseer_policy_caches_blocks() {
        let model = tiny_model();
        let ids: Vec<usize> = (0..model.cfg.text_tokens).collect();
        let mut engine =
            DiTEngine::new(model, Policy::taylorseer(3, 1, 2), 8, 8);
        let res = engine.generate(&ids, 3, 11);
        assert!(res.stats.cached_layer_steps > 0, "no layer-steps cached");
        assert!(res.image.data().iter().all(|x| x.is_finite()));
        // Cached steps don't contribute attention pairs → density < 1 on
        // dispatch steps.
        assert!(res.stats.per_step_density.iter().any(|&d| d == 0.0));
    }

    #[test]
    fn text_free_geometry_is_zero_safe() {
        // Regression: pure-image configs (text_tokens == 0) used to rely
        // on exact divisibility in `text_groups()`.
        let cfg = ModelConfig { text_tokens: 0, ..tiny_model().cfg };
        let geo = Geometry::from_model(&cfg, 8, 8, 1);
        assert_eq!(geo.text_groups(), 0);
        assert_eq!(geo.text_blocks(), 0);
        let geo2 = Geometry::from_model(&cfg, 8, 8, 2);
        assert_eq!(geo2.text_groups(), 0);
        // Non-zero prefixes still round up to whole groups.
        let cfg3 = ModelConfig { text_tokens: 8, ..tiny_model().cfg };
        let geo3 = Geometry::from_model(&cfg3, 8, 8, 1);
        assert_eq!(geo3.text_groups(), 1);
        assert_eq!(geo3.text_blocks(), 1);
    }

    #[test]
    fn text_free_model_generates() {
        // A pure-image model must run end-to-end on the full path and on
        // the plan-driven sparse path.
        let cfg = ModelConfig { text_tokens: 0, ..tiny_model().cfg };
        let model = MiniMMDiT::new(cfg.clone(), Weights::random(&cfg, 13));
        let mut dense = DiTEngine::new(model.clone(), Policy::full(), 8, 8);
        let r = dense.generate(&[], 3, 4);
        assert!(r.image.data().iter().all(|x| x.is_finite()));
        let scfg = SparsityConfig {
            tau_q: 0.3,
            tau_kv: 0.2,
            interval: 2,
            order: 1,
            s_q: 0.0,
            block_q: 8,
            block_k: 8,
            pool: 1,
            warmup: 1,
            ramp_steps: 1,
        };
        let mut sparse = DiTEngine::new(model, Policy::flashomni(scfg), 8, 8);
        let r2 = sparse.generate(&[], 3, 6);
        assert!(r2.image.data().iter().all(|x| x.is_finite()));
        assert_eq!(r2.stats.per_step_density.len(), 6);
    }

    #[test]
    fn stats_flops_monotonic() {
        let model = tiny_model();
        let ids: Vec<usize> = (0..model.cfg.text_tokens).collect();
        let mut dense = DiTEngine::new(model.clone(), Policy::full(), 8, 8);
        let r1 = dense.generate(&ids, 3, 6);
        assert!((r1.stats.flop_speedup() - 1.0).abs() < 1e-9);
        let mut fora = DiTEngine::new(model, Policy::fora(2, 1), 8, 8);
        let r2 = fora.generate(&ids, 3, 6);
        assert!(r2.stats.flops_done < r1.stats.flops_done);
    }
}
