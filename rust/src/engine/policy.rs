//! Sparsity **policies** — the paper's method and all five baselines,
//! every one expressed as an emitter of unified sparse symbols feeding the
//! same engine/kernels (the paper's central "unified" claim).
//!
//! | Policy | Sparsity it emits | Paper reference |
//! |---|---|---|
//! | `Full` | none | Full-Attention rows of Tables 1–2 |
//! | `FlashOmni(τq, τkv, N, D, Sq)` | `S_c` (Eq. 1 selection) + `S_s`, TaylorSeer forecast, `S_q` degradation | the proposed method |
//! | `TaylorSeer(N, D)` | whole-block caching w/ Taylor forecast | Liu et al. 2025b |
//! | `FORA(N)` | whole-block caching, direct reuse | Selvaraju et al. 2024 |
//! | `ToCa(τq, N)` | token-block `S_c` only, direct reuse | Zou et al. 2025 |
//! | `SpargeAttn(l1, l2)` | per-step dynamic `S_s` only | Zhang et al. 2025b |
//! | `DiTFastAttnV2(θ)` | static head-wise arrow `S_s` | Zhang et al. 2025a |
//!
//! Simplifications vs the original baselines are documented on each
//! constructor (and in DESIGN.md).

use crate::config::SparsityConfig;
use crate::masks::{arrow_mask, compressed_map, flashomni_masks, select_skipped_blocks, MaskSet};
use crate::tensor::Tensor;
use std::collections::HashMap;

use super::Geometry;

/// Which method generates the sparsity decisions.
#[derive(Clone, Debug)]
pub enum PolicyKind {
    Full,
    FlashOmni(SparsityConfig),
    TaylorSeer { interval: usize, order: usize, warmup: usize },
    Fora { interval: usize, warmup: usize },
    Toca(SparsityConfig),
    SpargeAttn { l1: f64, l2: f64, warmup: usize },
    DiTFastAttnV2 { theta: f64, warmup: usize },
}

/// A sparsity policy (kind + any calibration state).
#[derive(Clone, Debug)]
pub struct Policy {
    pub kind: PolicyKind,
    /// DiTFastAttnV2 per-(layer, head) calibrated static skip masks.
    calibrated: HashMap<(usize, usize), Vec<bool>>,
}

impl Policy {
    fn of(kind: PolicyKind) -> Self {
        Policy { kind, calibrated: HashMap::new() }
    }

    /// Dense baseline.
    pub fn full() -> Self {
        Self::of(PolicyKind::Full)
    }

    /// The paper's method with the `(τ_q, τ_kv, N, D, S_q)` configuration.
    pub fn flashomni(cfg: SparsityConfig) -> Self {
        Self::of(PolicyKind::FlashOmni(cfg))
    }

    /// TaylorSeer baseline: whole-block caching with order-`order` forecast.
    pub fn taylorseer(interval: usize, order: usize, warmup: usize) -> Self {
        Self::of(PolicyKind::TaylorSeer { interval, order, warmup })
    }

    /// FORA baseline: whole-block caching with direct reuse.
    pub fn fora(interval: usize, warmup: usize) -> Self {
        Self::of(PolicyKind::Fora { interval, warmup })
    }

    /// ToCa baseline (simplified): token-block caching driven by the same
    /// attention-derived importance scores (C metric), direct reuse, no
    /// block-sparse skipping and no GEMM optimizations beyond the unified
    /// engine's.
    pub fn toca(mut cfg: SparsityConfig) -> Self {
        cfg.tau_kv = 0.0;
        cfg.order = 0;
        cfg.s_q = 0.0;
        Self::of(PolicyKind::Toca(cfg))
    }

    /// SpargeAttn baseline (simplified): dynamic block-skip mask re-derived
    /// every step from the pooled QK map; `l1`/`l2` (the two-stage
    /// thresholds of the original) are combined into a single skipped-mass
    /// budget `l1 + l2`.
    pub fn sparge(l1: f64, l2: f64, warmup: usize) -> Self {
        Self::of(PolicyKind::SpargeAttn { l1, l2, warmup })
    }

    /// DiTFastAttnV2 baseline (simplified): head-wise static arrow-attention
    /// masks calibrated once — the smallest window whose retained pooled
    /// probability mass is ≥ 1 − θ.
    pub fn dfa2(theta: f64, warmup: usize) -> Self {
        Self::of(PolicyKind::DiTFastAttnV2 { theta, warmup })
    }

    /// Display name matching the paper's tables.
    pub fn name(&self) -> String {
        match &self.kind {
            PolicyKind::Full => "Full-Attention".into(),
            PolicyKind::FlashOmni(c) => format!("FlashOmni {}", c.label()),
            PolicyKind::TaylorSeer { interval, order, .. } => {
                format!("TaylorSeer (N={interval}, D={order})")
            }
            PolicyKind::Fora { interval, .. } => format!("FORA (N={interval})"),
            PolicyKind::Toca(c) => format!("ToCa (τ={:.0}%, N={})", c.tau_q * 100.0, c.interval),
            PolicyKind::SpargeAttn { l1, l2, .. } => {
                format!("SpargeAttn (l1={:.1}%, l2={:.1}%)", l1 * 100.0, l2 * 100.0)
            }
            PolicyKind::DiTFastAttnV2 { theta, .. } => format!("DiTFastAttnV2 (θ={theta})"),
        }
    }

    /// `(warmup, interval)` for the Update–Dispatch planner.
    pub fn schedule(&self) -> (usize, usize) {
        match &self.kind {
            PolicyKind::Full => (usize::MAX, 1),
            PolicyKind::FlashOmni(c) | PolicyKind::Toca(c) => (c.warmup, c.interval),
            PolicyKind::TaylorSeer { interval, warmup, .. }
            | PolicyKind::Fora { interval, warmup } => (*warmup, *interval),
            // No caching: one Update right after warmup generates (or
            // calibrates) symbols, then every step is a Dispatch.
            PolicyKind::SpargeAttn { warmup, .. } => (*warmup, usize::MAX / 2),
            PolicyKind::DiTFastAttnV2 { warmup, .. } => (*warmup, usize::MAX / 2),
        }
    }

    /// Whether the engine should maintain symbols at all.
    pub fn uses_symbols(&self) -> bool {
        !matches!(
            self.kind,
            PolicyKind::Full | PolicyKind::TaylorSeer { .. } | PolicyKind::Fora { .. }
        )
    }

    /// Whole-block caching at Dispatch steps (TaylorSeer / FORA).
    pub fn block_caching(&self) -> bool {
        matches!(self.kind, PolicyKind::TaylorSeer { .. } | PolicyKind::Fora { .. })
    }

    /// TaylorSeer expansion order `D`.
    pub fn order(&self) -> usize {
        match &self.kind {
            PolicyKind::FlashOmni(c) => c.order,
            PolicyKind::TaylorSeer { order, .. } => *order,
            _ => 0,
        }
    }

    /// Degradation threshold `S_q`.
    pub fn s_q(&self) -> f64 {
        match &self.kind {
            PolicyKind::FlashOmni(c) => c.s_q,
            _ => 0.0,
        }
    }

    /// Masks regenerated every step from fresh Q/K (dynamic BSS).
    pub fn per_step_masks(&self) -> bool {
        matches!(self.kind, PolicyKind::SpargeAttn { .. })
    }

    /// Drop calibration state between requests.
    pub fn reset(&mut self) {
        self.calibrated.clear();
    }

    /// Generate the logical masks for one `(layer, head)` at a refresh
    /// point, from the fresh per-head `Q`/`K` (`[N × head_dim]`).
    pub fn masks(
        &mut self,
        layer: usize,
        head: usize,
        step: usize,
        q: &Tensor,
        k: &Tensor,
        geo: &Geometry,
    ) -> MaskSet {
        let gq = geo.block_q * geo.pool;
        let gk = geo.block_k * geo.pool;
        match &self.kind {
            PolicyKind::Full | PolicyKind::TaylorSeer { .. } | PolicyKind::Fora { .. } => {
                MaskSet::dense(geo.q_groups(), geo.kv_groups())
            }
            PolicyKind::FlashOmni(c) => {
                let tau_q = c.tau_at(c.tau_q, step);
                let tau_kv = c.tau_at(c.tau_kv, step);
                flashomni_masks(q, k, gq, gk, geo.text_tokens, tau_q, tau_kv)
            }
            PolicyKind::Toca(c) => {
                let tau_q = c.tau_at(c.tau_q, step);
                flashomni_masks(q, k, gq, gk, geo.text_tokens, tau_q, 0.0)
            }
            PolicyKind::SpargeAttn { l1, l2, .. } => {
                let map = compressed_map(q, k, gq, gk, geo.text_tokens);
                let m_s = select_skipped_blocks(&map, l1 + l2);
                MaskSet {
                    m_c: vec![true; map.q_groups],
                    m_s,
                    q_groups: map.q_groups,
                    kv_groups: map.kv_groups,
                }
            }
            PolicyKind::DiTFastAttnV2 { theta, .. } => {
                let qg = geo.q_groups();
                let kg = geo.kv_groups();
                let key = (layer, head);
                let theta = *theta;
                let m_s = if let Some(m) = self.calibrated.get(&key) {
                    m.clone()
                } else {
                    let map = compressed_map(q, k, gq, gk, geo.text_tokens);
                    let tg = map.text_groups;
                    let mut chosen = vec![true; qg * kg];
                    // Smallest arrow window whose retained mass ≥ 1 − θ.
                    let mut w = 1usize;
                    while w < kg {
                        let cand = arrow_mask(qg, kg, tg, w, 1);
                        let mut kept = 0.0f64;
                        for i in 0..qg {
                            for j in 0..kg {
                                if cand[i * kg + j] {
                                    kept += map.p[i * kg + j] as f64;
                                }
                            }
                        }
                        if kept / qg as f64 >= 1.0 - theta {
                            chosen = cand;
                            break;
                        }
                        w *= 2;
                    }
                    self.calibrated.insert(key, chosen.clone());
                    chosen
                };
                MaskSet { m_c: vec![true; qg], m_s, q_groups: qg, kv_groups: kg }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::testutil::randn;
    use crate::util::rng::Pcg32;

    fn geo() -> Geometry {
        Geometry::from_model(
            &ModelConfig {
                dim: 32,
                heads: 2,
                layers: 1,
                text_tokens: 8,
                patch_h: 4,
                patch_w: 4,
                patch_size: 2,
                channels: 3,
                mlp_ratio: 2,
                vocab: 16,
            },
            8,
            8,
            1,
        )
    }

    #[test]
    fn names_match_paper_style() {
        let c = SparsityConfig::paper(0.5, 0.15, 5, 1, 0.3);
        assert_eq!(Policy::flashomni(c).name(), "FlashOmni (50%, 15%, 5, 1, 30%)");
        assert_eq!(Policy::taylorseer(5, 1, 4).name(), "TaylorSeer (N=5, D=1)");
        assert_eq!(Policy::fora(3, 4).name(), "FORA (N=3)");
    }

    #[test]
    fn full_policy_emits_dense_masks() {
        let g = geo();
        let mut p = Policy::full();
        let mut rng = Pcg32::seeded(1);
        let q = randn(&mut rng, &[g.seq, 16]);
        let k = randn(&mut rng, &[g.seq, 16]);
        let m = p.masks(0, 0, 5, &q, &k, &g);
        assert!(m.m_c.iter().all(|&b| b));
        assert!(m.m_s.iter().all(|&b| b));
    }

    #[test]
    fn sparge_skips_but_never_caches() {
        let g = geo();
        let mut p = Policy::sparge(0.2, 0.2, 1);
        let mut rng = Pcg32::seeded(2);
        let q = randn(&mut rng, &[g.seq, 16]);
        let k = randn(&mut rng, &[g.seq, 16]);
        let m = p.masks(0, 0, 5, &q, &k, &g);
        assert!(m.m_c.iter().all(|&b| b), "SpargeAttn must not cache");
        assert!(m.m_s.iter().any(|&b| !b), "SpargeAttn must skip something");
        assert!(p.per_step_masks());
        assert!(!p.block_caching());
    }

    #[test]
    fn dfa2_calibrates_once_and_is_static() {
        let g = geo();
        let mut p = Policy::dfa2(0.4, 1);
        let mut rng = Pcg32::seeded(3);
        let q = randn(&mut rng, &[g.seq, 16]);
        let k = randn(&mut rng, &[g.seq, 16]);
        let m1 = p.masks(0, 0, 1, &q, &k, &g);
        // Different Q/K later — mask must be unchanged (static).
        let q2 = randn(&mut rng, &[g.seq, 16]);
        let k2 = randn(&mut rng, &[g.seq, 16]);
        let m2 = p.masks(0, 0, 7, &q2, &k2, &g);
        assert_eq!(m1.m_s, m2.m_s);
        // Other heads calibrate independently.
        let m3 = p.masks(0, 1, 1, &q, &k, &g);
        assert_eq!(m3.m_s.len(), m1.m_s.len());
        p.reset();
        assert!(p.calibrated.is_empty());
    }

    #[test]
    fn toca_no_bss() {
        let g = geo();
        let c = SparsityConfig {
            tau_q: 0.5,
            warmup: 0,
            ramp_steps: 1,
            ..SparsityConfig::default()
        };
        let mut p = Policy::toca(c);
        assert_eq!(p.order(), 0);
        let mut rng = Pcg32::seeded(4);
        let q = randn(&mut rng, &[g.seq, 16]);
        let k = randn(&mut rng, &[g.seq, 16]);
        let m = p.masks(0, 0, 3, &q, &k, &g);
        assert!(m.m_s.iter().all(|&b| b), "ToCa must not skip pairs");
    }

    #[test]
    fn schedules() {
        assert_eq!(Policy::fora(4, 2).schedule(), (2, 4));
        let (w, i) = Policy::sparge(0.1, 0.1, 3).schedule();
        assert_eq!(w, 3);
        assert!(i > 1_000_000);
    }
}
