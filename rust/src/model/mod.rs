//! **MiniMMDiT** — the multimodal diffusion-transformer substrate.
//!
//! A faithful small-scale double-stream MMDiT in the style of SD3 / FLUX:
//! text and vision tokens are projected by *separate* stream weights,
//! concatenated for **joint self-attention** (the four-region attention map
//! of §3.1: t→t, v→t, t→v, v→v), then routed back through per-stream output
//! projections, adaLN-zero modulation, and per-stream MLPs. The final layer
//! decodes the vision stream into per-patch rectified-flow velocities.
//!
//! The same architecture (same formulas, same weight names) is implemented
//! in JAX in `python/compile/model.py`; weights trained there are exported
//! to `artifacts/weights.fot` and loaded here. Integration tests check that
//! the two implementations agree on the AOT-compiled HLO oracle.
//!
//! The block loop is parameterized by [`BlockExec`] so the FlashOmni engine
//! can replace the attention module (and, for degraded/cached layers, the
//! whole block) without duplicating the rest of the forward pass.

pub mod blocks;
pub mod weights;

use crate::config::ModelConfig;
use crate::tensor::Tensor;
pub use weights::{BlockWeights, StreamWeights, Weights};

/// Hook that executes one MMDiT block on the residual streams.
pub trait BlockExec {
    /// Execute block `layer`, mutating the residual streams in place.
    /// `cvec` is the timestep-conditioning vector (`[dim]`).
    fn block(
        &mut self,
        layer: usize,
        weights: &BlockWeights,
        cfg: &ModelConfig,
        cvec: &[f32],
        txt: &mut Tensor,
        img: &mut Tensor,
    );
}

/// Dense reference executor: full attention, no caching, no skipping.
pub struct DenseExec;

impl BlockExec for DenseExec {
    fn block(
        &mut self,
        _layer: usize,
        weights: &BlockWeights,
        cfg: &ModelConfig,
        cvec: &[f32],
        txt: &mut Tensor,
        img: &mut Tensor,
    ) {
        blocks::block_dense(weights, cfg, cvec, txt, img);
    }
}

/// The model: config + weights.
#[derive(Clone, Debug)]
pub struct MiniMMDiT {
    pub cfg: ModelConfig,
    pub w: Weights,
}

impl MiniMMDiT {
    pub fn new(cfg: ModelConfig, w: Weights) -> Self {
        MiniMMDiT { cfg, w }
    }

    /// Load config + weights from a `.fot` artifact.
    pub fn load(path: &str) -> Result<Self, String> {
        let w = Weights::load(path)?;
        Ok(MiniMMDiT { cfg: w.cfg.clone(), w })
    }

    /// One denoising forward pass: predict the rectified-flow velocity for
    /// every vision patch.
    ///
    /// * `text_ids` — `[text_tokens]` hash-embedding ids,
    /// * `patches` — `[vision_tokens × patch_dim]` noisy latents `x_t`,
    /// * `t` — diffusion time in `[0, 1]`,
    /// * `exec` — block executor (dense or the FlashOmni engine).
    pub fn forward_with(
        &self,
        exec: &mut dyn BlockExec,
        text_ids: &[usize],
        patches: &Tensor,
        t: f64,
    ) -> Tensor {
        let cfg = &self.cfg;
        let (mut txt, mut img) = self.embed_streams(text_ids, patches);
        let cvec = self.conditioning(t);

        // Transformer blocks.
        for (layer, bw) in self.w.blocks.iter().enumerate() {
            exec.block(layer, bw, cfg, &cvec, &mut txt, &mut img);
        }

        self.decode(&cvec, &img)
    }

    /// Embed prompt ids + noisy patches into the two residual streams —
    /// the shared prefix of every forward pass. Exposed so drivers that
    /// run the block loop themselves (the batched engine advances many
    /// requests layer-by-layer in lockstep) produce bit-identical streams.
    pub fn embed_streams(&self, text_ids: &[usize], patches: &Tensor) -> (Tensor, Tensor) {
        self.embed_streams_with(&self.cfg, text_ids, patches)
    }

    /// [`MiniMMDiT::embed_streams`] under an explicit per-request config —
    /// the ragged batch path runs requests whose `patch_h × patch_w` grid
    /// differs from the model's native one (weights are
    /// resolution-independent; only the sequence length changes). `cfg`
    /// must agree with the model on every weight-shaping field
    /// (`dim`, `text_tokens`, `patch_size`, `channels`, `vocab`).
    pub fn embed_streams_with(
        &self,
        cfg: &ModelConfig,
        text_ids: &[usize],
        patches: &Tensor,
    ) -> (Tensor, Tensor) {
        let _sp = crate::obs::Span::enter("model.embed", &crate::obs::metrics::MODEL_EMBED);
        assert_eq!(cfg.patch_dim(), self.cfg.patch_dim(), "patch_dim is weight-shaping");
        assert_eq!(cfg.dim, self.cfg.dim, "dim is weight-shaping");
        assert_eq!(text_ids.len(), cfg.text_tokens);
        assert_eq!(patches.shape(), &[cfg.vision_tokens(), cfg.patch_dim()]);
        let mut txt = Tensor::zeros(&[cfg.text_tokens, cfg.dim]);
        for (r, &id) in text_ids.iter().enumerate() {
            assert!(id < cfg.vocab, "text id {id} out of vocab {}", cfg.vocab);
            txt.row_mut(r).copy_from_slice(self.w.text_embed.row(id));
        }
        let img = blocks::linear(patches, &self.w.patch_w, &self.w.patch_b);
        (txt, img)
    }

    /// Timestep-conditioning vector for diffusion time `t` (`[dim]`) —
    /// depends only on `t`, so lockstep batch members at the same step
    /// could share it (each slot keeps its own `t`, so it is per-slot).
    pub fn conditioning(&self, t: f64) -> Vec<f32> {
        blocks::timestep_conditioning(&self.w, &self.cfg, t)
    }

    /// Final layer: decode the vision stream into per-patch rectified-flow
    /// velocities — the shared suffix of every forward pass.
    pub fn decode(&self, cvec: &[f32], img: &Tensor) -> Tensor {
        self.decode_with(&self.cfg, cvec, img)
    }

    /// [`MiniMMDiT::decode`] under an explicit per-request config (the
    /// final layer is row-local, so only the row count differs).
    pub fn decode_with(&self, cfg: &ModelConfig, cvec: &[f32], img: &Tensor) -> Tensor {
        let _sp = crate::obs::Span::enter("model.decode", &crate::obs::metrics::MODEL_DECODE);
        blocks::final_layer(&self.w, cfg, cvec, img)
    }

    /// Dense forward (reference path).
    pub fn forward_dense(&self, text_ids: &[usize], patches: &Tensor, t: f64) -> Tensor {
        self.forward_with(&mut DenseExec, text_ids, patches, t)
    }

    /// Total parameter count.
    pub fn param_count(&self) -> usize {
        self.w.param_count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            dim: 32,
            heads: 2,
            layers: 2,
            text_tokens: 4,
            patch_h: 4,
            patch_w: 4,
            patch_size: 2,
            channels: 3,
            mlp_ratio: 2,
            vocab: 16,
        }
    }

    #[test]
    fn forward_shapes_and_determinism() {
        let cfg = tiny_cfg();
        let model = MiniMMDiT::new(cfg.clone(), Weights::random(&cfg, 7));
        let mut rng = Pcg32::seeded(1);
        let patches = crate::testutil::randn(&mut rng, &[cfg.vision_tokens(), cfg.patch_dim()]);
        let ids: Vec<usize> = (0..cfg.text_tokens).map(|i| i % cfg.vocab).collect();
        let v1 = model.forward_dense(&ids, &patches, 0.5);
        let v2 = model.forward_dense(&ids, &patches, 0.5);
        assert_eq!(v1.shape(), &[cfg.vision_tokens(), cfg.patch_dim()]);
        assert_eq!(v1, v2, "forward must be deterministic");
        assert!(v1.data().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn timestep_changes_output() {
        let cfg = tiny_cfg();
        let model = MiniMMDiT::new(cfg.clone(), Weights::random(&cfg, 7));
        let mut rng = Pcg32::seeded(2);
        let patches = crate::testutil::randn(&mut rng, &[cfg.vision_tokens(), cfg.patch_dim()]);
        let ids = vec![0; cfg.text_tokens];
        let a = model.forward_dense(&ids, &patches, 0.1);
        let b = model.forward_dense(&ids, &patches, 0.9);
        assert!(a.max_abs_diff(&b) > 1e-6, "t must influence the output");
    }

    #[test]
    fn text_changes_output() {
        let cfg = tiny_cfg();
        let model = MiniMMDiT::new(cfg.clone(), Weights::random(&cfg, 7));
        let mut rng = Pcg32::seeded(3);
        let patches = crate::testutil::randn(&mut rng, &[cfg.vision_tokens(), cfg.patch_dim()]);
        let a = model.forward_dense(&vec![1; cfg.text_tokens], &patches, 0.5);
        let b = model.forward_dense(&vec![9; cfg.text_tokens], &patches, 0.5);
        assert!(
            a.max_abs_diff(&b) > 1e-6,
            "prompt must influence the output (t→v attention works)"
        );
    }
}
