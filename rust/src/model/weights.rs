//! Weight container + `.fot` (de)serialization + random initialization.
//!
//! Weight names mirror `python/compile/model.py` exactly so the trained JAX
//! parameters load unambiguously.

use crate::config::ModelConfig;
use crate::tensor::Tensor;
use crate::util::fot::FotFile;
use crate::util::json::Json;
use crate::util::rng::Pcg32;

/// Per-stream (text or vision) block weights.
#[derive(Clone, Debug)]
pub struct StreamWeights {
    /// adaLN-zero conditioning projection `[dim × 6·dim]` (+bias).
    pub ada_w: Tensor,
    pub ada_b: Vec<f32>,
    pub wq: Tensor,
    pub bq: Vec<f32>,
    pub wk: Tensor,
    pub bk: Vec<f32>,
    pub wv: Tensor,
    pub bv: Vec<f32>,
    /// Learned per-head-feature RMSNorm scales for Q/K (`[head_dim]`).
    pub q_rms: Vec<f32>,
    pub k_rms: Vec<f32>,
    /// Attention output projection `[dim × dim]` (+bias).
    pub wo: Tensor,
    pub bo: Vec<f32>,
    pub mlp_w1: Tensor,
    pub mlp_b1: Vec<f32>,
    pub mlp_w2: Tensor,
    pub mlp_b2: Vec<f32>,
}

/// One double-stream MMDiT block.
#[derive(Clone, Debug)]
pub struct BlockWeights {
    pub txt: StreamWeights,
    pub img: StreamWeights,
}

/// All model weights.
#[derive(Clone, Debug)]
pub struct Weights {
    pub cfg: ModelConfig,
    /// Text hash-embedding table `[vocab × dim]`.
    pub text_embed: Tensor,
    /// Patch embedding `[patch_dim × dim]` (+bias).
    pub patch_w: Tensor,
    pub patch_b: Vec<f32>,
    /// Timestep-conditioning MLP.
    pub time_w1: Tensor,
    pub time_b1: Vec<f32>,
    pub time_w2: Tensor,
    pub time_b2: Vec<f32>,
    pub blocks: Vec<BlockWeights>,
    /// Final adaLN `[dim × 2·dim]` and decode `[dim × patch_dim]`.
    pub final_ada_w: Tensor,
    pub final_ada_b: Vec<f32>,
    pub final_w: Tensor,
    pub final_b: Vec<f32>,
}

fn randt(rng: &mut Pcg32, shape: &[usize], scale: f32) -> Tensor {
    let n: usize = shape.iter().product();
    Tensor::from_vec(shape, (0..n).map(|_| rng.normal() * scale).collect())
}

impl StreamWeights {
    fn random(cfg: &ModelConfig, rng: &mut Pcg32) -> Self {
        let d = cfg.dim;
        let hd = cfg.head_dim();
        let m = cfg.mlp_ratio * d;
        let s = 1.0 / (d as f32).sqrt();
        StreamWeights {
            ada_w: randt(rng, &[d, 6 * d], s * 0.1),
            ada_b: vec![0.0; 6 * d],
            wq: randt(rng, &[d, d], s),
            bq: vec![0.0; d],
            wk: randt(rng, &[d, d], s),
            bk: vec![0.0; d],
            wv: randt(rng, &[d, d], s),
            bv: vec![0.0; d],
            q_rms: vec![1.0; hd],
            k_rms: vec![1.0; hd],
            wo: randt(rng, &[d, d], s),
            bo: vec![0.0; d],
            mlp_w1: randt(rng, &[d, m], s),
            mlp_b1: vec![0.0; m],
            mlp_w2: randt(rng, &[m, d], 1.0 / (m as f32).sqrt()),
            mlp_b2: vec![0.0; d],
        }
    }

    fn param_count(&self) -> usize {
        self.ada_w.numel()
            + self.ada_b.len()
            + self.wq.numel() * 3
            + self.bq.len() * 3
            + self.q_rms.len() * 2
            + self.wo.numel()
            + self.bo.len()
            + self.mlp_w1.numel()
            + self.mlp_b1.len()
            + self.mlp_w2.numel()
            + self.mlp_b2.len()
    }
}

impl Weights {
    /// Random (untrained) weights — used by unit tests and the kernel
    /// benches; the shipped artifact is trained in JAX.
    pub fn random(cfg: &ModelConfig, seed: u64) -> Self {
        let mut rng = Pcg32::seeded(seed);
        let d = cfg.dim;
        let s = 1.0 / (d as f32).sqrt();
        Weights {
            cfg: cfg.clone(),
            text_embed: randt(&mut rng, &[cfg.vocab, d], 0.02),
            patch_w: randt(&mut rng, &[cfg.patch_dim(), d], s),
            patch_b: vec![0.0; d],
            time_w1: randt(&mut rng, &[d, d], s),
            time_b1: vec![0.0; d],
            time_w2: randt(&mut rng, &[d, d], s),
            time_b2: vec![0.0; d],
            blocks: (0..cfg.layers)
                .map(|_| BlockWeights {
                    txt: StreamWeights::random(cfg, &mut rng),
                    img: StreamWeights::random(cfg, &mut rng),
                })
                .collect(),
            final_ada_w: randt(&mut rng, &[d, 2 * d], s * 0.1),
            final_ada_b: vec![0.0; 2 * d],
            final_w: randt(&mut rng, &[d, cfg.patch_dim()], s),
            final_b: vec![0.0; cfg.patch_dim()],
        }
    }

    pub fn param_count(&self) -> usize {
        self.text_embed.numel()
            + self.patch_w.numel()
            + self.patch_b.len()
            + self.time_w1.numel()
            + self.time_b1.len()
            + self.time_w2.numel()
            + self.time_b2.len()
            + self
                .blocks
                .iter()
                .map(|b| b.txt.param_count() + b.img.param_count())
                .sum::<usize>()
            + self.final_ada_w.numel()
            + self.final_ada_b.len()
            + self.final_w.numel()
            + self.final_b.len()
    }

    /// Serialize into a `.fot` file (same names as the python exporter).
    pub fn to_fot(&self) -> FotFile {
        let mut f = FotFile::new();
        let put = |f: &mut FotFile, name: &str, t: &Tensor| {
            f.insert_f32(name, t.shape(), t.data());
        };
        let putv = |f: &mut FotFile, name: &str, v: &[f32]| {
            f.insert_f32(name, &[v.len()], v);
        };
        put(&mut f, "text_embed", &self.text_embed);
        put(&mut f, "patch_embed.w", &self.patch_w);
        putv(&mut f, "patch_embed.b", &self.patch_b);
        put(&mut f, "time_mlp.w1", &self.time_w1);
        putv(&mut f, "time_mlp.b1", &self.time_b1);
        put(&mut f, "time_mlp.w2", &self.time_w2);
        putv(&mut f, "time_mlp.b2", &self.time_b2);
        for (i, b) in self.blocks.iter().enumerate() {
            for (s, sw) in [("txt", &b.txt), ("img", &b.img)] {
                let p = format!("blocks.{i}.{s}");
                put(&mut f, &format!("{p}.ada.w"), &sw.ada_w);
                putv(&mut f, &format!("{p}.ada.b"), &sw.ada_b);
                put(&mut f, &format!("{p}.wq"), &sw.wq);
                putv(&mut f, &format!("{p}.bq"), &sw.bq);
                put(&mut f, &format!("{p}.wk"), &sw.wk);
                putv(&mut f, &format!("{p}.bk"), &sw.bk);
                put(&mut f, &format!("{p}.wv"), &sw.wv);
                putv(&mut f, &format!("{p}.bv"), &sw.bv);
                putv(&mut f, &format!("{p}.q_rms"), &sw.q_rms);
                putv(&mut f, &format!("{p}.k_rms"), &sw.k_rms);
                put(&mut f, &format!("{p}.wo"), &sw.wo);
                putv(&mut f, &format!("{p}.bo"), &sw.bo);
                put(&mut f, &format!("{p}.mlp.w1"), &sw.mlp_w1);
                putv(&mut f, &format!("{p}.mlp.b1"), &sw.mlp_b1);
                put(&mut f, &format!("{p}.mlp.w2"), &sw.mlp_w2);
                putv(&mut f, &format!("{p}.mlp.b2"), &sw.mlp_b2);
            }
        }
        put(&mut f, "final.ada.w", &self.final_ada_w);
        putv(&mut f, "final.ada.b", &self.final_ada_b);
        put(&mut f, "final.w", &self.final_w);
        putv(&mut f, "final.b", &self.final_b);
        f.meta.insert("config".into(), self.cfg.to_json());
        f.meta.insert("format".into(), Json::Str("minimmdit-v1".into()));
        f
    }

    /// Load from a `.fot` file produced by `to_fot` or the python exporter.
    pub fn from_fot(f: &FotFile) -> Result<Self, String> {
        let cfg = ModelConfig::from_json(
            f.meta.get("config").ok_or("weights file missing config meta")?,
        )?;
        let t = |name: &str| -> Result<Tensor, String> { Tensor::from_fot(f, name) };
        let v = |name: &str| -> Result<Vec<f32>, String> { Ok(f.get(name)?.to_f32()?) };
        let stream = |p: &str| -> Result<StreamWeights, String> {
            Ok(StreamWeights {
                ada_w: t(&format!("{p}.ada.w"))?,
                ada_b: v(&format!("{p}.ada.b"))?,
                wq: t(&format!("{p}.wq"))?,
                bq: v(&format!("{p}.bq"))?,
                wk: t(&format!("{p}.wk"))?,
                bk: v(&format!("{p}.bk"))?,
                wv: t(&format!("{p}.wv"))?,
                bv: v(&format!("{p}.bv"))?,
                q_rms: v(&format!("{p}.q_rms"))?,
                k_rms: v(&format!("{p}.k_rms"))?,
                wo: t(&format!("{p}.wo"))?,
                bo: v(&format!("{p}.bo"))?,
                mlp_w1: t(&format!("{p}.mlp.w1"))?,
                mlp_b1: v(&format!("{p}.mlp.b1"))?,
                mlp_w2: t(&format!("{p}.mlp.w2"))?,
                mlp_b2: v(&format!("{p}.mlp.b2"))?,
            })
        };
        let blocks = (0..cfg.layers)
            .map(|i| {
                Ok(BlockWeights {
                    txt: stream(&format!("blocks.{i}.txt"))?,
                    img: stream(&format!("blocks.{i}.img"))?,
                })
            })
            .collect::<Result<Vec<_>, String>>()?;
        Ok(Weights {
            text_embed: t("text_embed")?,
            patch_w: t("patch_embed.w")?,
            patch_b: v("patch_embed.b")?,
            time_w1: t("time_mlp.w1")?,
            time_b1: v("time_mlp.b1")?,
            time_w2: t("time_mlp.w2")?,
            time_b2: v("time_mlp.b2")?,
            blocks,
            final_ada_w: t("final.ada.w")?,
            final_ada_b: v("final.ada.b")?,
            final_w: t("final.w")?,
            final_b: v("final.b")?,
            cfg,
        })
    }

    pub fn save(&self, path: &str) -> Result<(), String> {
        self.to_fot().save(path)
    }

    pub fn load(path: &str) -> Result<Self, String> {
        Self::from_fot(&FotFile::load(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fot_roundtrip_preserves_weights() {
        let cfg = ModelConfig {
            dim: 16,
            heads: 2,
            layers: 2,
            text_tokens: 4,
            patch_h: 2,
            patch_w: 2,
            patch_size: 2,
            channels: 3,
            mlp_ratio: 2,
            vocab: 8,
        };
        let w = Weights::random(&cfg, 3);
        let f = w.to_fot();
        let w2 = Weights::from_fot(&f).unwrap();
        assert_eq!(w.cfg, w2.cfg);
        assert_eq!(w.text_embed, w2.text_embed);
        assert_eq!(w.blocks[1].img.mlp_w2, w2.blocks[1].img.mlp_w2);
        assert_eq!(w.final_b, w2.final_b);
        assert_eq!(w.param_count(), w2.param_count());
    }

    #[test]
    fn mini_param_count_in_range() {
        let cfg = ModelConfig::mini();
        let w = Weights::random(&cfg, 1);
        let p = w.param_count();
        // ~2.4M parameters for the shipped config.
        assert!(p > 1_000_000 && p < 5_000_000, "params = {p}");
    }
}
