//! MMDiT block building blocks, shared between the dense reference path
//! and the FlashOmni sparse engine.
//!
//! The attention stage is factored so the engine can substitute sparse
//! kernels tile-by-tile:
//!
//! ```text
//! x ──LN──modulate──► x_mod ──GEMM-Q/K/V──► q,k,v ──headwise RMS+RoPE──►
//!   joint attention per head ──► O_cat ──GEMM-O──► attn_out
//!   x += gate₁ ⊙ attn_out ;  x += gate₂ ⊙ MLP(modulate(LN(x)))
//! ```

use crate::config::ModelConfig;
use crate::kernels::attention::attention_dense;
use crate::kernels::elementwise::{gated_add, gelu, layernorm, modulate, rope, silu};
use crate::kernels::gemm::matmul;
use crate::model::{BlockWeights, StreamWeights, Weights};
use crate::tensor::Tensor;

/// RoPE frequency base (matches the JAX model).
pub const ROPE_THETA: f32 = 10_000.0;
/// LayerNorm epsilon.
pub const LN_EPS: f32 = 1e-6;
/// RMSNorm epsilon.
pub const RMS_EPS: f32 = 1e-6;

/// `y = x·W + b`.
pub fn linear(x: &Tensor, w: &Tensor, b: &[f32]) -> Tensor {
    let mut y = matmul(x, w);
    let d = y.cols();
    assert_eq!(b.len(), d);
    for r in 0..y.rows() {
        let row = y.row_mut(r);
        for c in 0..d {
            row[c] += b[c];
        }
    }
    y
}

/// Sinusoidal timestep features (dim = model dim; `t` scaled by 1000).
pub fn timestep_features(cfg: &ModelConfig, t: f64) -> Vec<f32> {
    let d = cfg.dim;
    let half = d / 2;
    let ts = (t * 1000.0) as f32;
    let mut out = vec![0.0f32; d];
    for i in 0..half {
        let freq = (-(10_000.0f32).ln() * i as f32 / half as f32).exp();
        out[i] = (ts * freq).cos();
        out[half + i] = (ts * freq).sin();
    }
    out
}

/// Timestep conditioning vector `c = W₂·silu(W₁·sin_emb + b₁) + b₂`.
pub fn timestep_conditioning(w: &Weights, cfg: &ModelConfig, t: f64) -> Vec<f32> {
    let emb = Tensor::from_vec(&[1, cfg.dim], timestep_features(cfg, t));
    let mut h = linear(&emb, &w.time_w1, &w.time_b1);
    silu(&mut h);
    linear(&h, &w.time_w2, &w.time_b2).into_vec()
}

/// adaLN-zero: project `silu(c)` to 6 per-feature vectors
/// `(shift1, scale1, gate1, shift2, scale2, gate2)`.
pub fn adaln6(sw: &StreamWeights, cvec: &[f32]) -> [Vec<f32>; 6] {
    let d = cvec.len();
    let mut c = Tensor::from_vec(&[1, d], cvec.to_vec());
    silu(&mut c);
    let a = linear(&c, &sw.ada_w, &sw.ada_b).into_vec();
    let chunk = |i: usize| a[i * d..(i + 1) * d].to_vec();
    [chunk(0), chunk(1), chunk(2), chunk(3), chunk(4), chunk(5)]
}

/// Final-layer adaLN: `(shift, scale)`.
pub fn adaln2(w: &Weights, cvec: &[f32]) -> (Vec<f32>, Vec<f32>) {
    let d = cvec.len();
    let mut c = Tensor::from_vec(&[1, d], cvec.to_vec());
    silu(&mut c);
    let a = linear(&c, &w.final_ada_w, &w.final_ada_b).into_vec();
    (a[..d].to_vec(), a[d..].to_vec())
}

/// Headwise RMSNorm: normalize each `[head_dim]` slice of every row and
/// multiply by the learned scale.
pub fn headwise_rmsnorm(x: &mut Tensor, heads: usize, scale: &[f32]) {
    let d = x.cols();
    assert_eq!(d % heads, 0);
    let hd = d / heads;
    assert_eq!(scale.len(), hd);
    for r in 0..x.rows() {
        let row = x.row_mut(r);
        for h in 0..heads {
            let seg = &mut row[h * hd..(h + 1) * hd];
            let mut ss = 0.0f32;
            for &v in seg.iter() {
                ss += v * v;
            }
            let inv = 1.0 / (ss / hd as f32 + RMS_EPS).sqrt();
            for (v, &s) in seg.iter_mut().zip(scale) {
                *v = *v * inv * s;
            }
        }
    }
}

/// Headwise RoPE: rotate each `[head_dim]` slice with 1-D positions.
pub fn headwise_rope(x: &mut Tensor, heads: usize, positions: &[usize]) {
    let d = x.cols();
    let hd = d / heads;
    let n = x.rows();
    assert_eq!(positions.len(), n);
    // Reuse the single-head rope on per-head temporaries.
    let mut tmp = Tensor::zeros(&[n, hd]);
    for h in 0..heads {
        for r in 0..n {
            tmp.row_mut(r).copy_from_slice(&x.row(r)[h * hd..(h + 1) * hd]);
        }
        rope(&mut tmp, positions, ROPE_THETA);
        for r in 0..n {
            x.row_mut(r)[h * hd..(h + 1) * hd].copy_from_slice(tmp.row(r));
        }
    }
}

/// Vertically stack two `[·, d]` tensors.
pub fn vstack(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.cols(), b.cols());
    let mut data = Vec::with_capacity(a.numel() + b.numel());
    data.extend_from_slice(a.data());
    data.extend_from_slice(b.data());
    Tensor::from_vec(&[a.rows() + b.rows(), a.cols()], data)
}

/// Vertically stack any number of `[·, d]` tensors (row counts may
/// differ; column counts must agree). An empty list yields `[0 × 0]`.
/// The ragged batch path uses this to build the concatenated token
/// buffers its cu-seqlen kernels walk.
pub fn vstack_all(ts: &[&Tensor]) -> Tensor {
    let Some(first) = ts.first() else {
        return Tensor::zeros(&[0, 0]);
    };
    let d = first.cols();
    let rows: usize = ts.iter().map(|t| t.rows()).sum();
    let mut data = Vec::with_capacity(rows * d);
    for t in ts {
        assert_eq!(t.cols(), d, "vstack_all: column counts must agree");
        data.extend_from_slice(t.data());
    }
    Tensor::from_vec(&[rows, d], data)
}

/// Split rows `[0, t)` and `[t, n)`.
pub fn vsplit(x: &Tensor, t: usize) -> (Tensor, Tensor) {
    let d = x.cols();
    let n = x.rows();
    (
        Tensor::from_vec(&[t, d], x.data()[..t * d].to_vec()),
        Tensor::from_vec(&[n - t, d], x.data()[t * d..].to_vec()),
    )
}

/// Copy head `h` of `[n × heads·hd]` into a contiguous `[n × hd]` tensor.
pub fn extract_head(x: &Tensor, heads: usize, h: usize) -> Tensor {
    let d = x.cols();
    let hd = d / heads;
    let n = x.rows();
    let mut out = Tensor::zeros(&[n, hd]);
    for r in 0..n {
        out.row_mut(r).copy_from_slice(&x.row(r)[h * hd..(h + 1) * hd]);
    }
    out
}

/// Write head `h` back into the concatenated layout.
pub fn insert_head(dst: &mut Tensor, src: &Tensor, heads: usize, h: usize) {
    let d = dst.cols();
    let hd = d / heads;
    for r in 0..dst.rows() {
        dst.row_mut(r)[h * hd..(h + 1) * hd].copy_from_slice(src.row(r));
    }
}

/// Pre-attention stage shared by dense and sparse paths: LN + modulate per
/// stream, returning the modulated streams and the adaLN parameter sets.
pub struct PreAttn {
    pub txt_mod: Tensor,
    pub img_mod: Tensor,
    pub ada_txt: [Vec<f32>; 6],
    pub ada_img: [Vec<f32>; 6],
}

pub fn pre_attention(
    bw: &BlockWeights,
    cvec: &[f32],
    txt: &Tensor,
    img: &Tensor,
) -> PreAttn {
    let ada_txt = adaln6(&bw.txt, cvec);
    let ada_img = adaln6(&bw.img, cvec);
    let mut txt_mod = txt.clone();
    layernorm(&mut txt_mod, LN_EPS);
    modulate(&mut txt_mod, &ada_txt[0], &ada_txt[1]);
    let mut img_mod = img.clone();
    layernorm(&mut img_mod, LN_EPS);
    modulate(&mut img_mod, &ada_img[0], &ada_img[1]);
    PreAttn { txt_mod, img_mod, ada_txt, ada_img }
}

/// Project + normalize + rotate the joint Q/K/V from modulated streams
/// (dense path — the sparse engine uses GEMM-Q for the query instead).
pub fn qkv_joint(
    bw: &BlockWeights,
    cfg: &ModelConfig,
    txt_mod: &Tensor,
    img_mod: &Tensor,
) -> (Tensor, Tensor, Tensor) {
    let heads = cfg.heads;
    let mut q_t = linear(txt_mod, &bw.txt.wq, &bw.txt.bq);
    let mut k_t = linear(txt_mod, &bw.txt.wk, &bw.txt.bk);
    let v_t = linear(txt_mod, &bw.txt.wv, &bw.txt.bv);
    let mut q_i = linear(img_mod, &bw.img.wq, &bw.img.bq);
    let mut k_i = linear(img_mod, &bw.img.wk, &bw.img.bk);
    let v_i = linear(img_mod, &bw.img.wv, &bw.img.bv);
    headwise_rmsnorm(&mut q_t, heads, &bw.txt.q_rms);
    headwise_rmsnorm(&mut k_t, heads, &bw.txt.k_rms);
    headwise_rmsnorm(&mut q_i, heads, &bw.img.q_rms);
    headwise_rmsnorm(&mut k_i, heads, &bw.img.k_rms);
    let mut q = vstack(&q_t, &q_i);
    let mut k = vstack(&k_t, &k_i);
    let v = vstack(&v_t, &v_i);
    let positions: Vec<usize> = (0..cfg.seq_len()).collect();
    headwise_rope(&mut q, heads, &positions);
    headwise_rope(&mut k, heads, &positions);
    (q, k, v)
}

/// Normalize + rotate an already-projected joint Q (sparse GEMM-Q path).
/// Cached rows hold zeros; RMS-norm of a zero vector stays zero (eps), and
/// RoPE is a rotation, so cached rows remain zero and are never read.
pub fn norm_rope_joint_q(
    q: &mut Tensor,
    bw: &BlockWeights,
    cfg: &ModelConfig,
    text_rows: usize,
) {
    let heads = cfg.heads;
    let (mut q_t, mut q_i) = vsplit(q, text_rows);
    headwise_rmsnorm(&mut q_t, heads, &bw.txt.q_rms);
    headwise_rmsnorm(&mut q_i, heads, &bw.img.q_rms);
    *q = vstack(&q_t, &q_i);
    let positions: Vec<usize> = (0..cfg.seq_len()).collect();
    headwise_rope(q, heads, &positions);
}

/// Dense joint attention over all heads → concatenated `[N × dim]` output,
/// dispatched on an explicit [`ExecPool`](crate::exec::ExecPool) (no
/// per-call thread spawn); results are placed by head index, so the output
/// is bit-identical to the sequential loop. The engine passes its
/// configured pool here so a custom `DiTEngine::set_exec_pool` governs the
/// dense path too.
pub fn joint_attention_dense_on(
    pool: &crate::exec::ExecPool,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    heads: usize,
    block: usize,
) -> Tensor {
    let per_head: Vec<Tensor> = pool.parallel_map_indexed(heads, |h| {
        let qh = extract_head(q, heads, h);
        let kh = extract_head(k, heads, h);
        let vh = extract_head(v, heads, h);
        attention_dense(&qh, &kh, &vh, block, block)
    });
    let mut o = Tensor::zeros(&[q.rows(), q.cols()]);
    for (h, oh) in per_head.iter().enumerate() {
        insert_head(&mut o, oh, heads, h);
    }
    o
}

/// [`joint_attention_dense_on`] on the process-wide global pool — the
/// reference path for standalone model execution (`block_dense`).
pub fn joint_attention_dense(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    heads: usize,
    block: usize,
) -> Tensor {
    joint_attention_dense_on(&crate::exec::ExecPool::global(), q, k, v, heads, block)
}

/// Post-attention stage: per-stream output projection + gated residual.
pub fn post_attention(
    bw: &BlockWeights,
    pre: &PreAttn,
    o_cat: &Tensor,
    txt: &mut Tensor,
    img: &mut Tensor,
) {
    let t = txt.rows();
    let (o_t, o_i) = vsplit(o_cat, t);
    let attn_t = linear(&o_t, &bw.txt.wo, &bw.txt.bo);
    let attn_i = linear(&o_i, &bw.img.wo, &bw.img.bo);
    gated_add(txt, &pre.ada_txt[2], &attn_t);
    gated_add(img, &pre.ada_img[2], &attn_i);
}

/// Per-stream MLP with adaLN modulation and gated residual.
pub fn mlp_stream(sw: &StreamWeights, ada: &[Vec<f32>; 6], x: &mut Tensor) {
    let mut h = x.clone();
    layernorm(&mut h, LN_EPS);
    modulate(&mut h, &ada[3], &ada[4]);
    let mut y = linear(&h, &sw.mlp_w1, &sw.mlp_b1);
    gelu(&mut y);
    let y = linear(&y, &sw.mlp_w2, &sw.mlp_b2);
    gated_add(x, &ada[5], &y);
}

/// Full dense block (the reference executor).
pub fn block_dense(
    bw: &BlockWeights,
    cfg: &ModelConfig,
    cvec: &[f32],
    txt: &mut Tensor,
    img: &mut Tensor,
) {
    let pre = pre_attention(bw, cvec, txt, img);
    let (q, k, v) = qkv_joint(bw, cfg, &pre.txt_mod, &pre.img_mod);
    let o = joint_attention_dense(&q, &k, &v, cfg.heads, 16);
    post_attention(bw, &pre, &o, txt, img);
    mlp_stream(&bw.txt, &pre.ada_txt, txt);
    mlp_stream(&bw.img, &pre.ada_img, img);
}

/// Final layer: LN + modulate + decode to per-patch velocity.
pub fn final_layer(w: &Weights, _cfg: &ModelConfig, cvec: &[f32], img: &Tensor) -> Tensor {
    let (shift, scale) = adaln2(w, cvec);
    let mut h = img.clone();
    layernorm(&mut h, LN_EPS);
    modulate(&mut h, &shift, &scale);
    linear(&h, &w.final_w, &w.final_b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::weights::Weights;
    use crate::testutil::{assert_close, randn};
    use crate::util::rng::Pcg32;

    fn cfg() -> ModelConfig {
        ModelConfig {
            dim: 32,
            heads: 2,
            layers: 1,
            text_tokens: 4,
            patch_h: 4,
            patch_w: 4,
            patch_size: 2,
            channels: 3,
            mlp_ratio: 2,
            vocab: 16,
        }
    }

    #[test]
    fn vstack_vsplit_roundtrip() {
        let mut rng = Pcg32::seeded(1);
        let a = randn(&mut rng, &[3, 5]);
        let b = randn(&mut rng, &[7, 5]);
        let s = vstack(&a, &b);
        let (a2, b2) = vsplit(&s, 3);
        assert_eq!(a, a2);
        assert_eq!(b, b2);
    }

    #[test]
    fn head_extract_insert_roundtrip() {
        let mut rng = Pcg32::seeded(2);
        let x = randn(&mut rng, &[5, 8]);
        let mut y = Tensor::zeros(&[5, 8]);
        for h in 0..2 {
            let xh = extract_head(&x, 2, h);
            assert_eq!(xh.shape(), &[5, 4]);
            insert_head(&mut y, &xh, 2, h);
        }
        assert_eq!(x, y);
    }

    #[test]
    fn qkv_matches_norm_rope_on_gemm_q_output() {
        // The sparse path (project → norm_rope_joint_q) must equal the
        // dense path when no tile is skipped.
        let cfg = cfg();
        let w = Weights::random(&cfg, 5);
        let bw = &w.blocks[0];
        let mut rng = Pcg32::seeded(3);
        let txt_mod = randn(&mut rng, &[cfg.text_tokens, cfg.dim]);
        let img_mod = randn(&mut rng, &[cfg.vision_tokens(), cfg.dim]);
        let (q_dense, _, _) = qkv_joint(bw, &cfg, &txt_mod, &img_mod);
        let q_t = linear(&txt_mod, &bw.txt.wq, &bw.txt.bq);
        let q_i = linear(&img_mod, &bw.img.wq, &bw.img.bq);
        let mut q_sparse = vstack(&q_t, &q_i);
        norm_rope_joint_q(&mut q_sparse, bw, &cfg, cfg.text_tokens);
        assert_close(&q_sparse, &q_dense, 1e-5, 1e-5);
    }

    #[test]
    fn zero_rows_stay_zero_through_norm_rope() {
        let cfg = cfg();
        let w = Weights::random(&cfg, 6);
        let bw = &w.blocks[0];
        let n = cfg.seq_len();
        let mut q = Tensor::zeros(&[n, cfg.dim]);
        // Fill only the text rows; vision rows (as if cached) stay zero.
        let mut rng = Pcg32::seeded(4);
        for r in 0..cfg.text_tokens {
            for c in 0..cfg.dim {
                q.row_mut(r)[c] = rng.normal();
            }
        }
        norm_rope_joint_q(&mut q, bw, &cfg, cfg.text_tokens);
        for r in cfg.text_tokens..n {
            assert!(q.row(r).iter().all(|&x| x == 0.0), "row {r} not zero");
        }
    }

    #[test]
    fn timestep_features_distinct() {
        let cfg = cfg();
        let a = timestep_features(&cfg, 0.1);
        let b = timestep_features(&cfg, 0.9);
        assert_ne!(a, b);
        assert_eq!(a.len(), cfg.dim);
    }

    #[test]
    fn block_dense_finite_and_text_vision_coupled() {
        let cfg = cfg();
        let w = Weights::random(&cfg, 7);
        let mut rng = Pcg32::seeded(5);
        let mut txt = randn(&mut rng, &[cfg.text_tokens, cfg.dim]);
        let mut img = randn(&mut rng, &[cfg.vision_tokens(), cfg.dim]);
        let img0 = img.clone();
        let cvec = vec![0.1; cfg.dim];
        block_dense(&w.blocks[0], &cfg, &cvec, &mut txt, &mut img);
        assert!(txt.data().iter().all(|x| x.is_finite()));
        assert!(img.max_abs_diff(&img0) > 0.0);
    }
}
