//! Router acceptance tests (tentpole PR):
//!
//! (a) **streaming previews** — with a preview interval K, the engine
//!     emits a decode every K completed steps, and each preview is
//!     **bitwise-identical** to a solo `DiTEngine` run truncated to the
//!     same step prefix (previews are prefixes of the final decode),
//! (b) **admission control** — the in-flight permit cap sheds excess
//!     submits with `Rejected::Overloaded` instead of queueing without
//!     bound, and every non-shed request still completes,
//! (c) **deadlines** — a request whose deadline passes while queued is
//!     retired with `Rejected::DeadlineExceeded` at claim time, before it
//!     can consume a batch slot,
//! (d) **priorities** — interactive jobs are claimed strictly before
//!     bulk jobs,
//! (e) **close semantics** — accepted requests drain, new submits are
//!     refused with `Rejected::Closed`.

use flashomni::batch::BatchedEngine;
use flashomni::config::{ModelConfig, SparsityConfig};
use flashomni::diffusion::{initial_noise, plan_steps, time_grid};
use flashomni::engine::{DiTEngine, Policy};
use flashomni::model::{weights::Weights, MiniMMDiT};
use flashomni::router::{
    Priority, Rejected, RequestEvent, Router, RouterConfig, SubmitOptions,
};
use flashomni::tensor::Tensor;
use flashomni::workload::{caption_ids, Request};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

fn tiny_model(layers: usize, seed: u64) -> MiniMMDiT {
    let cfg = ModelConfig {
        dim: 32,
        heads: 2,
        layers,
        text_tokens: 8,
        patch_h: 4,
        patch_w: 4,
        patch_size: 2,
        channels: 3,
        mlp_ratio: 2,
        vocab: 256,
    };
    MiniMMDiT::new(cfg.clone(), Weights::random(&cfg, seed))
}

fn fo_policy(interval: usize, warmup: usize) -> Policy {
    Policy::flashomni(SparsityConfig {
        tau_q: 0.6,
        tau_kv: 0.3,
        interval,
        order: 1,
        s_q: 0.0,
        block_q: 8,
        block_k: 8,
        pool: 1,
        warmup,
        ramp_steps: 1,
    })
}

fn request(id: u64, scene: usize, seed: u64, steps: usize) -> Request {
    Request {
        id,
        scene,
        prompt_ids: caption_ids(scene, 8),
        seed,
        steps,
        arrival_s: 0.0,
        patch_hw: None,
    }
}

/// Solo decode of the first `k` of `steps` denoising steps — the
/// reference a preview at step `k` must match bitwise.
fn solo_prefix(
    model: &MiniMMDiT,
    policy: &Policy,
    req: &Request,
    warmup: usize,
    interval: usize,
    k: usize,
) -> Tensor {
    let mut engine = DiTEngine::new(
        MiniMMDiT::new(model.cfg.clone(), model.w.clone()),
        policy.clone(),
        8,
        8,
    );
    let grid = time_grid(req.steps);
    let plan = plan_steps(req.steps, warmup.min(req.steps), interval);
    let x = initial_noise(&model.cfg, req.seed);
    engine.generate_with_grid(&req.prompt_ids, x, &grid[..=k], &plan[..k]).image
}

// ---------------------------------------------------------------- (a) --

#[test]
fn previews_are_bitwise_prefixes_of_final_decode() {
    let model = tiny_model(1, 11);
    let (warmup, interval) = (2, 3);
    let policy = fo_policy(interval, warmup);
    let steps = 9;
    let req = request(0, 1, 42, steps);

    let mut engine = BatchedEngine::new(model.clone(), policy.clone(), 8, 8, 2);
    engine.set_preview_interval(2);
    engine.admit(req.clone(), Instant::now());
    let out = engine.run_to_completion();
    let previews = engine.take_previews();

    // Every 2nd completed step previews, except the final one (its decode
    // is the BatchResult image): steps 2, 4, 6, 8.
    assert_eq!(previews.iter().map(|p| p.step).collect::<Vec<_>>(), vec![2, 4, 6, 8]);
    for p in &previews {
        assert_eq!(p.id, req.id);
        assert_eq!(p.steps, steps);
        let solo = solo_prefix(&model, &policy, &req, warmup, interval, p.step);
        assert_eq!(
            p.image, solo,
            "preview at step {} must be bitwise-identical to the solo prefix decode",
            p.step
        );
    }
    // And the final image is the full solo run — previews really are
    // prefixes of it, not of some divergent trajectory.
    let full = solo_prefix(&model, &policy, &req, warmup, interval, steps);
    assert_eq!(out[0].image, full);
}

#[test]
fn router_streams_previews_before_the_terminal_event() {
    let model = tiny_model(1, 5);
    let (warmup, interval) = (1, 3);
    let policy = fo_policy(interval, warmup);
    let steps = 7;
    let mut cfg = RouterConfig::new(1, 2);
    cfg.preview_interval = 3;
    let m = model.clone();
    let p = policy.clone();
    let router = Router::start(
        move |_| DiTEngine::new(MiniMMDiT::new(m.cfg.clone(), m.w.clone()), p.clone(), 8, 8),
        cfg,
    );
    let req = request(0, 2, 7, steps);
    let handle = router.submit(req.clone(), SubmitOptions::interactive()).expect("admitted");
    let (result, previews) = handle.wait();
    let resp = result.expect("request must complete");
    // Previews at steps 3 and 6 (7 % 3 ≠ 0, so the final step never
    // collides with a preview), streamed before Done.
    assert_eq!(previews.iter().map(|p| p.step).collect::<Vec<_>>(), vec![3, 6]);
    for p in &previews {
        let solo = solo_prefix(&model, &policy, &req, warmup, interval, p.step);
        assert_eq!(p.image, solo, "router preview at step {} diverged from solo", p.step);
    }
    assert_eq!(resp.image, solo_prefix(&model, &policy, &req, warmup, interval, steps));
    router.shutdown();
}

// ---------------------------------------------------------------- (b) --

#[test]
fn router_sheds_on_overload_and_serves_the_rest() {
    let model = tiny_model(1, 3);
    let cfg = RouterConfig {
        workers: 1,
        max_batch: 1,
        max_in_flight: 2,
        queue_cap: 2,
        preview_interval: 0,
    };
    let m = model.clone();
    let router = Router::start(
        move |_| {
            DiTEngine::new(MiniMMDiT::new(m.cfg.clone(), m.w.clone()), Policy::full(), 8, 8)
        },
        cfg,
    );
    let mut handles = Vec::new();
    let mut shed = 0usize;
    for id in 0..6u64 {
        match router.submit(request(id, 1 + id as usize, id, 4), SubmitOptions::interactive()) {
            Ok(h) => handles.push(h),
            Err(Rejected::Overloaded { in_flight, .. }) => {
                assert!(in_flight <= 2, "overload snapshot cannot exceed the cap");
                shed += 1;
            }
            Err(other) => panic!("unexpected rejection: {other}"),
        }
    }
    // 6 back-to-back submits against an in-flight cap of 2: at least 4
    // must shed immediately (a permit only frees when a request finishes,
    // which takes real engine work).
    assert!(shed >= 1, "overload must shed");
    assert_eq!(handles.len() + shed, 6);
    for h in handles {
        let id = h.id;
        let (result, _) = h.wait();
        result.unwrap_or_else(|e| panic!("admitted request {id} must be served, got: {e}"));
    }
    router.shutdown();
}

// ---------------------------------------------------------------- (c) --

#[test]
fn expired_deadline_rejects_before_consuming_a_slot() {
    let model = tiny_model(1, 9);
    let cfg = RouterConfig {
        workers: 1,
        max_batch: 1,
        max_in_flight: 8,
        queue_cap: 8,
        preview_interval: 0,
    };
    let m = model.clone();
    let router = Router::start(
        move |_| {
            DiTEngine::new(MiniMMDiT::new(m.cfg.clone(), m.w.clone()), Policy::full(), 8, 8)
        },
        cfg,
    );
    // A long request occupies the single batch slot...
    let blocker =
        router.submit(request(0, 1, 5, 8), SubmitOptions::interactive()).expect("admitted");
    // ...and a request whose deadline is effectively already over waits
    // behind it. By the time any worker can claim it, it has expired —
    // it must be rejected at claim time, never executed.
    let doomed = router
        .submit(
            request(1, 2, 6, 4),
            SubmitOptions::interactive().with_deadline(Duration::from_nanos(1)),
        )
        .expect("admission itself succeeds; the deadline bites at claim time");
    let (doomed_result, doomed_previews) = doomed.wait();
    match doomed_result {
        Err(Rejected::DeadlineExceeded { waited_s }) => assert!(waited_s >= 0.0),
        other => panic!("expected DeadlineExceeded, got {other:?}"),
    }
    assert!(doomed_previews.is_empty(), "an expired request must never start executing");
    let (blocker_result, _) = blocker.wait();
    assert!(blocker_result.is_ok(), "the in-flight request is never killed by others' deadlines");
    router.shutdown();
}

// ---------------------------------------------------------------- (d) --

#[test]
fn interactive_jobs_are_claimed_before_bulk_jobs() {
    let model = tiny_model(1, 13);
    let cfg = RouterConfig {
        workers: 1,
        max_batch: 1,
        max_in_flight: 8,
        queue_cap: 8,
        preview_interval: 0,
    };
    let m = model.clone();
    let router = Router::start(
        move |_| {
            DiTEngine::new(MiniMMDiT::new(m.cfg.clone(), m.w.clone()), Policy::full(), 8, 8)
        },
        cfg,
    );
    // Occupy the worker so the next two submits queue up...
    let blocker =
        router.submit(request(0, 1, 1, 12), SubmitOptions::interactive()).expect("admitted");
    // ...then enqueue bulk BEFORE interactive. The interactive job must
    // still finish first (strict class priority, not FIFO across classes).
    let bulk = router.submit(request(1, 2, 2, 2), SubmitOptions::bulk()).expect("admitted");
    let inter =
        router.submit(request(2, 3, 3, 2), SubmitOptions::interactive()).expect("admitted");
    assert_eq!(bulk.id, 1);
    assert_eq!(inter.id, 2);

    let order: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::new()));
    let mut joins = Vec::new();
    for h in [blocker, bulk, inter] {
        let order = Arc::clone(&order);
        joins.push(std::thread::spawn(move || {
            let id = h.id;
            let (result, _) = h.wait();
            assert!(result.is_ok(), "request {id} failed");
            order.lock().unwrap().push(id);
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let order = order.lock().unwrap().clone();
    let pos = |id: u64| order.iter().position(|&x| x == id).unwrap();
    assert!(
        pos(2) < pos(1),
        "interactive (id 2) must complete before bulk (id 1); order: {order:?}"
    );
    router.shutdown();
}

// ---------------------------------------------------------------- (e) --

#[test]
fn close_drains_accepted_requests_and_refuses_new_ones() {
    let model = tiny_model(1, 17);
    let m = model.clone();
    let router = Router::start(
        move |_| {
            DiTEngine::new(MiniMMDiT::new(m.cfg.clone(), m.w.clone()), Policy::full(), 8, 8)
        },
        RouterConfig::new(1, 2),
    );
    let handles: Vec<_> = (0..3u64)
        .map(|id| {
            router.submit(request(id, 1 + id as usize, id, 3), SubmitOptions::interactive())
                .expect("admitted")
        })
        .collect();
    router.close();
    match router.submit(request(9, 9, 9, 3), SubmitOptions::interactive()) {
        Err(Rejected::Closed) => {}
        other => panic!("submit after close must return Closed, got {:?}", other.map(|h| h.id)),
    }
    for h in handles {
        let id = h.id;
        let (result, _) = h.wait();
        result.unwrap_or_else(|e| panic!("accepted request {id} must drain on close, got: {e}"));
    }
    router.shutdown();
    // Every permit must have been returned.
}

#[test]
fn request_events_end_with_exactly_one_terminal() {
    let model = tiny_model(1, 19);
    let m = model.clone();
    let mut cfg = RouterConfig::new(1, 1);
    cfg.preview_interval = 2;
    let router = Router::start(
        move |_| {
            DiTEngine::new(MiniMMDiT::new(m.cfg.clone(), m.w.clone()), Policy::full(), 8, 8)
        },
        cfg,
    );
    let handle = router.submit(request(0, 4, 21, 5), SubmitOptions::interactive()).unwrap();
    let mut terminals = 0;
    let mut previews_after_terminal = false;
    while let Some(ev) = handle.recv() {
        match ev {
            RequestEvent::Preview(_) => previews_after_terminal = terminals > 0,
            RequestEvent::Done(_) | RequestEvent::Rejected(_) => terminals += 1,
        }
    }
    assert_eq!(terminals, 1, "exactly one terminal event per request");
    assert!(!previews_after_terminal, "previews never follow the terminal event");
    router.shutdown();
}

#[test]
fn bulk_only_load_is_still_served() {
    // Priority is strict, but with no interactive traffic bulk drains
    // normally (no accidental starvation of an all-bulk queue).
    let model = tiny_model(1, 23);
    let m = model.clone();
    let router = Router::start(
        move |_| {
            DiTEngine::new(MiniMMDiT::new(m.cfg.clone(), m.w.clone()), Policy::full(), 8, 8)
        },
        RouterConfig::new(1, 2),
    );
    let handles: Vec<_> = (0..3u64)
        .map(|id| {
            router
                .submit(request(id, 1 + id as usize, id, 2), SubmitOptions::bulk())
                .expect("admitted")
        })
        .collect();
    for h in handles {
        assert!(h.wait().0.is_ok());
    }
    assert_eq!(router.in_flight(), 0, "all permits returned after completion");
    // Priority::default() is Interactive — pin it so SubmitOptions built
    // via Default keep latency-sensitive semantics.
    assert_eq!(Priority::default(), Priority::Interactive);
    router.shutdown();
}
