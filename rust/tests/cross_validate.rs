//! Cross-validation: the native rust engine must reproduce the JAX/Pallas
//! goldens in `artifacts/golden.fot` (produced by `make artifacts`), and
//! the PJRT oracle path must execute the AOT artifacts to the same values.
//!
//! These tests are skipped (with a loud message) when `artifacts/` has not
//! been built yet — run `make artifacts` first.

use flashomni::config::ModelConfig;
use flashomni::kernels::attention::flashomni_attention;
use flashomni::kernels::gemm_o::{gemm_o_dispatch, WeightPanels};
use flashomni::kernels::gemm_q::gemm_q;
use flashomni::model::MiniMMDiT;
use flashomni::plan::{DecodeMode, HeadPlan, SparsePlan};
use flashomni::symbols::{BitSymbols, HeadSymbols, LayerSymbols};
use flashomni::tensor::Tensor;
use flashomni::util::fot::FotFile;

fn artifacts_dir() -> Option<String> {
    for dir in ["artifacts", "../artifacts"] {
        if std::path::Path::new(dir).join("golden.fot").exists() {
            return Some(dir.to_string());
        }
    }
    eprintln!("SKIP: artifacts/golden.fot not found — run `make artifacts`");
    None
}

fn head_syms_from_packed(s_c: &[u8], s_s: &[u8], qg: usize, kg: usize) -> HeadSymbols {
    let ss_bytes_per_row = kg.div_ceil(8);
    // golden s_s is row-packed [qg, bytes]; flatten to a row-major bitmask.
    let mut m_s = Vec::with_capacity(qg * kg);
    for i in 0..qg {
        let row = BitSymbols::from_bytes(
            s_s[i * ss_bytes_per_row..(i + 1) * ss_bytes_per_row].to_vec(),
            kg,
        );
        m_s.extend(row.to_bits());
    }
    let m_c = BitSymbols::from_bytes(s_c.to_vec(), qg).to_bits();
    HeadSymbols::from_masks(&m_c, &m_s, kg, 1)
}

#[test]
fn native_attention_matches_pallas_golden() {
    let Some(dir) = artifacts_dir() else { return };
    let g = FotFile::load(format!("{dir}/golden.fot")).unwrap();
    let q = Tensor::from_fot(&g, "attn.q").unwrap();
    let k = Tensor::from_fot(&g, "attn.k").unwrap();
    let v = Tensor::from_fot(&g, "attn.v").unwrap();
    let want = Tensor::from_fot(&g, "attn.out").unwrap();
    let block = g.get("attn.block").unwrap();
    // block stored as i32 pair
    let bq = i32::from_le_bytes(block.data[0..4].try_into().unwrap()) as usize;
    let bk = i32::from_le_bytes(block.data[4..8].try_into().unwrap()) as usize;
    let (n, _d) = (q.rows(), q.cols());
    let (qg, kg) = (n.div_ceil(bq), n.div_ceil(bk));
    let s_c = g.get("attn.s_c").unwrap().to_u8().unwrap();
    let s_s = g.get("attn.s_s").unwrap().to_u8().unwrap();
    let sym = head_syms_from_packed(&s_c, &s_s, qg, kg);
    let plan = HeadPlan::from_symbols(&sym, qg, kg, DecodeMode::RowCached);
    let (got, stats) = flashomni_attention(&q, &k, &v, &plan, bq, bk, None);
    assert!(stats.computed_pairs < stats.total_pairs, "golden symbols should skip work");
    let diff = got.max_abs_diff(&want);
    assert!(diff < 5e-5, "native attention vs Pallas golden: max diff {diff}");
}

#[test]
fn native_gemm_q_matches_pallas_golden() {
    let Some(dir) = artifacts_dir() else { return };
    let g = FotFile::load(format!("{dir}/golden.fot")).unwrap();
    let x = Tensor::from_fot(&g, "gq.x").unwrap();
    let w = Tensor::from_fot(&g, "gq.w").unwrap();
    let want = Tensor::from_fot(&g, "gq.out").unwrap();
    let s_c = g.get("gq.s_c").unwrap();
    let heads = s_c.shape[0];
    let bytes = s_c.shape[1];
    let bq = 8;
    let qg = x.rows() / bq;
    let packed = s_c.to_u8().unwrap();
    let syms = LayerSymbols {
        heads: (0..heads)
            .map(|h| {
                let m_c =
                    BitSymbols::from_bytes(packed[h * bytes..(h + 1) * bytes].to_vec(), qg)
                        .to_bits();
                HeadSymbols::from_masks(&m_c, &vec![true; qg * qg], qg, 1)
            })
            .collect(),
    };
    let plan = SparsePlan::compile(&syms, qg, qg, bq, bq, DecodeMode::RowCached);
    let (got, _) = gemm_q(&x, &w, &plan, None);
    let diff = got.max_abs_diff(&want);
    assert!(diff < 5e-4, "native GEMM-Q vs Pallas golden: max diff {diff}");
}

#[test]
fn native_gemm_o_matches_pallas_golden() {
    let Some(dir) = artifacts_dir() else { return };
    let g = FotFile::load(format!("{dir}/golden.fot")).unwrap();
    let o = Tensor::from_fot(&g, "go.o").unwrap();
    let w = Tensor::from_fot(&g, "go.w").unwrap();
    let bias = Tensor::from_fot(&g, "go.bias").unwrap();
    let want = Tensor::from_fot(&g, "go.out").unwrap();
    let s_c = g.get("gq.s_c").unwrap(); // same symbols as gemm-q golden
    let heads = s_c.shape[0];
    let bytes = s_c.shape[1];
    let bq = 8;
    let qg = o.rows() / bq;
    let packed = s_c.to_u8().unwrap();
    let syms = LayerSymbols {
        heads: (0..heads)
            .map(|h| {
                let m_c =
                    BitSymbols::from_bytes(packed[h * bytes..(h + 1) * bytes].to_vec(), qg)
                        .to_bits();
                HeadSymbols::from_masks(&m_c, &vec![true; qg * qg], qg, 1)
            })
            .collect(),
    };
    let panels = WeightPanels::new(&w, heads);
    let plan = SparsePlan::compile(&syms, qg, qg, bq, bq, DecodeMode::RowCached);
    let (got, _) = gemm_o_dispatch(&o, &panels, &plan, &bias);
    let diff = got.max_abs_diff(&want);
    assert!(diff < 1e-3, "native GEMM-O vs Pallas golden: max diff {diff}");
}

#[test]
fn native_model_matches_jax_golden_step() {
    // The strongest cross-check: the full rust MiniMMDiT forward on the
    // trained weights equals the JAX forward (recorded in the golden).
    let Some(dir) = artifacts_dir() else { return };
    let g = FotFile::load(format!("{dir}/golden.fot")).unwrap();
    let model = MiniMMDiT::load(&format!("{dir}/weights.fot")).unwrap();
    let ids_raw = g.get("mmdit.ids").unwrap();
    let ids: Vec<usize> = ids_raw
        .data
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]) as usize)
        .collect();
    let patches = Tensor::from_fot(&g, "mmdit.patches").unwrap();
    let want = Tensor::from_fot(&g, "mmdit.velocity").unwrap();
    let got = model.forward_dense(&ids, &patches, 0.5);
    let rel = got.rel_l2(&want);
    assert!(
        rel < 1e-4,
        "rust model vs JAX model rel-L2 {rel} (max abs diff {})",
        got.max_abs_diff(&want)
    );
}

#[test]
fn weights_config_matches_mini() {
    let Some(dir) = artifacts_dir() else { return };
    let model = MiniMMDiT::load(&format!("{dir}/weights.fot")).unwrap();
    assert_eq!(model.cfg, ModelConfig::mini());
    assert!(model.param_count() > 1_000_000);
}
