//! Ragged-batching acceptance tests (tentpole PR):
//!
//! (a) kernel level — `gemm_q_ragged` / `flashomni_attention_ragged` /
//!     `gemm_o_dispatch_ragged` walking one concatenated token buffer
//!     with cu-seqlen offsets are **bitwise-identical** per request to
//!     the solo kernels, at odd sequence lengths (ragged last blocks,
//!     SIMD lane-padding edges under whatever `FO_SIMD` selects),
//! (b) engine level — a mixed-resolution batch (per-request `patch_hw`
//!     overrides) produces images and compute stats bitwise-identical to
//!     per-request solo `DiTEngine` runs,
//! (c) the token-budget packer — over-budget rejection, refresh-boundary
//!     admission under a budget, and non-stalling retirement that
//!     returns tokens to the budget.

use flashomni::batch::{BatchScheduler, BatchedEngine};
use flashomni::config::{ModelConfig, SparsityConfig};
use flashomni::engine::{DiTEngine, Policy, RunStats};
use flashomni::exec::ExecPool;
use flashomni::kernels::attention::{flashomni_attention, flashomni_attention_ragged};
use flashomni::kernels::gemm_o::{gemm_o_dispatch, gemm_o_dispatch_ragged, WeightPanels};
use flashomni::kernels::gemm_q::{gemm_q, gemm_q_ragged};
use flashomni::model::blocks::{extract_head, vstack_all};
use flashomni::model::{weights::Weights, MiniMMDiT};
use flashomni::plan::{DecodeMode, SparsePlan};
use flashomni::symbols::{HeadSymbols, LayerSymbols};
use flashomni::tensor::Tensor;
use flashomni::testutil::{prop_check, rand_mask, randn};
use flashomni::workload::{caption_ids, Request};
use flashomni::util::rng::Pcg32;
use std::time::Instant;

fn random_layer_syms(rng: &mut Pcg32, heads: usize, qg: usize, kg: usize) -> LayerSymbols {
    LayerSymbols {
        heads: (0..heads)
            .map(|_| {
                let m_c = rand_mask(rng, qg, 0.6);
                let m_s = rand_mask(rng, qg * kg, 0.5);
                HeadSymbols::from_masks(&m_c, &m_s, kg, 1)
            })
            .collect(),
    }
}

// ---------------------------------------------------------------- (a) --

#[test]
fn ragged_kernels_bitwise_equal_solo_at_odd_lengths() {
    let pool = ExecPool::global();
    prop_check("ragged kernels == per-request solo kernels", 8, |rng| {
        let heads = 1 + rng.below(3);
        let d_h = 4 + rng.below(5);
        let (bq, bk) = (8usize, 8usize);
        let batch = 2 + rng.below(3);
        let d_in = 6 + rng.below(6);
        let d_out = 5 + rng.below(7);
        // Odd per-request lengths: ragged last blocks + lane-padding edges.
        let ns: Vec<usize> = (0..batch).map(|_| 7 + rng.below(57)).collect();
        let plans: Vec<SparsePlan> = ns
            .iter()
            .map(|&n| {
                let (t_q, t_kv) = (n.div_ceil(bq), n.div_ceil(bk));
                let syms = random_layer_syms(rng, heads, t_q, t_kv);
                SparsePlan::compile(&syms, t_q, t_kv, bq, bk, DecodeMode::RowCached)
            })
            .collect();
        let plan_refs: Vec<&SparsePlan> = plans.iter().collect();
        let mut indptr = vec![0usize];
        for (i, &n) in ns.iter().enumerate() {
            indptr.push(indptr[i] + n);
        }

        // GEMM-Q.
        let xs: Vec<Tensor> = ns.iter().map(|&n| randn(rng, &[n, d_in])).collect();
        let wq = randn(rng, &[d_in, heads * d_h]);
        let x_cat = vstack_all(&xs.iter().collect::<Vec<_>>());
        let ragged_q = gemm_q_ragged(&x_cat, &indptr, &wq, &plan_refs, None, &pool);
        for (r, x) in xs.iter().enumerate() {
            let (ys, ss) = gemm_q(x, &wq, &plans[r], None);
            assert_eq!(ys.data(), ragged_q[r].0.data(), "gemm_q request {r} (n={})", ns[r]);
            assert_eq!(ss.computed_tiles, ragged_q[r].1.computed_tiles);
        }

        // Attention.
        let qs: Vec<Tensor> = ns.iter().map(|&n| randn(rng, &[n, heads * d_h])).collect();
        let ks: Vec<Tensor> = ns.iter().map(|&n| randn(rng, &[n, heads * d_h])).collect();
        let vs: Vec<Tensor> = ns.iter().map(|&n| randn(rng, &[n, heads * d_h])).collect();
        let q_cat = vstack_all(&qs.iter().collect::<Vec<_>>());
        let k_cat = vstack_all(&ks.iter().collect::<Vec<_>>());
        let v_cat = vstack_all(&vs.iter().collect::<Vec<_>>());
        let ragged_a =
            flashomni_attention_ragged(&q_cat, &k_cat, &v_cat, &indptr, &plan_refs, &pool);
        for r in 0..batch {
            for h in 0..heads {
                let (oh, st) = flashomni_attention(
                    &extract_head(&qs[r], heads, h),
                    &extract_head(&ks[r], heads, h),
                    &extract_head(&vs[r], heads, h),
                    &plans[r].heads[h],
                    bq,
                    bk,
                    None,
                );
                assert_eq!(oh.data(), ragged_a[r][h].0.data(), "attention req {r} head {h}");
                assert_eq!(st.computed_pairs, ragged_a[r][h].1.computed_pairs);
            }
        }

        // GEMM-O dispatch (cached bias path).
        let os: Vec<Tensor> = ns.iter().map(|&n| randn(rng, &[n, heads * d_h])).collect();
        let wo = randn(rng, &[heads * d_h, d_out]);
        let panels = WeightPanels::new(&wo, heads);
        let biases: Vec<Tensor> = ns.iter().map(|&n| randn(rng, &[n, d_out])).collect();
        let o_cat = vstack_all(&os.iter().collect::<Vec<_>>());
        let bias_refs: Vec<&Tensor> = biases.iter().collect();
        let ragged_o =
            gemm_o_dispatch_ragged(&o_cat, &indptr, &panels, &plan_refs, &bias_refs, &pool);
        for r in 0..batch {
            let (solo, ss) = gemm_o_dispatch(&os[r], &panels, &plans[r], &biases[r]);
            assert_eq!(solo.data(), ragged_o[r].0.data(), "gemm_o_dispatch request {r}");
            assert_eq!(ss.computed_tiles, ragged_o[r].1.computed_tiles);
        }
    });
}

// ---------------------------------------------------------------- (b) --

fn tiny_model(layers: usize, seed: u64) -> MiniMMDiT {
    let cfg = ModelConfig {
        dim: 32,
        heads: 2,
        layers,
        text_tokens: 8,
        patch_h: 4,
        patch_w: 4,
        patch_size: 2,
        channels: 3,
        mlp_ratio: 2,
        vocab: 256,
    };
    MiniMMDiT::new(cfg.clone(), Weights::random(&cfg, seed))
}

fn fo_policy(interval: usize, warmup: usize) -> Policy {
    Policy::flashomni(SparsityConfig {
        tau_q: 0.6,
        tau_kv: 0.3,
        interval,
        order: 1,
        s_q: 0.0,
        block_q: 8,
        block_k: 8,
        pool: 1,
        warmup,
        ramp_steps: 1,
    })
}

fn request(id: u64, scene: usize, seed: u64, steps: usize, hw: Option<(usize, usize)>) -> Request {
    Request {
        id,
        scene,
        prompt_ids: caption_ids(scene, 8),
        seed,
        steps,
        arrival_s: 0.0,
        patch_hw: hw,
    }
}

/// Solo reference at the request's own resolution: same weights, config
/// with the `patch_hw` override applied.
fn solo_at(model: &MiniMMDiT, policy: &Policy, req: &Request) -> (Tensor, RunStats) {
    let mut cfg = model.cfg.clone();
    if let Some((ph, pw)) = req.patch_hw {
        cfg.patch_h = ph;
        cfg.patch_w = pw;
    }
    let m = MiniMMDiT::new(cfg, model.w.clone());
    let mut engine = DiTEngine::new(m, policy.clone(), 8, 8);
    let res = engine.generate(&req.prompt_ids, req.seed, req.steps);
    (res.image, res.stats)
}

fn assert_same_compute(batched: &RunStats, solo: &RunStats) {
    assert_eq!(batched.attn_computed_pairs, solo.attn_computed_pairs);
    assert_eq!(batched.attn_total_pairs, solo.attn_total_pairs);
    assert_eq!(batched.gq_computed, solo.gq_computed);
    assert_eq!(batched.gq_total, solo.gq_total);
    assert_eq!(batched.go_computed, solo.go_computed);
    assert_eq!(batched.go_total, solo.go_total);
    assert_eq!(batched.total_layer_steps, solo.total_layer_steps);
    assert_eq!(batched.per_step_density, solo.per_step_density);
}

#[test]
fn mixed_resolution_batch_bitwise_equals_solo() {
    // Four resolutions in one batch — native 4×4 (seq 24), 6×4 (seq 32),
    // 6×6 (seq 44: ragged joint blocks), 8×8 (seq 72) — with distinct
    // prompts and seeds. Every request must match its solo run at its own
    // resolution bit-for-bit, images and compute accounting alike.
    let model = tiny_model(2, 11);
    let policy = fo_policy(3, 2);
    let reqs: Vec<Request> = [None, Some((6, 4)), Some((6, 6)), Some((8, 8))]
        .into_iter()
        .enumerate()
        .map(|(i, hw)| request(i as u64, 3 * i + 1, 100 + i as u64, 9, hw))
        .collect();
    let mut engine = BatchedEngine::new(model.clone(), policy.clone(), 8, 8, reqs.len());
    for r in &reqs {
        assert!(engine.can_admit());
        engine.admit(r.clone(), Instant::now());
    }
    let expected_tokens: usize = [24, 32, 44, 72].iter().sum();
    assert_eq!(engine.tokens_in_flight(), expected_tokens);
    let mut out = engine.run_to_completion();
    out.sort_by_key(|r| r.id);
    assert_eq!(out.len(), reqs.len());
    for (b, req) in out.iter().zip(&reqs) {
        let (img, stats) = solo_at(&model, &policy, req);
        assert_eq!(b.image, img, "request {} (patch {:?}) differs from solo", b.id, req.patch_hw);
        assert_same_compute(&b.stats, &stats);
    }
}

#[test]
fn native_resolution_override_is_identity() {
    // `patch_hw: Some(native)` must behave exactly like `None`.
    let model = tiny_model(1, 7);
    let policy = fo_policy(3, 1);
    let base = request(0, 5, 42, 7, None);
    let forced = request(1, 5, 42, 7, Some((4, 4)));
    let mut engine = BatchedEngine::new(model.clone(), policy.clone(), 8, 8, 2);
    engine.admit(base, Instant::now());
    engine.admit(forced, Instant::now());
    let out = engine.run_to_completion();
    assert_eq!(out[0].image, out[1].image);
}

// ---------------------------------------------------------------- (c) --

#[test]
fn token_budget_rejects_over_budget_admissions() {
    // seq = 24 tokens per request at the native grid; a budget of 2×seq
    // admits exactly two, the third waits (FIFO, no reordering).
    let model = tiny_model(1, 3);
    let seq = model.cfg.seq_len();
    let engine = BatchedEngine::new(model.clone(), Policy::full(), 8, 8, 8);
    let mut sched = BatchScheduler::with_token_budget(engine, 2 * seq);
    assert_eq!(sched.token_budget(), 2 * seq);
    for id in 0..3u64 {
        sched.submit(request(id, 1 + id as usize, id, 2, None));
    }
    let _ = sched.step();
    assert_eq!(sched.active(), 2, "budget 2×seq admits exactly two");
    assert_eq!(sched.pending_len(), 1);
    assert_eq!(sched.engine().tokens_in_flight(), 2 * seq);
    let done = sched.run_to_completion();
    assert_eq!(done.len(), 2 + 1, "the queued request is served once budget frees");
}

#[test]
fn oversized_request_runs_solo_instead_of_stalling() {
    // A request bigger than the whole budget must still run (alone) —
    // otherwise the queue deadlocks.
    let model = tiny_model(1, 3);
    let seq = model.cfg.seq_len();
    let engine = BatchedEngine::new(model.clone(), Policy::full(), 8, 8, 8);
    let mut sched = BatchScheduler::with_token_budget(engine, seq / 2);
    sched.submit(request(0, 1, 5, 2, None));
    sched.submit(request(1, 2, 6, 2, None));
    let _ = sched.step();
    assert_eq!(sched.active(), 1, "oversized request admitted solo into an empty engine");
    assert_eq!(sched.pending_len(), 1);
    let done = sched.run_to_completion();
    assert_eq!(done.len(), 2);
}

#[test]
fn token_budget_admission_waits_for_refresh_boundary() {
    // Fitting the budget is necessary but not sufficient: admission still
    // only happens when every in-flight slot is about to run a Full step.
    let model = tiny_model(1, 5);
    let policy = fo_policy(3, 1); // kinds: W U D D U D D ...
    let engine = BatchedEngine::new(model.clone(), policy, 8, 8, 4);
    let mut sched = BatchScheduler::with_token_budget(engine, 10 * model.cfg.seq_len());
    sched.submit(request(0, 1, 9, 8, None));
    let _ = sched.step(); // step 0 (Warmup); next is Update → boundary
    sched.submit(request(1, 2, 10, 8, None));
    let _ = sched.step();
    assert_eq!(sched.active(), 2, "budget-fitting request admitted at the Update boundary");
    // Mid-window submission must wait even though it fits the budget.
    sched.submit(request(2, 3, 11, 8, None));
    let _ = sched.step();
    assert_eq!(sched.active(), 2, "mid-Dispatch arrival stays pending");
    assert_eq!(sched.pending_len(), 1);
    let done = sched.run_to_completion();
    assert_eq!(done.len(), 3);
}

#[test]
fn oversized_request_is_not_starved_by_steady_small_traffic() {
    // Head-of-line fairness: an oversized request (cost > whole budget,
    // admissible only into an empty engine) sits at the front while small
    // requests keep arriving behind it. FIFO discipline must hold the
    // smalls back, drain the engine, run the oversized solo, then resume
    // — the oversized request may wait, but never forever.
    let model = tiny_model(1, 3);
    let small = model.cfg.seq_len(); // 24 tokens at the native 4×4 grid
    let engine = BatchedEngine::new(model.clone(), Policy::full(), 8, 8, 8);
    let mut sched = BatchScheduler::with_token_budget(engine, 2 * small);
    sched.submit(request(0, 1, 5, 4, None)); // small, in flight
    sched.submit(request(1, 2, 6, 4, None)); // small, in flight
    let _ = sched.step();
    assert_eq!(sched.active(), 2);
    // Oversized (8×8 grid → 72 tokens > 48 budget) joins the queue, then
    // steady small traffic keeps arriving behind it.
    sched.submit(request(2, 3, 7, 2, Some((8, 8))));
    sched.submit(request(3, 4, 8, 2, None));
    let _ = sched.step();
    sched.submit(request(4, 5, 9, 2, None));
    let _ = sched.step();
    // No small request ever jumps the oversized head-of-line: the engine
    // drains to empty, then the oversized runs alone.
    let mut saw_solo_oversized = false;
    let mut done = Vec::new();
    for _ in 0..200 {
        if sched.is_idle() {
            break;
        }
        done.extend(sched.step());
        if sched.active() == 1 && sched.engine().tokens_in_flight() > 2 * small {
            saw_solo_oversized = true;
        }
    }
    assert!(saw_solo_oversized, "the oversized request must get its solo slot");
    let mut ids: Vec<u64> = done.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, vec![0, 1, 2, 3, 4], "nothing starves, nothing is lost");
    // FIFO held: the trailing smalls (3, 4) finished after the oversized.
    let pos = |id: u64| done.iter().position(|r| r.id == id).unwrap();
    assert!(pos(2) < pos(3) && pos(2) < pos(4));
}

#[test]
fn deadline_expired_head_releases_its_budget_claim() {
    // An oversized request at the front of the queue blocks everything
    // behind it (head-of-line discipline). If its deadline expires while
    // it waits, the next tick must retire it unserved — releasing its
    // head-of-line claim so the requests behind it are admitted — and
    // surface it through `take_expired`.
    let model = tiny_model(1, 3);
    let small = model.cfg.seq_len();
    let engine = BatchedEngine::new(model.clone(), Policy::full(), 8, 8, 8);
    let mut sched = BatchScheduler::with_token_budget(engine, 2 * small);
    sched.submit(request(0, 1, 5, 6, None)); // small, long-running
    let _ = sched.step();
    assert_eq!(sched.active(), 1);
    // Oversized front with an already-passed deadline; a small behind it.
    let now = Instant::now();
    sched.submit_with_deadline(request(1, 2, 6, 2, Some((8, 8))), now, Some(now));
    sched.submit(request(2, 3, 7, 2, None));
    let _ = sched.step();
    // The expired head is gone and the small behind it was admitted in
    // the same tick — it did not wait for the engine to drain.
    let expired = sched.take_expired();
    assert_eq!(expired.len(), 1);
    assert_eq!(expired[0].req.id, 1);
    assert_eq!(sched.active(), 2, "the small behind the expired head joined immediately");
    assert_eq!(sched.pending_len(), 0);
    let done = sched.run_to_completion();
    let mut ids: Vec<u64> = done.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, vec![0, 2], "the expired request never consumed a batch slot");
    assert!(sched.take_expired().is_empty(), "take_expired drains");
}

#[test]
fn retirement_frees_budget_without_stalling() {
    // A short request retires mid-flight and returns its tokens; the
    // waiting request joins without the long request ever pausing.
    let model = tiny_model(1, 3);
    let seq = model.cfg.seq_len();
    let engine = BatchedEngine::new(model.clone(), Policy::full(), 8, 8, 8);
    let mut sched = BatchScheduler::with_token_budget(engine, 2 * seq);
    sched.submit(request(0, 1, 5, 2, None)); // short
    sched.submit(request(1, 2, 6, 6, None)); // long
    sched.submit(request(2, 3, 7, 2, None)); // waits on budget
    let mut done = sched.step();
    assert_eq!(sched.active(), 2);
    done.extend(sched.step()); // short request finishes its 2nd step
    assert!(done.iter().any(|r| r.id == 0), "short request retired");
    assert_eq!(sched.engine().tokens_in_flight(), seq, "its tokens returned to the budget");
    done.extend(sched.step());
    assert_eq!(sched.active(), 2, "waiting request admitted as soon as budget freed");
    done.extend(sched.run_to_completion());
    let mut ids: Vec<u64> = done.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, vec![0, 1, 2]);
    // The long request ran all its steps despite churn around it.
    assert_eq!(done.iter().find(|r| r.id == 1).unwrap().stats.per_step_density.len(), 6);
}
