//! Robustness & failure-injection tests: parser fuzzing, corrupted
//! artifacts, coordinator invariants under concurrency, and engine
//! behaviour on degenerate inputs.

use flashomni::config::{ModelConfig, SparsityConfig};
use flashomni::coordinator::{Coordinator, ServeReport};
use flashomni::engine::{DiTEngine, Policy};
use flashomni::model::{weights::Weights, MiniMMDiT};
use flashomni::router::{Rejected, Router, RouterConfig, SubmitOptions};
use flashomni::workload::{poisson_trace, Request};
use flashomni::util::fot::FotFile;
use flashomni::util::json::Json;
use flashomni::util::rng::Pcg32;

#[test]
fn json_parser_never_panics_on_fuzz() {
    // Random byte soup + mutated valid documents: parse must return
    // Ok/Err, never panic or loop.
    let mut rng = Pcg32::seeded(0xf422);
    let seed_docs = [
        r#"{"a":[1,2,{"b":null}],"c":"x"}"#,
        r#"[true,false,1e9,"é"]"#,
        r#"{"nested":{"deep":[[[{"k":1}]]]}}"#,
    ];
    for case in 0..500 {
        let mut bytes: Vec<u8> = if case % 2 == 0 {
            seed_docs[case % seed_docs.len()].as_bytes().to_vec()
        } else {
            (0..rng.below(64)).map(|_| rng.next_u32() as u8).collect()
        };
        // Mutate a few bytes.
        for _ in 0..rng.below(4) {
            if !bytes.is_empty() {
                let i = rng.below(bytes.len());
                bytes[i] = rng.next_u32() as u8;
            }
        }
        let text = String::from_utf8_lossy(&bytes).into_owned();
        let _ = Json::parse(&text); // must not panic
    }
}

#[test]
fn fot_parser_never_panics_on_corruption() {
    let mut f = FotFile::new();
    f.insert_f32("w", &[4, 4], &[0.5; 16]);
    f.insert_u8("sym", &[3], &[224, 235, 197]);
    let good = f.to_bytes();
    let mut rng = Pcg32::seeded(0xc044);
    for _ in 0..300 {
        let mut bytes = good.clone();
        // Corrupt length-prefix, header, or payload bytes.
        for _ in 0..1 + rng.below(6) {
            let i = rng.below(bytes.len());
            bytes[i] = rng.next_u32() as u8;
        }
        let _ = FotFile::from_bytes(&bytes); // Ok or Err, never panic
        // Truncations too.
        let cut = rng.below(bytes.len());
        let _ = FotFile::from_bytes(&bytes[..cut]);
    }
}

#[test]
fn weights_loader_rejects_missing_tensor() {
    let cfg = ModelConfig {
        dim: 16,
        heads: 2,
        layers: 1,
        text_tokens: 4,
        patch_h: 2,
        patch_w: 2,
        patch_size: 2,
        channels: 3,
        mlp_ratio: 2,
        vocab: 8,
    };
    let w = Weights::random(&cfg, 1);
    let mut f = w.to_fot();
    f.tensors.remove("blocks.0.txt.wq");
    let err = Weights::from_fot(&f).unwrap_err();
    assert!(err.contains("blocks.0.txt.wq"), "error should name the tensor: {err}");
}

fn tiny_engine(_wid: usize) -> DiTEngine {
    let cfg = ModelConfig {
        dim: 32,
        heads: 2,
        layers: 1,
        text_tokens: 8,
        patch_h: 4,
        patch_w: 4,
        patch_size: 2,
        channels: 3,
        mlp_ratio: 2,
        vocab: 256,
    };
    DiTEngine::new(
        MiniMMDiT::new(cfg.clone(), Weights::random(&cfg, 1)),
        Policy::flashomni(SparsityConfig::paper(0.5, 0.15, 3, 1, 0.0)),
        8,
        8,
    )
}

#[test]
fn coordinator_multi_worker_no_lost_or_duplicated_requests() {
    // Property: every submitted request id comes back exactly once, under
    // multiple workers and mixed step counts in one ragged batch.
    let coord = Coordinator::start(tiny_engine, 3, 2);
    let mut expected = Vec::new();
    for i in 0..24u64 {
        let steps = if i % 3 == 0 { 4 } else { 3 };
        coord.submit(Request {
            id: i,
            scene: i as usize,
            prompt_ids: vec![(i % 200) as usize; 8],
            seed: i,
            steps,
            arrival_s: 0.0,
            patch_hw: None,
        });
        expected.push(i);
    }
    let responses = coord.collect(24);
    coord.shutdown();
    let mut got: Vec<u64> = responses.iter().map(|r| r.id).collect();
    got.sort_unstable();
    assert_eq!(got, expected);
    // Mixed step counts ride one ragged batch; shorter requests retire
    // early without corrupting the rest: all images finite, sane latency.
    for r in &responses {
        assert!(r.image.data().iter().all(|x| x.is_finite()));
        assert!(r.latency_s >= r.exec_s);
    }
    let rep = ServeReport::from_responses(&responses, 1.0);
    assert_eq!(rep.requests, 24);
}

#[test]
fn coordinator_results_independent_of_worker_count() {
    // Same requests through 1 and 3 workers → identical images per id
    // (engines are deterministic and per-request state is reset).
    let trace = poisson_trace(5, 6, 1000.0, 3, 8);
    let run = |workers: usize| {
        let coord = Coordinator::start(tiny_engine, workers, 2);
        for r in &trace {
            coord.submit(r.clone());
        }
        let mut rs = coord.collect(trace.len());
        coord.shutdown();
        rs.sort_by_key(|r| r.id);
        rs
    };
    let a = run(1);
    let b = run(3);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.image, y.image, "request {} image differs across worker counts", x.id);
    }
}

#[test]
fn engine_handles_extreme_step_counts() {
    let mut e = tiny_engine(0);
    // 1 step (all warmup), 2 steps, and a long run.
    for steps in [1usize, 2, 30] {
        let r = e.generate(&vec![1; 8], 7, steps);
        assert_eq!(r.stats.per_step_density.len(), steps);
        assert!(r.image.data().iter().all(|x| x.is_finite()), "steps={steps}");
    }
}

#[test]
fn engine_rejects_bad_vocab_ids_loudly() {
    let mut e = tiny_engine(0);
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        e.generate(&vec![usize::MAX; 8], 7, 2);
    }));
    assert!(result.is_err(), "out-of-vocab ids must not silently corrupt");
}

/// A request whose prompt ids are out of vocab — trips the engine's
/// embedding assertion mid-batch (see `engine_rejects_bad_vocab_ids_loudly`).
fn poison_request(id: u64) -> Request {
    Request {
        id,
        scene: id as usize,
        prompt_ids: vec![usize::MAX; 8],
        seed: id,
        steps: 3,
        arrival_s: 0.0,
        patch_hw: None,
    }
}

fn good_request(id: u64) -> Request {
    Request {
        id,
        scene: id as usize,
        prompt_ids: vec![(id % 200) as usize; 8],
        seed: id,
        steps: 3,
        arrival_s: 0.0,
        patch_hw: None,
    }
}

#[test]
fn coordinator_survives_engine_panic_and_keeps_serving() {
    // Regression for the poison-cascade bug: a panicking engine used to
    // take the worker thread down, poisoning the shared queue mutex so
    // close()/Drop re-panicked on `lock().unwrap()` and no later request
    // was ever served. Now the panic is caught, the poisoned request gets
    // a per-request `Err(Rejected::WorkerPanicked)`, the worker rebuilds
    // its engine, and shutdown drains gracefully.
    let coord = Coordinator::start(tiny_engine, 1, 1);
    coord.submit(good_request(0));
    coord.submit(poison_request(1));
    coord.submit(good_request(2));
    let results = coord.collect_results(3);
    let mut ok_ids = Vec::new();
    let mut failed_ids = Vec::new();
    for (id, r) in &results {
        match r {
            Ok(resp) => {
                assert_eq!(resp.id, *id);
                assert!(resp.image.data().iter().all(|x| x.is_finite()));
                ok_ids.push(*id);
            }
            Err(Rejected::WorkerPanicked { message, .. }) => {
                assert!(!message.is_empty(), "panic payload should carry the message");
                failed_ids.push(*id);
            }
            Err(other) => panic!("unexpected rejection for {id}: {other}"),
        }
    }
    ok_ids.sort_unstable();
    assert_eq!(ok_ids, vec![0, 2], "requests after the panic are served by the rebuilt engine");
    assert_eq!(failed_ids, vec![1]);
    // The decisive part of the regression: shutdown after a worker panic
    // must not re-panic on a poisoned lock.
    coord.shutdown();
}

#[test]
fn router_survives_engine_panic_and_returns_permits() {
    let cfg = RouterConfig { workers: 1, max_batch: 1, max_in_flight: 4, queue_cap: 4, preview_interval: 0 };
    let router = Router::start(tiny_engine, cfg);
    let h0 = router.submit(good_request(0), SubmitOptions::interactive()).expect("admitted");
    let h1 = router.submit(poison_request(1), SubmitOptions::interactive()).expect("admitted");
    let h2 = router.submit(good_request(2), SubmitOptions::interactive()).expect("admitted");
    assert!(h0.wait().0.is_ok());
    match h1.wait().0 {
        Err(Rejected::WorkerPanicked { .. }) => {}
        other => panic!("poisoned request must report the worker panic, got {other:?}"),
    }
    assert!(h2.wait().0.is_ok(), "the rebuilt engine serves later requests");
    assert_eq!(router.in_flight(), 0, "every permit (including the panicked one) returned");
    router.shutdown();
}

#[test]
fn sparsity_config_degenerate_values() {
    // τ = 1.0 (cache everything allowed) and interval 1 must not break.
    let cfg = SparsityConfig {
        warmup: 1,
        ramp_steps: 1,
        ..SparsityConfig::paper(1.0, 0.9, 1, 2, 0.0)
    };
    let model = {
        let c = ModelConfig {
            dim: 32,
            heads: 2,
            layers: 1,
            text_tokens: 8,
            patch_h: 4,
            patch_w: 4,
            patch_size: 2,
            channels: 3,
            mlp_ratio: 2,
            vocab: 256,
        };
        MiniMMDiT::new(c.clone(), Weights::random(&c, 2))
    };
    let mut e = DiTEngine::new(model, Policy::flashomni(cfg), 8, 8);
    let r = e.generate(&vec![1; 8], 1, 6);
    assert!(r.image.data().iter().all(|x| x.is_finite()));
}
