//! Execution-runtime acceptance tests (PR 2):
//!
//! (a) `ExecPool::parallel_map` ordering and determinism under varying
//!     pool sizes,
//! (b) pool-backed kernels are **bitwise-identical** to the serial
//!     kernels (GEMM-Q, GEMM-O update/stage1/dispatch, multi-head
//!     attention), and the whole engine is invariant to the pool size,
//! (c) the `PlanCache` hits on repeated symbols, misses on changed
//!     symbols/geometry, and evicts FIFO at capacity,
//! (d) coordinator close semantics: prompt wakeup, full drain.

use flashomni::config::{ModelConfig, SparsityConfig};
use flashomni::coordinator::Coordinator;
use flashomni::engine::{DiTEngine, Policy};
use flashomni::exec::ExecPool;
use flashomni::kernels::attention::flashomni_attention;
use flashomni::kernels::gemm_o::{
    gemm_o_dispatch, gemm_o_dispatch_pool, gemm_o_stage1, gemm_o_stage1_pool, gemm_o_update,
    gemm_o_update_pool, WeightPanels,
};
use flashomni::kernels::gemm_q::{gemm_q, gemm_q_pool};
use flashomni::model::blocks::{extract_head, insert_head};
use flashomni::model::{weights::Weights, MiniMMDiT};
use flashomni::plan::cache::{symbol_key, PlanCache};
use flashomni::plan::{DecodeMode, SparsePlan};
use flashomni::symbols::{HeadSymbols, LayerSymbols};
use flashomni::tensor::Tensor;
use flashomni::testutil::{prop_check, rand_mask, randn};
use flashomni::workload::poisson_trace;
use flashomni::util::rng::Pcg32;
use std::sync::Arc;

fn random_layer_syms(
    rng: &mut Pcg32,
    heads: usize,
    qg: usize,
    kg: usize,
) -> LayerSymbols {
    LayerSymbols {
        heads: (0..heads)
            .map(|_| {
                let m_c = rand_mask(rng, qg, 0.6);
                let m_s = rand_mask(rng, qg * kg, 0.5);
                HeadSymbols::from_masks(&m_c, &m_s, kg, 1)
            })
            .collect(),
    }
}

// ---------------------------------------------------------------- (a) --

#[test]
fn parallel_map_order_invariant_across_pool_sizes() {
    let reference: Vec<u64> = (0..257u64).map(|i| i.wrapping_mul(i) ^ 0xabc).collect();
    for threads in [1, 2, 5, 9] {
        let pool = ExecPool::new(threads);
        let got = pool.parallel_map_indexed(257, |i| (i as u64).wrapping_mul(i as u64) ^ 0xabc);
        assert_eq!(got, reference, "pool size {threads} must not change results");
    }
}

#[test]
fn parallel_map_over_tensors_matches_serial() {
    let mut rng = Pcg32::seeded(7);
    let items: Vec<Tensor> = (0..12).map(|_| randn(&mut rng, &[8, 8])).collect();
    let serial: Vec<f32> =
        items.iter().map(|t| t.data().iter().sum::<f32>()).collect();
    let pool = ExecPool::new(4);
    let pooled = pool.parallel_map(&items, |_, t| t.data().iter().sum::<f32>());
    assert_eq!(serial, pooled);
}

// ---------------------------------------------------------------- (b) --

#[test]
fn pool_kernels_bitwise_match_serial_kernels() {
    let pools: Vec<ExecPool> = vec![ExecPool::new(1), ExecPool::new(2), ExecPool::new(7)];
    prop_check("pool kernels == serial kernels", 12, |rng| {
        let heads = 1 + rng.below(4);
        let d_h = 2 + rng.below(6);
        let b = 4 + rng.below(8);
        let t_q = 2 + rng.below(6);
        let n = t_q * b - rng.below(b.min(2)); // exercise ragged last block
        let t_q = n.div_ceil(b);
        let syms = random_layer_syms(rng, heads, t_q, t_q);
        let plan = SparsePlan::compile(&syms, t_q, t_q, b, b, DecodeMode::RowCached);

        // GEMM-Q.
        let x = randn(rng, &[n, 4 + rng.below(8)]);
        let wq = randn(rng, &[x.cols(), heads * d_h]);
        let (yq, _) = gemm_q(&x, &wq, &plan, None);
        // GEMM-O trio.
        let o = randn(rng, &[n, heads * d_h]);
        let wo = randn(rng, &[heads * d_h, 4 + rng.below(8)]);
        let panels = WeightPanels::new(&wo, heads);
        let (out_s, bias_s, _) = gemm_o_update(&o, &panels, &plan);
        let stage_s = gemm_o_stage1(&o, &panels, &plan);
        let (disp_s, _) = gemm_o_dispatch(&o, &panels, &plan, &bias_s);
        for pool in &pools {
            let (yp, _) = gemm_q_pool(&x, &wq, &plan, None, pool);
            assert_eq!(yq.data(), yp.data(), "gemm_q pool size {}", pool.size());
            let (out_p, bias_p, _) = gemm_o_update_pool(&o, &panels, &plan, pool);
            assert_eq!(out_s.data(), out_p.data(), "gemm_o_update pool {}", pool.size());
            assert_eq!(bias_s.data(), bias_p.data());
            let stage_p = gemm_o_stage1_pool(&o, &panels, &plan, pool);
            assert_eq!(stage_s.data(), stage_p.data());
            let (disp_p, _) = gemm_o_dispatch_pool(&o, &panels, &plan, &bias_s, pool);
            assert_eq!(disp_s.data(), disp_p.data());
        }
    });
}

#[test]
fn pooled_attention_heads_match_serial_loop() {
    let mut rng = Pcg32::seeded(11);
    let (heads, d, b, n) = (4, 8, 8, 32);
    let t = n / b;
    let q = randn(&mut rng, &[n, heads * d]);
    let k = randn(&mut rng, &[n, heads * d]);
    let v = randn(&mut rng, &[n, heads * d]);
    let syms = random_layer_syms(&mut rng, heads, t, t);
    let plan = SparsePlan::compile(&syms, t, t, b, b, DecodeMode::RowCached);
    let run = |h: usize| {
        let qh = extract_head(&q, heads, h);
        let kh = extract_head(&k, heads, h);
        let vh = extract_head(&v, heads, h);
        flashomni_attention(&qh, &kh, &vh, &plan.heads[h], b, b, None).0
    };
    let mut serial = Tensor::zeros(&[n, heads * d]);
    for h in 0..heads {
        insert_head(&mut serial, &run(h), heads, h);
    }
    for threads in [1, 3, 8] {
        let pool = ExecPool::new(threads);
        let per_head = pool.parallel_map_indexed(heads, run);
        let mut pooled = Tensor::zeros(&[n, heads * d]);
        for (h, oh) in per_head.iter().enumerate() {
            insert_head(&mut pooled, oh, heads, h);
        }
        assert_eq!(serial.data(), pooled.data(), "pool size {threads}");
    }
}

fn tiny_model() -> MiniMMDiT {
    let cfg = ModelConfig {
        dim: 32,
        heads: 2,
        layers: 2,
        text_tokens: 8,
        patch_h: 4,
        patch_w: 4,
        patch_size: 2,
        channels: 3,
        mlp_ratio: 2,
        vocab: 16,
    };
    MiniMMDiT::new(cfg.clone(), Weights::random(&cfg, 11))
}

fn sparse_cfg() -> SparsityConfig {
    SparsityConfig {
        tau_q: 0.6,
        tau_kv: 0.3,
        interval: 3,
        order: 1,
        s_q: 0.0,
        block_q: 8,
        block_k: 8,
        pool: 1,
        warmup: 2,
        ramp_steps: 1,
    }
}

#[test]
fn engine_output_invariant_across_pool_sizes() {
    let model = tiny_model();
    let ids: Vec<usize> = (0..model.cfg.text_tokens).collect();
    let mut images: Vec<Tensor> = Vec::new();
    for threads in [1usize, 2, 6] {
        let mut engine =
            DiTEngine::new(model.clone(), Policy::flashomni(sparse_cfg()), 8, 8);
        engine.set_exec_pool(Arc::new(ExecPool::new(threads)));
        let res = engine.generate(&ids, 5, 8);
        assert!(res.image.data().iter().all(|f| f.is_finite()));
        images.push(res.image);
    }
    assert_eq!(images[0], images[1], "pool size must not change the image");
    assert_eq!(images[0], images[2], "pool size must not change the image");
}

// ---------------------------------------------------------------- (c) --

#[test]
fn plan_cache_hits_and_invalidation_across_refreshes() {
    let mut rng = Pcg32::seeded(23);
    let heads = 2;
    let (t_q, t_kv) = (4, 4);
    let compile = |s: &LayerSymbols| SparsePlan::compile(s, t_q, t_kv, 8, 8, DecodeMode::RowCached);
    let syms_a = random_layer_syms(&mut rng, heads, t_q, t_kv);
    let mut syms_b = random_layer_syms(&mut rng, heads, t_q, t_kv);
    // Make sure the second refresh differs in live structure, not just in
    // don't-care bits (an S_s flip inside a cached row changes the symbol
    // bytes but compiles to the same plan).
    while compile(&syms_b) == compile(&syms_a) {
        syms_b = random_layer_syms(&mut rng, heads, t_q, t_kv);
    }
    let mut cache: PlanCache<SparsePlan> = PlanCache::new(8);
    let key_a = symbol_key(&syms_a, &[t_q, t_kv, 8, 8, 0]);
    let key_b = symbol_key(&syms_b, &[t_q, t_kv, 8, 8, 0]);
    let (plan_a, hit) = cache.get_or_compile(&key_a, || compile(&syms_a));
    assert!(!hit);
    // Same symbols re-emitted at the next refresh → hit, same plan.
    let (plan_a2, hit) = cache.get_or_compile(&key_a, || compile(&syms_a));
    assert!(hit);
    assert!(Arc::ptr_eq(&plan_a, &plan_a2));
    // A refresh that flips any mask bit must miss (invalidation-by-key).
    let (plan_b, hit) = cache.get_or_compile(&key_b, || compile(&syms_b));
    assert!(!hit);
    assert_ne!(*plan_a, *plan_b);
    // Same symbols under a different geometry must also miss.
    let key_a_geo = symbol_key(&syms_a, &[t_q, t_kv, 8, 8, 1]);
    let (_, hit) = cache.get_or_compile(&key_a_geo, || compile(&syms_a));
    assert!(!hit);
    let s = cache.stats();
    assert_eq!((s.hits, s.misses), (1, 3));
}

#[test]
fn per_step_mask_policy_runs_with_cache() {
    // SpargeAttn-style per-step masks recompile (or re-hit) every Dispatch
    // step; the run must stay finite and the counters must add up.
    let model = tiny_model();
    let ids: Vec<usize> = (0..model.cfg.text_tokens).collect();
    let mut engine = DiTEngine::new(model, Policy::sparge(0.4, 0.3, 1), 8, 8);
    let res = engine.generate(&ids, 3, 8);
    assert!(res.image.data().iter().all(|f| f.is_finite()));
    let total = res.stats.plan_cache_hits + res.stats.plan_cache_misses;
    assert!(total > 0, "per-step policy must consult the plan cache");
    let cs = engine.plan_cache_stats();
    assert_eq!(cs.hits + cs.misses, total);
}

// ---------------------------------------------------------------- (d) --

fn tiny_engine(_wid: usize) -> DiTEngine {
    let cfg = ModelConfig {
        dim: 32,
        heads: 2,
        layers: 1,
        text_tokens: 8,
        patch_h: 4,
        patch_w: 4,
        patch_size: 2,
        channels: 3,
        mlp_ratio: 2,
        vocab: 256,
    };
    DiTEngine::new(MiniMMDiT::new(cfg.clone(), Weights::random(&cfg, 1)), Policy::full(), 8, 8)
}

#[test]
fn coordinator_drains_then_exits_on_close() {
    let coord = Coordinator::start(tiny_engine, 2, 2);
    let trace = poisson_trace(5, 6, 1000.0, 3, 8);
    for req in &trace {
        coord.submit(req.clone());
    }
    coord.close();
    let responses = coord.collect(6);
    assert_eq!(responses.len(), 6);
    let t0 = std::time::Instant::now();
    coord.shutdown();
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(2),
        "workers must exit promptly after the queue drains"
    );
}

#[test]
fn coordinator_workers_share_engine_pools() {
    // Engines built by the default factory all dispatch on the global
    // pool — same Arc, no per-worker thread sets.
    let e1 = tiny_engine(0);
    let e2 = tiny_engine(1);
    assert!(Arc::ptr_eq(e1.exec_pool(), e2.exec_pool()));
    assert!(Arc::ptr_eq(e1.exec_pool(), &ExecPool::global()));
}
